"""Custom Pallas TPU kernels for the paper's compute hot-spots.

Each kernel lives in its own subpackage with three files:

* ``<name>.py`` — the Pallas kernel (BlockSpecs, grid, VMEM scratch),
* ``ops.py``    — jit'd public wrappers (shape padding, backend glue,
  automatic interpreter mode off-TPU) — the only layer callers touch,
* ``ref.py``    — a pure-jnp oracle the parity tests compare against.

Shared dtype contract: int8 operand tiles in VMEM, int32 (or exactly
fp32-embedded) MAC accumulation, fp32 results out of the fused dequant
epilogue.  Authoring guide and validation recipe: docs/kernels.md.
"""
from repro.kernels.qconv.ops import qconv2d_i8
from repro.kernels.qlstm.ops import qlstm_cell
from repro.kernels.qmac.ops import qmac_i8, qmac_i8_deq
from repro.kernels.vact.ops import vact, vact_q8

__all__ = [
    "qmac_i8",
    "qmac_i8_deq",
    "qconv2d_i8",
    "vact",
    "vact_q8",
    "qlstm_cell",
]
