"""Q-Conv: int8 im2col conv kernel for the stride-2 pixel stem.

The conv is lowered as im2col patch extraction feeding the Q-MAC
blocking scheme: the K*K filter taps become the innermost sequential
grid axis, each tap contributing an int8 x int8 -> int32 tile product
that is dequantized per-pixel and accumulated in an fp32 VMEM scratch,
with the per-out-channel dequant + bias + activation epilogue fused
into the final tap (see docs/kernels.md).
"""
