"""Public wrappers for Q-Conv: tap extraction, padding, backend glue.

Two interchangeable executions of the same integer program:

* ``kernel=False`` (default) — per-tap ``dot_general`` contractions.
  On TPU these are int8 -> int32 MXU dots; off-TPU the integer dot is
  embedded *exactly* in fp32 (every product and channel partial sum is
  an integer < 2^24, so fp32 sgemm returns the same bits as int32
  accumulation — and is the fast CPU path).
* ``kernel=True`` — the Pallas tap-blocked kernel
  (:func:`repro.kernels.qconv.qconv.qconv_i8_taps_kernel`), run in
  interpreter mode automatically off-TPU.

Both run the identical integer program and accumulate dequantized
taps in fp32 in the same (kh-major, kw) order.  Within one execution
context the result is bitwise reproducible — the serve-vs-eval parity
guarantee rides on both sides calling this same function.  Across
backends (Pallas vs XLA lowering) the fp tap accumulation may differ
by FMA contraction, so cross-backend agreement is to ~1 ulp (the
qconv parity suite pins this at rtol=1e-6, matching kernels/qmac).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.qconv import qconv as _k
from repro.kernels.qconv import ref as _ref

# exact fp32 embedding of the int dot needs every channel partial sum
# below 2^24: C * 127 * 127 <= 2^24  =>  C <= 1040
_EXACT_F32_MAX_C = 1040


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _round_block(dim: int) -> int:
    """Largest power-of-two block <= dim (min 8) for small test shapes."""
    b = 8
    while b * 2 <= min(dim, 128):
        b *= 2
    return b


def _pad_axis(x, axis: int, mult: int):
    p = (-x.shape[axis]) % mult
    if p:
        pads = [(0, 0)] * x.ndim
        pads[axis] = (0, p)
        x = jnp.pad(x, pads)
    return x


def _tap_views(qx, sx, kh, kw, stride, ho, wo):
    """The KH*KW shifted strided views of the (padded) input, in the
    kernel's (kh-major, kw) tap order."""
    taps = []
    for di in range(kh):
        for dj in range(kw):
            sl = (slice(None),
                  slice(di, di + (ho - 1) * stride + 1, stride),
                  slice(dj, dj + (wo - 1) * stride + 1, stride),
                  slice(None))
            taps.append((qx[sl], sx[sl]))
    return taps


def _padded(qx, sx, kh, kw, stride, padding):
    b, h, w, _ = qx.shape
    if padding == "SAME":
        ho, (pt, pb) = _ref.same_pads(h, kh, stride)
        wo, (plf, prt) = _ref.same_pads(w, kw, stride)
        pads = ((0, 0), (pt, pb), (plf, prt), (0, 0))
        return jnp.pad(qx, pads), jnp.pad(sx, pads), ho, wo
    if padding == "VALID":
        return qx, sx, _ref.valid_out(h, kh, stride), \
            _ref.valid_out(w, kw, stride)
    raise ValueError(f"unsupported padding {padding!r}")


def qconv2d_i8(qx: jax.Array, sx: jax.Array, qw: jax.Array,
               sw: jax.Array, b: jax.Array, *, stride: int = 1,
               padding: str = "SAME", fuse_relu: bool = False,
               kernel: bool = False,
               interpret: Optional[bool] = None,
               exact_f32: Optional[bool] = None) -> jax.Array:
    """Integer Q-Conv with fused dequant + bias (+ ReLU) epilogue.

    Dtype contract: int8 operands, int32 (or exactly-embedded fp32)
    channel accumulation, fp32 output.  Shapes:

      qx [B, H, W, C] int8      per-pixel quantized activations
      sx [B, H, W, 1] fp32      their per-pixel (rowwise) scales
      qw [KH, KW, C, N] int8    per-out-channel quantized filters
      sw fp32, size 1 or N      the per-out-channel weight scales
      b  [N] fp32               bias
      -> [B, H', W', N] fp32

    ``padding`` is "SAME" or "VALID"; any stride / odd spatial size /
    channel count is handled (the Pallas path auto-pads to tile
    multiples and slices the result back).
    """
    bsz, _, _, c = qx.shape
    kh, kw, _, n = qw.shape
    sw2 = jnp.asarray(sw, jnp.float32).reshape(1, -1)
    b2 = b.astype(jnp.float32).reshape(1, -1)
    qxp, sxp, ho, wo = _padded(qx, sx.astype(jnp.float32), kh, kw,
                               stride, padding)
    taps = _tap_views(qxp, sxp, kh, kw, stride, ho, wo)

    if kernel:
        if interpret is None:
            interpret = _interpret_default()
        m = bsz * ho * wo
        bm = _round_block(m)
        bn = _round_block(n)
        qxt = jnp.stack([t[0].reshape(m, c) for t in taps])
        sxt = jnp.stack([t[1].reshape(m, 1) for t in taps])
        qwt = qw.reshape(kh * kw, c, n)
        qxt = _pad_axis(_pad_axis(qxt, 1, bm), 2, 8)
        sxt = _pad_axis(sxt, 1, bm)
        qwt = _pad_axis(_pad_axis(qwt, 1, 8), 2, bn)
        swp = _pad_axis(jnp.broadcast_to(sw2, (1, n)), 1, bn)
        bp = _pad_axis(b2, 1, bn)
        out = _k.qconv_i8_taps_kernel(qxt, sxt, qwt, swp, bp, bm=bm,
                                      bn=bn, fuse_relu=fuse_relu,
                                      interpret=interpret)
        return out[:m, :n].reshape(bsz, ho, wo, n)

    if exact_f32 is None:
        exact_f32 = (jax.default_backend() != "tpu"
                     and c <= _EXACT_F32_MAX_C)
    dn = (((3,), (0,)), ((), ()))
    acc = jnp.zeros((bsz, ho, wo, n), jnp.float32)
    for t, (xt, st) in enumerate(taps):
        wt = qw.reshape(kh * kw, c, n)[t]
        if exact_f32:
            d = jax.lax.dot_general(xt.astype(jnp.float32),
                                    wt.astype(jnp.float32), dn)
        else:
            d = jax.lax.dot_general(
                xt, wt, dn,
                preferred_element_type=jnp.int32).astype(jnp.float32)
        acc = acc + d * st
    out = acc * sw2.reshape(1, 1, 1, -1) + b2.reshape(1, 1, 1, -1)
    return jnp.maximum(out, 0.0) if fuse_relu else out


# re-export oracle for test convenience
ref_qconv2d_i8 = _ref.qconv2d_i8
