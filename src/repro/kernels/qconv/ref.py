"""Pure-jnp oracle for the Q-Conv kernel.

Deliberately computes the per-tap contraction a *different* way
(broadcast-multiply + sum instead of ``dot_general``): every int8
product and every channel partial sum is an integer below 2^24, so
fp32 holds them exactly and any contraction order gives the same
bits.  Only the fp32 *tap* accumulation is order-sensitive, and the
oracle walks taps in the same (kh-major, kw) order as the kernel, so
eager-mode agreement with the XLA tap path is bitwise; compiled
backends may regroup the fp accumulation into FMAs and land within
1 ulp (asserted at rtol=1e-6, same bar as kernels/qmac).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def same_pads(size: int, k: int, stride: int):
    """SAME output size and (lo, hi) pads for one spatial dim."""
    out = -(-size // stride)
    total = max((out - 1) * stride + k - size, 0)
    return out, (total // 2, total - total // 2)


def valid_out(size: int, k: int, stride: int) -> int:
    return (size - k) // stride + 1


def qconv2d_i8(qx: jax.Array, sx: jax.Array, qw: jax.Array,
               sw: jax.Array, b: jax.Array, *, stride: int = 1,
               padding: str = "SAME",
               fuse_relu: bool = False) -> jax.Array:
    """Integer Q-Conv oracle.

    qx [B,H,W,C] int8, sx [B,H,W,1] fp32 per-pixel scales,
    qw [KH,KW,C,N] int8, sw broadcastable-to-[N] fp32 per-out-channel
    scales, b [N] fp32 -> [B,H',W',N] fp32.
    """
    bsz, h, w, c = qx.shape
    kh, kw, _, n = qw.shape
    if padding == "SAME":
        ho, (pt, pb) = same_pads(h, kh, stride)
        wo, (plf, prt) = same_pads(w, kw, stride)
        qx = jnp.pad(qx, ((0, 0), (pt, pb), (plf, prt), (0, 0)))
        sx = jnp.pad(sx, ((0, 0), (pt, pb), (plf, prt), (0, 0)))
    elif padding == "VALID":
        ho, wo = valid_out(h, kh, stride), valid_out(w, kw, stride)
    else:
        raise ValueError(f"unsupported padding {padding!r}")
    acc = jnp.zeros((bsz, ho, wo, n), jnp.float32)
    for di in range(kh):
        for dj in range(kw):
            xt = qx[:, di:di + (ho - 1) * stride + 1:stride,
                    dj:dj + (wo - 1) * stride + 1:stride, :]
            st = sx[:, di:di + (ho - 1) * stride + 1:stride,
                    dj:dj + (wo - 1) * stride + 1:stride, :]
            # integer contraction over C, embedded exactly in fp32
            prod = (xt.astype(jnp.float32)[..., None]
                    * qw[di, dj].astype(jnp.float32)).sum(axis=3)
            acc = acc + prod * st.astype(jnp.float32)
    out = acc * jnp.asarray(sw, jnp.float32).reshape(1, 1, 1, -1) \
        + b.astype(jnp.float32)
    return jnp.maximum(out, 0.0) if fuse_relu else out
