"""Q-Conv: int8 tap-wise im2col conv Pallas TPU kernel.

The stride-2 pixel stem (paper's Q-Conv block) is lowered onto the
Q-MAC MAC-array adaptation the same way the matmul path is
(kernels/qmac): int8 operand tiles in VMEM, MXU int8 contractions, and
a fused dequant epilogue so the fp32 result never makes an extra HBM
round trip.  The conv-specific part is the im2col layout: instead of
materializing [M, K*K*C] patch rows (which would re-quantize every
pixel K*K times and inflate the activation-scale grid), the patches
are kept *blocked by filter tap* —

    qxt: [T, M, C]   int8   tap-shifted activation views (T = KH*KW)
    sxt: [T, M, 1]   fp32   per-pixel activation scales, same shift
    qwt: [T, C, N]   int8   one [C, N] weight slice per tap

and the tap axis T becomes the innermost sequential grid axis: each
step contributes one int8 x int8 -> int32 tile contraction over C,
dequantized by its per-pixel scale and accumulated into an fp32 VMEM
scratch (classic K-innermost Pallas matmul blocking, with fp32 rather
than int32 carry because the activation scale varies per tap).  The
final tap applies the fused epilogue: per-out-channel weight scale,
bias, and optionally ReLU.

This keeps the activation quantization grid *identical* to the
fake-quant reference path (one scale per input pixel over channels,
``fake_quant_rowwise``) — the property the serve-vs-eval bit-parity
guarantee depends on.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BM = 128
DEFAULT_BN = 128


def _conv_taps_kernel(x_ref, w_ref, sx_ref, sw_ref, b_ref, o_ref,
                      acc_ref, *, fuse_relu):
    """One (bm x bn) output tile; grid axis 2 walks the filter taps."""
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # int8 x int8 -> int32 contraction over the (padded) channel dim,
    # dequantized by the per-pixel activation scale of this tap
    d = jax.lax.dot_general(
        x_ref[0], w_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    acc_ref[...] += d.astype(jnp.float32) * sx_ref[0]

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _flush():
        out = acc_ref[...] * sw_ref[...] + b_ref[...]
        if fuse_relu:
            out = jnp.maximum(out, 0.0)
        o_ref[...] = out


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "fuse_relu", "interpret"))
def qconv_i8_taps_kernel(qxt, sxt, qwt, sw, b, *, bm=DEFAULT_BM,
                         bn=DEFAULT_BN, fuse_relu=False,
                         interpret=False):
    """Tap-blocked im2col Q-Conv: int8 in, int32 MACs, fp32 out.

    Blocking parameters: ``bm`` (output-pixel tile rows) and ``bn``
    (out-channel tile columns) must divide M and N; the (padded)
    channel count C rides whole in each block, and the tap count T is
    the sequential K-style grid axis.

    Shapes / dtypes:
      qxt [T, M, C] int8, sxt [T, M, 1] fp32, qwt [T, C, N] int8,
      sw [1, N] fp32 (per-out-channel), b [1, N] fp32 -> [M, N] fp32.

    M = B*H_out*W_out with zero-padded rows beyond the true pixel
    count; C/N zero-pad the same way (callers slice the result).
    """
    t, m, c = qxt.shape
    _, _, n = qwt.shape
    assert qwt.shape[0] == t and sxt.shape == (t, m, 1), \
        (qxt.shape, sxt.shape, qwt.shape)
    grid = (m // bm, n // bn, t)
    return pl.pallas_call(
        functools.partial(_conv_taps_kernel, fuse_relu=fuse_relu),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, c), lambda i, j, tt: (tt, i, 0)),
            pl.BlockSpec((1, c, bn), lambda i, j, tt: (tt, 0, j)),
            pl.BlockSpec((1, bm, 1), lambda i, j, tt: (tt, i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, tt: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, tt: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, tt: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(qxt, qwt, sxt, sw, b)
