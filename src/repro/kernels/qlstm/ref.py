"""Pure-jnp oracle for the fused Q-LSTM cell."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.vact import cordic_sigmoid, cordic_tanh


def qlstm_cell(qx, sx, qh, sh, qw, sw, qu, su, b, c, n_iters: int):
    """One quantized LSTM step (paper Sec. III: Q-LSTM block).

    qx:[B,Din]i8  qh:[B,H]i8  qw:[Din,4H]i8  qu:[H,4H]i8
    sx/sh: scalars; sw/su: [1,4H] per-channel; b: [4H]; c: [B,H] fp32.
    Gate order i|f|g|o.  Returns (h', c') fp32.
    """
    acc_x = jax.lax.dot_general(qx, qw, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.int32)
    acc_h = jax.lax.dot_general(qh, qu, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.int32)
    gates = (acc_x.astype(jnp.float32) * sx * sw
             + acc_h.astype(jnp.float32) * sh * su + b)
    H = c.shape[-1]
    i = cordic_sigmoid(gates[:, 0 * H:1 * H], n_iters)
    f = cordic_sigmoid(gates[:, 1 * H:2 * H], n_iters)
    g = cordic_tanh(gates[:, 2 * H:3 * H], n_iters)
    o = cordic_sigmoid(gates[:, 3 * H:4 * H], n_iters)
    c_new = f * c + i * g
    h_new = cordic_tanh(c_new, n_iters) * o
    return h_new, c_new
