"""Fused Q-LSTM cell Pallas kernel (paper's Q-LSTM block).

The paper's Q-LSTM block wires two Q-MACs (x- and h- paths) directly
into V-ACT sigmoid/tanh stages with the cell state held in local
memory.  The TPU analogue is a single Pallas kernel: both int8 gate
matmuls hit the MXU, all four gate activations run on the VPU via the
CORDIC pipeline, and c/h never leave VMEM within a step.

Grid: batch tiles only; each program computes the full 4H gate stripe
for its batch rows (RL-scale hidden sizes — the paper's agent uses
H = 32 — easily fit VMEM; the wrapper asserts the footprint).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.vact.vact import _sigmoid_tile


def _tanh_tile(x, n_iters):
    return 2.0 * _sigmoid_tile(2.0 * x, n_iters) - 1.0


def _qlstm_kernel(qx_ref, sx_ref, qh_ref, sh_ref, qw_ref, sw_ref,
                  qu_ref, su_ref, b_ref, c_ref, h_out_ref, c_out_ref,
                  *, hidden, n_iters):
    acc_x = jax.lax.dot_general(
        qx_ref[...], qw_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    acc_h = jax.lax.dot_general(
        qh_ref[...], qu_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    gates = (acc_x.astype(jnp.float32) * sx_ref[0, 0] * sw_ref[...]
             + acc_h.astype(jnp.float32) * sh_ref[0, 0] * su_ref[...]
             + b_ref[...])
    H = hidden
    i = _sigmoid_tile(gates[:, 0 * H:1 * H], n_iters)
    f = _sigmoid_tile(gates[:, 1 * H:2 * H], n_iters)
    g = _tanh_tile(gates[:, 2 * H:3 * H], n_iters)
    o = _sigmoid_tile(gates[:, 3 * H:4 * H], n_iters)
    c_new = f * c_ref[...] + i * g
    h_out_ref[...] = _tanh_tile(c_new, n_iters) * o
    c_out_ref[...] = c_new


@functools.partial(jax.jit,
                   static_argnames=("n_iters", "bb", "interpret"))
def qlstm_cell_kernel(qx, sx, qh, sh, qw, sw, qu, su, b, c, *,
                      n_iters, bb=8, interpret=False):
    B, Din = qx.shape
    H = c.shape[-1]
    grid = (B // bb,)
    kern = functools.partial(_qlstm_kernel, hidden=H, n_iters=n_iters)
    h_new, c_new = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, Din), lambda i: (i, 0)),        # qx
            pl.BlockSpec((1, 1), lambda i: (0, 0)),           # sx
            pl.BlockSpec((bb, H), lambda i: (i, 0)),          # qh
            pl.BlockSpec((1, 1), lambda i: (0, 0)),           # sh
            pl.BlockSpec((Din, 4 * H), lambda i: (0, 0)),     # qw
            pl.BlockSpec((1, 4 * H), lambda i: (0, 0)),       # sw
            pl.BlockSpec((H, 4 * H), lambda i: (0, 0)),       # qu
            pl.BlockSpec((1, 4 * H), lambda i: (0, 0)),       # su
            pl.BlockSpec((1, 4 * H), lambda i: (0, 0)),       # b
            pl.BlockSpec((bb, H), lambda i: (i, 0)),          # c
        ],
        out_specs=[
            pl.BlockSpec((bb, H), lambda i: (i, 0)),
            pl.BlockSpec((bb, H), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H), jnp.float32),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
        ],
        interpret=interpret,
    )(qx, sx, qh, sh, qw, sw, qu, su, b, c)
    return h_new, c_new
