"""Public wrapper for the fused Q-LSTM cell kernel."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.qlstm import qlstm as _k
from repro.kernels.qlstm import ref as _ref

# VMEM budget guard for the full-stripe blocking (per-core VMEM ~ 8 MiB;
# leave generous headroom for double buffering).
_VMEM_BUDGET_BYTES = 4 * 1024 * 1024


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def qlstm_cell(qx, sx, qh, sh, qw, sw, qu, su, b, c, *,
               n_iters: int = 13, interpret: Optional[bool] = None):
    """Fused quantized LSTM cell step (one timestep, full stripe).

    Dtype contract: int8 input/hidden (qx [B, Din], qh [B, H]) with
    per-tensor fp32 scales, int8 gate weights (qw [Din, 4H],
    qu [H, 4H]) with per-column fp32 scales, fp32 bias b [4H] and cell
    state c [B, H]; int32 MACs, CORDIC gate nonlinearities
    (``n_iters`` rounds), fp32 (h', c') out.  The whole [Din + H, 4H]
    weight stripe must fit VMEM (checked; tile H or fall back to
    qmac+vact otherwise); batch pads to a multiple of 8.
    """
    if interpret is None:
        interpret = _interpret_default()
    B, Din = qx.shape
    H = c.shape[-1]
    footprint = (Din * 4 * H) + (H * 4 * H) + 4 * (4 * H) * 4
    if footprint > _VMEM_BUDGET_BYTES:
        raise ValueError(
            f"qlstm full-stripe blocking needs {footprint} B of VMEM "
            f"(> {_VMEM_BUDGET_BYTES}); tile H or fall back to qmac+vact")
    bb = 8
    pb = (-B) % bb
    if pb:
        pad = lambda a: jnp.pad(a, ((0, pb), (0, 0)))
        qx, qh, c = pad(qx), pad(qh), pad(c)
    sx = jnp.asarray(sx, jnp.float32).reshape(1, 1)
    sh = jnp.asarray(sh, jnp.float32).reshape(1, 1)
    sw = jnp.asarray(sw, jnp.float32).reshape(1, 4 * H)
    su = jnp.asarray(su, jnp.float32).reshape(1, 4 * H)
    b = jnp.asarray(b, jnp.float32).reshape(1, 4 * H)
    h_new, c_new = _k.qlstm_cell_kernel(qx, sx, qh, sh, qw, sw, qu, su,
                                        b, c, n_iters=n_iters, bb=bb,
                                        interpret=interpret)
    return h_new[:B], c_new[:B]


ref_qlstm_cell = _ref.qlstm_cell
