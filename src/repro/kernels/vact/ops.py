"""Public wrappers for V-ACT: shape-agnostic, auto-padded, backend glue."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.vact import vact as _k
from repro.kernels.vact import ref as _ref


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _as2d(x):
    if x.ndim == 1:
        return x[None, :], x.shape
    return x.reshape(-1, x.shape[-1]), x.shape


def _pad2d(x, bm, bn, value=0.0):
    p0 = (-x.shape[0]) % bm
    p1 = (-x.shape[1]) % bn
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)), constant_values=value)
    return x


def _blk(dim, cap):
    b = 8
    while b * 2 <= min(dim, cap):
        b *= 2
    return b


def vact(x: jax.Array, kind: str, n_iters: int,
         interpret: Optional[bool] = None) -> jax.Array:
    """V-ACT CORDIC activation on any-shaped fp input.

    ``kind`` is one of the CORDIC-approximated nonlinearities (tanh,
    sigmoid, softmax, ...) evaluated in ``n_iters`` shift-add rounds.
    The input is flattened to [rows, features] (last axis = features);
    rows tile at <= 128 (and features too, except softmax whose row
    reduction must see the whole feature axis in one block).  fp32
    compute, fp32 out, original shape restored.
    """
    if interpret is None:
        interpret = _interpret_default()
    x2, shape = _as2d(x.astype(jnp.float32))
    if kind == "softmax":
        bm = _blk(x2.shape[0], _k.DEFAULT_BM)
        # pad rows only; columns must stay exact for the reduction
        xp = _pad2d(x2, bm, x2.shape[1])
        out = _k.vact_softmax_kernel(xp, n_iters=n_iters, bm=bm,
                                     interpret=interpret)
    else:
        bm = _blk(x2.shape[0], _k.DEFAULT_BM)
        bn = _blk(x2.shape[1], _k.DEFAULT_BN)
        xp = _pad2d(x2, bm, bn)
        out = _k.vact_ew_kernel(xp, kind=kind, n_iters=n_iters, bm=bm,
                                bn=bn, interpret=interpret)
    return out[: x2.shape[0], : x2.shape[1]].reshape(shape)


def vact_q8(qx: jax.Array, sx: jax.Array, kind: str, n_iters: int,
            interpret: Optional[bool] = None) -> jax.Array:
    """Fused int8 -> int8 V-ACT activation (requantizing).

    Dtype contract: qx int8 with per-tensor scale ``sx`` (fp32 scalar),
    dequant + CORDIC ``kind`` + requant all inside the kernel; output
    is int8 on the fixed 1/127 grid (activations land in [-1, 1]).
    Same [rows <= 128, features <= 128] tiling as :func:`vact`.
    """
    if interpret is None:
        interpret = _interpret_default()
    x2, shape = _as2d(qx)
    bm = _blk(x2.shape[0], _k.DEFAULT_BM)
    bn = _blk(x2.shape[1], _k.DEFAULT_BN)
    xp = _pad2d(x2, bm, bn)
    s = jnp.asarray(sx, jnp.float32).reshape(1, 1)
    out = _k.vact_ew_q8_kernel(xp, s, kind=kind, n_iters=n_iters,
                               bm=bm, bn=bn, interpret=interpret)
    return out[: x2.shape[0], : x2.shape[1]].reshape(shape)


ref_vact = _ref.vact
ref_vact_q8 = _ref.vact_q8
