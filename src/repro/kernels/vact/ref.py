"""Pure-jnp oracle for the V-ACT kernel: the core CORDIC math itself."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.vact import (cordic_exp, cordic_sigmoid, cordic_softmax,
                             cordic_tanh)


def vact(x: jax.Array, kind: str, n_iters: int) -> jax.Array:
    if kind == "relu":
        return jax.nn.relu(x)
    if kind == "sigmoid":
        return cordic_sigmoid(x, n_iters)
    if kind == "tanh":
        return cordic_tanh(x, n_iters)
    if kind == "softmax":
        return cordic_softmax(x, n_iters, axis=-1)
    raise KeyError(kind)


def vact_q8(qx: jax.Array, sx: jax.Array, kind: str, n_iters: int):
    """Fused int8-in / int8-out oracle.

    Output scale is static: sigmoid/tanh land in [-1, 1] so one LSB is
    1/127 — exactly the paper's 'V-ACT emits FxP directly' datapath.
    """
    x = qx.astype(jnp.float32) * sx
    y = vact(x, kind, n_iters)
    qy = jnp.clip(jnp.round(y * 127.0), -127, 127).astype(jnp.int8)
    return qy
