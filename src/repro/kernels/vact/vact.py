"""V-ACT Pallas TPU kernel: fused quantized CORDIC activation unit.

One kernel body evaluates ReLU / Sigmoid / Tanh (elementwise) or Softmax
(row-wise) on a VMEM tile using the low-latency hyperbolic CORDIC
schedule from the paper ((3n/8 + 1) iterations, repeats at i = 4, 13).
The iteration loop is statically unrolled — on the FPGA these are
physical pipeline stages; here they are (shift-mul, add) stages the
Mosaic compiler schedules on the VPU.

The fused int8 variants dequantize on load and requantize on store, so
a quantized network's activation never round-trips HBM in fp32 — the
TPU analogue of V-ACT sitting inline in the FxP datapath.

NOTE vs core/vact.py: inside the kernel we use exp2(m) rather than
ldexp (Mosaic-friendly); numerics are identical in fp32 for |m| <= 126.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.vact import LN2, _ATANH, cordic_gain, hyperbolic_schedule

DEFAULT_BM = 256
DEFAULT_BN = 128


def _cordic_exp_tile(x, n_iters: int):
    """e^x on a tile: range-reduce, CORDIC sinh/cosh, exponent scale."""
    m = jnp.floor(x / LN2)
    r = x - m * LN2
    sched = hyperbolic_schedule(n_iters)
    gain = cordic_gain(sched)
    cx = jnp.full_like(r, 1.0 / gain)
    cy = jnp.zeros_like(r)
    zz = r
    for i in sched:                      # static unroll: pipeline stages
        d = jnp.where(zz >= 0, 1.0, -1.0).astype(r.dtype)
        shift = jnp.asarray(2.0 ** (-i), r.dtype)
        cx, cy = cx + d * cy * shift, cy + d * cx * shift
        zz = zz - d * jnp.asarray(_ATANH[i - 1], r.dtype)
    e_r = cx + cy
    m = jnp.clip(m, -126.0, 126.0)
    return e_r * jnp.exp2(m)


def _sigmoid_tile(x, n_iters):
    e = _cordic_exp_tile(-jnp.abs(x), n_iters)
    pos = 1.0 / (1.0 + e)
    return jnp.where(x >= 0, pos, 1.0 - pos)


def _apply_kind(x, kind: str, n_iters: int):
    if kind == "relu":
        return jnp.maximum(x, 0.0)
    if kind == "sigmoid":
        return _sigmoid_tile(x, n_iters)
    if kind == "tanh":
        return 2.0 * _sigmoid_tile(2.0 * x, n_iters) - 1.0
    raise KeyError(kind)


def _ew_kernel(x_ref, o_ref, *, kind, n_iters):
    o_ref[...] = _apply_kind(x_ref[...].astype(jnp.float32), kind, n_iters)


def _ew_q8_kernel(qx_ref, sx_ref, qo_ref, *, kind, n_iters):
    x = qx_ref[...].astype(jnp.float32) * sx_ref[0, 0]
    y = _apply_kind(x, kind, n_iters)
    qo_ref[...] = jnp.clip(jnp.round(y * 127.0), -127, 127).astype(jnp.int8)


def _softmax_kernel(x_ref, o_ref, *, n_iters):
    x = x_ref[...].astype(jnp.float32)
    m = jnp.max(x, axis=-1, keepdims=True)
    e = _cordic_exp_tile(x - m, n_iters)
    o_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)


@functools.partial(jax.jit,
                   static_argnames=("kind", "n_iters", "bm", "bn",
                                    "interpret"))
def vact_ew_kernel(x, *, kind, n_iters, bm=DEFAULT_BM, bn=DEFAULT_BN,
                   interpret=False):
    m, n = x.shape
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        functools.partial(_ew_kernel, kind=kind, n_iters=n_iters),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x)


@functools.partial(jax.jit,
                   static_argnames=("kind", "n_iters", "bm", "bn",
                                    "interpret"))
def vact_ew_q8_kernel(qx, sx, *, kind, n_iters, bm=DEFAULT_BM,
                      bn=DEFAULT_BN, interpret=False):
    """int8 in -> int8 out (scale 1/127), fused (de/re)quantization."""
    m, n = qx.shape
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        functools.partial(_ew_q8_kernel, kind=kind, n_iters=n_iters),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int8),
        interpret=interpret,
    )(qx, sx)


@functools.partial(jax.jit,
                   static_argnames=("n_iters", "bm", "interpret"))
def vact_softmax_kernel(x, *, n_iters, bm=DEFAULT_BM, interpret=False):
    """Row softmax; each block holds full rows (n must fit VMEM)."""
    m, n = x.shape
    grid = (m // bm,)
    return pl.pallas_call(
        functools.partial(_softmax_kernel, n_iters=n_iters),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x)
