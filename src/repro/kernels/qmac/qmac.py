"""Q-MAC: int8 SIMD matmul Pallas TPU kernel (paper Sec. III-A).

TPU adaptation of the paper's 16x-8-bit-multiplier MAC array: the MXU
consumes int8 operand tiles at 2x the bf16 rate, so the "16 MACs/cycle
at FxP8" configuration becomes an int8 matmul whose operand tiles live
in VMEM and accumulate in int32 — with dequantization fused into the
epilogue so the fp32 result never costs an extra HBM round trip.

Blocking: (bm x bk) int8 activation tile, (bk x bn) int8 weight tile,
(bm x bn) int32 VMEM accumulator.  The K grid axis is innermost and
sequential; the accumulator is zeroed at k==0 and flushed at the last
k step (classic Pallas matmul pattern).  Tile sides are multiples of
the MXU native 128 lane width; int8 sublane packing (32 rows) is
respected by keeping bm/bk/bn multiples of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _mm_kernel(x_ref, w_ref, o_ref, acc_ref):
    """int8 x int8 -> int32 tile matmul with K-loop accumulation."""
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


def _mm_deq_kernel(x_ref, w_ref, sx_ref, sw_ref, o_ref, acc_ref):
    """Same, with fused dequant epilogue: out = acc * sx * sw (fp32)."""
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = (acc_ref[...].astype(jnp.float32)
                      * sx_ref[...] * sw_ref[...])


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def qmac_i8_kernel(qx, qw, *, bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK,
                   interpret=False):
    """[M,K]i8 x [K,N]i8 -> [M,N]i32; M,K,N must be multiples of tiles."""
    m, k = qx.shape
    k2, n = qw.shape
    assert k == k2, (qx.shape, qw.shape)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(qx, qw)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def qmac_i8_deq_kernel(qx, sx, qw, sw, *, bm=DEFAULT_BM, bn=DEFAULT_BN,
                       bk=DEFAULT_BK, interpret=False):
    """Fused int8 matmul + dequant.  sx: [M,1] fp32, sw: [1,N] fp32."""
    m, k = qx.shape
    _, n = qw.shape
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _mm_deq_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(qx, qw, sx, sw)
