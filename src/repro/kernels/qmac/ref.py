"""Pure-jnp oracle for the Q-MAC kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def qmac_i8(qx: jax.Array, qw: jax.Array) -> jax.Array:
    """int8 x int8 -> int32 matmul oracle. qx: [M, K], qw: [K, N]."""
    return jax.lax.dot_general(
        qx, qw, (((qx.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


def qmac_i8_deq(qx: jax.Array, sx: jax.Array, qw: jax.Array,
                sw: jax.Array) -> jax.Array:
    """Fused dequantize: (qx·qw) * sx * sw -> fp32.

    sx: [M, 1] per-row (per-token) scales; sw: [1, N] per-channel scales.
    """
    acc = qmac_i8(qx, qw).astype(jnp.float32)
    return acc * sx * sw
