"""jit'd public wrappers for the Q-MAC kernel (padding + backend glue)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.qmac import qmac as _k
from repro.kernels.qmac import ref as _ref


def _pad_to(x, m0, m1):
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def qmac_i8(qx: jax.Array, qw: jax.Array, *, bm=None, bn=None, bk=None,
            interpret=None) -> jax.Array:
    """int8 [M,K] x int8 [K,N] -> int32 [M,N], any M/K/N (auto-padded)."""
    if interpret is None:
        interpret = _interpret_default()
    m, k = qx.shape
    _, n = qw.shape
    bm = bm or min(_k.DEFAULT_BM, _round_block(m))
    bn = bn or min(_k.DEFAULT_BN, _round_block(n))
    bk = bk or min(_k.DEFAULT_BK, _round_block(k))
    qxp = _pad_to(qx, bm, bk)
    qwp = _pad_to(qw, bk, bn)
    out = _k.qmac_i8_kernel(qxp, qwp, bm=bm, bn=bn, bk=bk,
                            interpret=interpret)
    return out[:m, :n]


def qmac_i8_deq(qx, sx, qw, sw, *, bm=None, bn=None, bk=None,
                interpret=None) -> jax.Array:
    """Fused dequantizing int8 matmul -> fp32."""
    if interpret is None:
        interpret = _interpret_default()
    m, k = qx.shape
    _, n = qw.shape
    bm = bm or min(_k.DEFAULT_BM, _round_block(m))
    bn = bn or min(_k.DEFAULT_BN, _round_block(n))
    bk = bk or min(_k.DEFAULT_BK, _round_block(k))
    qxp = _pad_to(qx, bm, bk)
    qwp = _pad_to(qw, bk, bn)
    sxp = _pad_to(sx.astype(jnp.float32), bm, 1)
    swp = _pad_to(sw.astype(jnp.float32), 1, bn)
    out = _k.qmac_i8_deq_kernel(qxp, sxp, qwp, swp, bm=bm, bn=bn, bk=bk,
                                interpret=interpret)
    return out[:m, :n]


def _round_block(dim: int) -> int:
    """Largest power-of-two block <= dim (min 8) for small test shapes."""
    b = 8
    while b * 2 <= min(dim, 128):
        b *= 2
    return b


# re-export oracle for test convenience
ref_qmac_i8 = _ref.qmac_i8
ref_qmac_i8_deq = _ref.qmac_i8_deq
