"""jit'd public wrappers for the Q-MAC kernel (padding + backend glue)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.qmac import qmac as _k
from repro.kernels.qmac import ref as _ref


def _pad_to(x, m0, m1):
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def qmac_i8(qx: jax.Array, qw: jax.Array, *, bm=None, bn=None, bk=None,
            interpret=None) -> jax.Array:
    """Q-MAC int8 matmul: int8 [M,K] x int8 [K,N] -> int32 [M,N].

    Dtype contract: int8 operands, int32 accumulation, int32 out (no
    epilogue).  ``bm``/``bn``/``bk`` are the M/N/K tile sizes (default:
    largest power of two <= min(dim, 128)); any M/K/N is accepted —
    operands are zero-padded to tile multiples and the result sliced
    back.  |acc| <= K*127*128 must fit int32, i.e. K <= 131072.
    ``interpret=None`` runs the Pallas interpreter off-TPU.
    """
    if interpret is None:
        interpret = _interpret_default()
    m, k = qx.shape
    _, n = qw.shape
    bm = bm or min(_k.DEFAULT_BM, _round_block(m))
    bn = bn or min(_k.DEFAULT_BN, _round_block(n))
    bk = bk or min(_k.DEFAULT_BK, _round_block(k))
    qxp = _pad_to(qx, bm, bk)
    qwp = _pad_to(qw, bk, bn)
    out = _k.qmac_i8_kernel(qxp, qwp, bm=bm, bn=bn, bk=bk,
                            interpret=interpret)
    return out[:m, :n]


def qmac_i8_deq(qx, sx, qw, sw, *, bm=None, bn=None, bk=None,
                interpret=None) -> jax.Array:
    """Fused dequantizing Q-MAC matmul: (qx . qw) * sx * sw -> fp32.

    Dtype contract: int8 operands, int32 MAC accumulation, fp32 out of
    the fused per-row x per-channel dequant epilogue.  Shapes:
    qx [M, K] int8, sx [M, 1] fp32 per-row (per-token) scales,
    qw [K, N] int8, sw [1, N] fp32 per-out-channel scales -> [M, N].
    Blocking and padding as in :func:`qmac_i8`.
    """
    if interpret is None:
        interpret = _interpret_default()
    m, k = qx.shape
    _, n = qw.shape
    bm = bm or min(_k.DEFAULT_BM, _round_block(m))
    bn = bn or min(_k.DEFAULT_BN, _round_block(n))
    bk = bk or min(_k.DEFAULT_BK, _round_block(k))
    qxp = _pad_to(qx, bm, bk)
    qwp = _pad_to(qw, bk, bn)
    sxp = _pad_to(sx.astype(jnp.float32), bm, 1)
    swp = _pad_to(sw.astype(jnp.float32), 1, bn)
    out = _k.qmac_i8_deq_kernel(qxp, sxp, qwp, swp, bm=bm, bn=bn, bk=bk,
                                interpret=interpret)
    return out[:m, :n]


def _round_block(dim: int) -> int:
    """Largest power-of-two block <= dim (min 8) for small test shapes."""
    b = 8
    while b * 2 <= min(dim, 128):
        b *= 2
    return b


# re-export oracle for test convenience
ref_qmac_i8 = _ref.qmac_i8
ref_qmac_i8_deq = _ref.qmac_i8_deq
