"""The paper's own architecture: E2HRL hierarchical RL agent.

3 Q-Conv layers (stride 2, ReLU) -> flatten -> Q-FC -> 32-d embedding
-> sub-goal module (Q-FC h2 or Q-LSTM K4) -> concat -> action softmax.
Input 32x32x3 (paper Table V I/P size for the proposed engine).
"""
import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class HRLConfig:
    name: str = "e2hrl"
    obs_shape: Tuple[int, int, int] = (32, 32, 3)
    conv_channels: Tuple[int, ...] = (16, 32, 32)
    conv_kernel: int = 3
    embed_dim: int = 32
    subgoal_dim: int = 8
    subgoal_kind: str = "fc"       # "fc" (FC-HRL) | "lstm" (LSTM-HRL)
    subgoal_hidden: int = 32
    n_actions: int = 6
    value_head: bool = True


CONFIG = HRLConfig()
CONFIG_LSTM = HRLConfig(name="e2hrl-lstm", subgoal_kind="lstm")
