"""ArchConfig: one dataclass describes every assigned architecture.

``reduced()`` yields the CPU-smoke-test configuration of the same
family (same code paths, tiny dims), per the assignment: full configs
are exercised only abstractly via the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | encdec | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    # attention details
    rope: bool = True
    rope_theta: float = 1e6
    qkv_bias: bool = False
    qk_norm: bool = False
    window: Optional[int] = None         # SWA window (mixtral)
    tie_embeddings: bool = False
    act: str = "silu"
    norm: str = "rmsnorm"
    # moe
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # ssm (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    # hybrid (recurrentgemma): pattern repeats (R, R, A)
    lru_width: int = 0
    local_window: int = 0
    block_pattern: Tuple[str, ...] = ()
    # enc-dec (whisper): n_layers counts EACH of encoder and decoder
    is_encdec: bool = False
    # modality frontend stub: None | "audio" | "vq"
    frontend: Optional[str] = None
    # execution
    remat: bool = True
    scan_layers: bool = True
    # sequence parallelism: saved inter-block activations sharded over
    # the model axis (in-block compute all-gathers as needed).  Cuts
    # saved-activation memory by the TP degree at the cost of per-block
    # collectives — required to fit the biggest archs' train steps.
    seq_shard: bool = False
    # q-chunk size for flash-style attention (None = never chunk)
    q_chunk: Optional[int] = 512
    # gradient-accumulation microbatches per step (1 = none): divides
    # per-layer transient memory by k at the cost of k sequential
    # passes; grads accumulate in fp32 sharded like the params
    microbatches: int = 1
    # notes for DESIGN/EXPERIMENTS
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run the 500k-token decode shape?"""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.window is not None      # SWA bounds the KV working set

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dict(
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 4 if not self.block_pattern
                         else len(self.block_pattern) + 1),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_ff=128,
            vocab=256,
            head_dim=16,
            # production-mesh execution knobs don't apply on-host
            seq_shard=False,
            microbatches=1,
        )
        if self.is_moe:
            kw.update(n_experts=min(self.n_experts, 8),
                      top_k=min(self.top_k, 2))
        if self.family == "ssm":
            kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=8,
                      n_heads=0, n_kv_heads=0)
        if self.family == "hybrid":
            kw.update(lru_width=64, local_window=8)
        if self.window is not None:
            kw.update(window=8)
        return dataclasses.replace(self, **kw)


def pad_vocab(vocab: int, multiple: int = 128) -> int:
    """Megatron-style padded table size: divisible by any mesh axis up
    to ``multiple`` and MXU-aligned.  Padded logit columns are masked to
    -inf in logits_from_hidden, so semantics don't change."""
    return ((vocab + multiple - 1) // multiple) * multiple


def param_count(cfg: ArchConfig) -> float:
    """Analytic parameter count (embedding + blocks), for 6ND checks."""
    d, f, v, L = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.n_layers
    hd = cfg.hd
    emb = v * d * (1 if cfg.tie_embeddings else 2)
    attn = d * (cfg.n_heads * hd) + 2 * d * (cfg.n_kv_heads * hd) \
        + (cfg.n_heads * hd) * d if cfg.n_heads else 0
    if cfg.family == "ssm":
        d_in = cfg.ssm_expand * d
        blk = d * (2 * d_in + 2 * cfg.ssm_state + d_in // cfg.ssm_head_dim) \
            + d_in * d
        return emb + L * blk
    if cfg.is_moe:
        mlp = cfg.n_experts * 3 * d * f
    else:
        mlp = 3 * d * f if cfg.act in ("silu", "geglu") else 2 * d * f
    blocks = L * (attn + mlp)
    if cfg.is_encdec:
        blocks = 2 * L * attn + L * attn + 2 * L * mlp  # enc+dec+cross
    if cfg.family == "hybrid":
        rec = d * cfg.lru_width * 3 + 2 * cfg.lru_width ** 2 \
            + cfg.lru_width * d
        n_rec = sum(1 for i in range(L)
                    if cfg.block_pattern[i % len(cfg.block_pattern)] == "R")
        n_att = L - n_rec
        blocks = n_rec * (rec + 3 * d * f) + n_att * (attn + 3 * d * f)
    return emb + blocks


def active_param_count(cfg: ArchConfig) -> float:
    """Active (per-token) params for MoE: 6*N_active*D MODEL_FLOPS."""
    if not cfg.is_moe:
        return param_count(cfg)
    d, f, L = cfg.d_model, cfg.d_ff, cfg.n_layers
    hd = cfg.hd
    emb = cfg.vocab * d * (1 if cfg.tie_embeddings else 2)
    attn = d * (cfg.n_heads * hd) + 2 * d * (cfg.n_kv_heads * hd) \
        + (cfg.n_heads * hd) * d
    mlp = cfg.top_k * 3 * d * f
    return emb + L * (attn + mlp)
