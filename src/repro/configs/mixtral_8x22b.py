"""Mixtral-8x22B [arXiv:2401.04088; hf]: 8-expert top-2 MoE, SWA.

MoE sharding regime: TP-within-expert (8 experts < 16-way model axis;
d_ff 16384 shards 16-way) — see distributed/sharding rules.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab=32768, head_dim=128,
    n_experts=8, top_k=2, window=4096,
    rope_theta=1e6, act="silu",
    seq_shard=True, microbatches=8,
    source="arXiv:2401.04088 (hf:mistralai/Mixtral-8x22B)",
)
