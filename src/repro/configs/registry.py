"""Architecture registry: --arch <id> resolution."""
from repro.configs import (chameleon_34b, mamba2_2_7b, mixtral_8x22b,
                           phi3_mini_3_8b, qwen2_72b, qwen3_moe_30b_a3b,
                           recurrentgemma_9b, stablelm_12b,
                           tinyllama_1_1b, whisper_large_v3)

ARCHS = {m.CONFIG.name: m.CONFIG for m in [
    qwen2_72b, stablelm_12b, phi3_mini_3_8b, tinyllama_1_1b,
    whisper_large_v3, mixtral_8x22b, qwen3_moe_30b_a3b,
    recurrentgemma_9b, mamba2_2_7b, chameleon_34b,
]}


def get_arch(name: str):
    if name not in ARCHS:
        raise KeyError(f"unknown arch '{name}' "
                       f"(available: {sorted(ARCHS)})")
    return ARCHS[name]
