"""Whisper-large-v3 backbone [arXiv:2212.04356]: enc-dec transformer.

The conv/audio frontend is a STUB per the assignment: input_specs()
feeds precomputed frame embeddings [B, S, d_model].  n_layers counts
each of encoder and decoder (32 + 32).  Positional: sinusoidal (any
length), LayerNorm + GELU per the whisper architecture.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3", family="encdec", is_encdec=True,
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
    d_ff=5120, vocab=51866, head_dim=64,
    rope=False, act="gelu", norm="layernorm", frontend="audio",
    microbatches=4,
    source="arXiv:2212.04356 (hf:openai/whisper-large-v3)",
)
