"""StableLM-2-12B [hf:stabilityai]: dense GQA."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
    d_ff=13824, vocab=100352, head_dim=160,
    rope_theta=1e4, act="silu",
    microbatches=4,
    source="hf:stabilityai/stablelm-2-12b",
)
