"""Qwen2-72B [arXiv:2407.10671; hf]: dense GQA, QKV bias."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-72b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=29568, vocab=152064, head_dim=128,
    qkv_bias=True, rope_theta=1e6, act="silu",
    # execution: SP + 4 microbatches -> 12.9 GiB/chip at train_4k
    seq_shard=True, microbatches=4,
    source="arXiv:2407.10671 (hf:Qwen/Qwen2-72B)",
)
