"""The assigned input-shape set (same four shapes for every LM arch)."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str               # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in [TRAIN_4K, PREFILL_32K, DECODE_32K,
                              LONG_500K]}


def shape_applicable(cfg, shape: ShapeConfig) -> Optional[str]:
    """None if the (arch, shape) cell runs; else a skip reason."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return "skip (pure full attention; no sub-quadratic path)"
    return None
