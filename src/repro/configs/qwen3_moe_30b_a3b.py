"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B]: 128-expert top-8 MoE.

MoE sharding regime: expert parallelism (128 experts / 16-way model
axis = 8 experts per device); complements mixtral's TP-in-expert.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4,
    d_ff=768, vocab=151936, head_dim=128,
    n_experts=128, top_k=8, qk_norm=True,
    rope_theta=1e6, act="silu",
    microbatches=4,
    source="hf:Qwen/Qwen3-30B-A3B",
)
