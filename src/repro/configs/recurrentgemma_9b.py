"""RecurrentGemma-9B [arXiv:2402.19427]: RG-LRU + local attn, 1:2.

Block pattern repeats (R, R, A); 38 layers = 12 full patterns + 2
recurrent blocks.  MQA (kv=1), local window 2048, GeGLU-style MLP.
Sub-quadratic: runs the long_500k decode shape.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
    d_ff=12288, vocab=256000, head_dim=256,
    lru_width=4096, local_window=2048, block_pattern=("R", "R", "A"),
    rope_theta=1e4, act="gelu",
    microbatches=4,
    source="arXiv:2402.19427 (RecurrentGemma-9B)",
)
