"""Mamba2-2.7B [arXiv:2405.21060]: SSD (state-space duality), attn-free.

d_inner = 2 * 2560 = 5120, head_dim 64 -> 80 SSD heads, d_state 128.
Constant-size recurrent state: runs the long_500k decode shape.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=50280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=128,
    microbatches=2,
    source="arXiv:2405.21060 (state-spaces/mamba2-2.7b)",
)
