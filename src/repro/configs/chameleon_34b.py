"""Chameleon-34B [arXiv:2405.09818]: early-fusion VLM backbone.

VQ image tokens share the text token space (vocab 65536); the VQ-VAE
image tokenizer is a STUB per the assignment — input_specs() feeds
token ids directly.  QK-norm per the chameleon training recipe.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b", family="dense",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab=65536, head_dim=128,
    qk_norm=True, rope_theta=1e4, act="silu", frontend="vq",
    seq_shard=True, microbatches=2,
    source="arXiv:2405.09818 (Chameleon-34B)",
)
