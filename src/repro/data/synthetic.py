"""Deterministic synthetic token streams (no external datasets here).

The generator is stateless-by-step: batch ``i`` is a pure function of
(seed, i), so any host can materialize any shard of any step — this is
what makes the input pipeline elastically restartable: after a crash,
resume at step N with no data-loader state to restore.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 1234


def batch_at(cfg: DataConfig, step: int,
             shard: Tuple[int, int] = (0, 1)) -> dict:
    """Materialize (tokens, labels) for ``step``; ``shard=(k, n)`` gives
    the k-th of n per-host slices of the global batch."""
    k, n = shard
    assert cfg.global_batch % n == 0
    local = cfg.global_batch // n
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step), k)
    # Markov-ish stream: correlated tokens so the LM loss actually falls
    base = jax.random.randint(key, (local, cfg.seq_len + 1), 0,
                              cfg.vocab, dtype=jnp.int32)
    tokens = base[:, :-1]
    labels = base[:, 1:]
    return {"tokens": tokens, "labels": labels}


def iterate(cfg: DataConfig, start_step: int = 0,
            shard: Tuple[int, int] = (0, 1)) -> Iterator[dict]:
    step = start_step
    while True:
        yield batch_at(cfg, step, shard)
        step += 1
