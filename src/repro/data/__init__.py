from repro.data.sharded_loader import place
from repro.data.synthetic import DataConfig, batch_at, iterate
