"""Device-sharded batch placement for the production mesh.

``place(batch, mesh)`` lays the global batch out over the data axes
with ``jax.make_array_from_callback`` so each host only materializes
its own slice — at 256-way batch over 512 chips nothing ever holds the
global batch in one memory.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.sharding import batch_spec


def place(batch: Dict, mesh: Mesh) -> Dict:
    def put(x):
        x = np.asarray(x)
        spec = batch_spec(mesh, extra_dims=x.ndim - 1)
        sharding = NamedSharding(mesh, spec)
        return jax.make_array_from_callback(
            x.shape, sharding, lambda idx: x[idx])

    return {k: put(v) for k, v in batch.items()}
