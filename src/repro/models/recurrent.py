"""RecurrentGemma-style hybrid LM: (R, R, A) super-blocks.

R = RG-LRU recurrent block, A = local (sliding-window) attention; each
followed by a GeGLU MLP.  The layer stack scans over *super-blocks*
(the repeating pattern) so HLO stays O(1) in depth; remainder layers
(38 = 12x3 + 2) are unrolled explicitly.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, pad_vocab
from repro.core.policy import QuantPolicy
from repro.models.common import (chunked_ce, cross_entropy, logits_from_hidden,
                                 stack_init)
from repro.nn.attention import (AttnConfig, attention_apply,
                                attention_decode, attention_init,
                                init_cache)
from repro.nn.linear import embedding_apply, embedding_init, linear_init
from repro.nn.mlp import swiglu_apply, swiglu_init
from repro.nn.module import KeySeq
from repro.nn.norm import rmsnorm_apply, rmsnorm_init
from repro.nn.rglru import (recurrent_block_apply, recurrent_block_init,
                            recurrent_block_init_state)

Array = jax.Array


def attn_config(cfg: ArchConfig) -> AttnConfig:
    return AttnConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd, causal=True,
        window=cfg.local_window, rope=True, rope_theta=cfg.rope_theta,
        q_chunk=cfg.q_chunk)


def _layout(cfg: ArchConfig):
    pat = cfg.block_pattern or ("R",)
    n_super = cfg.n_layers // len(pat)
    tail = tuple(pat[i] for i in range(cfg.n_layers % len(pat)))
    return pat, n_super, tail


def _sub_init(key, kind: str, cfg: ArchConfig, dtype):
    ks = KeySeq(key)
    p = {"ln1": rmsnorm_init(ks(), cfg.d_model, dtype),
         "ln2": rmsnorm_init(ks(), cfg.d_model, dtype),
         "mlp": swiglu_init(ks(), cfg.d_model, cfg.d_ff, dtype)}
    if kind == "R":
        p["rec"] = recurrent_block_init(ks(), cfg.d_model, cfg.lru_width,
                                        dtype=dtype)
    else:
        p["attn"] = attention_init(ks(), attn_config(cfg), dtype)
    return p


def _super_init(key, cfg: ArchConfig, dtype):
    pat, _, _ = _layout(cfg)
    ks = KeySeq(key)
    return {f"b{i}_{kind}": _sub_init(ks(), kind, cfg, dtype)
            for i, kind in enumerate(pat)}


def _sub_apply(p, x, kind, cfg, policy, positions):
    h = rmsnorm_apply(p["ln1"], x)
    if kind == "R":
        x = x + recurrent_block_apply(p["rec"], h, policy)
    else:
        x = x + attention_apply(p["attn"], h, attn_config(cfg), policy,
                                positions=positions)
    h = rmsnorm_apply(p["ln2"], x)
    return x + swiglu_apply(p["mlp"], h, policy, act=cfg.act)


def _sub_decode(p, x, kind, cfg, policy, cache, index, kv_bits):
    h = rmsnorm_apply(p["ln1"], x)
    if kind == "R":
        out, cache = recurrent_block_apply(p["rec"], h, policy,
                                           state=cache)
        x = x + out
    else:
        out, cache = attention_decode(p["attn"], h, attn_config(cfg),
                                      cache, index, policy,
                                      kv_bits=kv_bits)
        x = x + out
    h = rmsnorm_apply(p["ln2"], x)
    return x + swiglu_apply(p["mlp"], h, policy, act=cfg.act), cache


def init(key, cfg: ArchConfig, dtype=jnp.float32):
    pat, n_super, tail = _layout(cfg)
    ks = KeySeq(key)
    params = {
        "embed": embedding_init(ks(), pad_vocab(cfg.vocab), cfg.d_model,
                                axes=("vocab", "d_model"), dtype=dtype),
        "supers": stack_init(lambda k: _super_init(k, cfg, dtype), ks(),
                             n_super),
        "ln_f": rmsnorm_init(ks(), cfg.d_model, dtype),
        "lm_head": linear_init(ks(), cfg.d_model, pad_vocab(cfg.vocab),
                               axes=("d_model", "vocab"), bias=False,
                               dtype=dtype),
    }
    if tail:
        params["tail"] = [_sub_init(ks(), kind, cfg, dtype)
                          for kind in tail]
    return params


def forward(params, tokens: Array, cfg: ArchConfig,
            policy: Optional[QuantPolicy] = None,
            return_hidden: bool = False) -> Array:
    pat, n_super, tail = _layout(cfg)
    B, S = tokens.shape
    x = embedding_apply(params["embed"], tokens, policy)
    x = x.astype(policy.compute_dtype if policy else jnp.float32)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def super_body(p, h):
        for i, kind in enumerate(pat):
            h = _sub_apply(p[f"b{i}_{kind}"], h, kind, cfg, policy,
                           positions)
        return h

    if cfg.remat:
        super_body = jax.checkpoint(
            super_body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(lambda h, p: (super_body(p, h), None), x,
                        params["supers"])
    for p, kind in zip(params.get("tail", []), tail, strict=True):
        x = _sub_apply(p, x, kind, cfg, policy, positions)
    x = rmsnorm_apply(params["ln_f"], x)
    if return_hidden:
        return x
    return logits_from_hidden(x, params["lm_head"]["w"], None,
                              policy, n_valid=cfg.vocab)


def loss_fn(params, batch, cfg: ArchConfig,
            policy: Optional[QuantPolicy] = None) -> Array:
    x = forward(params, batch["tokens"], cfg, policy,
                return_hidden=True)
    head = lambda h: logits_from_hidden(h, params["lm_head"]["w"], None,
                                        policy, n_valid=cfg.vocab)
    return chunked_ce(head, x, batch["labels"], batch.get("mask"))


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def _sub_cache(kind, cfg, batch, max_len, kv_bits, dtype):
    if kind == "R":
        return recurrent_block_init_state(batch, cfg.lru_width)
    cap = min(cfg.local_window, max_len)
    return init_cache(batch, cap, cfg.n_kv_heads, cfg.hd, kv_bits,
                      dtype, ring=cap < max_len)


def init_caches(cfg: ArchConfig, batch: int, max_len: int,
                kv_bits: int = 32, dtype=jnp.float32):
    pat, n_super, tail = _layout(cfg)
    one = {f"b{i}_{kind}": _sub_cache(kind, cfg, batch, max_len,
                                      kv_bits, dtype)
           for i, kind in enumerate(pat)}
    stacked = jax.tree.map(
        lambda l: jnp.broadcast_to(l[None], (n_super,) + l.shape), one)
    caches = {"supers": stacked}
    if tail:
        caches["tail"] = [_sub_cache(kind, cfg, batch, max_len, kv_bits,
                                     dtype) for kind in tail]
    return caches


def prefill(params, tokens: Array, cfg: ArchConfig,
            policy: Optional[QuantPolicy] = None, kv_bits: int = 32):
    """Prefill by running the full forward then decoding is resumed via
    sequential state (recurrent) / full-length caches (attention)."""
    pat, n_super, tail = _layout(cfg)
    B, S = tokens.shape
    x = embedding_apply(params["embed"], tokens, policy)
    x = x.astype(policy.compute_dtype if policy else jnp.float32)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def sub_prefill(p, h, kind):
        hh = rmsnorm_apply(p["ln1"], h)
        if kind == "R":
            gate_in = hh
            from repro.nn.linear import linear_apply
            from repro.core.vact import activation
            from repro.nn.conv import causal_conv1d_apply
            from repro.nn.rglru import rglru_apply
            gate = activation(linear_apply(p["rec"]["lin_y"], gate_in,
                                           policy), "gelu", policy)
            u = linear_apply(p["rec"]["lin_x"], gate_in, policy)
            u_conv = causal_conv1d_apply(p["rec"]["conv"], u)
            hs, last = rglru_apply(p["rec"]["rglru"], u_conv, policy)
            out = linear_apply(p["rec"]["lin_out"], hs * gate, policy)
            w = p["rec"]["conv"]["w"].shape[0] - 1
            conv_state = u[:, S - w:S].astype(jnp.float32)
            cache = {"conv": conv_state, "rglru": last}
            h = h + out
        else:
            out, cache = attention_apply(
                p["attn"], hh, attn_config(cfg), policy,
                positions=positions, return_cache=True, kv_bits=kv_bits)
            h = h + out
        hh = rmsnorm_apply(p["ln2"], h)
        return h + swiglu_apply(p["mlp"], hh, policy, act=cfg.act), cache

    def super_step(h, p):
        caches = {}
        for i, kind in enumerate(pat):
            h, caches[f"b{i}_{kind}"] = sub_prefill(p[f"b{i}_{kind}"], h,
                                                    kind)
        return h, caches

    x, super_caches = jax.lax.scan(super_step, x, params["supers"])
    caches = {"supers": super_caches}
    if tail:
        tail_caches = []
        for p, kind in zip(params["tail"], tail, strict=True):
            x, c = sub_prefill(p, x, kind)
            tail_caches.append(c)
        caches["tail"] = tail_caches
    x = rmsnorm_apply(params["ln_f"], x[:, -1:])
    logits = logits_from_hidden(x, params["lm_head"]["w"], None,
                              policy, n_valid=cfg.vocab)
    return logits[:, 0], caches


def decode_step(params, token: Array, caches, index, cfg: ArchConfig,
                policy: Optional[QuantPolicy] = None, kv_bits: int = 32):
    pat, n_super, tail = _layout(cfg)
    x = embedding_apply(params["embed"], token, policy)
    x = x.astype(policy.compute_dtype if policy else jnp.float32)

    def super_step(h, xs):
        p, cache = xs
        new = {}
        for i, kind in enumerate(pat):
            key = f"b{i}_{kind}"
            h, new[key] = _sub_decode(p[key], h, kind, cfg, policy,
                                      cache[key], index, kv_bits)
        return h, new

    x, super_caches = jax.lax.scan(super_step, x,
                                   (params["supers"], caches["supers"]))
    out_caches = {"supers": super_caches}
    if tail:
        tail_caches = []
        for p, kind, c in zip(params["tail"], tail, caches["tail"], strict=True):
            x, c = _sub_decode(p, x, kind, cfg, policy, c, index, kv_bits)
            tail_caches.append(c)
        out_caches["tail"] = tail_caches
    x = rmsnorm_apply(params["ln_f"], x)
    logits = logits_from_hidden(x, params["lm_head"]["w"], None,
                              policy, n_valid=cfg.vocab)
    return logits[:, 0], out_caches
