"""Decoder-only LM family: dense (qwen2/stablelm/phi3/tinyllama/
chameleon) and MoE (mixtral TP-in-expert, qwen3-moe expert-parallel).

Layers are scanned (stacked params, jax.lax.scan) with optional remat —
this keeps HLO size O(1) in depth, which matters when lowering 80-layer
models for 512 devices.  Every matmul routes through q_matmul.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, pad_vocab
from repro.core.policy import QuantPolicy
from repro.distributed.sharding import constrain
from repro.models.common import (chunked_ce, cross_entropy,
                                 logits_from_hidden, stack_init)
from repro.nn.attention import (AttnConfig, attention_apply,
                                attention_decode, attention_init,
                                init_cache)
from repro.nn.linear import embedding_init, embedding_apply, linear_init
from repro.nn.mlp import swiglu_apply, swiglu_init
from repro.nn.moe import moe_apply, moe_init
from repro.nn.module import KeySeq
from repro.nn.norm import rmsnorm_apply, rmsnorm_init

Array = jax.Array


def attn_config(cfg: ArchConfig) -> AttnConfig:
    return AttnConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd, causal=True,
        window=cfg.window, rope=cfg.rope, rope_theta=cfg.rope_theta,
        qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm,
        q_chunk=cfg.q_chunk)


def _block_init(key, cfg: ArchConfig, dtype):
    ks = KeySeq(key)
    p = {
        "ln1": rmsnorm_init(ks(), cfg.d_model, dtype),
        "attn": attention_init(ks(), attn_config(cfg), dtype),
        "ln2": rmsnorm_init(ks(), cfg.d_model, dtype),
    }
    if cfg.is_moe:
        p["moe"] = moe_init(ks(), cfg.d_model, cfg.d_ff, cfg.n_experts,
                            dtype)
    else:
        p["mlp"] = swiglu_init(ks(), cfg.d_model, cfg.d_ff, dtype)
    return p


def _block_apply(p, x, cfg: ArchConfig, policy, positions):
    # carry layout: under SP ("seq"->"model") the residual stream and
    # therefore the scan-saved activations live sequence-sharded; the
    # gathers below are the Megatron-SP g/ḡ boundaries (all-gather on
    # entry, reduce-scatter via the output constraint's transpose).
    x = constrain(x, ("batch", "seq", None))
    h = rmsnorm_apply(p["ln1"], x)
    h = constrain(h, ("batch", None, None))       # SP: gather seq
    a = attention_apply(p["attn"], h, attn_config(cfg), policy,
                        positions=positions)
    x = x + constrain(a, ("batch", "seq", None))
    h = rmsnorm_apply(p["ln2"], x)
    h = constrain(h, ("batch", None, None))       # SP: gather seq
    if cfg.is_moe:
        m = moe_apply(p["moe"], h, top_k=cfg.top_k, policy=policy,
                      capacity_factor=cfg.capacity_factor, act=cfg.act)
    else:
        m = swiglu_apply(p["mlp"], h, policy, act=cfg.act)
    return x + constrain(m, ("batch", "seq", None))


def _block_prefill(p, x, cfg, policy, positions, kv_bits):
    h = rmsnorm_apply(p["ln1"], x)
    a, cache = attention_apply(p["attn"], h, attn_config(cfg), policy,
                               positions=positions, return_cache=True,
                               kv_bits=kv_bits)
    x = x + a
    h = rmsnorm_apply(p["ln2"], x)
    if cfg.is_moe:
        m = moe_apply(p["moe"], h, top_k=cfg.top_k, policy=policy,
                      capacity_factor=cfg.capacity_factor, act=cfg.act)
    else:
        m = swiglu_apply(p["mlp"], h, policy, act=cfg.act)
    return x + m, cache


def _block_decode(p, x, cfg, policy, cache, index, kv_bits):
    h = rmsnorm_apply(p["ln1"], x)
    a, cache = attention_decode(p["attn"], h, attn_config(cfg), cache,
                                index, policy, kv_bits=kv_bits)
    x = x + a
    h = rmsnorm_apply(p["ln2"], x)
    if cfg.is_moe:
        m = moe_apply(p["moe"], h, top_k=cfg.top_k, policy=policy,
                      capacity_factor=cfg.capacity_factor, act=cfg.act)
    else:
        m = swiglu_apply(p["mlp"], h, policy, act=cfg.act)
    return x + m, cache


# ---------------------------------------------------------------------------
# model init / forward
# ---------------------------------------------------------------------------

def init(key, cfg: ArchConfig, dtype=jnp.float32):
    ks = KeySeq(key)
    v_pad = pad_vocab(cfg.vocab)
    params = {
        "embed": embedding_init(ks(), v_pad, cfg.d_model,
                                axes=("vocab", "d_model"), dtype=dtype),
        "blocks": stack_init(
            lambda k: _block_init(k, cfg, dtype), ks(), cfg.n_layers),
        "ln_f": rmsnorm_init(ks(), cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = linear_init(
            ks(), cfg.d_model, v_pad, axes=("d_model", "vocab"),
            bias=False, dtype=dtype)
    return params


def _head(params, x, cfg, policy):
    tie = params["embed"] if cfg.tie_embeddings else None
    head = None if cfg.tie_embeddings else params["lm_head"]["w"]
    return logits_from_hidden(x, head, tie, policy, n_valid=cfg.vocab)


def forward(params, tokens: Array, cfg: ArchConfig,
            policy: Optional[QuantPolicy] = None,
            return_hidden: bool = False) -> Array:
    """Training/scoring forward: tokens [B, S] -> fp32 logits [B,S,V]."""
    B, S = tokens.shape
    x = embedding_apply(params["embed"], tokens, policy)
    x = x.astype(policy.compute_dtype if policy else jnp.float32)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    body = functools.partial(_block_apply, cfg=cfg, policy=policy,
                             positions=positions)
    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)

    if cfg.scan_layers:
        x, _ = jax.lax.scan(lambda h, p: (body(p, h), None),
                            x, params["blocks"])
    else:
        for i in range(cfg.n_layers):
            x = body(jax.tree.map(lambda l, i=i: l[i],
                                  params["blocks"]), x)

    x = rmsnorm_apply(params["ln_f"], x)
    if return_hidden:
        return x
    return _head(params, x, cfg, policy)


def loss_fn(params, batch, cfg: ArchConfig,
            policy: Optional[QuantPolicy] = None) -> Array:
    x = forward(params, batch["tokens"], cfg, policy,
                return_hidden=True)
    return chunked_ce(lambda h: _head(params, h, cfg, policy), x,
                      batch["labels"], batch.get("mask"))


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_caches(cfg: ArchConfig, batch: int, max_len: int,
                kv_bits: int = 32, dtype=jnp.float32):
    """Stacked per-layer KV caches [L, ...].

    Sliding-window archs get ring buffers of size min(window, max_len):
    this is what makes long_500k decoding O(window) in memory.
    """
    cap = max_len if cfg.window is None else min(cfg.window, max_len)
    ring = cfg.window is not None and cap < max_len
    one = init_cache(batch, cap, cfg.n_kv_heads, cfg.hd, kv_bits, dtype,
                     ring=ring)
    return jax.tree.map(
        lambda l: jnp.broadcast_to(l[None], (cfg.n_layers,) + l.shape),
        one)


def prefill(params, tokens: Array, cfg: ArchConfig,
            policy: Optional[QuantPolicy] = None, kv_bits: int = 32):
    """Prefill: returns (last-position logits [B, V], caches)."""
    B, S = tokens.shape
    x = embedding_apply(params["embed"], tokens, policy)
    x = x.astype(policy.compute_dtype if policy else jnp.float32)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def step(h, layer_params):
        out, cache = _block_prefill(layer_params, h, cfg, policy,
                                    positions, kv_bits)
        return out, cache

    x, caches = jax.lax.scan(step, x, params["blocks"])
    x = rmsnorm_apply(params["ln_f"], x[:, -1:])
    return _head(params, x, cfg, policy)[:, 0], caches


def decode_step(params, token: Array, caches, index, cfg: ArchConfig,
                policy: Optional[QuantPolicy] = None, kv_bits: int = 32):
    """One decode step: token [B, 1] int32 -> (logits [B, V], caches)."""
    x = embedding_apply(params["embed"], token, policy)
    x = x.astype(policy.compute_dtype if policy else jnp.float32)

    def step(h, xs):
        layer_params, cache = xs
        out, cache = _block_decode(layer_params, h, cfg, policy, cache,
                                   index, kv_bits)
        return out, cache

    x, caches = jax.lax.scan(step, x, (params["blocks"], caches))
    x = rmsnorm_apply(params["ln_f"], x)
    return _head(params, x, cfg, policy)[:, 0], caches