"""Shared model plumbing: stacked (scanned) layer init, losses, specs."""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.nn.module import Param, is_param

Array = jax.Array


def stack_init(block_init_fn: Callable, key: Array, n: int):
    """vmap a block init over n layer keys; leaves get leading 'layers'
    axis in both value and logical axes."""
    keys = jax.random.split(key, n)
    boxed = jax.vmap(block_init_fn)(keys)

    def fix(p: Param) -> Param:
        axes = p.axes if p.axes is not None \
            else (None,) * (p.value.ndim - 1)
        return Param(p.value, ("layers",) + tuple(axes))

    return jax.tree.map(fix, boxed, is_leaf=is_param)


def cross_entropy(logits: Array, labels: Array,
                  mask: Optional[Array] = None) -> Array:
    """Mean next-token CE.  logits fp32 [B, S, V]; labels int [B, S].

    Computed without gathering the full softmax: logsumexp minus the
    label logit (works with vocab-sharded logits: the reductions lower
    to all-reduces over the model axis).
    """
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    lab = jnp.take_along_axis(logits, labels[..., None],
                              axis=-1)[..., 0]
    nll = lse - lab
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1)
    return nll.mean()



def chunked_ce(head_fn, x, labels, mask=None, chunk: int = 1024):
    """Fused chunked head+CE: the [B, S, vocab] logits tensor is never
    materialized — the head matmul and the CE reduction run per token
    chunk under remat (backward recomputes each chunk's logits).  Cuts
    the loss-head transient from O(S*V) to O(chunk*V) bytes, which for
    a 152k vocab at 4k seq is the largest single buffer in the step.
    """
    B, S, D = x.shape
    x = constrain(x, ("batch", None, None))        # gather seq under SP
    if chunk is None or S <= chunk or S % chunk != 0:
        return cross_entropy(head_fn(x), labels, mask)
    n = S // chunk
    xs = jnp.moveaxis(x.reshape(B, n, chunk, D), 1, 0)
    ls = jnp.moveaxis(labels.reshape(B, n, chunk), 1, 0)
    ms = jnp.moveaxis(mask.reshape(B, n, chunk), 1, 0) \
        if mask is not None else jnp.ones((n, B, chunk), jnp.float32)

    @jax.checkpoint
    def body(carry, xs_c):
        x_c, l_c, m_c = xs_c
        logits = head_fn(x_c).astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        lab = jnp.take_along_axis(logits, l_c[..., None],
                                  axis=-1)[..., 0]
        nll = (lse - lab) * m_c
        tot, cnt = carry
        return (tot + nll.sum(), cnt + m_c.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())),
                                 (xs, ls, ms))
    return tot / jnp.maximum(cnt, 1)


def sinusoidal_positions(length: int, d_model: int) -> Array:
    """Whisper-style sinusoidal position embeddings [length, d_model]."""
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d_model // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-dim * (jnp.log(10000.0) / (d_model // 2 - 1)))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def logits_from_hidden(x, head, tie_emb, policy, n_valid=None):
    """Final projection, fp32 logits, vocab-sharded.

    Under SP the hidden state arrives sequence-sharded; gather it first
    (claiming "seq" here would steal the mesh axis from "vocab" and
    leave full-vocab logits unsharded — far worse)."""
    from repro.core.qmatmul import q_matmul
    from repro.nn.linear import embedding_attend
    x = constrain(x, ("batch", None, None))
    if tie_emb is not None:
        logits = embedding_attend(tie_emb, x, policy)
    else:
        logits = q_matmul(x, head, policy)
    logits = logits.astype(jnp.float32)
    if n_valid is not None and n_valid < logits.shape[-1]:
        # mask padded vocab columns (see configs.base.pad_vocab)
        pad_mask = jnp.where(jnp.arange(logits.shape[-1]) < n_valid,
                             0.0, -1e9)
        logits = logits + pad_mask
    logits = constrain(logits, ("batch", None, "vocab"))
    return logits
