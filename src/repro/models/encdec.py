"""Whisper-style encoder-decoder backbone (LayerNorm + GELU).

The audio frontend (mel conv stem) is a STUB per the assignment:
inputs are precomputed frame embeddings [B, S_enc, d_model].
Positional encoding is sinusoidal (length-agnostic), so every assigned
shape lowers cleanly.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, pad_vocab
from repro.core.policy import QuantPolicy
from repro.models.common import (chunked_ce, cross_entropy, logits_from_hidden,
                                 sinusoidal_positions, stack_init)
from repro.nn.attention import (AttnConfig, attention_apply,
                                attention_decode, attention_init,
                                cache_update, init_cache)
from repro.nn.linear import (embedding_apply, embedding_init,
                             linear_apply, linear_init)
from repro.nn.mlp import mlp_apply, mlp_init
from repro.nn.module import KeySeq
from repro.nn.norm import layernorm_apply, layernorm_init

Array = jax.Array


def _acfg(cfg: ArchConfig, causal: bool, cross: bool = False):
    return AttnConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd, causal=causal,
        rope=False, cross=cross, q_chunk=cfg.q_chunk)


def _enc_block_init(key, cfg, dtype):
    ks = KeySeq(key)
    return {
        "ln1": layernorm_init(ks(), cfg.d_model, dtype),
        "attn": attention_init(ks(), _acfg(cfg, causal=False), dtype),
        "ln2": layernorm_init(ks(), cfg.d_model, dtype),
        "mlp": mlp_init(ks(), cfg.d_model, cfg.d_ff, dtype=dtype),
    }


def _dec_block_init(key, cfg, dtype):
    ks = KeySeq(key)
    return {
        "ln1": layernorm_init(ks(), cfg.d_model, dtype),
        "self": attention_init(ks(), _acfg(cfg, causal=True), dtype),
        "ln_x": layernorm_init(ks(), cfg.d_model, dtype),
        "cross": attention_init(ks(), _acfg(cfg, causal=False,
                                            cross=True), dtype),
        "ln2": layernorm_init(ks(), cfg.d_model, dtype),
        "mlp": mlp_init(ks(), cfg.d_model, cfg.d_ff, dtype=dtype),
    }


def init(key, cfg: ArchConfig, dtype=jnp.float32):
    ks = KeySeq(key)
    return {
        "embed": embedding_init(ks(), pad_vocab(cfg.vocab), cfg.d_model,
                                axes=("vocab", "d_model"), dtype=dtype),
        "enc_blocks": stack_init(
            lambda k: _enc_block_init(k, cfg, dtype), ks(), cfg.n_layers),
        "dec_blocks": stack_init(
            lambda k: _dec_block_init(k, cfg, dtype), ks(), cfg.n_layers),
        "ln_enc": layernorm_init(ks(), cfg.d_model, dtype),
        "ln_dec": layernorm_init(ks(), cfg.d_model, dtype),
        "lm_head": linear_init(ks(), cfg.d_model, pad_vocab(cfg.vocab),
                               axes=("d_model", "vocab"), bias=False,
                               dtype=dtype),
    }


def encode(params, frames: Array, cfg: ArchConfig,
           policy: Optional[QuantPolicy] = None) -> Array:
    """frames: [B, S, d_model] (stub frontend embeddings)."""
    B, S, _ = frames.shape
    x = frames + sinusoidal_positions(S, cfg.d_model)[None].astype(
        frames.dtype)

    def body(p, h):
        a = attention_apply(p["attn"], layernorm_apply(p["ln1"], h),
                            _acfg(cfg, causal=False), policy)
        h = h + a
        return h + mlp_apply(p["mlp"], layernorm_apply(p["ln2"], h),
                             policy, act=cfg.act)

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(lambda h, p: (body(p, h), None), x,
                        params["enc_blocks"])
    return layernorm_apply(params["ln_enc"], x)


def decode_train(params, tokens: Array, enc_out: Array, cfg: ArchConfig,
                 policy: Optional[QuantPolicy] = None,
                 return_hidden: bool = False) -> Array:
    B, S = tokens.shape
    x = embedding_apply(params["embed"], tokens, policy)
    x = x.astype(enc_out.dtype)
    x = x + sinusoidal_positions(S, cfg.d_model)[None].astype(x.dtype)

    def body(p, h):
        a = attention_apply(p["self"], layernorm_apply(p["ln1"], h),
                            _acfg(cfg, causal=True), policy)
        h = h + a
        c = attention_apply(p["cross"], layernorm_apply(p["ln_x"], h),
                            _acfg(cfg, causal=False, cross=True), policy,
                            encoder_out=enc_out)
        h = h + c
        return h + mlp_apply(p["mlp"], layernorm_apply(p["ln2"], h),
                             policy, act=cfg.act)

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(lambda h, p: (body(p, h), None), x,
                        params["dec_blocks"])
    x = layernorm_apply(params["ln_dec"], x)
    if return_hidden:
        return x
    return logits_from_hidden(x, params["lm_head"]["w"], None,
                              policy, n_valid=cfg.vocab)


def loss_fn(params, batch, cfg: ArchConfig,
            policy: Optional[QuantPolicy] = None) -> Array:
    enc_out = encode(params, batch["frames"], cfg, policy)
    x = decode_train(params, batch["tokens"], enc_out, cfg, policy,
                     return_hidden=True)
    head = lambda h: logits_from_hidden(h, params["lm_head"]["w"], None,
                                        policy, n_valid=cfg.vocab)
    return chunked_ce(head, x, batch["labels"], batch.get("mask"))


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_caches(cfg: ArchConfig, batch: int, max_len: int,
                kv_bits: int = 32, dtype=jnp.float32,
                enc_len: Optional[int] = None):
    enc_len = enc_len or max_len
    one = {
        "self": init_cache(batch, max_len, cfg.n_kv_heads, cfg.hd,
                           kv_bits, dtype),
        "cross": init_cache(batch, enc_len, cfg.n_kv_heads, cfg.hd,
                            kv_bits, dtype),
    }
    return jax.tree.map(
        lambda l: jnp.broadcast_to(l[None], (cfg.n_layers,) + l.shape),
        one)


def prefill(params, batch, cfg: ArchConfig,
            policy: Optional[QuantPolicy] = None, kv_bits: int = 32):
    """Encode frames + build decoder cross caches; prime self caches
    with the decoder prompt tokens.  Returns (logits [B, V], caches)."""
    frames, tokens = batch["frames"], batch["tokens"]
    enc_out = encode(params, frames, cfg, policy)
    B, S = tokens.shape
    x = embedding_apply(params["embed"], tokens, policy)
    x = x.astype(enc_out.dtype)
    x = x + sinusoidal_positions(S, cfg.d_model)[None].astype(x.dtype)

    def step(h, p):
        a, self_c = attention_apply(
            p["self"], layernorm_apply(p["ln1"], h),
            _acfg(cfg, causal=True), policy, return_cache=True,
            kv_bits=kv_bits)
        h = h + a
        # build the (static) cross K/V cache from encoder output
        from repro.nn.attention import _project_qkv
        _, ck, cv = _project_qkv(p["cross"], enc_out, enc_out,
                                 _acfg(cfg, False, True), policy)
        cross_c = cache_update(
            init_cache(B, enc_out.shape[1], cfg.n_kv_heads, cfg.hd,
                       kv_bits, enc_out.dtype), ck, cv, 0, kv_bits)
        c = attention_apply(p["cross"], layernorm_apply(p["ln_x"], h),
                            _acfg(cfg, causal=False, cross=True), policy,
                            encoder_out=enc_out)
        h = h + c
        h = h + mlp_apply(p["mlp"], layernorm_apply(p["ln2"], h), policy,
                          act=cfg.act)
        return h, {"self": self_c, "cross": cross_c}

    x, caches = jax.lax.scan(step, x, params["dec_blocks"])
    x = layernorm_apply(params["ln_dec"], x[:, -1:])
    logits = logits_from_hidden(x, params["lm_head"]["w"], None,
                              policy, n_valid=cfg.vocab)
    return logits[:, 0], caches


def decode_step(params, token: Array, caches, index, cfg: ArchConfig,
                policy: Optional[QuantPolicy] = None, kv_bits: int = 32):
    B = token.shape[0]
    x = embedding_apply(params["embed"], token, policy)
    x = x.astype(policy.compute_dtype if policy else jnp.float32)
    # position embedding for the current index (dynamic-slice safe)
    S_max = caches["self"]["k"].shape[2]
    table = sinusoidal_positions(S_max, cfg.d_model)
    x = x + jax.lax.dynamic_slice_in_dim(table, index, 1)[None].astype(
        x.dtype)

    def step(h, xs):
        p, cache = xs
        a, self_c = attention_decode(
            p["self"], layernorm_apply(p["ln1"], h),
            _acfg(cfg, causal=True), cache["self"], index, policy,
            kv_bits=kv_bits)
        h = h + a
        c, _ = attention_decode(
            p["cross"], layernorm_apply(p["ln_x"], h),
            _acfg(cfg, causal=False, cross=True), None, index, policy,
            cross_cache=cache["cross"], kv_bits=kv_bits)
        h = h + c
        h = h + mlp_apply(p["mlp"], layernorm_apply(p["ln2"], h), policy,
                          act=cfg.act)
        return h, {"self": self_c, "cross": cache["cross"]}

    x, caches = jax.lax.scan(step, x, (params["dec_blocks"], caches))
    x = layernorm_apply(params["ln_dec"], x)
    logits = logits_from_hidden(x, params["lm_head"]["w"], None,
                              policy, n_valid=cfg.vocab)
    return logits[:, 0], caches
