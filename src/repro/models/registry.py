"""Family registry: resolve an ArchConfig to its model module + specs.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
input of the lowered step — weak-type-correct, shardable, no device
allocation (dry-run contract).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.configs.shapes import ShapeConfig
from repro.models import encdec, mamba, recurrent, transformer

FAMILIES = {
    "dense": transformer,
    "moe": transformer,
    "encdec": encdec,
    "hybrid": recurrent,
    "ssm": mamba,
}


def model_for(cfg: ArchConfig):
    return FAMILIES[cfg.family]


def sharding_rules(cfg: ArchConfig, model_axis: int = 16,
                   serve: bool = False) -> Dict:
    """Per-arch logical->mesh overrides (see DESIGN.md §4).

    ``serve=True``: no optimizer state exists and steps are
    latency-bound, so weights drop the FSDP ("d_model" over data)
    sharding — pure TP, no per-step weight all-gathers."""
    rules: Dict[str, Any] = {}
    if serve:
        rules["d_model"] = None
    # KV heads shard on the model axis only when the head count divides
    if cfg.n_kv_heads and cfg.n_kv_heads % model_axis == 0:
        rules["kv_heads"] = "model"
    if cfg.seq_shard:
        rules["seq"] = "model"       # sequence parallelism (see base.py)
    # MoE: expert-parallel when experts divide the axis, else
    # TP-within-expert (d_ff_expert already -> "model" in BASE_RULES)
    if cfg.is_moe:
        if cfg.n_experts % model_axis == 0:
            rules["experts"] = "model"
            rules["d_ff_expert"] = None
        else:
            rules["experts"] = None
            rules["d_ff_expert"] = "model"
    return rules


def _token_batch(shape: ShapeConfig, seq: int, batch: int):
    tokens = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    labels = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    return {"tokens": tokens, "labels": labels}


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Abstract inputs for the step that this (arch, shape) cell lowers.

    train  -> loss/grad step inputs {tokens, labels} (+frames for encdec)
    prefill-> {tokens} (+frames)
    decode -> {token [B,1]}; caches are built separately (they are state,
              not inputs — see launch/dryrun.py)
    """
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        if cfg.is_encdec:
            return {
                "frames": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                               jnp.float32),
                "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
            }
        return _token_batch(shape, S, B)
    if shape.kind == "prefill":
        if cfg.is_encdec:
            return {
                "frames": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                               jnp.float32),
                "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            }
        return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    # decode: one new token against a seq_len-deep cache
    return {"token": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
