"""The paper's agent: quantized hierarchical RL network (E2HRL / Fig 4-5).

Pipeline (paper Sec. III):
  obs image -> 3x Q-Conv (stride 2 replaces pooling, ReLU)
            -> flatten -> Q-FC -> 32-d image embedding
            -> sub-goal module (Q-FC "FC-HRL" or Q-LSTM "LSTM-HRL")
            -> concat(embedding, sub-goal) -> Q-FC -> Softmax action

Two-stage PPO (paper): train the action module first, freeze it, then
fine-tune the sub-goal module — the param tree is split accordingly
("action" vs "subgoal" subtrees; rl/ppo.py masks gradients by stage).
A value head (not in the FPGA datapath, needed by PPO) reads the same
concat features.

Every matmul is a Q-MAC (q_matmul); softmax/sigmoid/tanh are V-ACT
(cordic backend when the policy says so).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.e2hrl import HRLConfig
from repro.core.policy import QuantPolicy
from repro.core.vact import activation
from repro.nn.conv import conv2d_init, qconv_block
from repro.nn.linear import linear_apply, linear_init
from repro.nn.lstm import lstm_apply, lstm_init
from repro.nn.module import KeySeq
from repro.core.qmatmul import q_matmul

Array = jax.Array


def _flat_dim(cfg: HRLConfig) -> int:
    h, w, _ = cfg.obs_shape
    for _ in cfg.conv_channels:
        h = (h + 1) // 2
        w = (w + 1) // 2
    return h * w * cfg.conv_channels[-1]


def init(key, cfg: HRLConfig, dtype=jnp.float32):
    ks = KeySeq(key)
    convs = []
    c_in = cfg.obs_shape[-1]
    for c_out in cfg.conv_channels:
        convs.append(conv2d_init(ks(), c_in, c_out, cfg.conv_kernel,
                                 dtype))
        c_in = c_out
    params = {
        "stem": {
            "convs": convs,
            "fc": linear_init(ks(), _flat_dim(cfg), cfg.embed_dim,
                              axes=(None, None), dtype=dtype),
        },
        "subgoal": {},
        "action": {
            "fc": linear_init(ks(), cfg.embed_dim + cfg.subgoal_dim,
                              cfg.n_actions, axes=(None, None),
                              dtype=dtype),
        },
    }
    if cfg.subgoal_kind == "fc":
        params["subgoal"] = {
            "fc1": linear_init(ks(), cfg.embed_dim, cfg.subgoal_hidden,
                               axes=(None, None), dtype=dtype),
            "fc2": linear_init(ks(), cfg.subgoal_hidden, cfg.subgoal_dim,
                               axes=(None, None), dtype=dtype),
        }
    else:
        params["subgoal"] = {
            "lstm": lstm_init(ks(), cfg.embed_dim, cfg.subgoal_hidden,
                              dtype),
            "out": linear_init(ks(), cfg.subgoal_hidden, cfg.subgoal_dim,
                               axes=(None, None), dtype=dtype),
        }
    if cfg.value_head:
        params["value"] = linear_init(
            ks(), cfg.embed_dim + cfg.subgoal_dim, 1, axes=(None, None),
            dtype=dtype)
    return params


def embed(params, obs: Array, cfg: HRLConfig,
          policy: Optional[QuantPolicy] = None) -> Array:
    """obs: [B, H, W, C] in [0, 1] -> [B, embed_dim] (ReLU'd)."""
    x = obs
    for pc in params["stem"]["convs"]:
        x = qconv_block(pc, x, stride=2, policy=policy)
    x = x.reshape(x.shape[0], -1)
    x = linear_apply(params["stem"]["fc"], x, policy)
    return activation(x, "relu", policy)


def subgoal(params, e: Array, cfg: HRLConfig,
            policy: Optional[QuantPolicy] = None,
            lstm_state: Optional[Tuple] = None):
    """e: [B, embed_dim] (fc) or [B, K, embed_dim] (lstm window)."""
    p = params["subgoal"]
    if cfg.subgoal_kind == "fc":
        h = activation(linear_apply(p["fc1"], e, policy), "relu", policy)
        g = activation(linear_apply(p["fc2"], h, policy), "tanh", policy)
        return g, None
    hs, state = lstm_apply(p["lstm"], e, policy, lstm_state)
    g = activation(linear_apply(p["out"], hs[:, -1], policy), "tanh",
                   policy)
    return g, state


def apply(params, obs: Array, cfg: HRLConfig,
          policy: Optional[QuantPolicy] = None,
          lstm_state: Optional[Tuple] = None):
    """Full agent.  obs: [B,H,W,C] (fc) or [B,K,H,W,C] (lstm window).

    Returns (action_logits [B, A], value [B], new_lstm_state).
    """
    if cfg.subgoal_kind == "lstm":
        B, K = obs.shape[:2]
        e_seq = embed(params, obs.reshape((B * K,) + obs.shape[2:]), cfg,
                      policy).reshape(B, K, -1)
        e = e_seq[:, -1]
        g, state = subgoal(params, e_seq, cfg, policy, lstm_state)
    else:
        e = embed(params, obs, cfg, policy)
        g, state = subgoal(params, e, cfg, policy)
    feat = jnp.concatenate([e, g], axis=-1)
    logits = linear_apply(params["action"]["fc"], feat, policy)
    value = None
    if cfg.value_head:
        value = linear_apply(params["value"], feat, policy)[..., 0]
    return logits, value, state


def action_probs(logits: Array,
                 policy: Optional[QuantPolicy] = None) -> Array:
    """Softmax action head — V-ACT's softmax mode under quantization."""
    return activation(logits, "softmax", policy)
