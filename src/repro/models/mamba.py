"""Mamba2 LM (attention-free SSD stack)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, pad_vocab
from repro.core.policy import QuantPolicy
from repro.models.common import (chunked_ce, cross_entropy,
                                 logits_from_hidden, stack_init)
from repro.nn.linear import embedding_apply, embedding_init, linear_init
from repro.nn.module import KeySeq
from repro.nn.norm import rmsnorm_apply, rmsnorm_init
from repro.nn.ssm import (SSMConfig, ssm_apply, ssm_init, ssm_init_state)

Array = jax.Array


def ssm_config(cfg: ArchConfig) -> SSMConfig:
    return SSMConfig(
        d_model=cfg.d_model, d_inner=cfg.ssm_expand * cfg.d_model,
        head_dim=cfg.ssm_head_dim, d_state=cfg.ssm_state,
        n_groups=1, chunk=cfg.ssm_chunk)


def _block_init(key, cfg: ArchConfig, dtype):
    ks = KeySeq(key)
    return {
        "ln": rmsnorm_init(ks(), cfg.d_model, dtype),
        "ssm": ssm_init(ks(), ssm_config(cfg), dtype),
    }


def init(key, cfg: ArchConfig, dtype=jnp.float32):
    ks = KeySeq(key)
    return {
        "embed": embedding_init(ks(), pad_vocab(cfg.vocab), cfg.d_model,
                                axes=("vocab", "d_model"), dtype=dtype),
        "blocks": stack_init(lambda k: _block_init(k, cfg, dtype), ks(),
                             cfg.n_layers),
        "ln_f": rmsnorm_init(ks(), cfg.d_model, dtype),
        "lm_head": linear_init(ks(), cfg.d_model, pad_vocab(cfg.vocab),
                               axes=("d_model", "vocab"), bias=False,
                               dtype=dtype),
    }


def forward(params, tokens: Array, cfg: ArchConfig,
            policy: Optional[QuantPolicy] = None,
            return_hidden: bool = False) -> Array:
    scfg = ssm_config(cfg)
    x = embedding_apply(params["embed"], tokens, policy)
    x = x.astype(policy.compute_dtype if policy else jnp.float32)

    def body(p, h):
        return h + ssm_apply(p["ssm"], rmsnorm_apply(p["ln"], h), scfg,
                             policy)

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(lambda h, p: (body(p, h), None), x,
                        params["blocks"])
    x = rmsnorm_apply(params["ln_f"], x)
    if return_hidden:
        return x
    return logits_from_hidden(x, params["lm_head"]["w"], None,
                              policy, n_valid=cfg.vocab)


def loss_fn(params, batch, cfg: ArchConfig,
            policy: Optional[QuantPolicy] = None) -> Array:
    x = forward(params, batch["tokens"], cfg, policy,
                return_hidden=True)
    head = lambda h: logits_from_hidden(h, params["lm_head"]["w"], None,
                                        policy, n_valid=cfg.vocab)
    return chunked_ce(head, x, batch["labels"], batch.get("mask"))


def init_caches(cfg: ArchConfig, batch: int, max_len: int,
                kv_bits: int = 32, dtype=jnp.float32):
    """Constant-size recurrent state per layer (no KV growth)."""
    del max_len, kv_bits
    one = ssm_init_state(batch, ssm_config(cfg))
    return jax.tree.map(
        lambda l: jnp.broadcast_to(l[None], (cfg.n_layers,) + l.shape),
        one)


def prefill(params, tokens: Array, cfg: ArchConfig,
            policy: Optional[QuantPolicy] = None, kv_bits: int = 32):
    """Prefill via the chunked SSD path; emits real final states."""
    del kv_bits
    scfg = ssm_config(cfg)
    x = embedding_apply(params["embed"], tokens, policy)
    x = x.astype(policy.compute_dtype if policy else jnp.float32)

    def step(h, p):
        out, state = ssm_apply(p["ssm"], rmsnorm_apply(p["ln"], h), scfg,
                               policy, return_state=True)
        return h + out, state

    x, caches = jax.lax.scan(step, x, params["blocks"])
    x = rmsnorm_apply(params["ln_f"], x[:, -1:])
    logits = logits_from_hidden(x, params["lm_head"]["w"], None,
                              policy, n_valid=cfg.vocab)
    return logits[:, 0], caches


def decode_step(params, token: Array, caches, index, cfg: ArchConfig,
                policy: Optional[QuantPolicy] = None, kv_bits: int = 32):
    del index, kv_bits
    scfg = ssm_config(cfg)
    x = embedding_apply(params["embed"], token, policy)
    x = x.astype(policy.compute_dtype if policy else jnp.float32)

    def step(h, xs):
        p, state = xs
        out, state = ssm_apply(p["ssm"], rmsnorm_apply(p["ln"], h), scfg,
                               policy, state=state)
        return h + out, state

    x, caches = jax.lax.scan(step, x, (params["blocks"], caches))
    x = rmsnorm_apply(params["ln_f"], x)
    logits = logits_from_hidden(x, params["lm_head"]["w"], None,
                              policy, n_valid=cfg.vocab)
    return logits[:, 0], caches
