"""Mode 2 — abstract-evaluation audit of every accepted training combo.

No training FLOPs run: each (env x net x algo x precision) combination
``rl_train`` accepts is swept through ``jax.make_jaxpr`` /
``jax.eval_shape`` / ``jit.lower`` on the *real* step functions
(:mod:`repro.rl.train_steps` — the exact programs training runs) and
audited for:

* **QF901** — no 64-bit dtype anywhere in the traced step, and the
  threaded state comes back with exactly the avals it went in with
  (shape, dtype, weak_type): an aval drift means silent upcasts or a
  retrace every iteration.
* **QF902** — every packed ``QTensor``'s scale sits on its consumer's
  per-out-channel grid: 2-D ``[in, out]`` weights -> ``(1, out)``,
  stacked 3-D ``[L, in, out]`` -> ``(L, 1, out)``, conv HWIO 4-D ->
  ``(1, 1, 1, c_out)``.  Any *other* rank is itself a finding — a new
  layer family must extend the table (and ``quantize_params``)
  deliberately, not inherit a wrong branch (the PR 6 conv bug).
* **QF903** — the serving bucket ladder compiles exactly one program
  per bucket: ``len(_jit_cache) == len(buckets)`` and every cached
  function's jit cache holds exactly 1 entry after a sweep of request
  sizes (a second entry = a silent retrace, the latency cliff the
  pad-to-bucket design exists to prevent).
* **QF904** — donation survives lowering: the step's StableHLO carries
  ``tf.aliasing_output`` input-output aliases (a donate_argnums that
  silently failed to stick would double peak memory).

The bucket audit (QF903) runs a few tiny real forwards (warmup
compiles); everything else is abstract.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.analysis.rules import Finding

CHECKS: Dict[str, str] = {
    "QF901": "64-bit dtype in traced step, or threaded-state aval "
             "drift (shape/dtype/weak_type) across one iteration",
    "QF902": "QTensor scale off the consumer's per-out-channel grid",
    "QF903": "serving bucket ladder compiled more (or fewer) than one "
             "program per bucket",
    "QF904": "donate_argnums did not survive lowering "
             "(no input-output aliases in the StableHLO)",
}

PRECISION_AXIS = ("fp32", "fxp8")
_BAD_DTYPES = ("float64", "int64", "uint64", "complex128")


@dataclasses.dataclass
class TraceResult:
    findings: List[Finding]
    combos_checked: List[str]


# ---------------------------------------------------------------------------
# combo enumeration — by construction the same acceptance logic the
# CLI runs: the real constructors either build the combo or raise
# ---------------------------------------------------------------------------


def accepted_combos() -> List[Tuple[str, str, str, str]]:
    """Every (env, net, algo, precision) that ``rl_train``'s dispatch
    accepts, decided by calling the real env/agent constructors."""
    from repro.rl.envs import make, registered
    from repro.rl.inference import (NETS, ON_POLICY_ALGOS, VALUE_ALGOS,
                                    build_env, make_value_agent)
    from repro.rl.trainer import make_agent

    combos = []
    key = jax.random.PRNGKey(0)
    for env_name in sorted(registered()):
        for net in NETS:
            for algo in ON_POLICY_ALGOS + VALUE_ALGOS:
                try:
                    if algo in ON_POLICY_ALGOS:
                        env = (build_env(env_name, net)
                               if net == "conv" else make(env_name))
                        make_agent("mlp", env, key, None, net)
                    else:
                        env = build_env(env_name, net)
                        make_value_agent(algo, env.spec, net=net)
                except ValueError:
                    continue
                for precision in PRECISION_AXIS:
                    combos.append((env_name, net, algo, precision))
    return combos


def _combo_tag(env_name, net, algo, precision) -> str:
    return f"trace:{env_name}/{net}/{algo}/{precision}"


# ---------------------------------------------------------------------------
# QF901 helpers — jaxpr dtype walk + aval parity
# ---------------------------------------------------------------------------


def _iter_subjaxprs(params):
    for v in params.values():
        if isinstance(v, jax.core.ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, jax.core.Jaxpr):
            yield v
        elif isinstance(v, (tuple, list)):
            for x in v:
                if isinstance(x, jax.core.ClosedJaxpr):
                    yield x.jaxpr
                elif isinstance(x, jax.core.Jaxpr):
                    yield x


def find_wide_dtypes(closed: "jax.core.ClosedJaxpr") -> List[str]:
    """All distinct 64-bit dtypes appearing on any var in the jaxpr."""
    seen = set()
    stack = [closed.jaxpr]
    visited = set()
    while stack:
        jxp = stack.pop()
        if id(jxp) in visited:
            continue
        visited.add(id(jxp))
        for v in list(jxp.invars) + list(jxp.outvars) + \
                list(jxp.constvars):
            aval = getattr(v, "aval", None)
            if aval is not None and hasattr(aval, "dtype"):
                if str(aval.dtype) in _BAD_DTYPES:
                    seen.add(str(aval.dtype))
        for eqn in jxp.eqns:
            for v in eqn.outvars:
                aval = getattr(v, "aval", None)
                if aval is not None and hasattr(aval, "dtype"):
                    if str(aval.dtype) in _BAD_DTYPES:
                        seen.add(str(aval.dtype))
            stack.extend(_iter_subjaxprs(eqn.params))
    return sorted(seen)


def _aval_sig(x):
    return (tuple(x.shape), str(x.dtype),
            bool(getattr(x, "weak_type", False)))


def state_parity_mismatches(in_tree, out_tree, label: str) -> List[str]:
    """Leaves whose (shape, dtype, weak_type) changed across the step."""
    ins, in_def = jax.tree.flatten(in_tree)
    outs, out_def = jax.tree.flatten(out_tree)
    if in_def != out_def:
        return [f"{label}: pytree structure changed "
                f"({in_def} -> {out_def})"]
    bad = []
    paths = jax.tree_util.tree_flatten_with_path(in_tree)[0]
    for (path, i), o in zip(paths, outs, strict=True):
        si, so = _aval_sig(i), _aval_sig(o)
        if si != so:
            bad.append(f"{label}{jax.tree_util.keystr(path)}: "
                       f"{si} -> {so}")
    return bad


# ---------------------------------------------------------------------------
# QF902 — quantization grid audit
# ---------------------------------------------------------------------------


def expected_scale_shape(qvalue_shape: Tuple[int, ...]
                         ) -> Optional[Tuple[int, ...]]:
    """The per-out-channel grid the blessed consumers broadcast
    against; None = rank not in the convention table."""
    nd = len(qvalue_shape)
    if nd == 2:                       # [in, out] linear
        return (1, qvalue_shape[1])
    if nd == 3:                       # [L, in, out] stacked layers
        return (qvalue_shape[0], 1, qvalue_shape[2])
    if nd == 4:                       # [H, W, I, O] conv HWIO
        return (1, 1, 1, qvalue_shape[3])
    return None


def check_packed_tree(packed, bits: int, tag: str) -> List[Finding]:
    """Walk an (abstract or concrete) packed tree and check every
    QTensor against the grid table."""
    from repro.core.fxp import QTensor

    findings: List[Finding] = []

    def visit(node, path):
        if isinstance(node, QTensor):
            qshape = tuple(node.qvalue.shape)
            want = expected_scale_shape(qshape)
            got = tuple(node.scale.shape)
            if want is None:
                findings.append(Finding(
                    tag, 0, "QF902",
                    f"{path}: rank-{len(qshape)} QTensor {qshape} has "
                    "no entry in the per-out-channel grid table — "
                    "extend expected_scale_shape AND quantize_params "
                    "for the new layer family"))
            elif got != want:
                findings.append(Finding(
                    tag, 0, "QF902",
                    f"{path}: scale grid {got} != consumer grid "
                    f"{want} for weight {qshape} (w{bits})"))
            if node.bits != bits:
                findings.append(Finding(
                    tag, 0, "QF902",
                    f"{path}: packed bits {node.bits} != policy "
                    f"w_bits {bits}"))
            return
        if isinstance(node, dict):
            for k, v in node.items():
                visit(v, f"{path}/{k}")
        elif isinstance(node, (tuple, list)):
            for i, v in enumerate(node):
                visit(v, f"{path}[{i}]")

    visit(packed, "params")
    return findings


def audit_qtensor_grids(params, bits: int, tag: str) -> List[Finding]:
    """eval_shape ``quantize_params`` over ``params`` and check every
    produced QTensor against the grid table — abstract, no FLOPs."""
    from repro.core.policy import QuantPolicy
    from repro.core.quantizer import quantize_params

    policy = QuantPolicy(name=f"w{bits}", w_bits=bits,
                         per_channel=True)
    packed = jax.eval_shape(lambda p: quantize_params(p, policy),
                            params)
    return check_packed_tree(packed, bits, tag)


# ---------------------------------------------------------------------------
# per-combo step construction
# ---------------------------------------------------------------------------

_N_ENVS = 4
_ROLLOUT = 2
_CAPACITY = 512


def _build_value_step(env_name, net, algo, precision):
    from repro.core.policy import get_policy
    from repro.optim import AdamWConfig, adamw_init, constant
    from repro.rl.actor_learner import pack_weights
    from repro.rl.inference import build_env, make_value_agent
    from repro.rl.replay import make_replay
    from repro.rl.rollout import init_envs
    from repro.rl.train_steps import make_value_iteration

    env = build_env(env_name, net)
    spec = env.spec
    key = jax.random.PRNGKey(0)
    a_policy = get_policy("fxp8") if precision == "fxp8" else None
    agent = make_value_agent(algo, spec, key, net=net)
    params = agent.params
    target = jax.tree.map(jnp.copy, params)
    if algo == "ddpg":
        opt = {"actor": adamw_init(params["actor"]),
               "critic": adamw_init(params["critic"])}
        rb = make_replay("uniform", _CAPACITY, spec.obs_shape,
                         spec.action_space.shape, jnp.float32)
    else:
        opt = adamw_init(params)
        rb = make_replay("uniform", _CAPACITY, spec.obs_shape)
    buf = rb.init()
    est, obs = init_envs(env, jax.random.PRNGKey(1), _N_ENVS)
    iteration = make_value_iteration(
        env, agent, rb, a_policy, constant(1e-3),
        AdamWConfig(weight_decay=0.0, max_grad_norm=10.0), algo=algo,
        rollout_len=_ROLLOUT, updates_per_iter=1, per_beta0=0.4,
        beta_iters=1)
    comm = 8 if a_policy else 32
    packed = pack_weights(agent.behaviour_subtree(params), comm)
    args = (params, target, opt, buf, packed, est, obs,
            jax.random.PRNGKey(2), jnp.asarray(0))
    threaded = {"params": params, "target": target, "opt": opt,
                "buf": buf, "est": est, "obs": obs}
    out_slots = ("params", "target", "opt", "buf", "est", "obs")
    return iteration, args, threaded, out_slots, params


def _build_onpolicy_step(env_name, net, algo, precision):
    from repro.core.policy import get_policy
    from repro.launch.mesh import make_host_mesh
    from repro.rl.trainer import make_agent
    from repro.optim import AdamWConfig, adamw_init, constant
    from repro.rl import PPOConfig
    from repro.rl.actor_learner import pack_weights
    from repro.rl.dists import distribution_for
    from repro.rl.inference import build_env
    from repro.rl.envs import make
    from repro.rl.ppo import a2c_loss, ppo_loss
    from repro.rl.rollout import init_envs
    from repro.rl.train_steps import make_onpolicy_iteration

    env = build_env(env_name, net) if net == "conv" else make(env_name)
    key = jax.random.PRNGKey(0)
    pol_name = "fxp8" if precision == "fxp8" else None
    params, apply_fn = make_agent("mlp", env, key, pol_name, net)
    a_policy = get_policy(pol_name) if pol_name else None
    mesh = make_host_mesh(1)
    dist = distribution_for(env.action_space)
    pcfg = (PPOConfig() if algo == "ppo"
            else PPOConfig(epochs=1, minibatches=1))
    # 8 steps x 4 envs = 32 samples: divisible by the default 4
    # minibatches
    rollout = 8
    iteration = make_onpolicy_iteration(
        env, apply_fn, a_policy, mesh, dist, pcfg,
        ppo_loss if algo == "ppo" else a2c_loss, constant(3e-3),
        AdamWConfig(weight_decay=0.0, max_grad_norm=0.5),
        rollout_len=rollout, n_envs=_N_ENVS, n_slots=1)
    opt = adamw_init(params)
    est, obs = init_envs(env, jax.random.PRNGKey(1), _N_ENVS,
                         mesh=mesh)
    packed = pack_weights(params, 8 if a_policy else 32)
    args = (params, opt, est, obs, packed, jax.random.PRNGKey(2),
            None, jnp.ones((1,), bool))
    threaded = {"params": params, "opt": opt, "est": est, "obs": obs}
    out_slots = ("params", "opt", "est", "obs")
    return iteration, args, threaded, out_slots, params


def _build_sharded_value_step(env_name, net, algo, precision,
                              replay_kind="uniform"):
    from repro.core.policy import get_policy
    from repro.launch.mesh import make_host_mesh
    from repro.optim import AdamWConfig, adamw_init, constant
    from repro.rl.actor_learner import pack_weights
    from repro.rl.inference import build_env, make_value_agent
    from repro.rl.replay import make_sharded_replay
    from repro.rl.rollout import init_envs
    from repro.rl.train_steps import make_sharded_value_iteration

    env = build_env(env_name, net)
    spec = env.spec
    key = jax.random.PRNGKey(0)
    a_policy = get_policy("fxp8") if precision == "fxp8" else None
    agent = make_value_agent(algo, spec, key, net=net)
    params = agent.params
    target = jax.tree.map(jnp.copy, params)
    mesh = make_host_mesh(1)
    if algo == "ddpg":
        opt = {"actor": adamw_init(params["actor"]),
               "critic": adamw_init(params["critic"])}
        srb = make_sharded_replay(replay_kind, 1, _CAPACITY,
                                  spec.obs_shape,
                                  spec.action_space.shape, jnp.float32)
    else:
        opt = adamw_init(params)
        srb = make_sharded_replay(replay_kind, 1, _CAPACITY,
                                  spec.obs_shape)
    buf = srb.init()
    est, obs = init_envs(env, jax.random.PRNGKey(1), _N_ENVS,
                         mesh=mesh)
    iteration = make_sharded_value_iteration(
        env, agent, srb, a_policy, constant(1e-3),
        AdamWConfig(weight_decay=0.0, max_grad_norm=10.0), mesh,
        algo=algo, rollout_len=_ROLLOUT, updates_per_iter=1,
        per_beta0=0.4, beta_iters=1)
    comm = 8 if a_policy else 32
    packed = pack_weights(agent.behaviour_subtree(params), comm)
    args = (params, target, opt, buf, packed, est, obs,
            jax.random.PRNGKey(2), jnp.asarray(0),
            jnp.ones((1,), bool))
    threaded = {"params": params, "target": target, "opt": opt,
                "buf": buf, "est": est, "obs": obs}
    out_slots = ("params", "target", "opt", "buf", "est", "obs")
    return iteration, args, threaded, out_slots, params


# the sharded value path (mesh-mapped collection + per-device replay
# shards + psum'd learner) must satisfy the same invariants as the
# single-device programs — QF904 especially: the double-buffered
# overlap doubles peak memory if donation silently fails to stick
SHARDED_VALUE_COMBOS = (
    ("cartpole", "mlp", "dqn", "fp32", "uniform"),
    ("cartpole", "mlp", "dqn", "fxp8", "per"),
    ("cartpole", "mlp", "qrdqn", "fxp8", "uniform"),
    ("pendulum", "mlp", "ddpg", "fxp8", "uniform"),
    # pixel stem at fxp8: the integer qconv path (custom-vjp over the
    # taps/Pallas kernel) must keep donation + single-trace discipline
    ("catch", "conv", "qrdqn", "fxp8", "uniform"),
)


# ---------------------------------------------------------------------------
# audits
# ---------------------------------------------------------------------------


def audit_step(env_name, net, algo, precision,
               sharded_replay: Optional[str] = None) -> List[Finding]:
    from repro.rl.inference import ON_POLICY_ALGOS

    tag = _combo_tag(env_name, net, algo, precision)
    if sharded_replay is not None:
        tag += f"/sharded-{sharded_replay}"
        iteration, args, threaded, out_slots, params = \
            _build_sharded_value_step(env_name, net, algo, precision,
                                      sharded_replay)
    else:
        build = (_build_onpolicy_step if algo in ON_POLICY_ALGOS
                 else _build_value_step)
        iteration, args, threaded, out_slots, params = build(
            env_name, net, algo, precision)

    findings: List[Finding] = []

    # QF901a: 64-bit dtypes anywhere in the traced step
    closed = jax.make_jaxpr(iteration)(*args)
    for dt in find_wide_dtypes(closed):
        findings.append(Finding(
            tag, 0, "QF901",
            f"{dt} appears in the traced iteration — 64-bit values "
            "must not enter the quantized training step"))

    # QF901b: threaded-state aval parity across the step
    out = jax.eval_shape(iteration, *args)
    for i, name in enumerate(out_slots):
        for msg in state_parity_mismatches(threaded[name], out[i],
                                           name):
            findings.append(Finding(
                tag, 0, "QF901",
                f"threaded-state aval drift: {msg}"))

    # QF904: donation must survive lowering
    lowered_text = iteration.lower(*args).as_text()
    if "tf.aliasing_output" not in lowered_text:
        findings.append(Finding(
            tag, 0, "QF904",
            "no input-output aliases in the lowered step — "
            "donate_argnums did not stick"))

    # QF902: packed-weight grids, at the serving/actor precisions
    findings.extend(audit_qtensor_grids(params, 8, tag))
    findings.extend(audit_qtensor_grids(params, 4, tag))
    return findings


def audit_buckets(env_name: str = "cartpole", net: str = "mlp",
                  max_bucket: int = 8) -> List[Finding]:
    """QF903 on a real PolicyServer: sweep request sizes across the
    ladder, then require one compiled program per bucket, each traced
    exactly once."""
    from repro.rl.inference import build_env, make_value_agent
    from repro.serve.engine import PolicyServer
    from repro.serve.loader import ServedPolicy

    tag = f"trace:{env_name}/{net}/serve/w8"
    env = build_env(env_name, net)
    agent = make_value_agent("dqn", env.spec,
                             key=jax.random.PRNGKey(0), net=net)
    policy = ServedPolicy.from_agent(agent, env_name, net=net)
    server = PolicyServer(policy, precision="w8",
                          max_bucket=max_bucket)
    server.warmup()
    obs_shape = tuple(policy.env.obs_shape)
    # odd request sizes spanning every bucket + an overflow chunk
    for n in [1, 2, 3, max_bucket, max_bucket + 1]:
        server.act(jnp.zeros((n,) + obs_shape, jnp.float32))
    return check_bucket_ladder(server, tag)


def check_bucket_ladder(server, tag: str) -> List[Finding]:
    findings: List[Finding] = []
    if set(server._jit_cache) != set(server.buckets):
        findings.append(Finding(
            tag, 0, "QF903",
            f"bucket ladder {server.buckets} compiled programs for "
            f"{sorted(server._jit_cache)} — one program per bucket"))
    for b, fn in server._jit_cache.items():
        n_traces = fn._cache_size()
        if n_traces != 1:
            findings.append(Finding(
                tag, 0, "QF903",
                f"bucket {b} retraced: {n_traces} cache entries for "
                "one bucket size — a shape/dtype leak past the "
                "pad-to-bucket boundary"))
    return findings


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------


def run_trace_audit(fast: bool = False,
                    combos: Optional[List[Tuple[str, str, str, str]]]
                    = None) -> TraceResult:
    """Sweep the accepted combos.  ``fast`` keeps one representative
    per (net, algo, precision) family instead of every env — the
    per-family program structure is identical, only shapes differ."""
    all_combos = combos if combos is not None else accepted_combos()
    if fast:
        seen, picked = set(), []
        for c in all_combos:
            k = c[1:]
            if k not in seen:
                seen.add(k)
                picked.append(c)
        all_combos = picked

    findings: List[Finding] = []
    checked: List[str] = []
    for env_name, net, algo, precision in all_combos:
        findings.extend(audit_step(env_name, net, algo, precision))
        checked.append(_combo_tag(env_name, net, algo, precision))

    # the sharded value programs (per-device collect + replay shards +
    # psum learner), donation assertion included
    for env_name, net, algo, precision, rep in SHARDED_VALUE_COMBOS:
        findings.extend(audit_step(env_name, net, algo, precision,
                                   sharded_replay=rep))
        checked.append(_combo_tag(env_name, net, algo, precision)
                       + f"/sharded-{rep}")

    # the serving ladder, on both torso families
    findings.extend(audit_buckets("cartpole", "mlp"))
    checked.append("trace:cartpole/mlp/serve/w8")
    findings.extend(audit_buckets("catch", "conv", max_bucket=4))
    checked.append("trace:catch/conv/serve/w8")
    return TraceResult(findings=findings, combos_checked=checked)
