"""Audited exceptions to the lint rules.

``allowlist.toml`` (next to this file) holds ``[[allow]]`` entries:

    [[allow]]
    rule   = "QF201"
    path   = "src/repro/rl/envs/wrappers.py"
    match  = "normalize_observation"
    reason = "factory-time guard; runs on host before any tracing"

An entry suppresses a finding when ``rule`` and ``path`` match exactly
and ``match`` is either a substring of the finding's message or equal
to its qualname (empty ``match`` matches the whole file+rule).  Every
entry must carry a non-empty ``reason`` — that's the audit trail.

Two failure directions, both CI-fatal:
* an **unlisted** finding fails the run (exit 1);
* a **stale** entry — one that suppressed nothing — also fails
  (exit 2), so the allowlist can only shrink as violations get fixed.

Parsed with :mod:`tomllib` on 3.11+, with a fallback mini-parser for
the restricted string-only format on 3.10 (CI's floor), so the gate
never needs a toml dependency.
"""
from __future__ import annotations

import dataclasses
import os
from typing import List, Optional, Sequence, Tuple

from repro.analysis.rules import Finding

DEFAULT_PATH = os.path.join(os.path.dirname(__file__),
                            "allowlist.toml")


@dataclasses.dataclass
class AllowEntry:
    rule: str
    path: str
    match: str = ""
    reason: str = ""
    lineno: int = 0

    def covers(self, f: Finding) -> bool:
        if f.rule != self.rule or f.path != self.path:
            return False
        if not self.match:
            return True
        return self.match in f.message or self.match == f.qualname


class AllowlistError(ValueError):
    pass


def _parse_restricted(text: str, src: str) -> List[AllowEntry]:
    """String-only [[allow]] tables — enough for this file, no toml
    module needed."""
    entries: List[AllowEntry] = []
    current: Optional[dict] = None
    for i, raw in enumerate(text.splitlines(), 1):
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        if line == "[[allow]]":
            current = {"lineno": i}
            entries.append(current)  # filled in place
            continue
        if "=" in line and current is not None:
            key, _, val = line.partition("=")
            key, val = key.strip(), val.strip()
            # strip trailing comments outside the quoted string
            if val.startswith('"'):
                end = val.find('"', 1)
                if end < 0:
                    raise AllowlistError(
                        f"{src}:{i}: unterminated string")
                current[key] = val[1:end]
                continue
        raise AllowlistError(
            f"{src}:{i}: unsupported syntax {line!r} — allowlist "
            "entries are [[allow]] tables of quoted strings")
    return [AllowEntry(rule=e.get("rule", ""), path=e.get("path", ""),
                       match=e.get("match", ""),
                       reason=e.get("reason", ""),
                       lineno=e["lineno"]) for e in entries]


def load_allowlist(path: str = DEFAULT_PATH) -> List[AllowEntry]:
    if not os.path.exists(path):
        return []
    with open(path, "rb") as fh:
        raw = fh.read()
    try:
        import tomllib
        data = tomllib.loads(raw.decode("utf-8"))
        entries = [AllowEntry(rule=e.get("rule", ""),
                              path=e.get("path", ""),
                              match=e.get("match", ""),
                              reason=e.get("reason", ""))
                   for e in data.get("allow", [])]
    except ModuleNotFoundError:
        entries = _parse_restricted(raw.decode("utf-8"), path)
    for e in entries:
        if not e.rule or not e.path:
            raise AllowlistError(
                f"{path}: entry missing rule/path: {e}")
        if not e.reason.strip():
            raise AllowlistError(
                f"{path}: entry for {e.rule} {e.path} has no reason "
                "— every audited exception needs one")
    return entries


def apply_allowlist(
        findings: Sequence[Finding],
        entries: Sequence[AllowEntry],
) -> Tuple[List[Finding], List[AllowEntry], List[Finding]]:
    """-> (unsuppressed findings, stale entries, suppressed)."""
    used = [False] * len(entries)
    kept: List[Finding] = []
    suppressed: List[Finding] = []
    for f in findings:
        hit = False
        for i, e in enumerate(entries):
            if e.covers(f):
                used[i] = True
                hit = True
        (suppressed if hit else kept).append(f)
    stale = [e for i, e in enumerate(entries) if not used[i]]
    return kept, stale, suppressed
