"""QF401 — jitted state-threading loops must declare buffer donation.

A jitted step that takes a buffer-sized pytree (optimizer state,
replay buffer, observation bank, ...) and returns its updated version
holds *two* copies live across every call unless the input is donated.
The rule flags ``jax.jit`` sites — decorator, ``partial(jax.jit, ...)``
or direct call on a locally-defined function — where the wrapped
function threads a known state-pytree name through to its return value
without ``donate_argnums``/``donate_argnames``.

Deliberately narrow: ``params`` is *not* a state name (packed actor
weights may alias parameter leaves, making donation unsafe), and only
returns of *bare names* count — a function returning fresh computed
values isn't threading state.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set

from repro.analysis.rules import (Finding, LintContext, dotted_name,
                                  func_params, resolve_dotted)

RULE_ID = "QF401"
SUMMARY = ("jax.jit threads a buffer-sized state pytree without "
           "donate_argnums")

# parameter names that carry buffer-sized threaded state in this repo
STATE_NAMES = {
    "opt", "opt_state", "buf", "buffer", "replay", "target", "est",
    "env_state", "obs", "state", "caches", "rb_state",
}
JIT_NAMES = {"jax.jit", "jax.pmap"}
PARTIAL_NAMES = {"functools.partial", "partial"}
DONATE_KWS = {"donate_argnums", "donate_argnames"}


def _jit_call_kwargs(call: ast.Call, imports) -> Optional[Set[str]]:
    """If ``call`` is jax.jit(...) or partial(jax.jit, ...), return the
    set of keyword names it passes; else None."""
    name = dotted_name(call.func)
    if name is None:
        return None
    resolved = resolve_dotted(name, imports)
    if resolved in JIT_NAMES:
        return {kw.arg for kw in call.keywords if kw.arg}
    if resolved in PARTIAL_NAMES and call.args:
        inner = dotted_name(call.args[0])
        if inner and resolve_dotted(inner, imports) in JIT_NAMES:
            return {kw.arg for kw in call.keywords if kw.arg}
    return None


def _returned_bare_names(func: ast.AST) -> Set[str]:
    names: Set[str] = set()
    for node in ast.walk(func):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)) and node is not func:
            continue
        if isinstance(node, ast.Return) and node.value is not None:
            vals = (node.value.elts
                    if isinstance(node.value, ast.Tuple)
                    else [node.value])
            for v in vals:
                if isinstance(v, ast.Name):
                    names.add(v.id)
    return names


def _threaded_state(func: ast.AST) -> Set[str]:
    params = set(func_params(func))
    return (params & STATE_NAMES) & _returned_bare_names(func)


def check(ctx: LintContext) -> List[Finding]:
    findings: List[Finding] = []
    for f in ctx.files:
        # qualname lookup by def node, and by (scope, name) for
        # resolving jax.jit(fn) on a local function
        by_node = {id(info.node): qn
                   for qn, info in f.functions.items()}
        by_name = {}
        for _qn, info in f.functions.items():
            if isinstance(info.node, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                by_name.setdefault(info.node.name, info)

        def flag(func_node, qn, threaded, rel=f.rel):
            findings.append(Finding(
                rel, func_node.lineno, RULE_ID,
                f"jit of `{qn}` threads state "
                f"{sorted(threaded)} without donate_argnums",
                qn))

        # 1) decorator sites
        for qn, info in f.functions.items():
            node = info.node
            if isinstance(node, ast.Lambda):
                continue
            for dec in node.decorator_list:
                kwargs = None
                if isinstance(dec, ast.Call):
                    kwargs = _jit_call_kwargs(dec, f.imports)
                else:
                    name = dotted_name(dec)
                    if name and resolve_dotted(
                            name, f.imports) in JIT_NAMES:
                        kwargs = set()
                if kwargs is None:
                    continue
                if kwargs & DONATE_KWS:
                    continue
                threaded = _threaded_state(node)
                if threaded:
                    flag(node, qn, threaded)

        # 2) direct jax.jit(local_fn, ...) call sites
        for node in ast.walk(f.tree):
            if not isinstance(node, ast.Call):
                continue
            kwargs = _jit_call_kwargs(node, f.imports)
            if kwargs is None or kwargs & DONATE_KWS:
                continue
            # the wrapped function: first positional arg (or second,
            # after jax.jit itself, for the partial form)
            name = dotted_name(node.func)
            resolved = resolve_dotted(name, f.imports) if name else ""
            args = node.args
            target = (args[1] if resolved in PARTIAL_NAMES
                      and len(args) > 1
                      else args[0] if resolved in JIT_NAMES and args
                      else None)
            if not isinstance(target, ast.Name):
                continue
            info = by_name.get(target.id)
            if info is None:
                continue
            threaded = _threaded_state(info.node)
            if threaded:
                flag(node, by_node.get(id(info.node), target.id),
                     threaded)

    # a def can carry the decorator AND appear in a call — dedupe
    seen, out = set(), []
    for fd in findings:
        key = (fd.path, fd.qualname)
        if key not in seen:
            seen.add(key)
            out.append(fd)
    return out
