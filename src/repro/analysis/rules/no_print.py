"""QF601 — bare ``print()`` in library code.

Library modules report through structured telemetry
(:mod:`repro.obs`): jit-safe metric buffers, JSONL records and the
``Console`` renderer — never raw ``print()``, which bypasses the
``verbose`` gate, cannot be captured into a run's telemetry and turns
log format into an implicit API.  Launch drivers
(``src/repro/launch/``) are the human-facing CLIs and stay exempt;
``repro.obs.console`` itself holds the one sanctioned print site and
carries an allowlist entry.
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.rules import (Finding, LintContext, dotted_name,
                                  walk_body)

RULE_ID = "QF601"
SUMMARY = ("bare print() in library code (route output through "
           "repro.obs: Console / JsonlSink)")


def _exempt(rel: str, cfg) -> bool:
    exempt = getattr(cfg, "qf601_exempt", ())
    return any(rel == p or rel.startswith(p.rstrip("/") + "/")
               for p in exempt)


def _is_print(node: ast.AST) -> bool:
    return (isinstance(node, ast.Call)
            and dotted_name(node.func) == "print")


def check(ctx: LintContext) -> List[Finding]:
    findings: List[Finding] = []
    for f in ctx.files:
        if _exempt(f.rel, ctx.config):
            continue
        in_func = set()
        for qn, info in f.functions.items():
            for node in walk_body(info.node):
                if _is_print(node):
                    in_func.add(id(node))
                    findings.append(Finding(
                        f.rel, node.lineno, RULE_ID,
                        f"bare print() in `{qn}` — emit through "
                        "repro.obs (Console for human lines, "
                        "JsonlSink for records)", qn))
        for node in ast.walk(f.tree):
            if _is_print(node) and id(node) not in in_func:
                findings.append(Finding(
                    f.rel, node.lineno, RULE_ID,
                    "bare print() at module level — emit through "
                    "repro.obs (Console for human lines, JsonlSink "
                    "for records)", ""))
    return findings
