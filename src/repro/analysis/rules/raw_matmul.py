"""QF101 — raw matmul/conv primitives outside the blessed entry points.

Quantized data-path modules (``rl/``, ``serve/``, ``nn/linear.py``)
must route every contraction through ``core/qmatmul.py`` or
``nn/conv.py`` so the fake-quant insertion points stay consistent.  A
raw ``jnp.dot`` in a net silently skips quantization and desyncs
train/serve bit-parity.
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.rules import (Finding, LintContext, dotted_name,
                                  resolve_dotted)

RULE_ID = "QF101"
SUMMARY = ("raw matmul/conv primitive in a quantized data-path module "
           "(use core.qmatmul / nn.conv)")

# fully-resolved dotted names that perform a contraction
BANNED_CALLS = {
    "jax.numpy.dot", "jax.numpy.matmul", "jax.numpy.einsum",
    "jax.numpy.tensordot", "jax.numpy.vdot", "jax.numpy.inner",
    "jax.lax.dot", "jax.lax.dot_general",
    "jax.lax.conv", "jax.lax.conv_general_dilated",
    "jax.lax.conv_transpose", "jax.lax.conv_with_general_padding",
}


def _in_scope(rel: str, cfg) -> bool:
    if any(rel == b or rel.startswith(b.rstrip("/") + "/")
           for b in cfg.qf101_blessed):
        return False
    return any(rel == s or rel.startswith(s.rstrip("/") + "/")
               for s in cfg.qf101_scope)


def check(ctx: LintContext) -> List[Finding]:
    findings: List[Finding] = []
    for f in ctx.files:
        if not _in_scope(f.rel, ctx.config):
            continue
        # map node -> enclosing function qualname for reporting
        owner = {}
        for qn, info in f.functions.items():
            for node in ast.walk(info.node):
                owner.setdefault(id(node), qn)
        for node in ast.walk(f.tree):
            if isinstance(node, ast.Call):
                name = dotted_name(node.func)
                if name is None:
                    continue
                resolved = resolve_dotted(name, f.imports)
                if resolved in BANNED_CALLS:
                    findings.append(Finding(
                        f.rel, node.lineno, RULE_ID,
                        f"raw contraction `{name}` — route through "
                        "core.qmatmul / nn.conv",
                        owner.get(id(node), "")))
            elif isinstance(node, ast.BinOp) and isinstance(
                    node.op, ast.MatMult):
                findings.append(Finding(
                    f.rel, node.lineno, RULE_ID,
                    "`@` matmul operator — route through "
                    "core.qmatmul / nn.conv",
                    owner.get(id(node), "")))
    return findings
