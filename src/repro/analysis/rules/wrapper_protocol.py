"""QF501 — env wrappers must go through the ``_wrap`` tagging protocol.

``wrapper_stack(env)`` is how order-sensitive compositions are
validated (e.g. ``running_normalize_observation`` refuses to wrap a
frame-stacked env).  That introspection only works if every wrapper
routes through ``_wrap``, which tags the produced step function.  A
wrapper that calls ``env.replace(step=...)`` directly produces an
untagged step and silently breaks the stack checks downstream.
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.rules import (Finding, LintContext, dotted_name,
                                  resolve_dotted)

RULE_ID = "QF501"
SUMMARY = ("env wrapper rebinds reset/step without the _wrap tagging "
           "protocol (wrapper_stack would miss it)")

REBIND_KWS = {"step", "reset"}
EXEMPT_FUNCS = {"_wrap"}


def _in_scope(rel: str, cfg) -> bool:
    return any(rel == s or rel.startswith(s.rstrip("/") + "/")
               for s in cfg.qf501_scope)


def check(ctx: LintContext) -> List[Finding]:
    findings: List[Finding] = []
    for f in ctx.files:
        if not _in_scope(f.rel, ctx.config):
            continue
        for qn, info in f.functions.items():
            # the tagging helper itself (by exact or trailing name —
            # it may live nested or in a class)
            leaf = qn.split(".")[-1]
            if leaf in EXEMPT_FUNCS:
                continue
            for node in ast.walk(info.node):
                if isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)) and \
                        node is not info.node:
                    continue       # nested defs report under their qn
                if not isinstance(node, ast.Call):
                    continue
                name = dotted_name(node.func)
                if name is None:
                    continue
                resolved = resolve_dotted(name, f.imports)
                is_replace = (name.endswith(".replace")
                              or resolved == "dataclasses.replace")
                if not is_replace:
                    continue
                kws = {kw.arg for kw in node.keywords if kw.arg}
                if kws & REBIND_KWS:
                    findings.append(Finding(
                        f.rel, node.lineno, RULE_ID,
                        f"`{name}(... {sorted(kws & REBIND_KWS)} ...)`"
                        " rebinds env functions outside _wrap — use "
                        "_wrap(env, name, reset=..., step=...)", qn))
    return findings
