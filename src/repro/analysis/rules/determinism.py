"""QF301 — host-side nondeterminism inside jit-reachable code.

Randomness in traced code must flow through ``jax.random`` keys
(``fold_in``/``split``) so runs are reproducible and resumable;
``numpy.random``/stdlib ``random`` draw from hidden host state that is
baked in at trace time, and wall-clock reads (``time.time`` et al.)
make the compiled program depend on when it was traced.  Host-level
timing *outside* traced code (e.g. serving latency measurement) is
fine and not flagged.
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.rules import (Finding, LintContext, dotted_name,
                                  resolve_dotted)
from repro.analysis.rules.tracer_control import _own_statements

RULE_ID = "QF301"
SUMMARY = ("numpy.random / stdlib random / wall-clock read in "
           "jit-reachable code (thread jax.random keys instead)")

BANNED_EXACT = {
    "time.time", "time.time_ns", "time.perf_counter",
    "time.perf_counter_ns", "time.monotonic", "time.monotonic_ns",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.date.today",
}
BANNED_PREFIXES = ("numpy.random.", "random.")


def _banned(resolved: str) -> bool:
    if resolved.startswith("jax."):
        return False                      # jax.random is the fix
    if resolved in BANNED_EXACT:
        return True
    return any(resolved.startswith(p) for p in BANNED_PREFIXES)


def check(ctx: LintContext) -> List[Finding]:
    findings: List[Finding] = []
    for f in ctx.files:
        for qn, info in f.functions.items():
            if not ctx.is_reachable(f.rel, qn):
                continue
            for node in _own_statements(info.node):
                if not isinstance(node, ast.Call):
                    continue
                name = dotted_name(node.func)
                if name is None:
                    continue
                resolved = resolve_dotted(name, f.imports)
                if _banned(resolved):
                    findings.append(Finding(
                        f.rel, node.lineno, RULE_ID,
                        f"nondeterministic `{name}` in jit-reachable "
                        f"`{qn}` — use jax.random with fold_in keys",
                        qn))
    return findings
