"""Rule registry + the shared AST context the lint rules consume.

Each rule module defines ``RULE_ID``, ``SUMMARY`` and
``check(ctx) -> list[Finding]``.  The driver (:mod:`repro.analysis.lint`)
builds one :class:`LintContext` — parsed ASTs, import maps and the
cross-module jit-reachability graph — and hands it to every rule, so
the (comparatively expensive) reachability analysis runs once.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Optional, Tuple

# ---------------------------------------------------------------------------
# findings
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Finding:
    """One reported violation: ``path:line rule-id message``."""

    path: str          # repo-relative posix path
    line: int
    rule: str
    message: str
    qualname: str = ""  # enclosing function, for allowlist matching

    def render(self) -> str:
        return f"{self.path}:{self.line} {self.rule} {self.message}"


# ---------------------------------------------------------------------------
# per-file AST context
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FuncInfo:
    """One function (or lambda) definition with its lexical context."""

    qualname: str                 # e.g. "value_train.<locals>.iteration"
    node: ast.AST                 # FunctionDef | AsyncFunctionDef | Lambda
    parent: Optional["FuncInfo"]  # lexically enclosing function
    cls: Optional[str]            # enclosing class name, if a method


@dataclasses.dataclass
class FileCtx:
    path: str                     # absolute
    rel: str                      # repo-relative posix (src/repro/...)
    module: str                   # dotted module name (repro....)
    tree: ast.Module
    # local name -> dotted target ("jnp" -> "jax.numpy",
    # "mlp_q_apply" -> "repro.rl.nets.mlp_q_apply")
    imports: Dict[str, str] = dataclasses.field(default_factory=dict)
    # qualname -> FuncInfo for every def/lambda in the file
    functions: Dict[str, FuncInfo] = dataclasses.field(
        default_factory=dict)


@dataclasses.dataclass
class LintContext:
    root: str                     # repo root (absolute)
    files: List[FileCtx]
    # (rel, qualname) pairs the reachability analysis marked as traced
    reachable: set = dataclasses.field(default_factory=set)
    config: object = None         # LintConfig (lint.py)

    def file(self, rel: str) -> Optional[FileCtx]:
        for f in self.files:
            if f.rel == rel:
                return f
        return None

    def is_reachable(self, rel: str, qualname: str) -> bool:
        return (rel, qualname) in self.reachable


# ---------------------------------------------------------------------------
# shared AST helpers
# ---------------------------------------------------------------------------


def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def resolve_dotted(name: str, imports: Dict[str, str]) -> str:
    """Rewrite the leading alias of a dotted name via the import map:
    ``jnp.dot`` -> ``jax.numpy.dot``, ``np.random.rand`` ->
    ``numpy.random.rand``.  Unknown heads pass through unchanged."""
    head, _, rest = name.partition(".")
    target = imports.get(head)
    if target is None:
        return name
    return f"{target}.{rest}" if rest else target


def build_file_ctx(path: str, rel: str, module: str,
                   source: str) -> FileCtx:
    tree = ast.parse(source, filename=path)
    ctx = FileCtx(path=path, rel=rel, module=module, tree=tree)

    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                ctx.imports[alias.asname or
                            alias.name.split(".")[0]] = (
                    alias.name if alias.asname else
                    alias.name.split(".")[0])
                # "import jax.numpy as jnp" binds jnp -> jax.numpy;
                # plain "import jax.numpy" binds only "jax"
                if alias.asname:
                    ctx.imports[alias.asname] = alias.name
        elif isinstance(node, ast.ImportFrom):
            if node.module is None or node.level:
                continue   # relative imports: not used in this repo
            for alias in node.names:
                ctx.imports[alias.asname or alias.name] = \
                    f"{node.module}.{alias.name}"

    # collect defs/lambdas with qualnames
    def visit(node: ast.AST, prefix: str, parent: Optional[FuncInfo],
              cls: Optional[str]):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef,
                                  ast.AsyncFunctionDef)):
                qn = f"{prefix}{child.name}"
                info = FuncInfo(qn, child, parent, cls)
                ctx.functions[qn] = info
                visit(child, f"{qn}.<locals>.", info, None)
            elif isinstance(child, ast.Lambda):
                qn = f"{prefix}<lambda@{child.lineno}>"
                info = FuncInfo(qn, child, parent, cls)
                ctx.functions[qn] = info
                visit(child, f"{qn}.<locals>.", info, None)
            elif isinstance(child, ast.ClassDef):
                visit(child, f"{prefix}{child.name}.", parent,
                      child.name)
            else:
                visit(child, prefix, parent, cls)

    visit(tree, "", None, None)
    return ctx


def func_params(node: ast.AST) -> List[str]:
    args = node.args
    names = [a.arg for a in args.posonlyargs + args.args
             + args.kwonlyargs]
    if args.vararg:
        names.append(args.vararg.arg)
    if args.kwarg:
        names.append(args.kwarg.arg)
    return names


def body_nodes(func: ast.AST):
    """Statements/expression of a def or lambda body."""
    if isinstance(func, ast.Lambda):
        return [func.body]
    return func.body


def walk_body(func: ast.AST, *, into_nested: bool = False):
    """Walk a function body, optionally stopping at nested defs (so a
    rule looking at *this* function's statements doesn't double-count
    its closures — they have their own FuncInfo entries)."""
    stack = list(body_nodes(func))
    while stack:
        node = stack.pop()
        yield node
        if not into_nested and isinstance(
                node, (ast.FunctionDef, ast.AsyncFunctionDef,
                       ast.Lambda)):
            continue
        stack.extend(ast.iter_child_nodes(node))


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def _load_rules():
    from repro.analysis.rules import (determinism, donation, no_print,
                                      raw_matmul, tracer_control,
                                      wrapper_protocol)
    mods = [raw_matmul, tracer_control, determinism, donation,
            wrapper_protocol, no_print]
    return {m.RULE_ID: m for m in mods}


RULES = _load_rules()


def rule_ids() -> Tuple[str, ...]:
    return tuple(sorted(RULES))
