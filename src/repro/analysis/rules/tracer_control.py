"""QF201 — Python control flow on likely-tracer values in jit-reachable code.

Inside a function that jit tracing can reach, a Python ``if``/``while``
/``assert``/``bool()``/``len()`` on an array value concretizes the
tracer and either crashes (``ConcretizationTypeError``) or silently
bakes one branch into the compiled program.  Shape/dtype/ndim/size
accesses are static under tracing and are pruned, as are ``is None``
checks, ``isinstance``/``hasattr``/``callable`` guards and string
comparisons — the rule only fires when a *likely-array* value (inferred
from jnp/lax usage or array-attribute access) flows into the condition.
"""
from __future__ import annotations

import ast
from typing import List, Set

from repro.analysis.rules import (Finding, LintContext, body_nodes,
                                  dotted_name, func_params,
                                  resolve_dotted)

RULE_ID = "QF201"
SUMMARY = ("Python branching / bool() / len() on a likely tracer in "
           "jit-reachable code (use lax.cond / jnp.where)")

# attribute access that marks a name as array-like — deliberately
# excludes shape/dtype/ndim/size: host code reads those off meshes,
# spaces and specs all the time, and they are static under tracing
ARRAY_ATTRS = {
    "astype", "reshape", "sum", "mean", "max", "min", "any", "all",
    "item", "at", "T", "argmax", "argmin", "clip", "squeeze",
    "ravel", "flatten", "transpose",
}
# attribute chains that are *static* under tracing
STATIC_ATTRS = {"shape", "dtype", "ndim", "size"}
# call heads that always produce traced arrays
ARRAY_PRODUCERS = ("jax.numpy.", "jax.lax.", "jax.nn.", "jax.random.",
                   "jax.scipy.")
# guards whose results are always concrete Python values
NEUTRAL_CALLS = {"isinstance", "hasattr", "callable", "getattr",
                 "type", "id", "repr", "str"}
SINK_CALLS = {"bool", "len", "int", "float"}


def _is_jaxish(resolved: str) -> bool:
    return any(resolved.startswith(p) for p in ARRAY_PRODUCERS)


SCALAR_ANNOTATIONS = {"int", "float", "str", "bool", "bytes"}


def _scalar_annotated(func: ast.AST) -> Set[str]:
    """Params annotated as plain Python scalars — config knobs like
    ``top_k: int`` flow into jnp calls but are never tracers."""
    if isinstance(func, ast.Lambda):
        return set()
    out: Set[str] = set()
    args = func.args
    for a in args.posonlyargs + args.args + args.kwonlyargs:
        ann = a.annotation
        if isinstance(ann, ast.Name) and ann.id in SCALAR_ANNOTATIONS:
            out.add(a.arg)
        elif isinstance(ann, ast.Constant) and \
                ann.value in SCALAR_ANNOTATIONS:
            out.add(a.arg)
    return out


def _infer_array_params(func: ast.AST, imports) -> Set[str]:
    """Params used in jnp/lax calls or via array attributes."""
    params = set(func_params(func)) - _scalar_annotated(func)
    arrayish: Set[str] = set()
    for node in ast.walk(func):
        if isinstance(node, ast.Attribute) and isinstance(
                node.value, ast.Name):
            if (node.value.id in params
                    and node.attr in ARRAY_ATTRS):
                arrayish.add(node.value.id)
        elif isinstance(node, ast.Call):
            name = dotted_name(node.func)
            if name is None:
                continue
            if _is_jaxish(resolve_dotted(name, imports)):
                for arg in list(node.args) + [
                        kw.value for kw in node.keywords]:
                    if isinstance(arg, ast.Name) and \
                            arg.id in params:
                        arrayish.add(arg.id)
    return arrayish


class _Taint:
    """Expression-level taint evaluation against a set of names."""

    def __init__(self, tainted: Set[str], imports):
        self.tainted = tainted
        self.imports = imports

    def expr(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Constant):
            return False
        if isinstance(node, ast.Attribute):
            if node.attr in STATIC_ATTRS:
                return False          # x.shape etc. are static
            return self.expr(node.value)
        if isinstance(node, ast.Subscript):
            return self.expr(node.value)
        if isinstance(node, ast.Call):
            # a compute method on a tainted receiver (x.sum(), y.any())
            # yields a traced array
            if isinstance(node.func, ast.Attribute) and \
                    node.func.attr in ARRAY_ATTRS and \
                    self.expr(node.func.value):
                return True
            name = dotted_name(node.func)
            if name is not None:
                if name in NEUTRAL_CALLS:
                    return False
                resolved = resolve_dotted(name, self.imports)
                if _is_jaxish(resolved):
                    return True
            args = list(node.args) + [kw.value
                                      for kw in node.keywords]
            return any(self.expr(a) for a in args)
        if isinstance(node, ast.Compare):
            # `x is None`, `x is not None` are concrete
            if all(isinstance(op, (ast.Is, ast.IsNot))
                   for op in node.ops):
                return False
            # string comparisons are config dispatch, not tracers
            operands = [node.left] + list(node.comparators)
            if any(isinstance(o, ast.Constant)
                   and isinstance(o.value, str) for o in operands):
                return False
            return any(self.expr(o) for o in operands)
        if isinstance(node, ast.BoolOp):
            return any(self.expr(v) for v in node.values)
        if isinstance(node, (ast.BinOp, ast.UnaryOp)):
            kids = ([node.left, node.right]
                    if isinstance(node, ast.BinOp)
                    else [node.operand])
            return any(self.expr(k) for k in kids)
        if isinstance(node, ast.IfExp):
            return self.expr(node.body) or self.expr(node.orelse)
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self.expr(e) for e in node.elts)
        if isinstance(node, ast.Starred):
            return self.expr(node.value)
        return False


def _target_names(target: ast.AST) -> List[str]:
    if isinstance(target, ast.Name):
        return [target.id]
    if isinstance(target, (ast.Tuple, ast.List)):
        out: List[str] = []
        for e in target.elts:
            out.extend(_target_names(e))
        return out
    if isinstance(target, ast.Starred):
        return _target_names(target.value)
    return []


def _own_statements(func: ast.AST):
    """Statements of this function, not descending into nested defs."""
    stack = list(body_nodes(func))
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        yield node
        stack.extend(ast.iter_child_nodes(node))


def _check_function(f, qn, info) -> List[Finding]:
    func = info.node
    tainted = _infer_array_params(func, f.imports)
    if not tainted and not _any_jax_calls(func, f.imports):
        return []
    tt = _Taint(tainted, f.imports)

    # propagate taint through assignments to a fixpoint
    stmts = [n for n in _own_statements(func)
             if isinstance(n, (ast.Assign, ast.AugAssign,
                               ast.AnnAssign))]
    changed = True
    while changed:
        changed = False
        for st in stmts:
            if isinstance(st, ast.Assign):
                targets, value = st.targets, st.value
            elif isinstance(st, ast.AnnAssign):
                if st.value is None:
                    continue
                targets, value = [st.target], st.value
            else:  # AugAssign
                targets, value = [st.target], st.value
            if value is not None and tt.expr(value):
                for t in targets:
                    for name in _target_names(t):
                        if name not in tt.tainted:
                            tt.tainted.add(name)
                            changed = True

    findings: List[Finding] = []

    def flag(node, what):
        findings.append(Finding(
            f.rel, node.lineno, RULE_ID,
            f"{what} on a likely tracer in jit-reachable "
            f"`{qn}` — use lax.cond / jnp.where / lax.select", qn))

    for node in _own_statements(func):
        if isinstance(node, ast.If) and tt.expr(node.test):
            flag(node, "Python `if`")
        elif isinstance(node, ast.While) and tt.expr(node.test):
            flag(node, "Python `while`")
        elif isinstance(node, ast.Assert) and tt.expr(node.test):
            flag(node, "`assert`")
        elif isinstance(node, ast.IfExp) and tt.expr(node.test):
            flag(node, "conditional expression")
        elif isinstance(node, ast.Call):
            name = dotted_name(node.func)
            if (name in SINK_CALLS and node.args
                    and tt.expr(node.args[0])):
                flag(node, f"`{name}()`")
    # dedupe (an `if a and b:` can hit two paths at one line)
    seen, out = set(), []
    for fd in findings:
        key = (fd.path, fd.line, fd.message)
        if key not in seen:
            seen.add(key)
            out.append(fd)
    return out


def _any_jax_calls(func: ast.AST, imports) -> bool:
    for node in ast.walk(func):
        if isinstance(node, ast.Call):
            name = dotted_name(node.func)
            if name and _is_jaxish(resolve_dotted(name, imports)):
                return True
    return False


def check(ctx: LintContext) -> List[Finding]:
    findings: List[Finding] = []
    for f in ctx.files:
        for qn, info in f.functions.items():
            if not ctx.is_reachable(f.rel, qn):
                continue
            findings.extend(_check_function(f, qn, info))
    return findings
