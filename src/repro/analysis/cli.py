"""``python -m repro.analysis`` — run the static checker.

    python -m repro.analysis              # lint + trace audit
    python -m repro.analysis lint         # AST rules only (fast)
    python -m repro.analysis trace        # abstract-eval audit only
    python -m repro.analysis --list-rules

Exit codes: 0 clean, 1 findings, 2 stale allowlist / config error.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional

from repro.analysis.allowlist import (AllowlistError, apply_allowlist,
                                      load_allowlist, DEFAULT_PATH)
from repro.analysis.lint import LintConfig, run_lint
from repro.analysis.rules import RULES, Finding, rule_ids


def _find_root(start: str) -> str:
    d = os.path.abspath(start)
    while True:
        if os.path.isdir(os.path.join(d, "src", "repro")):
            return d
        parent = os.path.dirname(d)
        if parent == d:
            raise SystemExit(
                "repro.analysis: could not locate the repo root "
                "(no src/repro above cwd) — pass --root")
        d = parent


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static invariant checker: AST lint + trace audit")
    p.add_argument("mode", nargs="?", default="all",
                   choices=["all", "lint", "trace"])
    p.add_argument("--root", default=None,
                   help="repo root (default: walk up from cwd)")
    p.add_argument("--rules", default=None,
                   help="comma-separated rule ids to run (lint mode)")
    p.add_argument("--allowlist", default=DEFAULT_PATH,
                   help="allowlist toml (default: the committed one)")
    p.add_argument("--no-allowlist", action="store_true",
                   help="report raw findings, ignore the allowlist")
    p.add_argument("--json", dest="json_out", default=None,
                   help="also write findings as JSON to this path")
    p.add_argument("--list-rules", action="store_true")
    p.add_argument("--trace-fast", action="store_true",
                   help="trace audit on a reduced combo sample "
                        "(per-family coverage instead of the full "
                        "env x net x algo x precision sweep)")
    return p


def _emit(findings: List[Finding], json_out: Optional[str],
          extra: Optional[dict] = None) -> None:
    for f in findings:
        print(f.render())
    if json_out:
        payload = {"findings": [f.__dict__ for f in findings]}
        payload.update(extra or {})
        with open(json_out, "w", encoding="utf-8") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
            fh.write("\n")


def main(argv: Optional[List[str]] = None) -> int:
    args = build_parser().parse_args(argv)

    if args.list_rules:
        for rid in rule_ids():
            print(f"{rid}  {RULES[rid].SUMMARY}")
        from repro.analysis import trace_audit
        for rid, summary in sorted(trace_audit.CHECKS.items()):
            print(f"{rid}  {summary}")
        return 0

    root = args.root or _find_root(os.getcwd())
    findings: List[Finding] = []
    extra: dict = {}

    if args.mode in ("all", "lint"):
        cfg = LintConfig()
        if args.rules:
            want = tuple(r.strip() for r in args.rules.split(","))
            unknown = [r for r in want if r not in RULES]
            if unknown:
                print(f"unknown rule ids: {unknown}",
                      file=sys.stderr)
                return 2
            cfg = LintConfig(rules=want)
        findings.extend(run_lint(root, config=cfg))

    if args.mode in ("all", "trace"):
        from repro.analysis import trace_audit
        tr = trace_audit.run_trace_audit(fast=args.trace_fast)
        findings.extend(tr.findings)
        extra["trace_combos"] = tr.combos_checked

    if args.no_allowlist:
        _emit(findings, args.json_out, extra)
        return 1 if findings else 0

    try:
        entries = load_allowlist(args.allowlist)
    except AllowlistError as e:
        print(f"allowlist error: {e}", file=sys.stderr)
        return 2

    kept, stale, suppressed = apply_allowlist(findings, entries)
    _emit(kept, args.json_out,
          {**extra, "suppressed": len(suppressed),
           "stale_allowlist": len(stale)})
    if suppressed:
        print(f"[allowlist] {len(suppressed)} finding(s) suppressed "
              f"by audited entries", file=sys.stderr)
    if stale:
        for e in stale:
            print(f"stale allowlist entry: rule={e.rule} "
                  f"path={e.path} match={e.match!r} — it suppresses "
                  "nothing; remove it", file=sys.stderr)
        return 2
    return 1 if kept else 0


if __name__ == "__main__":
    raise SystemExit(main())
