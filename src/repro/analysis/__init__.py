"""repro.analysis — static invariant checker for the quantized RL stack.

Two modes, one CLI (``python -m repro.analysis``), both CI-gated:

* **lint** (:mod:`repro.analysis.lint`) — AST rules over ``src/repro``
  that ruff cannot express because they need repo conventions and a
  cross-module jit-reachability graph: raw matmuls outside the blessed
  Q-MAC entry points (QF101), Python control flow on likely tracers
  (QF201), nondeterminism inside jit-reachable code (QF301), jitted
  state-threading loops without donation (QF401), and env wrappers that
  bypass the ``wrapper_stack`` tagging protocol (QF501).  Audited
  exceptions live in ``allowlist.toml`` next to this file; unlisted
  findings fail, stale entries fail too.

* **trace** (:mod:`repro.analysis.trace_audit`) — abstract evaluation
  (``jax.eval_shape`` / ``jax.make_jaxpr`` / ``jit.lower``, no real
  FLOPs) over every (env x net x algo x precision) combination the
  training CLI accepts: no 64-bit or weak-type promotion in the traced
  step (QF901), every packed QTensor on its consumer's per-out-channel
  scale grid (QF902 — the PR 6 conv-bug class, checked for all current
  and future layers), exactly one compiled program per serving bucket
  (QF903), and donation that actually survives lowering (QF904).
"""
from repro.analysis.rules import Finding, RULES, rule_ids

__all__ = ["Finding", "RULES", "rule_ids"]
