"""Mode 1 driver: parse ``src/repro``, build the jit-reachability
graph, run every rule, filter through the allowlist.

The reachability graph is what makes QF201/QF301 repo-aware rather
than a grep: a function is *jit-reachable* when tracing can enter it —

* **R1** it is decorated with a tracing transform (``@jax.jit``,
  ``@partial(jax.jit, ...)``, ``shard_map``, ``custom_vjp``, ...);
* **R2** it is passed by name (or as a lambda) into a transform call
  (``jax.jit(f)``, ``lax.scan(body, ...)``, ``jax.grad``,
  ``eval_shape``, ``defvjp``, ...);
* **R3** it follows the repo's traced-function naming conventions in a
  *library* module (``*_apply``, ``*loss*``, ``step``, ``reset``,
  agent policies) — these are called through env/agent structs, which
  a static call graph cannot see;
* plus transitive closure over calls: names resolved through lexical
  scope, module scope and imports, and attribute calls name-matched
  into library modules only (driver modules — ``launch/``, ``serve/``
  — host orchestration code like latency timing that must never be
  flagged as traced unless it enters via R1/R2).
"""
from __future__ import annotations

import ast
import dataclasses
import os
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.rules import (Finding, RULES, FileCtx, FuncInfo,
                                  LintContext, build_file_ctx,
                                  dotted_name, resolve_dotted)

# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LintConfig:
    # QF101: quantized data-path modules that must route contractions
    # through the blessed entry points
    # nn/conv.py is *scoped* (not blessed) since the Pallas/taps qconv
    # became the fxp8 default: its only remaining raw contractions are
    # the documented fp fallback + STE backward (see docs/kernels.md
    # "When to fall back to XLA"), each carrying an allowlist entry.
    qf101_scope: Tuple[str, ...] = (
        "src/repro/rl/", "src/repro/serve/", "src/repro/nn/linear.py",
        "src/repro/nn/conv.py",
    )
    qf101_blessed: Tuple[str, ...] = (
        "src/repro/core/qmatmul.py",
        "src/repro/core/vact.py", "src/repro/kernels/",
    )
    # QF501: modules implementing env wrappers
    qf501_scope: Tuple[str, ...] = (
        "src/repro/rl/envs/wrappers.py",
    )
    # QF601: driver CLIs exempt from the no-print rule — they are the
    # human-facing surface; everything else routes through repro.obs
    # (analysis/ is outside the lint universe already)
    qf601_exempt: Tuple[str, ...] = (
        "src/repro/launch/",
    )
    # library modules: naming conventions + attribute name-matching
    # may mark functions here as jit-reachable
    library: Tuple[str, ...] = (
        "src/repro/core/", "src/repro/nn/", "src/repro/rl/",
        "src/repro/kernels/", "src/repro/optim/",
        "src/repro/models/", "src/repro/distributed/",
        "src/repro/data/",
    )
    # rules to run (all by default)
    rules: Tuple[str, ...] = ()


TRANSFORMS = {
    "jax.jit", "jax.pmap", "jax.vmap", "jax.grad",
    "jax.value_and_grad", "jax.checkpoint", "jax.remat",
    "jax.custom_vjp", "jax.custom_jvp", "jax.eval_shape",
    "jax.make_jaxpr", "jax.linearize", "jax.jvp", "jax.vjp",
    "jax.experimental.shard_map.shard_map",
    "jax.lax.scan", "jax.lax.while_loop", "jax.lax.fori_loop",
    "jax.lax.cond", "jax.lax.switch", "jax.lax.map",
    "jax.lax.associative_scan", "jax.lax.custom_root",
    "jax.tree_util.Partial",
}
PARTIAL_NAMES = {"functools.partial", "partial"}
# attribute calls that take traced callbacks positionally
CALLBACK_ATTRS = {"defvjp", "defjvp"}
# attribute names too generic to name-match across modules
METHOD_DENYLIST = {
    "append", "extend", "get", "items", "keys", "values", "pop",
    "update", "setdefault", "copy", "add", "discard", "remove",
    "sort", "index", "count", "join", "split", "strip", "format",
    "startswith", "endswith", "lower", "upper", "replace", "encode",
    "decode", "read", "write", "close", "open", "flush", "mkdir",
    "exists", "tolist", "item", "block_until_ready", "astype",
    "reshape", "sum", "mean", "max", "min", "any", "all", "clip",
    "squeeze", "ravel", "flatten", "transpose", "at", "set",
    "dump", "dumps", "load", "loads", "render",
}
# R3 conventions: leaf names tracing enters through struct fields
CONVENTION_EXACT = {"step", "reset", "greedy", "sampled", "behave",
                    "init", "apply"}
CONVENTION_SUFFIX = ("_apply",)
CONVENTION_SUBSTR = ("loss",)


def _is_library(rel: str, cfg: LintConfig) -> bool:
    return any(rel == p or rel.startswith(p.rstrip("/") + "/")
               for p in cfg.library)


def _leaf(qualname: str) -> str:
    return qualname.split(".")[-1]


def _matches_convention(leaf: str) -> bool:
    if leaf in CONVENTION_EXACT:
        return True
    if any(leaf.endswith(s) for s in CONVENTION_SUFFIX):
        return True
    return any(s in leaf for s in CONVENTION_SUBSTR)


# ---------------------------------------------------------------------------
# file collection
# ---------------------------------------------------------------------------


def collect_files(root: str,
                  paths: Optional[List[str]] = None) -> List[FileCtx]:
    """Parse the lint universe.  ``paths`` (absolute or root-relative)
    overrides the default ``src/repro/**`` sweep — used by the fixture
    self-tests."""
    out: List[FileCtx] = []
    if paths is None:
        base = os.path.join(root, "src", "repro")
        paths = []
        for dirpath, dirnames, filenames in os.walk(base):
            dirnames.sort()
            # the checker does not lint itself
            if os.path.basename(dirpath) == "analysis" and \
                    os.path.dirname(dirpath) == base:
                dirnames[:] = []
                continue
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    paths.append(os.path.join(dirpath, fn))
    for p in paths:
        ap = p if os.path.isabs(p) else os.path.join(root, p)
        rel = os.path.relpath(ap, root).replace(os.sep, "/")
        module = _module_name(rel)
        with open(ap, "r", encoding="utf-8") as fh:
            src = fh.read()
        out.append(build_file_ctx(ap, rel, module, src))
    return out


def _module_name(rel: str) -> str:
    parts = rel.split("/")
    if parts[:1] == ["src"]:
        parts = parts[1:]
    if parts and parts[-1].endswith(".py"):
        parts[-1] = parts[-1][:-3]
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


# ---------------------------------------------------------------------------
# jit-reachability graph
# ---------------------------------------------------------------------------


class _Reach:
    def __init__(self, files: List[FileCtx], cfg: LintConfig):
        self.files = files
        self.cfg = cfg
        self.by_module: Dict[str, FileCtx] = {
            f.module: f for f in files}
        # leaf name -> [(file, qualname)] in library modules only
        self.lib_by_leaf: Dict[str, List[Tuple[FileCtx, str]]] = {}
        for f in files:
            if not _is_library(f.rel, cfg):
                continue
            for qn in f.functions:
                self.lib_by_leaf.setdefault(_leaf(qn), []).append(
                    (f, qn))
        # lambda node -> qualname per file
        self.node_qn: Dict[int, Tuple[FileCtx, str]] = {}
        for f in files:
            for qn, info in f.functions.items():
                self.node_qn[id(info.node)] = (f, qn)
        self.reachable: Set[Tuple[str, str]] = set()
        self.work: List[Tuple[FileCtx, str]] = []

    def mark(self, f: FileCtx, qn: str):
        key = (f.rel, qn)
        if key not in self.reachable and qn in f.functions:
            self.reachable.add(key)
            self.work.append((f, qn))

    # -- name resolution -------------------------------------------------
    def resolve_name(self, f: FileCtx, scope: Optional[FuncInfo],
                     name: str) -> Optional[Tuple[FileCtx, str]]:
        # lexical scope chain (nested defs)
        info = scope
        while info is not None:
            cand = f"{info.qualname}.<locals>.{name}"
            if cand in f.functions:
                return f, cand
            info = info.parent
        # module level (incl. methods of module-level classes is NOT
        # name-only reachable here; plain defs only)
        if name in f.functions:
            return f, name
        # imports: from repro.x import name / import repro.x as m
        target = f.imports.get(name)
        if target and target.startswith("repro."):
            mod, _, leaf = target.rpartition(".")
            other = self.by_module.get(mod)
            if other and leaf in other.functions:
                return other, leaf
            # "from repro.rl import rollout" style: target is a module
            other = self.by_module.get(target)
            if other:
                return None
        return None

    def resolve_attr(self, f: FileCtx, name: str) -> List[
            Tuple[FileCtx, str]]:
        """``x.foo`` / ``mod.foo`` call targets."""
        resolved = resolve_dotted(name, f.imports)
        if resolved.startswith("repro."):
            mod, _, leaf = resolved.rpartition(".")
            other = self.by_module.get(mod)
            if other and leaf in other.functions:
                return [(other, leaf)]
        leaf = name.rsplit(".", 1)[-1]
        if leaf in METHOD_DENYLIST:
            return []
        # struct-field dispatch (env.step, agent.behave, buf.sample):
        # name-match into library modules only
        return list(self.lib_by_leaf.get(leaf, []))

    # -- roots ------------------------------------------------------------
    def _decorator_is_transform(self, f: FileCtx,
                                dec: ast.AST) -> bool:
        if isinstance(dec, ast.Call):
            name = dotted_name(dec.func)
            if name is None:
                return False
            resolved = resolve_dotted(name, f.imports)
            if resolved in TRANSFORMS:
                return True
            if resolved in PARTIAL_NAMES and dec.args:
                inner = dotted_name(dec.args[0])
                return bool(inner) and resolve_dotted(
                    inner, f.imports) in TRANSFORMS
            return False
        name = dotted_name(dec)
        return bool(name) and resolve_dotted(
            name, f.imports) in TRANSFORMS

    def seed(self):
        for f in self.files:
            # R1: transform decorators
            for qn, info in f.functions.items():
                node = info.node
                if not isinstance(node, ast.Lambda):
                    for dec in node.decorator_list:
                        if self._decorator_is_transform(f, dec):
                            self.mark(f, qn)
                # R3: naming conventions in library modules
                if _is_library(f.rel, self.cfg) and \
                        _matches_convention(_leaf(qn)):
                    self.mark(f, qn)
            # R2: functions passed into transform calls, anywhere
            for node in ast.walk(f.tree):
                if not isinstance(node, ast.Call):
                    continue
                name = dotted_name(node.func)
                is_transform = False
                if name is not None:
                    resolved = resolve_dotted(name, f.imports)
                    is_transform = (
                        resolved in TRANSFORMS
                        or name.rsplit(".", 1)[-1] in CALLBACK_ATTRS
                        or (resolved in PARTIAL_NAMES and node.args
                            and (inner := dotted_name(node.args[0]))
                            is not None
                            and resolve_dotted(inner, f.imports)
                            in TRANSFORMS))
                if not is_transform:
                    continue
                scope = self._enclosing_scope(f, node)
                for arg in list(node.args) + [kw.value for kw in
                                              node.keywords]:
                    if isinstance(arg, ast.Lambda):
                        hit = self.node_qn.get(id(arg))
                        if hit:
                            self.mark(*hit)
                    elif isinstance(arg, ast.Name):
                        hit = self.resolve_name(f, scope, arg.id)
                        if hit:
                            self.mark(*hit)

    def _enclosing_scope(self, f: FileCtx,
                         node: ast.AST) -> Optional[FuncInfo]:
        # cheapest correct option: find the innermost FuncInfo whose
        # subtree contains the node
        best, best_depth = None, -1
        for qn, info in f.functions.items():
            depth = qn.count(".")
            if depth <= best_depth:
                continue
            for sub in ast.walk(info.node):
                if sub is node:
                    best, best_depth = info, depth
                    break
        return best

    # -- propagation -------------------------------------------------------
    def propagate(self):
        while self.work:
            f, qn = self.work.pop()
            info = f.functions[qn]
            for node in ast.walk(info.node):
                # nested defs have their own reachability entries;
                # tracing falls through into them only via calls
                if not isinstance(node, ast.Call):
                    continue
                name = dotted_name(node.func)
                if name is None:
                    continue
                if "." in name:
                    for hit in self.resolve_attr(f, name):
                        self.mark(*hit)
                else:
                    hit = self.resolve_name(f, info, name)
                    if hit:
                        self.mark(*hit)


def build_reachability(files: List[FileCtx],
                       cfg: LintConfig) -> Set[Tuple[str, str]]:
    r = _Reach(files, cfg)
    r.seed()
    r.propagate()
    return r.reachable


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------


def run_lint(root: str, paths: Optional[List[str]] = None,
             config: Optional[LintConfig] = None) -> List[Finding]:
    cfg = config or LintConfig()
    files = collect_files(root, paths)
    ctx = LintContext(root=root, files=files, config=cfg)
    ctx.reachable = build_reachability(files, cfg)
    findings: List[Finding] = []
    active = cfg.rules or tuple(sorted(RULES))
    for rule_id in active:
        findings.extend(RULES[rule_id].check(ctx))
    findings.sort(key=lambda fd: (fd.path, fd.line, fd.rule))
    return findings
