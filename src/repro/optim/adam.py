"""AdamW, functional (no optax in this container).

API mirrors the optax triple but stays a plain pytree of arrays so it
jits/shards/checkpoints like any other state:

    state = adamw_init(params)
    new_params, state, stats = adamw_update(
        grads, state, params, step, schedule, cfg)

Optimizer state is sharded like the parameters (first/second moments
inherit the param NamedSharding), which is what keeps 72B-scale
optimizer state partitioned over the mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.optim.clip import clip_by_global_norm, zero_nonfinite

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    max_grad_norm: Optional[float] = 1.0
    # moment dtype — fp32 master moments even under bf16 params
    m_dtype: object = jnp.float32


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(grads, state, params, schedule: Callable,
                 cfg: AdamWConfig = AdamWConfig()):
    """One AdamW step.  Returns (new_params, new_state, stats)."""
    grads, nonfinite = zero_nonfinite(grads)
    if cfg.max_grad_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.max_grad_norm)
    else:
        from repro.optim.clip import global_norm
        gnorm = global_norm(grads)

    count = state["count"] + 1
    lr = schedule(count)
    b1, b2 = cfg.b1, cfg.b2

    def upd(g, mu, nu, p):
        g32 = g.astype(jnp.float32)
        mu = b1 * mu + (1 - b1) * g32
        nu = b2 * nu + (1 - b2) * jnp.square(g32)
        mu_hat = mu / (1 - b1 ** count)
        nu_hat = nu / (1 - b2 ** count)
        step = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), mu, nu

    flat_g, tdef = jax.tree.flatten(grads)
    flat_mu = tdef.flatten_up_to(state["mu"])
    flat_nu = tdef.flatten_up_to(state["nu"])
    flat_p = tdef.flatten_up_to(params)
    out = [upd(g, m, n, p) for g, m, n, p in
           zip(flat_g, flat_mu, flat_nu, flat_p, strict=True)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_state = {
        "mu": tdef.unflatten([o[1] for o in out]),
        "nu": tdef.unflatten([o[2] for o in out]),
        "count": count,
    }
    stats = {"grad_norm": gnorm, "lr": lr,
             "nonfinite": nonfinite.astype(jnp.int32)}
    return new_params, new_state, stats


def optimizer_shardings(param_shardings):
    """Optimizer-state sharding tree matching ``adamw_init`` structure."""
    return {
        "mu": param_shardings,
        "nu": param_shardings,
        "count": None,   # replicated scalar; resolved by caller's mesh
    }
