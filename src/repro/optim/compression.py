"""Quantized gradient collectives with error feedback (beyond-paper #2).

The paper cuts learner->actor *weight sync* to int8 (Q-Actor).  We
generalize the same trick to the data-parallel gradient all-reduce: ship
int8 payloads + one fp scale per tensor, and keep a local error-feedback
buffer so the quantization bias does not accumulate (Seide et al. /
1-bit Adam semantics: e_{t+1} = g_t + e_t - deq(q_t)).

Two wire strategies, chosen by axis size:

* ``gather``  — all_gather the int8 shards and sum locally.  The wire
  payload is genuinely 8-bit.  Bytes/device ~ (n-1)/n * S vs 2*S*4 for
  an fp32 ring all-reduce, an ~8x cut for n=2 (the cross-pod DCN hop,
  where bandwidth is scarcest).
* ``psum``    — quantize, then arithmetic all-reduce in an int32
  container (no overflow up to 2^23 summands).  XLA has no sub-word
  accumulating all-reduce, so the container is 32-bit on the wire; this
  path exists to keep the math identical when ``gather`` would lose
  (n >= 8 on fast ICI).

Both are used inside ``shard_map`` bodies (see launch/train.py) where
gradients are per-device values and the collective is explicit.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.fxp import fxp_qmax

Array = jax.Array


def _axis_size(axis_name) -> int:
    return jax.lax.psum(1, axis_name)


def compressed_psum_mean(g: Array, axis_name, bits: int = 8,
                         error: Optional[Array] = None,
                         strategy: str = "gather"
                         ) -> Tuple[Array, Array]:
    """Mean of ``g`` over ``axis_name`` with ``bits``-wide payloads.

    Returns (mean_estimate fp32, new_error_buffer).  ``error`` is the
    per-device error-feedback buffer (same shape as g); pass zeros on
    step 0.  bits == 32 short-circuits to an exact psum.
    """
    n = _axis_size(axis_name)
    g32 = g.astype(jnp.float32)
    if bits >= 32:
        mean = jax.lax.psum(g32, axis_name) / n
        return mean, (error if error is not None
                      else jnp.zeros_like(g32))

    if error is None:
        error = jnp.zeros_like(g32)
    corr = g32 + error

    # shared scale so payloads are summable: pmax of the local absmax
    qmax = fxp_qmax(bits)
    amax = jax.lax.pmax(jnp.max(jnp.abs(corr)), axis_name)
    scale = jnp.maximum(amax, 1e-12) / qmax
    q = jnp.clip(jnp.round(corr / scale), -qmax, qmax)

    if strategy == "gather":
        payload = q.astype(jnp.int8 if bits <= 8 else jnp.int16)
        allq = jax.lax.all_gather(payload, axis_name)     # [n, ...] int8
        total = jnp.sum(allq.astype(jnp.float32), axis=0)
    else:  # "psum"
        total = jax.lax.psum(q.astype(jnp.int32), axis_name) \
                   .astype(jnp.float32)

    mean = total * scale / n
    new_error = corr - q * scale          # local residual
    return mean.astype(jnp.float32), new_error


def compression_ratio(bits: int, n: int, strategy: str = "gather") -> float:
    """Wire-bytes ratio vs an fp32 ring all-reduce (analytic, for the
    roofline collective term)."""
    full = 2 * 4.0 * (n - 1) / n            # reduce-scatter + all-gather
    if bits >= 32:
        return 1.0
    if strategy == "gather":
        comp = (bits / 8.0) * (n - 1)       # all-gather of full payload
    else:
        comp = 2 * 4.0 * (n - 1) / n        # int32 container: no win
    return comp / full
