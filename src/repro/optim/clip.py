"""Gradient clipping / finiteness guards."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def global_norm(tree) -> Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree, max_norm: float) -> Tuple:
    """Returns (clipped_tree, pre_clip_norm)."""
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), tree), norm


def zero_nonfinite(tree):
    """Replace non-finite grads with 0 (skip-step semantics per-leaf);
    returns (tree, any_nonfinite flag) so the loop can count skips."""
    flags = [jnp.all(jnp.isfinite(l)) for l in jax.tree.leaves(tree)]
    ok = jnp.stack(flags).all() if flags else jnp.asarray(True)
    cleaned = jax.tree.map(
        lambda g: jnp.where(jnp.isfinite(g), g, 0.0).astype(g.dtype), tree)
    return cleaned, ~ok
