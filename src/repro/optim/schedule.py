"""Learning-rate schedules (scalar jnp functions of the step counter)."""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

Schedule = Callable


def constant(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def linear_warmup(lr: float, warmup_steps: int) -> Schedule:
    def f(step):
        frac = jnp.minimum(step / jnp.maximum(warmup_steps, 1), 1.0)
        return jnp.asarray(lr, jnp.float32) * frac
    return f


def warmup_cosine(lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1) -> Schedule:
    """Linear warmup then cosine decay to ``final_frac * lr``."""
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = step / jnp.maximum(warmup_steps, 1)
        prog = (step - warmup_steps) / jnp.maximum(
            total_steps - warmup_steps, 1)
        prog = jnp.clip(prog, 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (
            1 + jnp.cos(jnp.pi * prog))
        return lr * jnp.where(step < warmup_steps, warm, cos)
    return f


def inverse_sqrt(lr: float, warmup_steps: int) -> Schedule:
    def f(step):
        step = jnp.maximum(jnp.asarray(step, jnp.float32), 1.0)
        warm = jnp.minimum(step / jnp.maximum(warmup_steps, 1), 1.0)
        return lr * warm * jnp.sqrt(
            jnp.maximum(warmup_steps, 1) / jnp.maximum(step, warmup_steps))
    return f
