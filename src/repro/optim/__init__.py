from repro.optim.adam import (AdamWConfig, adamw_init, adamw_update,
                              optimizer_shardings)
from repro.optim.clip import clip_by_global_norm, global_norm, zero_nonfinite
from repro.optim.compression import compressed_psum_mean, compression_ratio
from repro.optim.schedule import (constant, inverse_sqrt, linear_warmup,
                                  warmup_cosine)
