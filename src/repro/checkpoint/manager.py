"""CheckpointManager: retention, auto-resume, and restart semantics.

Directory layout:  <dir>/step_<N>.npz(.json)  + <dir>/LATEST (atomic
pointer).  ``latest_step`` never trusts LATEST blindly — it falls back
to scanning so a crash between the npz rename and the pointer update
still resumes correctly (the fault window is closed from both sides).
"""
from __future__ import annotations

import glob
import os
import re
from typing import Any, Dict, Optional, Tuple

from repro.checkpoint import checkpointer

_STEP_RE = re.compile(r"step_(\d+)\.npz$")


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 save_every: int = 100):
        self.dir = directory
        self.keep = keep
        self.save_every = save_every
        os.makedirs(directory, exist_ok=True)

    # -- paths ------------------------------------------------------------
    def path_for(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step}.npz")

    def all_steps(self):
        steps = []
        for p in glob.glob(os.path.join(self.dir, "step_*.npz")):
            m = _STEP_RE.search(p)
            if m:
                steps.append(int(m.group(1)))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # -- save/restore -----------------------------------------------------
    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.save_every == 0

    def save(self, step: int, tree: Any,
             metadata: Optional[Dict] = None) -> str:
        path = self.path_for(step)
        md = dict(metadata or {})
        md["step"] = step
        checkpointer.save(path, tree, md)
        # atomic LATEST pointer
        tmp = os.path.join(self.dir, ".latest.tmp")
        with open(tmp, "w") as f:
            f.write(str(step))
        os.replace(tmp, os.path.join(self.dir, "LATEST"))
        self._gc()
        return path

    def restore(self, like: Any, shardings: Any = None,
                step: Optional[int] = None) -> Tuple[Any, Dict]:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        return checkpointer.restore(self.path_for(step), like, shardings)

    def metadata(self, step: Optional[int] = None) -> Dict:
        """The sidecar metadata alone — no array restore, no template.

        Lets a launcher validate run flags (algo, replay backend, net
        shapes) BEFORE building a restore template: a flag mismatch
        then fails with the launcher's own error instead of an opaque
        missing-leaf KeyError from the tree restore.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        return checkpointer.read_metadata(self.path_for(step))

    def restore_or_init(self, init_fn, shardings: Any = None):
        """Auto-resume: restore latest if present, else init fresh.

        Returns (tree, start_step).  This is the restart entry point the
        launchers use — a preempted/failed job relaunches with the same
        command line and continues.
        """
        step = self.latest_step()
        if step is None:
            return init_fn(), 0
        like = init_fn()
        tree, md = self.restore(like, shardings, step)
        return tree, int(md.get("step", step))

    # -- retention ----------------------------------------------------
    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            for suffix in (".npz", ".npz.json"):
                p = os.path.join(self.dir, f"step_{s}{suffix}")
                if os.path.exists(p):
                    os.unlink(p)
