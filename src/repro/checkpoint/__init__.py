from repro.checkpoint.checkpointer import restore, save
from repro.checkpoint.manager import CheckpointManager
