"""Device-count-independent checkpointing (no orbax in this container).

Format: one ``.npz`` holding every leaf (flattened pytree paths as keys)
plus a JSON sidecar with the treedef, dtypes, and user metadata.  Writes
are atomic (tmp file + os.replace) so a killed process never leaves a
torn checkpoint — the fault-tolerance primitive everything else builds
on.  Leaves are gathered to host before writing, so the file does not
depend on the mesh shape; ``restore`` re-shards onto whatever mesh the
restoring job runs (elastic restart across different device counts).

QTensor leaves round-trip (payload + scale + bits are stored separately).
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fxp import QTensor


def _is_qtensor(x) -> bool:
    return isinstance(x, QTensor)


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=_is_qtensor)
    return flat, treedef


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def save(path: str, tree: Any, metadata: Optional[Dict] = None) -> None:
    """Atomically write ``tree`` to ``path`` (.npz + .json sidecar)."""
    flat, _ = _flatten_with_paths(tree)
    arrays: Dict[str, np.ndarray] = {}
    leaf_meta: Dict[str, Dict] = {}
    for p, leaf in flat:
        key = _path_str(p)
        if _is_qtensor(leaf):
            arrays[key + "#q"] = np.asarray(leaf.qvalue)
            arrays[key + "#s"] = np.asarray(leaf.scale)
            leaf_meta[key] = {"kind": "qtensor", "bits": int(leaf.bits)}
        else:
            arr = np.asarray(leaf)
            dtype = str(arr.dtype)
            if arr.dtype.kind == "V" or dtype in ("bfloat16", "float8_e4m3fn",
                                                  "float8_e5m2"):
                # ml_dtypes aren't npz-native: store the raw bytes view
                arr = arr.view(np.dtype(f"uint{arr.dtype.itemsize * 8}"))
            arrays[key] = arr
            leaf_meta[key] = {"kind": "array", "dtype": dtype}

    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    dirname = os.path.dirname(path) or "."
    fd, tmp = tempfile.mkstemp(dir=dirname, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **arrays)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise

    side = {"leaves": leaf_meta, "metadata": metadata or {}}
    fd, tmp = tempfile.mkstemp(dir=dirname, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(side, f)
        os.replace(tmp, path + ".json")
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def read_metadata(path: str) -> Dict:
    """The sidecar metadata for the checkpoint at ``path`` — no array
    IO, no restore template.  The sidecar format (``path + ".json"``,
    ``{"leaves": ..., "metadata": ...}``) is owned here, next to the
    save/restore that write and read it."""
    with open(path + ".json") as f:
        return json.load(f)["metadata"]


def restore(path: str, like: Any,
            shardings: Any = None) -> Tuple[Any, Dict]:
    """Restore into the structure of ``like``.

    ``shardings`` (optional) is a matching tree of NamedShardings — when
    given, leaves are placed directly onto the (possibly different) mesh
    with ``jax.device_put``, which is what makes restarts elastic.
    Returns (tree, metadata).
    """
    with np.load(path) as zf:
        data = {k: zf[k] for k in zf.files}
    with open(path + ".json") as f:
        side = json.load(f)

    flat, treedef = _flatten_with_paths(like)
    if shardings is not None:
        sflat, _ = _flatten_with_paths(shardings)
        sleaves = [l for _, l in sflat]
    else:
        sleaves = [None] * len(flat)

    leaves = []
    for (p, _leaf), shard in zip(flat, sleaves, strict=True):
        key = _path_str(p)
        meta = side["leaves"].get(key)
        if meta is None:
            raise KeyError(f"checkpoint missing leaf '{key}'")
        if meta["kind"] == "qtensor":
            q, s = data[key + "#q"], data[key + "#s"]
            if shard is not None and isinstance(shard, QTensor):
                q = jax.device_put(q, shard.qvalue)
                s = jax.device_put(s, shard.scale)
            leaves.append(QTensor(jnp.asarray(q), jnp.asarray(s),
                                  meta["bits"]))
        else:
            v = data[key]
            want = np.dtype(meta["dtype"])      # ml_dtypes registers names
            if v.dtype != want:
                v = v.view(want)
            if shard is not None:
                v = jax.device_put(v, shard)
            leaves.append(jnp.asarray(v))
    return jax.tree_util.tree_unflatten(treedef, leaves), side["metadata"]
