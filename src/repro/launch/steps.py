"""Step builders shared by the dry-run, the launchers and the roofline.

Everything here is *abstract-first*: ``abstract_params`` /
``abstract_caches`` build ShapeDtypeStruct trees via eval_shape (no
allocation), and the matching NamedSharding trees come from the
logical-axis rules — the dry-run contract.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.configs.shapes import ShapeConfig
from repro.core.policy import QuantPolicy
from repro.distributed.sharding import (batch_spec, data_axes,
                                        make_shardings, mesh_rules)
from repro.models.registry import input_specs, model_for, sharding_rules
from repro.nn.module import axes_of, unbox
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         warmup_cosine)

Array = jax.Array


# ---------------------------------------------------------------------------
# abstract state + shardings
# ---------------------------------------------------------------------------

def abstract_params(cfg: ArchConfig, mesh: Mesh,
                    dtype=jnp.float32,
                    weight_ptq: Optional[QuantPolicy] = None,
                    serve: bool = False) -> Tuple[Any, Any]:
    """(ShapeDtypeStruct param tree, NamedSharding tree).

    ``weight_ptq``: serve-path semantics — weights stored as int8
    QTensors (payload + scales), exactly what a deployed engine loads.
    """
    model = model_for(cfg)
    boxed = jax.eval_shape(
        functools.partial(model.init, cfg=cfg, dtype=dtype),
        jax.random.PRNGKey(0))
    axes = axes_of(boxed)
    if weight_ptq is not None and weight_ptq.quantized_w:
        from repro.core.quantizer import quantize_params
        params = jax.eval_shape(
            lambda t: quantize_params(t, weight_ptq), unbox(boxed))
    else:
        params = unbox(boxed)
    rules = sharding_rules(cfg, mesh.shape.get("model", 1),
                           serve=serve)
    shardings = make_shardings(params, axes, mesh, rules)
    return params, shardings


def abstract_opt_state(abs_params, param_shardings, mesh: Mesh):
    opt = jax.eval_shape(adamw_init, abs_params)
    shard = {
        "mu": param_shardings,
        "nu": param_shardings,
        "count": NamedSharding(mesh, P()),
    }
    return opt, shard


def abstract_caches(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                    kv_bits: int = 32, dtype=jnp.float32):
    """(ShapeDtypeStruct cache tree, NamedSharding tree) for decode."""
    model = model_for(cfg)
    caches = jax.eval_shape(
        lambda: model.init_caches(cfg, shape.global_batch, shape.seq_len,
                                  kv_bits, dtype))
    shardings = cache_shardings(caches, cfg, shape.global_batch, mesh)
    return caches, shardings


def cache_shardings(caches, cfg: ArchConfig, batch: int, mesh: Mesh):
    """Sharding rules for serving state, by leaf name:

      k/v[_scale]  [.., B, cap, n_kv, hd]  batch->data, kv->model if div
      pos          [.., B, cap]            batch->data
      ssm          [.., B, H, hd, N]       batch->data, heads->model
      conv         [.., B, w, C]           batch->data, C->model if div
      rglru        [.., B, W]              batch->data, W->model if div
    """
    model_n = mesh.shape.get("model", 1)
    dax = data_axes(mesh)
    n_data = 1
    for a in (dax or ()):
        n_data *= mesh.shape[a]
    # global_batch=1 (long_500k) cannot shard the batch dim
    dax = dax if (dax and batch % n_data == 0) else None

    def spec(path, leaf):
        name = str(getattr(path[-1], "key", path[-1]))
        nd = leaf.ndim
        ax: list = [None] * nd
        if name in ("k", "v", "k_scale", "v_scale"):
            ax[nd - 4] = dax
            if cfg.n_kv_heads and cfg.n_kv_heads % model_n == 0:
                ax[nd - 2] = "model"
            elif leaf.shape[nd - 3] % model_n == 0:
                # kv_heads don't divide (GQA kv=8 vs TP=16, whisper
                # kv=20): shard the SEQUENCE dim — flash-decoding
                # layout.  Each device scores its slice of the context;
                # the softmax/output reductions over the sharded dim
                # lower to tiny stat-sized collectives instead of
                # gathering the KV cache itself (which costs ~GBs/layer)
                ax[nd - 3] = "model"
        elif name == "pos":
            ax[nd - 2] = dax
            if leaf.shape[nd - 1] % model_n == 0 and \
                    not (cfg.n_kv_heads and
                         cfg.n_kv_heads % model_n == 0):
                ax[nd - 1] = "model"
        elif name == "ssm":
            ax[nd - 4] = dax
            if leaf.shape[nd - 3] % model_n == 0:
                ax[nd - 3] = "model"
        elif name == "conv":
            ax[nd - 3] = dax
            if leaf.shape[nd - 1] % model_n == 0:
                ax[nd - 1] = "model"
        elif name == "rglru":
            ax[nd - 2] = dax
            if leaf.shape[nd - 1] % model_n == 0:
                ax[nd - 1] = "model"
        return NamedSharding(mesh, P(*ax))

    return jax.tree_util.tree_map_with_path(spec, caches)


def batch_shardings(specs: Dict, mesh: Mesh):
    return {k: NamedSharding(mesh, batch_spec(mesh, v.ndim - 1,
                                              batch_size=v.shape[0]))
            for k, v in specs.items()}


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------

def make_train_step(cfg: ArchConfig, mesh: Optional[Mesh],
                    policy: Optional[QuantPolicy],
                    ocfg: AdamWConfig = AdamWConfig(),
                    schedule: Optional[Callable] = None) -> Callable:
    model = model_for(cfg)
    rules = sharding_rules(cfg, mesh.shape.get("model", 1)) if mesh \
        else {}
    sched = schedule or warmup_cosine(3e-4, 100, 10_000)

    def _compute_cast(params):
        """fp32 masters -> bf16 compute copies, ONCE per step and
        outside the layer scan: FSDP weight all-gathers and the dw
        partial-sum reductions then move bf16, not f32 (2x collective
        bytes).  Cotangents convert back to f32 at this boundary."""
        if policy is None or policy.compute_dtype != jnp.bfloat16:
            return params
        return jax.tree.map(
            lambda p: p.astype(jnp.bfloat16)
            if (hasattr(p, "dtype") and p.dtype == jnp.float32
                and p.ndim >= 2) else p, params)

    def train_step(params, opt_state, batch):
        with mesh_rules(mesh, rules):
            k = max(cfg.microbatches, 1)
            if k > 1:
                from repro.distributed.sharding import constrain

                def split(x):
                    assert x.shape[0] % k == 0, (x.shape, k)
                    return x.reshape((k, x.shape[0] // k) + x.shape[1:])

                mb = jax.tree.map(split, batch)
                mb = jax.tree.map(
                    lambda x: constrain(
                        x, (None, "batch") + (None,) * (x.ndim - 2)),
                    mb)

                def acc(carry, b):
                    l_acc, g_acc = carry
                    l, g = jax.value_and_grad(
                        lambda p: model.loss_fn(_compute_cast(p), b,
                                                cfg, policy))(params)
                    g = jax.tree.map(
                        lambda a, gi: a + gi.astype(jnp.float32),
                        g_acc, g)
                    return (l_acc + l, g), None

                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (loss, grads), _ = jax.lax.scan(
                    acc, (jnp.zeros(()), zeros), mb)
                loss = loss / k
                grads = jax.tree.map(lambda g: g / k, grads)
            else:
                loss, grads = jax.value_and_grad(
                    lambda p: model.loss_fn(_compute_cast(p), batch,
                                            cfg, policy))(params)
        params, opt_state, stats = adamw_update(grads, opt_state,
                                                params, sched, ocfg)
        return params, opt_state, dict(loss=loss, **stats)

    return train_step


def make_prefill_step(cfg: ArchConfig, mesh: Optional[Mesh],
                      policy: Optional[QuantPolicy],
                      kv_bits: int = 32) -> Callable:
    model = model_for(cfg)
    rules = sharding_rules(cfg, mesh.shape.get("model", 1)) if mesh \
        else {}

    def prefill_step(params, batch):
        with mesh_rules(mesh, rules):
            if cfg.is_encdec:
                return model.prefill(params, batch, cfg, policy,
                                     kv_bits)
            return model.prefill(params, batch["tokens"], cfg, policy,
                                 kv_bits)

    return prefill_step


def make_decode_step(cfg: ArchConfig, mesh: Optional[Mesh],
                     policy: Optional[QuantPolicy],
                     kv_bits: int = 32) -> Callable:
    model = model_for(cfg)
    rules = sharding_rules(cfg, mesh.shape.get("model", 1),
                           serve=True) if mesh else {}

    def decode_step(params, caches, token, index):
        with mesh_rules(mesh, rules):
            logits, caches = model.decode_step(params, token, caches,
                                               index, cfg, policy,
                                               kv_bits)
        return logits, caches

    return decode_step


# ---------------------------------------------------------------------------
# lowering helper: one (arch x shape x mesh) cell -> jax.stages.Lowered
# ---------------------------------------------------------------------------

def lower_cell(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
               policy: Optional[QuantPolicy] = None,
               dtype=jnp.float32, donate: bool = True):
    """Build and lower the step this cell specifies; returns (lowered,
    meta dict).  No device allocation happens here."""
    specs = input_specs(cfg, shape)
    in_batch_shard = batch_shardings(specs, mesh)
    # serve steps load PTQ'd int8 weights (QTensor payload + scales);
    # train keeps fp32 masters
    serve = shape.kind != "train"
    ptq = policy if (serve and policy
                     and policy.quantized_w) else None
    # pure-TP weights only for latency-bound decode; prefill keeps the
    # FSDP layout (weight gathers amortize over the full sequence)
    abs_params, p_shard = abstract_params(
        cfg, mesh, dtype, weight_ptq=ptq,
        serve=(shape.kind == "decode"))
    kv_bits = policy.kv_bits if policy else 32

    if shape.kind == "train":
        # <=8k seq: direct (unchunked) attention — the chunk-map's
        # saved q-stack interacts badly with SP sharding in backward
        # (measured: chunking costs +28% collective bytes); the
        # [B,H,S,S] score transient fits under microbatching here
        if shape.seq_len <= 8192 and cfg.microbatches >= 2:
            cfg = cfg.replace(q_chunk=None)
        step = make_train_step(cfg, mesh, policy)
        abs_opt, o_shard = abstract_opt_state(abs_params, p_shard, mesh)
        jitted = jax.jit(
            step,
            in_shardings=(p_shard, o_shard, in_batch_shard),
            donate_argnums=(0, 1) if donate else ())
        lowered = jitted.lower(abs_params, abs_opt, specs)
        meta = {"step": "train_step", "inputs": specs}
    elif shape.kind == "prefill":
        step = make_prefill_step(cfg, mesh, policy, kv_bits)
        jitted = jax.jit(step, in_shardings=(p_shard, in_batch_shard))
        lowered = jitted.lower(abs_params, specs)
        meta = {"step": "prefill_step", "inputs": specs}
    else:  # decode
        step = make_decode_step(cfg, mesh, policy, kv_bits)
        abs_caches, c_shard = abstract_caches(cfg, shape, mesh, kv_bits,
                                              dtype)
        idx = jax.ShapeDtypeStruct((), jnp.int32)
        jitted = jax.jit(
            step,
            in_shardings=(p_shard, c_shard,
                          in_batch_shard["token"],
                          NamedSharding(mesh, P())),
            donate_argnums=(1,) if donate else ())
        lowered = jitted.lower(abs_params, abs_caches, specs["token"],
                               idx)
        meta = {"step": "serve_step", "inputs": specs}
    return lowered, meta
