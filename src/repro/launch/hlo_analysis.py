"""HLO cost model: flops / bytes / collective traffic with while-loop
trip-count scaling.

Why not ``compiled.cost_analysis()``: XLA's aggregate counts each
``while`` body ONCE, so any scan-over-layers model (all of ours) is
undercounted by ~n_layers x.  We parse the optimized HLO text into a
computation graph and walk it recursively, multiplying loop bodies by
their trip counts (recovered from the loop-condition constants).

Counted:
  flops            dot/convolution FLOPs with fp operands (2*out*K)
  int_ops          same for integer dots (the int8 MXU path, 2x peak)
  bytes            operand+output bytes of fusions/dots/copies/DUS
                   (XLA's own bytes-accessed convention)
  collectives      bytes by kind, all-reduce counted 2x (ring RS+AG)

SECURITY note: this is a text parser for compiler output we generate
ourselves; it is a measurement tool, not a validator.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}
_INT_TYPES = {"s4", "u4", "s8", "u8", "s16", "u16", "s32", "u32"}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

# op line inside a computation:  %name = <shape> opcode(...) , attrs
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\(.*?\)|[\w\[\],{}\/*\s]+?))"
    r"\s*([\w\-]+)\((.*)$")
_PARAM_DECL_RE = re.compile(r"([\w.\-]+):\s*(\([^=]*?\)|[\w\[\],{}]+)")


def _shape_elems_bytes(shape_str: str) -> Tuple[float, float]:
    """(total elements, total bytes) over all leaf shapes in the str."""
    elems = 0.0
    total = 0.0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        total += n * _DTYPE_BYTES[dt]
    return elems, total


def _leaf_dims(shape_str: str) -> List[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _leaf_dtype(shape_str: str) -> Optional[str]:
    m = _SHAPE_RE.search(shape_str)
    return m.group(1) if m else None


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    out_shape: str
    rest: str            # operand list + attributes (raw tail)
    operands: List[str]  # %-refs


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    shapes: Dict[str, str]          # symbol -> shape string
    params: List[str] = dataclasses.field(default_factory=list)


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            ls = line.strip()
            # computation header: "%name (params) -> type {"
            if ls.endswith("{") and "->" in ls and "(" in ls:
                name = ls.split("(", 1)[0].strip()
                name = name.replace("ENTRY", "").strip().lstrip("%")
                if not name:
                    continue
                cur = Computation(name, [], {})
                hdr = ls[ls.find("(") + 1: ls.rfind("->")]
                for pm in _PARAM_DECL_RE.finditer(hdr):
                    cur.shapes[pm.group(1)] = pm.group(2)
                    cur.params.append(pm.group(1))
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, shape, opcode, rest = m.groups()
        operands = re.findall(r"%([\w.\-]+)", rest.split(
            ", metadata=")[0].split(", calls=")[0].split(
            ", condition=")[0].split(", body=")[0].split(
            ", to_apply=")[0])
        op = Op(name, opcode, shape.strip(), rest, operands)
        cur.shapes[name] = op.out_shape
        cur.ops.append(op)
    return comps


def _trip_count(cond: Computation) -> int:
    """Loop bound from the condition computation: the largest integer
    constant that is compared against (scan bounds are exact)."""
    best = 1
    for op in cond.ops:
        if op.opcode == "constant":
            m = re.search(r"constant\((\d+)\)", "constant(" + op.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "after-all", "partition-id", "replica-id",
               "reshape", "broadcast", "iota", "transpose",
               # control flow: cost comes from the bodies, not the op
               "while", "conditional", "call"}


def _dot_flops(op: Op, comp: Computation) -> Tuple[float, bool]:
    """(flops, is_integer) for a dot; 2 * prod(out) * K."""
    out_elems, _ = _shape_elems_bytes(op.out_shape)
    k = 1.0
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    lhs_ref = op.operands[0] if op.operands else None
    lhs_shape = comp.shapes.get(lhs_ref, "") if lhs_ref else ""
    dims = _leaf_dims(lhs_shape)
    if m and dims:
        for d in m.group(1).split(","):
            if d and int(d) < len(dims):
                k *= dims[int(d)]
    is_int = _leaf_dtype(op.out_shape) in _INT_TYPES
    return 2.0 * out_elems * k, is_int


def _conv_flops(op: Op, comp: Computation) -> float:
    out_elems, _ = _shape_elems_bytes(op.out_shape)
    # kernel elements from rhs operand shape (excluding out-features)
    if len(op.operands) > 1:
        kdims = _leaf_dims(comp.shapes.get(op.operands[1], ""))
        if kdims:
            import math
            return 2.0 * out_elems * (math.prod(kdims[:-1]))
    return 0.0


class CostModel:
    def __init__(self, text: str):
        self.comps = parse_module(text)
        self.entry = None
        for name in self.comps:
            if ".entry" in name or name.startswith("main") \
                    or "ENTRY" in name:
                self.entry = name
        # jax entry computation is usually 'main.N'
        if self.entry is None:
            # fall back: the computation that nobody calls
            called = set()
            for c in self.comps.values():
                for op in c.ops:
                    for attr in ("calls=", "body=", "condition=",
                                 "to_apply="):
                        for m in re.finditer(
                                attr + r"%([\w.\-]+)", op.rest):
                            called.add(m.group(1))
            for name in self.comps:
                if name not in called:
                    self.entry = name
        self._memo: Dict[str, Dict[str, float]] = {}

    def _called(self, op: Op, attr: str) -> Optional[str]:
        m = re.search(attr + r"%([\w.\-]+)", op.rest)
        return m.group(1) if m else None

    def _op_bytes(self, op: Op, comp: Computation) -> float:
        """Bytes accessed by one op, XLA-convention: slicing ops touch
        the slice, not the base buffer.

        For fusions, each operand is charged the bytes its *uses inside
        the fused computation* actually touch: a parameter consumed only
        by dynamic-slice / dynamic-update-slice (the scan-stacked
        weights/activations pattern) costs the slice size, not the full
        [L, ...] stack — otherwise an 80-layer scan would be charged
        80x its true traffic.
        """
        _, ob = _shape_elems_bytes(op.out_shape)
        oc = op.opcode
        if oc == "dynamic-slice" or oc == "gather":
            return 2.0 * ob
        if oc == "dynamic-update-slice":
            ub = _shape_elems_bytes(
                comp.shapes.get(op.operands[1], ""))[1] \
                if len(op.operands) > 1 else 0.0
            return 2.0 * ub + ob * 0.0      # base is aliased in place
        ib = 0.0
        callee = self.comps.get(self._called(op, "calls=") or "") \
            if oc == "fusion" else None
        if callee is not None and callee.ops \
                and callee.ops[-1].opcode == "dynamic-update-slice":
            # fusion whose root is a DUS into a big (aliased) buffer:
            # the write is update-sized, not buffer-sized
            root = callee.ops[-1]
            ob = _shape_elems_bytes(
                callee.shapes.get(root.operands[1], ""))[1] \
                if len(root.operands) > 1 else ob
        for i, ref in enumerate(op.operands):
            s = comp.shapes.get(ref)
            if not s:
                continue
            full = _shape_elems_bytes(s)[1]
            if callee is not None and i < len(callee.params):
                pname = callee.params[i]
                uses = [o for o in callee.ops
                        if pname in o.operands]
                if uses and all(o.opcode in ("dynamic-slice",
                                             "dynamic-update-slice")
                                for o in uses):
                    touched = 0.0
                    for o in uses:
                        if o.opcode == "dynamic-slice":
                            touched += _shape_elems_bytes(
                                o.out_shape)[1]
                        else:
                            touched += _shape_elems_bytes(
                                callee.shapes.get(o.operands[1], "")
                            )[1] if len(o.operands) > 1 else 0.0
                    full = min(full, touched)
            ib += full
        return ib + ob

    def cost_of(self, comp_name: str) -> Dict[str, float]:
        if comp_name in self._memo:
            return self._memo[comp_name]
        comp = self.comps.get(comp_name)
        zero = {"flops": 0.0, "int_ops": 0.0, "bytes": 0.0,
                **{k: 0.0 for k in COLLECTIVE_OPS}}
        if comp is None:
            return zero
        total = dict(zero)
        self._memo[comp_name] = total       # break cycles
        for op in comp.ops:
            oc = op.opcode
            if oc == "while":
                body = self._called(op, "body=")
                cond = self._called(op, "condition=")
                trips = _trip_count(self.comps[cond]) \
                    if cond in self.comps else 1
                sub = self.cost_of(body) if body else zero
                csub = self.cost_of(cond) if cond else zero
                for k in total:
                    total[k] += trips * (sub[k] + csub[k])
                continue
            if oc in ("fusion", "call", "custom-call", "map",
                      "reduce", "reduce-window", "sort", "scatter",
                      "select-and-scatter"):
                callee = self._called(op, "calls=") or \
                    self._called(op, "to_apply=")
                if callee:
                    sub = self.cost_of(callee)
                    for k in total:
                        # a fusion's interior never materializes: its
                        # traffic is the op's own boundary bytes below
                        if k == "bytes" and oc == "fusion":
                            continue
                        total[k] += sub[k]
            if oc == "conditional":
                # count the most expensive branch
                branches = re.findall(r"%([\w.\-]+)", op.rest)
                best = zero
                for b in branches:
                    if b in self.comps:
                        c = self.cost_of(b)
                        if c["flops"] + c["bytes"] > \
                                best["flops"] + best["bytes"]:
                            best = c
                for k in total:
                    total[k] += best[k]
                continue

            base = oc.replace("-start", "")
            if base in COLLECTIVE_OPS and not oc.endswith("-done"):
                _, b = _shape_elems_bytes(op.out_shape)
                if base == "all-reduce":
                    b *= 2.0        # ring: reduce-scatter + all-gather
                if base == "all-gather":
                    pass            # output-sized traffic
                total[base] += b
                total["bytes"] += 0.0
                continue

            if oc == "dot":
                f, is_int = _dot_flops(op, comp)
                total["int_ops" if is_int else "flops"] += f
            elif oc == "convolution":
                total["flops"] += _conv_flops(op, comp)

            if oc not in _SKIP_BYTES:
                total["bytes"] += self._op_bytes(op, comp)
        self._memo[comp_name] = total
        return total

    def totals(self) -> Dict[str, float]:
        t = self.cost_of(self.entry) if self.entry else {}
        t = dict(t)
        t["collective_bytes"] = sum(t.get(k, 0.0)
                                    for k in COLLECTIVE_OPS)
        return t


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Trip-count-aware collective traffic by kind."""
    cm = CostModel(hlo_text)
    t = cm.totals()
    out = {k: t.get(k, 0.0) for k in COLLECTIVE_OPS}
    out["total"] = t.get("collective_bytes", 0.0)
    return out


def op_histogram(hlo_text: str, ops=("fusion", "all-gather", "all-reduce",
                                     "reduce-scatter", "all-to-all",
                                     "collective-permute", "custom-call",
                                     "while", "dot", "convolution",
                                     "dynamic-update-slice")) -> Dict[str, int]:
    hist = {}
    for op in ops:
        hist[op] = len(re.findall(rf"= [^=]*\b{re.escape(op)}\(",
                                  hlo_text))
    return hist


def cost_terms(compiled, hlo_text: Optional[str] = None) -> Dict[str, float]:
    """Trip-count-corrected {flops, int_ops, bytes, collective_bytes}
    from a compiled executable, with XLA's own (uncorrected) aggregate
    kept for reference."""
    text = hlo_text if hlo_text is not None else compiled.as_text()
    cm = CostModel(text)
    t = cm.totals()
    xla = compiled.cost_analysis()
    if isinstance(xla, list):
        xla = xla[0]
    return {
        "flops": t.get("flops", 0.0),
        "int_ops": t.get("int_ops", 0.0),
        "bytes": t.get("bytes", 0.0),
        "collective_bytes": t.get("collective_bytes", 0.0),
        "collectives": {k: t.get(k, 0.0) for k in COLLECTIVE_OPS},
        "xla_flops_1trip": float(xla.get("flops", 0.0)),
        "xla_bytes_1trip": float(xla.get("bytes accessed", 0.0)),
    }


def memory_stats(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes")
    out = {}
    for k in keys:
        out[k] = float(getattr(ma, k, 0.0))
    out["total_bytes"] = (out["argument_size_in_bytes"]
                          + out["output_size_in_bytes"]
                          + out["temp_size_in_bytes"]
                          - out["alias_size_in_bytes"])
    return out
