"""Batched RL policy serving driver.

    PYTHONPATH=src python -m repro.launch.serve_policy \
        --ckpt /tmp/dqn_run --policy w8 --episodes 200 \
        --slots 64 --batch-bucket 32 --check-parity

Loads a value-RL checkpoint (``rl_train --algo dqn|qrdqn|ddpg`` with
``--ckpt-dir``), packs the behaviour net to int8/int4 ``QTensor``s,
and serves a bank of concurrent episode slots through the
micro-batching engine — reporting actions/s, p50/p99 per-request
latency, mean episode return and the packed model footprint.
``--check-parity`` first asserts the served greedy actions are
bit-identical to the evaluation path (guaranteed at w8).
"""
from __future__ import annotations

import argparse
import os
import time
from typing import Optional

from repro.obs import SCHEMA, JsonlSink
from repro.serve import (PRECISIONS, PolicyServer, check_parity,
                         load_policy, serve_episodes)


def serve_policy(ckpt_dir: str, algo: Optional[str] = None,
                 net: Optional[str] = None,
                 env_name: Optional[str] = None,
                 step: Optional[int] = None,
                 precision: str = "w8", mode: str = "greedy",
                 temperature: float = 1.0, episodes: int = 100,
                 n_slots: int = 64, max_bucket: int = 32,
                 seed: int = 0, do_check_parity: bool = False,
                 verbose: bool = True,
                 metrics_dir: Optional[str] = None,
                 metrics_every: int = 50,
                 profile_dir: Optional[str] = None):
    policy = load_policy(ckpt_dir, algo=algo, net=net,
                         env_name=env_name, step=step)
    if verbose:
        print(f"serving {policy.algo}/{policy.net} on "
              f"{policy.env_name} (step {policy.step}, "
              f"precision {precision}, mode {mode})")
    if do_check_parity:
        if precision == "fp32":
            raise ValueError("--check-parity compares a *packed* "
                             "precision against the eval path; use "
                             "--policy w8 (bit-exact) or w4")
        bad = check_parity(policy, precision, seed=seed)
        if verbose:
            print(f"parity vs value_eval at {precision}: "
                  f"{bad} mismatching actions")
        if precision == "w8" and bad:
            raise AssertionError(
                f"served w8 greedy actions diverged from the "
                f"evaluation path on {bad} observations — the packed "
                "weights no longer share value_eval's fxp8 grid")
    server = PolicyServer(policy, precision=precision, mode=mode,
                          temperature=temperature,
                          max_bucket=max_bucket, seed=seed)
    sink = None
    if metrics_dir:
        sink = JsonlSink(
            os.path.join(metrics_dir, "serve.jsonl"),
            run={"driver": "serve_policy", "algo": policy.algo,
                 "env": policy.env_name, "net": policy.net,
                 "precision": precision, "mode": mode,
                 "n_slots": n_slots, "max_bucket": max_bucket,
                 "seed": seed})
    if profile_dir:
        import jax
        os.makedirs(profile_dir, exist_ok=True)
        jax.profiler.start_trace(profile_dir)
    try:
        stats = serve_episodes(server, episodes, n_slots=n_slots,
                               seed=seed, telemetry=sink,
                               flush_every=metrics_every)
    finally:
        if profile_dir:
            import jax
            jax.profiler.stop_trace()
            if sink:
                sink.write({"schema": SCHEMA, "kind": "profile",
                            "t_wall": time.time(), "dir": profile_dir,
                            "window": [0, int(server._requests)]})
        if sink:
            sink.close()
    s = stats.server
    if verbose:
        mib = 1024 * 1024
        print(f"served {stats.episodes} episodes / "
              f"{stats.env_steps} env steps in {stats.wall_s:.2f}s "
              f"(mean return {stats.mean_return:.1f})")
        print(f"  actions/s      {s['actions_per_s']:.0f}")
        print(f"  latency p50    {s['p50_ms']:.3f} ms")
        print(f"  latency p99    {s['p99_ms']:.3f} ms")
        print(f"  model bytes    {s['model_bytes']:.0f} "
              f"({s['model_bytes'] / mib:.3f} MiB, "
              f"{s['compression']:.3f}x of fp32)")
        print(f"  jit programs   {s['jit_programs']:.0f} "
              f"(buckets <= {max_bucket})")
    return stats


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt", required=True,
                    help="checkpoint dir written by rl_train --ckpt-dir")
    ap.add_argument("--algo", default=None,
                    help="cross-check against the checkpoint metadata")
    ap.add_argument("--net", default=None,
                    help="cross-check against the checkpoint metadata")
    ap.add_argument("--env", default=None,
                    help="cross-check against the checkpoint metadata")
    ap.add_argument("--step", type=int, default=None,
                    help="checkpoint step (default: latest)")
    ap.add_argument("--policy", default="w8",
                    choices=sorted(PRECISIONS),
                    help="serving precision (weight packing)")
    ap.add_argument("--mode", default="greedy",
                    choices=["greedy", "sample"])
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--episodes", type=int, default=100)
    ap.add_argument("--slots", type=int, default=64,
                    help="concurrent episode slots")
    ap.add_argument("--batch-bucket", type=int, default=32,
                    help="largest micro-batch bucket (pad-to-bucket "
                         "ladder is powers of two up to this)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--check-parity", action="store_true",
                    help="assert served greedy actions match the "
                         "evaluation path before serving")
    # observability (docs/observability.md)
    ap.add_argument("--metrics-dir", default=None,
                    help="write obs/v1 JSONL telemetry (serve.jsonl) "
                         "here")
    ap.add_argument("--metrics-every", type=int, default=50,
                    help="loop steps per serve record (0: one record "
                         "for the whole run)")
    ap.add_argument("--profile-dir", default=None,
                    help="capture a jax.profiler trace of the serving "
                         "loop into this dir")
    args = ap.parse_args(argv)
    serve_policy(args.ckpt, algo=args.algo, net=args.net,
                 env_name=args.env, step=args.step,
                 precision=args.policy, mode=args.mode,
                 temperature=args.temperature, episodes=args.episodes,
                 n_slots=args.slots, max_bucket=args.batch_bucket,
                 seed=args.seed, do_check_parity=args.check_parity,
                 metrics_dir=args.metrics_dir,
                 metrics_every=args.metrics_every,
                 profile_dir=args.profile_dir)


if __name__ == "__main__":
    main()
