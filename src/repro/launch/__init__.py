# NOTE: dryrun.py must be imported/run as __main__ FIRST in a fresh
# process (it sets XLA_FLAGS before jax init); do not import it here.
from repro.launch.mesh import (describe, make_host_mesh,
                               make_production_mesh)
