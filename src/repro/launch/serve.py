"""Quantized batched serving driver (prefill + decode loop).

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --smoke --policy w8a8kv8 --batch 4 --prompt-len 32 --gen 16

Demonstrates the paper's deployment story end-to-end on the host mesh:
weights PTQ'd to int8 (QTensor, 4x smaller), activations int8 at the
matmuls, KV cache optionally int8 — with greedy/temperature sampling.
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.registry import get_arch
from repro.core.policy import get_policy
from repro.core.quantizer import quantize_params, quantized_nbytes
from repro.launch.mesh import make_host_mesh
from repro.models.registry import model_for
from repro.nn.module import unbox


def pad_caches(caches, extra: int):
    """Grow attention-cache capacity by ``extra`` slots (prefill built
    them at prompt length; decode needs prompt+gen).  Ring buffers
    (sliding-window, marked by 'pos') and recurrent states are
    fixed-capacity by design and pass through unchanged."""

    def walk(node):
        if isinstance(node, dict):
            if "k" in node and "v" in node and "pos" not in node:
                out = dict(node)
                for key in ("k", "v", "k_scale", "v_scale"):
                    if key in node:
                        arr = node[key]
                        t_axis = arr.ndim - 3
                        pad = [(0, 0)] * arr.ndim
                        pad[t_axis] = (0, extra)
                        out[key] = jnp.pad(arr, pad)
                return out
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        return node

    return walk(caches)


def serve(arch: str, smoke: bool = True, policy_name: str = "w8a8kv8",
          batch: int = 4, prompt_len: int = 32, gen: int = 16,
          temperature: float = 0.0, seed: int = 0,
          weight_ptq: bool = True, verbose: bool = True):
    cfg = get_arch(arch)
    if smoke:
        cfg = cfg.reduced()
    policy = get_policy(policy_name)
    model = model_for(cfg)

    params = unbox(model.init(jax.random.PRNGKey(seed), cfg))
    if weight_ptq and policy.quantized_w:
        params = quantize_params(params, policy)
        stored, fp32 = quantized_nbytes(params)
        if verbose:
            print(f"PTQ weights: {stored / 2**20:.1f} MiB "
                  f"(fp32 {fp32 / 2**20:.1f} MiB, "
                  f"{fp32 / max(stored, 1):.2f}x smaller)")

    key = jax.random.PRNGKey(seed + 1)
    max_len = prompt_len + gen
    if cfg.is_encdec:
        frames = jax.random.normal(key, (batch, prompt_len, cfg.d_model))
        prompts = jax.random.randint(key, (batch, prompt_len), 0,
                                     cfg.vocab)
        batch_in = {"frames": frames, "tokens": prompts}
    else:
        prompts = jax.random.randint(key, (batch, prompt_len), 0,
                                     cfg.vocab)
        batch_in = prompts

    kv_bits = policy.kv_bits

    @jax.jit
    def do_prefill(params, b):
        return model.prefill(params, b, cfg, policy, kv_bits)

    @jax.jit
    def do_decode(params, token, caches, index):
        return model.decode_step(params, token, caches, index, cfg,
                                 policy, kv_bits)

    t0 = time.time()
    logits, caches = do_prefill(params, batch_in)
    caches = pad_caches(caches, gen)     # capacity: prompt_len + gen
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    def sample(key, logits):
        if temperature <= 0:
            return jnp.argmax(logits, -1, keepdims=True).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / temperature)[:, None].astype(jnp.int32)

    key, sub = jax.random.split(key)
    token = sample(sub, logits)
    out_tokens = [token]
    t0 = time.time()
    index = jnp.asarray(prompt_len, jnp.int32)
    for i in range(gen - 1):
        logits, caches = do_decode(params, token, caches, index + i)
        key, sub = jax.random.split(key)
        token = sample(sub, logits)
        out_tokens.append(token)
    jax.block_until_ready(token)
    t_decode = time.time() - t0

    toks = jnp.concatenate(out_tokens, axis=1)
    if verbose:
        print(f"prefill: {batch}x{prompt_len} tok in {t_prefill:.3f}s "
              f"({batch * prompt_len / max(t_prefill, 1e-9):.0f} tok/s)")
        print(f"decode:  {batch}x{gen - 1} tok in {t_decode:.3f}s "
              f"({batch * (gen - 1) / max(t_decode, 1e-9):.0f} tok/s)")
        print(f"sample output ids: {toks[0, :10].tolist()}")
    return toks, {"t_prefill": t_prefill, "t_decode": t_decode}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--policy", default="w8a8kv8")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)
    serve(args.arch, args.smoke, args.policy, args.batch,
          args.prompt_len, args.gen, args.temperature)


if __name__ == "__main__":
    main()
