"""Roofline terms for TPU v5e from compiled-HLO statistics.

    compute term    = HLO_FLOPs / (peak FLOP/s)          [per device]
    memory term     = HLO_bytes / HBM bandwidth          [per device]
    collective term = collective_bytes / link bandwidth  [per device]

cost_analysis() reports per-device (post-SPMD-partitioning) numbers, so
no further division by chip count is needed.  MODEL_FLOPS = 6*N*D
(dense) or 6*N_active*D (MoE) gives the useful-work ceiling; the ratio
against HLO FLOPs exposes remat/redundant compute.
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.configs.base import (ArchConfig, active_param_count,
                                param_count)
from repro.configs.shapes import ShapeConfig

# TPU v5e hardware constants (per chip / per link)
PEAK_BF16 = 197e12           # FLOP/s
PEAK_INT8 = 394e12           # OP/s (2x bf16)
PEAK_FP32 = PEAK_BF16 / 8    # MXU fp32 rate ~1/8 bf16
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per link (given)


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """6*N*D for train; 2*N*D for a forward-only step (prefill);
    2*N*D_new for decode (D = tokens processed by the step)."""
    n = active_param_count(cfg) if cfg.is_moe else param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence per step
    return 2.0 * n * shape.global_batch


def roofline_terms(cfg: ArchConfig, shape: ShapeConfig, mesh,
                   cost: Dict, peak_flops: float = PEAK_BF16,
                   peak_int8: float = PEAK_INT8,
                   hbm_bw: float = HBM_BW,
                   ici_bw: float = ICI_BW) -> Dict:
    chips = mesh.devices.size
    # fp ops at the bf16 MXU rate; int8 dots at the 2x int8 rate
    t_compute = (cost["flops"] / peak_flops
                 + cost.get("int_ops", 0.0) / peak_int8)
    t_memory = cost["bytes"] / hbm_bw
    t_collective = cost["collective_bytes"] / ici_bw
    bound = max((("compute", t_compute), ("memory", t_memory),
                 ("collective", t_collective)), key=lambda kv: kv[1])[0]
    mf = model_flops(cfg, shape)
    hlo_total = (cost["flops"] + cost.get("int_ops", 0.0)) * chips
    useful = mf / hlo_total if hlo_total else 0.0
    t_bound = max(t_compute, t_memory, t_collective)
    # model-flops utilization IF the roofline bound were achieved
    mfu_ceiling = (mf / (chips * peak_flops)) / t_bound if t_bound else 0
    return {
        "t_compute": t_compute,
        "t_memory": t_memory,
        "t_collective": t_collective,
        "bound": bound,
        "t_step": t_bound,
        "model_flops": mf,
        "hlo_flops_total": hlo_total,
        "useful_flops_frac": min(useful, 1.0),
        "mfu_at_roofline": mfu_ceiling,
        "chips": chips,
    }


def summarize(results) -> str:
    """Markdown table from a list of run_cell() dicts."""
    rows = ["| arch | shape | step | bound | t_comp (s) | t_mem (s) | "
            "t_coll (s) | t_step (s) | MFU@roof | useful/HLO |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for r in results:
        if r.get("status") != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | - | "
                        f"{r['status']} | | | | | | |")
            continue
        f = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['step']} | {f['bound']} "
            f"| {f['t_compute']:.2e} | {f['t_memory']:.2e} "
            f"| {f['t_collective']:.2e} | {f['t_step']:.2e} "
            f"| {100 * f['mfu_at_roofline']:.1f}% "
            f"| {100 * f['useful_flops_frac']:.1f}% |")
    return "\n".join(rows)
