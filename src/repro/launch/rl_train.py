"""Q-Actor RL training driver: quantized actors + full-precision
learner + int8 weight sync (the paper's Fig. 2 system).

    PYTHONPATH=src python -m repro.launch.rl_train --env cartpole \
        --iters 40 --actor-policy fxp8 [--algo ppo|a2c|dqn|qrdqn|ddpg] \
        [--agent hrl] [--two-stage] [--mesh host] [--replay per]

This module is CLI parsing + dispatch only: the drivers live in
:mod:`repro.rl.trainer` (the ``Trainer`` protocol — ``init /
iteration / save / restore / eval_policy`` — with the train loop,
checkpoint flow, RNG derivation and FleetSync weight sync implemented
once for both families).  The historical names (``rl_train``,
``value_train``, ``value_eval``, ``make_agent``, ``build_mesh``, the
inference-layer re-exports) remain importable from here.

Two training families share the quantized-actor/fp32-learner split:

  * on-policy (``--algo ppo|a2c``): the actor fleet is shard_map'd
    over the data axes of a real device mesh (``--mesh host`` by
    default); see :mod:`repro.rl.trainer.onpolicy`.
  * off-policy value-based (``--algo dqn|qrdqn|ddpg``): quantized
    behaviour actors fill a truncation-aware n-step replay
    (``--replay {uniform,per}``), the fp32 learner updates against
    polyak targets.  With ``--mesh host`` collection and learning
    shard over the mesh: per-device local replay shards with
    stratified global (PER) sampling, psum'd learner grads, and
    ``--sync doublebuf`` double-buffered weight sync (the next collect
    overlaps the learner update); see :mod:`repro.rl.trainer.value`.

Checkpoints make both loops restart-safe (including mid-stage restarts
of ``--two-stage`` runs and the sharded replay/target state of
value-based runs).
"""
from __future__ import annotations

import argparse

# the inference layer (env stack + net reconstruction + action heads)
# is shared with repro.serve — the historical rl_train names re-export
from repro.rl.inference import (NETS, ON_POLICY_ALGOS,  # noqa: F401
                                VALUE_ALGOS, ValueAgent, build_env,
                                make_value_agent)
from repro.rl.envs import registered
from repro.rl.replay import KINDS as REPLAY_KINDS
from repro.rl.trainer import (SYNC_MODES, build_mesh,  # noqa: F401
                              make_agent, rl_train, value_eval,
                              value_train)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--algo", default="ppo",
                    choices=list(ON_POLICY_ALGOS + VALUE_ALGOS))
    ap.add_argument("--env", default="cartpole",
                    choices=list(registered()))
    ap.add_argument("--agent", default="mlp", choices=["mlp", "hrl"])
    ap.add_argument("--net", default="mlp", choices=list(NETS),
                    help="conv = Q-Conv pixel stem over the running-"
                         "normalized (+ frame-stacked) image pipeline")
    ap.add_argument("--frame-stack", type=int, default=1,
                    help="stack the last K frames (conv net only)")
    ap.add_argument("--iters", type=int, default=None,
                    help="default: 40 (on-policy) / 300 (value-based)")
    ap.add_argument("--n-envs", type=int, default=32)
    ap.add_argument("--rollout-len", type=int, default=None,
                    help="default: 128 (on-policy) / 8 (value-based)")
    ap.add_argument("--actor-policy", default="fxp8")
    ap.add_argument("--fp32-actors", action="store_true")
    ap.add_argument("--comm-bits", type=int, default=8)
    ap.add_argument("--max-lag", type=int, default=1)
    ap.add_argument("--lr", type=float, default=None,
                    help="default: 3e-3 (on-policy) / 1e-3 (value-based)")
    ap.add_argument("--two-stage", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=None)
    ap.add_argument("--mesh", default=None,
                    choices=["host", "production"],
                    help="device mesh for the actor fleet (default: "
                         "host for on-policy; unset = single-device "
                         "for value-based)")
    ap.add_argument("--mesh-devices", type=int, default=None,
                    help="restrict the host mesh to the first N devices")
    ap.add_argument("--sync", default=None, choices=list(SYNC_MODES),
                    help="sharded value weight sync: lockstep fences "
                         "every iteration; doublebuf overlaps the next "
                         "collect with the learner update (default "
                         "with a mesh)")
    # value-based knobs (--algo dqn|qrdqn|ddpg)
    ap.add_argument("--replay-capacity", type=int, default=50_000)
    ap.add_argument("--replay", default="uniform",
                    choices=list(REPLAY_KINDS),
                    help="replay backend: uniform circular, or per "
                         "(sum-tree proportional prioritization)")
    ap.add_argument("--per-alpha", type=float, default=0.6,
                    help="PER priority exponent (0=uniform, 1=greedy)")
    ap.add_argument("--per-beta0", type=float, default=0.4,
                    help="initial PER importance-weight exponent")
    ap.add_argument("--per-beta-iters", type=int, default=None,
                    help="iterations to anneal beta to 1 over "
                         "(default: the whole run)")
    ap.add_argument("--tqc-drop", type=int, default=0,
                    help="ddpg: drop the top-k pooled target quantiles "
                         "(TQC truncation; >0 switches the twin "
                         "critics to 25-quantile heads)")
    ap.add_argument("--n-step", type=int, default=3)
    ap.add_argument("--updates-per-iter", type=int, default=4)
    ap.add_argument("--learn-start", type=int, default=None,
                    help="min replay size before updates (default: the "
                         "algo config's, 256)")
    # observability (docs/observability.md)
    ap.add_argument("--metrics-dir", default=None,
                    help="write obs/v1 JSONL telemetry (train.jsonl) "
                         "here; training stays bitwise identical")
    ap.add_argument("--profile-dir", default=None,
                    help="capture a jax.profiler trace into this dir")
    ap.add_argument("--profile-start", type=int, default=0,
                    help="global step the profiler window opens at")
    ap.add_argument("--profile-steps", type=int, default=1,
                    help="iterations the profiler window spans")
    args = ap.parse_args(argv)
    actor_policy = None if args.fp32_actors else args.actor_policy
    if args.algo not in VALUE_ALGOS and (args.replay != "uniform"
                                         or args.tqc_drop
                                         or args.sync is not None):
        raise ValueError(
            "--replay/--tqc-drop/--sync configure the value-based "
            f"replay loop; --algo {args.algo} is on-policy — drop "
            "these flags")
    if args.replay != "per" and (args.per_alpha != 0.6
                                 or args.per_beta0 != 0.4
                                 or args.per_beta_iters is not None):
        raise ValueError(
            "--per-alpha/--per-beta0/--per-beta-iters configure the "
            "prioritized backend and would be silently ignored — add "
            "--replay per (or drop them)")
    if args.algo in VALUE_ALGOS:
        if args.two_stage or args.agent == "hrl":
            raise ValueError("--two-stage/--agent hrl are on-policy "
                             "(PPO) features; value-based algos drive "
                             "the MLP nets")
        if args.sync is not None and args.mesh is None:
            raise ValueError("--sync configures the sharded weight "
                             "sync — add --mesh host")
        sync = args.sync or ("doublebuf" if args.mesh is not None
                             else "lockstep")
        value_train(args.algo, args.env,
                    iters=args.iters if args.iters is not None else 300,
                    n_envs=args.n_envs,
                    rollout_len=(args.rollout_len
                                 if args.rollout_len is not None else 8),
                    actor_policy=actor_policy,
                    lr=args.lr if args.lr is not None else 1e-3,
                    comm_bits=args.comm_bits, ckpt_dir=args.ckpt_dir,
                    save_every=(args.save_every
                                if args.save_every is not None else 50),
                    replay_capacity=args.replay_capacity,
                    n_step=args.n_step,
                    updates_per_iter=args.updates_per_iter,
                    learn_start=args.learn_start, net=args.net,
                    frame_stack_k=args.frame_stack,
                    replay=args.replay, per_alpha=args.per_alpha,
                    per_beta0=args.per_beta0,
                    per_beta_iters=args.per_beta_iters,
                    tqc_drop=args.tqc_drop, mesh_kind=args.mesh,
                    mesh_devices=args.mesh_devices, sync=sync,
                    max_lag=args.max_lag,
                    metrics_dir=args.metrics_dir,
                    profile_dir=args.profile_dir,
                    profile_start=args.profile_start,
                    profile_steps=args.profile_steps)
    else:
        rl_train(args.env, args.agent,
                 args.iters if args.iters is not None else 40,
                 args.n_envs,
                 args.rollout_len if args.rollout_len is not None
                 else 128,
                 actor_policy,
                 args.lr if args.lr is not None else 3e-3,
                 args.comm_bits, args.max_lag,
                 two_stage=args.two_stage, ckpt_dir=args.ckpt_dir,
                 save_every=(args.save_every
                             if args.save_every is not None else 10),
                 mesh_kind=args.mesh or "host",
                 mesh_devices=args.mesh_devices,
                 algo=args.algo, net=args.net,
                 frame_stack_k=args.frame_stack,
                 metrics_dir=args.metrics_dir,
                 profile_dir=args.profile_dir,
                 profile_start=args.profile_start,
                 profile_steps=args.profile_steps)


if __name__ == "__main__":
    main()
