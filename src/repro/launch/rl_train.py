"""Q-Actor RL training driver: quantized actors + full-precision
learner + int8 weight sync (the paper's Fig. 2 system).

    PYTHONPATH=src python -m repro.launch.rl_train --env cartpole \
        --iters 40 --actor-policy fxp8 [--agent hrl] [--two-stage]

The actor fleet is a vectorized batch of environments; each "actor" is
a slice running under a (possibly stale, possibly quantized) copy of
the learner weights.  The learner updates with PPO.  Checkpoints make
the loop restart-safe.
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs.e2hrl import HRLConfig
from repro.core.policy import get_policy
from repro.models import hrl
from repro.nn.module import unbox
from repro.optim import AdamWConfig, adamw_init, adamw_update, constant
from repro.rl import PPOConfig, batch_from_traj, init_envs, rollout
from repro.rl.actor_learner import (ActorLearnerConfig, VersionBuffer,
                                    pack_weights, sync_bytes,
                                    unpack_weights)
from repro.rl.dists import distribution_for
from repro.rl.envs import Environment, make, registered
from repro.rl.envs.spaces import head_dim
from repro.rl.nets import mlp_ac_apply, mlp_ac_init
from repro.rl.ppo import minibatch_epochs, stage_mask
from repro.rl.rollout import episode_returns


def make_agent(agent: str, env: Environment, key,
               policy_name: Optional[str]):
    spec = env.spec
    if agent == "mlp":
        if len(spec.obs_shape) != 1:
            raise ValueError(
                f"{spec.name} has obs shape {spec.obs_shape}; wrap with "
                "envs.wrappers.flatten_observation for the mlp agent "
                "or use --agent hrl")
        params = unbox(mlp_ac_init(key, spec.obs_shape[0],
                                   head_dim(spec.action_space)))
        apply_fn = mlp_ac_apply
        return params, apply_fn
    if len(spec.obs_shape) != 3:
        raise ValueError(
            f"{spec.name} has obs shape {spec.obs_shape}; the hrl agent "
            "needs image (H, W, C) observations — use --agent mlp")
    cfg = HRLConfig(obs_shape=spec.obs_shape, n_actions=spec.n_actions)
    params = unbox(hrl.init(key, cfg))

    def apply_fn(p, obs, policy=None):
        logits, value, _ = hrl.apply(p, obs, cfg, policy)
        return logits, value

    return params, apply_fn


def rl_train(env_name: str = "cartpole", agent: str = "mlp",
             iters: int = 40, n_envs: int = 32, rollout_len: int = 128,
             actor_policy: Optional[str] = "fxp8", lr: float = 3e-3,
             comm_bits: int = 8, max_lag: int = 1, seed: int = 0,
             two_stage: bool = False, ckpt_dir: Optional[str] = None,
             log_every: int = 5, verbose: bool = True):
    env = make(env_name)
    dist = distribution_for(env.action_space)
    key = jax.random.PRNGKey(seed)
    params, apply_fn = make_agent(agent, env, key, actor_policy)
    a_policy = get_policy(actor_policy) if actor_policy else None

    opt = adamw_init(params)
    ocfg = AdamWConfig(weight_decay=0.0, max_grad_norm=0.5)
    pcfg = PPOConfig()
    sched = constant(lr)
    start = 0
    mgr = None
    if ckpt_dir:
        mgr = CheckpointManager(ckpt_dir, keep=2, save_every=10)
        if mgr.latest_step() is not None:
            (params, opt), md = mgr.restore((params, opt))
            start = int(md.get("step", 0))
            if verbose:
                print(f"resumed from iter {start}")

    est, obs = init_envs(env, jax.random.PRNGKey(seed + 1), n_envs)
    versions = VersionBuffer(max_lag)
    learner_apply = lambda p, o: apply_fn(p, o, None)

    total_sync_payload = 0

    @jax.jit
    def iteration(params, opt, est, obs, packed, key):
        k1, k2 = jax.random.split(key)
        actor_params = unpack_weights(packed)
        actor_apply = lambda p, o: apply_fn(p, o, a_policy)
        res = rollout(actor_params, env, actor_apply, k1, est, obs,
                      rollout_len, dist)
        batch = batch_from_traj(res.traj, res.last_value, pcfg)

        def opt_step(p, s, g):
            p, s, _ = adamw_update(g, s, p, sched, ocfg)
            return p, s

        gmask = None
        params, opt, stats = minibatch_epochs(
            k2, params, opt, batch, learner_apply, pcfg, opt_step,
            grad_mask=gmask, dist=dist)
        ret, n_ep = episode_returns(res.traj)
        return params, opt, res.final_env, res.final_obs, ret, n_ep

    history = []
    t0 = time.time()
    stage_list = (["action", "subgoal"] if two_stage and agent == "hrl"
                  else [None])
    for stage in stage_list:
        for it in range(start, iters):
            # learner -> actors: quantized weight sync (staleness-aware)
            packed = pack_weights(params, comm_bits)
            versions.push(packed)
            stale = versions.stale(max_lag - 1)
            payload, fp32_eq = sync_bytes(stale)
            total_sync_payload += payload
            key, sub = jax.random.split(key)
            params, opt, est, obs, ret, n_ep = iteration(
                params, opt, est, obs, stale, sub)
            history.append(float(ret))
            if verbose and (it % log_every == 0 or it == iters - 1):
                sfx = f" [stage={stage}]" if stage else ""
                print(f"iter {it:4d}  return {float(ret):8.2f}  "
                      f"episodes {int(n_ep):4d}  "
                      f"sync {payload / 2**20:.2f} MiB "
                      f"(fp32 {fp32_eq / 2**20:.2f}){sfx}")
            if mgr and mgr.should_save(it):
                mgr.save(it, (params, opt))
    if verbose:
        print(f"done in {time.time() - t0:.0f}s; "
              f"total sync payload {total_sync_payload / 2**20:.1f} MiB")
    return params, history


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--env", default="cartpole",
                    choices=list(registered()))
    ap.add_argument("--agent", default="mlp", choices=["mlp", "hrl"])
    ap.add_argument("--iters", type=int, default=40)
    ap.add_argument("--n-envs", type=int, default=32)
    ap.add_argument("--rollout-len", type=int, default=128)
    ap.add_argument("--actor-policy", default="fxp8")
    ap.add_argument("--fp32-actors", action="store_true")
    ap.add_argument("--comm-bits", type=int, default=8)
    ap.add_argument("--max-lag", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--two-stage", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args(argv)
    rl_train(args.env, args.agent, args.iters, args.n_envs,
             args.rollout_len,
             None if args.fp32_actors else args.actor_policy,
             args.lr, args.comm_bits, args.max_lag,
             two_stage=args.two_stage, ckpt_dir=args.ckpt_dir)


if __name__ == "__main__":
    main()
