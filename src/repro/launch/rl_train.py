"""Q-Actor RL training driver: quantized actors + full-precision
learner + int8 weight sync (the paper's Fig. 2 system).

    PYTHONPATH=src python -m repro.launch.rl_train --env cartpole \
        --iters 40 --actor-policy fxp8 [--algo ppo|a2c|dqn|qrdqn|ddpg] \
        [--agent hrl] [--two-stage]

Two training families share the quantized-actor/fp32-learner split:

  * on-policy (``--algo ppo|a2c``): the actor fleet is shard_map'd over
    the data axes of a real device mesh (``--mesh host`` by default —
    whatever this host exposes, e.g. 8 CPU devices under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``; ``--mesh
    production`` for the 16x16 pod shape).  Each device dequantizes the
    broadcast int8 weight sync locally and rolls ``n_envs/n_devices``
    environments; per-device trajectories come back as one global batch
    whose per-device slots carry a liveness mask into the PPO loss (and
    out of the advantage statistics).  This synchronous driver always
    reports every slot alive — an async aggregator only has to flip
    mask bits to drop a straggler, it never has to reshape the loss.
    Truncated episodes bootstrap through the timeout (GAE consumes the
    env's terminated/truncated split).
  * off-policy value-based (``--algo dqn|qrdqn|ddpg``): the quantized
    behaviour actor (epsilon-greedy Q net, or deterministic actor +
    exploration noise for Box envs) fills a truncation-aware n-step
    replay (``--replay {uniform,per}`` — uniform circular, or sum-tree
    prioritized with ``--per-alpha/--per-beta0/--per-beta-iters``; see
    :mod:`repro.rl.replay`); the fp32 learner updates Double-DQN /
    QR-DQN / TD3-style twin-critic DDPG (``--tqc-drop`` swaps the
    min-backup for TQC quantile truncation) against polyak target
    networks — see :mod:`repro.rl.value`.

Checkpoints make both loops restart-safe (including mid-stage restarts
of ``--two-stage`` runs and the replay/target state of value-based
runs).
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs.e2hrl import HRLConfig
from repro.core.policy import get_policy
from repro.distributed.sharding import data_axis_size
from repro.launch.mesh import describe, make_host_mesh, make_production_mesh
from repro.models import hrl
from repro.nn.module import unbox
from repro.optim import AdamWConfig, adamw_init, constant
from repro.rl import PPOConfig, init_envs
from repro.rl.actor_learner import (VersionBuffer, pack_weights,
                                    sync_bytes)
from repro.rl.dists import distribution_for
# the inference layer (env stack + net reconstruction + action heads)
# is shared with repro.serve — the historical rl_train names re-export
from repro.rl.inference import (NETS, ON_POLICY_ALGOS,  # noqa: F401
                                VALUE_ALGOS, ValueAgent, build_env,
                                make_value_agent)
from repro.rl.envs import Environment, make, registered
from repro.rl.envs.spaces import head_dim
from repro.rl.envs.wrappers import NormStats
from repro.rl.nets import (conv_ac_apply, conv_ac_init, mlp_ac_apply,
                           mlp_ac_init)
from repro.rl.ppo import a2c_loss, ppo_loss, stage_mask
from repro.rl.replay import KINDS as REPLAY_KINDS
from repro.rl.replay import make_replay, replay_size
from repro.rl.rollout import episode_returns_from
from repro.rl.train_steps import (make_onpolicy_iteration,
                                  make_value_iteration)


def make_agent(agent: str, env: Environment, key,
               policy_name: Optional[str], net: str = "mlp"):
    spec = env.spec
    if agent == "mlp":
        if net == "conv":
            if len(spec.obs_shape) != 3:
                raise ValueError(
                    f"{spec.name} has obs shape {spec.obs_shape}; "
                    "--net conv needs image (H, W, C) observations")
            params = unbox(conv_ac_init(key, spec.obs_shape,
                                        head_dim(spec.action_space)))
            return params, conv_ac_apply
        if len(spec.obs_shape) != 1:
            raise ValueError(
                f"{spec.name} has obs shape {spec.obs_shape}; use "
                "--net conv for the Q-Conv pixel stem, wrap with "
                "envs.wrappers.flatten_observation for the mlp agent, "
                "or use --agent hrl")
        params = unbox(mlp_ac_init(key, spec.obs_shape[0],
                                   head_dim(spec.action_space)))
        apply_fn = mlp_ac_apply
        return params, apply_fn
    if net != "mlp":
        raise ValueError("--net conv selects the standalone conv "
                         "actor-critic; the hrl agent has its own conv "
                         "stem — drop --net")
    if len(spec.obs_shape) != 3:
        raise ValueError(
            f"{spec.name} has obs shape {spec.obs_shape}; the hrl agent "
            "needs image (H, W, C) observations — use --agent mlp")
    cfg = HRLConfig(obs_shape=spec.obs_shape, n_actions=spec.n_actions)
    params = unbox(hrl.init(key, cfg))

    def apply_fn(p, obs, policy=None):
        logits, value, _ = hrl.apply(p, obs, cfg, policy)
        return logits, value

    return params, apply_fn


def build_mesh(mesh_kind: str = "host",
               mesh_devices: Optional[int] = None):
    if mesh_kind == "production":
        if mesh_devices is not None:
            raise ValueError("--mesh-devices restricts the host mesh "
                             "only; the production mesh shape is fixed")
        return make_production_mesh()
    if mesh_kind == "host":
        return make_host_mesh(mesh_devices)
    raise ValueError(f"unknown mesh kind {mesh_kind!r} "
                     "(expected 'host' or 'production')")


def rl_train(env_name: str = "cartpole", agent: str = "mlp",
             iters: int = 40, n_envs: int = 32, rollout_len: int = 128,
             actor_policy: Optional[str] = "fxp8", lr: float = 3e-3,
             comm_bits: int = 8, max_lag: int = 1, seed: int = 0,
             two_stage: bool = False, ckpt_dir: Optional[str] = None,
             save_every: int = 10, mesh_kind: str = "host",
             mesh_devices: Optional[int] = None,
             log_every: int = 5, verbose: bool = True,
             algo: str = "ppo", net: str = "mlp",
             frame_stack_k: int = 1,
             state_out: Optional[dict] = None):
    if algo not in ON_POLICY_ALGOS:
        raise ValueError(f"rl_train drives the on-policy family "
                         f"{ON_POLICY_ALGOS}; use value_train for "
                         f"{VALUE_ALGOS} (or the --algo CLI dispatch)")
    if two_stage and agent != "hrl":
        raise ValueError("--two-stage trains the HRL sub-goal curriculum "
                         "and requires --agent hrl")
    if net == "conv":
        env = build_env(env_name, net, frame_stack_k)
    else:
        # the mlp/hrl agents keep the historical raw-env view
        # (make_agent validates the obs shape)
        if frame_stack_k > 1:
            raise ValueError("--frame-stack is a pixel-pipeline knob "
                             "and requires --net conv")
        env = make(env_name)
    dist = distribution_for(env.action_space)
    key = jax.random.PRNGKey(seed)
    params, apply_fn = make_agent(agent, env, key, actor_policy, net)
    a_policy = get_policy(actor_policy) if actor_policy else None

    if mesh_kind == "host" and mesh_devices is None:
        # default: the largest device prefix that divides n_envs, so
        # odd host device counts degrade to fewer slots instead of
        # failing (explicit --mesh-devices keeps the hard error below)
        mesh_devices = len(jax.devices())
        while mesh_devices > 1 and n_envs % mesh_devices != 0:
            mesh_devices -= 1
    mesh = build_mesh(mesh_kind, mesh_devices)
    n_slots = data_axis_size(mesh)
    if n_envs % n_slots != 0:
        raise ValueError(f"--n-envs {n_envs} must be divisible by the "
                         f"mesh's {n_slots} data slot(s)")
    if verbose:
        print(f"{describe(mesh)}: {n_slots} actor slot(s) x "
              f"{n_envs // n_slots} envs")

    opt = adamw_init(params)
    ocfg = AdamWConfig(weight_decay=0.0, max_grad_norm=0.5)
    # a2c: one pass over the whole batch, no clipping surrogate
    pcfg = (PPOConfig() if algo == "ppo"
            else PPOConfig(epochs=1, minibatches=1))
    loss_fn = ppo_loss if algo == "ppo" else a2c_loss
    sched = constant(lr)
    stage_list = (["action", "subgoal"] if two_stage else [None])
    stage_names = [s or "all" for s in stage_list]
    est, obs = init_envs(env, jax.random.PRNGKey(seed + 1), n_envs,
                         mesh=mesh)
    start = 0
    mgr = None
    if ckpt_dir:
        mgr = CheckpointManager(ckpt_dir, keep=2, save_every=save_every)
        if mgr.latest_step() is not None:
            # env state rides in the checkpoint so wrapper carries
            # (e.g. the Welford running-norm stats) resume exactly
            (params, opt, est, obs), md = mgr.restore(
                (params, opt, est, obs))
            md_stage = str(md.get("stage", "all"))
            if md_stage not in stage_names:
                raise ValueError(
                    f"checkpoint in {ckpt_dir} was saved in stage "
                    f"{md_stage!r} but this run's stages are "
                    f"{stage_names} — relaunch with the original "
                    "--two-stage/--agent flags")
            # the checkpoint holds post-update state for its step, so
            # training continues at the NEXT step (re-running the saved
            # one would apply its optimizer update twice); the global
            # step is rebuilt from the recorded (stage, stage_iter) so
            # a changed --iters cannot land the resume in the wrong
            # stage
            it = int(md.get("stage_iter", md.get("step", 0)))
            # clamp for a shrunken --iters: the recorded stage already
            # met the new budget, so continue at the next stage rather
            # than skipping past the end of the whole run
            start = stage_names.index(md_stage) * iters + min(it + 1,
                                                              iters)
            if verbose:
                print(f"resumed at global iter {start} "
                      f"(stage {md_stage}, iter {it} done)")

    versions = VersionBuffer(max_lag)
    # synchronous driver: every device delivers; the mask still flows
    # through the loss so an async aggregator only has to flip bits
    alive = jnp.ones((n_slots,), bool)

    total_sync_payload = 0

    iteration = make_onpolicy_iteration(
        env, apply_fn, a_policy, mesh, dist, pcfg, loss_fn, sched,
        ocfg, rollout_len=rollout_len, n_envs=n_envs, n_slots=n_slots)

    history = []
    t0 = time.time()
    for si, stage in enumerate(stage_list):
        # the stage grad-mask actually freezes the off-stage subtree
        # (zero grads keep adam state at zero -> bitwise-frozen params)
        gmask = stage_mask(params, stage) if stage else None
        for it in range(iters):
            g = si * iters + it   # global step: stages never collide
            if g < start:
                continue          # resume lands mid-stage, not at stage 1
            # learner -> actors: quantized weight sync (staleness-aware)
            packed = pack_weights(params, comm_bits)
            versions.push(packed)
            stale = versions.stale(max_lag - 1)
            payload, fp32_eq = sync_bytes(stale)
            total_sync_payload += payload
            key, sub = jax.random.split(key)
            params, opt, est, obs, ret, n_ep = iteration(
                params, opt, est, obs, stale, sub, gmask, alive)
            history.append(float(ret))
            if verbose and (it % log_every == 0 or it == iters - 1):
                sfx = f" [stage={stage}]" if stage else ""
                print(f"iter {it:4d}  return {float(ret):8.2f}  "
                      f"episodes {int(n_ep):4d}  "
                      f"sync {payload / 2**20:.2f} MiB "
                      f"(fp32 {fp32_eq / 2**20:.2f}){sfx}")
            if mgr and mgr.should_save(g):
                mgr.save(g, (params, opt, est, obs),
                         metadata={"stage": stage or "all",
                                   "stage_iter": it})
    if verbose:
        print(f"done in {time.time() - t0:.0f}s; "
              f"total sync payload {total_sync_payload / 2**20:.1f} MiB")
    if state_out is not None:
        state_out.update(env_state=est, obs=obs)
    return params, history



def value_eval(algo: str, env_name: str, params,
               n_envs: int = 16, n_steps: Optional[int] = None,
               actor_policy: Optional[str] = None, seed: int = 0,
               net: str = "mlp", frame_stack_k: int = 1,
               norm_stats: Optional[NormStats] = None):
    """Greedy-policy evaluation: (mean episode return, episode count).

    Runs the trained policy with exploration off for ``n_steps``
    (default: one full episode horizon plus slack) — the training-loop
    returns only count episodes that *complete inside a chunk*, which
    undercounts long-horizon envs; this is the clean measurement.

    ``net="conv"`` evaluates over the pixel pipeline with the running
    normalizer *frozen*: pass the training run's merged stats as
    ``norm_stats`` (see ``wrappers.norm_stats_of``/``merge_norm_stats``;
    None falls back to the identity transform).
    """
    if net == "conv":
        from repro.rl.envs.wrappers import init_norm_stats
        frozen = (norm_stats if norm_stats is not None
                  else init_norm_stats(make(env_name).obs_shape))
        env = build_env(env_name, net, frame_stack_k, norm_stats=frozen)
    else:
        env = build_env(env_name, net, frame_stack_k)
    spec = env.spec
    agent = make_value_agent(algo, spec, net=net)  # closures, no init
    policy = get_policy(actor_policy) if actor_policy else None
    n_steps = n_steps or spec.max_steps + spec.max_steps // 4

    @jax.jit
    def run(params, key):
        est, obs = init_envs(env, key, n_envs)

        def one(carry, _):
            est, o = carry
            a = agent.greedy(params, o, policy)
            est, nxt, r, d, tr, _ = jax.vmap(env.step)(est, a)
            return (est, nxt), (r, d | tr)

        (_, _), (rews, bounds) = jax.lax.scan(one, (est, obs), None,
                                              length=n_steps)
        return episode_returns_from(rews, bounds)

    ret, n_ep = run(params, jax.random.PRNGKey(seed + 17))
    return float(ret), int(n_ep)


def value_train(algo: str = "dqn", env_name: str = "cartpole",
                iters: int = 300, n_envs: int = 32, rollout_len: int = 8,
                actor_policy: Optional[str] = "fxp8", lr: float = 1e-3,
                comm_bits: int = 8, seed: int = 0,
                ckpt_dir: Optional[str] = None, save_every: int = 50,
                replay_capacity: int = 50_000, n_step: int = 3,
                updates_per_iter: int = 4, log_every: int = 20,
                verbose: bool = True,
                learn_start: Optional[int] = None, net: str = "mlp",
                frame_stack_k: int = 1,
                replay: str = "uniform", per_alpha: float = 0.6,
                per_beta0: float = 0.4,
                per_beta_iters: Optional[int] = None,
                tqc_drop: int = 0,
                state_out: Optional[dict] = None):
    """Off-policy value-based training (paper Fig. 2 split, replay
    flavour): the *quantized* behaviour actor collects ``rollout_len``
    steps per iteration into a truncation-aware n-step replay; the
    fp32 learner runs ``updates_per_iter`` sampled updates against
    polyak target networks.  Checkpoints capture params, targets,
    optimizer state, the replay buffer (pointers included) AND the env
    state (so wrapper carries like the Welford running-norm stats
    survive preemption), so a relaunch with the same command line
    resumes exactly.  ``state_out`` (optional dict) receives the final
    ``env_state``/``obs``/``replay`` state — e.g. to extract the
    normalizer stats for a frozen evaluation.

    ``replay`` picks the backend (:mod:`repro.rl.replay`): ``uniform``
    is the bit-exact historical buffer; ``per`` is sum-tree
    proportional prioritization — transitions insert at max priority,
    sampling follows ``(|td| + eps) ** per_alpha``, the losses weight
    each sample by its annealed-beta importance weight (``per_beta0``
    -> 1 over ``per_beta_iters`` iterations, default the whole run),
    and every TD update writes the fresh per-sample errors back into
    the tree.  ``tqc_drop`` (ddpg) truncates the top-k pooled target
    quantiles — see :func:`make_value_agent`.
    """
    if algo not in VALUE_ALGOS:
        raise ValueError(f"value_train drives {VALUE_ALGOS}, got "
                         f"{algo!r}; use rl_train for {ON_POLICY_ALGOS}")
    env = build_env(env_name, net, frame_stack_k)
    spec = env.spec
    key = jax.random.PRNGKey(seed)
    a_policy = get_policy(actor_policy) if actor_policy else None
    comm = comm_bits if a_policy else 32
    # epsilon anneals over the first half of the step budget
    decay = max((iters * rollout_len) // 2, 1)

    agent = make_value_agent(algo, spec, key, n_step=n_step,
                             eps_decay_steps=decay,
                             learn_start=learn_start, net=net,
                             tqc_drop=tqc_drop)
    cfg, params = agent.cfg, agent.params
    discrete = agent.discrete
    # fresh buffers, not an alias: params and target are both donated
    # to the jitted iteration, and a shared buffer cannot donate twice
    target = jax.tree.map(jnp.copy, params)
    if algo == "ddpg":
        opt = {"actor": adamw_init(params["actor"]),
               "critic": adamw_init(params["critic"])}
        rb = make_replay(replay, replay_capacity, spec.obs_shape,
                         spec.action_space.shape, jnp.float32,
                         alpha=per_alpha)
    else:
        opt = adamw_init(params)
        rb = make_replay(replay, replay_capacity, spec.obs_shape,
                         alpha=per_alpha)
    buf = rb.init()
    beta_iters = max(per_beta_iters if per_beta_iters is not None
                     else iters, 1)
    ocfg = AdamWConfig(weight_decay=0.0, max_grad_norm=10.0)
    sched = constant(lr)

    est, obs = init_envs(env, jax.random.PRNGKey(seed + 1), n_envs)
    start = 0
    mgr = None
    if ckpt_dir:
        mgr = CheckpointManager(ckpt_dir, keep=2, save_every=save_every)
        if mgr.latest_step() is not None:
            # flags are validated against the sidecar metadata BEFORE
            # the tree restore: a mismatched template (e.g. uniform
            # Replay vs a saved PER tree, scalar vs quantile critics)
            # must fail with these errors, not a missing-leaf KeyError
            md = mgr.metadata()
            md_net = str(md.get("net", net))
            if md_net != net:
                raise ValueError(
                    f"checkpoint in {ckpt_dir} was saved by --net "
                    f"{md_net!r}, not {net!r} — the torso family (and "
                    "the obs pipeline) differs; relaunch with the "
                    "original flags")
            md_env = str(md.get("env", env_name))
            if md_env != env_name:
                raise ValueError(
                    f"checkpoint in {ckpt_dir} was saved by --env "
                    f"{md_env!r}, not {env_name!r} — relaunch with the "
                    "original flags")
            md_algo = str(md.get("algo", ""))
            if md_algo != algo:
                raise ValueError(
                    f"checkpoint in {ckpt_dir} was saved by --algo "
                    f"{md_algo!r}, not {algo!r} — relaunch with the "
                    "original flags")
            md_replay = str(md.get("replay", "uniform"))
            if md_replay != replay:
                raise ValueError(
                    f"checkpoint in {ckpt_dir} was saved by --replay "
                    f"{md_replay!r}, not {replay!r} — the sampling "
                    "stream (and the PER tree state) is part of the "
                    "run; relaunch with the original flags")
            md_tqc = int(md.get("tqc_drop", 0))
            if md_tqc != tqc_drop:
                raise ValueError(
                    f"checkpoint in {ckpt_dir} was saved by --tqc-drop "
                    f"{md_tqc}, not {tqc_drop} — the critic head shape "
                    "differs (restore does not shape-check); relaunch "
                    "with the original flags")
            if replay == "per":
                # the priority exponent and beta schedule shape every
                # subsequent draw: a silent change would diverge from
                # the uninterrupted run's sampling stream
                for flag, have in (("per_alpha", per_alpha),
                                   ("per_beta0", per_beta0),
                                   ("per_beta_iters", beta_iters)):
                    saved = md.get(flag)
                    if saved is not None and float(saved) != float(have):
                        raise ValueError(
                            f"checkpoint in {ckpt_dir} was saved with "
                            f"--{flag.replace('_', '-')} {saved}, not "
                            f"{have} — the prioritized sampling stream "
                            "depends on it; relaunch with the original "
                            "flags")
            (params, target, opt, buf, est, obs), md = mgr.restore(
                (params, target, opt, buf, est, obs))
            start = int(md.get("it", md.get("step", 0))) + 1
            if verbose:
                print(f"resumed at iter {start} "
                      f"(replay size {int(replay_size(buf))})")

    # the donation contract (threaded replay/target/env state) lives
    # with the step itself — see repro.rl.train_steps
    iteration = make_value_iteration(
        env, agent, rb, a_policy, sched, ocfg, algo=algo,
        rollout_len=rollout_len, updates_per_iter=updates_per_iter,
        per_beta0=per_beta0, beta_iters=beta_iters)

    history = []
    total_sync_payload = 0
    t0 = time.time()
    if verbose:
        pol = actor_policy if a_policy else "fp32"
        rep = (f"per(alpha={per_alpha}, beta {per_beta0}->1/"
               f"{beta_iters}it)" if rb.prioritized else "uniform")
        print(f"{algo} on {spec.name}: {n_envs} envs x {rollout_len} "
              f"steps/iter, n_step={cfg.n_step}, {pol} behaviour actor, "
              f"{rep} replay")
    for it in range(start, iters):
        # only the behaviour net ships to the fleet (ddpg: the actor
        # alone — syncing the twin critics would triple the payload)
        packed = pack_weights(agent.behaviour_subtree(params), comm)
        payload, _ = sync_bytes(packed)
        total_sync_payload += payload
        # key derived from the iteration index, not a running split:
        # a resumed run at iteration k draws the same stream the
        # uninterrupted run would have (sequential splits would replay
        # the stream from 0 after every preemption)
        sub = jax.random.fold_in(key, it)
        params, target, opt, buf, est, obs, ret, n_ep = iteration(
            params, target, opt, buf, packed, est, obs, sub,
            jnp.asarray(it))
        history.append(float(ret))
        if verbose and (it % log_every == 0 or it == iters - 1):
            print(f"iter {it:4d}  return {float(ret):8.2f}  "
                  f"episodes {int(n_ep):4d}  "
                  f"replay {int(replay_size(buf)):6d}")
        if mgr and mgr.should_save(it):
            # env/net/frame_stack/n_envs make the checkpoint
            # self-describing for the serving loader
            # (repro.serve.load_policy rebuilds the net and — for conv
            # policies — the env-state template from these alone)
            md_out = {"algo": algo, "it": it, "replay": replay,
                      "tqc_drop": tqc_drop, "env": env_name, "net": net,
                      "frame_stack": frame_stack_k, "n_envs": n_envs,
                      "n_step": n_step,
                      "actor_policy": actor_policy or "fp32"}
            if rb.prioritized:
                md_out.update(per_alpha=per_alpha, per_beta0=per_beta0,
                              per_beta_iters=beta_iters)
            mgr.save(it, (params, target, opt, buf, est, obs),
                     metadata=md_out)
    if verbose:
        print(f"done in {time.time() - t0:.0f}s; "
              f"total sync payload {total_sync_payload / 2**20:.1f} MiB")
    if state_out is not None:
        state_out.update(env_state=est, obs=obs, replay=buf)
    return params, history


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--algo", default="ppo",
                    choices=list(ON_POLICY_ALGOS + VALUE_ALGOS))
    ap.add_argument("--env", default="cartpole",
                    choices=list(registered()))
    ap.add_argument("--agent", default="mlp", choices=["mlp", "hrl"])
    ap.add_argument("--net", default="mlp", choices=list(NETS),
                    help="conv = Q-Conv pixel stem over the running-"
                         "normalized (+ frame-stacked) image pipeline")
    ap.add_argument("--frame-stack", type=int, default=1,
                    help="stack the last K frames (conv net only)")
    ap.add_argument("--iters", type=int, default=None,
                    help="default: 40 (on-policy) / 300 (value-based)")
    ap.add_argument("--n-envs", type=int, default=32)
    ap.add_argument("--rollout-len", type=int, default=None,
                    help="default: 128 (on-policy) / 8 (value-based)")
    ap.add_argument("--actor-policy", default="fxp8")
    ap.add_argument("--fp32-actors", action="store_true")
    ap.add_argument("--comm-bits", type=int, default=8)
    ap.add_argument("--max-lag", type=int, default=1)
    ap.add_argument("--lr", type=float, default=None,
                    help="default: 3e-3 (on-policy) / 1e-3 (value-based)")
    ap.add_argument("--two-stage", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=None)
    ap.add_argument("--mesh", default="host",
                    choices=["host", "production"])
    ap.add_argument("--mesh-devices", type=int, default=None,
                    help="restrict the host mesh to the first N devices")
    # value-based knobs (--algo dqn|qrdqn|ddpg)
    ap.add_argument("--replay-capacity", type=int, default=50_000)
    ap.add_argument("--replay", default="uniform",
                    choices=list(REPLAY_KINDS),
                    help="replay backend: uniform circular, or per "
                         "(sum-tree proportional prioritization)")
    ap.add_argument("--per-alpha", type=float, default=0.6,
                    help="PER priority exponent (0=uniform, 1=greedy)")
    ap.add_argument("--per-beta0", type=float, default=0.4,
                    help="initial PER importance-weight exponent")
    ap.add_argument("--per-beta-iters", type=int, default=None,
                    help="iterations to anneal beta to 1 over "
                         "(default: the whole run)")
    ap.add_argument("--tqc-drop", type=int, default=0,
                    help="ddpg: drop the top-k pooled target quantiles "
                         "(TQC truncation; >0 switches the twin "
                         "critics to 25-quantile heads)")
    ap.add_argument("--n-step", type=int, default=3)
    ap.add_argument("--updates-per-iter", type=int, default=4)
    ap.add_argument("--learn-start", type=int, default=None,
                    help="min replay size before updates (default: the "
                         "algo config's, 256)")
    args = ap.parse_args(argv)
    actor_policy = None if args.fp32_actors else args.actor_policy
    if args.algo not in VALUE_ALGOS and (args.replay != "uniform"
                                         or args.tqc_drop):
        raise ValueError(
            "--replay/--tqc-drop configure the value-based replay "
            f"loop; --algo {args.algo} is on-policy — drop these flags")
    if args.replay != "per" and (args.per_alpha != 0.6
                                 or args.per_beta0 != 0.4
                                 or args.per_beta_iters is not None):
        raise ValueError(
            "--per-alpha/--per-beta0/--per-beta-iters configure the "
            "prioritized backend and would be silently ignored — add "
            "--replay per (or drop them)")
    if args.algo in VALUE_ALGOS:
        if args.two_stage or args.agent == "hrl":
            raise ValueError("--two-stage/--agent hrl are on-policy "
                             "(PPO) features; value-based algos drive "
                             "the MLP nets")
        if (args.mesh != "host" or args.mesh_devices is not None
                or args.max_lag != 1):
            raise ValueError(
                "--mesh/--mesh-devices/--max-lag configure the sharded "
                "on-policy driver; the value-based loop is single-host "
                "— drop these flags (sharded value collection is a "
                "ROADMAP follow-up)")
        value_train(args.algo, args.env,
                    iters=args.iters if args.iters is not None else 300,
                    n_envs=args.n_envs,
                    rollout_len=(args.rollout_len
                                 if args.rollout_len is not None else 8),
                    actor_policy=actor_policy,
                    lr=args.lr if args.lr is not None else 1e-3,
                    comm_bits=args.comm_bits, ckpt_dir=args.ckpt_dir,
                    save_every=(args.save_every
                                if args.save_every is not None else 50),
                    replay_capacity=args.replay_capacity,
                    n_step=args.n_step,
                    updates_per_iter=args.updates_per_iter,
                    learn_start=args.learn_start, net=args.net,
                    frame_stack_k=args.frame_stack,
                    replay=args.replay, per_alpha=args.per_alpha,
                    per_beta0=args.per_beta0,
                    per_beta_iters=args.per_beta_iters,
                    tqc_drop=args.tqc_drop)
    else:
        rl_train(args.env, args.agent,
                 args.iters if args.iters is not None else 40,
                 args.n_envs,
                 args.rollout_len if args.rollout_len is not None
                 else 128,
                 actor_policy,
                 args.lr if args.lr is not None else 3e-3,
                 args.comm_bits, args.max_lag,
                 two_stage=args.two_stage, ckpt_dir=args.ckpt_dir,
                 save_every=(args.save_every
                             if args.save_every is not None else 10),
                 mesh_kind=args.mesh, mesh_devices=args.mesh_devices,
                 algo=args.algo, net=args.net,
                 frame_stack_k=args.frame_stack)


if __name__ == "__main__":
    main()
