"""Q-Actor RL training driver: quantized actors + full-precision
learner + int8 weight sync (the paper's Fig. 2 system).

    PYTHONPATH=src python -m repro.launch.rl_train --env cartpole \
        --iters 40 --actor-policy fxp8 [--agent hrl] [--two-stage]

The actor fleet is shard_map'd over the data axes of a real device mesh
(``--mesh host`` by default — whatever this host exposes, e.g. 8 CPU
devices under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``;
``--mesh production`` for the 16x16 pod shape).  Each device dequantizes
the broadcast int8 weight sync locally and rolls ``n_envs/n_devices``
environments; per-device trajectories come back as one global batch
whose per-device slots carry a liveness mask into the PPO loss (and out
of the advantage statistics).  This synchronous driver always reports
every slot alive — an async aggregator only has to flip mask bits to
drop a straggler, it never has to reshape the loss.  The learner
updates with PPO.  Checkpoints make the loop restart-safe (including
mid-stage restarts of ``--two-stage`` runs).
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs.e2hrl import HRLConfig
from repro.core.policy import get_policy
from repro.distributed.sharding import data_axis_size
from repro.launch.mesh import describe, make_host_mesh, make_production_mesh
from repro.models import hrl
from repro.nn.module import unbox
from repro.optim import AdamWConfig, adamw_init, adamw_update, constant
from repro.rl import PPOConfig, batch_from_traj, init_envs
from repro.rl.actor_learner import (VersionBuffer, collect_sharded,
                                    fleet_mask, pack_weights, sync_bytes)
from repro.rl.dists import distribution_for
from repro.rl.envs import Environment, make, registered
from repro.rl.envs.spaces import head_dim
from repro.rl.nets import mlp_ac_apply, mlp_ac_init
from repro.rl.ppo import minibatch_epochs, stage_mask
from repro.rl.rollout import episode_returns


def make_agent(agent: str, env: Environment, key,
               policy_name: Optional[str]):
    spec = env.spec
    if agent == "mlp":
        if len(spec.obs_shape) != 1:
            raise ValueError(
                f"{spec.name} has obs shape {spec.obs_shape}; wrap with "
                "envs.wrappers.flatten_observation for the mlp agent "
                "or use --agent hrl")
        params = unbox(mlp_ac_init(key, spec.obs_shape[0],
                                   head_dim(spec.action_space)))
        apply_fn = mlp_ac_apply
        return params, apply_fn
    if len(spec.obs_shape) != 3:
        raise ValueError(
            f"{spec.name} has obs shape {spec.obs_shape}; the hrl agent "
            "needs image (H, W, C) observations — use --agent mlp")
    cfg = HRLConfig(obs_shape=spec.obs_shape, n_actions=spec.n_actions)
    params = unbox(hrl.init(key, cfg))

    def apply_fn(p, obs, policy=None):
        logits, value, _ = hrl.apply(p, obs, cfg, policy)
        return logits, value

    return params, apply_fn


def build_mesh(mesh_kind: str = "host",
               mesh_devices: Optional[int] = None):
    if mesh_kind == "production":
        if mesh_devices is not None:
            raise ValueError("--mesh-devices restricts the host mesh "
                             "only; the production mesh shape is fixed")
        return make_production_mesh()
    if mesh_kind == "host":
        return make_host_mesh(mesh_devices)
    raise ValueError(f"unknown mesh kind {mesh_kind!r} "
                     "(expected 'host' or 'production')")


def rl_train(env_name: str = "cartpole", agent: str = "mlp",
             iters: int = 40, n_envs: int = 32, rollout_len: int = 128,
             actor_policy: Optional[str] = "fxp8", lr: float = 3e-3,
             comm_bits: int = 8, max_lag: int = 1, seed: int = 0,
             two_stage: bool = False, ckpt_dir: Optional[str] = None,
             save_every: int = 10, mesh_kind: str = "host",
             mesh_devices: Optional[int] = None,
             log_every: int = 5, verbose: bool = True):
    if two_stage and agent != "hrl":
        raise ValueError("--two-stage trains the HRL sub-goal curriculum "
                         "and requires --agent hrl")
    env = make(env_name)
    dist = distribution_for(env.action_space)
    key = jax.random.PRNGKey(seed)
    params, apply_fn = make_agent(agent, env, key, actor_policy)
    a_policy = get_policy(actor_policy) if actor_policy else None

    if mesh_kind == "host" and mesh_devices is None:
        # default: the largest device prefix that divides n_envs, so
        # odd host device counts degrade to fewer slots instead of
        # failing (explicit --mesh-devices keeps the hard error below)
        mesh_devices = len(jax.devices())
        while mesh_devices > 1 and n_envs % mesh_devices != 0:
            mesh_devices -= 1
    mesh = build_mesh(mesh_kind, mesh_devices)
    n_slots = data_axis_size(mesh)
    if n_envs % n_slots != 0:
        raise ValueError(f"--n-envs {n_envs} must be divisible by the "
                         f"mesh's {n_slots} data slot(s)")
    if verbose:
        print(f"{describe(mesh)}: {n_slots} actor slot(s) x "
              f"{n_envs // n_slots} envs")

    opt = adamw_init(params)
    ocfg = AdamWConfig(weight_decay=0.0, max_grad_norm=0.5)
    pcfg = PPOConfig()
    sched = constant(lr)
    stage_list = (["action", "subgoal"] if two_stage else [None])
    stage_names = [s or "all" for s in stage_list]
    start = 0
    mgr = None
    if ckpt_dir:
        mgr = CheckpointManager(ckpt_dir, keep=2, save_every=save_every)
        if mgr.latest_step() is not None:
            (params, opt), md = mgr.restore((params, opt))
            md_stage = str(md.get("stage", "all"))
            if md_stage not in stage_names:
                raise ValueError(
                    f"checkpoint in {ckpt_dir} was saved in stage "
                    f"{md_stage!r} but this run's stages are "
                    f"{stage_names} — relaunch with the original "
                    "--two-stage/--agent flags")
            # the checkpoint holds post-update state for its step, so
            # training continues at the NEXT step (re-running the saved
            # one would apply its optimizer update twice); the global
            # step is rebuilt from the recorded (stage, stage_iter) so
            # a changed --iters cannot land the resume in the wrong
            # stage
            it = int(md.get("stage_iter", md.get("step", 0)))
            # clamp for a shrunken --iters: the recorded stage already
            # met the new budget, so continue at the next stage rather
            # than skipping past the end of the whole run
            start = stage_names.index(md_stage) * iters + min(it + 1,
                                                              iters)
            if verbose:
                print(f"resumed at global iter {start} "
                      f"(stage {md_stage}, iter {it} done)")

    est, obs = init_envs(env, jax.random.PRNGKey(seed + 1), n_envs,
                         mesh=mesh)
    versions = VersionBuffer(max_lag)
    learner_apply = lambda p, o: apply_fn(p, o, None)
    # synchronous driver: every device delivers; the mask still flows
    # through the loss so an async aggregator only has to flip bits
    alive = jnp.ones((n_slots,), bool)

    total_sync_payload = 0

    @jax.jit
    def iteration(params, opt, est, obs, packed, key, gmask, alive):
        k1, k2 = jax.random.split(key)
        res = collect_sharded(packed, env, apply_fn, a_policy, k1, est,
                              obs, rollout_len, mesh, dist)
        mask = fleet_mask(alive, n_envs // n_slots)
        batch = batch_from_traj(res.traj, res.last_value, pcfg,
                                actor_mask=mask)

        def opt_step(p, s, g):
            p, s, _ = adamw_update(g, s, p, sched, ocfg)
            return p, s

        params, opt, stats = minibatch_epochs(
            k2, params, opt, batch, learner_apply, pcfg, opt_step,
            grad_mask=gmask, dist=dist)
        ret, n_ep = episode_returns(res.traj)
        return params, opt, res.final_env, res.final_obs, ret, n_ep

    history = []
    t0 = time.time()
    for si, stage in enumerate(stage_list):
        # the stage grad-mask actually freezes the off-stage subtree
        # (zero grads keep adam state at zero -> bitwise-frozen params)
        gmask = stage_mask(params, stage) if stage else None
        for it in range(iters):
            g = si * iters + it   # global step: stages never collide
            if g < start:
                continue          # resume lands mid-stage, not at stage 1
            # learner -> actors: quantized weight sync (staleness-aware)
            packed = pack_weights(params, comm_bits)
            versions.push(packed)
            stale = versions.stale(max_lag - 1)
            payload, fp32_eq = sync_bytes(stale)
            total_sync_payload += payload
            key, sub = jax.random.split(key)
            params, opt, est, obs, ret, n_ep = iteration(
                params, opt, est, obs, stale, sub, gmask, alive)
            history.append(float(ret))
            if verbose and (it % log_every == 0 or it == iters - 1):
                sfx = f" [stage={stage}]" if stage else ""
                print(f"iter {it:4d}  return {float(ret):8.2f}  "
                      f"episodes {int(n_ep):4d}  "
                      f"sync {payload / 2**20:.2f} MiB "
                      f"(fp32 {fp32_eq / 2**20:.2f}){sfx}")
            if mgr and mgr.should_save(g):
                mgr.save(g, (params, opt),
                         metadata={"stage": stage or "all",
                                   "stage_iter": it})
    if verbose:
        print(f"done in {time.time() - t0:.0f}s; "
              f"total sync payload {total_sync_payload / 2**20:.1f} MiB")
    return params, history


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--env", default="cartpole",
                    choices=list(registered()))
    ap.add_argument("--agent", default="mlp", choices=["mlp", "hrl"])
    ap.add_argument("--iters", type=int, default=40)
    ap.add_argument("--n-envs", type=int, default=32)
    ap.add_argument("--rollout-len", type=int, default=128)
    ap.add_argument("--actor-policy", default="fxp8")
    ap.add_argument("--fp32-actors", action="store_true")
    ap.add_argument("--comm-bits", type=int, default=8)
    ap.add_argument("--max-lag", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--two-stage", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=10)
    ap.add_argument("--mesh", default="host",
                    choices=["host", "production"])
    ap.add_argument("--mesh-devices", type=int, default=None,
                    help="restrict the host mesh to the first N devices")
    args = ap.parse_args(argv)
    rl_train(args.env, args.agent, args.iters, args.n_envs,
             args.rollout_len,
             None if args.fp32_actors else args.actor_policy,
             args.lr, args.comm_bits, args.max_lag,
             two_stage=args.two_stage, ckpt_dir=args.ckpt_dir,
             save_every=args.save_every, mesh_kind=args.mesh,
             mesh_devices=args.mesh_devices)


if __name__ == "__main__":
    main()
