"""Production mesh construction (function, not module constant — importing
this module never touches jax device state)."""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_devices: Optional[int] = None):
    """Whatever this host has (1 CPU device in the container, N under
    ``--xla_force_host_platform_device_count=N``) — used by the runnable
    examples, the RL training loop and the throughput benchmarks.

    ``n_devices`` restricts the mesh to the first N devices (so a single
    benchmark process can sweep device counts).
    """
    devs = jax.devices()
    n = len(devs) if n_devices is None else n_devices
    if not 1 <= n <= len(devs):
        raise ValueError(f"n_devices={n_devices} but this host exposes "
                         f"{len(devs)} device(s)")
    return Mesh(np.asarray(devs[:n]).reshape(n, 1), ("data", "model"))


def describe(mesh) -> str:
    return (f"mesh {dict(zip(mesh.axis_names, mesh.devices.shape, strict=True))} "
            f"({mesh.devices.size} devices)")
