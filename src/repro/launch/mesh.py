"""Production mesh construction (function, not module constant — importing
this module never touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host has (1 CPU device in the container) — used by
    the runnable examples and the smoke training loop."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def describe(mesh) -> str:
    return (f"mesh {dict(zip(mesh.axis_names, mesh.devices.shape))} "
            f"({mesh.devices.size} devices)")
