import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    # The CPU backend hoists whole-stack dtype converts out of the
    # backward while-loop (LICM), inflating the apparent live-buffer
    # size by O(L * activations); TPU buffer assignment does not pay
    # this, so disable the pass for a faithful memory estimate.
    "--xla_disable_hlo_passes=while-loop-invariant-code-motion")

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes, print memory/cost analysis, emit roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b \
        --shape train_4k [--multi-pod] [--policy w8a8_bf16] [--json out]

The two XLA_FLAGS lines above MUST stay the first statements: jax locks
the device count at first backend init.
"""
import argparse      # noqa: E402
import json          # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs.registry import ARCHS, get_arch          # noqa: E402
from repro.configs.shapes import SHAPES, shape_applicable   # noqa: E402
from repro.core.policy import get_policy                    # noqa: E402
from repro.launch import hlo_analysis                       # noqa: E402
from repro.launch.mesh import describe, make_production_mesh  # noqa: E402
from repro.launch.roofline import roofline_terms            # noqa: E402
from repro.launch.steps import lower_cell                   # noqa: E402


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             policy_name: str = "qforce8",
             dtype=jnp.float32, verbose: bool = True) -> dict:
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    skip = shape_applicable(cfg, shape)
    if skip:
        return {"arch": arch, "shape": shape_name, "status": skip}

    mesh = make_production_mesh(multi_pod=multi_pod)
    policy = get_policy(policy_name)
    t0 = time.time()
    lowered, meta = lower_cell(cfg, shape, mesh, policy, dtype)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = hlo_analysis.memory_stats(compiled)
    hlo = compiled.as_text()
    cost = hlo_analysis.cost_terms(compiled, hlo)
    roof = roofline_terms(cfg, shape, mesh, cost)
    result = {
        "arch": arch, "shape": shape_name, "status": "ok",
        "mesh": dict(zip(mesh.axis_names,
                         (int(s) for s in mesh.devices.shape), strict=True)),
        "step": meta["step"], "policy": policy_name,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": mem, "cost": cost, "roofline": roof,
        "hlo_ops": hlo_analysis.op_histogram(hlo),
    }
    if verbose:
        print(f"== {arch} x {shape_name} on {describe(mesh)} "
              f"[{meta['step']}, {policy_name}] ==")
        print(f"   lower {t_lower:.1f}s  compile {t_compile:.1f}s")
        print(f"   memory/device: "
              f"args {mem['argument_size_in_bytes']/2**30:.2f} GiB  "
              f"temps {mem['temp_size_in_bytes']/2**30:.2f} GiB  "
              f"total {mem['total_bytes']/2**30:.2f} GiB")
        print(f"   HLO flops/device {cost['flops']:.3e}  "
              f"bytes/device {cost['bytes']:.3e}  "
              f"collective bytes/device {cost['collective_bytes']:.3e}")
        print(f"   roofline: compute {roof['t_compute']:.2e}s  "
              f"memory {roof['t_memory']:.2e}s  "
              f"collective {roof['t_collective']:.2e}s  "
              f"-> bound: {roof['bound']}  "
              f"(model-flops util ceiling "
              f"{100 * roof['useful_flops_frac']:.0f}%)")
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help=f"one of {sorted(ARCHS)} or 'all'")
    ap.add_argument("--shape", default="all",
                    help=f"one of {sorted(SHAPES)} or 'all'")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--policy", default="qforce8")
    ap.add_argument("--dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--json", default=None, help="write results here")
    args = ap.parse_args(argv)

    archs = sorted(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    dtype = jnp.float32 if args.dtype == "float32" else jnp.bfloat16

    results, failures = [], 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    results.append(run_cell(arch, shape, mp,
                                            args.policy, dtype))
                except Exception as e:   # a failure here is a real bug
                    failures += 1
                    results.append({"arch": arch, "shape": shape,
                                    "multi_pod": mp, "status": "FAIL",
                                    "error": repr(e)[:500]})
                    print(f"!! FAIL {arch} x {shape} "
                          f"(multi_pod={mp}): {e}", file=sys.stderr)

    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1)
    ok = sum(1 for r in results if r["status"] == "ok")
    skipped = sum(1 for r in results if r["status"].startswith("skip"))
    print(f"\n{ok} ok / {skipped} skipped / {failures} FAILED")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
