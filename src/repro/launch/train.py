"""Fault-tolerant LM training driver.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --steps 50 --smoke [--policy w8a8] [--ckpt-dir /tmp/ck]

``--smoke`` runs the reduced config on the host mesh (the container's
CPU); the full configs are dry-run-only per the assignment.  The loop
is restart-safe: auto-resume from the newest checkpoint, atomic saves,
and a data pipeline that is a pure function of the step index.
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.registry import get_arch
from repro.core.policy import get_policy
from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, batch_at, place
from repro.distributed.sharding import make_shardings
from repro.launch.mesh import describe, make_host_mesh
from repro.launch.steps import (abstract_opt_state, abstract_params,
                                batch_shardings, make_train_step)
from repro.models.registry import input_specs, model_for
from repro.nn.module import axes_of, unbox
from repro.optim import AdamWConfig, adamw_init, warmup_cosine


def train(arch: str, steps: int = 50, smoke: bool = True,
          policy_name: Optional[str] = "w8a8", seq_len: int = 128,
          batch: int = 8, ckpt_dir: Optional[str] = None,
          save_every: int = 20, lr: float = 3e-4,
          log_every: int = 10, seed: int = 0):
    cfg = get_arch(arch)
    if smoke:
        cfg = cfg.reduced()
    policy = get_policy(policy_name) if policy_name else None
    mesh = make_host_mesh()
    model = model_for(cfg)
    print(f"training {cfg.name} on {describe(mesh)} "
          f"policy={policy_name}")

    # init (or resume)
    boxed = model.init(jax.random.PRNGKey(seed), cfg)
    params = unbox(boxed)
    p_shard = make_shardings(params, axes_of(boxed), mesh)
    opt_state = adamw_init(params)
    start_step = 0
    mgr = None
    if ckpt_dir:
        mgr = CheckpointManager(ckpt_dir, keep=3, save_every=save_every)
        if mgr.latest_step() is not None:
            (params, opt_state), start_step = mgr.restore(
                (params, opt_state))[0], mgr.latest_step()
            print(f"resumed from step {start_step}")

    dcfg = DataConfig(vocab=cfg.vocab, seq_len=seq_len,
                      global_batch=batch, seed=seed)
    sched = warmup_cosine(lr, max(steps // 10, 1), steps)
    step_fn = make_train_step(cfg, mesh, policy,
                              AdamWConfig(weight_decay=0.0),
                              schedule=sched)
    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

    t0 = time.time()
    tokens_per_batch = seq_len * batch
    losses = []
    for step in range(start_step, steps):
        data = place(batch_at(dcfg, step), mesh)
        params, opt_state, stats = jit_step(params, opt_state, data)
        if step % log_every == 0 or step == steps - 1:
            loss = float(stats["loss"])
            losses.append(loss)
            dt = time.time() - t0
            print(f"step {step:5d}  loss {loss:8.4f}  "
                  f"gnorm {float(stats['grad_norm']):7.3f}  "
                  f"{(step - start_step + 1) * tokens_per_batch / max(dt, 1e-9):8.0f} tok/s")
        if mgr and mgr.should_save(step):
            mgr.save(step, (params, opt_state))
    if mgr:
        mgr.save(steps, (params, opt_state))
    return params, losses


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--policy", default="w8a8")
    ap.add_argument("--fp32", action="store_true",
                    help="disable quantization (baseline)")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=20)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args(argv)
    train(args.arch, args.steps, args.smoke,
          None if args.fp32 else args.policy, args.seq_len, args.batch,
          args.ckpt_dir, args.save_every, args.lr)


if __name__ == "__main__":
    main()
