"""Quantized linear / embedding layers — every matmul goes via q_matmul."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.fxp import QTensor
from repro.core.policy import QuantPolicy
from repro.core.qmatmul import q_matmul
from repro.nn.module import (Axes, KeySeq, Param, lecun_init, normal_init,
                             param, zeros_init)


def linear_init(key, d_in: int, d_out: int, *, axes: Axes,
                bias: bool = True, init=None, dtype=jnp.float32):
    ks = KeySeq(key)
    p = {"w": param(ks(), (d_in, d_out), axes, init or lecun_init(), dtype)}
    if bias:
        p["b"] = param(ks(), (d_out,), (axes[-1],) if axes else None,
                       zeros_init(), dtype)
    return p


def linear_apply(p, x, policy: Optional[QuantPolicy] = None):
    y = q_matmul(x, p["w"], policy)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def embedding_init(key, vocab: int, d_model: int, *, axes: Axes,
                   init=None, dtype=jnp.float32):
    return {"emb": param(key, (vocab, d_model), axes,
                         init or normal_init(0.02), dtype)}


def embedding_apply(p, ids, policy: Optional[QuantPolicy] = None):
    """Token lookup; int8 QTensor tables are gathered then dequantized
    (so the HBM read is 1 byte/elem — the serving win)."""
    emb = p["emb"]
    if isinstance(emb, QTensor):
        rows = jnp.take(emb.qvalue, ids, axis=0)
        scale = emb.scale  # [1, d] per-channel or [1,1]
        return rows.astype(jnp.float32) * scale
    out = jnp.take(emb, ids, axis=0)
    return out


def embedding_attend(p, x, policy: Optional[QuantPolicy] = None):
    """Tied LM head: logits = x @ emb^T."""
    emb = p["emb"]
    if isinstance(emb, QTensor):
        emb = emb.deq(x.dtype)
    return q_matmul(x, emb.T, policy)
