"""Multi-head / grouped-query attention with quantized projections and
(optionally) an int8-quantized KV cache.

All four projections route through q_matmul (the Q-MAC path).  The KV
cache supports ``kv_bits=8``: payloads are stored int8 with per
(token, head) scales — for 32k-context decode this halves/quarters the
dominant HBM term (see EXPERIMENTS.md §Perf), the direct LM analogue of
the paper's quantized-actor inference.

Supports: causal, bidirectional (encoder), sliding-window (SWA),
cross-attention (enc-dec), GQA/MQA, qk-norm, QKV biases, RoPE.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.fxp import fxp_dtype, fxp_qmax
from repro.core.policy import QuantPolicy
from repro.nn.linear import linear_apply, linear_init
from repro.nn.module import KeySeq, lecun_init, ones_init, param
from repro.nn.norm import rmsnorm_apply
from repro.nn.rotary import apply_rope

Array = jax.Array
NEG_INF = -1e9


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    causal: bool = True
    window: Optional[int] = None        # sliding-window size (SWA)
    rope: bool = True
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    qk_norm: bool = False
    cross: bool = False                 # cross-attention (enc-dec)
    # q-chunked (flash-style) attention: bounds the live score block to
    # [B, H, q_chunk, T] instead of [B, H, S, T].  Non-divisible or
    # small S falls back to the direct path.
    q_chunk: int = 512


def attention_init(key, cfg: AttnConfig, dtype=jnp.float32):
    ks = KeySeq(key)
    H, Hk, D, dm = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    p = {
        "wq": linear_init(ks(), dm, H * D, axes=("d_model", "heads"),
                          bias=cfg.qkv_bias, dtype=dtype),
        # kv projection: logical axis "kv_heads" — sharding rules decide
        # whether it maps to the model axis (divisible) or is replicated
        "wk": linear_init(ks(), dm, Hk * D, axes=("d_model", "kv_heads"),
                          bias=cfg.qkv_bias, dtype=dtype),
        "wv": linear_init(ks(), dm, Hk * D, axes=("d_model", "kv_heads"),
                          bias=cfg.qkv_bias, dtype=dtype),
        "wo": linear_init(ks(), H * D, dm, axes=("heads", "d_model"),
                          bias=False, dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = {"scale": param(ks(), (D,), (None,), ones_init(),
                                      dtype)}
        p["k_norm"] = {"scale": param(ks(), (D,), (None,), ones_init(),
                                      dtype)}
    return p


# ---------------------------------------------------------------------------
# KV cache (optionally int8)
# ---------------------------------------------------------------------------

def init_cache(batch: int, max_len: int, n_kv: int, head_dim: int,
               kv_bits: int = 32, dtype=jnp.float32, ring: bool = False):
    """Allocate a fixed-capacity KV cache for one layer.

    ``ring=True`` makes it a circular buffer of ``max_len`` slots (used
    for sliding-window attention where max_len == window << sequence):
    a per-slot absolute-position array drives masking.  This is what
    keeps the long_500k decode cells sub-quadratic in memory.
    """
    if kv_bits < 32:
        dt = fxp_dtype(kv_bits)
        cache = {
            "k": jnp.zeros((batch, max_len, n_kv, head_dim), dt),
            "v": jnp.zeros((batch, max_len, n_kv, head_dim), dt),
            "k_scale": jnp.zeros((batch, max_len, n_kv, 1), jnp.float32),
            "v_scale": jnp.zeros((batch, max_len, n_kv, 1), jnp.float32),
        }
    else:
        cache = {
            "k": jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
            "v": jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
        }
    if ring:
        cache["pos"] = jnp.full((batch, max_len), -1, jnp.int32)
    return cache


def _quant_kv(x: Array, bits: int):
    qmax = fxp_qmax(bits)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(fxp_dtype(bits))
    return q, scale.astype(jnp.float32)


def cache_update(cache, k_new: Array, v_new: Array, index,
                 kv_bits: int = 32):
    """Write k/v for positions [index, index+S) (decode: S == 1)."""
    if "pos" in cache:
        return _ring_update(cache, k_new, v_new, index, kv_bits)
    if kv_bits < 32:
        qk, sk = _quant_kv(k_new, kv_bits)
        qv, sv = _quant_kv(v_new, kv_bits)
        return {
            "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], qk,
                                                     index, axis=1),
            "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], qv,
                                                     index, axis=1),
            "k_scale": jax.lax.dynamic_update_slice_in_dim(
                cache["k_scale"], sk, index, axis=1),
            "v_scale": jax.lax.dynamic_update_slice_in_dim(
                cache["v_scale"], sv, index, axis=1),
        }
    return {
        "k": jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k_new.astype(cache["k"].dtype), index, axis=1),
        "v": jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v_new.astype(cache["v"].dtype), index, axis=1),
    }


def _ring_update(cache, k_new: Array, v_new: Array, index,
                 kv_bits: int = 32):
    """Circular-buffer write: position p lands in slot p % capacity."""
    B, S = k_new.shape[0], k_new.shape[1]
    cap = cache["k"].shape[1]
    pos = index + jnp.arange(S)
    slots = jnp.mod(pos, cap)                      # [S]
    out = dict(cache)
    if kv_bits < 32:
        qk, sk = _quant_kv(k_new, kv_bits)
        qv, sv = _quant_kv(v_new, kv_bits)
        out["k"] = cache["k"].at[:, slots].set(qk)
        out["v"] = cache["v"].at[:, slots].set(qv)
        out["k_scale"] = cache["k_scale"].at[:, slots].set(sk)
        out["v_scale"] = cache["v_scale"].at[:, slots].set(sv)
    else:
        out["k"] = cache["k"].at[:, slots].set(
            k_new.astype(cache["k"].dtype))
        out["v"] = cache["v"].at[:, slots].set(
            v_new.astype(cache["v"].dtype))
    out["pos"] = cache["pos"].at[:, slots].set(
        jnp.broadcast_to(pos[None, :], (B, S)).astype(jnp.int32))
    return out


def cache_kv(cache, dtype=jnp.float32) -> Tuple[Array, Array]:
    """Read the cache back as fp arrays (dequantizing if int8)."""
    if "k_scale" in cache:
        k = cache["k"].astype(dtype) * cache["k_scale"].astype(dtype)
        v = cache["v"].astype(dtype) * cache["v_scale"].astype(dtype)
        return k, v
    return cache["k"].astype(dtype), cache["v"].astype(dtype)


# ---------------------------------------------------------------------------
# core attention math
# ---------------------------------------------------------------------------

def _mask_bias(q_pos: Array, k_pos: Array, causal: bool,
               window: Optional[int], valid_len=None) -> Array:
    """Additive mask [*, S, T] from absolute positions."""
    i = q_pos[..., :, None]
    j = k_pos[..., None, :]
    ok = jnp.ones(jnp.broadcast_shapes(i.shape, j.shape), bool)
    if causal:
        ok &= j <= i
    if window is not None:
        ok &= (i - j) < window
    if valid_len is not None:
        ok &= j < valid_len
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def gqa_attend(q: Array, k: Array, v: Array, bias: Array,
               compute_dtype=jnp.float32) -> Array:
    """Grouped einsum path (decode: S small, KV read un-repeated).

    q:[B,S,H,D] k,v:[B,T,Hk,D] bias:[B?,S,T] -> [B,S,H,D]."""
    B, S, H, D = q.shape
    Hk = k.shape[2]
    G = H // Hk
    qg = q.reshape(B, S, Hk, G, D).astype(compute_dtype)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg,
                        k.astype(compute_dtype)) / math.sqrt(D)
    scores = scores.astype(jnp.float32) + bias[:, None, None]
    w = jax.nn.softmax(scores, axis=-1).astype(compute_dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v.astype(compute_dtype))
    return out.reshape(B, S, H, D)


def attend_full(q: Array, k: Array, v: Array, q_pos: Array,
                k_pos: Array, *, causal: bool, window: Optional[int],
                compute_dtype=jnp.float32,
                q_chunk: Optional[int] = 512) -> Array:
    """Train/prefill attention: KV repeated to H heads (TP-shardable on
    the head axis) and Q processed in chunks so the live score block is
    [B, H, q_chunk, T] — never the full [B, H, S, S] (which at 32k
    context would not fit any memory).  The mask is built on the fly
    from positions; no [S, T] bias tensor is ever materialized beyond
    one chunk.

    q: [B,S,H,D]  k,v: [B,T,Hk,D]  q_pos: [B,S]  k_pos: [B,T].
    """
    from repro.distributed.sharding import constrain
    B, S, H, D = q.shape
    T, Hk = k.shape[1], k.shape[2]
    G = H // Hk
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    k = constrain(k.astype(compute_dtype), ("batch", None, "heads", None))
    v = constrain(v.astype(compute_dtype), ("batch", None, "heads", None))
    q = constrain(q.astype(compute_dtype), ("batch", None, "heads", None))
    scale = 1.0 / math.sqrt(D)

    def block(q_blk: Array, pos_blk: Array) -> Array:
        scores = jnp.einsum("bshd,bthd->bhst", q_blk, k) * scale
        scores = constrain(scores, ("batch", "heads", None, None))
        bias = _mask_bias(pos_blk, k_pos, causal, window)
        scores = scores.astype(jnp.float32) + bias[:, None]
        w = jax.nn.softmax(scores, axis=-1).astype(compute_dtype)
        out = jnp.einsum("bhst,bthd->bshd", w, v)
        return constrain(out, ("batch", None, "heads", None))

    if q_chunk is None or S <= q_chunk or S % q_chunk != 0:
        return block(q, q_pos)

    n = S // q_chunk
    # pin the stack layout: chunk dim UNSHARDED, heads on "model".
    # Under SP the incoming q carries a 16-way seq sharding; reshaping
    # S -> (n, q_chunk) would otherwise dump it onto the chunk dim, and
    # every backward dynamic_slice of the saved stack then all-gathers
    # the WHOLE stack (once per chunk iteration).
    q_blks = constrain(
        jnp.moveaxis(q.reshape(B, n, q_chunk, H, D), 1, 0),
        (None, "batch", None, "heads", None))
    pos_blks = jnp.moveaxis(q_pos.reshape(B, n, q_chunk), 1, 0)
    # remat each chunk: backward recomputes its scores instead of the
    # scan stacking [n, B, H, q_chunk, T] softmax weights
    blk = jax.checkpoint(block)
    out = jax.lax.map(lambda xs: blk(*xs), (q_blks, pos_blks))
    return jnp.moveaxis(out, 0, 1).reshape(B, S, H, D)


def _project_qkv(p, x, kv_src, cfg: AttnConfig, policy):
    B = x.shape[0]
    H, Hk, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = linear_apply(p["wq"], x, policy).reshape(B, -1, H, D)
    k = linear_apply(p["wk"], kv_src, policy).reshape(B, -1, Hk, D)
    v = linear_apply(p["wv"], kv_src, policy).reshape(B, -1, Hk, D)
    if cfg.qk_norm:
        q = rmsnorm_apply(p["q_norm"], q)
        k = rmsnorm_apply(p["k_norm"], k)
    return q, k, v


def attention_apply(p, x: Array, cfg: AttnConfig,
                    policy: Optional[QuantPolicy] = None, *,
                    positions: Optional[Array] = None,
                    encoder_out: Optional[Array] = None,
                    cache=None, cache_index=None, kv_bits: int = 32,
                    return_cache: bool = False):
    """Full-sequence attention (train / prefill).

    If ``return_cache`` and not cross-attention, also returns the filled
    KV cache (quantized per kv_bits) for subsequent decode steps.
    """
    B, S, _ = x.shape
    kv_src = encoder_out if cfg.cross else x
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    q, k, v = _project_qkv(p, x, kv_src, cfg, policy)
    if cfg.rope and not cfg.cross:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif cfg.rope and cfg.cross:
        q = apply_rope(q, positions, cfg.rope_theta)
    T = k.shape[1]
    k_pos = jnp.broadcast_to(jnp.arange(T)[None, :], (B, T))
    cdt = policy.compute_dtype if policy else jnp.float32
    out = attend_full(q, k, v, positions, k_pos,
                      causal=cfg.causal and not cfg.cross,
                      window=cfg.window, compute_dtype=cdt,
                      q_chunk=cfg.q_chunk)
    out = linear_apply(p["wo"], out.reshape(B, S, -1), policy)
    if return_cache and not cfg.cross:
        cache = init_cache(B, T if cache is None else cache["k"].shape[1],
                           cfg.n_kv_heads, cfg.head_dim, kv_bits,
                           k.dtype) if cache is None else cache
        cache = cache_update(cache, k, v, 0, kv_bits)
        return out, cache
    return out


def attention_decode(p, x: Array, cfg: AttnConfig, cache,
                     cache_index: Array,
                     policy: Optional[QuantPolicy] = None, *,
                     encoder_out: Optional[Array] = None,
                     cross_cache=None, kv_bits: int = 32):
    """One-token decode step against a fixed-capacity cache.

    x: [B, 1, d_model]; cache_index: scalar int32 (current length).
    Returns (out [B,1,d_model], updated cache).
    """
    B = x.shape[0]
    positions = jnp.full((B, 1), cache_index, jnp.int32)
    cdt = policy.compute_dtype if policy else jnp.float32
    if cfg.cross:
        # cross-attention: cache holds the (static) encoder K/V
        k, v = cache_kv(cross_cache, cdt)
        q, _, _ = _project_qkv(p, x, x, cfg, policy)
        if cfg.rope:
            q = apply_rope(q, positions, cfg.rope_theta)
        T = k.shape[1]
        k_pos = jnp.broadcast_to(jnp.arange(T)[None, :], (B, T))
        bias = _mask_bias(positions, k_pos, causal=False, window=None)
        out = gqa_attend(q, k, v, bias, cdt)
        out = linear_apply(p["wo"], out.reshape(B, 1, -1), policy)
        return out, cache
    q, k_new, v_new = _project_qkv(p, x, x, cfg, policy)
    if cfg.rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k_new = apply_rope(k_new, positions, cfg.rope_theta)
    cache = cache_update(cache, k_new, v_new, cache_index, kv_bits)
    k, v = cache_kv(cache, cdt)
    T = k.shape[1]
    if "pos" in cache:
        # ring buffer: mask from stored absolute positions
        k_pos = cache["pos"]                               # [B, T]
        ok = (k_pos >= 0) & (k_pos <= cache_index)
        if cfg.window is not None:
            ok &= k_pos > (cache_index - cfg.window)
        bias = jnp.where(ok, 0.0, NEG_INF)[:, None, :].astype(
            jnp.float32)                                   # [B, 1, T]
    else:
        k_pos = jnp.broadcast_to(jnp.arange(T)[None, :], (B, T))
        bias = _mask_bias(positions, k_pos, causal=True,
                          window=cfg.window,
                          valid_len=cache_index + 1)
    out = gqa_attend(q, k, v, bias, cdt)
    out = linear_apply(p["wo"], out.reshape(B, 1, -1), policy)
    return out, cache
