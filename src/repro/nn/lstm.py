"""Q-LSTM layer: quantized gate matmuls + V-ACT activations.

Three execution paths with identical semantics:
  * policy backend "ref"/"xla": q_matmul gates + core.vact activations,
  * policy backend "pallas" at 8-bit: the fused kernels/qlstm cell,
  * fp32 policy: plain LSTM (the E2HRL FxP32 baseline).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.policy import QuantPolicy, cordic_iterations
from repro.core.qmatmul import q_matmul, quantize_rowwise
from repro.core.fxp import quantize
from repro.core.vact import activation
from repro.nn.module import KeySeq, lecun_init, param, zeros_init


def lstm_init(key, d_in: int, d_hidden: int, dtype=jnp.float32):
    ks = KeySeq(key)
    return {
        "w_x": param(ks(), (d_in, 4 * d_hidden), ("d_model", "d_ff"),
                     lecun_init(), dtype),
        "w_h": param(ks(), (d_hidden, 4 * d_hidden), ("d_model", "d_ff"),
                     lecun_init(), dtype),
        "b": param(ks(), (4 * d_hidden,), ("d_ff",), zeros_init(), dtype),
    }


def lstm_cell(p, x, h, c, policy: Optional[QuantPolicy] = None):
    """One step.  x: [B, Din]; h, c: [B, H] -> (h', c')."""
    H = h.shape[-1]
    if (policy is not None and policy.backend == "pallas"
            and policy.w_bits == 8 and policy.a_bits == 8):
        from repro.kernels.qlstm import ops as qlstm_ops
        qx, sx_arr = quantize_rowwise(x, 8)
        qh, sh_arr = quantize_rowwise(h, 8)
        # the fused kernel takes per-tensor activation scales
        sx = jnp.max(sx_arr)
        sh = jnp.max(sh_arr)
        qx = jnp.clip(jnp.round(x / sx), -127, 127).astype(jnp.int8)
        qh = jnp.clip(jnp.round(h / sh), -127, 127).astype(jnp.int8)
        qw, sw = quantize(p["w_x"], 8, channel_axis=1)
        qu, su = quantize(p["w_h"], 8, channel_axis=1)
        return qlstm_ops.qlstm_cell(
            qx, sx, qh, sh, qw, sw.reshape(1, -1), qu, su.reshape(1, -1),
            p["b"], c, n_iters=cordic_iterations(policy))
    gates = (q_matmul(x, p["w_x"], policy)
             + q_matmul(h, p["w_h"], policy) + p["b"])
    i = activation(gates[..., 0 * H:1 * H], "sigmoid", policy)
    f = activation(gates[..., 1 * H:2 * H], "sigmoid", policy)
    g = activation(gates[..., 2 * H:3 * H], "tanh", policy)
    o = activation(gates[..., 3 * H:4 * H], "sigmoid", policy)
    c_new = f * c + i * g
    h_new = activation(c_new, "tanh", policy) * o
    return h_new, c_new


def lstm_apply(p, xs, policy: Optional[QuantPolicy] = None,
               state: Optional[Tuple] = None):
    """xs: [B, S, Din] -> (hs [B, S, H], (h_T, c_T))."""
    B, S, _ = xs.shape
    H = p["b"].shape[-1] // 4 if not hasattr(p["b"], "value") \
        else p["b"].value.shape[-1] // 4
    if state is None:
        h = jnp.zeros((B, H), xs.dtype)
        c = jnp.zeros((B, H), jnp.float32)
    else:
        h, c = state

    def step(carry, x_t):
        h, c = carry
        h, c = lstm_cell(p, x_t, h, c, policy)
        return (h, c), h

    (h, c), hs = jax.lax.scan(step, (h, c),
                              jnp.swapaxes(xs, 0, 1))
    return jnp.swapaxes(hs, 0, 1), (h, c)
