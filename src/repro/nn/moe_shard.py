"""shard_map MoE dispatch — explicit EP / TP-within-expert execution.

XLA's SPMD partitioner cannot partition the capacity-buffer scatter of
a global-view MoE dispatch (it falls back to replicating the [E, C, D]
buffers — 100+ GiB/device at 1M-token steps).  Here the data movement
is *written down* with shard_map + lax collectives instead of inferred:

  EP  (E % model == 0, qwen3-moe):
      local dispatch -> all_to_all over "model" (split experts, concat
      capacity) -> each device runs its E/m experts over m*C_loc slots
      -> all_to_all back -> local combine.
  TPE (E < model, mixtral):
      experts replicated, d_ff model-sharded: local dispatch -> local
      partial FFN -> psum over "model" -> local combine.

Expert weights arrive FSDP-sharded on d_model ("data") and are
all-gathered inside the body — the same per-layer weight traffic the
dense layers get from the SPMD partitioner.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.policy import QuantPolicy
from repro.core.qmatmul import q_batched_matmul
from repro.core.vact import activation

Array = jax.Array


def _local_dispatch(x_rep, e_flat, n_experts: int, capacity: int):
    """Group this shard's (token, k) pairs by expert id — GATHER
    formulation: slot (e, c) pulls sorted-token starts[e]+c.  The index
    tensors stay [E, C] / [Tk] (a few MB); the scatter formulation's
    backward materializes u32/f32 [E, C, D] index/operand buffers
    (~4 GB each at 1M-token steps, measured 3x step traffic).

    x_rep: [Tk_loc, D] -> (buf [E, C, D], pos_c [Tk_loc], keep)."""
    tk = e_flat.shape[0]
    order = jnp.argsort(e_flat)
    sorted_e = e_flat[order]
    counts = jnp.bincount(e_flat, length=n_experts)
    starts = jnp.cumsum(counts) - counts
    ranks = jnp.arange(tk) - starts[sorted_e]
    pos = jnp.zeros_like(ranks).at[order].set(ranks)
    keep = pos < capacity
    pos_c = jnp.where(keep, pos, capacity)

    slot = starts[:, None] + jnp.arange(capacity)[None]      # [E, C]
    valid = jnp.arange(capacity)[None] < counts[:, None]     # [E, C]
    token = order[jnp.clip(slot, 0, tk - 1)]                 # [E, C]
    buf = x_rep[token] * valid[..., None].astype(x_rep.dtype)
    return buf, pos_c, keep


def _expert_ffn(buf, w_gate, w_up, w_down, policy, act):
    g = q_batched_matmul(buf, w_gate, policy)
    u = q_batched_matmul(buf, w_up, policy)
    h = activation(g, act, policy) * u
    return q_batched_matmul(h, w_down, policy)


def moe_shard_map(x, router_w, w_gate, w_up, w_down, mesh, *,
                  top_k: int, capacity_factor: float,
                  policy: Optional[QuantPolicy], act: str) -> Array:
    """x: [B, S, D] (batch-sharded over the data axes) -> [B, S, D]."""
    B, S, D = x.shape
    E = w_gate.shape[0]
    dax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_data = 1
    for a in dax:
        n_data *= mesh.shape[a]
    m = mesh.shape.get("model", 1)
    ep = E % m == 0 and E >= m and m > 1
    t_loc = (B * S) // n_data
    cap = max(int(math.ceil(t_loc * top_k / E * capacity_factor)), 4)

    from repro.core.fxp import QTensor, as_dense
    serve = isinstance(w_gate, QTensor)      # PTQ int8 weights loaded
    fsdp = (dax if not serve else None) or None
    if ep:
        w_in_spec = P("model", fsdp, None)
        w_out_spec = P("model", None, fsdp)
    else:
        w_in_spec = P(None, fsdp, "model")
        w_out_spec = P(None, "model", fsdp)
    rw_spec = P(fsdp, None)

    def leaf_spec(w, qv_spec):
        """QTensor weights carry their own scale spec (broadcast dims
        unsharded)."""
        if isinstance(w, QTensor):
            sspec = P(*[qv_spec[i] if w.scale.shape[i] > 1 else None
                        for i in range(w.scale.ndim)])
            return QTensor(qv_spec, sspec, w.bits)
        return qv_spec

    def body(xb, rw, wg, wu, wd):
        b_loc = xb.shape[0]
        xf = xb.reshape(-1, D)
        cdt = policy.compute_dtype if policy else jnp.float32
        if serve:
            rw = as_dense(rw, jnp.float32)
            wg, wu, wd = (as_dense(t, cdt) for t in (wg, wu, wd))
        elif dax:
            # FSDP gather of the d_model shards (per-layer, like dense)
            wg = jax.lax.all_gather(wg, dax, axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, dax, axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, dax, axis=2, tiled=True)
            rw = jax.lax.all_gather(rw, dax, axis=0, tiled=True)

        # routing: fp32, local (replicated across "model")
        logits = xf.astype(jnp.float32) @ rw.astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, gate_idx = jax.lax.top_k(probs, top_k)
        gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)
        e_flat = gate_idx.reshape(-1)
        w_flat = gate_vals.reshape(-1)
        x_rep = jnp.repeat(xf, top_k, axis=0)

        buf, pos_c, keep = _local_dispatch(x_rep, e_flat, E, cap)

        if ep:
            # [E, C, D] --(split experts, concat slots)--> [E/m, mC, D]
            buf = jax.lax.all_to_all(buf, "model", split_axis=0,
                                     concat_axis=1, tiled=True)
            out_buf = _expert_ffn(buf, wg, wu, wd, policy, act)
            # [E/m, mC, D] --(split slots, concat experts)--> [E, C, D]
            out_buf = jax.lax.all_to_all(out_buf, "model", split_axis=1,
                                         concat_axis=0, tiled=True)
        else:
            # TPE: d_ff sharded -> partial d_model products, reduce
            out_buf = _expert_ffn(buf, wg, wu, wd, policy, act)
            out_buf = jax.lax.psum(out_buf, "model")

        gathered = out_buf[e_flat, jnp.minimum(pos_c, cap - 1)]
        gathered = jnp.where(keep[:, None], gathered, 0.0)
        weighted = gathered * w_flat[:, None].astype(gathered.dtype)
        out = weighted.reshape(-1, top_k, D).sum(axis=1)
        return out.reshape(b_loc, S, D).astype(xb.dtype)

    from repro.distributed.sharding import shard_map as _sm
    fn = _sm(body, mesh=mesh,
             in_specs=(P(dax if dax else None, None, None),
                       leaf_spec(router_w, rw_spec),
                       leaf_spec(w_gate, w_in_spec),
                       leaf_spec(w_up, w_in_spec),
                       leaf_spec(w_down, w_out_spec)),
             out_specs=P(dax if dax else None, None, None),
             check_replication=False)
    return fn(x, router_w, w_gate, w_up, w_down)


def shardable(x, mesh, n_experts: int) -> bool:
    """Can this call drop to the shard_map path?"""
    if mesh is None or "model" not in mesh.axis_names:
        return False
    dax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_data = 1
    for a in dax:
        n_data *= mesh.shape[a]
    B = x.shape[0]
    return B % max(n_data, 1) == 0
