"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Real-gated linear recurrent unit:
    r_t = sigmoid(W_r x_t)            (recurrence gate)
    i_t = sigmoid(W_i x_t)            (input gate)
    a_t = a ^ (c * r_t)               (per-channel learned decay, c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The full-sequence path uses ``jax.lax.associative_scan`` (log-depth —
this is the sub-quadratic property that lets recurrentgemma run the
long_500k shape).  Decode is a single fused step.

The surrounding recurrent block is: linear_in -> causal conv1d ->
RG-LRU -> (gated by GeLU branch) -> linear_out, all via q_matmul.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.policy import QuantPolicy
from repro.core.qmatmul import q_matmul
from repro.core.vact import activation
from repro.nn.conv import causal_conv1d_apply, causal_conv1d_init
from repro.nn.linear import linear_apply, linear_init
from repro.nn.module import KeySeq, normal_init, param

_C = 8.0
_MAX_SQRT_GRADIENT = 1000.0


def rglru_init(key, width: int, dtype=jnp.float32):
    ks = KeySeq(key)
    return {
        "w_r": linear_init(ks(), width, width, axes=("d_inner", "d_inner"),
                           bias=True, dtype=dtype),
        "w_i": linear_init(ks(), width, width, axes=("d_inner", "d_inner"),
                           bias=True, dtype=dtype),
        # Lambda parametrized so a = sigmoid(L) starts near 0.9-0.999
        "L": param(ks(), (width,), ("d_inner",),
                   lambda k, s, d: jax.random.uniform(k, s, d, 2.0, 6.0)),
    }


def _gates(p, x, policy):
    r = jax.nn.sigmoid(q_matmul(x, p["w_r"]["w"], policy)
                       + p["w_r"]["b"])
    i = jax.nn.sigmoid(q_matmul(x, p["w_i"]["w"], policy)
                       + p["w_i"]["b"])
    log_a_base = -_C * jax.nn.softplus(p["L"].astype(jnp.float32))
    log_a = log_a_base * r.astype(jnp.float32)          # [B,S,W]
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) with the Griffin stability clamp
    mult = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12, 1.0))
    gated_x = x.astype(jnp.float32) * i.astype(jnp.float32) * mult
    return a, gated_x


def rglru_apply(p, x, policy: Optional[QuantPolicy] = None,
                state: Optional[jnp.ndarray] = None):
    """x: [B, S, W].  With state [B, W]: one decode step (S==1)."""
    a, b = _gates(p, x, policy)
    if state is not None:
        h = a[:, 0] * state + b[:, 0]
        return h[:, None, :].astype(x.dtype), h
    # associative scan over the linear recurrence h = a h_prev + b
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_s, h_s = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h_s.astype(x.dtype), h_s[:, -1]


def recurrent_block_init(key, d_model: int, width: int,
                         conv_width: int = 4, dtype=jnp.float32):
    ks = KeySeq(key)
    return {
        "lin_x": linear_init(ks(), d_model, width,
                             axes=("d_model", "d_inner"), bias=False,
                             dtype=dtype),
        "lin_y": linear_init(ks(), d_model, width,
                             axes=("d_model", "d_inner"), bias=False,
                             dtype=dtype),
        "conv": causal_conv1d_init(ks(), width, conv_width, dtype),
        "rglru": rglru_init(ks(), width, dtype),
        "lin_out": linear_init(ks(), width, d_model,
                               axes=("d_inner", "d_model"), bias=False,
                               dtype=dtype),
    }


def recurrent_block_apply(p, x, policy: Optional[QuantPolicy] = None,
                          state: Optional[dict] = None):
    """Griffin recurrent block.  state: {"conv": ..., "rglru": ...}."""
    gate = activation(linear_apply(p["lin_y"], x, policy), "gelu", policy)
    u = linear_apply(p["lin_x"], x, policy)
    if state is not None:
        u, conv_state = causal_conv1d_apply(p["conv"], u, state["conv"])
        h, rg_state = rglru_apply(p["rglru"], u, policy, state["rglru"])
        out = linear_apply(p["lin_out"], h * gate, policy)
        return out, {"conv": conv_state, "rglru": rg_state}
    u = causal_conv1d_apply(p["conv"], u)
    h, _ = rglru_apply(p["rglru"], u, policy)
    return linear_apply(p["lin_out"], h * gate, policy)


def recurrent_block_init_state(batch: int, width: int,
                               conv_width: int = 4):
    return {
        "conv": jnp.zeros((batch, conv_width - 1, width), jnp.float32),
        "rglru": jnp.zeros((batch, width), jnp.float32),
    }
