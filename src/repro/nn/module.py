"""Minimal functional module system (no flax in this container).

Convention: every layer provides ``<name>_init(key, ...) -> params`` and
``<name>_apply(params, x, ...) -> out``.  Parameters are plain pytrees of
arrays *boxed* in :class:`Param`, which carries the logical sharding axes
(MaxText-style logical axis names).  Before jit/optimization, ``unbox``
strips the boxes; ``axes_of`` extracts the parallel axes tree used by
``distributed.sharding`` to build NamedShardings.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
Axes = Optional[Tuple[Optional[str], ...]]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Param:
    """A parameter leaf annotated with logical sharding axes."""

    value: Any
    axes: Axes = None

    def tree_flatten(self):
        return (self.value,), (self.axes,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux[0])

    @property
    def shape(self):
        return self.value.shape


def is_param(x) -> bool:
    return isinstance(x, Param)


def unbox(tree):
    """Strip Param boxes -> plain array pytree (what jit/optimizers see)."""
    return jax.tree.map(lambda p: p.value if is_param(p) else p, tree,
                        is_leaf=is_param)


def axes_of(tree):
    """Same structure as ``unbox(tree)`` with axes tuples as leaves."""
    return jax.tree.map(lambda p: p.axes if is_param(p) else None, tree,
                        is_leaf=is_param)


def rebox(values, axes):
    """Inverse of unbox given an axes tree of identical structure."""
    return jax.tree.map(lambda v, a: Param(v, a), values, axes,
                        is_leaf=lambda x: x is None)


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def normal_init(stddev: float = 0.02) -> Callable:
    def f(key, shape, dtype=jnp.float32):
        return jax.random.normal(key, shape, dtype) * jnp.asarray(
            stddev, dtype)
    return f


def lecun_init() -> Callable:
    def f(key, shape, dtype=jnp.float32):
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        std = math.sqrt(1.0 / fan_in)
        return jax.random.normal(key, shape, dtype) * jnp.asarray(std, dtype)
    return f


def he_init() -> Callable:
    def f(key, shape, dtype=jnp.float32):
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        std = math.sqrt(2.0 / fan_in)
        return jax.random.normal(key, shape, dtype) * jnp.asarray(std, dtype)
    return f


def zeros_init() -> Callable:
    return lambda key, shape, dtype=jnp.float32: jnp.zeros(shape, dtype)


def ones_init() -> Callable:
    return lambda key, shape, dtype=jnp.float32: jnp.ones(shape, dtype)


def param(key, shape: Sequence[int], axes: Axes,
          init: Optional[Callable] = None, dtype=jnp.float32) -> Param:
    init = init or lecun_init()
    assert axes is None or len(axes) == len(shape), (shape, axes)
    return Param(init(key, tuple(shape), dtype), axes)


class KeySeq:
    """Deterministic key dispenser: ks = KeySeq(key); k1 = ks()."""

    def __init__(self, key: Array):
        self._key = key

    def __call__(self) -> Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def split(self, n: int):
        self._key, *subs = jax.random.split(self._key, n + 1)
        return subs


def count_params(tree) -> int:
    from repro.core.fxp import QTensor
    total = 0
    for leaf in jax.tree.leaves(unbox(tree),
                                is_leaf=lambda l: isinstance(l, QTensor)):
        if isinstance(leaf, QTensor):
            total += int(jnp.size(leaf.qvalue))
        else:
            total += int(jnp.size(leaf))
    return total
