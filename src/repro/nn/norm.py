"""RMSNorm / LayerNorm (fp32 statistics, policy-dtype output)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.module import ones_init, param, zeros_init


def rmsnorm_init(key, d: int, dtype=jnp.float32):
    return {"scale": param(key, (d,), (None,), ones_init(), dtype)}


def rmsnorm_apply(p, x, eps: float = 1e-6):
    """fp32 accumulation without fp32 elementwise upcasts.

    The sum of squares runs through an einsum with
    preferred_element_type=f32 (a dot, so XLA cannot "helpfully" hoist
    a whole-tensor bf16->f32 convert of the scan-saved activations out
    of the backward loop — that hoist alone costs O(L*B*S*d) live
    bytes).  The normalization multiply stays in the input dtype; the
    rsqrt scalar is fp32 throughout.
    """
    dt = x.dtype
    d = x.shape[-1]
    ss = jnp.einsum("...d,...d->...", x, x,
                    preferred_element_type=jnp.float32)
    inv = jax.lax.rsqrt(ss[..., None] / d + eps)
    return x * inv.astype(dt) * p["scale"].astype(dt)


def layernorm_init(key, d: int, dtype=jnp.float32):
    return {"scale": param(key, (d,), (None,), ones_init(), dtype),
            "bias": param(key, (d,), (None,), zeros_init(), dtype)}


def layernorm_apply(p, x, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * (var + eps) ** -0.5
    return (out * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(dt)
