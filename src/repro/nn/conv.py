"""Convolutions: quantized 2D conv (Q-Conv, the RL agent's vision stem)
and causal depthwise 1D conv (mamba2 / recurrentgemma stems).

Q-Conv follows the paper: stride-2 replaces max-pooling, ReLU after.
At int8 weights *and* activations the conv runs as a true integer
program — per-pixel int8 activations against per-out-channel int8
filters, tap-wise Q-MAC contractions with a fused dequant + bias
(+ ReLU) epilogue (``repro.kernels.qconv``; Pallas kernel when
``policy.backend == "pallas"``, tap-wise ``dot_general`` otherwise;
see docs/kernels.md).  The quantization grids are exactly the ones the
fake-quant path uses (``fake_quant_rowwise`` per pixel,
``fake_quant(..., channel_axis=3)`` per out-channel), so the packed
serving path stays bit-compatible with training-time eval.  Wider
policies fall back to fake-quantized operands on the XLA conv.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.fxp import dequantize, fake_quant, fake_quant_rowwise
from repro.core.fxp import quantize, QTensor, as_dense
from repro.core.policy import QuantPolicy
from repro.core.qmatmul import quantize_rowwise
from repro.core.vact import activation
from repro.kernels.qconv import ops as qconv_ops
from repro.nn.module import KeySeq, he_init, param, zeros_init


def conv2d_init(key, c_in: int, c_out: int, kernel: int,
                dtype=jnp.float32):
    ks = KeySeq(key)
    return {
        "w": param(ks(), (kernel, kernel, c_in, c_out),
                   (None, None, None, "d_ff"), he_init(), dtype),
        "b": param(ks(), (c_out,), ("d_ff",), zeros_init(), dtype),
    }


def _raw_conv(x, w, stride: int, padding: str):
    """fp NHWC/HWIO conv — fallback + the integer path's STE backward."""
    dn = jax.lax.conv_dimension_numbers(x.shape, w.shape,
                                        ("NHWC", "HWIO", "NHWC"))
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), padding, dimension_numbers=dn)


def _use_integer_conv(policy: Optional[QuantPolicy], w) -> bool:
    """True when the conv can run as a real int8 program: quantized
    activations at <= 8 bits against int8-representable weights, on a
    backend with an integer lowering (the ref backend keeps the
    fake-quant ops visible for inspection)."""
    if policy is None or not policy.quantized_a or policy.a_bits > 8:
        return False
    if policy.backend not in ("xla", "pallas"):
        return False
    if isinstance(w, QTensor):
        return w.bits <= 8
    return policy.quantized_w and policy.w_bits <= 8


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _qconv(policy, stride, padding, fuse_relu, x, w, b):
    out, _ = _qconv_fwd(policy, stride, padding, fuse_relu, x, w, b)
    return out


def _qconv_fwd(policy, stride, padding, fuse_relu, x, w, b):
    qw, sw = quantize(w, policy.w_bits, channel_axis=3)
    qx, sx = quantize_rowwise(x, policy.a_bits)
    out = qconv_ops.qconv2d_i8(
        qx, sx, qw, sw.reshape(-1), b.astype(jnp.float32),
        stride=stride, padding=padding, fuse_relu=fuse_relu,
        kernel=policy.backend == "pallas")
    # STE residuals: the dequantized operands the integer program saw
    return out, (dequantize(qx, sx, x.dtype), dequantize(qw, sw, w.dtype),
                 b)


def _qconv_bwd(policy, stride, padding, fuse_relu, res, g):
    x_dq, w_dq, b = res

    def fp_ref(x, w, b):
        out = _raw_conv(x.astype(jnp.float32), w.astype(jnp.float32),
                        stride, padding) + b.astype(jnp.float32)
        return jnp.maximum(out, 0.0) if fuse_relu else out

    _, vjp = jax.vjp(fp_ref, x_dq, w_dq, b)
    return vjp(g)


_qconv.defvjp(_qconv_fwd, _qconv_bwd)


def conv2d_apply(p, x, *, stride: int = 1, padding: str = "SAME",
                 policy: Optional[QuantPolicy] = None,
                 fuse_relu: bool = False):
    """x: [B, H, W, C] -> [B, H', W', C'].

    With an int8-capable ``policy`` (quantized activations and weights
    at <= 8 bits, xla/pallas backend) this dispatches to the integer
    Q-Conv program — packed ``QTensor`` weights go straight to the
    kernel, fp weights go through the straight-through-estimator
    wrapper so training still differentiates.  Otherwise operands are
    fake-quantized (when the policy asks) and fed to the XLA conv.
    """
    if _use_integer_conv(policy, p["w"]):
        if isinstance(p["w"], QTensor):
            qx, sx = quantize_rowwise(x, policy.a_bits)
            return qconv_ops.qconv2d_i8(
                qx, sx, p["w"].qvalue, p["w"].scale.reshape(-1),
                p["b"].astype(jnp.float32), stride=stride,
                padding=padding, fuse_relu=fuse_relu,
                kernel=policy.backend == "pallas")
        return _qconv(policy, stride, padding, fuse_relu,
                      x, as_dense(p["w"]), p["b"])
    w = as_dense(p["w"])
    if policy is not None and policy.quantized_w \
            and not isinstance(p["w"], QTensor):
        w = fake_quant(w, policy.w_bits, channel_axis=3)
    if policy is not None and policy.quantized_a:
        x = fake_quant_rowwise(x, policy.a_bits)
    out = _raw_conv(
        x.astype(policy.compute_dtype if policy else jnp.float32),
        w.astype(policy.compute_dtype if policy else jnp.float32),
        stride, padding)
    out = out + p["b"].astype(out.dtype)
    return jnp.maximum(out, 0.0) if fuse_relu else out


def qconv_block(p, x, *, stride: int = 2,
                policy: Optional[QuantPolicy] = None):
    """Paper's Q-Conv block: stride-2 conv (replaces pooling) + ReLU.

    On the integer path the ReLU rides in the kernel epilogue and only
    the V-ACT requantization step runs outside; elsewhere the ReLU goes
    through ``activation`` as before.  Both orders are equivalent
    (ReLU-then-requant == fused-ReLU-then-requant, elementwise).
    """
    if _use_integer_conv(policy, p["w"]):
        out = conv2d_apply(p, x, stride=stride, policy=policy,
                           fuse_relu=True)
        return activation(out, "identity", policy)
    return activation(conv2d_apply(p, x, stride=stride, policy=policy),
                      "relu", policy)


def causal_conv1d_init(key, channels: int, width: int = 4,
                       dtype=jnp.float32):
    ks = KeySeq(key)
    return {
        "w": param(ks(), (width, channels), (None, "d_inner"),
                   he_init(), dtype),
        "b": param(ks(), (channels,), ("d_inner",), zeros_init(), dtype),
    }


def causal_conv1d_apply(p, x, state=None):
    """Depthwise causal conv.  x: [B, S, C].

    With ``state`` ([B, width-1, C], the trailing inputs) this performs
    one decode step (S == 1) and returns (out, new_state).
    """
    w, b = as_dense(p["w"]), p["b"]
    width = w.shape[0]
    if state is not None:
        window = jnp.concatenate([state, x], axis=1)     # [B, width, C]
        out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                         w.astype(jnp.float32)) + b
        return out[:, None, :].astype(x.dtype), window[:, 1:]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        pad.astype(jnp.float32)[:, :, :],
        w.astype(jnp.float32)[:, None, :],   # [W, 1, C] depthwise
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1])
    return (out + b).astype(x.dtype)
