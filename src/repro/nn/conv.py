"""Convolutions: quantized 2D conv (Q-Conv, the RL agent's vision stem)
and causal depthwise 1D conv (mamba2 / recurrentgemma stems).

Q-Conv follows the paper: stride-2 replaces max-pooling, ReLU after.
Weights/activations are fake-quantized per policy (im2col+Q-MAC would
be the TPU kernel; XLA already lowers conv to MXU convolutions, so we
quantize operands and let XLA fuse — documented adaptation).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.fxp import fake_quant, fake_quant_rowwise
from repro.core.fxp import QTensor, as_dense
from repro.core.policy import QuantPolicy
from repro.core.vact import activation
from repro.nn.module import KeySeq, he_init, param, zeros_init


def conv2d_init(key, c_in: int, c_out: int, kernel: int,
                dtype=jnp.float32):
    ks = KeySeq(key)
    return {
        "w": param(ks(), (kernel, kernel, c_in, c_out),
                   (None, None, None, "d_ff"), he_init(), dtype),
        "b": param(ks(), (c_out,), ("d_ff",), zeros_init(), dtype),
    }


def conv2d_apply(p, x, *, stride: int = 1, padding: str = "SAME",
                 policy: Optional[QuantPolicy] = None):
    """x: [B, H, W, C] -> [B, H', W', C']."""
    w = as_dense(p["w"])
    if policy is not None and policy.quantized_w \
            and not isinstance(p["w"], QTensor):
        w = fake_quant(w, policy.w_bits, channel_axis=3)
    if policy is not None and policy.quantized_a:
        x = fake_quant_rowwise(x, policy.a_bits)
    dn = jax.lax.conv_dimension_numbers(x.shape, w.shape,
                                        ("NHWC", "HWIO", "NHWC"))
    out = jax.lax.conv_general_dilated(
        x.astype(policy.compute_dtype if policy else jnp.float32),
        w.astype(policy.compute_dtype if policy else jnp.float32),
        (stride, stride), padding, dimension_numbers=dn)
    return out + p["b"].astype(out.dtype)


def qconv_block(p, x, *, stride: int = 2,
                policy: Optional[QuantPolicy] = None):
    """Paper's Q-Conv block: stride-2 conv (replaces pooling) + ReLU."""
    return activation(conv2d_apply(p, x, stride=stride, policy=policy),
                      "relu", policy)


def causal_conv1d_init(key, channels: int, width: int = 4,
                       dtype=jnp.float32):
    ks = KeySeq(key)
    return {
        "w": param(ks(), (width, channels), (None, "d_inner"),
                   he_init(), dtype),
        "b": param(ks(), (channels,), ("d_inner",), zeros_init(), dtype),
    }


def causal_conv1d_apply(p, x, state=None):
    """Depthwise causal conv.  x: [B, S, C].

    With ``state`` ([B, width-1, C], the trailing inputs) this performs
    one decode step (S == 1) and returns (out, new_state).
    """
    w, b = as_dense(p["w"]), p["b"]
    width = w.shape[0]
    if state is not None:
        window = jnp.concatenate([state, x], axis=1)     # [B, width, C]
        out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                         w.astype(jnp.float32)) + b
        return out[:, None, :].astype(x.dtype), window[:, 1:]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        pad.astype(jnp.float32)[:, :, :],
        w.astype(jnp.float32)[:, None, :],   # [W, 1, C] depthwise
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1])
    return (out + b).astype(x.dtype)
