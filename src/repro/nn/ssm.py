"""Mamba-2 SSD (state-space duality) layer [arXiv:2405.21060].

Chunked SSD for train/prefill (intra-chunk quadratic + inter-chunk
linear recurrence over chunk states) and a constant-memory recurrent
step for decode.  Projections route through q_matmul; the recurrence
state stays fp32 (quantizing the running state compounds error — the
paper's feedback-resilience argument applies to policy outputs, not to
carried state; noted in DESIGN.md).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.policy import QuantPolicy
from repro.core.qmatmul import q_matmul
from repro.nn.conv import causal_conv1d_apply, causal_conv1d_init
from repro.nn.linear import linear_apply, linear_init
from repro.nn.module import KeySeq, normal_init, ones_init, param
from repro.nn.norm import rmsnorm_apply


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_model: int
    d_inner: int           # expand * d_model
    head_dim: int = 64     # P
    d_state: int = 128     # N
    n_groups: int = 1      # G
    conv_width: int = 4
    chunk: int = 128

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim


def ssm_init(key, cfg: SSMConfig, dtype=jnp.float32):
    ks = KeySeq(key)
    conv_dim = cfg.d_inner + 2 * cfg.n_groups * cfg.d_state
    d_in_proj = 2 * cfg.d_inner + 2 * cfg.n_groups * cfg.d_state \
        + cfg.n_heads
    return {
        "in_proj": linear_init(ks(), cfg.d_model, d_in_proj,
                               axes=("d_model", "d_inner"), bias=False,
                               dtype=dtype),
        "conv": causal_conv1d_init(ks(), conv_dim, cfg.conv_width, dtype),
        "A_log": param(ks(), (cfg.n_heads,), ("heads",),
                       lambda k, s, d: jnp.log(
                           jax.random.uniform(k, s, d, 1.0, 16.0))),
        "D": param(ks(), (cfg.n_heads,), ("heads",), ones_init()),
        "dt_bias": param(ks(), (cfg.n_heads,), ("heads",),
                         normal_init(0.1)),
        "norm": {"scale": param(ks(), (cfg.d_inner,), (None,),
                                ones_init(), dtype)},
        "out_proj": linear_init(ks(), cfg.d_inner, cfg.d_model,
                                axes=("d_inner", "d_model"), bias=False,
                                dtype=dtype),
    }


def _split_zxbcdt(zxbcdt, cfg: SSMConfig):
    di, gn, h = cfg.d_inner, cfg.n_groups * cfg.d_state, cfg.n_heads
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:di + di + 2 * gn]
    dt = zxbcdt[..., di + di + 2 * gn:]
    assert dt.shape[-1] == h
    return z, xBC, dt


def _segsum(x):
    """Stable segment-sum: out[..., i, j] = sum_{k in (j, i]} x_k."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(X, A, Bm, C, chunk: int):
    """Minimal SSD (discrete): X:[b,l,h,p] A:[b,l,h] B,C:[b,l,g,n].

    Returns (Y [b,l,h,p], final_state [b,h,p,n]).
    """
    b, l, h, p = X.shape
    g, n = Bm.shape[2], Bm.shape[3]
    q = chunk
    nc = l // q
    assert l % q == 0, (l, q)
    rep = h // g

    def cshape(t):
        return t.reshape(b, nc, q, *t.shape[2:])

    Xc, Ac, Bc, Cc = cshape(X), cshape(A), cshape(Bm), cshape(C)
    Ac = jnp.moveaxis(Ac, -1, 2)                  # [b, nc, h, q]
    A_cum = jnp.cumsum(Ac, axis=-1)               # [b, nc, h, q]

    # 1. intra-chunk (diagonal block): quadratic within chunk
    L = jnp.exp(_segsum(Ac))                      # [b,nc,h,q,q]
    Cr = jnp.repeat(Cc, rep, axis=3) if g != h else Cc
    Br = jnp.repeat(Bc, rep, axis=3) if g != h else Bc
    # scores: C_i . B_j  -> [b,nc,h,q,q]
    CB = jnp.einsum("bcihn,bcjhn->bchij", Cr, Br)
    Y_diag = jnp.einsum("bchij,bchij,bcjhp->bcihp", CB, L, Xc)

    # 2. chunk states: B^T (decay-weighted) X
    decay_states = jnp.exp(A_cum[..., -1:] - A_cum)    # [b,nc,h,q]
    states = jnp.einsum("bcjhn,bchj,bcjhp->bchpn",
                        Br, decay_states, Xc)          # [b,nc,h,p,n]

    # 3. inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(A_cum[..., -1])              # [b,nc,h]

    def scan_fn(carry, inp):
        s, d = inp                                     # [b,h,p,n], [b,h]
        new = carry * d[..., None, None] + s
        return new, carry                              # emit PREVIOUS

    init = jnp.zeros((b, h, p, n), X.dtype)
    final, prev_states = jax.lax.scan(
        scan_fn, init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)      # [b,nc,h,p,n]

    # 4. off-diagonal contribution from previous chunks' state
    state_decay = jnp.exp(A_cum)                       # [b,nc,h,q]
    Y_off = jnp.einsum("bcihn,bchpn,bchi->bcihp",
                       Cr, prev_states, state_decay)

    Y = (Y_diag + Y_off).reshape(b, l, h, p)
    return Y, final


def ssm_apply(p, u, cfg: SSMConfig,
              policy: Optional[QuantPolicy] = None,
              state: Optional[dict] = None,
              return_state: bool = False):
    """Full-sequence forward. u: [B, S, d_model].

    With ``state`` (dict with "ssm" [B,H,P,N] and "conv" [B,W-1,C]),
    performs a single decode step (S == 1).  ``return_state=True`` on
    the full path also returns the final recurrent state (prefill).
    """
    B, S, _ = u.shape
    h, pd, n, g = cfg.n_heads, cfg.head_dim, cfg.d_state, cfg.n_groups
    zxbcdt = linear_apply(p["in_proj"], u, policy)
    z, xBC, dt = _split_zxbcdt(zxbcdt, cfg)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,S,H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                 # [H]

    if state is not None:
        xBC_t, conv_state = causal_conv1d_apply(p["conv"], xBC,
                                                state["conv"])
        xBC_t = jax.nn.silu(xBC_t)
        x = xBC_t[..., :cfg.d_inner].reshape(B, h, pd)
        Bm = xBC_t[..., cfg.d_inner:cfg.d_inner + g * n].reshape(B, g, n)
        Cm = xBC_t[..., cfg.d_inner + g * n:].reshape(B, g, n)
        rep = h // g
        Br = jnp.repeat(Bm, rep, axis=1)
        Cr = jnp.repeat(Cm, rep, axis=1)
        dt1 = dt[:, 0]                                           # [B,H]
        dA = jnp.exp(dt1 * A)                                    # [B,H]
        ssm = state["ssm"]
        ssm = ssm * dA[..., None, None] \
            + jnp.einsum("bhn,bhp,bh->bhpn", Br, x, dt1)
        y = jnp.einsum("bhn,bhpn->bhp", Cr, ssm)
        y = y + x * p["D"][None, :, None]
        y = y.reshape(B, 1, cfg.d_inner)
        y = rmsnorm_apply(p["norm"], y * jax.nn.silu(z))
        out = linear_apply(p["out_proj"], y, policy)
        return out, {"ssm": ssm, "conv": conv_state}

    xBC_raw = xBC
    xBC = jax.nn.silu(causal_conv1d_apply(p["conv"], xBC))
    x = xBC[..., :cfg.d_inner].reshape(B, S, h, pd)
    Bm = xBC[..., cfg.d_inner:cfg.d_inner + g * n].reshape(B, S, g, n)
    Cm = xBC[..., cfg.d_inner + g * n:].reshape(B, S, g, n)
    X_dt = x.astype(jnp.float32) * dt[..., None]                 # dt * x
    A_dt = A[None, None, :] * dt                                 # [B,S,H]
    Y, final = ssd_chunked(X_dt, A_dt, Bm.astype(jnp.float32),
                           Cm.astype(jnp.float32), cfg.chunk)
    Y = Y + x * p["D"][None, None, :, None]
    Y = Y.reshape(B, S, cfg.d_inner).astype(u.dtype)
    Y = rmsnorm_apply(p["norm"], Y * jax.nn.silu(z))
    out = linear_apply(p["out_proj"], Y, policy)
    if return_state:
        w = cfg.conv_width - 1
        conv_state = xBC_raw[:, S - w:S].astype(jnp.float32)
        return out, {"ssm": final, "conv": conv_state}
    return out


def ssm_init_state(batch: int, cfg: SSMConfig):
    conv_dim = cfg.d_inner + 2 * cfg.n_groups * cfg.d_state
    return {
        "ssm": jnp.zeros((batch, cfg.n_heads, cfg.head_dim, cfg.d_state),
                         jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_dim),
                          jnp.float32),
    }
