"""Dense FFN blocks: SwiGLU (llama-family), GELU (whisper), GeGLU
(gemma-family), and the plain ReLU FC used by the RL agent."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.policy import QuantPolicy
from repro.core.vact import activation
from repro.nn.linear import linear_apply, linear_init
from repro.nn.module import KeySeq


def swiglu_init(key, d_model: int, d_ff: int, dtype=jnp.float32):
    ks = KeySeq(key)
    return {
        "w_gate": linear_init(ks(), d_model, d_ff,
                              axes=("d_model", "d_ff"), bias=False,
                              dtype=dtype),
        "w_up": linear_init(ks(), d_model, d_ff,
                            axes=("d_model", "d_ff"), bias=False,
                            dtype=dtype),
        "w_down": linear_init(ks(), d_ff, d_model,
                              axes=("d_ff", "d_model"), bias=False,
                              dtype=dtype),
    }


def swiglu_apply(p, x, policy: Optional[QuantPolicy] = None,
                 act: str = "silu"):
    g = linear_apply(p["w_gate"], x, policy)
    u = linear_apply(p["w_up"], x, policy)
    h = activation(g, act, policy) * u
    return linear_apply(p["w_down"], h, policy)


def mlp_init(key, d_model: int, d_ff: int, *, bias: bool = True,
             dtype=jnp.float32):
    ks = KeySeq(key)
    return {
        "w_in": linear_init(ks(), d_model, d_ff,
                            axes=("d_model", "d_ff"), bias=bias,
                            dtype=dtype),
        "w_out": linear_init(ks(), d_ff, d_model,
                             axes=("d_ff", "d_model"), bias=bias,
                             dtype=dtype),
    }


def mlp_apply(p, x, policy: Optional[QuantPolicy] = None,
              act: str = "gelu"):
    h = activation(linear_apply(p["w_in"], x, policy), act, policy)
    return linear_apply(p["w_out"], h, policy)
