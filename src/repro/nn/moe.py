"""Mixture-of-Experts FFN with sort-based capacity dispatch.

Two sharding regimes are exercised by the assigned archs (rules decide
via logical axes, see distributed/sharding.py):

  * expert parallelism (qwen3-moe: 128 experts / 16-way model axis):
    logical axis "experts" -> "model"; the dispatch scatter/gather
    lowers to all-to-all style collectives across the model axis.
  * TP-within-expert (mixtral: 8 experts < 16-way model axis):
    logical axis "d_ff_expert" -> "model"; experts replicated,
    each expert's FFN is tensor-parallel.

Dispatch: tokens pick top-k experts; a position within each expert's
capacity buffer is assigned by sorting token-assignments by expert id
(O(Tk log Tk), memory O(Tk) — no [T, E, C] one-hot blowup).  Tokens
beyond capacity are dropped (their combine weight contributes nothing),
standard GShard capacity-factor semantics.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.policy import QuantPolicy
from repro.core.qmatmul import q_batched_matmul, q_matmul
from repro.core.vact import activation
from repro.distributed.sharding import constrain
from repro.nn.linear import linear_init
from repro.nn.module import KeySeq, lecun_init, param


def moe_init(key, d_model: int, d_ff: int, n_experts: int,
             dtype=jnp.float32):
    ks = KeySeq(key)
    ax_w_in = ("experts", "d_model", "d_ff_expert")
    ax_w_out = ("experts", "d_ff_expert", "d_model")
    return {
        "router": linear_init(ks(), d_model, n_experts,
                              axes=("d_model", None), bias=False,
                              dtype=dtype),
        "w_gate": param(ks(), (n_experts, d_model, d_ff), ax_w_in,
                        lecun_init(), dtype),
        "w_up": param(ks(), (n_experts, d_model, d_ff), ax_w_in,
                      lecun_init(), dtype),
        "w_down": param(ks(), (n_experts, d_ff, d_model), ax_w_out,
                        lecun_init(), dtype),
    }


def _dispatch_indices(expert_idx: jnp.ndarray, n_experts: int,
                      capacity: int):
    """Position of each (token, slot) inside its expert's buffer.

    expert_idx: [Tk] int32.  Returns (pos [Tk], keep-mask [Tk]).
    """
    tk = expert_idx.shape[0]
    order = jnp.argsort(expert_idx)                    # stable
    sorted_e = expert_idx[order]
    # rank within the sorted array minus start offset of the segment
    counts = jnp.bincount(expert_idx, length=n_experts)
    starts = jnp.cumsum(counts) - counts               # [E]
    ranks = jnp.arange(tk) - starts[sorted_e]          # pos within expert
    pos_sorted = ranks
    pos = jnp.zeros_like(pos_sorted).at[order].set(pos_sorted)
    keep = pos < capacity
    return pos, keep


def moe_apply(p, x, *, top_k: int, policy: Optional[QuantPolicy] = None,
              capacity_factor: float = 1.25, act: str = "silu",
              router_bf16: bool = False):
    """x: [B, S, d_model] -> [B, S, d_model]."""
    B, S, D = x.shape
    E = p["w_gate"].shape[0] if not hasattr(p["w_gate"], "value") \
        else p["w_gate"].value.shape[0]
    w_gate, w_up, w_down = p["w_gate"], p["w_up"], p["w_down"]
    T = B * S

    # multi-device mesh active -> explicit shard_map dispatch (EP or
    # TP-within-expert); the global-view path below stays for hosts
    # and for non-divisible batches (long_500k B=1)
    from repro.distributed.sharding import current_mesh
    from repro.nn import moe_shard
    mesh = current_mesh()
    if mesh is not None and mesh.devices.size > 1 and \
            moe_shard.shardable(x, mesh, E):
        return moe_shard.moe_shard_map(
            x, p["router"]["w"], w_gate, w_up, w_down, mesh,
            top_k=top_k, capacity_factor=capacity_factor,
            policy=policy, act=act)

    xf = x.reshape(T, D)

    # --- routing (always executed in fp32: tiny, accuracy-critical) ----
    logits = q_matmul(xf, p["router"]["w"], None).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)      # [T, k]
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # --- dispatch ------------------------------------------------------
    capacity = int(math.ceil(T * top_k / E * capacity_factor))
    capacity = max(capacity, 4)
    e_flat = gate_idx.reshape(-1)                          # [Tk]
    w_flat = gate_vals.reshape(-1)
    pos, keep = _dispatch_indices(e_flat, E, capacity)
    # dropped tokens go to a scratch slot (capacity) that is sliced off
    pos_c = jnp.where(keep, pos, capacity)
    x_rep = jnp.repeat(xf, top_k, axis=0)                  # [Tk, D]
    x_rep = constrain(x_rep, ("batch", None))
    buf = jnp.zeros((E, capacity + 1, D), x.dtype)
    # expert buffers: experts over the model axis (EP) or replicated
    # (TP-within-expert), capacity over data — without this constraint
    # SPMD replicates the [E, C, D] buffers per device (100+ GiB at
    # 1M-token steps); the scatter below lowers to the EP all-to-all
    buf = constrain(buf, ("experts", "batch", None))
    buf = buf.at[e_flat, pos_c].set(x_rep, mode="drop")
    buf = constrain(buf, ("experts", "batch", None))
    buf = buf[:, :capacity]

    # --- expert FFN (batched quantized matmuls) ------------------------
    g = q_batched_matmul(buf, w_gate, policy)
    u = q_batched_matmul(buf, w_up, policy)
    h = activation(g, act, policy) * u
    h = constrain(h, ("experts", "batch", None))
    out_buf = q_batched_matmul(h, w_down, policy)          # [E, C, D]
    out_buf = constrain(out_buf, ("experts", "batch", None))

    # --- combine -------------------------------------------------------
    gathered = out_buf[e_flat, jnp.minimum(pos_c, capacity - 1)]
    gathered = constrain(gathered, ("batch", None))
    gathered = jnp.where((keep * 1.0)[:, None] > 0, gathered, 0.0)
    weighted = gathered * w_flat[:, None].astype(gathered.dtype)
    out = weighted.reshape(T, top_k, D).sum(axis=1)
    return out.reshape(B, S, D).astype(x.dtype)


def moe_aux_loss(logits: jnp.ndarray, gate_idx: jnp.ndarray,
                 n_experts: int) -> jnp.ndarray:
    """Switch-style load-balancing auxiliary loss."""
    probs = jax.nn.softmax(logits, -1)
    me = probs.mean(0)
    one_hot = jax.nn.one_hot(gate_idx[:, 0], n_experts)
    ce = one_hot.mean(0)
    return n_experts * jnp.sum(me * ce)
