"""Quantization-aware functional NN layers (no flax; see module.py)."""
from repro.nn.module import (KeySeq, Param, axes_of, count_params, is_param,
                             param, rebox, unbox)
