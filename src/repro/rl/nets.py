"""Small actor-critic / Q networks for vector- and image-observation envs.

Every matmul is a Q-MAC (q_matmul under the QuantPolicy), every
activation a V-ACT — the same compute fabric as the big models, so the
Fig.-3a reward-parity experiments exercise exactly the quantized paths.

Two families share the heads:

  * ``mlp_*`` — 2-layer torsos over flat [B, D] observations;
  * ``conv_*`` — the paper's Q-Conv vision stem (stride-2 conv replaces
    pooling, ReLU after) over [B, H, W, C] pixel observations, so catch
    and keydoor train without ``flatten_observation``.  The conv weights
    are named ``w`` like every matmul weight, so ``pack_weights`` ships
    them to the actor fleet as int8 QTensors automatically.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.policy import QuantPolicy
from repro.core.vact import activation
from repro.nn.conv import conv2d_init, qconv_block
from repro.nn.linear import linear_apply, linear_init
from repro.nn.module import KeySeq

Array = jax.Array


def mlp_ac_init(key, obs_dim: int, head_dim: int, hidden: int = 64,
                dtype=jnp.float32):
    """``head_dim`` = spaces.head_dim(action_space): n logits for
    Discrete, 2*act_dim (mean, log_std) for Box."""
    ks = KeySeq(key)
    return {
        "torso": {
            "fc1": linear_init(ks(), obs_dim, hidden, axes=(None, None),
                               dtype=dtype),
            "fc2": linear_init(ks(), hidden, hidden, axes=(None, None),
                               dtype=dtype),
        },
        "pi": linear_init(ks(), hidden, head_dim, axes=(None, None),
                          dtype=dtype),
        "v": linear_init(ks(), hidden, 1, axes=(None, None), dtype=dtype),
    }


def mlp_ac_apply(params, obs: Array,
                 policy: Optional[QuantPolicy] = None
                 ) -> Tuple[Array, Array]:
    """obs [B, D] -> (dist params [B, H], value [B])."""
    h = activation(linear_apply(params["torso"]["fc1"], obs, policy),
                   "tanh", policy)
    h = activation(linear_apply(params["torso"]["fc2"], h, policy),
                   "tanh", policy)
    logits = linear_apply(params["pi"], h, policy)
    value = linear_apply(params["v"], h, policy)[..., 0]
    return logits, value


def mlp_q_init(key, obs_dim: int, n_actions: int, hidden: int = 64,
               dtype=jnp.float32):
    ks = KeySeq(key)
    return {
        "fc1": linear_init(ks(), obs_dim, hidden, axes=(None, None),
                           dtype=dtype),
        "fc2": linear_init(ks(), hidden, hidden, axes=(None, None),
                           dtype=dtype),
        "q": linear_init(ks(), hidden, n_actions, axes=(None, None),
                         dtype=dtype),
    }


def mlp_q_apply(params, obs: Array,
                policy: Optional[QuantPolicy] = None) -> Array:
    h = activation(linear_apply(params["fc1"], obs, policy), "relu",
                   policy)
    h = activation(linear_apply(params["fc2"], h, policy), "relu",
                   policy)
    return linear_apply(params["q"], h, policy)


def mlp_qr_init(key, obs_dim: int, n_actions: int, n_quantiles: int,
                hidden: int = 64, dtype=jnp.float32):
    """QR-DQN: the plain Q net with a widened [n_actions * n_quantiles]
    head — same quantized torso, reshaped by :func:`mlp_qr_apply`."""
    return mlp_q_init(key, obs_dim, n_actions * n_quantiles, hidden,
                      dtype)


def mlp_qr_apply(params, obs: Array, n_actions: int, n_quantiles: int,
                 policy: Optional[QuantPolicy] = None) -> Array:
    """obs [B, D] -> quantile values [B, n_actions, n_quantiles]."""
    q = mlp_q_apply(params, obs, policy)
    return q.reshape(q.shape[:-1] + (n_actions, n_quantiles))


def mlp_pi_init(key, obs_dim: int, act_dim: int, hidden: int = 64,
                dtype=jnp.float32):
    """Deterministic DDPG actor: obs -> tanh-squashed action."""
    ks = KeySeq(key)
    return {
        "fc1": linear_init(ks(), obs_dim, hidden, axes=(None, None),
                           dtype=dtype),
        "fc2": linear_init(ks(), hidden, hidden, axes=(None, None),
                           dtype=dtype),
        "out": linear_init(ks(), hidden, act_dim, axes=(None, None),
                           dtype=dtype),
    }


def mlp_pi_apply(params, obs: Array, low: float, high: float,
                 policy: Optional[QuantPolicy] = None) -> Array:
    """obs [B, D] -> action [B, act_dim] rescaled into [low, high].
    The tanh squash runs through V-ACT like every other activation."""
    h = activation(linear_apply(params["fc1"], obs, policy), "relu",
                   policy)
    h = activation(linear_apply(params["fc2"], h, policy), "relu",
                   policy)
    u = activation(linear_apply(params["out"], h, policy), "tanh",
                   policy)
    mid, half = 0.5 * (high + low), 0.5 * (high - low)
    return mid + half * u


def mlp_twin_q_init(key, obs_dim: int, act_dim: int, hidden: int = 64,
                    dtype=jnp.float32):
    """TD3-style twin critics Q(s, a) — two independent Q torsos over
    the concatenated (obs, action) input."""
    ks = KeySeq(key)
    return {"q1": mlp_q_init(ks(), obs_dim + act_dim, 1, hidden, dtype),
            "q2": mlp_q_init(ks(), obs_dim + act_dim, 1, hidden, dtype)}


def mlp_twin_q_apply(params, obs: Array, act: Array,
                     policy: Optional[QuantPolicy] = None
                     ) -> Tuple[Array, Array]:
    """(obs [B, D], act [B, d]) -> (q1 [B], q2 [B])."""
    x = jnp.concatenate(
        [obs, act.reshape(obs.shape[0], -1).astype(obs.dtype)], axis=-1)
    q1 = mlp_q_apply(params["q1"], x, policy)[..., 0]
    q2 = mlp_q_apply(params["q2"], x, policy)[..., 0]
    return q1, q2


def mlp_twin_qr_init(key, obs_dim: int, act_dim: int, n_quantiles: int,
                     hidden: int = 64, dtype=jnp.float32):
    """TQC-style twin *quantile* critics Z(s, a) — the twin-Q torsos
    with [n_quantiles] heads, so the DDPG backup can pool, sort and
    truncate the target return distribution instead of min-clipping."""
    ks = KeySeq(key)
    return {"q1": mlp_q_init(ks(), obs_dim + act_dim, n_quantiles,
                             hidden, dtype),
            "q2": mlp_q_init(ks(), obs_dim + act_dim, n_quantiles,
                             hidden, dtype)}


def mlp_twin_qr_apply(params, obs: Array, act: Array,
                      policy: Optional[QuantPolicy] = None
                      ) -> Tuple[Array, Array]:
    """(obs [B, D], act [B, d]) -> (z1 [B, N], z2 [B, N])."""
    x = jnp.concatenate(
        [obs, act.reshape(obs.shape[0], -1).astype(obs.dtype)], axis=-1)
    return (mlp_q_apply(params["q1"], x, policy),
            mlp_q_apply(params["q2"], x, policy))


# ---------------------------------------------------------------------------
# Q-Conv pixel family (catch / keydoor without flatten_observation)
# ---------------------------------------------------------------------------

CONV_CHANNELS = (16, 32)
CONV_KERNEL = 3
CONV_HIDDEN = 128


def conv_flat_dim(obs_shape: Tuple[int, ...],
                  channels: Sequence[int] = CONV_CHANNELS) -> int:
    """Flattened feature size after the stride-2 Q-Conv stack (SAME
    padding halves each spatial dim, rounding up — same arithmetic as
    the HRL stem)."""
    h, w, _ = obs_shape
    for _ in channels:
        h = (h + 1) // 2
        w = (w + 1) // 2
    return h * w * channels[-1]


def conv_torso_init(key, obs_shape: Tuple[int, ...],
                    channels: Sequence[int] = CONV_CHANNELS,
                    kernel: int = CONV_KERNEL, hidden: int = CONV_HIDDEN,
                    dtype=jnp.float32):
    """Stride-2 Q-Conv stem + FC: obs [H, W, C] -> [hidden] features.

    ``obs_shape`` is the *wrapped* observation shape, so a frame-stacked
    env (C*k channels) sizes the first conv automatically.
    """
    if len(obs_shape) != 3:
        raise ValueError(f"conv torso needs (H, W, C) observations, "
                         f"got shape {obs_shape}")
    ks = KeySeq(key)
    convs = []
    c_in = obs_shape[-1]
    for c_out in channels:
        convs.append(conv2d_init(ks(), c_in, c_out, kernel, dtype))
        c_in = c_out
    return {
        "convs": convs,
        "fc": linear_init(ks(), conv_flat_dim(obs_shape, channels),
                          hidden, axes=(None, None), dtype=dtype),
    }


def conv_torso_apply(params, obs: Array,
                     policy: Optional[QuantPolicy] = None) -> Array:
    """obs [B, H, W, C] -> [B, hidden] (ReLU'd features)."""
    x = obs
    for pc in params["convs"]:
        x = qconv_block(pc, x, stride=2, policy=policy)
    x = x.reshape(x.shape[0], -1)
    return activation(linear_apply(params["fc"], x, policy), "relu",
                      policy)


def conv_ac_init(key, obs_shape: Tuple[int, ...], head_dim: int,
                 channels: Sequence[int] = CONV_CHANNELS,
                 kernel: int = CONV_KERNEL, hidden: int = CONV_HIDDEN,
                 dtype=jnp.float32):
    """Conv actor-critic: shared Q-Conv trunk + policy/value heads —
    the pixel counterpart of :func:`mlp_ac_init`."""
    ks = KeySeq(key)
    return {
        "torso": conv_torso_init(ks(), obs_shape, channels, kernel,
                                 hidden, dtype),
        "pi": linear_init(ks(), hidden, head_dim, axes=(None, None),
                          dtype=dtype),
        "v": linear_init(ks(), hidden, 1, axes=(None, None), dtype=dtype),
    }


def conv_ac_apply(params, obs: Array,
                  policy: Optional[QuantPolicy] = None
                  ) -> Tuple[Array, Array]:
    """obs [B, H, W, C] -> (dist params [B, H], value [B]) — the same
    contract as :func:`mlp_ac_apply`, so rollout/PPO/A2C are agnostic."""
    h = conv_torso_apply(params["torso"], obs, policy)
    logits = linear_apply(params["pi"], h, policy)
    value = linear_apply(params["v"], h, policy)[..., 0]
    return logits, value


def conv_q_init(key, obs_shape: Tuple[int, ...], n_actions: int,
                channels: Sequence[int] = CONV_CHANNELS,
                kernel: int = CONV_KERNEL, hidden: int = CONV_HIDDEN,
                dtype=jnp.float32):
    ks = KeySeq(key)
    return {
        "torso": conv_torso_init(ks(), obs_shape, channels, kernel,
                                 hidden, dtype),
        "q": linear_init(ks(), hidden, n_actions, axes=(None, None),
                         dtype=dtype),
    }


def conv_q_apply(params, obs: Array,
                 policy: Optional[QuantPolicy] = None) -> Array:
    """obs [B, H, W, C] -> Q values [B, A]."""
    h = conv_torso_apply(params["torso"], obs, policy)
    return linear_apply(params["q"], h, policy)


def conv_qr_init(key, obs_shape: Tuple[int, ...], n_actions: int,
                 n_quantiles: int,
                 channels: Sequence[int] = CONV_CHANNELS,
                 kernel: int = CONV_KERNEL, hidden: int = CONV_HIDDEN,
                 dtype=jnp.float32):
    """QR-DQN over pixels: the conv Q net with a widened
    [n_actions * n_quantiles] head, reshaped by :func:`conv_qr_apply`."""
    return conv_q_init(key, obs_shape, n_actions * n_quantiles, channels,
                       kernel, hidden, dtype)


def conv_qr_apply(params, obs: Array, n_actions: int, n_quantiles: int,
                  policy: Optional[QuantPolicy] = None) -> Array:
    """obs [B, H, W, C] -> quantile values [B, n_actions, n_quantiles]."""
    q = conv_q_apply(params, obs, policy)
    return q.reshape(q.shape[:-1] + (n_actions, n_quantiles))
