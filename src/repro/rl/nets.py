"""Small actor-critic / Q networks for vector-observation envs.

Every matmul is a Q-MAC (q_matmul under the QuantPolicy), every
activation a V-ACT — the same compute fabric as the big models, so the
Fig.-3a reward-parity experiments exercise exactly the quantized paths.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.policy import QuantPolicy
from repro.core.vact import activation
from repro.nn.linear import linear_apply, linear_init
from repro.nn.module import KeySeq

Array = jax.Array


def mlp_ac_init(key, obs_dim: int, head_dim: int, hidden: int = 64,
                dtype=jnp.float32):
    """``head_dim`` = spaces.head_dim(action_space): n logits for
    Discrete, 2*act_dim (mean, log_std) for Box."""
    ks = KeySeq(key)
    return {
        "torso": {
            "fc1": linear_init(ks(), obs_dim, hidden, axes=(None, None),
                               dtype=dtype),
            "fc2": linear_init(ks(), hidden, hidden, axes=(None, None),
                               dtype=dtype),
        },
        "pi": linear_init(ks(), hidden, head_dim, axes=(None, None),
                          dtype=dtype),
        "v": linear_init(ks(), hidden, 1, axes=(None, None), dtype=dtype),
    }


def mlp_ac_apply(params, obs: Array,
                 policy: Optional[QuantPolicy] = None
                 ) -> Tuple[Array, Array]:
    """obs [B, D] -> (dist params [B, H], value [B])."""
    h = activation(linear_apply(params["torso"]["fc1"], obs, policy),
                   "tanh", policy)
    h = activation(linear_apply(params["torso"]["fc2"], h, policy),
                   "tanh", policy)
    logits = linear_apply(params["pi"], h, policy)
    value = linear_apply(params["v"], h, policy)[..., 0]
    return logits, value


def mlp_q_init(key, obs_dim: int, n_actions: int, hidden: int = 64,
               dtype=jnp.float32):
    ks = KeySeq(key)
    return {
        "fc1": linear_init(ks(), obs_dim, hidden, axes=(None, None),
                           dtype=dtype),
        "fc2": linear_init(ks(), hidden, hidden, axes=(None, None),
                           dtype=dtype),
        "q": linear_init(ks(), hidden, n_actions, axes=(None, None),
                         dtype=dtype),
    }


def mlp_q_apply(params, obs: Array,
                policy: Optional[QuantPolicy] = None) -> Array:
    h = activation(linear_apply(params["fc1"], obs, policy), "relu",
                   policy)
    h = activation(linear_apply(params["fc2"], h, policy), "relu",
                   policy)
    return linear_apply(params["q"], h, policy)
