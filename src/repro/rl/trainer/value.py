"""The off-policy value-based trainer (dqn / qrdqn / ddpg).

Single-device (``mesh_kind=None``, the historical default) the loop is
bit-exact with the pre-trainer ``value_train``: same RNG stream
(``fold_in(seed_key, it)``), same replay backend, same jitted
iteration.  With a mesh (``--mesh host``) collection AND learning
shard over the data axes: per-device ``collect_value_sharded``
rollouts feed per-device local replay shards
(:func:`repro.rl.replay.make_sharded_replay` — stratified global
sampling, globally-normalized PER weights), the learner's grads
``psum`` over the data axis, and the int8 weight sync runs through
FleetSync in ``lockstep`` (fetch lag 0 + a per-iteration dispatch
barrier) or ``doublebuf`` mode (fetch lag 1, no barrier: collect k+1
runs against version k while the learner's k+1 update is in flight).
At 1 mesh device the sharded path is bit-exact with the single-device
path (slot 0 keeps the identical RNG stream; 1-device psum/pmax are
identities).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.policy import get_policy
from repro.obs import MetricSpec
from repro.optim import AdamWConfig, adamw_init, constant
from repro.rl.actor_learner import pack_weights
from repro.rl.envs import make
from repro.rl.envs.wrappers import NormStats
from repro.rl.inference import (ON_POLICY_ALGOS, VALUE_ALGOS, build_env,
                                make_value_agent)
from repro.rl.replay import make_replay, make_sharded_replay, replay_size
from repro.rl.rollout import init_envs
from repro.rl.train_steps import (make_sharded_value_iteration,
                                  make_value_iteration)
from repro.rl.trainer.base import Trainer, flag_mismatch, resolve_mesh
from repro.rl.trainer.evaluation import greedy_eval
from repro.rl.trainer.state import TrainState

SYNC_MODES = ("lockstep", "doublebuf")


def value_eval(algo: str, env_name: str, params,
               n_envs: int = 16, n_steps: Optional[int] = None,
               actor_policy: Optional[str] = None, seed: int = 0,
               net: str = "mlp", frame_stack_k: int = 1,
               norm_stats: Optional[NormStats] = None):
    """Greedy-policy evaluation: (mean episode return, episode count).

    ``net="conv"`` evaluates over the pixel pipeline with the running
    normalizer *frozen*: pass the training run's merged stats as
    ``norm_stats`` (see ``wrappers.norm_stats_of``/``merge_norm_stats``;
    None falls back to the identity transform).
    """
    if net == "conv":
        from repro.rl.envs.wrappers import init_norm_stats
        frozen = (norm_stats if norm_stats is not None
                  else init_norm_stats(make(env_name).obs_shape))
        env = build_env(env_name, net, frame_stack_k, norm_stats=frozen)
    else:
        env = build_env(env_name, net, frame_stack_k)
    spec = env.spec
    agent = make_value_agent(algo, spec, net=net)  # closures, no init
    policy = get_policy(actor_policy) if actor_policy else None
    n_steps = n_steps or spec.max_steps + spec.max_steps // 4
    return greedy_eval(env, lambda p, o: agent.greedy(p, o, policy),
                       params, jax.random.PRNGKey(seed + 17), n_envs,
                       n_steps)


class ValueTrainer(Trainer):
    family = "value"

    def __init__(self, algo: str = "dqn", env_name: str = "cartpole",
                 iters: int = 300, n_envs: int = 32,
                 rollout_len: int = 8,
                 actor_policy: Optional[str] = "fxp8", lr: float = 1e-3,
                 comm_bits: int = 8, seed: int = 0,
                 ckpt_dir: Optional[str] = None, save_every: int = 50,
                 replay_capacity: int = 50_000, n_step: int = 3,
                 updates_per_iter: int = 4, log_every: int = 20,
                 verbose: bool = True,
                 learn_start: Optional[int] = None, net: str = "mlp",
                 frame_stack_k: int = 1,
                 replay: str = "uniform", per_alpha: float = 0.6,
                 per_beta0: float = 0.4,
                 per_beta_iters: Optional[int] = None,
                 tqc_drop: int = 0,
                 mesh_kind: Optional[str] = None,
                 mesh_devices: Optional[int] = None,
                 sync: str = "lockstep", max_lag: int = 1,
                 metrics_dir: Optional[str] = None,
                 profile_dir: Optional[str] = None,
                 profile_start: int = 0, profile_steps: int = 1):
        if algo not in VALUE_ALGOS:
            raise ValueError(f"value_train drives {VALUE_ALGOS}, got "
                             f"{algo!r}; use rl_train for "
                             f"{ON_POLICY_ALGOS}")
        if sync not in SYNC_MODES:
            raise ValueError(f"unknown sync mode {sync!r} "
                             f"(expected one of {SYNC_MODES})")
        if mesh_kind is None and mesh_devices is not None:
            raise ValueError("--mesh-devices restricts a device mesh; "
                             "the value loop is single-device without "
                             "--mesh host")
        super().__init__(iters=iters, seed=seed, ckpt_dir=ckpt_dir,
                         save_every=save_every, log_every=log_every,
                         verbose=verbose, max_lag=max_lag,
                         fetch_lag=1 if sync == "doublebuf" else 0,
                         barrier=(sync == "lockstep"
                                  and mesh_kind is not None),
                         metrics_dir=metrics_dir,
                         profile_dir=profile_dir,
                         profile_start=profile_start,
                         profile_steps=profile_steps)
        self.algo, self.env_name, self.net = algo, env_name, net
        self.n_envs, self.rollout_len = n_envs, rollout_len
        self.frame_stack_k = frame_stack_k
        self.replay, self.per_alpha = replay, per_alpha
        self.per_beta0, self.tqc_drop = per_beta0, tqc_drop
        self.sync_mode = sync
        self.actor_policy_name = actor_policy
        self.env = build_env(env_name, net, frame_stack_k)
        spec = self.env.spec
        self.a_policy = get_policy(actor_policy) if actor_policy else None
        self.comm = comm_bits if self.a_policy else 32
        # epsilon anneals over the first half of the step budget
        decay = max((iters * rollout_len) // 2, 1)
        self.agent = make_value_agent(algo, spec, self.key,
                                      n_step=n_step,
                                      eps_decay_steps=decay,
                                      learn_start=learn_start, net=net,
                                      tqc_drop=tqc_drop)
        if mesh_kind is not None:
            self.mesh, self.n_slots = resolve_mesh(
                mesh_kind, mesh_devices, n_envs, verbose=verbose)
        else:
            self.mesh = None
        act = ((spec.action_space.shape, jnp.float32)
               if algo == "ddpg" else ((), jnp.int32))
        if self.mesh is not None:
            self.rb = make_sharded_replay(replay, self.n_slots,
                                          replay_capacity, spec.obs_shape,
                                          act[0], act[1],
                                          alpha=per_alpha)
        else:
            self.rb = make_replay(replay, replay_capacity,
                                  spec.obs_shape, act[0], act[1],
                                  alpha=per_alpha)
        self.beta_iters = max(per_beta_iters if per_beta_iters is not None
                              else iters, 1)
        self.n_step = n_step
        self.updates_per_iter = updates_per_iter
        self.ocfg = AdamWConfig(weight_decay=0.0, max_grad_norm=10.0)
        self.sched = constant(lr)

    # ---- trainer seams ---------------------------------------------------
    def init_state(self) -> TrainState:
        params = self.agent.params
        # fresh buffers, not an alias: params and target are both
        # donated to the jitted iteration, and a shared buffer cannot
        # donate twice
        target = jax.tree.map(jnp.copy, params)
        if self.algo == "ddpg":
            opt = {"actor": adamw_init(params["actor"]),
                   "critic": adamw_init(params["critic"])}
        else:
            opt = adamw_init(params)
        est, obs = init_envs(self.env, jax.random.PRNGKey(self.seed + 1),
                             self.n_envs, mesh=self.mesh)
        return TrainState(params, target, opt, self.rb.init(), est, obs)

    def build_iteration(self):
        if self.mesh is not None:
            return make_sharded_value_iteration(
                self.env, self.agent, self.rb, self.a_policy,
                self.sched, self.ocfg, self.mesh, algo=self.algo,
                rollout_len=self.rollout_len,
                updates_per_iter=self.updates_per_iter,
                per_beta0=self.per_beta0, beta_iters=self.beta_iters,
                metrics=self.metrics)
        return make_value_iteration(
            self.env, self.agent, self.rb, self.a_policy, self.sched,
            self.ocfg, algo=self.algo, rollout_len=self.rollout_len,
            updates_per_iter=self.updates_per_iter,
            per_beta0=self.per_beta0, beta_iters=self.beta_iters,
            metrics=self.metrics)

    def metric_spec(self) -> MetricSpec:
        gauges = ["return_mean", "epsilon", "replay_size"]
        if self.rb.prioritized:
            gauges.append("replay_max_priority")
        if self.mesh is not None:
            gauges.append("alive_frac")
        return MetricSpec(counters=("env_steps", "episodes"),
                          gauges=tuple(gauges))

    def run_meta(self) -> dict:
        meta = super().run_meta()
        meta.update(algo=self.algo, env=self.env_name, net=self.net,
                    n_envs=self.n_envs, rollout_len=self.rollout_len,
                    replay=self.replay, sync=self.sync_mode)
        return meta

    def pack(self, state):
        # only the behaviour net ships to the fleet (ddpg: the actor
        # alone — syncing the twin critics would triple the payload)
        return pack_weights(self.agent.behaviour_subtree(state.params),
                            self.comm)

    def step(self, iteration, state, packed, key, g, stage_ctx, alive,
             mbuf=None):
        args = (state.params, state.target, state.opt, state.replay,
                packed, state.est, state.obs, key, jnp.asarray(g))
        if self.mesh is not None:
            args = args + (alive,)
        if mbuf is not None:
            args = args + (mbuf,)
        out = iteration(*args)
        p, t, o, b, est, obs, ret, n_ep = out[:8]
        new = TrainState(p, t, o, b, est, obs)
        return ((new, ret, n_ep) if mbuf is None
                else (new, ret, n_ep, out[8]))

    def eval_policy(self, params, n_envs: int = 16,
                    n_steps: Optional[int] = None,
                    actor_policy: Optional[str] = None, seed: int = 0,
                    norm_stats: Optional[NormStats] = None):
        return value_eval(self.algo, self.env_name, params,
                          n_envs=n_envs, n_steps=n_steps,
                          actor_policy=actor_policy, seed=seed,
                          net=self.net,
                          frame_stack_k=self.frame_stack_k,
                          norm_stats=norm_stats)

    # ---- checkpoint seams ------------------------------------------------
    def validate_metadata(self, md: dict) -> None:
        d = self.ckpt_dir
        md_net = str(md.get("net", self.net))
        if md_net != self.net:
            raise flag_mismatch(d, "net", repr(md_net), repr(self.net),
                                "the torso family (and the obs "
                                "pipeline) differs")
        md_env = str(md.get("env", self.env_name))
        if md_env != self.env_name:
            raise flag_mismatch(d, "env", repr(md_env),
                                repr(self.env_name))
        md_algo = str(md.get("algo", ""))
        if md_algo != self.algo:
            raise flag_mismatch(d, "algo", repr(md_algo),
                                repr(self.algo))
        md_replay = str(md.get("replay", "uniform"))
        if md_replay != self.replay:
            raise flag_mismatch(d, "replay", repr(md_replay),
                                repr(self.replay),
                                "the sampling stream (and the PER tree "
                                "state) is part of the run")
        md_tqc = int(md.get("tqc_drop", 0))
        if md_tqc != self.tqc_drop:
            raise flag_mismatch(d, "tqc-drop", md_tqc, self.tqc_drop,
                                "the critic head shape differs "
                                "(restore does not shape-check)")
        # the sharded buffer's slot layout (and the doublebuf fetch
        # stream) are part of the run: a mismatched mesh cannot restore
        # the [n_slots]-leading replay tree bitwise
        md_slots = int(md.get("replay_slots", 1))
        if md_slots != self.n_slots:
            raise ValueError(
                f"checkpoint in {d} was saved with {md_slots} replay "
                f"slot(s), but this run's mesh shards {self.n_slots} — "
                "the sharded buffer layout differs; relaunch with the "
                "original --mesh/--mesh-devices flags")
        md_sync = str(md.get("sync", self.sync_mode))
        if md_sync != self.sync_mode:
            raise flag_mismatch(d, "sync", repr(md_sync),
                                repr(self.sync_mode),
                                "the weight-sync fetch stream differs",
                                verb="saved with")
        if self.replay == "per":
            # the priority exponent and beta schedule shape every
            # subsequent draw: a silent change would diverge from the
            # uninterrupted run's sampling stream
            for flag, have in (("per_alpha", self.per_alpha),
                               ("per_beta0", self.per_beta0),
                               ("per_beta_iters", self.beta_iters)):
                saved = md.get(flag)
                if saved is not None and float(saved) != float(have):
                    raise flag_mismatch(
                        d, flag.replace("_", "-"), saved, have,
                        "the prioritized sampling stream depends on it",
                        verb="saved with")

    def legacy_template(self, state: TrainState):
        return tuple(state)

    def state_from_legacy(self, restored) -> TrainState:
        return TrainState(*restored)

    def metadata(self, it: int, stage) -> dict:
        # env/net/frame_stack/n_envs make the checkpoint self-
        # describing for the serving loader (repro.serve.load_policy
        # rebuilds the net — and for conv policies the env-state
        # template — from these alone)
        md = {"algo": self.algo, "it": it, "replay": self.replay,
              "tqc_drop": self.tqc_drop, "env": self.env_name,
              "net": self.net, "frame_stack": self.frame_stack_k,
              "n_envs": self.n_envs, "n_step": self.n_step,
              "actor_policy": self.actor_policy_name or "fp32",
              "replay_slots": self.n_slots, "sync": self.sync_mode}
        if self.rb.prioritized:
            md.update(per_alpha=self.per_alpha,
                      per_beta0=self.per_beta0,
                      per_beta_iters=self.beta_iters)
        return md

    def resume_start(self, md: dict) -> int:
        return int(md.get("it", md.get("step", 0))) + 1

    def resume_message(self, md, state, start: int) -> str:
        return (f"resumed at iter {start} "
                f"(replay size {int(replay_size(state.replay))})")

    def header(self, state) -> str:
        pol = self.actor_policy_name if self.a_policy else "fp32"
        rep = (f"per(alpha={self.per_alpha}, beta {self.per_beta0}->1/"
               f"{self.beta_iters}it)" if self.rb.prioritized
               else "uniform")
        return (f"{self.algo} on {self.env.spec.name}: {self.n_envs} "
                f"envs x {self.rollout_len} steps/iter, "
                f"n_step={self.agent.cfg.n_step}, {pol} behaviour "
                f"actor, {rep} replay")

    def host_metrics(self, state, metrics: dict) -> dict:
        # without the jit-threaded buffer the window record still
        # carries the replay fill (one scalar host read, same value
        # the gauge reports)
        if "replay_size" in metrics:
            return {}
        return {"replay_size": int(replay_size(state.replay))}

    def log_line(self, it, ret, n_ep, metrics: dict, stage):
        return (f"iter {it:4d}  return {float(ret):8.2f}  "
                f"episodes {int(n_ep):4d}  "
                f"replay {int(metrics['replay_size']):6d}")

    def export_state(self, state, state_out) -> None:
        if state_out is not None:
            state_out.update(env_state=state.est, obs=state.obs,
                             replay=state.replay)


def value_train(algo: str = "dqn", env_name: str = "cartpole",
                iters: int = 300, n_envs: int = 32, rollout_len: int = 8,
                actor_policy: Optional[str] = "fxp8", lr: float = 1e-3,
                comm_bits: int = 8, seed: int = 0,
                ckpt_dir: Optional[str] = None, save_every: int = 50,
                replay_capacity: int = 50_000, n_step: int = 3,
                updates_per_iter: int = 4, log_every: int = 20,
                verbose: bool = True,
                learn_start: Optional[int] = None, net: str = "mlp",
                frame_stack_k: int = 1,
                replay: str = "uniform", per_alpha: float = 0.6,
                per_beta0: float = 0.4,
                per_beta_iters: Optional[int] = None,
                tqc_drop: int = 0,
                state_out: Optional[dict] = None,
                mesh_kind: Optional[str] = None,
                mesh_devices: Optional[int] = None,
                sync: str = "lockstep", max_lag: int = 1,
                metrics_dir: Optional[str] = None,
                profile_dir: Optional[str] = None,
                profile_start: int = 0, profile_steps: int = 1):
    """Off-policy value-based training (paper Fig. 2 split, replay
    flavour) — see :class:`ValueTrainer`.  Returns (params, history);
    ``state_out`` (optional dict) receives the final
    ``env_state``/``obs``/``replay`` state."""
    trainer = ValueTrainer(
        algo, env_name, iters=iters, n_envs=n_envs,
        rollout_len=rollout_len, actor_policy=actor_policy, lr=lr,
        comm_bits=comm_bits, seed=seed, ckpt_dir=ckpt_dir,
        save_every=save_every, replay_capacity=replay_capacity,
        n_step=n_step, updates_per_iter=updates_per_iter,
        log_every=log_every, verbose=verbose, learn_start=learn_start,
        net=net, frame_stack_k=frame_stack_k, replay=replay,
        per_alpha=per_alpha, per_beta0=per_beta0,
        per_beta_iters=per_beta_iters, tqc_drop=tqc_drop,
        mesh_kind=mesh_kind, mesh_devices=mesh_devices, sync=sync,
        max_lag=max_lag, metrics_dir=metrics_dir,
        profile_dir=profile_dir, profile_start=profile_start,
        profile_steps=profile_steps)
    state, history = trainer.train(state_out=state_out)
    return state.params, history
