"""The one training-state schema both families thread and checkpoint.

``TrainState`` is a NamedTuple registered with *index* tree paths
(``SequenceKey``, not the NamedTuple default attribute paths), so it
flattens to index-keyed checkpoint paths ("0/..." for params, "1/..."
for target, ...), which is exactly the layout the value family's
legacy 6-tuple
``(params, target, opt, replay, est, obs)`` produced — a value
checkpoint written before this schema restores into a ``TrainState``
unchanged, and a new checkpoint still restores through the old tuple
template (the serving loader's params-only 6-tuple template keeps
working too).  The on-policy family's legacy layout was a 4-tuple
``(params, opt, est, obs)``; its ``None`` slots here shift the index
keys, so schema-less on-policy checkpoints go through the trainer's
compatibility template instead (see ``trainer.base.restore_state``).

Slots the family does not use are ``None`` (None pytree nodes carry no
leaves — they cost nothing in the checkpoint and nothing under jit):

* on-policy (ppo/a2c): ``target`` and ``replay`` are None;
* value (dqn/qrdqn/ddpg): every slot is live (``replay`` holds the
  uniform/PER/sharded-PER buffer state, pointers and tree included).

The per-iteration RNG key is deliberately NOT state: both drivers
derive it as ``fold_in(base_key, it)`` (see ``trainer.base.train_loop``)
so it is a pure function of (seed, iteration) — a resumed run draws
exactly the stream the uninterrupted run would have, with nothing to
persist.

Donation: every slot is threaded through the jitted iteration, and the
step factories donate the threaded buffers (``repro.rl.train_steps``).
"""
from __future__ import annotations

from typing import Any, NamedTuple

# recorded in checkpoint metadata under "schema"; absence means a
# legacy pre-TrainState tuple, anything else is a future format this
# launcher refuses by name
STATE_SCHEMA = "trainstate/v1"


class TrainState(NamedTuple):
    params: Any     # online nets (value: {"actor","critic"} for ddpg)
    target: Any     # polyak target nets (None for on-policy)
    opt: Any        # optimizer state (value/ddpg: per-subtree dict)
    replay: Any     # replay buffer state (None for on-policy)
    est: Any        # vectorized env state (wrapper carries included)
    obs: Any        # last observations [n_envs, ...]


# index paths, not the NamedTuple-default attribute paths: a value
# TrainState must flatten to the identical "0/.."-"5/.." checkpoint
# keys the legacy (params, target, opt, replay, est, obs) tuple did,
# so pre-refactor checkpoints, the serving loader's tuple templates,
# and bitwise resume all keep working unchanged
import jax.tree_util as _jtu  # noqa: E402

_jtu.register_pytree_with_keys(
    TrainState,
    lambda ts: (tuple((_jtu.SequenceKey(i), x)
                      for i, x in enumerate(ts)), None),
    lambda aux, children: TrainState(*children))


def value_state(params, target, opt, replay, est, obs) -> TrainState:
    return TrainState(params, target, opt, replay, est, obs)


def onpolicy_state(params, opt, est, obs) -> TrainState:
    return TrainState(params, None, opt, None, est, obs)
