"""The on-policy trainer (ppo / a2c, mlp / conv / hrl agents).

The actor fleet is shard_map'd over the data axes of a real device
mesh; each device dequantizes the broadcast int8 weight sync locally
and rolls ``n_envs/n_devices`` environments.  Per-device trajectories
come back as one global batch whose per-device slots carry the
FleetSync ``alive`` mask into the PPO loss (and out of the advantage
statistics) — an async aggregator only has to flip mask bits to drop a
straggler, it never has to reshape the loss.  Truncated episodes
bootstrap through the timeout (GAE consumes the env's
terminated/truncated split).
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.configs.e2hrl import HRLConfig
from repro.core.policy import get_policy
from repro.models import hrl
from repro.nn.module import unbox
from repro.obs import MetricSpec
from repro.optim import AdamWConfig, adamw_init, constant
from repro.rl import PPOConfig, init_envs
from repro.rl.actor_learner import pack_weights
from repro.rl.dists import distribution_for
from repro.rl.envs import Environment, make
from repro.rl.envs.spaces import head_dim
from repro.rl.inference import (ON_POLICY_ALGOS, VALUE_ALGOS, build_env)
from repro.rl.nets import (conv_ac_apply, conv_ac_init, mlp_ac_apply,
                           mlp_ac_init)
from repro.rl.ppo import a2c_loss, ppo_loss, stage_mask
from repro.rl.train_steps import make_onpolicy_iteration
from repro.rl.trainer.base import Trainer, resolve_mesh
from repro.rl.trainer.evaluation import greedy_action, greedy_eval
from repro.rl.trainer.state import TrainState, onpolicy_state


def make_agent(agent: str, env: Environment, key,
               policy_name: Optional[str], net: str = "mlp"):
    spec = env.spec
    if agent == "mlp":
        if net == "conv":
            if len(spec.obs_shape) != 3:
                raise ValueError(
                    f"{spec.name} has obs shape {spec.obs_shape}; "
                    "--net conv needs image (H, W, C) observations")
            params = unbox(conv_ac_init(key, spec.obs_shape,
                                        head_dim(spec.action_space)))
            return params, conv_ac_apply
        if len(spec.obs_shape) != 1:
            raise ValueError(
                f"{spec.name} has obs shape {spec.obs_shape}; use "
                "--net conv for the Q-Conv pixel stem, wrap with "
                "envs.wrappers.flatten_observation for the mlp agent, "
                "or use --agent hrl")
        params = unbox(mlp_ac_init(key, spec.obs_shape[0],
                                   head_dim(spec.action_space)))
        apply_fn = mlp_ac_apply
        return params, apply_fn
    if net != "mlp":
        raise ValueError("--net conv selects the standalone conv "
                         "actor-critic; the hrl agent has its own conv "
                         "stem — drop --net")
    if len(spec.obs_shape) != 3:
        raise ValueError(
            f"{spec.name} has obs shape {spec.obs_shape}; the hrl agent "
            "needs image (H, W, C) observations — use --agent mlp")
    cfg = HRLConfig(obs_shape=spec.obs_shape, n_actions=spec.n_actions)
    params = unbox(hrl.init(key, cfg))

    def apply_fn(p, obs, policy=None):
        logits, value, _ = hrl.apply(p, obs, cfg, policy)
        return logits, value

    return params, apply_fn


class OnPolicyTrainer(Trainer):
    family = "onpolicy"

    def __init__(self, env_name: str = "cartpole", agent: str = "mlp",
                 iters: int = 40, n_envs: int = 32,
                 rollout_len: int = 128,
                 actor_policy: Optional[str] = "fxp8", lr: float = 3e-3,
                 comm_bits: int = 8, max_lag: int = 1, seed: int = 0,
                 two_stage: bool = False,
                 ckpt_dir: Optional[str] = None, save_every: int = 10,
                 mesh_kind: str = "host",
                 mesh_devices: Optional[int] = None,
                 log_every: int = 5, verbose: bool = True,
                 algo: str = "ppo", net: str = "mlp",
                 frame_stack_k: int = 1,
                 metrics_dir: Optional[str] = None,
                 profile_dir: Optional[str] = None,
                 profile_start: int = 0, profile_steps: int = 1):
        if algo not in ON_POLICY_ALGOS:
            raise ValueError(f"rl_train drives the on-policy family "
                             f"{ON_POLICY_ALGOS}; use value_train for "
                             f"{VALUE_ALGOS} (or the --algo CLI "
                             "dispatch)")
        if two_stage and agent != "hrl":
            raise ValueError("--two-stage trains the HRL sub-goal "
                             "curriculum and requires --agent hrl")
        # legacy on-policy sync: actors run (max_lag - 1) versions
        # behind the freshest push — lock-step at the default lag 1
        super().__init__(iters=iters, seed=seed, ckpt_dir=ckpt_dir,
                         save_every=save_every, log_every=log_every,
                         verbose=verbose, max_lag=max_lag,
                         fetch_lag=max_lag - 1, barrier=False,
                         metrics_dir=metrics_dir,
                         profile_dir=profile_dir,
                         profile_start=profile_start,
                         profile_steps=profile_steps)
        if net == "conv":
            self.env = build_env(env_name, net, frame_stack_k)
        else:
            # the mlp/hrl agents keep the historical raw-env view
            # (make_agent validates the obs shape)
            if frame_stack_k > 1:
                raise ValueError("--frame-stack is a pixel-pipeline "
                                 "knob and requires --net conv")
            self.env = make(env_name)
        self.env_name, self.n_envs = env_name, n_envs
        self.algo = algo
        self.rollout_len = rollout_len
        self.dist = distribution_for(self.env.action_space)
        self._init_params, self.apply_fn = make_agent(
            agent, self.env, self.key, actor_policy, net)
        self.a_policy = get_policy(actor_policy) if actor_policy else None
        self.comm = comm_bits
        self.mesh, self.n_slots = resolve_mesh(mesh_kind, mesh_devices,
                                               n_envs, verbose=verbose)
        self.ocfg = AdamWConfig(weight_decay=0.0, max_grad_norm=0.5)
        # a2c: one pass over the whole batch, no clipping surrogate
        self.pcfg = (PPOConfig() if algo == "ppo"
                     else PPOConfig(epochs=1, minibatches=1))
        self.loss_fn = ppo_loss if algo == "ppo" else a2c_loss
        self.sched = constant(lr)
        self.stage_list = ["action", "subgoal"] if two_stage else [None]
        self.stage_names = [s or "all" for s in self.stage_list]

    # ---- trainer seams ---------------------------------------------------
    def init_state(self) -> TrainState:
        est, obs = init_envs(self.env, jax.random.PRNGKey(self.seed + 1),
                             self.n_envs, mesh=self.mesh)
        return onpolicy_state(self._init_params,
                              adamw_init(self._init_params), est, obs)

    def build_iteration(self):
        return make_onpolicy_iteration(
            self.env, self.apply_fn, self.a_policy, self.mesh,
            self.dist, self.pcfg, self.loss_fn, self.sched, self.ocfg,
            rollout_len=self.rollout_len, n_envs=self.n_envs,
            n_slots=self.n_slots, metrics=self.metrics)

    def metric_spec(self) -> MetricSpec:
        return MetricSpec(counters=("env_steps", "episodes"),
                          gauges=("return_mean", "alive_frac"))

    def run_meta(self) -> dict:
        meta = super().run_meta()
        meta.update(algo=self.algo, env=self.env_name,
                    n_envs=self.n_envs, rollout_len=self.rollout_len)
        return meta

    def pack(self, state):
        return pack_weights(state.params, self.comm)

    def step(self, iteration, state, packed, key, g, stage_ctx, alive,
             mbuf=None):
        args = (state.params, state.opt, state.est, state.obs, packed,
                key, stage_ctx, alive)
        if mbuf is not None:
            params, opt, est, obs, ret, n_ep, mbuf = iteration(*args,
                                                               mbuf)
            return onpolicy_state(params, opt, est, obs), ret, n_ep, \
                mbuf
        params, opt, est, obs, ret, n_ep = iteration(*args)
        return onpolicy_state(params, opt, est, obs), ret, n_ep

    def stage_setup(self, state, stage):
        # the stage grad-mask actually freezes the off-stage subtree
        # (zero grads keep adam state at zero -> bitwise-frozen params)
        return stage_mask(state.params, stage) if stage else None

    def eval_policy(self, params, n_envs: int = 16,
                    n_steps: Optional[int] = None, seed: int = 0):
        spec = self.env.spec
        n_steps = n_steps or spec.max_steps + spec.max_steps // 4

        def act(p, o):
            dparams, _ = self.apply_fn(p, o, None)
            return greedy_action(self.dist, dparams)

        return greedy_eval(self.env, act, params,
                           jax.random.PRNGKey(seed + 17), n_envs,
                           n_steps)

    # ---- checkpoint seams ------------------------------------------------
    def validate_metadata(self, md: dict) -> None:
        md_stage = str(md.get("stage", "all"))
        if md_stage not in self.stage_names:
            raise ValueError(
                f"checkpoint in {self.ckpt_dir} was saved in stage "
                f"{md_stage!r} but this run's stages are "
                f"{self.stage_names} — relaunch with the original "
                "--two-stage/--agent flags")

    def legacy_template(self, state: TrainState):
        return (state.params, state.opt, state.est, state.obs)

    def state_from_legacy(self, restored) -> TrainState:
        return onpolicy_state(*restored)

    def metadata(self, it: int, stage) -> dict:
        return {"stage": stage or "all", "stage_iter": it}

    def resume_start(self, md: dict) -> int:
        # the checkpoint holds post-update state for its step, so
        # training continues at the NEXT step (re-running the saved one
        # would apply its optimizer update twice); the global step is
        # rebuilt from the recorded (stage, stage_iter) so a changed
        # --iters cannot land the resume in the wrong stage; the clamp
        # covers a shrunken --iters (the recorded stage already met the
        # new budget — continue at the next stage rather than skipping
        # past the end of the whole run)
        md_stage = str(md.get("stage", "all"))
        it = int(md.get("stage_iter", md.get("step", 0)))
        return (self.stage_names.index(md_stage) * self.iters
                + min(it + 1, self.iters))

    def resume_message(self, md, state, start: int) -> str:
        md_stage = str(md.get("stage", "all"))
        it = int(md.get("stage_iter", md.get("step", 0)))
        return (f"resumed at global iter {start} "
                f"(stage {md_stage}, iter {it} done)")

    def log_line(self, it, ret, n_ep, metrics: dict, stage):
        sfx = f" [stage={stage}]" if stage else ""
        return (f"iter {it:4d}  return {float(ret):8.2f}  "
                f"episodes {int(n_ep):4d}  "
                f"sync {metrics['sync_payload_bytes'] / 2**20:.2f} MiB "
                f"(fp32 {metrics['sync_fp32_bytes'] / 2**20:.2f}){sfx}")

    def export_state(self, state, state_out) -> None:
        if state_out is not None:
            state_out.update(env_state=state.est, obs=state.obs)


def rl_train(env_name: str = "cartpole", agent: str = "mlp",
             iters: int = 40, n_envs: int = 32, rollout_len: int = 128,
             actor_policy: Optional[str] = "fxp8", lr: float = 3e-3,
             comm_bits: int = 8, max_lag: int = 1, seed: int = 0,
             two_stage: bool = False, ckpt_dir: Optional[str] = None,
             save_every: int = 10, mesh_kind: str = "host",
             mesh_devices: Optional[int] = None,
             log_every: int = 5, verbose: bool = True,
             algo: str = "ppo", net: str = "mlp",
             frame_stack_k: int = 1,
             state_out: Optional[dict] = None,
             metrics_dir: Optional[str] = None,
             profile_dir: Optional[str] = None,
             profile_start: int = 0, profile_steps: int = 1):
    """On-policy training (paper Fig. 2 split over a device mesh) —
    see :class:`OnPolicyTrainer`.  Returns (params, history)."""
    trainer = OnPolicyTrainer(
        env_name, agent, iters=iters, n_envs=n_envs,
        rollout_len=rollout_len, actor_policy=actor_policy, lr=lr,
        comm_bits=comm_bits, max_lag=max_lag, seed=seed,
        two_stage=two_stage, ckpt_dir=ckpt_dir, save_every=save_every,
        mesh_kind=mesh_kind, mesh_devices=mesh_devices,
        log_every=log_every, verbose=verbose, algo=algo, net=net,
        frame_stack_k=frame_stack_k, metrics_dir=metrics_dir,
        profile_dir=profile_dir, profile_start=profile_start,
        profile_steps=profile_steps)
    state, history = trainer.train(state_out=state_out)
    return state.params, history
