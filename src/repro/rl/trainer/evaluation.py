"""The one greedy-evaluation head both families (and the serving
layer's parity tests) route through.

``greedy_eval`` runs a deterministic policy for ``n_steps`` over fresh
vectorized envs and returns the completed-episode mean return — the
training-loop returns only count episodes that finish *inside a
chunk*, which undercounts long-horizon envs; this is the clean
measurement.  The jitted program is bit-identical to the historical
``value_eval`` scan (same init_envs, same scan body, same
``episode_returns_from`` reduction) — only the action head is injected
instead of inlined.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.rl.dists import ActionDist, Categorical, TanhGaussian
from repro.rl.rollout import episode_returns_from, init_envs


def greedy_action(dist: ActionDist, dparams):
    """Deterministic action for a distribution head: the mode.

    Categorical -> argmax over logits; TanhGaussian -> the squashed
    mean (ignoring the exploration std), rescaled to the action box.
    """
    if isinstance(dist, Categorical):
        return jnp.argmax(dparams, axis=-1)
    if isinstance(dist, TanhGaussian):
        mu, _ = dist._split(dparams)
        return dist._mid + dist._half * jnp.tanh(mu)
    raise TypeError(f"no greedy head for distribution {type(dist).__name__}")


def greedy_eval(env, act_fn, params, key, n_envs: int, n_steps: int):
    """Run ``act_fn(params, obs) -> action`` greedily; returns
    (mean completed-episode return, episode count) as Python scalars."""

    @jax.jit
    def run(params, key):
        est, obs = init_envs(env, key, n_envs)

        def one(carry, _):
            est, o = carry
            a = act_fn(params, o)
            est, nxt, r, d, tr, _ = jax.vmap(env.step)(est, a)
            return (est, nxt), (r, d | tr)

        (_, _), (rews, bounds) = jax.lax.scan(one, (est, obs), None,
                                              length=n_steps)
        return episode_returns_from(rews, bounds)

    ret, n_ep = run(params, key)
    return float(ret), int(n_ep)
