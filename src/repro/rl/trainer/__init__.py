"""repro.rl.trainer — the layered training-driver stack.

Layers, bottom up:

  * :mod:`~repro.rl.trainer.state` — the one :class:`TrainState`
    schema (index-keyed pytree) both families checkpoint;
  * :mod:`~repro.rl.trainer.evaluation` — the shared greedy
    evaluation head;
  * :mod:`~repro.rl.trainer.base` — the :class:`Trainer` protocol
    (``init / iteration / save / restore / eval_policy``) plus the one
    train loop, checkpoint-metadata validation, fold_in RNG
    derivation, FleetSync weight sync and resume reconstruction;
  * :mod:`~repro.rl.trainer.value` / :mod:`~repro.rl.trainer.onpolicy`
    — the two families plugged into it.

``launch/rl_train.py`` is CLI parsing + dispatch over this package.
"""
from repro.rl.trainer.base import (Trainer, build_mesh, flag_mismatch,
                                   resolve_mesh)
from repro.rl.trainer.evaluation import greedy_action, greedy_eval
from repro.rl.trainer.onpolicy import (OnPolicyTrainer, make_agent,
                                       rl_train)
from repro.rl.trainer.state import (STATE_SCHEMA, TrainState,
                                    onpolicy_state, value_state)
from repro.rl.trainer.value import (SYNC_MODES, ValueTrainer,
                                    value_eval, value_train)

__all__ = [
    "OnPolicyTrainer", "STATE_SCHEMA", "SYNC_MODES", "TrainState",
    "Trainer", "ValueTrainer", "build_mesh", "flag_mismatch",
    "greedy_action", "greedy_eval", "make_agent", "onpolicy_state",
    "resolve_mesh", "rl_train", "value_eval", "value_state",
    "value_train",
]
