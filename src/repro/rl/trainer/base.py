"""The unified Trainer layer: one loop, one checkpoint flow, one RNG
convention for both training families.

``Trainer`` is the protocol the drivers plug into —

  * ``init``      -> :meth:`Trainer.init_state` (a :class:`TrainState`)
  * ``iteration`` -> :meth:`Trainer.build_iteration` /
    :meth:`Trainer.step` (the jitted step factories in
    :mod:`repro.rl.train_steps`)
  * ``save``      -> :meth:`Trainer.train`'s checkpoint writes (the
    ``TrainState`` plus family metadata and the ``schema`` tag)
  * ``restore``   -> :meth:`Trainer.restore` (metadata validated
    *before* the tree restore; schema-dispatched legacy templates)
  * ``eval_policy`` -> the family's greedy head over
    :func:`repro.rl.trainer.evaluation.greedy_eval`

so checkpoint metadata validation, fold_in RNG derivation
(``sub = fold_in(base_key, g)`` — a resumed run draws exactly the
stream the uninterrupted run would have), resume reconstruction, the
FleetSync weight-sync bookkeeping and the straggler ``alive`` mask are
implemented once here instead of twice in ``launch/rl_train.py``.

Weight sync runs through :class:`repro.rl.actor_learner.FleetSync`:
every iteration the learner pushes the freshly packed int8 weights and
the fleet fetches at the trainer's ``fetch_lag`` — 0 is lock-step
(optionally with a per-iteration ``block_until_ready`` barrier), 1 is
the double-buffered overlap (the next collect runs against version k
while the learner's k+1 update is still in flight in the async
dispatch stream).  ``alive`` is derived from per-slot fetch staleness,
not hardcoded all-true.
"""
from __future__ import annotations

import time
from typing import Optional

import jax

from repro.checkpoint import CheckpointManager
from repro.distributed.sharding import data_axis_size
from repro.launch.mesh import (describe, make_host_mesh,
                               make_production_mesh)
from repro.obs import (Console, MetricSpec, ProfileWindow,
                       RunTelemetry, SpanClock, flush)
from repro.rl.actor_learner import FleetSync, sync_bytes
from repro.rl.trainer.state import STATE_SCHEMA, TrainState


def build_mesh(mesh_kind: str = "host",
               mesh_devices: Optional[int] = None):
    if mesh_kind == "production":
        if mesh_devices is not None:
            raise ValueError("--mesh-devices restricts the host mesh "
                             "only; the production mesh shape is fixed")
        return make_production_mesh()
    if mesh_kind == "host":
        return make_host_mesh(mesh_devices)
    raise ValueError(f"unknown mesh kind {mesh_kind!r} "
                     "(expected 'host' or 'production')")


def resolve_mesh(mesh_kind: str, mesh_devices: Optional[int],
                 n_envs: int, verbose: bool = False):
    """Mesh construction + the env-divisibility contract, shared by
    both families: the default host mesh auto-fits its device count to
    the largest prefix dividing ``n_envs`` (odd host device counts
    degrade to fewer slots); an explicit ``--mesh-devices`` stays a
    hard error."""
    if mesh_kind == "host" and mesh_devices is None:
        mesh_devices = len(jax.devices())
        while mesh_devices > 1 and n_envs % mesh_devices != 0:
            mesh_devices -= 1
    mesh = build_mesh(mesh_kind, mesh_devices)
    n_slots = data_axis_size(mesh)
    if n_envs % n_slots != 0:
        raise ValueError(f"--n-envs {n_envs} must be divisible by the "
                         f"mesh's {n_slots} data slot(s)")
    Console(verbose).info(f"{describe(mesh)}: {n_slots} actor slot(s) "
                          f"x {n_envs // n_slots} envs")
    return mesh, n_slots


def flag_mismatch(ckpt_dir, flag: str, saved, have, reason: str = "",
                  verb: str = "saved by") -> ValueError:
    """The one checkpoint-vs-flags error format (metadata is validated
    BEFORE the tree restore, so a mismatched template fails with this
    and never a missing-leaf KeyError)."""
    why = f"{reason}; " if reason else ""
    return ValueError(
        f"checkpoint in {ckpt_dir} was {verb} --{flag} {saved}, not "
        f"{have} — {why}relaunch with the original flags")


class Trainer:
    """Base driver: subclasses supply the family-specific seams, this
    class owns the loop, the checkpoint flow and the weight sync."""

    family = "?"

    def __init__(self, *, iters: int, seed: int,
                 ckpt_dir: Optional[str], save_every: int,
                 log_every: int, verbose: bool, n_slots: int = 1,
                 max_lag: int = 1, fetch_lag: int = 0,
                 barrier: bool = False,
                 metrics_dir: Optional[str] = None,
                 profile_dir: Optional[str] = None,
                 profile_start: int = 0, profile_steps: int = 1):
        self.iters = iters
        self.seed = seed
        self.key = jax.random.PRNGKey(seed)
        self.ckpt_dir = ckpt_dir
        self.save_every = save_every
        self.log_every = log_every
        self.verbose = verbose
        self.console = Console(verbose)
        self.n_slots = n_slots
        self.max_lag = max_lag
        self.fetch_lag = fetch_lag
        self.barrier = barrier
        self.metrics_dir = metrics_dir
        self.profile_dir = profile_dir
        self.profile_start = profile_start
        self.profile_steps = profile_steps
        # the family metric spec, resolved in train() when telemetry
        # is on; None keeps the historical (uninstrumented) programs
        self.metrics: Optional[MetricSpec] = None
        self.stage_list = [None]
        self.stage_names = ["all"]

    # ---- family seams ----------------------------------------------------
    def init_state(self) -> TrainState:
        raise NotImplementedError

    def build_iteration(self):
        raise NotImplementedError

    def step(self, iteration, state, packed, key, g: int, stage_ctx,
             alive, mbuf=None):
        """Run one jitted iteration; returns ``(state, ret, n_ep)``,
        plus the updated metric buffer when ``mbuf`` is threaded."""
        raise NotImplementedError

    def pack(self, state):
        """The packed (int8) weight payload the fleet syncs."""
        raise NotImplementedError

    def eval_policy(self, params, **kw):
        raise NotImplementedError

    def stage_setup(self, state, stage):
        return None

    def validate_metadata(self, md: dict) -> None:
        pass

    def legacy_template(self, state: TrainState):
        """Restore template for schema-less (pre-TrainState) ckpts."""
        raise NotImplementedError

    def state_from_legacy(self, restored) -> TrainState:
        raise NotImplementedError

    def metadata(self, it: int, stage) -> dict:
        return {}

    def resume_start(self, md: dict) -> int:
        raise NotImplementedError

    def resume_message(self, md: dict, state, start: int) -> str:
        return f"resumed at iter {start}"

    def header(self, state) -> Optional[str]:
        return None

    def metric_spec(self) -> Optional[MetricSpec]:
        """The family's jit-threaded metric shape (None: no threaded
        buffer even with telemetry on)."""
        return None

    def run_meta(self) -> dict:
        """The ``meta`` record's ``run`` block."""
        return {"family": self.family, "seed": self.seed,
                "iters": self.iters, "n_slots": self.n_slots}

    def host_metrics(self, state, metrics: dict) -> dict:
        """Host-side gauges merged into each window record (families
        add what the jit buffer does not carry, e.g. replay fill when
        metrics are not threaded)."""
        return {}

    def log_line(self, it, ret, n_ep, metrics: dict, stage) -> str:
        """Render the console line from the window's structured
        metrics record."""
        raise NotImplementedError

    def export_state(self, state, state_out: Optional[dict]) -> None:
        pass

    # ---- the one driver --------------------------------------------------
    def restore(self, mgr: CheckpointManager, state: TrainState):
        """Schema-dispatched restore: flags are validated against the
        sidecar metadata first; ``trainstate/v1`` checkpoints restore
        straight into the :class:`TrainState` template, schema-less
        ones go through the family's legacy tuple template, and any
        other schema fails naming both."""
        md = mgr.metadata()
        schema = md.get("schema")
        if schema is not None and schema != STATE_SCHEMA:
            raise ValueError(
                f"checkpoint in {self.ckpt_dir} records state schema "
                f"{schema!r}, but this launcher reads {STATE_SCHEMA!r} "
                "(or the legacy schema-less tuple layout) — regenerate "
                "the checkpoint or use a matching launcher version")
        self.validate_metadata(md)
        if schema == STATE_SCHEMA:
            return mgr.restore(state)
        legacy, md = mgr.restore(self.legacy_template(state))
        return self.state_from_legacy(legacy), md

    def train(self, state_out: Optional[dict] = None):
        con = self.console
        state = self.init_state()
        start, mgr = 0, None
        if self.ckpt_dir:
            mgr = CheckpointManager(self.ckpt_dir, keep=2,
                                    save_every=self.save_every)
            if mgr.latest_step() is not None:
                state, md = self.restore(mgr, state)
                start = self.resume_start(md)
                con.info(self.resume_message(md, state, start))
        tel = None
        self.metrics = None
        if self.metrics_dir:
            # telemetry opens AFTER restore so the first window starts
            # at the resume step — the sink appends, keeping windows
            # contiguous across a restart
            self.metrics = self.metric_spec()
            tel = RunTelemetry(self.metrics_dir, run=self.run_meta(),
                               start=start)
        prof = (ProfileWindow(self.profile_dir, self.profile_start,
                              self.profile_steps)
                if self.profile_dir else None)
        clock = tel.clock if tel else SpanClock()
        iteration = self.build_iteration()
        mbuf = self.metrics.init() if self.metrics else None
        sync = FleetSync(self.n_slots, max_lag=self.max_lag)
        head = self.header(state)
        if head:
            con.info(head)
        history = []
        total_payload = 0
        w_payload = w_fp32 = 0
        t0 = time.time()
        t_win = time.perf_counter()
        for si, stage in enumerate(self.stage_list):
            ctx = self.stage_setup(state, stage)
            for it in range(self.iters):
                g = si * self.iters + it  # global step: stages never
                if g < start:             # collide; resume lands
                    continue              # mid-stage, not at stage 1
                if prof:
                    win = prof.tick(g)
                    if win:
                        if tel:
                            tel.profile(prof.dir, win)
                        con.info(f"profiler trace for steps "
                                 f"[{win[0]}, {win[1]}] -> {prof.dir}")
                with clock("sync"):
                    sync.push(self.pack(state))
                    stale = sync.fetch(self.fetch_lag)
                payload, fp32_eq = sync_bytes(stale)
                total_payload += payload
                w_payload += payload
                w_fp32 += fp32_eq
                # key derived from the global step, not a running
                # split: a resumed run at step g draws the same stream
                # the uninterrupted run would have
                sub = jax.random.fold_in(self.key, g)
                with clock("step"):
                    if mbuf is not None:
                        state, ret, n_ep, mbuf = self.step(
                            iteration, state, stale, sub, g, ctx,
                            sync.alive(), mbuf)
                    else:
                        state, ret, n_ep = self.step(
                            iteration, state, stale, sub, g, ctx,
                            sync.alive())
                    if self.barrier:
                        # lock-step: fence the dispatch stream so the
                        # next collect cannot overlap this learner
                        # update (the double-buffered mode omits
                        # exactly this)
                        jax.block_until_ready((state, ret))
                    # the host read of ret is the loop's pre-existing
                    # per-iteration sync point — time it as the step
                    ret_f = float(ret)
                history.append(ret_f)
                if it % self.log_every == 0 or it == self.iters - 1:
                    metrics = {}
                    hists = None
                    if mbuf is not None:
                        metrics, hists, mbuf = flush(self.metrics,
                                                     mbuf)
                    metrics.update(self.host_metrics(state, metrics))
                    metrics["sync_payload_bytes"] = w_payload
                    metrics["sync_fp32_bytes"] = w_fp32
                    metrics["staleness_max"] = int(
                        jax.device_get(sync.staleness()).max())
                    metrics.setdefault(
                        "alive_frac",
                        float(jax.device_get(sync.alive()).mean()))
                    wall = time.perf_counter() - t_win
                    if "env_steps" in metrics and wall > 0:
                        metrics["steps_per_s"] = round(
                            metrics["env_steps"] / wall, 2)
                    if tel:
                        tel.step_flush(g, metrics, hists)
                    con.info(self.log_line(it, ret_f, int(n_ep),
                                           metrics, stage))
                    w_payload = w_fp32 = 0
                    t_win = time.perf_counter()
                if mgr and mgr.should_save(g):
                    with clock("checkpoint"):
                        mgr.save(g, state,
                                 metadata={**self.metadata(it, stage),
                                           "schema": STATE_SCHEMA})
        if prof:
            win = prof.stop()
            if win:
                if tel:
                    tel.profile(prof.dir, win)
                con.info(f"profiler trace for steps "
                         f"[{win[0]}, {win[1]}] -> {prof.dir}")
        if tel:
            tel.close()
        con.info(f"done in {time.time() - t0:.0f}s; "
                 f"total sync payload {total_payload / 2**20:.1f} MiB")
        self.export_state(state, state_out)
        return state, history
