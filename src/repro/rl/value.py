"""Off-policy value-based RL on the quantized compute fabric.

The paper's Fig. 3a parity claim spans value-based methods, so this
module grows the old DQN loss stub into a family that trains end to
end under the fxp8-behaviour-actor / fp32-learner split:

  * a pure-JAX circular replay whose transitions carry a *discount*
    instead of a done flag — ``discount = gamma^K * (1 - terminated)``
    folds the n-step horizon, truncation (bootstrap: discount stays
    ``gamma^K``) and termination (no bootstrap: 0) into one number, so
    every target below is the same ``r + discount * Q(next_obs)``;
  * :func:`nstep_targets` — truncation-aware n-step returns computed
    from a fresh [T, B] rollout chunk before insertion (windows stop at
    episode boundaries; ``next_obs`` is the true pre-reset successor);
  * Double-DQN (:func:`dqn_loss`), QR-DQN quantile regression
    (:func:`qrdqn_loss`, à la fqf-iqn-qrdqn) for Discrete envs;
  * DDPG/TD3-style continuous control (twin critics, target-policy
    smoothing, polyak targets) for Box envs.

The behaviour policy (epsilon-greedy over the quantized Q net, or the
quantized deterministic actor + exploration noise) is the quantized
actor; the learner updates in fp32 — exactly the split the PPO driver
uses.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# configs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DQNConfig:
    gamma: float = 0.99
    eps_start: float = 1.0
    eps_end: float = 0.05
    eps_decay_steps: int = 2_000
    target_update_every: int = 100   # hard-update period (legacy loops)
    target_tau: float = 0.01         # polyak rate (the jitted driver)
    batch_size: int = 64
    double: bool = True              # Double-DQN action selection
    n_step: int = 1
    learn_start: int = 256           # min replay size before updates


@dataclasses.dataclass(frozen=True)
class QRDQNConfig(DQNConfig):
    n_quantiles: int = 32
    kappa: float = 1.0               # quantile-Huber threshold


@dataclasses.dataclass(frozen=True)
class DDPGConfig:
    """TD3-flavoured DDPG: twin critics + target-policy smoothing."""

    low: float = -1.0                # action bounds (Box envs)
    high: float = 1.0
    gamma: float = 0.99
    tau: float = 0.005               # polyak rate for both targets
    batch_size: int = 128
    n_step: int = 1
    learn_start: int = 256
    explore_noise: float = 0.1       # behaviour noise, x half-range
    policy_noise: float = 0.2        # target smoothing noise, x half-range
    noise_clip: float = 0.5          # smoothing clip, x half-range

    @property
    def half_range(self) -> float:
        return 0.5 * (self.high - self.low)


# ---------------------------------------------------------------------------
# replay (circular, discount-encoded transitions)
# ---------------------------------------------------------------------------

class Replay(NamedTuple):
    obs: Array          # [N, ...]
    actions: Array      # [N] (Discrete) or [N, d] (Box)
    rewards: Array      # [N] (n-step accumulated)
    next_obs: Array     # [N, ...] true successor (pre-reset at bounds)
    discounts: Array    # [N] gamma^K * (1 - terminated)
    ptr: Array          # scalar int32: next write slot
    size: Array         # scalar int32: valid entries


def replay_init(capacity: int, obs_shape,
                action_shape: Tuple[int, ...] = (),
                action_dtype=jnp.int32) -> Replay:
    z = jnp.zeros
    return Replay(z((capacity,) + tuple(obs_shape)),
                  z((capacity,) + tuple(action_shape), action_dtype),
                  z((capacity,)),
                  z((capacity,) + tuple(obs_shape)),
                  z((capacity,)),
                  jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32))


def replay_add(buf: Replay, obs, action, reward, next_obs,
               discount) -> Replay:
    """Add a batch of B transitions (contiguous circular write).

    ``B >= capacity`` keeps exactly the last ``capacity`` transitions:
    a full-batch write would produce duplicate scatter indices, whose
    write order XLA leaves unspecified, so the survivors are sliced out
    first and the scatter indices stay unique (deterministic).
    """
    B = obs.shape[0]
    cap = buf.obs.shape[0]
    ptr = buf.ptr
    if B >= cap:
        drop = B - cap
        obs, action, reward, next_obs, discount = (
            x[drop:] for x in (obs, action, reward, next_obs, discount))
        ptr = ptr + drop        # slots the dropped prefix would have used
        B = cap
    idx = (ptr + jnp.arange(B)) % cap
    return Replay(
        buf.obs.at[idx].set(obs),
        buf.actions.at[idx].set(action),
        buf.rewards.at[idx].set(reward),
        buf.next_obs.at[idx].set(next_obs),
        buf.discounts.at[idx].set(discount),
        (ptr + B) % cap,
        jnp.minimum(buf.size + B, cap),
    )


def replay_sample(buf: Replay, key: Array, n: int,
                  min_size: int = 1) -> dict:
    """Sample ``n`` transitions uniformly from the valid prefix.

    A buffer below ``min_size`` (e.g. the driver's ``learn_start``)
    must not train: eagerly that's a hard error; under jit (where
    ``size`` is a tracer) the returned ``"weight"`` column is 0 so a
    weighted loss masks the whole batch instead of silently training
    on all-zero transitions.
    """
    min_size = max(int(min_size), 1)
    if not isinstance(buf.size, jax.core.Tracer) \
            and int(buf.size) < min_size:
        raise ValueError(
            f"replay_sample: buffer holds {int(buf.size)} transitions "
            f"but min_size={min_size} — sampling would return "
            "uninitialized (all-zero) transitions; collect more steps "
            "first (learn_start)")
    idx = jax.random.randint(key, (n,), 0, jnp.maximum(buf.size, 1))
    weight = jnp.broadcast_to(
        (buf.size >= min_size).astype(jnp.float32), (n,))
    return {"obs": buf.obs[idx], "actions": buf.actions[idx],
            "rewards": buf.rewards[idx], "next_obs": buf.next_obs[idx],
            "discounts": buf.discounts[idx], "weight": weight}


# ---------------------------------------------------------------------------
# n-step targets from a rollout chunk (truncation-aware)
# ---------------------------------------------------------------------------

def nstep_targets(rewards: Array, dones: Array, truncated: Array,
                  next_obs: Array, gamma: float, n: int):
    """Fold a fresh [T, B] chunk into n-step transitions.

    For each start row t the window runs ``K = min(n, steps to the
    first episode boundary, T - t)`` steps.  Returns

      * ``returns``  [T, B]      sum_{k<K} gamma^k r_{t+k}
      * ``next_obs`` [T, B, ...] the true successor of the window's
        last step (pre-reset ``final_obs`` at boundaries)
      * ``discount`` [T, B]      gamma^K * (1 - terminated_at_end)

    so the target is always ``returns + discount * Q(next_obs)``:
    terminations zero the bootstrap, truncations keep it (through the
    pre-reset observation), and the chunk tail degrades to valid
    shorter-horizon targets rather than crossing into the next chunk.
    """
    if n < 1:
        raise ValueError(f"nstep_targets needs n >= 1, got {n}")
    T = rewards.shape[0]
    f32 = jnp.float32
    boundary = dones | truncated

    returns = rewards.astype(f32)
    nxt = next_obs
    term_end = dones
    gpow = jnp.full(rewards.shape, gamma, f32)       # gamma^K, K=1
    open_ = ~boundary                                # window extendable

    for k in range(1, min(n, T)):
        def shift(x, fill):
            pad = jnp.full((k,) + x.shape[1:], fill, x.dtype)
            return jnp.concatenate([x[k:], pad], axis=0)

        in_range = shift(jnp.ones_like(boundary), False)
        ext = open_ & in_range                       # extend to step t+k
        extm = ext.reshape(ext.shape + (1,) * (nxt.ndim - ext.ndim))
        returns = returns + jnp.where(
            ext, (gamma ** k) * shift(rewards.astype(f32), 0.0), 0.0)
        nxt = jnp.where(extm, shift(next_obs, 0.0), nxt)
        term_end = jnp.where(ext, shift(dones, False), term_end)
        gpow = jnp.where(ext, gamma ** (k + 1), gpow)
        open_ = ext & ~shift(boundary, True)

    discount = gpow * (1.0 - term_end.astype(f32))
    return returns, nxt, discount


# ---------------------------------------------------------------------------
# behaviour policy pieces
# ---------------------------------------------------------------------------

def epsilon(step: Array, cfg: DQNConfig) -> Array:
    frac = jnp.clip(step / cfg.eps_decay_steps, 0.0, 1.0)
    return cfg.eps_start + frac * (cfg.eps_end - cfg.eps_start)


def egreedy(key: Array, qvals: Array, eps: Array) -> Array:
    B, A = qvals.shape
    k1, k2 = jax.random.split(key)
    rand = jax.random.randint(k1, (B,), 0, A)
    greedy = jnp.argmax(qvals, axis=-1)
    return jnp.where(jax.random.uniform(k2, (B,)) < eps, rand, greedy)


def polyak(target, online, tau: float):
    """Soft target-network update: target += tau * (online - target)."""
    return jax.tree.map(lambda t, o: t + tau * (o - t), target, online)


def _weighted_mean(x: Array, weight: Optional[Array]) -> Array:
    if weight is None:
        return jnp.mean(x)
    return (x * weight).sum() / jnp.maximum(weight.sum(), 1.0)


def _batch_discount(batch: dict, cfg) -> Array:
    """Discount column; legacy batches carry ``dones`` instead."""
    if "discounts" in batch:
        return batch["discounts"]
    return cfg.gamma * (1.0 - batch["dones"].astype(jnp.float32))


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def dqn_loss(params, target_params, apply_fn: Callable, batch: dict,
             cfg: DQNConfig) -> Array:
    """(Double-)DQN TD error. ``apply_fn(params, obs) -> [B, A]``."""
    q = apply_fn(params, batch["obs"])
    q_sel = q[jnp.arange(q.shape[0]), batch["actions"]]
    q_next_t = apply_fn(target_params, batch["next_obs"])
    if cfg.double:
        a_star = jnp.argmax(apply_fn(params, batch["next_obs"]), axis=-1)
        q_next = q_next_t[jnp.arange(q_next_t.shape[0]), a_star]
    else:
        q_next = q_next_t.max(-1)
    target = batch["rewards"] + _batch_discount(batch, cfg) * q_next
    target = jax.lax.stop_gradient(target)
    return _weighted_mean(jnp.square(q_sel - target),
                          batch.get("weight"))


def quantile_taus(n: int) -> Array:
    """Quantile midpoints tau_i = (2i + 1) / 2n."""
    return (jnp.arange(n, dtype=jnp.float32) + 0.5) / n


def qrdqn_loss(params, target_params, apply_fn: Callable, batch: dict,
               cfg: QRDQNConfig) -> Array:
    """Quantile-regression DQN (Dabney et al.) with Double-DQN action
    selection.  ``apply_fn(params, obs) -> [B, A, n_quantiles]``."""
    theta = apply_fn(params, batch["obs"])            # [B, A, N]
    B, _, N = theta.shape
    rows = jnp.arange(B)
    theta_a = theta[rows, batch["actions"]]           # [B, N]

    next_t = apply_fn(target_params, batch["next_obs"])
    if cfg.double:
        a_star = jnp.argmax(
            apply_fn(params, batch["next_obs"]).mean(-1), axis=-1)
    else:
        a_star = jnp.argmax(next_t.mean(-1), axis=-1)
    next_q = next_t[rows, a_star]                     # [B, N]
    target = (batch["rewards"][:, None]
              + _batch_discount(batch, cfg)[:, None] * next_q)
    target = jax.lax.stop_gradient(target)

    # pairwise TD errors u[b, i, j] = target_j - theta_i
    u = target[:, None, :] - theta_a[:, :, None]      # [B, N, N]
    absu = jnp.abs(u)
    huber = jnp.where(absu <= cfg.kappa,
                      0.5 * jnp.square(u),
                      cfg.kappa * (absu - 0.5 * cfg.kappa))
    taus = quantile_taus(N)[None, :, None]
    rho = jnp.abs(taus - (u < 0).astype(jnp.float32)) * huber / cfg.kappa
    per_sample = rho.mean(axis=2).sum(axis=1)         # [B]
    return _weighted_mean(per_sample, batch.get("weight"))


def ddpg_critic_loss(critic_params, target_critic, target_actor,
                     critic_apply: Callable, actor_apply: Callable,
                     batch: dict, cfg: DDPGConfig, key: Array) -> Array:
    """Twin-critic TD error with target-policy smoothing (TD3 eq. 14).

    ``critic_apply(params, obs, act) -> (q1, q2)``;
    ``actor_apply(params, obs) -> action`` already inside the bounds.
    """
    na = actor_apply(target_actor, batch["next_obs"])
    noise = jnp.clip(jax.random.normal(key, na.shape) * cfg.policy_noise,
                     -cfg.noise_clip, cfg.noise_clip) * cfg.half_range
    na = jnp.clip(na + noise, cfg.low, cfg.high)
    q1_t, q2_t = critic_apply(target_critic, batch["next_obs"], na)
    target = (batch["rewards"]
              + _batch_discount(batch, cfg) * jnp.minimum(q1_t, q2_t))
    target = jax.lax.stop_gradient(target)
    q1, q2 = critic_apply(critic_params, batch["obs"], batch["actions"])
    err = jnp.square(q1 - target) + jnp.square(q2 - target)
    return _weighted_mean(err, batch.get("weight"))


def ddpg_actor_loss(actor_params, critic_params,
                    critic_apply: Callable, actor_apply: Callable,
                    batch: dict) -> Array:
    """Deterministic policy gradient: maximize Q1(s, pi(s))."""
    a = actor_apply(actor_params, batch["obs"])
    q1, _ = critic_apply(critic_params, batch["obs"], a)
    return -_weighted_mean(q1, batch.get("weight"))
