"""Off-policy value-based RL on the quantized compute fabric.

The paper's Fig. 3a parity claim spans value-based methods, so this
module grows the old DQN loss stub into a family that trains end to
end under the fxp8-behaviour-actor / fp32-learner split:

  * replay lives in :mod:`repro.rl.replay` now (uniform circular +
    sum-tree prioritized backends behind one protocol; the historical
    ``replay_*`` names are re-exported here).  Transitions carry a
    *discount* instead of a done flag — ``discount = gamma^K *
    (1 - terminated)`` folds the n-step horizon, truncation (bootstrap:
    discount stays ``gamma^K``) and termination (no bootstrap: 0) into
    one number, so every target below is the same
    ``r + discount * Q(next_obs)``;
  * :func:`nstep_targets` — truncation-aware n-step returns computed
    from a fresh [T, B] rollout chunk before insertion (windows stop at
    episode boundaries; ``next_obs`` is the true pre-reset successor);
  * Double-DQN (:func:`dqn_loss`), QR-DQN quantile regression
    (:func:`qrdqn_loss`, à la fqf-iqn-qrdqn) for Discrete envs;
  * DDPG/TD3-style continuous control (twin critics, target-policy
    smoothing, polyak targets) for Box envs.

The behaviour policy (epsilon-greedy over the quantized Q net, or the
quantized deterministic actor + exploration noise) is the quantized
actor; the learner updates in fp32 — exactly the split the PPO driver
uses.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

# the replay buffers grew into their own subsystem (repro.rl.replay:
# uniform + prioritized backends behind one protocol); these re-exports
# keep the historical repro.rl.value surface alive, bit-compatibly
from repro.rl.replay.uniform import (Replay, replay_add,  # noqa: F401
                                     replay_init, replay_sample)

Array = jax.Array


# ---------------------------------------------------------------------------
# configs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DQNConfig:
    gamma: float = 0.99
    eps_start: float = 1.0
    eps_end: float = 0.05
    eps_decay_steps: int = 2_000
    target_update_every: int = 100   # hard-update period (legacy loops)
    target_tau: float = 0.01         # polyak rate (the jitted driver)
    batch_size: int = 64
    double: bool = True              # Double-DQN action selection
    n_step: int = 1
    learn_start: int = 256           # min replay size before updates


@dataclasses.dataclass(frozen=True)
class QRDQNConfig(DQNConfig):
    n_quantiles: int = 32
    kappa: float = 1.0               # quantile-Huber threshold


@dataclasses.dataclass(frozen=True)
class DDPGConfig:
    """TD3-flavoured DDPG: twin critics + target-policy smoothing.

    ``critic_quantiles > 1`` switches the twin critics to quantile
    heads (TQC, Kuznetsov et al.): the Bellman target pools both target
    critics' quantiles, sorts them and drops the top ``tqc_drop``
    before the backup — truncation replaces TD3's min-clipping as the
    overestimation control.  The defaults (1 quantile, drop 0) keep the
    scalar twin-critic / min-backup path bit-exact.
    """

    low: float = -1.0                # action bounds (Box envs)
    high: float = 1.0
    gamma: float = 0.99
    tau: float = 0.005               # polyak rate for both targets
    batch_size: int = 128
    n_step: int = 1
    learn_start: int = 256
    explore_noise: float = 0.1       # behaviour noise, x half-range
    policy_noise: float = 0.2        # target smoothing noise, x half-range
    noise_clip: float = 0.5          # smoothing clip, x half-range
    critic_quantiles: int = 1        # >1: TQC quantile critics
    tqc_drop: int = 0                # pooled target quantiles dropped
    kappa: float = 1.0               # quantile-Huber threshold (TQC)

    def __post_init__(self):
        if self.critic_quantiles < 1:
            raise ValueError(f"critic_quantiles must be >= 1, got "
                             f"{self.critic_quantiles}")
        if self.tqc_drop < 0 or self.tqc_drop >= 2 * self.critic_quantiles:
            raise ValueError(
                f"tqc_drop={self.tqc_drop} must leave at least one of "
                f"the {2 * self.critic_quantiles} pooled target "
                "quantiles")
        if self.tqc_drop > 0 and self.critic_quantiles == 1:
            raise ValueError(
                "tqc_drop prunes pooled target *quantiles* — scalar "
                "twin critics (critic_quantiles=1) keep the TD3 "
                "min-backup; set critic_quantiles > 1 (e.g. 25) to "
                "enable TQC truncation")

    @property
    def half_range(self) -> float:
        return 0.5 * (self.high - self.low)


# ---------------------------------------------------------------------------
# n-step targets from a rollout chunk (truncation-aware)
# ---------------------------------------------------------------------------

def nstep_targets(rewards: Array, dones: Array, truncated: Array,
                  next_obs: Array, gamma: float, n: int):
    """Fold a fresh [T, B] chunk into n-step transitions.

    For each start row t the window runs ``K = min(n, steps to the
    first episode boundary, T - t)`` steps.  Returns

      * ``returns``  [T, B]      sum_{k<K} gamma^k r_{t+k}
      * ``next_obs`` [T, B, ...] the true successor of the window's
        last step (pre-reset ``final_obs`` at boundaries)
      * ``discount`` [T, B]      gamma^K * (1 - terminated_at_end)

    so the target is always ``returns + discount * Q(next_obs)``:
    terminations zero the bootstrap, truncations keep it (through the
    pre-reset observation), and the chunk tail degrades to valid
    shorter-horizon targets rather than crossing into the next chunk.
    """
    if n < 1:
        raise ValueError(f"nstep_targets needs n >= 1, got {n}")
    T = rewards.shape[0]
    f32 = jnp.float32
    boundary = dones | truncated

    returns = rewards.astype(f32)
    nxt = next_obs
    term_end = dones
    gpow = jnp.full(rewards.shape, gamma, f32)       # gamma^K, K=1
    open_ = ~boundary                                # window extendable

    for k in range(1, min(n, T)):
        def shift(x, fill, k=k):
            pad = jnp.full((k,) + x.shape[1:], fill, x.dtype)
            return jnp.concatenate([x[k:], pad], axis=0)

        in_range = shift(jnp.ones_like(boundary), False)
        ext = open_ & in_range                       # extend to step t+k
        extm = ext.reshape(ext.shape + (1,) * (nxt.ndim - ext.ndim))
        returns = returns + jnp.where(
            ext, (gamma ** k) * shift(rewards.astype(f32), 0.0), 0.0)
        nxt = jnp.where(extm, shift(next_obs, 0.0), nxt)
        term_end = jnp.where(ext, shift(dones, False), term_end)
        gpow = jnp.where(ext, gamma ** (k + 1), gpow)
        open_ = ext & ~shift(boundary, True)

    discount = gpow * (1.0 - term_end.astype(f32))
    return returns, nxt, discount


# ---------------------------------------------------------------------------
# behaviour policy pieces
# ---------------------------------------------------------------------------

def epsilon(step: Array, cfg: DQNConfig) -> Array:
    frac = jnp.clip(step / cfg.eps_decay_steps, 0.0, 1.0)
    return cfg.eps_start + frac * (cfg.eps_end - cfg.eps_start)


def egreedy(key: Array, qvals: Array, eps: Array) -> Array:
    B, A = qvals.shape
    k1, k2 = jax.random.split(key)
    rand = jax.random.randint(k1, (B,), 0, A)
    greedy = jnp.argmax(qvals, axis=-1)
    return jnp.where(jax.random.uniform(k2, (B,)) < eps, rand, greedy)


def polyak(target, online, tau: float):
    """Soft target-network update: target += tau * (online - target)."""
    return jax.tree.map(lambda t, o: t + tau * (o - t), target, online)


def _weighted_mean(x: Array, weight: Optional[Array]) -> Array:
    """Batch mean of per-sample losses scaled by per-sample weights.

    The denominator is the BATCH SIZE, not ``sum(weight)``: PER
    importance weights must rescale each sample's contribution
    (canonical ``(1/B) * sum_i w_i * delta_i``), and dividing by
    ``sum(w)`` would cancel the batch-max normalization — skewed
    weights would then *amplify* the effective learning rate instead
    of only ever shrinking it.  For the uniform backend's all-ones
    weights this is exactly ``jnp.mean`` (bit-compatible), and the
    all-zero underfill mask still zeroes the loss.
    """
    if weight is None:
        return jnp.mean(x)
    return (x * weight).sum() / x.shape[0]


def _batch_discount(batch: dict, cfg) -> Array:
    """Discount column; legacy batches carry ``dones`` instead."""
    if "discounts" in batch:
        return batch["discounts"]
    return cfg.gamma * (1.0 - batch["dones"].astype(jnp.float32))


# ---------------------------------------------------------------------------
# losses
#
# Every loss has two faces: the scalar (the historical API, what
# jax.grad differentiates) and a ``*_td`` variant returning
# ``(loss, |td|)`` where ``|td|`` is the per-sample absolute TD error —
# the priority signal the PER backend writes back after each update
# (jax.grad(..., has_aux=True)).  All of them consume the batch's
# per-sample ``"weight"`` column (PER importance weights, or the 0/1
# underfill mask), so prioritized sampling stays unbiased.
# ---------------------------------------------------------------------------

def dqn_loss_td(params, target_params, apply_fn: Callable, batch: dict,
                cfg: DQNConfig):
    """(Double-)DQN TD error. ``apply_fn(params, obs) -> [B, A]``.
    Returns ``(loss, |td| per sample)``."""
    q = apply_fn(params, batch["obs"])
    q_sel = q[jnp.arange(q.shape[0]), batch["actions"]]
    q_next_t = apply_fn(target_params, batch["next_obs"])
    if cfg.double:
        a_star = jnp.argmax(apply_fn(params, batch["next_obs"]), axis=-1)
        q_next = q_next_t[jnp.arange(q_next_t.shape[0]), a_star]
    else:
        q_next = q_next_t.max(-1)
    target = batch["rewards"] + _batch_discount(batch, cfg) * q_next
    target = jax.lax.stop_gradient(target)
    td = q_sel - target
    loss = _weighted_mean(jnp.square(td), batch.get("weight"))
    return loss, jax.lax.stop_gradient(jnp.abs(td))


def dqn_loss(params, target_params, apply_fn: Callable, batch: dict,
             cfg: DQNConfig) -> Array:
    return dqn_loss_td(params, target_params, apply_fn, batch, cfg)[0]


def quantile_taus(n: int) -> Array:
    """Quantile midpoints tau_i = (2i + 1) / 2n."""
    return (jnp.arange(n, dtype=jnp.float32) + 0.5) / n


def quantile_huber(theta: Array, target: Array, kappa: float) -> Array:
    """Per-sample quantile-Huber loss between predicted quantiles
    ``theta`` [B, N] and target atoms ``target`` [B, M] (Dabney et
    al.): pairwise u[b, i, j] = target_j - theta_i, asymmetrically
    weighted by |tau_i - 1{u < 0}|.  Returns [B]."""
    N = theta.shape[-1]
    u = target[:, None, :] - theta[:, :, None]        # [B, N, M]
    absu = jnp.abs(u)
    huber = jnp.where(absu <= kappa,
                      0.5 * jnp.square(u),
                      kappa * (absu - 0.5 * kappa))
    taus = quantile_taus(N)[None, :, None]
    rho = jnp.abs(taus - (u < 0).astype(jnp.float32)) * huber / kappa
    return rho.mean(axis=2).sum(axis=1)               # [B]


def qrdqn_loss_td(params, target_params, apply_fn: Callable,
                  batch: dict, cfg: QRDQNConfig):
    """Quantile-regression DQN (Dabney et al.) with Double-DQN action
    selection.  ``apply_fn(params, obs) -> [B, A, n_quantiles]``.
    Returns ``(loss, |td| per sample)`` with the TD error measured
    between the quantile means (the priority signal)."""
    theta = apply_fn(params, batch["obs"])            # [B, A, N]
    B = theta.shape[0]
    rows = jnp.arange(B)
    theta_a = theta[rows, batch["actions"]]           # [B, N]

    next_t = apply_fn(target_params, batch["next_obs"])
    if cfg.double:
        a_star = jnp.argmax(
            apply_fn(params, batch["next_obs"]).mean(-1), axis=-1)
    else:
        a_star = jnp.argmax(next_t.mean(-1), axis=-1)
    next_q = next_t[rows, a_star]                     # [B, N]
    target = (batch["rewards"][:, None]
              + _batch_discount(batch, cfg)[:, None] * next_q)
    target = jax.lax.stop_gradient(target)

    per_sample = quantile_huber(theta_a, target, cfg.kappa)
    loss = _weighted_mean(per_sample, batch.get("weight"))
    td = jnp.abs(target.mean(-1) - theta_a.mean(-1))
    return loss, jax.lax.stop_gradient(td)


def qrdqn_loss(params, target_params, apply_fn: Callable, batch: dict,
               cfg: QRDQNConfig) -> Array:
    return qrdqn_loss_td(params, target_params, apply_fn, batch, cfg)[0]


def truncated_target_quantiles(z1_t: Array, z2_t: Array,
                               drop: int) -> Array:
    """TQC's truncation operator: pool both target critics' quantiles
    [B, N] + [B, N], sort ascending, drop the top ``drop`` — the
    left-tail mixture that replaces TD3's min() as the overestimation
    control.  Returns [B, 2N - drop]."""
    pooled = jnp.sort(jnp.concatenate([z1_t, z2_t], axis=-1), axis=-1)
    n_keep = pooled.shape[-1] - drop
    if n_keep < 1:
        raise ValueError(f"tqc drop={drop} leaves no target quantiles "
                         f"out of {pooled.shape[-1]}")
    return pooled[..., :n_keep]


def ddpg_critic_loss_td(critic_params, target_critic, target_actor,
                        critic_apply: Callable, actor_apply: Callable,
                        batch: dict, cfg: DDPGConfig, key: Array):
    """Twin-critic TD error with target-policy smoothing (TD3 eq. 14),
    or — when ``cfg.critic_quantiles > 1`` — the TQC backup: both
    target critics' quantiles pooled, sorted, top-``cfg.tqc_drop``
    truncated, then quantile-Huber regressed by each online critic.

    ``critic_apply(params, obs, act) -> (q1, q2)`` with [B] heads
    (scalar path) or [B, n_quantiles] heads (TQC path);
    ``actor_apply(params, obs) -> action`` already inside the bounds.
    Returns ``(loss, |td| per sample)``.
    """
    na = actor_apply(target_actor, batch["next_obs"])
    noise = jnp.clip(jax.random.normal(key, na.shape) * cfg.policy_noise,
                     -cfg.noise_clip, cfg.noise_clip) * cfg.half_range
    na = jnp.clip(na + noise, cfg.low, cfg.high)
    q1_t, q2_t = critic_apply(target_critic, batch["next_obs"], na)
    q1, q2 = critic_apply(critic_params, batch["obs"], batch["actions"])
    if cfg.critic_quantiles == 1:
        target = (batch["rewards"]
                  + _batch_discount(batch, cfg) * jnp.minimum(q1_t, q2_t))
        target = jax.lax.stop_gradient(target)
        err = jnp.square(q1 - target) + jnp.square(q2 - target)
        loss = _weighted_mean(err, batch.get("weight"))
        td = 0.5 * (jnp.abs(q1 - target) + jnp.abs(q2 - target))
        return loss, jax.lax.stop_gradient(td)
    kept = truncated_target_quantiles(q1_t, q2_t, cfg.tqc_drop)
    target = (batch["rewards"][:, None]
              + _batch_discount(batch, cfg)[:, None] * kept)
    target = jax.lax.stop_gradient(target)
    per_sample = (quantile_huber(q1, target, cfg.kappa)
                  + quantile_huber(q2, target, cfg.kappa))
    loss = _weighted_mean(per_sample, batch.get("weight"))
    td = jnp.abs(target.mean(-1) - 0.5 * (q1.mean(-1) + q2.mean(-1)))
    return loss, jax.lax.stop_gradient(td)


def ddpg_critic_loss(critic_params, target_critic, target_actor,
                     critic_apply: Callable, actor_apply: Callable,
                     batch: dict, cfg: DDPGConfig, key: Array) -> Array:
    return ddpg_critic_loss_td(critic_params, target_critic,
                               target_actor, critic_apply, actor_apply,
                               batch, cfg, key)[0]


def ddpg_actor_loss(actor_params, critic_params,
                    critic_apply: Callable, actor_apply: Callable,
                    batch: dict) -> Array:
    """Deterministic policy gradient: maximize Q1(s, pi(s)) (scalar
    critics), or the mean over both critics' quantiles (TQC — the
    actor sees the untruncated mixture, per Kuznetsov et al.)."""
    a = actor_apply(actor_params, batch["obs"])
    q1, q2 = critic_apply(critic_params, batch["obs"], a)
    if q1.ndim == 2:                                  # quantile heads
        q = 0.5 * (q1.mean(-1) + q2.mean(-1))
        return -_weighted_mean(q, batch.get("weight"))
    return -_weighted_mean(q1, batch.get("weight"))
