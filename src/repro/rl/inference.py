"""The shared policy-inference path: net reconstruction + action heads.

Training (``value_train``), evaluation (``value_eval``) and the batched
policy server (:mod:`repro.serve`) all act through the SAME objects in
this module — :func:`build_env` for the observation stack,
:func:`make_value_agent` for the net reconstruction, and
``ValueAgent.greedy``/``ValueAgent.sampled`` for the action heads — so
a served policy can never drift from what the evaluation loop measures:
there is exactly one greedy forward per algo, and the server calls it
with int8/int4 ``QTensor`` weights where the eval loop calls it with
fp32 weights under a fake-quant policy (bit-identical grids at w8 by
construction of :func:`repro.core.quantizer.quantize_params`).

Nothing here touches replay buffers, optimizers or target networks —
this is the layer a deployment loads, which is why it lives outside
``repro.launch``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.nn.module import unbox
from repro.rl.envs import Discrete, Environment, make
from repro.rl.envs.wrappers import (NormStats, ensure_vector_obs,
                                    pixel_pipeline)
from repro.rl.nets import (conv_q_apply, conv_q_init, conv_qr_apply,
                           conv_qr_init, mlp_pi_apply, mlp_pi_init,
                           mlp_q_apply, mlp_q_init, mlp_qr_apply,
                           mlp_qr_init, mlp_twin_q_apply, mlp_twin_q_init,
                           mlp_twin_qr_apply, mlp_twin_qr_init)
from repro.rl.value import (DDPGConfig, DQNConfig, QRDQNConfig,
                            dqn_loss_td, egreedy, qrdqn_loss_td)

Array = jax.Array

ON_POLICY_ALGOS = ("ppo", "a2c")
VALUE_ALGOS = ("dqn", "qrdqn", "ddpg")
NETS = ("mlp", "conv")


def build_env(env_name: str, net: str = "mlp", frame_stack_k: int = 1,
              norm_stats: Optional[NormStats] = None) -> Environment:
    """The launch-path env stack for one training/eval/serving run.

    ``net="conv"`` builds the pixel pipeline — running (Welford)
    observation normalization over raw frames, then ``frame_stack`` —
    so catch/keydoor reach the Q-Conv stem with no
    ``flatten_observation``.  ``norm_stats`` freezes the normalizer
    (evaluation/serving).  ``net="mlp"`` keeps the historical vector
    view (images are flattened); ``--frame-stack`` is a conv-net knob.
    """
    if net not in NETS:
        raise ValueError(f"unknown net {net!r} (expected one of {NETS})")
    env = make(env_name)
    if net == "conv":
        if len(env.obs_shape) != 3:
            raise ValueError(
                f"--net conv needs image (H, W, C) observations; "
                f"{env_name} has shape {env.obs_shape} — use --net mlp")
        return pixel_pipeline(env, frame_stack_k, stats=norm_stats)
    if frame_stack_k > 1:
        raise ValueError("--frame-stack is a pixel-pipeline knob and "
                         "requires --net conv")
    return ensure_vector_obs(env)


@dataclasses.dataclass
class ValueAgent:
    """Nets + behaviour/greedy policies for one value-based algo.

    ``behave`` is the *quantized* exploration policy the actor fleet
    runs (epsilon-greedy over Q, or deterministic actor + noise);
    ``greedy`` is the same policy with exploration off (evaluation and
    greedy serving); ``sampled`` is the stochastic serving head
    (Boltzmann over Q for Discrete, bounded Gaussian for Box).
    """

    algo: str
    cfg: object
    params: object
    discrete: bool
    qvals: Optional[Callable] = None      # (p, obs, policy) -> [B, A]
    act: Optional[Callable] = None        # (p, obs, policy) -> [B, d]
    q_apply: Optional[Callable] = None    # raw apply for the loss
    critic_apply: Optional[Callable] = None
    loss_fn: Optional[Callable] = None

    def behave(self, behaviour_params, obs, key, eps, policy):
        """``behaviour_params`` is the synced subtree only: the Q net
        (discrete) or the bare actor net (ddpg) — the twin critics
        never ship to the fleet."""
        if self.discrete:
            return egreedy(key,
                           self.qvals(behaviour_params, obs, policy),
                           eps)
        a = self.act(behaviour_params, obs, policy)
        noise = (jax.random.normal(key, a.shape)
                 * self.cfg.explore_noise * self.cfg.half_range)
        return jnp.clip(a + noise, self.cfg.low, self.cfg.high)

    def behaviour_subtree(self, params):
        """The weights the learner actually syncs to the actor fleet —
        also exactly the subtree a deployment serves."""
        return params["actor"] if self.algo == "ddpg" else params

    def from_behaviour(self, behaviour_params):
        """Inverse of :meth:`behaviour_subtree`: re-wrap a served
        subtree into the tree shape ``greedy``/``sampled`` expect."""
        if self.algo == "ddpg":
            return {"actor": behaviour_params}
        return behaviour_params

    def greedy(self, params, obs, policy=None):
        if self.discrete:
            return jnp.argmax(self.qvals(params, obs, policy), axis=-1)
        return self.act(params["actor"], obs, policy)

    def sampled(self, params, obs, key, temperature: float = 1.0,
                policy=None):
        """Stochastic action head for serving: Boltzmann exploration
        over the Q values (Discrete) or the greedy action + bounded
        Gaussian noise scaled by ``temperature`` x half-range (Box).
        ``temperature -> 0`` recovers ``greedy``."""
        t = jnp.maximum(jnp.asarray(temperature, jnp.float32), 1e-6)
        if self.discrete:
            return jax.random.categorical(
                key, self.qvals(params, obs, policy) / t)
        a = self.act(params["actor"], obs, policy)
        noise = jax.random.normal(key, a.shape) * t * self.cfg.half_range
        return jnp.clip(a + noise, self.cfg.low, self.cfg.high)


def make_value_agent(algo: str, spec, key=None,
                     n_step: int = 3,
                     eps_decay_steps: int = 2_000,
                     learn_start: Optional[int] = None,
                     net: str = "mlp", tqc_drop: int = 0,
                     critic_quantiles: int = 0,
                     hidden: Optional[int] = None) -> ValueAgent:
    """Build the nets/policies for one value algo.  ``key=None`` skips
    the parameter init (``agent.params`` is None) — for callers that
    only need the apply closures and config, e.g. evaluation of
    already-trained params.  ``net="conv"`` selects the Q-Conv pixel
    nets (dqn/qrdqn over (H, W, C) observations).

    ``tqc_drop > 0`` (ddpg only) switches the twin critics to TQC
    quantile heads and truncates the top-k pooled target quantiles in
    the Bellman backup; ``critic_quantiles`` sizes those heads (0 =
    auto: 25 when truncating, scalar critics otherwise — the default
    keeps today's TD3 min-backup bit-exact).  ``hidden`` overrides the
    torso width (None = the nets' default)."""
    def tune(cfg):
        if learn_start is None:
            return cfg
        return dataclasses.replace(cfg, learn_start=learn_start)

    hidden_kw = {} if hidden is None else {"hidden": hidden}
    if net not in NETS:
        raise ValueError(f"unknown net {net!r} (expected one of {NETS})")
    conv = net == "conv"
    if conv and len(spec.obs_shape) != 3:
        raise ValueError(f"--net conv needs image (H, W, C) "
                         f"observations; {spec.name} has shape "
                         f"{spec.obs_shape}")
    if not conv and len(spec.obs_shape) != 1:
        raise ValueError(
            f"{spec.name} has obs shape {spec.obs_shape}; use "
            "--net conv for pixel envs (the mlp value nets need flat "
            "observations)")
    obs_dim = spec.obs_shape[0] if not conv else None
    discrete = isinstance(spec.action_space, Discrete)
    if algo in ("dqn", "qrdqn") and not discrete:
        raise ValueError(f"--algo {algo} needs a Discrete action space; "
                         f"{spec.name} is continuous — use --algo ddpg")
    if algo == "ddpg" and discrete:
        raise ValueError(f"--algo ddpg needs a Box action space; "
                         f"{spec.name} is discrete — use dqn/qrdqn")
    if algo == "ddpg" and conv:
        raise ValueError("--net conv drives the discrete Q family "
                         "(dqn/qrdqn); ddpg has no pixel actor-critic")
    if (tqc_drop or critic_quantiles) and algo != "ddpg":
        raise ValueError("--tqc-drop truncates the DDPG critic targets; "
                         f"--algo {algo} has no twin critics")

    if algo == "qrdqn":
        cfg = tune(QRDQNConfig(n_step=n_step,
                               eps_decay_steps=eps_decay_steps))
        if key is None:
            params = None
        elif conv:
            params = unbox(conv_qr_init(key, spec.obs_shape,
                                        spec.n_actions, cfg.n_quantiles,
                                        **hidden_kw))
        else:
            params = unbox(mlp_qr_init(key, obs_dim, spec.n_actions,
                                       cfg.n_quantiles, **hidden_kw))
        qr_apply = conv_qr_apply if conv else mlp_qr_apply

        def q_apply(p, o, pol=None):
            return qr_apply(p, o, spec.n_actions, cfg.n_quantiles, pol)

        return ValueAgent(algo, cfg, params, True,
                          qvals=lambda p, o, pol=None:
                              q_apply(p, o, pol).mean(-1),
                          q_apply=q_apply, loss_fn=qrdqn_loss_td)
    if algo == "dqn":
        cfg = tune(DQNConfig(n_step=n_step,
                             eps_decay_steps=eps_decay_steps))
        if key is None:
            params = None
        elif conv:
            params = unbox(conv_q_init(key, spec.obs_shape,
                                       spec.n_actions, **hidden_kw))
        else:
            params = unbox(mlp_q_init(key, obs_dim, spec.n_actions,
                                      **hidden_kw))
        q_fn = conv_q_apply if conv else mlp_q_apply
        return ValueAgent(algo, cfg, params, True, qvals=q_fn,
                          q_apply=q_fn, loss_fn=dqn_loss_td)
    if algo != "ddpg":
        raise ValueError(f"unknown value algo {algo!r} "
                         f"(expected one of {VALUE_ALGOS})")
    space = spec.action_space
    if not space.bounded:
        raise ValueError("ddpg needs finite Box action bounds")
    act_dim = space.shape[0]
    if critic_quantiles == 0:
        # auto: truncation needs a return distribution to prune; the
        # default stays the scalar TD3 min-backup, bit-exact
        critic_quantiles = 25 if tqc_drop > 0 else 1
    cfg = tune(DDPGConfig(low=space.low, high=space.high,
                          n_step=n_step,
                          critic_quantiles=critic_quantiles,
                          tqc_drop=tqc_drop))
    quantile = cfg.critic_quantiles > 1
    if key is None:
        params = None
    else:
        ka, kc = jax.random.split(key)
        critic = (mlp_twin_qr_init(kc, obs_dim, act_dim,
                                   cfg.critic_quantiles, **hidden_kw)
                  if quantile else
                  mlp_twin_q_init(kc, obs_dim, act_dim, **hidden_kw))
        params = {"actor": unbox(mlp_pi_init(ka, obs_dim, act_dim,
                                             **hidden_kw)),
                  "critic": unbox(critic)}
    twin_apply = mlp_twin_qr_apply if quantile else mlp_twin_q_apply
    return ValueAgent(
        algo, cfg, params, False,
        act=lambda p, o, pol=None: mlp_pi_apply(p, o, cfg.low, cfg.high,
                                                pol),
        critic_apply=lambda p, o, a, pol=None:
            twin_apply(p, o, a, pol))
