from repro.rl.actor_learner import (collect, collect_sharded, fleet_mask,
                                    merge_results, pack_weights,
                                    sync_bytes, unpack_weights)
from repro.rl.dists import (ActionDist, Categorical, TanhGaussian,
                            distribution_for)
from repro.rl.envs import Environment, EnvSpec, make, register, registered
from repro.rl.gae import gae, normalize
from repro.rl.ppo import (PPOConfig, a2c_loss, batch_from_traj,
                          minibatch_epochs, ppo_loss, stage_mask)
from repro.rl.replay import (PERState, ReplayBuffer, make_replay,
                             per_add, per_init, per_sample, per_update)
from repro.rl.rollout import (RolloutResult, Trajectory, episode_returns,
                              episode_returns_from, init_envs, rollout)
from repro.rl.value import (DDPGConfig, DQNConfig, QRDQNConfig, Replay,
                            ddpg_actor_loss, ddpg_critic_loss,
                            ddpg_critic_loss_td, dqn_loss, dqn_loss_td,
                            egreedy, epsilon, nstep_targets, polyak,
                            qrdqn_loss, qrdqn_loss_td, replay_add,
                            replay_init, replay_sample,
                            truncated_target_quantiles)
