"""PPO (clipped) — the paper's training algorithm — plus A2C.

Supports the paper's *two-stage* HRL schedule: stage "action" trains
stem+action+value with the sub-goal frozen; stage "subgoal" fine-tunes
the sub-goal module with everything else frozen (Sec. III: "Once the
action module is trained, its weights are frozen, and the sub-goal
module is fine-tuned independently").  Freezing = zeroing grads by
subtree, which keeps optimizer state layout stable across stages.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.rl.dists import ActionDist, Categorical
from repro.rl.gae import gae, normalize
from repro.rl.rollout import Trajectory

Array = jax.Array

_CATEGORICAL = Categorical()


@dataclasses.dataclass(frozen=True)
class PPOConfig:
    gamma: float = 0.99
    lam: float = 0.95
    clip_eps: float = 0.2
    vf_coef: float = 0.5
    ent_coef: float = 0.01
    epochs: int = 4
    minibatches: int = 4
    normalize_adv: bool = True


def ppo_loss(params, apply_fn: Callable, batch: dict, cfg: PPOConfig,
             dist: Optional[ActionDist] = None) -> Tuple[Array, dict]:
    """batch: flat dict of [N, ...] tensors (obs, actions, log_probs,
    advantages, returns, mask).  ``dist`` defaults to Categorical; pass
    the env's ActionDist (e.g. TanhGaussian) for continuous control.
    """
    dist = dist or _CATEGORICAL
    dparams, values = apply_fn(params, batch["obs"])
    dparams = dparams.astype(jnp.float32)
    logp = dist.log_prob(dparams, batch["actions"])

    mask = batch.get("mask")
    mean = (lambda x: (x * mask).sum() / jnp.maximum(mask.sum(), 1)) \
        if mask is not None else jnp.mean

    ratio = jnp.exp(logp - batch["log_probs"])
    adv = batch["advantages"]
    pg = -jnp.minimum(
        ratio * adv,
        jnp.clip(ratio, 1 - cfg.clip_eps, 1 + cfg.clip_eps) * adv)
    pg_loss = mean(pg)

    v_loss = 0.5 * mean(jnp.square(values - batch["returns"]))
    entropy = mean(dist.entropy(dparams))

    loss = pg_loss + cfg.vf_coef * v_loss - cfg.ent_coef * entropy
    stats = {"pg_loss": pg_loss, "v_loss": v_loss, "entropy": entropy,
             "approx_kl": mean(batch["log_probs"] - logp)}
    return loss, stats


def a2c_loss(params, apply_fn: Callable, batch: dict, cfg: PPOConfig,
             dist: Optional[ActionDist] = None) -> Tuple[Array, dict]:
    dist = dist or _CATEGORICAL
    dparams, values = apply_fn(params, batch["obs"])
    dparams = dparams.astype(jnp.float32)
    logp = dist.log_prob(dparams, batch["actions"])

    # same liveness-mask contract as ppo_loss: a masked (dead/straggler)
    # slot contributes zero loss
    mask = batch.get("mask")
    mean = (lambda x: (x * mask).sum() / jnp.maximum(mask.sum(), 1)) \
        if mask is not None else jnp.mean

    pg_loss = -mean(logp * batch["advantages"])
    v_loss = 0.5 * mean(jnp.square(values - batch["returns"]))
    entropy = mean(dist.entropy(dparams))
    loss = pg_loss + cfg.vf_coef * v_loss - cfg.ent_coef * entropy
    return loss, {"pg_loss": pg_loss, "v_loss": v_loss,
                  "entropy": entropy}


def batch_from_traj(traj: Trajectory, last_value: Array,
                    cfg: PPOConfig,
                    actor_mask: Optional[Array] = None,
                    value_fn: Optional[Callable] = None) -> dict:
    """GAE over [T, B] then flatten to [T*B, ...].

    ``actor_mask`` [B] (1 = actor delivered, 0 = straggler/dead): masked
    actors contribute zero loss — the aggregator's timeout semantics —
    and are excluded from the advantage-normalization statistics so a
    dead slot's stale trajectory cannot skew the live envs' updates.

    ``value_fn`` (obs [N, ...] -> values [N]) prices the truncation
    bootstrap: one extra forward over ``traj.next_obs`` so timed-out
    rows bootstrap from V(final_obs) instead of being cut like
    terminations.  Pass the learner's value head (the rollout hot path
    stays untouched).  Without it, truncations fall back to the legacy
    cut-at-boundary targets (biased at timeouts).
    """
    if value_fn is not None:
        T, B = traj.rewards.shape
        nobs = traj.next_obs.reshape((T * B,) + traj.next_obs.shape[2:])
        boot = value_fn(nobs).reshape(T, B)
        advs, rets = gae(traj.rewards, traj.values, traj.dones,
                         last_value, cfg.gamma, cfg.lam,
                         truncated=traj.truncated, bootstrap_values=boot)
    else:
        advs, rets = gae(traj.rewards, traj.values, traj.boundary,
                         last_value, cfg.gamma, cfg.lam)
    if cfg.normalize_adv:
        if actor_mask is not None:
            w = jnp.broadcast_to(actor_mask[None].astype(jnp.float32),
                                 advs.shape)
            n = jnp.maximum(w.sum(), 1.0)
            mu = (advs * w).sum() / n
            std = jnp.sqrt(jnp.maximum(
                (jnp.square(advs - mu) * w).sum() / n, 0.0))
            advs = (advs - mu) / (std + 1e-8)
        else:
            advs = normalize(advs)
    T, B = traj.rewards.shape
    flat = lambda x: x.reshape((T * B,) + x.shape[2:])
    batch = {
        "obs": flat(traj.obs),
        "actions": flat(traj.actions),
        "log_probs": flat(traj.log_probs),
        "advantages": flat(advs),
        "returns": flat(rets),
    }
    if actor_mask is not None:
        batch["mask"] = flat(
            jnp.broadcast_to(actor_mask[None].astype(jnp.float32),
                             (T, B)))
    return batch


# ---------------------------------------------------------------------------
# two-stage freezing
# ---------------------------------------------------------------------------

def stage_mask(params, stage: str):
    """1/0 pytree: which leaves train in this stage.

    stage "action":  stem + action head + value head (sub-goal frozen)
    stage "subgoal": sub-goal module only
    stage "all":     everything (non-hierarchical nets)
    """
    if stage == "all":
        return jax.tree.map(lambda _: 1.0, params)

    def mask_subtree(tree, on):
        return jax.tree.map(lambda _: 1.0 if on else 0.0, tree)

    out = {}
    for name, sub in params.items():
        trainable = (name == "subgoal") == (stage == "subgoal")
        out[name] = mask_subtree(sub, trainable)
    return out


def apply_stage_mask(grads, mask):
    return jax.tree.map(lambda g, m: g * m, grads, mask)


def minibatch_epochs(key, params, opt_state, batch, apply_fn, cfg,
                     optimizer_step, loss_fn=ppo_loss, grad_mask=None,
                     dist: Optional[ActionDist] = None):
    """Standard PPO epochs x minibatches loop (python loop: trace-time
    constants, jit the caller)."""
    n = batch["obs"].shape[0]
    if n % cfg.minibatches != 0:
        raise ValueError(
            f"minibatch_epochs: batch of {n} samples (rollout T*B) does "
            f"not divide into cfg.minibatches={cfg.minibatches} — the "
            f"tail {n % cfg.minibatches} samples would be silently "
            "dropped every epoch. Pick n_envs*rollout_len divisible by "
            "the minibatch count, or adjust PPOConfig.minibatches.")
    mb = n // cfg.minibatches
    stats = None
    # keep the historical 4-arg loss_fn contract intact when no dist
    # is supplied (custom losses need not know about ActionDist)
    extra = () if dist is None else (dist,)
    for _ in range(cfg.epochs):
        key, sub = jax.random.split(key)
        perm = jax.random.permutation(sub, n)
        for i in range(cfg.minibatches):
            idx = jax.lax.dynamic_slice_in_dim(perm, i * mb, mb)
            mbatch = {k: v[idx] for k, v in batch.items()}
            (_, stats), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, apply_fn, mbatch, cfg,
                                       *extra)
            if grad_mask is not None:
                grads = apply_stage_mask(grads, grad_mask)
            params, opt_state = optimizer_step(params, opt_state, grads)
    return params, opt_state, stats
