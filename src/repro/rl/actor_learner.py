"""Q-Actor distributed actor-learner (paper Fig. 2), TPU-native.

Learner: full-precision PPO updates.
Actors:  rollouts under a *quantized* copy of the policy (FxP8 by
default) — the paper's core speed/comm lever.

Sync is modeled exactly as the paper argues it matters:
  learner -> actor: int8 payload + fp scales (``pack_weights``), a
      ~4x wire-byte cut measured by ``sync_bytes``;
  actor -> learner: trajectories, aggregated with a liveness mask —
      a dead/straggling actor's slot is masked out of the PPO loss
      (timeout semantics), so the step never blocks on one actor.
Policy lag: ``FleetSync`` is a versioned mailbox of packed weights —
the learner pushes, slots fetch at a chosen lag (0 lock-step, 1
double-buffered overlap), and per-slot staleness drives the ``alive``
straggler mask (asynchrony via dispatch overlap, not threads — the
math, staleness and payloads are faithful; transport is jit-internal).

On a real mesh the actor fleet is shard_map'd over the data axes by
``collect_sharded``: the packed int8 weights are broadcast once per
sync, each device dequantizes locally and rolls B/n_devices
environments, and the outputs come back as one global (batch-sharded)
``RolloutResult`` — see launch/rl_train.py for the driver.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.fxp import QTensor
from repro.core.policy import QuantPolicy
from repro.core.quantizer import (dequantize_params, quantize_params,
                                  quantized_nbytes)
from repro.distributed.sharding import data_axes, data_axis_size, shard_map
from repro.rl.dists import ActionDist, distribution_for
from repro.rl.envs.base import Environment
from repro.rl.rollout import RolloutResult, rollout

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ActorLearnerConfig:
    n_actors: int = 4
    envs_per_actor: int = 16
    rollout_len: int = 64
    comm_bits: int = 8           # learner->actor payload precision
    max_lag: int = 1             # staleness window (versions)


# -- weight sync ------------------------------------------------------------

def pack_weights(params, comm_bits: int):
    """Quantize the param tree for the wire (QTensor leaves)."""
    if comm_bits >= 32:
        return params
    return quantize_params(params, QuantPolicy(w_bits=comm_bits,
                                               per_channel=True))


def unpack_weights(packed):
    return dequantize_params(packed)


def sync_bytes(packed) -> Tuple[int, int]:
    """(payload_bytes, fp32_equivalent_bytes) for one sync."""
    stored, fp32 = quantized_nbytes(packed)
    return stored, fp32


# -- the actor fleet ---------------------------------------------------------

class FleetSync:
    """Versioned int8 weight mailbox between the learner and the fleet.

    The learner ``push``es each new packed version; actor slots
    ``fetch`` with a chosen lag (0 = lock-step, 1 = double-buffered:
    the collect for iteration k+1 runs against version k while the
    learner's k+1 update is still in flight).  Each fetch is recorded
    per slot, so ``staleness``/``alive`` are *derived* from what the
    fleet actually read — a slot that stops fetching (straggler /
    dead actor) drops out of ``alive()`` once it falls more than
    ``max_lag`` versions behind, and the driver masks its batch out of
    the loss via ``fleet_mask`` instead of blocking on it.
    """

    def __init__(self, n_slots: int, max_lag: int = 1, depth: int = 2):
        self.n_slots = max(n_slots, 1)
        self.max_lag = max(max_lag, 1)
        self.depth = max(depth, max_lag + 1, 2)
        self._buf: List = []                      # [(version, packed)]
        self._version = -1
        self._seen = [-1] * self.n_slots

    @property
    def version(self) -> int:
        """Latest published version id (-1 before the first push)."""
        return self._version

    def push(self, packed) -> int:
        self._version += 1
        self._buf.append((self._version, packed))
        if len(self._buf) > self.depth:
            self._buf.pop(0)
        return self._version

    def fetch(self, lag: int = 0, slots: Optional[List[int]] = None):
        """Read the version ``lag`` behind the newest (clamped to the
        oldest retained) and record the read for ``slots`` (default:
        the whole fleet)."""
        idx = max(len(self._buf) - 1 - max(lag, 0), 0)
        version, packed = self._buf[idx]
        for s in (range(self.n_slots) if slots is None else slots):
            self._seen[s] = version
        return packed

    def staleness(self) -> Array:
        """Versions-behind-newest per slot, [n_slots] int32."""
        return jnp.asarray([self._version - s for s in self._seen],
                           jnp.int32)

    def alive(self) -> Array:
        """[n_slots] bool — slots within the staleness budget."""
        return self.staleness() <= self.max_lag


def collect(packed, env: Environment, apply_fn: Callable,
            actor_policy: Optional[QuantPolicy], key: Array,
            env_state, obs, n_steps: int,
            dist: Optional[ActionDist] = None) -> RolloutResult:
    """One actor's contribution: dequantize the synced weights, roll."""
    params = unpack_weights(packed)
    fn = (lambda p, o: apply_fn(p, o, actor_policy))
    return rollout(params, env, fn, key, env_state, obs, n_steps, dist)


def fleet_mask(alive: Array, envs_per_slot: int) -> Array:
    """Env-level float mask [n_slots * envs_per_slot] from a per-slot
    liveness vector (slot = actor in the emulation, device on a mesh)."""
    return jnp.repeat(alive.astype(jnp.float32), envs_per_slot)


def merge_results(results: List[RolloutResult],
                  alive: Array) -> Tuple[RolloutResult, Array]:
    """Stack per-actor results along the env axis; return (merged,
    env-level mask [n_actors*B]) for the masked PPO loss.

    ``alive`` [n_actors] bool — False marks a straggler whose batch is
    present (shape-stable) but masked to zero weight.

    The merged result honors the full ``RolloutResult`` contract: the
    env-state leaves are tree-concatenated along the env axis, so the
    merged ``final_env``/``final_obs`` resume collection directly.
    """
    traj = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=1),
                        *[r.traj for r in results])
    last_value = jnp.concatenate([r.last_value for r in results])
    final_env = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                             *[r.final_env for r in results])
    n_envs = results[0].last_value.shape[0]
    mask = fleet_mask(alive, n_envs)
    merged = RolloutResult(traj, last_value, final_env,
                           jnp.concatenate([r.final_obs for r in results]))
    return merged, mask


# -- sharded execution on a device mesh --------------------------------------

def collect_sharded(packed, env: Environment, apply_fn: Callable,
                    actor_policy: Optional[QuantPolicy], key: Array,
                    env_state, obs, n_steps: int, mesh: Mesh,
                    dist: Optional[ActionDist] = None) -> RolloutResult:
    """shard_map the actor fleet over the mesh's data axes.

    Global [B, ...] ``env_state``/``obs`` in, one global (batch-sharded)
    ``RolloutResult`` out.  The packed int8 weights and the key are
    broadcast; device ``d`` dequantizes locally and rolls envs
    ``[d*B/n, (d+1)*B/n)`` under the stream ``fold_in(key, d)`` — so the
    per-device RNG streams are independent by construction, and on a
    1-device mesh the result is bit-identical to
    ``collect(..., key=fold_in(key, 0), ...)``.
    """
    axes = data_axes(mesh)
    if not axes:
        raise ValueError(f"mesh {mesh.axis_names} has no data axes to "
                         "shard the actor fleet over")
    n_slots = data_axis_size(mesh)
    B = jax.tree.leaves(obs)[0].shape[0]
    if B % n_slots != 0:
        raise ValueError(
            f"n_envs={B} does not divide evenly over the mesh's "
            f"{n_slots} data slot(s) "
            f"({dict(zip(mesh.axis_names, mesh.devices.shape, strict=True))})")
    if dist is None:
        dist = distribution_for(env.action_space)

    def slot_index():
        idx = jax.lax.axis_index(axes[0])
        for a in axes[1:]:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        return idx

    def body(packed, key, est, obs):
        key = jax.random.fold_in(key, slot_index())
        return collect(packed, env, apply_fn, actor_policy, key, est, obs,
                       n_steps, dist)

    batch = P(axes)             # env axis (axis 0) over the data axes
    time_major = P(None, axes)  # trajectory leaves are [T, B, ...]
    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(), P(), batch, batch),
                   out_specs=RolloutResult(traj=time_major,
                                           last_value=batch,
                                           final_env=batch,
                                           final_obs=batch),
                   check_replication=False)
    return fn(packed, key, env_state, obs)


# -- value-family collection (eps-greedy / noisy behaviour actors) ------------

def slot_keys(key: Array, n_slots: int) -> Array:
    """Per-slot RNG key stack [n_slots, key_shape].

    Slot 0 keeps the caller's raw key so a 1-slot sharded run consumes
    exactly the stream the single-device path does (bit-exact by
    construction); slots d > 0 fold in the slot index for independent
    streams.  Note this differs from the on-policy ``collect_sharded``
    convention, which folds the index into every slot including 0.
    """
    ks = [key] + [jax.random.fold_in(key, d) for d in range(1, n_slots)]
    return jnp.stack(ks)


def slot_key(key: Array, idx) -> Array:
    """In-graph counterpart of ``slot_keys`` for a *traced* slot index
    (``lax.axis_index`` inside shard_map): slot 0 keeps the raw key,
    others fold the index in — bitwise the same per-slot streams as
    ``slot_keys(key, n)[idx]``."""
    return jnp.where(idx == 0, key, jax.random.fold_in(key, idx))


def collect_value(packed, env: Environment, behave_fn: Callable,
                  actor_policy: Optional[QuantPolicy], key: Array,
                  env_state, obs, n_steps: int, eps: Array):
    """One value-family actor's contribution: dequantize the synced
    weights once, scan ``n_steps`` behaviour-policy env steps.

    Returns ``((est, obs), (O, A, R, D, Tr, FO))`` with time-major
    [T, B, ...] trajectory leaves — the exact scan the value iteration
    ran inline before this was extracted, bit for bit.
    """
    actor_params = unpack_weights(packed)

    def one_full(carry, k):
        est, o = carry
        a = behave_fn(actor_params, o, k, eps, actor_policy)
        est, nxt, r, d, tr, fo = jax.vmap(env.step)(est, a)
        return (est, nxt), (o, a, r, d, tr, fo)

    keys = jax.random.split(key, n_steps)
    return jax.lax.scan(one_full, (env_state, obs), keys)


def collect_value_sharded(packed, env: Environment, behave_fn: Callable,
                          actor_policy: Optional[QuantPolicy], key: Array,
                          env_state, obs, n_steps: int, eps: Array,
                          mesh: Mesh):
    """shard_map the value-family fleet over the mesh's data axes.

    The packed int8 weights and epsilon are broadcast; device ``d``
    dequantizes locally and rolls its envs under ``slot_keys(key)[d]``.
    On a 1-device mesh the output is bit-identical to
    ``collect_value(..., key, ...)`` — slot 0 keeps the raw stream.
    """
    axes = data_axes(mesh)
    if not axes:
        raise ValueError(f"mesh {mesh.axis_names} has no data axes to "
                         "shard the actor fleet over")
    n_slots = data_axis_size(mesh)
    B = jax.tree.leaves(obs)[0].shape[0]
    if B % n_slots != 0:
        raise ValueError(
            f"n_envs={B} does not divide evenly over the mesh's "
            f"{n_slots} data slot(s) "
            f"({dict(zip(mesh.axis_names, mesh.devices.shape, strict=True))})")
    keys = slot_keys(key, n_slots)

    def body(packed, keys, eps, est, obs):
        return collect_value(packed, env, behave_fn, actor_policy,
                             keys[0], est, obs, n_steps, eps)

    batch = P(axes)             # env axis (axis 0) over the data axes
    time_major = P(None, axes)  # trajectory leaves are [T, B, ...]
    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(), batch, P(), batch, batch),
                   out_specs=((batch, batch), (time_major,) * 6),
                   check_replication=False)
    return fn(packed, keys, eps, env_state, obs)
