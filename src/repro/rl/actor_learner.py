"""Q-Actor distributed actor-learner (paper Fig. 2), TPU-native.

Learner: full-precision PPO updates.
Actors:  rollouts under a *quantized* copy of the policy (FxP8 by
default) — the paper's core speed/comm lever.

Sync is modeled exactly as the paper argues it matters:
  learner -> actor: int8 payload + fp scales (``pack_weights``), a
      ~4x wire-byte cut measured by ``sync_bytes``;
  actor -> learner: trajectories, aggregated with a liveness mask —
      a dead/straggling actor's slot is masked out of the PPO loss
      (timeout semantics), so the step never blocks on one actor.
Policy lag: a FIFO of the last ``max_lag`` packed versions lets actors
run k versions stale (asynchrony without an actual async runtime — the
math, staleness and payloads are faithful; transport is jit-internal).

On the production mesh the actor fleet is shard_map'd over the data
axes, so each device hosts B/n_devices environments; see
launch/rl_train.py.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.fxp import QTensor
from repro.core.policy import QuantPolicy
from repro.core.quantizer import (dequantize_params, quantize_params,
                                  quantized_nbytes)
from repro.rl.dists import ActionDist
from repro.rl.envs.base import Environment
from repro.rl.rollout import RolloutResult, rollout

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ActorLearnerConfig:
    n_actors: int = 4
    envs_per_actor: int = 16
    rollout_len: int = 64
    comm_bits: int = 8           # learner->actor payload precision
    max_lag: int = 1             # staleness window (versions)


# -- weight sync ------------------------------------------------------------

def pack_weights(params, comm_bits: int):
    """Quantize the param tree for the wire (QTensor leaves)."""
    if comm_bits >= 32:
        return params
    return quantize_params(params, QuantPolicy(w_bits=comm_bits,
                                               per_channel=True))


def unpack_weights(packed):
    return dequantize_params(packed)


def sync_bytes(packed) -> Tuple[int, int]:
    """(payload_bytes, fp32_equivalent_bytes) for one sync."""
    stored, fp32 = quantized_nbytes(packed)
    return stored, fp32


# -- the actor fleet ---------------------------------------------------------

class VersionBuffer:
    """FIFO of packed weight versions (policy-lag emulation)."""

    def __init__(self, max_lag: int):
        self.max_lag = max(max_lag, 1)
        self._buf: List = []

    def push(self, packed):
        self._buf.append(packed)
        if len(self._buf) > self.max_lag:
            self._buf.pop(0)

    def stale(self, lag: int = 0):
        """lag=0 -> freshest available; lag=k -> k versions old."""
        idx = max(len(self._buf) - 1 - lag, 0)
        return self._buf[idx]


def collect(packed, env: Environment, apply_fn: Callable,
            actor_policy: Optional[QuantPolicy], key: Array,
            env_state, obs, n_steps: int,
            dist: Optional[ActionDist] = None) -> RolloutResult:
    """One actor's contribution: dequantize the synced weights, roll."""
    params = unpack_weights(packed)
    fn = (lambda p, o: apply_fn(p, o, actor_policy))
    return rollout(params, env, fn, key, env_state, obs, n_steps, dist)


def merge_results(results: List[RolloutResult],
                  alive: Array) -> Tuple[RolloutResult, Array]:
    """Stack per-actor results along the env axis; return (merged,
    env-level mask [n_actors*B]) for the masked PPO loss.

    ``alive`` [n_actors] bool — False marks a straggler whose batch is
    present (shape-stable) but masked to zero weight.
    """
    traj = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=1),
                        *[r.traj for r in results])
    last_value = jnp.concatenate([r.last_value for r in results])
    n_envs = results[0].last_value.shape[0]
    mask = jnp.repeat(alive.astype(jnp.float32), n_envs)
    merged = RolloutResult(traj, last_value,
                           [r.final_env for r in results],
                           jnp.concatenate([r.final_obs for r in results]))
    return merged, mask
