"""Q-Actor distributed actor-learner (paper Fig. 2), TPU-native.

Learner: full-precision PPO updates.
Actors:  rollouts under a *quantized* copy of the policy (FxP8 by
default) — the paper's core speed/comm lever.

Sync is modeled exactly as the paper argues it matters:
  learner -> actor: int8 payload + fp scales (``pack_weights``), a
      ~4x wire-byte cut measured by ``sync_bytes``;
  actor -> learner: trajectories, aggregated with a liveness mask —
      a dead/straggling actor's slot is masked out of the PPO loss
      (timeout semantics), so the step never blocks on one actor.
Policy lag: a FIFO of the last ``max_lag`` packed versions lets actors
run k versions stale (asynchrony without an actual async runtime — the
math, staleness and payloads are faithful; transport is jit-internal).

On a real mesh the actor fleet is shard_map'd over the data axes by
``collect_sharded``: the packed int8 weights are broadcast once per
sync, each device dequantizes locally and rolls B/n_devices
environments, and the outputs come back as one global (batch-sharded)
``RolloutResult`` — see launch/rl_train.py for the driver.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.fxp import QTensor
from repro.core.policy import QuantPolicy
from repro.core.quantizer import (dequantize_params, quantize_params,
                                  quantized_nbytes)
from repro.distributed.sharding import data_axes, data_axis_size, shard_map
from repro.rl.dists import ActionDist, distribution_for
from repro.rl.envs.base import Environment
from repro.rl.rollout import RolloutResult, rollout

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ActorLearnerConfig:
    n_actors: int = 4
    envs_per_actor: int = 16
    rollout_len: int = 64
    comm_bits: int = 8           # learner->actor payload precision
    max_lag: int = 1             # staleness window (versions)


# -- weight sync ------------------------------------------------------------

def pack_weights(params, comm_bits: int):
    """Quantize the param tree for the wire (QTensor leaves)."""
    if comm_bits >= 32:
        return params
    return quantize_params(params, QuantPolicy(w_bits=comm_bits,
                                               per_channel=True))


def unpack_weights(packed):
    return dequantize_params(packed)


def sync_bytes(packed) -> Tuple[int, int]:
    """(payload_bytes, fp32_equivalent_bytes) for one sync."""
    stored, fp32 = quantized_nbytes(packed)
    return stored, fp32


# -- the actor fleet ---------------------------------------------------------

class VersionBuffer:
    """FIFO of packed weight versions (policy-lag emulation)."""

    def __init__(self, max_lag: int):
        self.max_lag = max(max_lag, 1)
        self._buf: List = []

    def push(self, packed):
        self._buf.append(packed)
        if len(self._buf) > self.max_lag:
            self._buf.pop(0)

    def stale(self, lag: int = 0):
        """lag=0 -> freshest available; lag=k -> k versions old."""
        idx = max(len(self._buf) - 1 - lag, 0)
        return self._buf[idx]


def collect(packed, env: Environment, apply_fn: Callable,
            actor_policy: Optional[QuantPolicy], key: Array,
            env_state, obs, n_steps: int,
            dist: Optional[ActionDist] = None) -> RolloutResult:
    """One actor's contribution: dequantize the synced weights, roll."""
    params = unpack_weights(packed)
    fn = (lambda p, o: apply_fn(p, o, actor_policy))
    return rollout(params, env, fn, key, env_state, obs, n_steps, dist)


def fleet_mask(alive: Array, envs_per_slot: int) -> Array:
    """Env-level float mask [n_slots * envs_per_slot] from a per-slot
    liveness vector (slot = actor in the emulation, device on a mesh)."""
    return jnp.repeat(alive.astype(jnp.float32), envs_per_slot)


def merge_results(results: List[RolloutResult],
                  alive: Array) -> Tuple[RolloutResult, Array]:
    """Stack per-actor results along the env axis; return (merged,
    env-level mask [n_actors*B]) for the masked PPO loss.

    ``alive`` [n_actors] bool — False marks a straggler whose batch is
    present (shape-stable) but masked to zero weight.

    The merged result honors the full ``RolloutResult`` contract: the
    env-state leaves are tree-concatenated along the env axis, so the
    merged ``final_env``/``final_obs`` resume collection directly.
    """
    traj = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=1),
                        *[r.traj for r in results])
    last_value = jnp.concatenate([r.last_value for r in results])
    final_env = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                             *[r.final_env for r in results])
    n_envs = results[0].last_value.shape[0]
    mask = fleet_mask(alive, n_envs)
    merged = RolloutResult(traj, last_value, final_env,
                           jnp.concatenate([r.final_obs for r in results]))
    return merged, mask


# -- sharded execution on a device mesh --------------------------------------

def collect_sharded(packed, env: Environment, apply_fn: Callable,
                    actor_policy: Optional[QuantPolicy], key: Array,
                    env_state, obs, n_steps: int, mesh: Mesh,
                    dist: Optional[ActionDist] = None) -> RolloutResult:
    """shard_map the actor fleet over the mesh's data axes.

    Global [B, ...] ``env_state``/``obs`` in, one global (batch-sharded)
    ``RolloutResult`` out.  The packed int8 weights and the key are
    broadcast; device ``d`` dequantizes locally and rolls envs
    ``[d*B/n, (d+1)*B/n)`` under the stream ``fold_in(key, d)`` — so the
    per-device RNG streams are independent by construction, and on a
    1-device mesh the result is bit-identical to
    ``collect(..., key=fold_in(key, 0), ...)``.
    """
    axes = data_axes(mesh)
    if not axes:
        raise ValueError(f"mesh {mesh.axis_names} has no data axes to "
                         "shard the actor fleet over")
    n_slots = data_axis_size(mesh)
    B = jax.tree.leaves(obs)[0].shape[0]
    if B % n_slots != 0:
        raise ValueError(
            f"n_envs={B} does not divide evenly over the mesh's "
            f"{n_slots} data slot(s) "
            f"({dict(zip(mesh.axis_names, mesh.devices.shape, strict=True))})")
    if dist is None:
        dist = distribution_for(env.action_space)

    def slot_index():
        idx = jax.lax.axis_index(axes[0])
        for a in axes[1:]:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        return idx

    def body(packed, key, est, obs):
        key = jax.random.fold_in(key, slot_index())
        return collect(packed, env, apply_fn, actor_policy, key, est, obs,
                       n_steps, dist)

    batch = P(axes)             # env axis (axis 0) over the data axes
    time_major = P(None, axes)  # trajectory leaves are [T, B, ...]
    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(), P(), batch, batch),
                   out_specs=RolloutResult(traj=time_major,
                                           last_value=batch,
                                           final_env=batch,
                                           final_obs=batch),
                   check_replication=False)
    return fn(packed, key, env_state, obs)
