"""Jitted per-iteration step functions for both training families.

Factories, not loose functions: each returns the *already-jitted*
iteration with the donation contract baked in, closing over everything
that is static for a run (env, nets, optimizer config, replay
backend).  Extracted from ``launch/rl_train.py`` so that

* the drivers stay orchestration-only (checkpoint flow, logging,
  weight-sync bookkeeping), and
* the trace audit (:mod:`repro.analysis.trace_audit`) can lower the
  real step functions abstractly — the exact programs training runs —
  and assert dtype/donation invariants on them without running a
  single iteration.

Donation contracts (QF401):

* on-policy ``iteration(params, opt, est, obs, packed, key, gmask,
  alive)`` donates ``opt``/``est``/``obs`` (argnums 1-3) — the
  threaded state.  ``params`` is NOT donated: ``packed`` aliases its
  unquantized leaves (biases, or the whole tree under fp32 actors),
  and a buffer cannot be both donated and passed again.
* value-based ``iteration(params, target, opt, buf, packed, est, obs,
  key, it)`` donates ``target``/``opt``/``buf``/``est``/``obs``
  (argnums 1, 2, 3, 5, 6) — without it XLA copies the whole replay
  buffer (capacity x obs, the dominant allocation) every iteration
  just to apply the circular write.  Same ``params``/``packed``
  aliasing caveat.
* the sharded value step (``make_sharded_value_iteration``) appends a
  per-slot ``alive`` arg but keeps the identical donation contract —
  the audit asserts donation survives the shard_map'd lowering too.

Telemetry (``metrics=...``): each factory optionally threads a
:mod:`repro.obs.metrics` buffer through the jitted step — appended as
the LAST argument, donated, and returned last, exactly like replay
state.  The metric updates consume already-computed traced values
(``ret``/``n_ep``/replay fill) and feed nothing back into the training
math, so the instrumented step stays bitwise identical to the
uninstrumented one (docs/observability.md contract; test-asserted).
With ``metrics=None`` (the default, and what the trace audit lowers)
signatures and donation contracts are exactly the historical ones
above.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import data_axes, shard_map
from repro.obs.metrics import counter_add, gauge_max, gauge_set
from repro.optim import adamw_update
from repro.rl.actor_learner import (collect_sharded, collect_value,
                                    collect_value_sharded, fleet_mask,
                                    slot_key)
from repro.rl.ppo import batch_from_traj, minibatch_epochs
from repro.rl.replay import (normalize_weights, per_global_weights,
                             replay_size)
from repro.rl.rollout import episode_returns, episode_returns_from
from repro.rl.value import (ddpg_actor_loss, ddpg_critic_loss_td,
                            epsilon, nstep_targets, polyak)


def make_onpolicy_iteration(env, apply_fn, a_policy, mesh, dist, pcfg,
                            loss_fn, sched, ocfg, *, rollout_len: int,
                            n_envs: int, n_slots: int, metrics=None):
    """One sharded-collect + minibatch-update step (ppo / a2c)."""
    learner_apply = lambda p, o: apply_fn(p, o, None)  # noqa: E731

    def body(params, opt, est, obs, packed, key, gmask, alive):
        k1, k2 = jax.random.split(key)
        res = collect_sharded(packed, env, apply_fn, a_policy, k1, est,
                              obs, rollout_len, mesh, dist)
        mask = fleet_mask(alive, n_envs // n_slots)
        # the learner's fp32 value head prices the truncation bootstrap
        batch = batch_from_traj(res.traj, res.last_value, pcfg,
                                actor_mask=mask,
                                value_fn=lambda o: learner_apply(params,
                                                                 o)[1])

        def opt_step(p, s, g):
            p, s, _ = adamw_update(g, s, p, sched, ocfg)
            return p, s

        params, opt, stats = minibatch_epochs(
            k2, params, opt, batch, learner_apply, pcfg, opt_step,
            loss_fn=loss_fn, grad_mask=gmask, dist=dist)
        ret, n_ep = episode_returns(res.traj)
        return params, opt, res.final_env, res.final_obs, ret, n_ep

    if metrics is None:
        return jax.jit(body, donate_argnums=(1, 2, 3))

    @partial(jax.jit, donate_argnums=(1, 2, 3, 8))
    def iteration(params, opt, est, obs, packed, key, gmask, alive,
                  mbuf):
        params, opt, est, obs, ret, n_ep = body(
            params, opt, est, obs, packed, key, gmask, alive)
        mbuf = counter_add(mbuf, "env_steps", rollout_len * n_envs)
        mbuf = counter_add(mbuf, "episodes", n_ep)
        mbuf = gauge_set(mbuf, "return_mean", ret)
        mbuf = gauge_set(mbuf, "alive_frac",
                         jnp.mean(alive.astype(jnp.float32)))
        return params, opt, est, obs, ret, n_ep, mbuf

    return iteration


def _value_metric_updates(mbuf, rb, *, env_steps, n_ep, ret, eps, buf):
    """The value-family metric writes, shared by the single-device and
    sharded steps (replay_size already sums a slot-leading state)."""
    mbuf = counter_add(mbuf, "env_steps", env_steps)
    mbuf = counter_add(mbuf, "episodes", n_ep)
    mbuf = gauge_set(mbuf, "return_mean", ret)
    mbuf = gauge_set(mbuf, "epsilon", eps)
    mbuf = gauge_set(mbuf, "replay_size", replay_size(buf))
    if rb.prioritized:
        mbuf = gauge_max(mbuf, "replay_max_priority",
                         jnp.max(buf.max_p))
    return mbuf


def make_value_iteration(env, agent, rb, a_policy, sched, ocfg, *,
                         algo: str, rollout_len: int,
                         updates_per_iter: int, per_beta0: float,
                         beta_iters: int, metrics=None):
    """One collect-into-replay + sampled-updates step (dqn / qrdqn /
    ddpg)."""
    cfg = agent.cfg
    discrete = agent.discrete

    def body(params, target, opt, buf, packed, est, obs, key, it):
        k_collect, k_update = jax.random.split(key)
        eps = (epsilon(it * rollout_len, cfg) if discrete
               else jnp.zeros(()))
        (est, obs), (O, A, R, D, Tr, FO) = collect_value(
            packed, env, agent.behave, a_policy, k_collect, est, obs,
            rollout_len, eps)

        rets, nxt, disc = nstep_targets(R, D, Tr, FO, cfg.gamma,
                                        cfg.n_step)
        T, B = R.shape
        flat = lambda x: x.reshape((T * B,) + x.shape[2:])  # noqa: E731
        buf = rb.add(buf, flat(O), flat(A), flat(rets), flat(nxt),
                     flat(disc))

        # PER bias correction anneals toward full (beta=1) over the
        # run; uniform ignores it (python literal, compiles away)
        beta = (per_beta0 + (1.0 - per_beta0)
                * jnp.clip(it / beta_iters, 0.0, 1.0)
                if rb.prioritized else 1.0)

        def opt_step(p, s, g):
            p, s, _ = adamw_update(g, s, p, sched, ocfg)
            return p, s

        for _ in range(updates_per_iter):
            k_update, k_s, k_n = jax.random.split(k_update, 3)
            batch = rb.sample(buf, k_s, cfg.batch_size,
                              min_size=cfg.learn_start, beta=beta)
            if algo == "ddpg":
                g_c, td = jax.grad(ddpg_critic_loss_td, has_aux=True)(
                    params["critic"], target["critic"], target["actor"],
                    agent.critic_apply, agent.act, batch, cfg, k_n)
                c_p, c_s = opt_step(params["critic"], opt["critic"], g_c)
                g_a = jax.grad(ddpg_actor_loss)(
                    params["actor"], c_p, agent.critic_apply, agent.act,
                    batch)
                a_p, a_s = opt_step(params["actor"], opt["actor"], g_a)
                params = {"actor": a_p, "critic": c_p}
                opt = {"actor": a_s, "critic": c_s}
                target = polyak(target, params, cfg.tau)
            else:
                g, td = jax.grad(agent.loss_fn, has_aux=True)(
                    params, target,
                    lambda p, o: agent.q_apply(p, o, None), batch, cfg)
                params, opt = opt_step(params, opt, g)
                target = polyak(target, params, cfg.target_tau)
            # priority refresh from the fresh TD errors (uniform: no-op)
            buf = rb.update(buf, batch["indices"], td)

        ret, n_ep = episode_returns_from(R, D | Tr)
        return params, target, opt, buf, est, obs, ret, n_ep

    if metrics is None:
        return jax.jit(body, donate_argnums=(1, 2, 3, 5, 6))

    @partial(jax.jit, donate_argnums=(1, 2, 3, 5, 6, 9))
    def iteration(params, target, opt, buf, packed, est, obs, key, it,
                  mbuf):
        n_envs = obs.shape[0]
        eps = (epsilon(it * rollout_len, cfg) if discrete
               else jnp.zeros(()))
        params, target, opt, buf, est, obs, ret, n_ep = body(
            params, target, opt, buf, packed, est, obs, key, it)
        mbuf = _value_metric_updates(
            mbuf, rb, env_steps=rollout_len * n_envs, n_ep=n_ep,
            ret=ret, eps=eps, buf=buf)
        return params, target, opt, buf, est, obs, ret, n_ep, mbuf

    return iteration


def make_sharded_value_iteration(env, agent, srb, a_policy, sched, ocfg,
                                 mesh, *, algo: str, rollout_len: int,
                                 updates_per_iter: int, per_beta0: float,
                                 beta_iters: int, metrics=None):
    """The value-family step shard_mapped over the mesh's data axes.

    Device ``d`` collects its envs under its own behaviour stream,
    writes into *its* local replay slot, samples its stratified share
    of the global batch, and contributes a local gradient; the learner
    is the explicit ``psum`` over the data axes (divided by the alive
    count), so every device applies the identical optimizer step and
    the params stay replicated.  The PER bias correction goes global
    the same way: ``psum`` of the local sizes and ``pmax`` of the local
    weight maxima feed :func:`per_global_weights`/
    :func:`normalize_weights` — the exact math the host-side
    ``make_sharded_replay`` facade computes.

    A straggler slot (``alive[d]`` False, derived from ``FleetSync``
    staleness) still runs shape-stably but its batch weights are zeroed
    and the psum denominator counts only live slots.

    At ``n_slots=1`` the whole step is bit-exact vs
    :func:`make_value_iteration`: slot 0 keeps the raw RNG streams,
    1-device ``psum``/``pmax`` are identities, and ``/ 1.0`` and
    ``* 1.0`` are IEEE-exact.  Signature adds the per-slot ``alive``
    vector; donation contract is unchanged (argnums 1, 2, 3, 5, 6).
    """
    cfg = agent.cfg
    discrete = agent.discrete
    rb = srb.local if srb.local is not None else srb
    n_slots = srb.n_slots
    axes = data_axes(mesh)
    if not axes:
        raise ValueError(f"mesh {mesh.axis_names} has no data axes to "
                         "shard the value fleet over")
    if cfg.batch_size % n_slots != 0:
        raise ValueError(
            f"batch size {cfg.batch_size} does not divide evenly over "
            f"{n_slots} replay slot(s) (--batch-size)")
    n_local = cfg.batch_size // n_slots
    learn_min = max(int(cfg.learn_start), 1)
    batch_spec = P(axes)

    def psum_mean(tree, n_alive):
        return jax.tree.map(
            lambda x: jax.lax.psum(x, axes) / n_alive, tree)

    def opt_step(p, s, g):
        p, s, _ = adamw_update(g, s, p, sched, ocfg)
        return p, s

    def update_shard(params, target, opt, buf, trans, key, it, alive):
        # leading slot axis arrives sharded to size 1: take local views
        lbuf = jax.tree.map(lambda x: x[0], buf)
        O, A, rets, nxt, disc = (x[0] for x in trans)
        a_live = alive[0].astype(jnp.float32)
        n_alive = jnp.maximum(
            jax.lax.psum(a_live, axes), 1.0)

        idx = jax.lax.axis_index(axes[0])
        for a in axes[1:]:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)

        lbuf = rb.add(lbuf, O, A, rets, nxt, disc)
        # global underfill gate: learn_start counts total transitions
        size_g = jax.lax.psum(replay_size(lbuf), axes)
        ok = (size_g >= learn_min).astype(jnp.float32)

        beta = (per_beta0 + (1.0 - per_beta0)
                * jnp.clip(it / beta_iters, 0.0, 1.0)
                if rb.prioritized else 1.0)

        k_update = key
        for _ in range(updates_per_iter):
            k_update, k_s, k_n = jax.random.split(k_update, 3)
            k_s, k_n = slot_key(k_s, idx), slot_key(k_n, idx)
            batch = rb.sample(lbuf, k_s, n_local, min_size=1, beta=beta)
            if rb.prioritized:
                w = per_global_weights(batch["probs"], size_g, beta,
                                       n_slots)
                w = normalize_weights(
                    w, jax.lax.pmax(jnp.max(w), axes))
                batch["weight"] = w * ok * a_live
            else:
                batch["weight"] = jnp.broadcast_to(ok * a_live,
                                                   (n_local,))
            if algo == "ddpg":
                g_c, td = jax.grad(ddpg_critic_loss_td, has_aux=True)(
                    params["critic"], target["critic"], target["actor"],
                    agent.critic_apply, agent.act, batch, cfg, k_n)
                c_p, c_s = opt_step(params["critic"], opt["critic"],
                                    psum_mean(g_c, n_alive))
                g_a = jax.grad(ddpg_actor_loss)(
                    params["actor"], c_p, agent.critic_apply, agent.act,
                    batch)
                a_p, a_s = opt_step(params["actor"], opt["actor"],
                                    psum_mean(g_a, n_alive))
                params = {"actor": a_p, "critic": c_p}
                opt = {"actor": a_s, "critic": c_s}
                target = polyak(target, params, cfg.tau)
            else:
                g, td = jax.grad(agent.loss_fn, has_aux=True)(
                    params, target,
                    lambda p, o: agent.q_apply(p, o, None), batch, cfg)
                params, opt = opt_step(params, opt,
                                       psum_mean(g, n_alive))
                target = polyak(target, params, cfg.target_tau)
            lbuf = rb.update(lbuf, batch["indices"], td)

        buf = jax.tree.map(lambda x: x[None], lbuf)
        return params, target, opt, buf

    update_fn = shard_map(
        update_shard, mesh=mesh,
        in_specs=(P(), P(), P(), batch_spec, batch_spec, P(), P(),
                  batch_spec),
        out_specs=(P(), P(), P(), batch_spec),
        check_replication=False)

    def body(params, target, opt, buf, packed, est, obs, key, it,
             alive):
        k_collect, k_update = jax.random.split(key)
        eps = (epsilon(it * rollout_len, cfg) if discrete
               else jnp.zeros(()))
        (est, obs), (O, A, R, D, Tr, FO) = collect_value_sharded(
            packed, env, agent.behave, a_policy, k_collect, est, obs,
            rollout_len, eps, mesh)

        rets, nxt, disc = nstep_targets(R, D, Tr, FO, cfg.gamma,
                                        cfg.n_step)
        T, B = R.shape
        Bl = B // n_slots

        def slotted(x):
            # [T, B, ...] -> [n_slots, T*Bl, ...]: slot d's rows in
            # the same t-major order the single-device flat() produced
            x = x.reshape((T, n_slots, Bl) + x.shape[2:])
            x = jnp.swapaxes(x, 0, 1)
            return x.reshape((n_slots, T * Bl) + x.shape[3:])

        trans = tuple(slotted(x) for x in (O, A, rets, nxt, disc))
        params, target, opt, buf = update_fn(params, target, opt, buf,
                                             trans, k_update, it, alive)
        ret, n_ep = episode_returns_from(R, D | Tr)
        return params, target, opt, buf, est, obs, ret, n_ep

    if metrics is None:
        return jax.jit(body, donate_argnums=(1, 2, 3, 5, 6))

    @partial(jax.jit, donate_argnums=(1, 2, 3, 5, 6, 10))
    def iteration(params, target, opt, buf, packed, est, obs, key, it,
                  alive, mbuf):
        n_envs = obs.shape[0]
        eps = (epsilon(it * rollout_len, cfg) if discrete
               else jnp.zeros(()))
        params, target, opt, buf, est, obs, ret, n_ep = body(
            params, target, opt, buf, packed, est, obs, key, it, alive)
        mbuf = _value_metric_updates(
            mbuf, srb, env_steps=rollout_len * n_envs, n_ep=n_ep,
            ret=ret, eps=eps, buf=buf)
        mbuf = gauge_set(mbuf, "alive_frac",
                         jnp.mean(alive.astype(jnp.float32)))
        return params, target, opt, buf, est, obs, ret, n_ep, mbuf

    return iteration
