"""Jitted per-iteration step functions for both training families.

Factories, not loose functions: each returns the *already-jitted*
iteration with the donation contract baked in, closing over everything
that is static for a run (env, nets, optimizer config, replay
backend).  Extracted from ``launch/rl_train.py`` so that

* the drivers stay orchestration-only (checkpoint flow, logging,
  weight-sync bookkeeping), and
* the trace audit (:mod:`repro.analysis.trace_audit`) can lower the
  real step functions abstractly — the exact programs training runs —
  and assert dtype/donation invariants on them without running a
  single iteration.

Donation contracts (QF401):

* on-policy ``iteration(params, opt, est, obs, packed, key, gmask,
  alive)`` donates ``opt``/``est``/``obs`` (argnums 1-3) — the
  threaded state.  ``params`` is NOT donated: ``packed`` aliases its
  unquantized leaves (biases, or the whole tree under fp32 actors),
  and a buffer cannot be both donated and passed again.
* value-based ``iteration(params, target, opt, buf, packed, est, obs,
  key, it)`` donates ``target``/``opt``/``buf``/``est``/``obs``
  (argnums 1, 2, 3, 5, 6) — without it XLA copies the whole replay
  buffer (capacity x obs, the dominant allocation) every iteration
  just to apply the circular write.  Same ``params``/``packed``
  aliasing caveat.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.optim import adamw_update
from repro.rl.actor_learner import (collect_sharded, fleet_mask,
                                    unpack_weights)
from repro.rl.ppo import batch_from_traj, minibatch_epochs
from repro.rl.rollout import episode_returns, episode_returns_from
from repro.rl.value import (ddpg_actor_loss, ddpg_critic_loss_td,
                            epsilon, nstep_targets, polyak)


def make_onpolicy_iteration(env, apply_fn, a_policy, mesh, dist, pcfg,
                            loss_fn, sched, ocfg, *, rollout_len: int,
                            n_envs: int, n_slots: int):
    """One sharded-collect + minibatch-update step (ppo / a2c)."""
    learner_apply = lambda p, o: apply_fn(p, o, None)  # noqa: E731

    @partial(jax.jit, donate_argnums=(1, 2, 3))
    def iteration(params, opt, est, obs, packed, key, gmask, alive):
        k1, k2 = jax.random.split(key)
        res = collect_sharded(packed, env, apply_fn, a_policy, k1, est,
                              obs, rollout_len, mesh, dist)
        mask = fleet_mask(alive, n_envs // n_slots)
        # the learner's fp32 value head prices the truncation bootstrap
        batch = batch_from_traj(res.traj, res.last_value, pcfg,
                                actor_mask=mask,
                                value_fn=lambda o: learner_apply(params,
                                                                 o)[1])

        def opt_step(p, s, g):
            p, s, _ = adamw_update(g, s, p, sched, ocfg)
            return p, s

        params, opt, stats = minibatch_epochs(
            k2, params, opt, batch, learner_apply, pcfg, opt_step,
            loss_fn=loss_fn, grad_mask=gmask, dist=dist)
        ret, n_ep = episode_returns(res.traj)
        return params, opt, res.final_env, res.final_obs, ret, n_ep

    return iteration


def make_value_iteration(env, agent, rb, a_policy, sched, ocfg, *,
                         algo: str, rollout_len: int,
                         updates_per_iter: int, per_beta0: float,
                         beta_iters: int):
    """One collect-into-replay + sampled-updates step (dqn / qrdqn /
    ddpg)."""
    cfg = agent.cfg
    discrete = agent.discrete

    @partial(jax.jit, donate_argnums=(1, 2, 3, 5, 6))
    def iteration(params, target, opt, buf, packed, est, obs, key, it):
        k_collect, k_update = jax.random.split(key)
        actor_params = unpack_weights(packed)
        eps = (epsilon(it * rollout_len, cfg) if discrete
               else jnp.zeros(()))

        def one_full(carry, k):
            est, o = carry
            a = agent.behave(actor_params, o, k, eps, a_policy)
            est, nxt, r, d, tr, fo = jax.vmap(env.step)(est, a)
            return (est, nxt), (o, a, r, d, tr, fo)

        keys = jax.random.split(k_collect, rollout_len)
        (est, obs), (O, A, R, D, Tr, FO) = jax.lax.scan(
            one_full, (est, obs), keys)

        rets, nxt, disc = nstep_targets(R, D, Tr, FO, cfg.gamma,
                                        cfg.n_step)
        T, B = R.shape
        flat = lambda x: x.reshape((T * B,) + x.shape[2:])  # noqa: E731
        buf = rb.add(buf, flat(O), flat(A), flat(rets), flat(nxt),
                     flat(disc))

        # PER bias correction anneals toward full (beta=1) over the
        # run; uniform ignores it (python literal, compiles away)
        beta = (per_beta0 + (1.0 - per_beta0)
                * jnp.clip(it / beta_iters, 0.0, 1.0)
                if rb.prioritized else 1.0)

        def opt_step(p, s, g):
            p, s, _ = adamw_update(g, s, p, sched, ocfg)
            return p, s

        for _ in range(updates_per_iter):
            k_update, k_s, k_n = jax.random.split(k_update, 3)
            batch = rb.sample(buf, k_s, cfg.batch_size,
                              min_size=cfg.learn_start, beta=beta)
            if algo == "ddpg":
                g_c, td = jax.grad(ddpg_critic_loss_td, has_aux=True)(
                    params["critic"], target["critic"], target["actor"],
                    agent.critic_apply, agent.act, batch, cfg, k_n)
                c_p, c_s = opt_step(params["critic"], opt["critic"], g_c)
                g_a = jax.grad(ddpg_actor_loss)(
                    params["actor"], c_p, agent.critic_apply, agent.act,
                    batch)
                a_p, a_s = opt_step(params["actor"], opt["actor"], g_a)
                params = {"actor": a_p, "critic": c_p}
                opt = {"actor": a_s, "critic": c_s}
                target = polyak(target, params, cfg.tau)
            else:
                g, td = jax.grad(agent.loss_fn, has_aux=True)(
                    params, target,
                    lambda p, o: agent.q_apply(p, o, None), batch, cfg)
                params, opt = opt_step(params, opt, g)
                target = polyak(target, params, cfg.target_tau)
            # priority refresh from the fresh TD errors (uniform: no-op)
            buf = rb.update(buf, batch["indices"], td)

        ret, n_ep = episode_returns_from(R, D | Tr)
        return params, target, opt, buf, est, obs, ret, n_ep

    return iteration
