"""Action distributions — the layer that makes rollout/PPO
distribution-agnostic.

The policy network emits a flat parameter vector ``dparams`` per state
(``spaces.head_dim(action_space)`` wide); an :class:`ActionDist` turns
it into sampling, log-probs and entropy.  Two concrete families:

  * :class:`Categorical` — ``dparams`` are unnormalized logits
    ``[..., n]`` (Discrete action spaces);
  * :class:`TanhGaussian` — ``dparams`` are ``[..., 2*d]`` (mean,
    log_std) of a Gaussian squashed by tanh and rescaled into the Box
    bounds (continuous control à la Pendulum).

All methods broadcast over leading batch axes, so the same code runs
unbatched inside ``vmap`` or on ``[T*B, ...]`` minibatches.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Union

import jax
import jax.numpy as jnp

from repro.rl.envs.spaces import Box, Discrete, Space

Array = jax.Array

LOG_STD_MIN = -5.0
LOG_STD_MAX = 2.0
_HALF_LOG_2PI = 0.5 * math.log(2.0 * math.pi)


@dataclasses.dataclass(frozen=True)
class Categorical:
    """Discrete actions from unnormalized logits ``[..., n]``."""

    def sample(self, key: Array, dparams: Array) -> Array:
        return jax.random.categorical(key, dparams)

    def log_prob(self, dparams: Array, action: Array) -> Array:
        logp = jax.nn.log_softmax(dparams)
        idx = action.astype(jnp.int32)[..., None]
        return jnp.take_along_axis(logp, idx, axis=-1)[..., 0]

    def entropy(self, dparams: Array) -> Array:
        logp = jax.nn.log_softmax(dparams)
        return -jnp.sum(jnp.exp(logp) * logp, axis=-1)


@dataclasses.dataclass(frozen=True)
class TanhGaussian:
    """tanh-squashed diagonal Gaussian rescaled into ``[low, high]``.

    ``dparams`` is ``[..., 2*d]``: the first half is the pre-squash
    mean, the second half log-std (clipped to a sane range).  Log-probs
    include the tanh + affine change-of-variables correction;
    ``entropy`` is the pre-squash Gaussian entropy (the standard
    tractable surrogate for the PPO bonus — squashing only shrinks it).
    """

    low: float
    high: float

    @property
    def _mid(self) -> float:
        return 0.5 * (self.high + self.low)

    @property
    def _half(self) -> float:
        return 0.5 * (self.high - self.low)

    def _split(self, dparams: Array):
        mu, log_std = jnp.split(dparams, 2, axis=-1)
        return mu, jnp.clip(log_std, LOG_STD_MIN, LOG_STD_MAX)

    def sample(self, key: Array, dparams: Array) -> Array:
        mu, log_std = self._split(dparams)
        u = mu + jnp.exp(log_std) * jax.random.normal(key, mu.shape)
        return self._mid + self._half * jnp.tanh(u)

    def log_prob(self, dparams: Array, action: Array) -> Array:
        mu, log_std = self._split(dparams)
        a = (action - self._mid) / self._half
        a = jnp.clip(a, -1.0 + 1e-6, 1.0 - 1e-6)
        u = jnp.arctanh(a)
        std = jnp.exp(log_std)
        logp_u = (-0.5 * jnp.square((u - mu) / std) - log_std
                  - _HALF_LOG_2PI)
        # |d action / d u| = half * (1 - tanh(u)^2)
        jac = jnp.log(self._half * (1.0 - jnp.square(a)) + 1e-9)
        return jnp.sum(logp_u - jac, axis=-1)

    def entropy(self, dparams: Array) -> Array:
        _, log_std = self._split(dparams)
        return jnp.sum(log_std + 0.5 + _HALF_LOG_2PI, axis=-1)


ActionDist = Union[Categorical, TanhGaussian]


def distribution_for(space: Space) -> ActionDist:
    """The canonical distribution family for an action space."""
    if isinstance(space, Discrete):
        return Categorical()
    if isinstance(space, Box):
        if not space.bounded:
            raise ValueError("TanhGaussian needs finite Box bounds")
        return TanhGaussian(space.low, space.high)
    raise TypeError(f"no distribution for space {space!r}")
