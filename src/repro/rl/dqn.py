"""DQN with a pure-JAX circular replay buffer + target network.

Included because Fig. 3a's parity claim spans value-based methods too;
the quantized actor here is the epsilon-greedy *behaviour* policy.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DQNConfig:
    gamma: float = 0.99
    eps_start: float = 1.0
    eps_end: float = 0.05
    eps_decay_steps: int = 2_000
    target_update_every: int = 100
    batch_size: int = 64


class Replay(NamedTuple):
    obs: Array          # [N, ...]
    actions: Array      # [N]
    rewards: Array      # [N]
    next_obs: Array     # [N, ...]
    dones: Array        # [N]
    ptr: Array          # scalar int32: next write slot
    size: Array         # scalar int32: valid entries


def replay_init(capacity: int, obs_shape) -> Replay:
    z = jnp.zeros
    return Replay(z((capacity,) + tuple(obs_shape)),
                  z((capacity,), jnp.int32), z((capacity,)),
                  z((capacity,) + tuple(obs_shape)),
                  z((capacity,), bool),
                  jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32))


def replay_add(buf: Replay, obs, action, reward, next_obs, done) -> Replay:
    """Add a batch of B transitions (contiguous circular write).

    ``B >= capacity`` keeps exactly the last ``capacity`` transitions:
    a full-batch write would produce duplicate scatter indices, whose
    write order XLA leaves unspecified, so the survivors are sliced out
    first and the scatter indices stay unique (deterministic).
    """
    B = obs.shape[0]
    cap = buf.obs.shape[0]
    ptr = buf.ptr
    if B >= cap:
        drop = B - cap
        obs, action, reward, next_obs, done = (
            x[drop:] for x in (obs, action, reward, next_obs, done))
        ptr = ptr + drop        # slots the dropped prefix would have used
        B = cap
    idx = (ptr + jnp.arange(B)) % cap
    return Replay(
        buf.obs.at[idx].set(obs),
        buf.actions.at[idx].set(action),
        buf.rewards.at[idx].set(reward),
        buf.next_obs.at[idx].set(next_obs),
        buf.dones.at[idx].set(done),
        (ptr + B) % cap,
        jnp.minimum(buf.size + B, cap),
    )


def replay_sample(buf: Replay, key: Array, n: int) -> dict:
    idx = jax.random.randint(key, (n,), 0, jnp.maximum(buf.size, 1))
    return {"obs": buf.obs[idx], "actions": buf.actions[idx],
            "rewards": buf.rewards[idx], "next_obs": buf.next_obs[idx],
            "dones": buf.dones[idx]}


def epsilon(step: Array, cfg: DQNConfig) -> Array:
    frac = jnp.clip(step / cfg.eps_decay_steps, 0.0, 1.0)
    return cfg.eps_start + frac * (cfg.eps_end - cfg.eps_start)


def egreedy(key: Array, qvals: Array, eps: Array) -> Array:
    B, A = qvals.shape
    k1, k2 = jax.random.split(key)
    rand = jax.random.randint(k1, (B,), 0, A)
    greedy = jnp.argmax(qvals, axis=-1)
    return jnp.where(jax.random.uniform(k2, (B,)) < eps, rand, greedy)


def dqn_loss(params, target_params, apply_fn: Callable, batch: dict,
             cfg: DQNConfig) -> Array:
    q = apply_fn(params, batch["obs"])
    q_sel = q[jnp.arange(q.shape[0]), batch["actions"]]
    q_next = apply_fn(target_params, batch["next_obs"])
    target = batch["rewards"] + cfg.gamma * (
        1.0 - batch["dones"].astype(jnp.float32)) * q_next.max(-1)
    target = jax.lax.stop_gradient(target)
    return jnp.mean(jnp.square(q_sel - target))
