"""Backward-compat shim — the DQN family grew into :mod:`repro.rl.value`.

The value-based subsystem (replay, n-step targets, Double-DQN, QR-DQN,
DDPG) lives in ``repro.rl.value``; import from there.  This module
keeps the ``repro.rl.dqn`` import path alive, but note one SEMANTIC
change: the replay buffer now stores a *discount*
(``gamma^K * (1 - terminated)``) per transition instead of a done
flag, and ``replay_sample`` returns a ``"discounts"`` column (plus a
``"weight"`` guard) instead of ``"dones"``.  Passing the old boolean
``done`` array to ``replay_add`` is a loud error here — storing it as
a discount would silently invert every TD target.  ``dqn_loss`` still
accepts legacy ``"dones"`` batches.
"""
import jax.numpy as jnp

from repro.rl.value import (DQNConfig, Replay, dqn_loss, egreedy,
                            epsilon, replay_init, replay_sample)
from repro.rl.value import replay_add as _replay_add

__all__ = ["DQNConfig", "Replay", "dqn_loss", "egreedy", "epsilon",
           "replay_add", "replay_init", "replay_sample"]


def replay_add(buf, obs, action, reward, next_obs, discount):
    """:func:`repro.rl.value.replay_add`, guarding the old signature:
    the 6th argument is a per-transition DISCOUNT now, not ``done``."""
    if jnp.asarray(discount).dtype == jnp.bool_:
        raise TypeError(
            "replay_add now stores a per-transition discount "
            "(gamma^K * (1 - terminated)), not a boolean done flag — "
            "build it with repro.rl.value.nstep_targets (or "
            "gamma * (1 - done) for plain 1-step transitions)")
    return _replay_add(buf, obs, action, reward, next_obs, discount)
