"""Generalized Advantage Estimation (reverse lax.scan)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def gae(rewards: Array, values: Array, dones: Array, last_value: Array,
        gamma: float = 0.99, lam: float = 0.95) -> Tuple[Array, Array]:
    """rewards/dones: [T, B]; values: [T, B]; last_value: [B].

    Returns (advantages [T,B], returns [T,B]).  ``dones[t]`` marks that
    the transition at t ended an episode: no bootstrapping across it.
    """
    not_done = 1.0 - dones.astype(jnp.float32)
    next_values = jnp.concatenate([values[1:], last_value[None]], axis=0)

    def back(carry, xs):
        r, v, nv, nd = xs
        delta = r + gamma * nv * nd - v
        adv = delta + gamma * lam * nd * carry
        return adv, adv

    _, advs = jax.lax.scan(back, jnp.zeros_like(last_value),
                           (rewards, values, next_values, not_done),
                           reverse=True)
    return advs, advs + values


def normalize(adv: Array, eps: float = 1e-8) -> Array:
    return (adv - adv.mean()) / (adv.std() + eps)
