"""Generalized Advantage Estimation (reverse lax.scan)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def gae(rewards: Array, values: Array, dones: Array, last_value: Array,
        gamma: float = 0.99, lam: float = 0.95,
        truncated: Array = None,
        bootstrap_values: Array = None) -> Tuple[Array, Array]:
    """rewards/dones: [T, B]; values: [T, B]; last_value: [B].

    Returns (advantages [T,B], returns [T,B]).  ``dones[t]`` marks a
    TERMINATION at t: no bootstrapping across it.  ``truncated[t]``
    marks a pure time-limit cut: the advantage chain still breaks (the
    next row belongs to a fresh episode) but the one-step target keeps
    bootstrapping — from ``bootstrap_values[t]`` = V(final_obs[t]), the
    value of the state the episode was actually cut in (the row below
    holds the *fresh* episode's value, which would be wrong).

    With ``truncated=None`` (legacy callers) every done is treated as a
    full cut — pass the trajectory's truncation signal to get unbiased
    targets at timeouts.
    """
    term = dones.astype(jnp.float32)
    if truncated is None:
        boundary = term
        next_values = jnp.concatenate([values[1:], last_value[None]],
                                      axis=0)
    else:
        if bootstrap_values is None:
            raise ValueError(
                "gae: truncated given without bootstrap_values — the "
                "truncation rows need V(final_obs) to bootstrap from")
        boundary = (dones | truncated).astype(jnp.float32)
        next_values = jnp.concatenate([values[1:], last_value[None]],
                                      axis=0)
        next_values = jnp.where(truncated, bootstrap_values, next_values)

    def back(carry, xs):
        r, v, nv, nterm, nbound = xs
        delta = r + gamma * nv * nterm - v
        adv = delta + gamma * lam * nbound * carry
        return adv, adv

    _, advs = jax.lax.scan(back, jnp.zeros_like(last_value),
                           (rewards, values, next_values, 1.0 - term,
                            1.0 - boundary),
                           reverse=True)
    return advs, advs + values


def normalize(adv: Array, eps: float = 1e-8) -> Array:
    return (adv - adv.mean()) / (adv.std() + eps)
