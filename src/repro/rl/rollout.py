"""Vectorized experience collection (B envs x T steps, one jit).

``apply_fn(params, obs) -> (dparams, value)`` is the *actor policy* —
``dparams`` parameterizes whatever :class:`~repro.rl.dists.ActionDist`
matches the env's action space (logits for Discrete, mean/log_std for
Box).  Pass quantized params + an FxP8 QuantPolicy and this is the
paper's quantized actor; the rollout code is precision- and
distribution-agnostic.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.rl.dists import ActionDist, distribution_for
from repro.rl.envs.base import Environment

Array = jax.Array


class Trajectory(NamedTuple):
    obs: Array          # [T, B, ...]
    actions: Array      # [T, B] (Discrete) or [T, B, d] (Box)
    log_probs: Array    # [T, B]
    values: Array       # [T, B]
    rewards: Array      # [T, B]
    dones: Array        # [T, B] terminations (no bootstrap across)
    truncated: Array    # [T, B] pure timeouts (bootstrap through)
    next_obs: Array     # [T, B, ...] true successor obs (pre-reset)

    @property
    def boundary(self) -> Array:
        """Episode boundaries — what auto-reset/episode stats key off."""
        return self.dones | self.truncated


class RolloutResult(NamedTuple):
    traj: Trajectory
    last_value: Array   # [B]
    final_env: Any      # env state carry (resume collection)
    final_obs: Array


def init_envs(env: Environment, key: Array, n_envs: int, mesh=None):
    """Reset ``n_envs`` environments; with ``mesh``, place every state
    leaf sharded over the mesh's data axes (env axis 0) so the sharded
    collection path starts without a reshard."""
    keys = jax.random.split(key, n_envs)
    state, obs = jax.vmap(env.reset)(keys)
    if mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.distributed.sharding import data_axes
        sharding = NamedSharding(mesh, P(data_axes(mesh) or None))
        state, obs = jax.tree.map(
            lambda x: jax.device_put(x, sharding), (state, obs))
    return state, obs


def rollout(params, env: Environment, apply_fn: Callable, key: Array,
            env_state, obs, n_steps: int,
            dist: Optional[ActionDist] = None) -> RolloutResult:
    """Collect ``n_steps`` transitions from every env (scan over time)."""
    if dist is None:
        dist = distribution_for(env.action_space)

    def one(carry, step_key):
        state, obs = carry
        dparams, value = apply_fn(params, obs)
        dparams = dparams.astype(jnp.float32)
        action = dist.sample(step_key, dparams)
        logp = dist.log_prob(dparams, action)
        state, next_obs, reward, done, truncated, final_obs = \
            jax.vmap(env.step)(state, action)
        tr = Trajectory(obs, action, logp, value, reward, done,
                        truncated, final_obs)
        return (state, next_obs), tr

    keys = jax.random.split(key, n_steps)
    (env_state, obs), traj = jax.lax.scan(one, (env_state, obs), keys)
    last_value = apply_fn(params, obs)[1]
    return RolloutResult(traj, last_value, env_state, obs)


def episode_returns(traj: Trajectory) -> Tuple[Array, Array]:
    """Mean undiscounted return and count of COMPLETED episodes.

    An episode completes at any boundary — termination OR truncation
    (a timed-out episode still has a return; only its value targets
    differ).
    """
    return episode_returns_from(traj.rewards, traj.boundary)


def episode_returns_from(rewards: Array, boundary: Array
                         ) -> Tuple[Array, Array]:
    """``episode_returns`` on raw [T, B] arrays (for collection loops
    that don't build a :class:`Trajectory`, e.g. the replay drivers)."""

    def per_env(rew, done):
        def f(carry, x):
            acc, total, n = carry
            r, d = x
            acc = acc + r
            total = total + jnp.where(d, acc, 0.0)
            n = n + d.astype(jnp.int32)
            acc = jnp.where(d, 0.0, acc)
            return (acc, total, n), None

        (_, total, n), _ = jax.lax.scan(f, (0.0, 0.0, 0), (rew, done))
        return total, n

    totals, ns = jax.vmap(per_env, in_axes=1)(rewards, boundary)
    n = ns.sum()
    return totals.sum() / jnp.maximum(n, 1), n
