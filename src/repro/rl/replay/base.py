"""The unified replay protocol: one typed facade over the jit-
compatible backends.

A :class:`ReplayBuffer` bundles the four pure functions every
off-policy driver needs — ``init``/``add``/``sample``/``update`` — for
one backend and one static configuration (capacity, shapes, PER
alpha).  The *state* they thread (``Replay`` or ``PERState``) is a flat
pytree: it rides through ``jax.jit`` (and ``donate_argnums``) and
checkpoints like any other training state, while the ``ReplayBuffer``
itself stays python-side, so backend dispatch costs nothing inside the
compiled iteration.

The batch contract every backend honours::

    sample(state, key, n, min_size=1, beta=1.0) -> {
        "obs", "actions", "rewards", "next_obs", "discounts",
        "weight",    # per-sample loss weights (IS weights under PER;
                     # the 0/1 underfill mask under uniform)
        "indices",   # sampled slots, for update()
        ...          # backend extras (PER: "probs")
    }
    update(state, indices, td_abs) -> state   # priority write-back
                                              # (identity for uniform)

so a driver written against this protocol runs unmodified under either
backend — ``--replay {uniform,per}`` is one string.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax.numpy as jnp

from repro.rl.replay import per as _per
from repro.rl.replay import uniform as _uniform

KINDS = ("uniform", "per")


@dataclasses.dataclass(frozen=True)
class ReplayBuffer:
    """One replay backend bound to its static configuration."""

    kind: str                      # one of KINDS
    capacity: int                  # global transition capacity
    init: Callable[[], Any]        # () -> state
    add: Callable[..., Any]        # (state, obs, act, rew, nxt, disc)
    sample: Callable[..., dict]    # (state, key, n, min_size=, beta=)
    update: Callable[..., Any]     # (state, indices, td_abs) -> state
    n_slots: int = 1               # >1: leading per-device slot axis
    local: Optional["ReplayBuffer"] = None  # per-slot backend (sharded)

    @property
    def prioritized(self) -> bool:
        return self.kind == "per"


def replay_size(state):
    """Valid-entry count of any backend's state (scalar int32) — for a
    sharded state ([n_slots] leading axis) the sum over slots."""
    if isinstance(state, _per.PERState):
        return jnp.sum(state.store.size)
    return jnp.sum(state.size)


def make_replay(kind: str, capacity: int, obs_shape,
                action_shape: Tuple[int, ...] = (),
                action_dtype=jnp.int32, *,
                alpha: float = 0.6) -> ReplayBuffer:
    """Build the :class:`ReplayBuffer` facade for one backend.

    ``alpha`` is the PER priority exponent (ignored by ``uniform``):
    sampling mass is ``(|td| + eps) ** alpha``, so 0 degrades PER to
    uniform-with-IS-weights and 1 is fully greedy prioritization.
    """
    if kind not in KINDS:
        raise ValueError(f"unknown replay kind {kind!r} "
                         f"(expected one of {KINDS})")
    if kind == "uniform":
        return ReplayBuffer(
            kind, capacity,
            init=lambda: _uniform.replay_init(capacity, obs_shape,
                                              action_shape, action_dtype),
            add=_uniform.replay_add,
            sample=lambda state, key, n, min_size=1, beta=1.0:
                _uniform.replay_sample(state, key, n, min_size),
            update=lambda state, indices, td_abs: state,
        )
    if not 0.0 <= alpha <= 1.0:
        raise ValueError(f"per alpha must be in [0, 1], got {alpha}")
    return ReplayBuffer(
        kind, capacity,
        init=lambda: _per.per_init(capacity, obs_shape, action_shape,
                                   action_dtype),
        add=_per.per_add,
        sample=_per.per_sample,
        update=lambda state, indices, td_abs:
            _per.per_update(state, indices, td_abs, alpha),
    )
