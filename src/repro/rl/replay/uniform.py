"""Uniform circular replay — the PR-3 buffer, moved out of
``repro.rl.value`` bit-for-bit.

Transitions are discount-encoded: ``discounts = gamma^K *
(1 - terminated)`` folds the n-step horizon, truncation and termination
into one number (see :func:`repro.rl.value.nstep_targets`), so every
TD target downstream is ``rewards + discounts * Q(next_obs)``.

The add/sample semantics here are the reference the PER backend's
storage reuses — and the bit-compatibility contract the regression
test in tests/test_replay.py pins: same (capacity, seed, add/sample
sequence) must produce byte-identical buffers and batches as the
pre-refactor ``repro.rl.value`` implementation.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


class Replay(NamedTuple):
    obs: Array          # [N, ...]
    actions: Array      # [N] (Discrete) or [N, d] (Box)
    rewards: Array      # [N] (n-step accumulated)
    next_obs: Array     # [N, ...] true successor (pre-reset at bounds)
    discounts: Array    # [N] gamma^K * (1 - terminated)
    ptr: Array          # scalar int32: next write slot
    size: Array         # scalar int32: valid entries


def replay_init(capacity: int, obs_shape,
                action_shape: Tuple[int, ...] = (),
                action_dtype=jnp.int32) -> Replay:
    z = jnp.zeros
    return Replay(z((capacity,) + tuple(obs_shape)),
                  z((capacity,) + tuple(action_shape), action_dtype),
                  z((capacity,)),
                  z((capacity,) + tuple(obs_shape)),
                  z((capacity,)),
                  jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32))


def write_slots(ptr: Array, capacity: int, batch: int):
    """The circular-write plan shared by every backend: for a batch of
    ``batch`` incoming transitions, returns ``(drop, idx, new_ptr)`` —
    drop the first ``drop`` rows (python int; only non-zero when the
    batch exceeds capacity, where a raw write would produce duplicate
    scatter indices with XLA-unspecified order), then scatter the
    survivors at slots ``idx`` and advance the pointer to ``new_ptr``.
    """
    drop = 0
    if batch >= capacity:
        drop = batch - capacity
        ptr = ptr + drop        # slots the dropped prefix would have used
        batch = capacity
    idx = (ptr + jnp.arange(batch)) % capacity
    return drop, idx, (ptr + batch) % capacity


def replay_add(buf: Replay, obs, action, reward, next_obs,
               discount) -> Replay:
    """Add a batch of B transitions (contiguous circular write).

    ``B >= capacity`` keeps exactly the last ``capacity`` transitions:
    a full-batch write would produce duplicate scatter indices, whose
    write order XLA leaves unspecified, so the survivors are sliced out
    first and the scatter indices stay unique (deterministic).
    """
    B = obs.shape[0]
    cap = buf.obs.shape[0]
    drop, idx, new_ptr = write_slots(buf.ptr, cap, B)
    if drop:
        obs, action, reward, next_obs, discount = (
            x[drop:] for x in (obs, action, reward, next_obs, discount))
        B = cap
    return Replay(
        buf.obs.at[idx].set(obs),
        buf.actions.at[idx].set(action),
        buf.rewards.at[idx].set(reward),
        buf.next_obs.at[idx].set(next_obs),
        buf.discounts.at[idx].set(discount),
        new_ptr,
        jnp.minimum(buf.size + B, cap),
    )


def gather(buf: Replay, idx: Array) -> dict:
    """The batch columns at slots ``idx`` (no weight — backends attach
    their own)."""
    return {"obs": buf.obs[idx], "actions": buf.actions[idx],
            "rewards": buf.rewards[idx], "next_obs": buf.next_obs[idx],
            "discounts": buf.discounts[idx]}


def check_min_size(size, min_size: int) -> Array:
    """The underfill guard shared by every backend: a buffer below
    ``min_size`` (e.g. the driver's ``learn_start``) must not train.
    Eagerly that's a hard error; under jit (where ``size`` is a tracer)
    the returned 0/1 mask multiplies the batch weights so a weighted
    loss masks the whole batch instead of silently training on
    uninitialized transitions."""
    if not isinstance(size, jax.core.Tracer) and int(size) < min_size:
        raise ValueError(
            f"replay sample: buffer holds {int(size)} transitions "
            f"but min_size={min_size} — sampling would return "
            "uninitialized (all-zero) transitions; collect more steps "
            "first (learn_start)")
    return (size >= min_size).astype(jnp.float32)


def replay_sample(buf: Replay, key: Array, n: int,
                  min_size: int = 1) -> dict:
    """Sample ``n`` transitions uniformly from the valid prefix.

    The ``"weight"`` column is 1 (or 0 under jit when the buffer is
    below ``min_size`` — see :func:`check_min_size`); ``"indices"``
    carries the sampled slots so the driver's priority write-back is
    backend-agnostic (a no-op here).
    """
    min_size = max(int(min_size), 1)
    ok = check_min_size(buf.size, min_size)
    idx = jax.random.randint(key, (n,), 0, jnp.maximum(buf.size, 1))
    batch = gather(buf, idx)
    batch["weight"] = jnp.broadcast_to(ok, (n,))
    batch["indices"] = idx
    return batch
