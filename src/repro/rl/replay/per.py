"""Proportional prioritized experience replay (Schaul et al., 2016) on
the pure-JAX sum tree.

State is the uniform circular storage plus a sum tree over the slots
and a running max priority:

  * **insertion** writes new transitions at the current max priority
    (they are guaranteed at least one replay before their priority is
    measured — the canonical "optimistic insert");
  * **sampling** is stratified inverse-CDF descent over the tree
    (:func:`repro.rl.replay.sum_tree.stratified_sample`), so slot ``i``
    is drawn with probability ``p_i / sum_j p_j`` where
    ``p_i = (|td_i| + eps) ** alpha`` — ``alpha`` interpolates between
    uniform (0) and fully greedy (1) prioritization;
  * **importance weights** ``w_i = (N * P(i)) ** -beta`` correct the
    sampling bias, normalized by the batch max so the effective
    learning rate only ever shrinks; ``beta`` anneals from ``beta0``
    to 1 over training (full correction at convergence);
  * **refresh**: after each TD update the sampled slots' priorities are
    rewritten from the fresh per-sample TD errors
    (:func:`per_update`).

Priorities live in the tree already exponentiated (``p ** alpha``), so
sampling is a plain proportional draw and ``max_priority`` tracks the
exponentiated domain.  Everything is jit-compatible and
donation-friendly: :class:`PERState` is a flat pytree whose arrays the
training loop can donate across iterations.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.rl.replay import sum_tree
from repro.rl.replay.uniform import (Replay, check_min_size, gather,
                                     replay_add, replay_init,
                                     write_slots)

Array = jax.Array

# floor added to |td| before the alpha exponent: keeps every visited
# transition revisitable (zero TD error must not mean zero mass)
PRIORITY_EPS = 1e-3


class PERState(NamedTuple):
    store: Replay       # the uniform circular storage
    tree: Array         # [2 * L] sum tree over the slots (mass = p^alpha)
    max_p: Array        # scalar f32: running max of the tree leaf mass


def per_init(capacity: int, obs_shape,
             action_shape: Tuple[int, ...] = (),
             action_dtype=jnp.int32) -> PERState:
    return PERState(
        replay_init(capacity, obs_shape, action_shape, action_dtype),
        sum_tree.init(capacity),
        jnp.ones((), jnp.float32),
    )


def per_add(state: PERState, obs, action, reward, next_obs,
            discount) -> PERState:
    """Circular write + max-priority insertion for the new slots."""
    B = obs.shape[0]
    cap = state.store.obs.shape[0]
    # the same write plan as the storage, so tree slots and storage
    # slots can never disagree
    _, idx, _ = write_slots(state.store.ptr, cap, B)
    store = replay_add(state.store, obs, action, reward, next_obs,
                       discount)
    tree = sum_tree.update(state.tree, idx,
                           jnp.full(idx.shape, state.max_p))
    return PERState(store, tree, state.max_p)


def per_sample(state: PERState, key: Array, n: int, min_size: int = 1,
               beta=1.0) -> dict:
    """Stratified proportional sample with annealed-beta IS weights.

    Returns the storage columns plus ``"indices"`` (for the priority
    write-back), ``"probs"`` (the sampling probabilities, for
    inspection) and ``"weight"`` — the max-normalized importance
    weights, zeroed under jit when the buffer is below ``min_size``
    (eagerly that is a hard error, same as the uniform backend).
    """
    min_size = max(int(min_size), 1)
    ok = check_min_size(state.store.size, min_size)
    idx, _ = sum_tree.stratified_sample(state.tree, key, n)
    # an EMPTY tree (total 0) — or a sub-ulp rounding of an internal
    # sum during the descent — can land on a zero-mass padded leaf
    # beyond the valid prefix: clamp to it so the returned indices are
    # always legal slots and a subsequent priority write-back can never
    # deposit sampling mass beyond it.  The mass is re-read at the
    # CLAMPED leaf — pricing the weight off the pre-clamp (zero-mass)
    # leaf would give that sample a ~(N*1e-12)^-beta weight that
    # dominates the batch-max normalization and crushes every other
    # weight.  The `ok` mask zeroes fully-masked batches; the floors
    # below just keep the arithmetic finite
    idx = jnp.minimum(idx, jnp.maximum(state.store.size - 1, 0))
    mass = sum_tree.get(state.tree, idx)
    t = sum_tree.total(state.tree)
    probs = jnp.maximum(mass, 1e-12) / jnp.maximum(t, 1e-12)
    N = jnp.maximum(state.store.size, 1).astype(jnp.float32)
    w = (N * probs) ** (-jnp.asarray(beta, jnp.float32))
    w = w / jnp.maximum(jnp.max(w), 1e-12)
    batch = gather(state.store, idx)
    batch["weight"] = w * ok
    batch["indices"] = idx
    batch["probs"] = probs
    return batch


def per_update(state: PERState, idx: Array, td_abs: Array,
               alpha: float = 0.6) -> PERState:
    """Priority refresh from fresh per-sample TD errors:
    ``mass = (|td| + eps) ** alpha``.  A slot sampled more than once in
    a batch may carry *different* TD errors (e.g. DDPG's per-row
    target-smoothing noise); ``sum_tree.update`` resolves duplicates
    deterministically (last occurrence wins)."""
    mass = (jnp.abs(td_abs) + PRIORITY_EPS) ** alpha
    tree = sum_tree.update(state.tree, idx,
                           mass.astype(jnp.float32))
    max_p = jnp.maximum(state.max_p, jnp.max(mass))
    return PERState(state.store, tree, max_p)
