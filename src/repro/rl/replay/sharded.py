"""Sharded replay: per-device local buffers, stratified global sampling.

The distributed-PER layout (Ape-X flavoured, but in-graph): every leaf
of the single-device state gains a leading ``[n_slots]`` axis — slot
``d`` is device ``d``'s *local* circular buffer (and, under PER, its
local sum tree) of capacity ``capacity // n_slots``.  Collection writes
each device's transitions into its own slot; sampling is **stratified
by device**: each slot draws ``n // n_slots`` transitions from its own
tree, which together form the global batch.

The importance weights are where the global view re-enters.  Under
stratified-by-slot sampling, a given draw lands on slot ``d``'s item
``i`` with effective probability ``p_local(i) / n_slots``, so the
PER bias correction must use that probability together with the
*global* size ``N = sum_d size_d`` and normalize by the *global* batch
max — :func:`per_global_weights` implements the first part and is
shared verbatim by this module's host-side facade and by the
shard_map'd learner (:func:`repro.rl.train_steps.
make_sharded_value_iteration`), where the same math runs per device
with ``psum``/``pmax`` supplying the cross-slot reductions.

Bit-exactness contract: at ``n_slots=1`` every formula degrades to the
single-device backend exactly (``x / 1.0`` and 1-device ``psum`` are
bitwise identities, and slot 0 keeps the caller's raw RNG stream via
:func:`repro.rl.actor_learner.slot_keys`), so a 1-slot sharded run
reproduces the legacy path bit for bit.  The state stays a flat pytree:
it donates, checkpoints, and restores bitwise like any other training
state — the PER tree included.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.rl.actor_learner import slot_keys
from repro.rl.replay.base import ReplayBuffer, make_replay, replay_size
from repro.rl.replay.uniform import check_min_size

Array = jax.Array


def per_global_weights(probs_local: Array, size_global, beta,
                       n_slots: int) -> Array:
    """Unnormalized IS weights for stratified-by-slot PER sampling.

    ``probs_local`` are each slot's *local* sampling probabilities
    (``mass / local_total``); the effective global per-draw probability
    is ``probs_local / n_slots``.  The caller normalizes by the global
    batch max (``jnp.max`` host-side, ``pmax`` of the local max inside
    shard_map) via :func:`normalize_weights`.
    """
    N = jnp.maximum(size_global, 1).astype(jnp.float32)
    return ((N * (probs_local / float(n_slots)))
            ** (-jnp.asarray(beta, jnp.float32)))


def normalize_weights(w: Array, w_max: Array) -> Array:
    """Max-normalize so the effective learning rate only ever shrinks."""
    return w / jnp.maximum(w_max, 1e-12)


def make_sharded_replay(kind: str, n_slots: int, capacity: int,
                        obs_shape, action_shape: Tuple[int, ...] = (),
                        action_dtype=jnp.int32, *,
                        alpha: float = 0.6) -> ReplayBuffer:
    """Build the sharded facade: ``n_slots`` local buffers of capacity
    ``capacity // n_slots`` behind the standard ``ReplayBuffer``
    protocol, with slot-major [n_slots, b, ...] batches.

    ``add`` expects slot-major inputs [n_slots, B_local, ...] (device
    ``d``'s transitions in row ``d``); ``sample`` stratifies the global
    batch ``n`` as ``n // n_slots`` per slot under the
    :func:`~repro.rl.actor_learner.slot_keys` streams and attaches
    globally-corrected weights; ``update`` writes priorities back
    slot-locally.  The per-slot backend is exposed as ``.local`` for
    the shard_map'd iteration, which runs the identical math device-
    side.
    """
    if n_slots < 1:
        raise ValueError(f"n_slots must be >= 1, got {n_slots}")
    if capacity % n_slots != 0:
        raise ValueError(
            f"replay capacity {capacity} does not divide evenly over "
            f"{n_slots} slot(s); round it to a multiple of the mesh "
            "size (--replay-capacity)")
    local = make_replay(kind, capacity // n_slots, obs_shape,
                        action_shape, action_dtype, alpha=alpha)

    def init():
        return jax.tree.map(lambda x: jnp.stack([x] * n_slots),
                            local.init())

    add = jax.vmap(local.add)

    def sample(state, key, n, min_size: int = 1, beta=1.0):
        if n % n_slots != 0:
            raise ValueError(
                f"batch size {n} does not divide evenly over "
                f"{n_slots} replay slot(s)")
        n_local = n // n_slots
        size_g = replay_size(state)
        # global underfill semantics: learn_start counts *total*
        # collected transitions, not per-slot fill
        ok = check_min_size(size_g, max(int(min_size), 1))
        keys = slot_keys(key, n_slots)
        batch = jax.vmap(
            lambda s, k: local.sample(s, k, n_local, min_size=1,
                                      beta=beta))(state, keys)
        if local.prioritized:
            w = per_global_weights(batch["probs"], size_g, beta, n_slots)
            w = normalize_weights(w, jnp.max(w))
            batch["weight"] = w * ok
        else:
            batch["weight"] = jnp.broadcast_to(ok, (n_slots, n_local))
        return batch

    update = jax.vmap(local.update)

    return ReplayBuffer(kind, capacity, init=init, add=add,
                        sample=sample, update=update,
                        n_slots=n_slots, local=local)
