"""repro.rl.replay — the off-policy replay subsystem.

Two jit-compatible, donation-friendly backends behind one typed
protocol (:class:`ReplayBuffer`, built by :func:`make_replay`):

  * ``uniform`` — the circular buffer (bit-compatible with the PR-3
    ``repro.rl.value`` implementation it was moved out of);
  * ``per`` — proportional prioritized replay on a pure-JAX sum tree
    (max-priority insertion, alpha priority exponent, annealed-beta
    importance weights, post-update priority refresh).

Either backend shards over a device mesh via
:func:`make_sharded_replay`: per-device local buffers (leading
[n_slots] state axis), stratified-by-device global sampling, and
globally-corrected IS weights — see :mod:`repro.rl.replay.sharded`.

See :mod:`repro.rl.replay.base` for the batch contract.
"""
from repro.rl.replay import sum_tree
from repro.rl.replay.base import (KINDS, ReplayBuffer, make_replay,
                                  replay_size)
from repro.rl.replay.per import (PERState, PRIORITY_EPS, per_add,
                                 per_init, per_sample, per_update)
from repro.rl.replay.sharded import (make_sharded_replay,
                                     normalize_weights,
                                     per_global_weights)
from repro.rl.replay.uniform import (Replay, replay_add, replay_init,
                                     replay_sample)

__all__ = [
    "KINDS", "PERState", "PRIORITY_EPS", "Replay", "ReplayBuffer",
    "make_replay", "make_sharded_replay", "normalize_weights",
    "per_add", "per_global_weights", "per_init", "per_sample",
    "per_update", "replay_add", "replay_init", "replay_sample",
    "replay_size", "sum_tree",
]
