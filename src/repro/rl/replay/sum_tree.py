"""Pure-JAX sum tree: the O(log n) prefix-sum index behind PER.

Layout is the classic implicit binary heap over one flat ``[2 * L]``
float32 array with ``L`` a power of two: node 1 is the root, node ``i``
has children ``2i`` and ``2i + 1``, the leaves occupy
``[L, 2L)`` (node 0 is unused by every read path; ``update`` uses it
as the scratch target for duplicate-index redirects).  Leaf ``j``
holds the
(already priority-exponentiated) sampling mass of replay slot ``j``;
every internal node holds the sum of its two children, so

  * :func:`update` rewrites a batch of leaves and refreshes exactly the
    touched root-paths level by level (``lax.fori_loop`` over the fixed
    depth, gather children / scatter parents) — ``O(m log L)`` work,
    fully vectorized, no data-dependent shapes;
  * :func:`stratified_sample` descends ``n`` prefix-sum queries from
    the root in lockstep (one ``fori_loop`` over the depth), which is
    the inverse-CDF sample without materializing the ``O(L)`` cumsum.

Internal sums are *recomputed* from the children at every refreshed
node rather than incrementally adjusted by a delta, so the invariant
``tree[i] == tree[2i] + tree[2i+1]`` holds bitwise after any update —
float drift can never accumulate in the internal nodes (the property
test in tests/test_replay.py checks this exactly).

Duplicate indices inside one ``update`` batch resolve deterministically
(last occurrence wins — see :func:`update`), so the tree state is
bitwise reproducible even when a PER batch re-prices the same slot
twice with different TD errors.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array


def leaf_count(capacity: int) -> int:
    """Smallest power of two >= capacity (the tree's leaf width)."""
    if capacity < 1:
        raise ValueError(f"sum tree needs capacity >= 1, got {capacity}")
    return 1 << (capacity - 1).bit_length()


def depth_of(tree: Array) -> int:
    """Levels between a leaf and the root (log2 of the leaf width)."""
    return (tree.shape[0] // 2).bit_length() - 1


def init(capacity: int) -> Array:
    """All-zero tree for ``capacity`` slots (leaves beyond ``capacity``
    stay zero forever, so they carry no sampling mass)."""
    return jnp.zeros((2 * leaf_count(capacity),), jnp.float32)


def total(tree: Array) -> Array:
    """Total sampling mass (the root)."""
    return tree[1]


def get(tree: Array, idx: Array) -> Array:
    """Leaf values at slot indices ``idx``."""
    L = tree.shape[0] // 2
    return tree[idx + L]


def update(tree: Array, idx: Array, values: Array) -> Array:
    """Set leaves ``idx`` (slot indices, [m]) to ``values`` and refresh
    their ancestors bottom-up.  ``O(m log L)`` (+ an O(m^2) dedupe mask,
    negligible at replay batch sizes).

    Duplicate indices resolve deterministically to the LAST occurrence:
    a raw leaf scatter with duplicate targets has XLA-unspecified write
    order (and a PER batch can legitimately carry duplicates with
    *different* values — e.g. DDPG TD errors differ across duplicate
    rows through the per-row target-smoothing noise), so earlier
    duplicates are redirected to the unused node 0 with value 0.  Node
    0 thereby accumulates a deterministic junk value — it is never read
    by ``total``/``get``/``find`` and carries no sampling mass.
    """
    L = tree.shape[0] // 2
    m = idx.shape[0]
    if m > 1:
        pos = jnp.arange(m)
        last = jnp.max(jnp.where(idx[None, :] == idx[:, None],
                                 pos[None, :], -1), axis=1)
        win = pos == last
        node = jnp.where(win, idx + L, 0)
        values = jnp.where(win, values, 0.0)
    else:
        node = idx + L
    tree = tree.at[node].set(values.astype(tree.dtype))

    def body(_, carry):
        tree, node = carry
        node = node // 2
        # duplicates among the m parents (including the redirected 0s,
        # whose path stays at node 0) all write the same recomputed
        # sum, so the scatter is deterministic
        tree = tree.at[node].set(tree[2 * node] + tree[2 * node + 1])
        return tree, node

    tree, _ = lax.fori_loop(0, depth_of(tree), body, (tree, node))
    return tree


def find(tree: Array, u: Array) -> Array:
    """Inverse-CDF lookup: for each prefix-sum query ``u`` in
    ``[0, total)`` return the leaf slot whose cumulative-mass interval
    contains it.  Descends all queries from the root in lockstep.

    The branch rule is ``go right iff u >= left-child sum``: with a
    strict ``>`` a query landing exactly on an interval boundary would
    fall into a zero-mass left leaf; with ``>=`` it lands on the first
    leaf whose interval is non-degenerate.  Zero-mass leaves are
    therefore unreachable while ``u < total``.
    """
    node = jnp.ones(u.shape, jnp.int32)

    def body(_, carry):
        node, u = carry
        left = tree[2 * node]
        go_right = u >= left
        node = 2 * node + go_right.astype(jnp.int32)
        u = jnp.where(go_right, u - left, u)
        return node, u

    node, _ = lax.fori_loop(0, depth_of(tree), body,
                            (node, u.astype(tree.dtype)))
    return node - tree.shape[0] // 2


def stratified_sample(tree: Array, key: Array, n: int):
    """Draw ``n`` slots proportionally to their leaf mass, stratified:
    query ``i`` is uniform on ``[i/n, (i+1)/n) * total``, so every
    1/n-quantile of the priority mass is hit exactly once (lower
    variance than n independent draws).  Returns ``(idx [n], mass [n])``
    — ``mass`` is the *unnormalized* leaf value; divide by
    :func:`total` for the sampling probability."""
    t = total(tree)
    u = (jnp.arange(n, dtype=jnp.float32)
         + jax.random.uniform(key, (n,))) / n * t
    # float guard: u == total would walk off the right edge
    u = jnp.minimum(u, t * (1.0 - 1e-7))
    idx = find(tree, u)
    return idx, get(tree, idx)
