"""KeyDoor: a pure-JAX *hierarchical* gridworld with image observations.

The task has exactly the two-level structure E2HRL's sub-goal module is
built for: the agent must first reach the KEY (sub-goal), then the DOOR
(final goal).  Observations are rendered 32x32x3 images (8x8 cells, 4px
each): agent=R, key=G (until picked), door=B — matching the paper's
32x32x3 I/P size (Table V) so the HRL conv stem is exercised as-is.

Rewards: +0.5 key pickup, +1.0 door-with-key (terminal), -0.01/step.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.rl.envs.base import Environment, EnvSpec, auto_reset
from repro.rl.envs.spaces import Box, Discrete

Array = jax.Array

GRID = 8
CELL_PX = 4
IMG = GRID * CELL_PX            # 32
MAX_STEPS = 64
N_ACTIONS = 4                   # up, down, left, right


class EnvState(NamedTuple):
    agent: Array        # [2] int32
    key_pos: Array      # [2]
    door: Array         # [2]
    has_key: Array      # bool
    t: Array
    key: Array          # PRNG


def _render(s: EnvState) -> Array:
    img = jnp.zeros((GRID, GRID, 3), jnp.float32)
    img = img.at[s.agent[0], s.agent[1], 0].set(1.0)
    img = img.at[s.key_pos[0], s.key_pos[1], 1].set(
        jnp.where(s.has_key, 0.0, 1.0))
    img = img.at[s.door[0], s.door[1], 2].set(1.0)
    img = jnp.repeat(jnp.repeat(img, CELL_PX, 0), CELL_PX, 1)
    return img


def _fresh(key: Array) -> EnvState:
    key, sub = jax.random.split(key)
    cells = jax.random.choice(sub, GRID * GRID, (3,), replace=False)
    pos = jnp.stack([cells // GRID, cells % GRID], -1).astype(jnp.int32)
    return EnvState(pos[0], pos[1], pos[2],
                    jnp.zeros((), bool), jnp.zeros((), jnp.int32), key)


def reset(key: Array) -> Tuple[EnvState, Array]:
    s = _fresh(key)
    return s, _render(s)


_MOVES = jnp.array([[-1, 0], [1, 0], [0, -1], [0, 1]], jnp.int32)


def step(s: EnvState, action: Array):
    agent = jnp.clip(s.agent + _MOVES[action], 0, GRID - 1)
    at_key = jnp.all(agent == s.key_pos)
    picked = at_key & ~s.has_key
    has_key = s.has_key | at_key
    at_door = jnp.all(agent == s.door)
    opened = at_door & has_key
    t = s.t + 1

    reward = (-0.01 + 0.5 * picked.astype(jnp.float32)
              + 1.0 * opened.astype(jnp.float32))
    done = opened
    truncated = (t >= MAX_STEPS) & ~opened

    nxt = EnvState(agent, s.key_pos, s.door, has_key, t, s.key)
    out = auto_reset(done | truncated, _fresh(s.key), nxt)
    return out, _render(out), reward, done, truncated, _render(nxt)


def subgoal_reached(s: EnvState) -> Array:
    """Oracle sub-goal indicator (key picked) — used by HRL diagnostics."""
    return s.has_key


def make() -> Environment:
    spec = EnvSpec("keydoor",
                   observation_space=Box(0.0, 1.0, (IMG, IMG, 3)),
                   action_space=Discrete(N_ACTIONS),
                   max_steps=MAX_STEPS)
    return Environment(spec=spec, reset=reset, step=step)
