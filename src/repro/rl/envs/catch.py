"""Pure-JAX Catch (bsuite-style) — a minimal pixel-grid env.

A ball falls one row per step down a ROWS x COLS board; the paddle on
the bottom row moves left/stay/right.  Reward is +1 for catching the
ball, -1 for missing, 0 otherwise; the episode ends when the ball
reaches the bottom row.  Observations are a (ROWS, COLS, 1) binary
image (ball and paddle pixels set), sized for conv stems and the
frame-stack wrapper — the registry's cheap stand-in for image RL.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.rl.envs.base import Environment, EnvSpec, auto_reset
from repro.rl.envs.spaces import Box, Discrete

Array = jax.Array

ROWS = 10
COLS = 5
MAX_STEPS = ROWS          # ball reaches the bottom in ROWS - 1 steps

N_ACTIONS = 3             # left, stay, right


class EnvState(NamedTuple):
    ball_row: Array
    ball_col: Array
    paddle_col: Array
    t: Array
    key: Array


def _render(s: EnvState) -> Array:
    img = jnp.zeros((ROWS, COLS, 1), jnp.float32)
    img = img.at[s.ball_row, s.ball_col, 0].set(1.0)
    img = img.at[ROWS - 1, s.paddle_col, 0].set(1.0)
    return img


def _fresh(key: Array) -> EnvState:
    key, sub = jax.random.split(key)
    ball_col = jax.random.randint(sub, (), 0, COLS, jnp.int32)
    return EnvState(jnp.zeros((), jnp.int32), ball_col,
                    jnp.asarray(COLS // 2, jnp.int32),
                    jnp.zeros((), jnp.int32), key)


def reset(key: Array) -> Tuple[EnvState, Array]:
    s = _fresh(key)
    return s, _render(s)


def step(s: EnvState, action: Array):
    """action in {0, 1, 2} -> paddle move {-1, 0, +1}."""
    paddle = jnp.clip(s.paddle_col + action.astype(jnp.int32) - 1,
                      0, COLS - 1)
    ball_row = s.ball_row + 1
    t = s.t + 1

    at_bottom = ball_row >= ROWS - 1
    caught = at_bottom & (paddle == s.ball_col)
    reward = jnp.where(at_bottom,
                       jnp.where(caught, 1.0, -1.0), 0.0
                       ).astype(jnp.float32)
    done = at_bottom
    truncated = (t >= MAX_STEPS) & ~at_bottom

    nxt = EnvState(ball_row, s.ball_col, paddle, t, s.key)
    out = auto_reset(done | truncated, _fresh(s.key), nxt)
    return out, _render(out), reward, done, truncated, _render(nxt)


def make() -> Environment:
    spec = EnvSpec("catch",
                   observation_space=Box(0.0, 1.0, (ROWS, COLS, 1)),
                   action_space=Discrete(N_ACTIONS),
                   max_steps=MAX_STEPS)
    return Environment(spec=spec, reset=reset, step=step)
