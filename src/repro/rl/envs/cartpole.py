"""Pure-JAX CartPole-v1 (Barto-Sutton dynamics, OpenAI Gym constants).

Functional API, vmap/scan friendly:

    env = make()
    state, obs = env.reset(key)
    state, obs, reward, done, truncated, final_obs = \
        env.step(state, action)

``done`` fires only when the pole/cart leave their limits (terminal);
the 500-step horizon reports ``truncated`` instead, so value targets
bootstrap through it (from ``final_obs``, the pre-reset observation).
Auto-reset on either boundary.  All ops are jax.lax level so thousands
of environments run inside one jit — this is what the quantized-actor
throughput claims are measured on.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.rl.envs.base import Environment, EnvSpec, auto_reset
from repro.rl.envs.spaces import Box, Discrete

Array = jax.Array

# Gym CartPole-v1 constants
GRAVITY = 9.8
CART_MASS = 1.0
POLE_MASS = 0.1
TOTAL_MASS = CART_MASS + POLE_MASS
POLE_HALF_LEN = 0.5
POLEMASS_LEN = POLE_MASS * POLE_HALF_LEN
FORCE_MAG = 10.0
DT = 0.02
THETA_LIMIT = 12 * 2 * jnp.pi / 360
X_LIMIT = 2.4
MAX_STEPS = 500

N_ACTIONS = 2
OBS_DIM = 4


class EnvState(NamedTuple):
    x: Array
    x_dot: Array
    theta: Array
    theta_dot: Array
    t: Array            # step counter
    key: Array          # per-env PRNG for auto-reset


def _obs(s: EnvState) -> Array:
    return jnp.stack([s.x, s.x_dot, s.theta, s.theta_dot], axis=-1)


def _fresh(key: Array) -> EnvState:
    key, sub = jax.random.split(key)
    vals = jax.random.uniform(sub, (4,), minval=-0.05, maxval=0.05)
    return EnvState(vals[0], vals[1], vals[2], vals[3],
                    jnp.zeros((), jnp.int32), key)


def reset(key: Array) -> Tuple[EnvState, Array]:
    s = _fresh(key)
    return s, _obs(s)


def step(s: EnvState, action: Array):
    """action in {0, 1}."""
    force = jnp.where(action == 1, FORCE_MAG, -FORCE_MAG)
    cos, sin = jnp.cos(s.theta), jnp.sin(s.theta)
    tmp = (force + POLEMASS_LEN * s.theta_dot ** 2 * sin) / TOTAL_MASS
    theta_acc = (GRAVITY * sin - cos * tmp) / (
        POLE_HALF_LEN * (4.0 / 3.0 - POLE_MASS * cos ** 2 / TOTAL_MASS))
    x_acc = tmp - POLEMASS_LEN * theta_acc * cos / TOTAL_MASS

    x = s.x + DT * s.x_dot
    x_dot = s.x_dot + DT * x_acc
    theta = s.theta + DT * s.theta_dot
    theta_dot = s.theta_dot + DT * theta_acc
    t = s.t + 1

    done = (jnp.abs(x) > X_LIMIT) | (jnp.abs(theta) > THETA_LIMIT)
    truncated = (t >= MAX_STEPS) & ~done
    reward = jnp.ones((), jnp.float32)          # +1 per surviving step

    nxt = EnvState(x, x_dot, theta, theta_dot, t, s.key)
    out = auto_reset(done | truncated, _fresh(s.key), nxt)
    return out, _obs(out), reward, done, truncated, _obs(nxt)


def make() -> Environment:
    spec = EnvSpec("cartpole",
                   observation_space=Box(-math.inf, math.inf, (OBS_DIM,)),
                   action_space=Discrete(N_ACTIONS),
                   max_steps=MAX_STEPS)
    return Environment(spec=spec, reset=reset, step=step)
