"""Pure-JAX Acrobot-v1 (Sutton's two-link underactuated swing-up).

Gym-compatible constants and RK4 integration.  Observations are the
6-vector [cos θ1, sin θ1, cos θ2, sin θ2, θ̇1, θ̇2]; the 3 discrete
actions apply torque {-1, 0, +1} to the joint between the links.
Reward is -1 per step until the tip swings above the bar
(-cos θ1 - cos(θ1 + θ2) > 1), which terminates.  Auto-resets like every
env behind this API.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.rl.envs.base import (Environment, EnvSpec, angle_wrap,
                                auto_reset)
from repro.rl.envs.spaces import Box, Discrete

Array = jax.Array

DT = 0.2
LINK_LENGTH_1 = 1.0
LINK_MASS_1 = 1.0
LINK_MASS_2 = 1.0
LINK_COM_1 = 0.5
LINK_COM_2 = 0.5
LINK_MOI = 1.0
GRAVITY = 9.8
MAX_VEL_1 = 4 * jnp.pi
MAX_VEL_2 = 9 * jnp.pi
MAX_STEPS = 500

N_ACTIONS = 3           # torque -1, 0, +1
OBS_DIM = 6


class EnvState(NamedTuple):
    theta1: Array
    theta2: Array
    dtheta1: Array
    dtheta2: Array
    t: Array
    key: Array


def _obs(s: EnvState) -> Array:
    return jnp.stack([jnp.cos(s.theta1), jnp.sin(s.theta1),
                      jnp.cos(s.theta2), jnp.sin(s.theta2),
                      s.dtheta1, s.dtheta2], axis=-1)


def _fresh(key: Array) -> EnvState:
    key, sub = jax.random.split(key)
    vals = jax.random.uniform(sub, (4,), minval=-0.1, maxval=0.1)
    return EnvState(vals[0], vals[1], vals[2], vals[3],
                    jnp.zeros((), jnp.int32), key)


def reset(key: Array) -> Tuple[EnvState, Array]:
    s = _fresh(key)
    return s, _obs(s)


def _dsdt(y: Array, torque: Array) -> Array:
    """Equations of motion (Sutton & Barto / Gym `_dsdt`)."""
    m1, m2 = LINK_MASS_1, LINK_MASS_2
    l1 = LINK_LENGTH_1
    lc1, lc2 = LINK_COM_1, LINK_COM_2
    i1 = i2 = LINK_MOI
    g = GRAVITY
    theta1, theta2, dtheta1, dtheta2 = y[0], y[1], y[2], y[3]

    d1 = (m1 * lc1 ** 2 + m2 *
          (l1 ** 2 + lc2 ** 2 + 2 * l1 * lc2 * jnp.cos(theta2)) + i1 + i2)
    d2 = m2 * (lc2 ** 2 + l1 * lc2 * jnp.cos(theta2)) + i2
    phi2 = m2 * lc2 * g * jnp.cos(theta1 + theta2 - jnp.pi / 2.0)
    phi1 = (-m2 * l1 * lc2 * dtheta2 ** 2 * jnp.sin(theta2)
            - 2 * m2 * l1 * lc2 * dtheta2 * dtheta1 * jnp.sin(theta2)
            + (m1 * lc1 + m2 * l1) * g * jnp.cos(theta1 - jnp.pi / 2.0)
            + phi2)
    ddtheta2 = ((torque + d2 / d1 * phi1
                 - m2 * l1 * lc2 * dtheta1 ** 2 * jnp.sin(theta2) - phi2)
                / (m2 * lc2 ** 2 + i2 - d2 ** 2 / d1))
    ddtheta1 = -(d2 * ddtheta2 + phi1) / d1
    return jnp.stack([dtheta1, dtheta2, ddtheta1, ddtheta2])


def _rk4(y0: Array, torque: Array, dt: float) -> Array:
    k1 = _dsdt(y0, torque)
    k2 = _dsdt(y0 + dt / 2 * k1, torque)
    k3 = _dsdt(y0 + dt / 2 * k2, torque)
    k4 = _dsdt(y0 + dt * k3, torque)
    return y0 + dt / 6.0 * (k1 + 2 * k2 + 2 * k3 + k4)


def step(s: EnvState, action: Array):
    """action in {0, 1, 2} -> torque {-1, 0, +1}."""
    torque = action.astype(jnp.float32) - 1.0
    y0 = jnp.stack([s.theta1, s.theta2, s.dtheta1, s.dtheta2])
    y = _rk4(y0, torque, DT)

    theta1 = angle_wrap(y[0])
    theta2 = angle_wrap(y[1])
    dtheta1 = jnp.clip(y[2], -MAX_VEL_1, MAX_VEL_1)
    dtheta2 = jnp.clip(y[3], -MAX_VEL_2, MAX_VEL_2)
    t = s.t + 1

    solved = -jnp.cos(theta1) - jnp.cos(theta2 + theta1) > 1.0
    done = solved
    truncated = (t >= MAX_STEPS) & ~solved
    reward = jnp.where(solved, 0.0, -1.0).astype(jnp.float32)

    nxt = EnvState(theta1, theta2, dtheta1, dtheta2, t, s.key)
    out = auto_reset(done | truncated, _fresh(s.key), nxt)
    return out, _obs(out), reward, done, truncated, _obs(nxt)


def make() -> Environment:
    spec = EnvSpec("acrobot",
                   observation_space=Box(-float(MAX_VEL_2),
                                         float(MAX_VEL_2), (OBS_DIM,)),
                   action_space=Discrete(N_ACTIONS),
                   max_steps=MAX_STEPS)
    return Environment(spec=spec, reset=reset, step=step)
