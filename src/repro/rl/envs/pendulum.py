"""Pure-JAX Pendulum-v1 — the continuous-action env in the registry.

Action is a Box torque in [-2, 2] (shape (1,)); the policy head is a
tanh-squashed Gaussian (see :mod:`repro.rl.dists`), exercising the
continuous path through PPO that "Learning Quantized Continuous
Controllers for Integer Hardware" needs.  Observation is
[cos θ, sin θ, θ̇]; reward is the negative quadratic cost; episodes are
pure time-limit (200 steps) with auto-reset — so ``done`` is *never*
set: the 200-step horizon reports ``truncated``, and value targets
bootstrap through it from ``final_obs`` (the pre-reset observation).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.rl.envs.base import (Environment, EnvSpec, angle_wrap,
                                auto_reset)
from repro.rl.envs.spaces import Box

Array = jax.Array

DT = 0.05
GRAVITY = 10.0
MASS = 1.0
LENGTH = 1.0
MAX_SPEED = 8.0
MAX_TORQUE = 2.0
MAX_STEPS = 200

OBS_DIM = 3
ACT_DIM = 1


class EnvState(NamedTuple):
    theta: Array
    theta_dot: Array
    t: Array
    key: Array


def _obs(s: EnvState) -> Array:
    return jnp.stack([jnp.cos(s.theta), jnp.sin(s.theta), s.theta_dot],
                     axis=-1)


def _fresh(key: Array) -> EnvState:
    key, sub = jax.random.split(key)
    vals = jax.random.uniform(sub, (2,),
                              minval=jnp.array([-jnp.pi, -1.0]),
                              maxval=jnp.array([jnp.pi, 1.0]))
    return EnvState(vals[0], vals[1], jnp.zeros((), jnp.int32), key)


def reset(key: Array) -> Tuple[EnvState, Array]:
    s = _fresh(key)
    return s, _obs(s)


def step(s: EnvState, action: Array):
    """action: float tensor of shape (1,), torque in [-2, 2]."""
    u = jnp.clip(action.reshape(()), -MAX_TORQUE, MAX_TORQUE)
    cost = (angle_wrap(s.theta) ** 2 + 0.1 * s.theta_dot ** 2
            + 0.001 * u ** 2)

    theta_dot = s.theta_dot + DT * (
        3 * GRAVITY / (2 * LENGTH) * jnp.sin(s.theta)
        + 3.0 / (MASS * LENGTH ** 2) * u)
    theta_dot = jnp.clip(theta_dot, -MAX_SPEED, MAX_SPEED)
    theta = s.theta + DT * theta_dot
    t = s.t + 1

    done = jnp.zeros((), bool)          # swing-up never terminates
    truncated = t >= MAX_STEPS
    reward = (-cost).astype(jnp.float32)

    nxt = EnvState(theta, theta_dot, t, s.key)
    out = auto_reset(truncated, _fresh(s.key), nxt)
    return out, _obs(out), reward, done, truncated, _obs(nxt)


def make() -> Environment:
    spec = EnvSpec("pendulum",
                   observation_space=Box(-MAX_SPEED, MAX_SPEED, (OBS_DIM,)),
                   action_space=Box(-MAX_TORQUE, MAX_TORQUE, (ACT_DIM,)),
                   max_steps=MAX_STEPS)
    return Environment(spec=spec, reset=reset, step=step)
