"""Composable environment wrappers (pure-function style).

Each wrapper takes an :class:`Environment` and returns a *new*
:class:`Environment` whose reset/step close over the inner functions —
no classes, no mutable state, so wrapped envs stay vmap/scan/jit
friendly and stack in any order:

    env = frame_stack(normalize_observation(make("catch"), 0.5, 0.5), 4)

Wrappers that need their own carry (time limit counter, frame buffer,
Welford stats) wrap the inner state in a NamedTuple, preserving the
auto-reset contract from :mod:`repro.rl.envs.base`.

Every wrapper tags the step function it produces
(``wrapper_stack(env)`` lists the applied wrappers outermost-first), so
order-sensitive compositions can be validated instead of silently
mis-normalizing — e.g. ``running_normalize_observation`` refuses to
wrap a frame-stacked env (stats are defined over *raw* frames; stack
after normalizing — :func:`pixel_pipeline` is the canonical order).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.rl.envs.base import Environment, auto_reset
from repro.rl.envs.spaces import Box

Array = jax.Array


def wrapper_stack(env: Environment) -> Tuple[str, ...]:
    """Names of the wrappers applied to ``env``, outermost first."""
    return getattr(env.step, "_wrapper_stack", ())


def _wrap(env: Environment, name: str, *, reset, step,
          spec=None) -> Environment:
    """Build the wrapped Environment and tag its step with the wrapper
    stack so compositions stay introspectable."""
    step._wrapper_stack = (name,) + wrapper_stack(env)
    return env.replace(spec=spec if spec is not None else env.spec,
                       reset=reset, step=step)


# ---------------------------------------------------------------------------
# stateless observation / reward transforms
# ---------------------------------------------------------------------------

def normalize_observation(env: Environment, mean, std) -> Environment:
    """Affine observation transform ``(obs - mean) / std``.

    ``mean``/``std`` are constants (scalars or obs-shaped arrays) — e.g.
    dataset statistics, or 0.5/0.5 to center pixel grids.  Keeping them
    static (rather than running estimates) keeps reset/step pure.
    """
    mean = jnp.asarray(mean, jnp.float32)
    std = jnp.asarray(std, jnp.float32)
    if bool(jnp.any(std == 0)):
        raise ValueError("normalize_observation: std must be non-zero")

    def norm(obs):
        return (obs.astype(jnp.float32) - mean) / std

    def reset(key):
        state, obs = env.reset(key)
        return state, norm(obs)

    def step(state, action):
        state, obs, reward, done, truncated, final_obs = \
            env.step(state, action)
        return state, norm(obs), reward, done, truncated, norm(final_obs)

    in_space = env.observation_space
    if isinstance(in_space, Box) and in_space.bounded:
        # elementwise transformed bounds (mean/std may be obs-shaped,
        # and a negative std flips low/high per element); Box carries
        # scalar bounds, so keep the tightest enclosing interval —
        # finite whenever the input is bounded
        lo = (in_space.low - mean) / std
        hi = (in_space.high - mean) / std
        space = Box(float(jnp.minimum(lo, hi).min()),
                    float(jnp.maximum(lo, hi).max()), env.obs_shape)
    else:
        space = Box(-math.inf, math.inf, env.obs_shape)
    spec = dataclasses.replace(env.spec, observation_space=space)
    return _wrap(env, "normalize_observation", reset=reset, step=step,
                 spec=spec)


def scale_reward(env: Environment, scale: float) -> Environment:
    """Multiply rewards by a constant (loss-scale style conditioning)."""

    def step(state, action):
        state, obs, reward, done, truncated, final_obs = \
            env.step(state, action)
        return (state, obs, reward * jnp.float32(scale), done, truncated,
                final_obs)

    return _wrap(env, "scale_reward", reset=env.reset, step=step)


def flatten_observation(env: Environment) -> Environment:
    """Ravel observations to 1-D — lets MLP policies drive pixel envs."""
    flat = int(math.prod(env.obs_shape))

    def ravel(obs):
        return obs.reshape(flat).astype(jnp.float32)

    def reset(key):
        state, obs = env.reset(key)
        return state, ravel(obs)

    def step(state, action):
        state, obs, reward, done, truncated, final_obs = \
            env.step(state, action)
        return state, ravel(obs), reward, done, truncated, ravel(final_obs)

    in_space = env.observation_space
    if isinstance(in_space, Box):
        space = Box(in_space.low, in_space.high, (flat,))
    else:
        space = Box(-math.inf, math.inf, (flat,))
    spec = dataclasses.replace(env.spec, observation_space=space)
    return _wrap(env, "flatten_observation", reset=reset, step=step,
                 spec=spec)


def ensure_vector_obs(env: Environment) -> Environment:
    """The MLP-policy view of any env: identity for vector observations,
    ``flatten_observation`` for image grids.  The one place the
    'what can an MLP agent consume' rule lives — benchmarks and tests
    share it rather than re-deriving the shape check."""
    if len(env.obs_shape) == 1:
        return env
    return flatten_observation(env)


# ---------------------------------------------------------------------------
# time limit
# ---------------------------------------------------------------------------

class TimeLimitState(NamedTuple):
    inner: Any
    t: Array            # steps taken in the current episode
    key: Array          # PRNG for the forced reset on timeout


def time_limit(env: Environment, max_steps: int) -> Environment:
    """Truncate episodes after ``max_steps`` wrapper-level steps.

    A pure timeout is reported as ``truncated`` — NOT folded into
    ``done`` — so value targets keep bootstrapping through it (the
    episode was cut, not terminated).  If the inner env terminates on
    the timeout tick, ``done`` wins.  On a pure timeout the inner env
    is force-reset (fresh key from the wrapper carry), so the
    auto-reset contract holds even for envs whose own horizon is
    longer; ``final_obs`` stays the pre-reset observation.
    """

    def reset(key):
        key, k_inner, k_carry = jax.random.split(key, 3)
        state, obs = env.reset(k_inner)
        return TimeLimitState(state, jnp.zeros((), jnp.int32), k_carry), obs

    def step(state, action):
        inner, obs, reward, done, truncated, final_obs = \
            env.step(state.inner, action)
        t = state.t + 1
        # pure wrapper timeout: episode still alive at the limit
        timeout = (t >= max_steps) & ~done & ~truncated
        truncated = truncated | timeout

        key, sub = jax.random.split(state.key)
        fresh_inner, fresh_obs = env.reset(sub)
        # inner auto-resets on its own boundary; only the wrapper
        # timeout needs the forced reset (final_obs keeps the inner
        # pre-reset observation either way)
        inner = auto_reset(timeout, fresh_inner, inner)
        obs = jnp.where(timeout, fresh_obs, obs)
        t = jnp.where(done | truncated, 0, t)
        return TimeLimitState(inner, t, key), obs, reward, done, \
            truncated, final_obs

    spec = dataclasses.replace(env.spec,
                               max_steps=min(env.spec.max_steps,
                                             max_steps))
    return _wrap(env, "time_limit", reset=reset, step=step, spec=spec)


# ---------------------------------------------------------------------------
# frame stacking
# ---------------------------------------------------------------------------

class FrameStackState(NamedTuple):
    inner: Any
    frames: Array       # [k, *obs_shape], frames[-1] is newest


def frame_stack(env: Environment, k: int) -> Environment:
    """Stack the last ``k`` observations along the trailing axis.

    Images (H, W, C) become (H, W, C*k); vectors (D,) become (D*k,) —
    the Binarized-P-Network-style temporal context for pixel inputs.
    On episode boundaries the buffer refills with the fresh episode's
    first observation.
    """
    if k < 1:
        raise ValueError(f"frame_stack needs k >= 1, got {k}")

    def stacked(frames: Array) -> Array:
        return jnp.concatenate([frames[i] for i in range(k)], axis=-1)

    def reset(key):
        state, obs = env.reset(key)
        frames = jnp.stack([obs] * k)
        return FrameStackState(state, frames), stacked(frames)

    def step(state, action):
        inner, obs, reward, done, truncated, final_obs = \
            env.step(state.inner, action)
        # the episode's true last stack ends in the pre-reset final_obs
        final = jnp.concatenate([state.frames[1:], final_obs[None]],
                                axis=0)
        rolled = jnp.concatenate([state.frames[1:], obs[None]], axis=0)
        fresh = jnp.stack([obs] * k)        # obs is already post-reset
        frames = jnp.where(done | truncated, fresh, rolled)
        return (FrameStackState(inner, frames), stacked(frames),
                reward, done, truncated, stacked(final))

    in_space = env.observation_space
    shape = in_space.shape[:-1] + (in_space.shape[-1] * k,)
    low = in_space.low if isinstance(in_space, Box) else -math.inf
    high = in_space.high if isinstance(in_space, Box) else math.inf
    spec = dataclasses.replace(env.spec,
                               observation_space=Box(low, high, shape))
    return _wrap(env, "frame_stack", reset=reset, step=step, spec=spec)


# ---------------------------------------------------------------------------
# running observation statistics (Welford carry in env state)
# ---------------------------------------------------------------------------

class NormStats(NamedTuple):
    """Welford accumulator: ``mean``/``m2`` are obs-shaped, ``count`` a
    float32 scalar.  ``var = m2 / count`` (population, matching
    ``jnp.var``)."""

    count: Array
    mean: Array
    m2: Array

    @property
    def std(self) -> Array:
        return jnp.sqrt(self.m2 / jnp.maximum(self.count, 1.0))


def init_norm_stats(obs_shape) -> NormStats:
    return NormStats(jnp.zeros((), jnp.float32),
                     jnp.zeros(obs_shape, jnp.float32),
                     jnp.zeros(obs_shape, jnp.float32))


def _welford_update(stats: NormStats, x: Array) -> NormStats:
    count = stats.count + 1.0
    delta = x - stats.mean
    mean = stats.mean + delta / count
    return NormStats(count, mean, stats.m2 + delta * (x - mean))


def _normalize_with(stats: NormStats, x: Array,
                    eps: float = 1e-8) -> Array:
    """(x - mean) / (std + eps); identity while the stream is empty."""
    seen = stats.count > 0.0
    mean = jnp.where(seen, stats.mean, 0.0)
    std = jnp.where(seen, stats.std, 1.0)
    return (x.astype(jnp.float32) - mean) / (std + eps)


def merge_norm_stats(stats: NormStats) -> NormStats:
    """Chan's parallel Welford merge over the leading (vmapped-env)
    axis: per-env carries [B, ...] -> one fleet-wide NormStats, e.g. to
    freeze for evaluation."""
    counts = stats.count.reshape(-1)                      # [B]
    B = counts.shape[0]
    mean_b = stats.mean.reshape((B,) + stats.mean.shape[1:])
    m2_b = stats.m2.reshape((B,) + stats.m2.shape[1:])
    n = counts.sum()
    cshape = (B,) + (1,) * (mean_b.ndim - 1)
    w = counts.reshape(cshape) / jnp.maximum(n, 1.0)
    mean = (w * mean_b).sum(axis=0)
    m2 = (m2_b + counts.reshape(cshape)
          * jnp.square(mean_b - mean)).sum(axis=0)
    return NormStats(n, mean, m2)


class RunningNormState(NamedTuple):
    inner: Any
    stats: NormStats


def norm_stats_of(state) -> NormStats:
    """Extract the Welford carry from a (possibly further-wrapped) env
    state — walks ``inner`` chains, so it works on e.g. the
    frame-stacked pixel pipeline's state.  Batched states return
    batched stats (merge with :func:`merge_norm_stats`)."""
    while True:
        if isinstance(state, RunningNormState):
            return state.stats
        if not hasattr(state, "inner"):
            raise TypeError(
                "no running_normalize_observation carry found in this "
                "env state — was the env built with the wrapper?")
        state = state.inner


def running_normalize_observation(env: Environment,
                                  stats: Optional[NormStats] = None,
                                  eps: float = 1e-8) -> Environment:
    """Normalize observations by *running* mean/std.

    Two modes:

      * ``stats=None`` (training): a Welford mean/var carry is threaded
        through the env state — jit/vmap/scan-safe, and
        checkpoint-resumable because it is an ordinary pytree leaf of
        whatever training state captures the env.  Every observation
        the wrapper emits (reset and step) updates the carry first and
        is normalized with the updated stats; ``final_obs`` is
        normalized with the same stats without a second update.
      * ``stats=NormStats`` (evaluation): the given statistics are
        closed over as constants and never updated — the frozen-at-eval
        mode.  ``init_norm_stats(shape)`` gives the identity transform.

    Statistics are defined over *raw single frames*: wrapping a
    frame-stacked env is refused (the stacked channels would fold k
    time-shifted copies of each pixel into one estimate) — normalize
    first, stack after (see :func:`pixel_pipeline`).
    """
    if "frame_stack" in wrapper_stack(env):
        raise ValueError(
            "running_normalize_observation must wrap the raw env, not a "
            "frame-stacked one: Welford statistics are defined over raw "
            "single frames. Apply running_normalize_observation first "
            "and frame_stack second (pixel_pipeline does this).")
    space = Box(-math.inf, math.inf, env.obs_shape)
    spec = dataclasses.replace(env.spec, observation_space=space)

    if stats is not None:
        frozen = jax.tree.map(jnp.asarray, stats)

        def reset(key):
            state, obs = env.reset(key)
            return state, _normalize_with(frozen, obs, eps)

        def step(state, action):
            state, obs, reward, done, truncated, final_obs = \
                env.step(state, action)
            return (state, _normalize_with(frozen, obs, eps), reward,
                    done, truncated, _normalize_with(frozen, final_obs,
                                                     eps))

        return _wrap(env, "running_normalize_observation", reset=reset,
                     step=step, spec=spec)

    def reset(key):
        state, obs = env.reset(key)
        st = _welford_update(init_norm_stats(env.obs_shape), obs)
        return RunningNormState(state, st), _normalize_with(st, obs, eps)

    def step(state, action):
        inner, obs, reward, done, truncated, final_obs = \
            env.step(state.inner, action)
        st = _welford_update(state.stats, obs)
        return (RunningNormState(inner, st), _normalize_with(st, obs, eps),
                reward, done, truncated,
                _normalize_with(st, final_obs, eps))

    return _wrap(env, "running_normalize_observation", reset=reset,
                 step=step, spec=spec)


def pixel_pipeline(env: Environment, k: int = 1,
                   stats: Optional[NormStats] = None) -> Environment:
    """The canonical pixel-env stack for conv agents: running (or
    frozen) observation normalization over raw frames, THEN frame
    stacking — the order :func:`running_normalize_observation`
    requires.  ``k=1`` skips the stacking wrapper entirely."""
    if k < 1:
        raise ValueError(f"pixel_pipeline needs frame_stack k >= 1, "
                         f"got {k}")
    if len(env.obs_shape) != 3:
        raise ValueError(
            f"pixel_pipeline needs image (H, W, C) observations; "
            f"{env.spec.name} has shape {env.obs_shape}")
    env = running_normalize_observation(env, stats=stats)
    return frame_stack(env, k) if k > 1 else env
