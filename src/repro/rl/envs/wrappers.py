"""Composable environment wrappers (pure-function style).

Each wrapper takes an :class:`Environment` and returns a *new*
:class:`Environment` whose reset/step close over the inner functions —
no classes, no mutable state, so wrapped envs stay vmap/scan/jit
friendly and stack in any order:

    env = frame_stack(normalize_observation(make("catch"), 0.5, 0.5), 4)

Wrappers that need their own carry (time limit counter, frame buffer)
wrap the inner state in a NamedTuple, preserving the auto-reset
contract from :mod:`repro.rl.envs.base`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.rl.envs.base import Environment, auto_reset
from repro.rl.envs.spaces import Box

Array = jax.Array


# ---------------------------------------------------------------------------
# stateless observation / reward transforms
# ---------------------------------------------------------------------------

def normalize_observation(env: Environment, mean, std) -> Environment:
    """Affine observation transform ``(obs - mean) / std``.

    ``mean``/``std`` are constants (scalars or obs-shaped arrays) — e.g.
    dataset statistics, or 0.5/0.5 to center pixel grids.  Keeping them
    static (rather than running estimates) keeps reset/step pure.
    """
    mean = jnp.asarray(mean, jnp.float32)
    std = jnp.asarray(std, jnp.float32)
    if bool(jnp.any(std == 0)):
        raise ValueError("normalize_observation: std must be non-zero")

    def norm(obs):
        return (obs.astype(jnp.float32) - mean) / std

    def reset(key):
        state, obs = env.reset(key)
        return state, norm(obs)

    def step(state, action):
        state, obs, reward, done, truncated, final_obs = \
            env.step(state, action)
        return state, norm(obs), reward, done, truncated, norm(final_obs)

    in_space = env.observation_space
    if isinstance(in_space, Box) and in_space.bounded:
        # elementwise transformed bounds (mean/std may be obs-shaped,
        # and a negative std flips low/high per element); Box carries
        # scalar bounds, so keep the tightest enclosing interval —
        # finite whenever the input is bounded
        lo = (in_space.low - mean) / std
        hi = (in_space.high - mean) / std
        space = Box(float(jnp.minimum(lo, hi).min()),
                    float(jnp.maximum(lo, hi).max()), env.obs_shape)
    else:
        space = Box(-math.inf, math.inf, env.obs_shape)
    spec = dataclasses.replace(env.spec, observation_space=space)
    return env.replace(spec=spec, reset=reset, step=step)


def scale_reward(env: Environment, scale: float) -> Environment:
    """Multiply rewards by a constant (loss-scale style conditioning)."""

    def step(state, action):
        state, obs, reward, done, truncated, final_obs = \
            env.step(state, action)
        return (state, obs, reward * jnp.float32(scale), done, truncated,
                final_obs)

    return env.replace(step=step)


def flatten_observation(env: Environment) -> Environment:
    """Ravel observations to 1-D — lets MLP policies drive pixel envs."""
    flat = int(math.prod(env.obs_shape))

    def ravel(obs):
        return obs.reshape(flat).astype(jnp.float32)

    def reset(key):
        state, obs = env.reset(key)
        return state, ravel(obs)

    def step(state, action):
        state, obs, reward, done, truncated, final_obs = \
            env.step(state, action)
        return state, ravel(obs), reward, done, truncated, ravel(final_obs)

    in_space = env.observation_space
    if isinstance(in_space, Box):
        space = Box(in_space.low, in_space.high, (flat,))
    else:
        space = Box(-math.inf, math.inf, (flat,))
    spec = dataclasses.replace(env.spec, observation_space=space)
    return env.replace(spec=spec, reset=reset, step=step)


def ensure_vector_obs(env: Environment) -> Environment:
    """The MLP-policy view of any env: identity for vector observations,
    ``flatten_observation`` for image grids.  The one place the
    'what can an MLP agent consume' rule lives — benchmarks and tests
    share it rather than re-deriving the shape check."""
    if len(env.obs_shape) == 1:
        return env
    return flatten_observation(env)


# ---------------------------------------------------------------------------
# time limit
# ---------------------------------------------------------------------------

class TimeLimitState(NamedTuple):
    inner: Any
    t: Array            # steps taken in the current episode
    key: Array          # PRNG for the forced reset on timeout


def time_limit(env: Environment, max_steps: int) -> Environment:
    """Truncate episodes after ``max_steps`` wrapper-level steps.

    A pure timeout is reported as ``truncated`` — NOT folded into
    ``done`` — so value targets keep bootstrapping through it (the
    episode was cut, not terminated).  If the inner env terminates on
    the timeout tick, ``done`` wins.  On a pure timeout the inner env
    is force-reset (fresh key from the wrapper carry), so the
    auto-reset contract holds even for envs whose own horizon is
    longer; ``final_obs`` stays the pre-reset observation.
    """

    def reset(key):
        key, k_inner, k_carry = jax.random.split(key, 3)
        state, obs = env.reset(k_inner)
        return TimeLimitState(state, jnp.zeros((), jnp.int32), k_carry), obs

    def step(state, action):
        inner, obs, reward, done, truncated, final_obs = \
            env.step(state.inner, action)
        t = state.t + 1
        # pure wrapper timeout: episode still alive at the limit
        timeout = (t >= max_steps) & ~done & ~truncated
        truncated = truncated | timeout

        key, sub = jax.random.split(state.key)
        fresh_inner, fresh_obs = env.reset(sub)
        # inner auto-resets on its own boundary; only the wrapper
        # timeout needs the forced reset (final_obs keeps the inner
        # pre-reset observation either way)
        inner = auto_reset(timeout, fresh_inner, inner)
        obs = jnp.where(timeout, fresh_obs, obs)
        t = jnp.where(done | truncated, 0, t)
        return TimeLimitState(inner, t, key), obs, reward, done, \
            truncated, final_obs

    spec = dataclasses.replace(env.spec,
                               max_steps=min(env.spec.max_steps,
                                             max_steps))
    return env.replace(spec=spec, reset=reset, step=step)


# ---------------------------------------------------------------------------
# frame stacking
# ---------------------------------------------------------------------------

class FrameStackState(NamedTuple):
    inner: Any
    frames: Array       # [k, *obs_shape], frames[-1] is newest


def frame_stack(env: Environment, k: int) -> Environment:
    """Stack the last ``k`` observations along the trailing axis.

    Images (H, W, C) become (H, W, C*k); vectors (D,) become (D*k,) —
    the Binarized-P-Network-style temporal context for pixel inputs.
    On episode boundaries the buffer refills with the fresh episode's
    first observation.
    """
    if k < 1:
        raise ValueError(f"frame_stack needs k >= 1, got {k}")

    def stacked(frames: Array) -> Array:
        return jnp.concatenate([frames[i] for i in range(k)], axis=-1)

    def reset(key):
        state, obs = env.reset(key)
        frames = jnp.stack([obs] * k)
        return FrameStackState(state, frames), stacked(frames)

    def step(state, action):
        inner, obs, reward, done, truncated, final_obs = \
            env.step(state.inner, action)
        # the episode's true last stack ends in the pre-reset final_obs
        final = jnp.concatenate([state.frames[1:], final_obs[None]],
                                axis=0)
        rolled = jnp.concatenate([state.frames[1:], obs[None]], axis=0)
        fresh = jnp.stack([obs] * k)        # obs is already post-reset
        frames = jnp.where(done | truncated, fresh, rolled)
        return (FrameStackState(inner, frames), stacked(frames),
                reward, done, truncated, stacked(final))

    in_space = env.observation_space
    shape = in_space.shape[:-1] + (in_space.shape[-1] * k,)
    low = in_space.low if isinstance(in_space, Box) else -math.inf
    high = in_space.high if isinstance(in_space, Box) else math.inf
    spec = dataclasses.replace(env.spec,
                               observation_space=Box(low, high, shape))
    return env.replace(spec=spec, reset=reset, step=step)
