"""Environment registry: ``register()`` factories, ``make()`` instances.

Replaces the hand-rolled ``ENVS`` dict.  Factories are callables
returning a fresh :class:`~repro.rl.envs.base.Environment`; ``make``
forwards kwargs so envs can expose knobs (grid size, max steps, ...).
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

from repro.rl.envs.base import Environment

_REGISTRY: Dict[str, Callable[..., Environment]] = {}


def register(name: str, factory: Callable[..., Environment],
             overwrite: bool = False) -> None:
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"environment {name!r} already registered "
                         "(pass overwrite=True to replace)")
    _REGISTRY[name] = factory


def make(name: str, **kwargs) -> Environment:
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown environment {name!r}; registered: "
            f"{', '.join(registered())}") from None
    env = factory(**kwargs)
    if not isinstance(env, Environment):
        raise TypeError(f"factory for {name!r} returned {type(env)}, "
                        "expected Environment")
    return env


def registered() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))
