"""Pure-JAX MountainCar-v0 (Moore's car-on-a-hill, Gym constants).

2-vector observation [position, velocity], 3 discrete actions
(push left / coast / push right), -1 reward per step, terminal at the
flag (position >= 0.5) or after 200 steps.  A sparse-reward staple for
the quantized-actor parity sweeps.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.rl.envs.base import Environment, EnvSpec, auto_reset
from repro.rl.envs.spaces import Box, Discrete

Array = jax.Array

MIN_POS = -1.2
MAX_POS = 0.6
MAX_SPEED = 0.07
GOAL_POS = 0.5
FORCE = 0.001
GRAVITY = 0.0025
MAX_STEPS = 200

N_ACTIONS = 3
OBS_DIM = 2


class EnvState(NamedTuple):
    position: Array
    velocity: Array
    t: Array
    key: Array


def _obs(s: EnvState) -> Array:
    return jnp.stack([s.position, s.velocity], axis=-1)


def _fresh(key: Array) -> EnvState:
    key, sub = jax.random.split(key)
    pos = jax.random.uniform(sub, (), minval=-0.6, maxval=-0.4)
    return EnvState(pos, jnp.zeros(()), jnp.zeros((), jnp.int32), key)


def reset(key: Array) -> Tuple[EnvState, Array]:
    s = _fresh(key)
    return s, _obs(s)


def step(s: EnvState, action: Array):
    """action in {0, 1, 2} -> force {-1, 0, +1} * FORCE."""
    velocity = (s.velocity + (action.astype(jnp.float32) - 1.0) * FORCE
                - jnp.cos(3 * s.position) * GRAVITY)
    velocity = jnp.clip(velocity, -MAX_SPEED, MAX_SPEED)
    position = jnp.clip(s.position + velocity, MIN_POS, MAX_POS)
    # inelastic left wall
    velocity = jnp.where((position <= MIN_POS) & (velocity < 0),
                         0.0, velocity)
    t = s.t + 1

    done = position >= GOAL_POS
    truncated = (t >= MAX_STEPS) & ~done
    reward = jnp.full((), -1.0, jnp.float32)

    nxt = EnvState(position, velocity, t, s.key)
    out = auto_reset(done | truncated, _fresh(s.key), nxt)
    return out, _obs(out), reward, done, truncated, _obs(nxt)


def make() -> Environment:
    spec = EnvSpec("mountain_car",
                   observation_space=Box(MIN_POS, MAX_POS, (OBS_DIM,)),
                   action_space=Discrete(N_ACTIONS),
                   max_steps=MAX_STEPS)
    return Environment(spec=spec, reset=reset, step=step)
