"""Typed observation/action spaces for the pure-JAX environment API.

Two space kinds cover every registered env:

  * ``Discrete(n)`` — integer actions in ``[0, n)`` (categorical heads);
  * ``Box(low, high, shape)`` — bounded/unbounded float tensors
    (observations, and continuous actions à la Pendulum).

Spaces are frozen dataclasses of python scalars, so an ``EnvSpec`` is
hashable and safe to close over inside jit.  ``sample`` draws a random
element (used by the conformance suite and exploration warmup) and
``contains`` is a jit-friendly membership check.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Tuple, Union

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class Discrete:
    """Integers ``{0, ..., n-1}``; scalar per env instance."""

    n: int

    @property
    def shape(self) -> Tuple[int, ...]:
        return ()

    @property
    def dtype(self):
        return jnp.int32

    def sample(self, key: Array) -> Array:
        return jax.random.randint(key, (), 0, self.n, jnp.int32)

    def contains(self, x: Array) -> Array:
        return (x >= 0) & (x < self.n)


@dataclasses.dataclass(frozen=True)
class Box:
    """Float tensor with (possibly infinite) scalar bounds.

    ``low``/``high`` are python floats broadcast over ``shape`` — every
    env here has uniform bounds per tensor, which keeps the spec
    hashable (no array fields).
    """

    low: float
    high: float
    shape: Tuple[int, ...]

    @property
    def dtype(self):
        return jnp.float32

    @property
    def bounded(self) -> bool:
        return math.isfinite(self.low) and math.isfinite(self.high)

    def sample(self, key: Array) -> Array:
        if self.bounded:
            return jax.random.uniform(key, self.shape, jnp.float32,
                                      self.low, self.high)
        return jax.random.normal(key, self.shape, jnp.float32)

    def contains(self, x: Array) -> Array:
        """Reduces over the event dims only, so a batched ``x``
        ([B, *shape]) yields a [B] mask — same element-wise semantics
        as Discrete.contains."""
        ok = (x >= self.low) & (x <= self.high)
        if self.shape:
            return jnp.all(ok, axis=tuple(range(-len(self.shape), 0)))
        return ok


Space = Union[Discrete, Box]


def head_dim(space: Space) -> int:
    """Policy-head width needed to parameterize a distribution over
    ``space``: ``n`` logits for Discrete, (mean, log_std) pairs for Box.
    """
    if isinstance(space, Discrete):
        return space.n
    return 2 * int(math.prod(space.shape))


def flat_dim(space: Space) -> int:
    """Number of scalars in one element of the space."""
    if isinstance(space, Discrete):
        return 1
    return int(math.prod(space.shape))
