"""Typed environment registry (see base.py for the protocol).

    from repro.rl.envs import make, register, registered
    env = make("cartpole")            # -> Environment (spec + reset/step)

Built-ins: cartpole, keydoor, acrobot, mountain_car, pendulum
(continuous Box actions), catch (pixel grid).  Wrappers live in
``repro.rl.envs.wrappers``; spaces in ``repro.rl.envs.spaces``.
"""
from repro.rl.envs import (acrobot, cartpole, catch, keydoor,
                           mountain_car, pendulum, spaces, wrappers)
from repro.rl.envs.base import Environment, EnvSpec
from repro.rl.envs.registry import make, register, registered
from repro.rl.envs.spaces import Box, Discrete

register("cartpole", cartpole.make)
register("keydoor", keydoor.make)
register("acrobot", acrobot.make)
register("mountain_car", mountain_car.make)
register("pendulum", pendulum.make)
register("catch", catch.make)

__all__ = ["Box", "Discrete", "Environment", "EnvSpec", "make",
           "register", "registered", "spaces", "wrappers"]
