from repro.rl.envs import cartpole, keydoor

ENVS = {"cartpole": cartpole.rollout_capable,
        "keydoor": keydoor.rollout_capable}


def get_env(name: str) -> dict:
    return ENVS[name]()
