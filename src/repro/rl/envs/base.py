"""The typed environment protocol every env and wrapper implements.

An :class:`Environment` is a frozen bundle of two *pure functions* plus
an :class:`EnvSpec` describing its interface:

    env = make("cartpole")
    state, obs = env.reset(key)                       # unbatched
    state, obs, reward, done, truncated, final_obs = \
        env.step(state, action)

Both functions are unbatched and jax.lax-level: batch with ``vmap``,
iterate with ``scan``, and the whole fleet jits into one program — the
substrate the quantized-actor throughput claims are measured on.

Termination vs truncation (the signals value targets bootstrap on):

  * ``done``       — the env reached a *terminal* state (pole fell,
    goal reached).  Value targets must NOT bootstrap across it.
  * ``truncated``  — the episode was cut by a pure time limit while
    still alive.  Value targets MUST bootstrap through it (from
    ``final_obs``); folding timeouts into ``done`` systematically
    biases GAE and every replay target.
  * ``done`` and ``truncated`` are mutually exclusive: a step that
    hits a terminal state on the time-limit tick reports ``done``.
  * episode boundary = ``done | truncated`` — what auto-reset,
    frame-stack refills and episode accounting key off.

Auto-reset contract: the state returned by a boundary transition is a
fresh episode and ``obs`` is the fresh episode's first observation;
``final_obs`` is the *pre-reset* observation of the transition itself
(``final_obs == obs`` off-boundary), so bootstrap targets always see
the state the episode actually ended in.  Wrappers preserve this.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from repro.rl.envs.spaces import Box, Discrete, Space

Array = jax.Array

# reset(key) -> (state, obs)
ResetFn = Callable[[Array], Tuple[Any, Array]]
# step(state, action) -> (state, obs, reward, done, truncated, final_obs)
StepFn = Callable[[Any, Array],
                  Tuple[Any, Array, Array, Array, Array, Array]]


def auto_reset(done: Array, fresh: Any, nxt: Any) -> Any:
    """Select ``fresh`` state leaves where ``done``, else ``nxt`` —
    the shared implementation of the auto-reset contract."""
    return jax.tree.map(lambda a, b: jnp.where(done, a, b), fresh, nxt)


def angle_wrap(x: Array) -> Array:
    """Wrap angles to [-pi, pi)."""
    return ((x + jnp.pi) % (2 * jnp.pi)) - jnp.pi


@dataclasses.dataclass(frozen=True)
class EnvSpec:
    """Static interface description of an environment."""

    name: str
    observation_space: Space
    action_space: Space
    max_steps: int

    @property
    def obs_shape(self) -> Tuple[int, ...]:
        return self.observation_space.shape

    @property
    def n_actions(self) -> int:
        if not isinstance(self.action_space, Discrete):
            raise TypeError(
                f"{self.name}: action space is {self.action_space!r}, "
                "not Discrete — use spec.action_space directly")
        return self.action_space.n

    @property
    def continuous(self) -> bool:
        return isinstance(self.action_space, Box)


@dataclasses.dataclass(frozen=True)
class Environment:
    """A spec plus pure reset/step functions (see module docstring)."""

    spec: EnvSpec
    reset: ResetFn
    step: StepFn

    # convenience passthroughs so call-sites stay short
    @property
    def observation_space(self) -> Space:
        return self.spec.observation_space

    @property
    def action_space(self) -> Space:
        return self.spec.action_space

    @property
    def obs_shape(self) -> Tuple[int, ...]:
        return self.spec.obs_shape

    def replace(self, **kw) -> "Environment":
        """Functional update — how wrappers derive new environments."""
        return dataclasses.replace(self, **kw)
