"""q_matmul: every matmul in the framework goes through here.

This is the software realization of the paper's Q-MAC: a precision-
configurable multiply-accumulate engine.  Three backends with identical
semantics (tests enforce agreement):

  * ``ref``    — pure-jnp fake-quant oracle (golden semantics),
  * ``xla``    — real int8 x int8 -> int32 ``lax.dot_general`` (this is
                 what the multi-pod dry-run lowers; XLA maps it onto the
                 MXU int8 path on TPU, i.e. the 2x-throughput mode),
  * ``pallas`` — the Q-MAC Pallas kernel (kernels/qmac), VMEM-tiled.

Gradients: straight-through (QAT standard) — the forward pass runs the
quantized product, the backward pass differentiates the fp32 product.

Weights may be passed as fp arrays (training / QAT) or as ``QTensor``
(serving: int8 payload lives in HBM, 4x smaller — this is what makes the
memory roofline term actually drop in the dry-run).
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.core.fxp import (QTensor, absmax_scale, dequantize, fake_quant,
                            fake_quant_rowwise, fxp_dtype, fxp_qmax,
                            quantize)
from repro.core.policy import QuantPolicy

Array = jax.Array


def quantize_rowwise(x: Array, bits: int):
    """Per-token (last-axis) symmetric quantization for activations.

    Elementwise math stays in x.dtype (bf16 holds +-qmax exactly for
    8-bit); only the scale is fp32.  Keeping the upcast out of the
    elementwise path stops XLA from converting whole saved-activation
    stacks to fp32 in the backward pass.
    """
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax.astype(jnp.float32), 1e-12) / fxp_qmax(bits)
    q = jnp.clip(jnp.round(x / scale.astype(x.dtype)),
                 -fxp_qmax(bits), fxp_qmax(bits))
    return q.astype(fxp_dtype(bits)), scale


def _int_dot(qx: Array, qw: Array) -> Array:
    """intN x intN -> int32 contraction of x's last dim with w's first."""
    dn = (((qx.ndim - 1,), (0,)), ((), ()))
    return jax.lax.dot_general(qx, qw, dn,
                               preferred_element_type=jnp.int32)


def _fp_dot(x: Array, w: Array, dtype) -> Array:
    dn = (((x.ndim - 1,), (0,)), ((), ()))
    return jax.lax.dot_general(x.astype(dtype), w.astype(dtype), dn)


# ---------------------------------------------------------------------------
# forward implementations per backend
# ---------------------------------------------------------------------------

def _fwd_quantized(policy: QuantPolicy, x: Array, w: Array) -> Array:
    """Quantized forward product (both operands quantized, fp dequant)."""
    cdt = policy.compute_dtype
    w_ch = 1 if policy.per_channel else None
    if policy.backend == "ref":
        xq = fake_quant_rowwise(x, policy.a_bits) \
            if policy.quantized_a else x
        wq = fake_quant(w, policy.w_bits, w_ch) if policy.quantized_w else w
        return _fp_dot(xq, wq, cdt)
    if policy.backend in ("xla", "pallas"):
        # integer accumulation path only at <=8 bits: 16-bit products
        # would overflow int32 accumulators (the FPGA uses wider
        # accumulators there; on TPU FxP16 maps to the bf16 MXU path).
        if policy.quantized_a and policy.quantized_w \
                and policy.a_bits <= 8 and policy.w_bits <= 8:
            qx, sx = quantize_rowwise(x, policy.a_bits)
            qw, sw = quantize(w, policy.w_bits, channel_axis=w_ch)
            if policy.backend == "pallas" and policy.a_bits == 8 \
                    and policy.w_bits == 8 and qx.ndim == 2:
                from repro.kernels.qmac import ops as qmac_ops
                acc = qmac_ops.qmac_i8(qx, qw)
            else:
                acc = _int_dot(qx, qw)
            sw_bc = sw.reshape((1,) * (acc.ndim - 1) + (-1,)) \
                if policy.per_channel else sw.reshape((1,) * acc.ndim)
            return (acc.astype(jnp.float32) * sx * sw_bc).astype(cdt)
        # weight-only (or 32-bit act): dequant weight, fp matmul
        xq = fake_quant_rowwise(x, policy.a_bits) \
            if policy.quantized_a else x
        wq = fake_quant(w, policy.w_bits, w_ch) if policy.quantized_w else w
        return _fp_dot(xq, wq, cdt)
    raise ValueError(f"unknown backend {policy.backend!r}")


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _qmm(policy: QuantPolicy, x: Array, w: Array) -> Array:
    return _fwd_quantized(policy, x, w)


def _qmm_fwd(policy, x, w):
    return _fwd_quantized(policy, x, w), (x, w)


def _qmm_bwd(policy, res, g):
    x, w = res
    cdt = policy.compute_dtype
    g = g.astype(cdt)
    # dx = g @ w^T  (contract g's last dim with w's last dim)
    dx = jax.lax.dot_general(
        g, w.astype(cdt), (((g.ndim - 1,), (1,)), ((), ())))
    # dw = x^T @ g  (contract all batch dims)
    bdims = tuple(range(x.ndim - 1))
    dw = jax.lax.dot_general(
        x.astype(cdt), g, ((bdims, bdims), ((), ())))
    return dx.astype(x.dtype), dw.astype(w.dtype)


_qmm.defvjp(_qmm_fwd, _qmm_bwd)


def _serve_quantized(policy: QuantPolicy, x: Array, w: QTensor) -> Array:
    """Forward with a pre-quantized (QTensor) weight — serving path."""
    cdt = policy.compute_dtype
    if policy.quantized_a and w.bits <= 8 and policy.a_bits <= 8:
        qx, sx = quantize_rowwise(x, policy.a_bits)
        if policy.backend == "pallas" and policy.a_bits == 8 \
                and w.bits == 8 and qx.ndim == 2:
            from repro.kernels.qmac import ops as qmac_ops
            acc = qmac_ops.qmac_i8(qx, qw=w.qvalue)
        else:
            acc = _int_dot(qx, w.qvalue)
        sw = w.scale.reshape((1,) * (acc.ndim - 1) + (-1,)) \
            if w.scale.size > 1 else w.scale.reshape((1,) * acc.ndim)
        return (acc.astype(jnp.float32) * sx * sw).astype(cdt)
    # weight-only serving: dequantize into compute dtype, fp matmul.
    return _fp_dot(x, w.deq(cdt), cdt)


def q_matmul(x: Array, w: Union[Array, QTensor],
             policy: Optional[QuantPolicy] = None) -> Array:
    """Contract ``x``'s last axis with ``w``'s first axis under ``policy``.

    The single entry point for every dense product in the framework.
    """
    if policy is None:
        policy = QuantPolicy()
    if isinstance(w, QTensor):
        return _serve_quantized(policy, x, w)
    if not (policy.quantized_w or policy.quantized_a):
        return _fp_dot(x, w, policy.compute_dtype)
    return _qmm(policy, x, w)


# ---------------------------------------------------------------------------
# batched (per-expert) variant for MoE: x [E, C, K] @ w [E, K, N]
# ---------------------------------------------------------------------------

def _fwd_bmm(policy: QuantPolicy, x: Array, w: Array) -> Array:
    cdt = policy.compute_dtype
    dn = (((2,), (1,)), ((0,), (0,)))
    if (policy.quantized_a and policy.quantized_w
            and policy.a_bits <= 8 and policy.w_bits <= 8
            and policy.backend in ("xla", "pallas")):
        qx, sx = quantize_rowwise(x, policy.a_bits)          # [E,C,1]
        # per-(expert, out-channel) weight scales
        amax = jnp.max(jnp.abs(w), axis=1, keepdims=True)     # [E,1,N]
        sw = jnp.maximum(amax, 1e-12) / fxp_qmax(policy.w_bits)
        qw = jnp.clip(jnp.round(w / sw), -fxp_qmax(policy.w_bits),
                      fxp_qmax(policy.w_bits)).astype(
                          fxp_dtype(policy.w_bits))
        acc = jax.lax.dot_general(qx, qw, dn,
                                  preferred_element_type=jnp.int32)
        return (acc.astype(jnp.float32) * sx * sw).astype(cdt)
    xq = fake_quant_rowwise(x, policy.a_bits) if policy.quantized_a else x
    wq = fake_quant(w, policy.w_bits, 2) if policy.quantized_w else w
    return jax.lax.dot_general(xq.astype(cdt), wq.astype(cdt), dn)


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _qbmm(policy: QuantPolicy, x: Array, w: Array) -> Array:
    return _fwd_bmm(policy, x, w)


def _qbmm_fwd(policy, x, w):
    return _fwd_bmm(policy, x, w), (x, w)


def _qbmm_bwd(policy, res, g):
    x, w = res
    cdt = policy.compute_dtype
    g = g.astype(cdt)
    dx = jax.lax.dot_general(                        # g[E,C,N] wT -> [E,C,K]
        g, w.astype(cdt), (((2,), (2,)), ((0,), (0,))))
    dw = jax.lax.dot_general(                        # xT g -> [E,K,N]
        x.astype(cdt), g, (((1,), (1,)), ((0,), (0,))))
    return dx.astype(x.dtype), dw.astype(w.dtype)


_qbmm.defvjp(_qbmm_fwd, _qbmm_bwd)


def q_batched_matmul(x: Array, w: Union[Array, QTensor],
                     policy: Optional[QuantPolicy] = None) -> Array:
    """Per-expert contraction: x [E, C, K] @ w [E, K, N] -> [E, C, N]."""
    if policy is None:
        policy = QuantPolicy()
    if isinstance(w, QTensor):
        # serving: dequantize per-expert weights into compute dtype
        wf = w.deq(policy.compute_dtype)
        return _fwd_bmm(policy.replace(w_bits=32), x, wf) \
            if policy.quantized_a else jax.lax.dot_general(
                x.astype(policy.compute_dtype), wf,
                (((2,), (1,)), ((0,), (0,))))
    if not (policy.quantized_w or policy.quantized_a):
        return jax.lax.dot_general(
            x.astype(policy.compute_dtype), w.astype(policy.compute_dtype),
            (((2,), (1,)), ((0,), (0,))))
    return _qbmm(policy, x, w)
