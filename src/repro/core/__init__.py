"""QForce-RL core: adaptive fixed-point quantization, Q-MAC matmul
dispatch, V-ACT activations, and precision policies."""
from repro.core.fxp import (QTensor, absmax_scale, dequantize, fake_quant,
                            fxp_dtype, fxp_qmax, is_qtensor, quantize,
                            quantize_eq1)
from repro.core.policy import (BF16, FP32, FXP8, FXP16, FXP32, PRESETS, W8,
                               W8A8, W8A8KV8, W8A8_BF16, QuantPolicy,
                               cordic_iterations, get_policy)
from repro.core.qmatmul import q_matmul, quantize_rowwise
from repro.core.quantizer import (dequantize_params, quantize_params,
                                  quantized_nbytes)
from repro.core.vact import (activation, cordic_exp, cordic_sigmoid,
                             cordic_softmax, cordic_tanh)

__all__ = [
    "QTensor", "QuantPolicy", "q_matmul", "quantize", "dequantize",
    "fake_quant", "quantize_eq1", "activation", "quantize_params",
    "dequantize_params", "get_policy", "FP32", "FXP8", "FXP16", "FXP32",
    "W8", "W8A8", "W8A8KV8", "BF16", "W8A8_BF16",
]
