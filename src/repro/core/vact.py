"""V-ACT: versatile CORDIC-based activation functions (paper Sec. III-B).

The paper evaluates Sigmoid / Tanh / ReLU / Softmax on a single
reconfigurable low-latency hyperbolic-CORDIC datapath at FxP8/16/32.
The TPU adaptation (see DESIGN.md) keeps the *algorithm* — shift-add
hyperbolic CORDIC with the low-latency iteration schedule, (3n/8 + 1)
iterations — as the paper-faithful numerical path, and exposes a
"native" path (jax.nn) that is what a production TPU deployment would
use on the VPU.  Both are selectable via ``QuantPolicy.act_backend``.

Decomposition used (identical to the hardware datapath):

    e^x      = 2^m * (cosh r + sinh r),  m = floor(x/ln2), r = x - m ln2
    sigmoid  = 1 / (1 + e^{-x})
    tanh     = 2 sigmoid(2x) - 1
    softmax  = e^{x - max} / sum e^{x - max}

cosh/sinh come from hyperbolic CORDIC rotations; the 2^m factor is a
pure exponent shift (free on the FPGA, an ldexp here).  The hyperbolic
iteration schedule repeats i = 4 and i = 13 to guarantee convergence.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fxp import fake_quant
from repro.core.policy import QuantPolicy, cordic_iterations

Array = jax.Array

LN2 = math.log(2.0)

# Hyperbolic CORDIC convergence requires repeating iterations 4, 13, 40...
_REPEAT = (4, 13, 40)
_MAX_ITERS = 24


def hyperbolic_schedule(n_iters: int) -> Sequence[int]:
    """Shift indices i (starting at 1) with the standard repeats."""
    seq = []
    i = 1
    while len(seq) < n_iters:
        seq.append(i)
        if i in _REPEAT and (len(seq) < n_iters):
            seq.append(i)           # repeated iteration
        i += 1
    return tuple(seq[:n_iters])


def cordic_gain(schedule: Sequence[int]) -> float:
    g = 1.0
    for i in schedule:
        g *= math.sqrt(1.0 - 2.0 ** (-2 * i))
    return g


_ATANH = tuple(math.atanh(2.0 ** (-i)) for i in range(1, _MAX_ITERS + 2))


def cordic_sinh_cosh(z: Array, n_iters: int):
    """Vectorized hyperbolic CORDIC (rotation mode).

    Valid for |z| <= sum(atanh(2^-i)) ~= 1.1182 over the schedule; the
    exp() range reduction below guarantees z in [0, ln2).
    Returns (sinh z, cosh z).
    """
    sched = hyperbolic_schedule(n_iters)
    gain = cordic_gain(sched)
    x = jnp.full_like(z, 1.0 / gain)  # pre-scale: removes the K factor
    y = jnp.zeros_like(z)
    zz = z
    for i in sched:
        d = jnp.where(zz >= 0, 1.0, -1.0)
        e = _ATANH[i - 1]
        shift = 2.0 ** (-i)
        x, y = x + d * y * shift, y + d * x * shift
        zz = zz - d * e
    return y, x


def cordic_exp(x: Array, n_iters: int) -> Array:
    """e^x via range reduction + hyperbolic CORDIC.

    m = floor(x / ln2) is a shift count on the FPGA; r in [0, ln2).
    """
    x = x.astype(jnp.float32)
    m = jnp.floor(x / LN2)
    r = x - m * LN2
    s, c = cordic_sinh_cosh(r, n_iters)
    e_r = s + c
    # clamp the exponent so 2^m stays finite in fp32
    m = jnp.clip(m, -126, 126).astype(jnp.int32)
    return jnp.ldexp(e_r, m)


def cordic_sigmoid(x: Array, n_iters: int) -> Array:
    e = cordic_exp(-jnp.abs(x), n_iters)          # e^{-|x|} in (0, 1]
    pos = 1.0 / (1.0 + e)                          # for x >= 0
    return jnp.where(x >= 0, pos, 1.0 - pos)


def cordic_tanh(x: Array, n_iters: int) -> Array:
    return 2.0 * cordic_sigmoid(2.0 * x, n_iters) - 1.0


def cordic_softmax(x: Array, n_iters: int, axis: int = -1) -> Array:
    m = jax.lax.stop_gradient(jnp.max(x, axis=axis, keepdims=True))
    e = cordic_exp(x - m, n_iters)
    return e / jnp.sum(e, axis=axis, keepdims=True)


# ---------------------------------------------------------------------------
# Dispatch
# ---------------------------------------------------------------------------

_NATIVE = {
    "relu": jax.nn.relu,
    "sigmoid": jax.nn.sigmoid,
    "tanh": jnp.tanh,
    "softmax": jax.nn.softmax,
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "identity": lambda x: x,
}

# activation kinds V-ACT implements natively in hardware
VACT_KINDS = ("relu", "sigmoid", "tanh", "softmax")


def activation(x: Array, kind: str, policy: Optional[QuantPolicy] = None,
               axis: int = -1) -> Array:
    """Evaluate an activation under the policy's act_backend.

    When the policy quantizes activations (a_bits < 32) the output is
    fake-quantized — this models V-ACT's fused requantize stage (the
    FPGA unit emits FxP directly; fusing avoids an HBM round trip).
    """
    if policy is None or policy.act_backend == "native" or kind not in VACT_KINDS:
        if kind == "softmax":
            out = jax.nn.softmax(x, axis=axis)
        else:
            out = _NATIVE[kind](x)
    else:
        n = cordic_iterations(policy)
        if kind == "relu":
            out = jax.nn.relu(x)     # ReLU is a mux on the FPGA too
        elif kind == "sigmoid":
            out = cordic_sigmoid(x, n)
        elif kind == "tanh":
            out = cordic_tanh(x, n)
        elif kind == "softmax":
            out = cordic_softmax(x, n, axis=axis)
        else:  # pragma: no cover
            raise KeyError(kind)
    if policy is not None and policy.quantized_a and kind != "softmax":
        out = fake_quant(out, policy.a_bits)
    return out.astype(x.dtype)
