"""Adaptive fixed-point (AdFxP) formats and uniform affine quantization.

This is the numerical heart of QForce-RL: the paper's Q-MAC consumes
adaptive fixed-point operands whose scale is derived from the dynamic
range of the tensor (paper Eq. 1).  We implement:

  * symmetric abs-max quantization (what AdFxP reduces to for zero-mean
    weight tensors; the form QuaRL / Q-Actor use in practice),
  * the paper's Eq. (1) affine variant (range = |min(x,0)| + |max(x,0)|),
  * straight-through-estimator (STE) fake quantization for QAT,
  * ``QTensor`` — a real quantized tensor (int payload + fp scale) used
    for weight-only serving and int8 KV caches, registered as a pytree so
    it flows through jit/pjit/scan and shows up in ``memory_analysis`` at
    its true (4x smaller) byte size.

Precisions follow the paper's FxP8/16/32 triple.  FxP32 is treated as the
full-precision baseline (the paper uses it as such).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

# int dtype and symmetric max magnitude per FxP precision.  4-bit
# values live in an int8 *container* (no sub-byte dtype on the
# accelerator) — two codes per byte when actually stored/shipped; see
# ``pack_nibbles`` and the sub-byte-aware
# ``repro.core.quantizer.quantized_nbytes``.
_FXP_SPECS = {
    4: (jnp.int8, 7.0),
    8: (jnp.int8, 127.0),
    16: (jnp.int16, 32767.0),
    32: (jnp.int32, 2147483647.0),
}


def fxp_dtype(bits: int):
    return _FXP_SPECS[bits][0]


def fxp_qmax(bits: int) -> float:
    return _FXP_SPECS[bits][1]


def _reduce_axes(x_ndim: int, channel_axis: Optional[int]) -> Tuple[int, ...]:
    """Axes to reduce when computing scales.

    ``channel_axis=None`` -> per-tensor scale; otherwise per-channel along
    that axis (the axis is kept, everything else reduced).
    """
    if channel_axis is None:
        return tuple(range(x_ndim))
    channel_axis = channel_axis % x_ndim
    return tuple(i for i in range(x_ndim) if i != channel_axis)


def absmax_scale(x: Array, bits: int, channel_axis: Optional[int] = None,
                 eps: float = 1e-12) -> Array:
    """Symmetric AdFxP scale: one LSB = absmax / qmax (keepdims)."""
    axes = _reduce_axes(x.ndim, channel_axis)
    amax = jnp.max(jnp.abs(x), axis=axes, keepdims=True)
    qmax = fxp_qmax(bits)
    return jnp.maximum(amax, eps) / qmax


def quantize(x: Array, bits: int, channel_axis: Optional[int] = None,
             scale: Optional[Array] = None) -> Tuple[Array, Array]:
    """Symmetric quantization to intN.  Returns (q, scale)."""
    if bits == 32:
        # FxP32 baseline: pass-through (scale 1).  Keeping a real int32
        # path would add nothing numerically (fp32 mantissa dominates).
        return x, jnp.ones((1,) * x.ndim, x.dtype)
    if scale is None:
        scale = absmax_scale(x, bits, channel_axis)
    dt, qmax = _FXP_SPECS[bits]
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(dt)
    return q, scale


def dequantize(q: Array, scale: Array, dtype=jnp.float32) -> Array:
    return q.astype(dtype) * scale.astype(dtype)


def quantize_eq1(w: Array, n: int = 8) -> Tuple[Array, Array]:
    """The paper's Eq. (1) uniform affine quantizer.

      Q_n(W) = round( W * 2^n / (|min(W,0)| + |max(W,0)|) )

    Range is the total dynamic span |min|+|max|; this is an affine grid of
    2^n steps across the observed range.  Returns (q, scale) with
    scale = span / 2^n so that dequantize(q, scale) ~= W.
    """
    lo = jnp.abs(jnp.minimum(jnp.min(w), 0.0))
    hi = jnp.abs(jnp.maximum(jnp.max(w), 0.0))
    span = jnp.maximum(lo + hi, 1e-12)
    scale = span / (2.0 ** n)
    q = jnp.round(w / scale)
    # clip to the signed grid implied by n+1 bits of headroom
    q = jnp.clip(q, -(2.0 ** n), 2.0 ** n)
    return q, scale


# ---------------------------------------------------------------------------
# Straight-through fake quantization (QAT)
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def fake_quant(x: Array, bits: int, channel_axis: Optional[int] = None) -> Array:
    """Quantize-dequantize with a straight-through gradient."""
    if bits == 32:
        return x
    q, s = quantize(x, bits, channel_axis)
    return dequantize(q, s, x.dtype)


def _fake_quant_fwd(x, bits, channel_axis):
    return fake_quant(x, bits, channel_axis), None


def _fake_quant_bwd(bits, channel_axis, res, g):
    del bits, channel_axis, res
    return (g,)


fake_quant.defvjp(_fake_quant_fwd, _fake_quant_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def fake_quant_rowwise(x: Array, bits: int) -> Array:
    """Per-token (last-axis scale) fake quantization with STE.

    Matches the grid of ``qmatmul.quantize_rowwise`` so the ref and
    xla/pallas backends share identical quantization semantics.
    """
    if bits == 32:
        return x
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    qmax = fxp_qmax(bits)
    scale = jnp.maximum(amax, 1e-12) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax)
    return (q * scale).astype(x.dtype)


def _fqr_fwd(x, bits):
    return fake_quant_rowwise(x, bits), None


def _fqr_bwd(bits, res, g):
    del bits, res
    return (g,)


fake_quant_rowwise.defvjp(_fqr_fwd, _fqr_bwd)


# ---------------------------------------------------------------------------
# QTensor: a really-quantized tensor (int payload + scale)
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QTensor:
    """int payload + broadcastable fp scale.  ``deq()`` restores fp."""

    qvalue: Array
    scale: Array
    bits: int = 8

    def tree_flatten(self):
        return (self.qvalue, self.scale), (self.bits,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        q, s = children
        return cls(q, s, aux[0])

    @property
    def shape(self):
        return self.qvalue.shape

    @property
    def dtype(self):
        return self.qvalue.dtype

    @property
    def ndim(self):
        return self.qvalue.ndim

    def deq(self, dtype=jnp.float32) -> Array:
        return dequantize(self.qvalue, self.scale, dtype)

    @classmethod
    def quant(cls, x: Array, bits: int = 8,
              channel_axis: Optional[int] = None) -> "QTensor":
        q, s = quantize(x, bits, channel_axis)
        return cls(q, s, bits)


def is_qtensor(x: Any) -> bool:
    return isinstance(x, QTensor)


# ---------------------------------------------------------------------------
# sub-byte (int4) storage: two codes per byte
# ---------------------------------------------------------------------------

def pack_nibbles(q: Array) -> Array:
    """Pack int4 codes (int8 container, values in [-8, 7]) into a flat
    uint8 array, two codes per byte (low nibble first).  Odd element
    counts pad the final high nibble with zero.  This is the *wire/
    storage* layout — compute unpacks back into the int8 container
    (the FPGA's 4-bit SIMD lanes read the nibbles directly)."""
    flat = q.reshape(-1).astype(jnp.int8)
    if flat.size % 2:
        flat = jnp.concatenate([flat, jnp.zeros((1,), jnp.int8)])
    lo = (flat[0::2] & 0x0F).astype(jnp.uint8)
    hi = (flat[1::2] & 0x0F).astype(jnp.uint8)
    return lo | (hi << 4)


def unpack_nibbles(packed: Array, size: int) -> Array:
    """Inverse of :func:`pack_nibbles`: ``size`` int4 codes, sign-
    extended back into the int8 container."""
    lo = (packed & 0x0F).astype(jnp.int8)
    hi = ((packed >> 4) & 0x0F).astype(jnp.int8)
    both = jnp.stack([lo, hi], axis=1).reshape(-1)[:size]
    # sign-extend the 4-bit two's-complement codes
    return jnp.where(both >= 8, both - 16, both).astype(jnp.int8)


def nbytes_of(x: Union[Array, QTensor, jax.ShapeDtypeStruct]) -> int:
    """Byte footprint (QTensor counts payload + scale)."""
    if isinstance(x, QTensor):
        return nbytes_of(x.qvalue) + nbytes_of(x.scale)
    return int(np.prod(x.shape)) * x.dtype.itemsize


def as_dense(w, dtype=None):
    """Plain-array view of a maybe-QTensor weight (dequantize if needed)."""
    if isinstance(w, QTensor):
        return w.deq(dtype or jnp.float32)
    return w.astype(dtype) if dtype is not None else w
