"""QuantPolicy: the framework-wide precision dial.

The paper's Q-MAC exposes precision as a runtime configuration
(FxP8/16/32 -> 16/4/1 MACs per cycle).  In this framework the same dial
is a policy object threaded through every matmul / activation / cache /
collective.  A single policy choice re-targets an entire architecture
(LM or RL agent) to a precision mode, which is exactly the deployment
story of the paper's "parametrized efficient deployment".
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """Per-role bit-widths + backend selection.

    bits == 32 means "full precision / no quantization" for that role
    (FxP32 is the paper's baseline and maps to fp32/bf16 on TPU).
    """

    name: str = "fp32"
    w_bits: int = 32              # weight matmul operand
    a_bits: int = 32              # activation matmul operand
    kv_bits: int = 32             # KV / recurrent-state cache payload
    grad_bits: int = 32           # DP gradient all-reduce payload
    comm_bits: int = 32           # learner->actor weight sync payload
    backend: str = "xla"          # one of {"ref", "xla", "pallas"}
    act_backend: str = "native"   # one of {"native", "cordic"}
    per_channel: bool = True      # per-out-channel weight scales
    # dtype used for fp compute around the quantized core
    compute_dtype: object = jnp.float32
    # CORDIC iteration count override (None -> 3*bits/8 + 1 heuristic)
    cordic_iters: Optional[int] = None

    @property
    def quantized_w(self) -> bool:
        return self.w_bits < 32

    @property
    def quantized_a(self) -> bool:
        return self.a_bits < 32

    def with_backend(self, backend: str) -> "QuantPolicy":
        return dataclasses.replace(self, backend=backend)

    def replace(self, **kw) -> "QuantPolicy":
        return dataclasses.replace(self, **kw)


# --- presets -------------------------------------------------------------

FP32 = QuantPolicy(name="fp32")
# paper's three SIMD modes
FXP8 = QuantPolicy(name="fxp8", w_bits=8, a_bits=8, kv_bits=8, comm_bits=8)
FXP16 = QuantPolicy(name="fxp16", w_bits=16, a_bits=16, kv_bits=16,
                    comm_bits=16)
FXP32 = QuantPolicy(name="fxp32")  # baseline: full precision semantics
# LM serving/training presets
W8A8 = QuantPolicy(name="w8a8", w_bits=8, a_bits=8)
W8 = QuantPolicy(name="w8", w_bits=8)                       # weight-only
W8A8KV8 = QuantPolicy(name="w8a8kv8", w_bits=8, a_bits=8, kv_bits=8)
# the QuaRL-style W8->W4 deployment sweep: int4 weights (two codes per
# byte on the wire/in HBM), activations fp32 or int8
W4 = QuantPolicy(name="w4", w_bits=4)                       # weight-only
W4A8 = QuantPolicy(name="w4a8", w_bits=4, a_bits=8)
BF16 = QuantPolicy(name="bf16", compute_dtype=jnp.bfloat16)
W8A8_BF16 = QuantPolicy(name="w8a8_bf16", w_bits=8, a_bits=8,
                        compute_dtype=jnp.bfloat16)
# the full QForce deployment point: int8 weights/activations/KV/comms
# around a bf16 MXU datapath — the TPU analogue of the paper's FxP8
QFORCE8 = QuantPolicy(name="qforce8", w_bits=8, a_bits=8, kv_bits=8,
                      comm_bits=8, compute_dtype=jnp.bfloat16)

PRESETS = {p.name: p for p in
           [FP32, FXP8, FXP16, FXP32, W8A8, W8, W8A8KV8, W4, W4A8,
            BF16, W8A8_BF16, QFORCE8]}


def get_policy(name: str) -> QuantPolicy:
    if name not in PRESETS:
        raise KeyError(f"unknown quant policy '{name}' "
                       f"(available: {sorted(PRESETS)})")
    return PRESETS[name]


def cordic_iterations(policy: QuantPolicy, bits: Optional[int] = None) -> int:
    """Paper: low-latency hybrid CORDIC converges in (3n/8 + 1) cycles.

    n is the datapath width.  We floor at 6 iterations so that even the
    FxP8 mode resolves tanh/sigmoid to ~2^-6, comparable to the int8 grid.
    """
    if policy.cordic_iters is not None:
        return policy.cordic_iters
    b = bits if bits is not None else max(policy.a_bits, 8)
    return max(3 * b // 8 + 1, 6)
