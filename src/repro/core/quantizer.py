"""Tree-level quantization: PTQ of whole parameter pytrees + calibration.

``quantize_params`` converts the matmul weights of a trained (or freshly
initialized) model into ``QTensor``s — this is the step the paper's
deployment flow performs when the learner's FxP32 policy is shipped to
the quantized actors / the FPGA engine, and the step an LM serving
config performs to halve/quarter HBM traffic.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.fxp import QTensor, quantize
from repro.core.policy import QuantPolicy

Array = jax.Array

# parameter leaf names that hold matmul weights (framework convention:
# nn/ layers always call their matmul weights "w" and their embedding
# tables "emb")
_WEIGHT_KEYS = ("w", "w_in", "w_out", "w_gate", "w_up", "w_down",
                "wq", "wk", "wv", "wo", "w_x", "w_h", "emb")


def _path_leaf_name(path) -> str:
    last = path[-1]
    if isinstance(last, jax.tree_util.DictKey):
        return str(last.key)
    return str(last)


def default_weight_predicate(path, leaf) -> bool:
    if not isinstance(leaf, jnp.ndarray) or leaf.ndim < 2:
        return False
    return _path_leaf_name(path) in _WEIGHT_KEYS


def quantize_params(params, policy: QuantPolicy,
                    predicate: Optional[Callable] = None):
    """PTQ: replace matmul weights with QTensors (int payload + scales).

    Per-channel scales go on the last axis (output features).  Stacked
    (scan-over-layers) weights [L, in, out] get per-(layer, channel)
    scales automatically because ``channel_axis`` counts from the end.
    """
    if predicate is None:
        predicate = default_weight_predicate
    if not policy.quantized_w:
        return params

    def convert(path, leaf):
        if predicate(path, leaf):
            ch = (leaf.ndim - 1) if policy.per_channel else None
            # for scan-stacked layers [L, in, out] keep a scale per
            # layer as well: reduce only the contraction axis (ndim-2).
            # Exactly 3D — conv kernels (HWIO, 4D) take the plain
            # per-out-channel branch below, the grid the conv forward's
            # fake-quant uses (channel_axis=3), so packed conv weights
            # dequantize bit-identically to the training-time grid
            if policy.per_channel and leaf.ndim == 3:
                amax = jnp.max(jnp.abs(leaf), axis=-2, keepdims=True)
                from repro.core.fxp import fxp_qmax, fxp_dtype
                scale = jnp.maximum(amax, 1e-12) / fxp_qmax(policy.w_bits)
                q = jnp.clip(jnp.round(leaf / scale),
                             -fxp_qmax(policy.w_bits),
                             fxp_qmax(policy.w_bits)).astype(
                                 fxp_dtype(policy.w_bits))
                return QTensor(q, scale, policy.w_bits)
            q, s = quantize(leaf, policy.w_bits, channel_axis=ch)
            return QTensor(q, s, policy.w_bits)
        return leaf

    return jax.tree_util.tree_map_with_path(convert, params)


def dequantize_params(params):
    """Inverse of quantize_params (lossy, for round-trip testing)."""
    return jax.tree.map(
        lambda l: l.deq() if isinstance(l, QTensor) else l,
        params, is_leaf=lambda l: isinstance(l, QTensor))


def quantized_nbytes(params) -> Tuple[int, int]:
    """(bytes as stored, bytes if everything were fp32) for a pytree.

    Sub-byte aware: a QTensor whose ``bits`` is narrower than its int
    container counts at its *packed* width — two int4 codes per byte
    (``fxp.pack_nibbles`` is the matching storage layout) — so model-
    size numbers track the paper's compression claims instead of the
    container dtype.
    """
    stored = 0
    fp32 = 0
    for leaf in jax.tree.leaves(
            params, is_leaf=lambda l: isinstance(l, QTensor)):
        if isinstance(leaf, QTensor):
            container_bits = leaf.qvalue.dtype.itemsize * 8
            payload_bits = min(int(leaf.bits), container_bits)
            stored += (leaf.qvalue.size * payload_bits + 7) // 8
            stored += leaf.scale.size * leaf.scale.dtype.itemsize
            fp32 += leaf.qvalue.size * 4
        else:
            stored += leaf.size * leaf.dtype.itemsize
            fp32 += leaf.size * 4
    return stored, fp32


class EmaCalibrator:
    """Running abs-max EMA for static activation scales (QAT helper)."""

    def __init__(self, momentum: float = 0.99):
        self.momentum = momentum

    def init(self) -> Array:
        return jnp.zeros(())

    def update(self, state: Array, x: Array) -> Array:
        amax = jnp.max(jnp.abs(x))
        return jnp.where(state == 0, amax,
                         self.momentum * state + (1 - self.momentum) * amax)
