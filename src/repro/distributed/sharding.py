"""Logical-axis sharding (MaxText-style) for the production meshes.

Parameters are annotated with *logical* axis names at init time
(nn/module.Param).  A per-(arch, mesh) rule table maps logical names to
mesh axes; ``make_shardings`` turns an axes tree into NamedShardings,
and ``constrain`` applies in-graph sharding constraints to activations
(used for sequence-parallel activations and MoE dispatch buffers).

Rule resolution handles the two mesh flavours transparently:
("data","model") single-pod and ("pod","data","model") multi-pod — the
"batch" logical axis maps to all data-like axes present.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.fxp import QTensor

AxisName = Union[str, Tuple[str, ...], None]

# Base logical->mesh rules.  Per-arch overrides replace entries (e.g.
# kv_heads -> "model" only when divisible; experts -> "model" for EP).
BASE_RULES: Dict[str, AxisName] = {
    "batch": "__data__",      # expands to ("pod","data") when present
    "seq": None,              # flip to "model" for sequence parallelism
    # FSDP/ZeRO-3: the d_model dim of every weight is sharded over the
    # data axis; XLA all-gathers weights per layer inside the scan and
    # reduce-scatters their gradients.  Without this, params+optimizer
    # of the 72B arch are 65 GiB/device; with it they are ~2.5 GiB.
    "d_model": "data",
    "heads": "model",
    "kv_heads": None,
    "d_ff": "model",
    "d_ff_expert": "model",
    "experts": None,
    "d_inner": "model",
    "vocab": "model",
    "layers": None,
}


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def data_axis_size(mesh: Mesh) -> int:
    """Total number of data-parallel slots (product of data-like axes)."""
    n = 1
    for a in data_axes(mesh):
        n *= mesh.shape[a]
    return n


def shard_map(f, mesh: Mesh, in_specs, out_specs,
              check_replication: bool = True):
    """Version-portable shard_map.

    jax <= 0.4.x ships it as ``jax.experimental.shard_map.shard_map``
    with a ``check_rep`` kwarg; newer releases promote it to
    ``jax.shard_map`` and rename the kwarg ``check_vma``.  Callers in
    this repo go through this wrapper so the kernel code works on both.
    """
    try:
        from jax.experimental.shard_map import shard_map as _sm
        kw = {"check_rep": check_replication}
    except ImportError:
        _sm = jax.shard_map
        kw = {"check_vma": check_replication}
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def resolve(rules: Dict[str, AxisName], name: Optional[str],
            mesh: Mesh) -> AxisName:
    if name is None:
        return None
    r = rules.get(name, None)
    if r == "__data__":
        ax = data_axes(mesh)
        return ax if ax else None
    if isinstance(r, str) and r not in mesh.axis_names:
        return None
    return r


def spec_for(axes, rules: Dict[str, AxisName], mesh: Mesh) -> P:
    if axes is None:
        return P()
    resolved = []
    used = set()
    for a in axes:
        r = resolve(rules, a, mesh)
        # a mesh axis may appear once per spec (e.g. seq->model under
        # SP collides with vocab->model): first occurrence wins
        flat = r if isinstance(r, tuple) else (r,) if r else ()
        if any(f in used for f in flat):
            r = None
        else:
            used.update(flat)
        resolved.append(r)
    return P(*resolved)


def make_shardings(params_like, axes_tree, mesh: Mesh,
                   rules: Optional[Dict[str, AxisName]] = None):
    """NamedSharding tree matching ``params_like`` (handles QTensor).

    ``params_like`` may be concrete arrays or ShapeDtypeStructs; the
    axes tree holds logical-axis tuples at the positions of (pre-
    quantization) weights.
    """
    rules = dict(BASE_RULES, **(rules or {}))

    def one(leaf, axes):
        if isinstance(leaf, QTensor):
            q_spec = spec_for(axes, rules, mesh)
            # scale: broadcast dims unsharded, last dim follows weight
            n = leaf.scale.ndim
            last = q_spec[-1] if len(q_spec) else None
            s_spec = P(*([None] * (n - 1) + [last])) if n else P()
            return QTensor(NamedSharding(mesh, q_spec),
                           NamedSharding(mesh, s_spec), leaf.bits)
        return NamedSharding(mesh, spec_for(axes, rules, mesh))

    return jax.tree.map(one, params_like, axes_tree,
                        is_leaf=lambda l: isinstance(l, QTensor))


# ---------------------------------------------------------------------------
# activation constraints via a thread-local mesh/rules context
# ---------------------------------------------------------------------------

_ctx = threading.local()


@contextlib.contextmanager
def mesh_rules(mesh: Optional[Mesh],
               rules: Optional[Dict[str, AxisName]] = None):
    prev = getattr(_ctx, "state", None)
    _ctx.state = (mesh, dict(BASE_RULES, **(rules or {}))) if mesh else None
    try:
        yield
    finally:
        _ctx.state = prev



def current_mesh() -> Optional[Mesh]:
    state = getattr(_ctx, "state", None)
    return state[0] if state else None


def constrain(x: jax.Array, axes: Tuple[Optional[str], ...]) -> jax.Array:
    """Apply a logical sharding constraint if a mesh context is active."""
    state = getattr(_ctx, "state", None)
    if state is None:
        return x
    mesh, rules = state
    spec = spec_for(axes, rules, mesh)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec))


def batch_spec(mesh: Mesh, extra_dims: int = 1,
               batch_size: Optional[int] = None) -> P:
    """PartitionSpec for [batch, ...] inputs: batch over all data axes.

    If ``batch_size`` is given and does not divide the data axes
    (long_500k runs with global_batch=1), the batch dim is replicated —
    pjit argument shardings require exact divisibility.
    """
    ax = data_axes(mesh)
    if ax and batch_size is not None:
        n = 1
        for a in ax:
            n *= mesh.shape[a]
        if batch_size % n != 0:
            ax = ()
    return P(ax if ax else None, *([None] * extra_dims))
