"""Checkpoint -> servable policy, with no training machinery.

``load_policy`` is the deployment entry point: it reads a value-RL
checkpoint written by ``repro.launch.rl_train.value_train``, validates
the run flags against the sidecar metadata (a mismatch fails with an
error naming the flag, never a missing-leaf ``KeyError`` from the tree
restore), reconstructs the matching net through the shared
:func:`repro.rl.inference.make_value_agent`, and restores ONLY the
parameter (and, for conv, the frozen-normalizer) subtrees — the replay
buffer, optimizer state and target net never leave the file.

The partial restore works because ``checkpointer.restore`` walks the
*template's* leaves: a ``None`` in the 6-tuple template
``(params, target, opt, replay, env_state, obs)`` is an empty subtree,
so only the requested positions are read back.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.core.policy import QuantPolicy, get_policy
from repro.core.quantizer import quantize_params
from repro.rl.envs.wrappers import (NormStats, merge_norm_stats,
                                    norm_stats_of)
from repro.rl.inference import (NETS, VALUE_ALGOS, ValueAgent, build_env,
                                make_value_agent)
from repro.rl.rollout import init_envs

# serving precision points: (weight pack bits, apply-policy preset).
# "w8" matches value_eval's fxp8 grid bit-for-bit (the parity the CI
# smoke asserts); "w4" is the QuaRL-style int4 deployment sweep.
PRECISIONS = {
    "fp32": (None, None),
    "w8": (8, "fxp8"),
    "w4": (4, "w4a8"),
}


def _mismatch(ckpt_dir: str, flag: str, saved, asked) -> ValueError:
    return ValueError(
        f"checkpoint in {ckpt_dir} was saved by --{flag} {saved!r}, "
        f"not {asked!r} — serve with the checkpoint's own flags "
        f"(or omit --{flag} to take it from the metadata)")


@dataclasses.dataclass
class ServedPolicy:
    """Everything serving needs, nothing training needs.

    ``params`` is the restored fp32 tree; :meth:`pack` produces the
    immutable ``QTensor`` weights actually shipped to the engine.
    ``env`` is the frozen evaluation env (conv normalizer stats merged
    and frozen) so episode slots see the training obs pipeline.
    """

    algo: str
    net: str
    env_name: str
    frame_stack: int
    step: int
    metadata: Dict
    agent: ValueAgent
    params: object
    env: object
    norm_stats: Optional[NormStats] = None

    @classmethod
    def from_agent(cls, agent: ValueAgent, env_name: str,
                   net: str = "mlp", frame_stack: int = 1,
                   norm_stats: Optional[NormStats] = None
                   ) -> "ServedPolicy":
        """Wrap an in-process agent (``agent.params`` initialized) as a
        servable policy — benchmarks and tests that measure the serving
        machinery itself, where no checkpoint exists."""
        if agent.params is None:
            raise ValueError("from_agent needs initialized params "
                             "(make_value_agent with a key)")
        env = build_env(env_name, net, frame_stack,
                        norm_stats=norm_stats)
        return cls(algo=agent.algo, net=net, env_name=env_name,
                   frame_stack=frame_stack, step=0, metadata={},
                   agent=agent, params=agent.params, env=env,
                   norm_stats=norm_stats)

    def behaviour_params(self):
        """The served subtree: the Q net, or the bare ddpg actor."""
        return self.agent.behaviour_subtree(self.params)

    def pack(self, precision: str = "w8"):
        """(packed behaviour subtree, apply QuantPolicy | None).

        ``w8``/``w4`` replace matmul weights with per-channel QTensors
        (int8 container; two int4 codes per byte when stored) and pick
        the apply policy whose activation grid matches training-time
        fake-quant.  ``fp32`` serves the weights as restored.
        """
        if precision not in PRECISIONS:
            raise ValueError(f"unknown serving precision {precision!r} "
                             f"(expected one of {sorted(PRECISIONS)})")
        bits, pol_name = PRECISIONS[precision]
        bp = self.behaviour_params()
        if bits is None:
            return bp, None
        packed = quantize_params(
            bp, QuantPolicy(name=f"w{bits}", w_bits=bits,
                            per_channel=True))
        return packed, get_policy(pol_name)


def load_policy(ckpt_dir: str, algo: Optional[str] = None,
                net: Optional[str] = None,
                env_name: Optional[str] = None,
                step: Optional[int] = None) -> ServedPolicy:
    """Reconstruct a servable policy from a value-RL checkpoint.

    ``algo``/``net``/``env_name`` are optional cross-checks: ``None``
    trusts the sidecar metadata; a non-``None`` value that disagrees
    with the metadata raises a :class:`ValueError` naming the flag.
    Metadata-free positions (older checkpoints) fall back to the
    caller's value and fail loudly when neither side knows.
    """
    mgr = CheckpointManager(ckpt_dir)
    step = step if step is not None else mgr.latest_step()
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    md = mgr.metadata(step)

    def pick(flag: str, asked, default=None):
        saved = md.get(flag, None)
        if saved is None:
            if asked is None and default is None:
                raise ValueError(
                    f"checkpoint in {ckpt_dir} predates '{flag}' "
                    f"metadata — pass --{flag} explicitly")
            return asked if asked is not None else default
        saved = str(saved)
        if asked is not None and str(asked) != saved:
            raise _mismatch(ckpt_dir, flag, saved, asked)
        return saved

    algo = pick("algo", algo)
    net = pick("net", net, default="mlp")
    env_name = pick("env", env_name)
    if algo not in VALUE_ALGOS:
        raise ValueError(f"checkpoint in {ckpt_dir} holds --algo "
                         f"{algo!r}; serving drives the value family "
                         f"{VALUE_ALGOS}")
    if net not in NETS:
        raise ValueError(f"checkpoint in {ckpt_dir} holds --net "
                         f"{net!r} (expected one of {NETS})")
    frame_stack = int(md.get("frame_stack", 1))
    tqc_drop = int(md.get("tqc_drop", 0))

    # template agent: same init path as training, so the restore
    # template's tree paths match the saved tree exactly
    train_env = build_env(env_name, net, frame_stack)
    agent = make_value_agent(algo, train_env.spec,
                             key=jax.random.PRNGKey(0), net=net,
                             tqc_drop=tqc_drop)

    norm_stats = None
    if net == "conv":
        # conv checkpoints carry the Welford normalizer inside the env
        # state (position 4 of the saved tuple); restore it alongside
        # the params and freeze the merged stats for serving
        n_envs = int(md.get("n_envs", 1))
        est, _ = init_envs(train_env, jax.random.PRNGKey(0), n_envs)
        (params, _, _, _, est, _), md = mgr.restore(
            (agent.params, None, None, None, est, None), step=step)
        norm_stats = merge_norm_stats(norm_stats_of(est))
        env = build_env(env_name, net, frame_stack,
                        norm_stats=norm_stats)
    else:
        (params, _, _, _, _, _), md = mgr.restore(
            (agent.params, None, None, None, None, None), step=step)
        env = build_env(env_name, net, frame_stack)

    return ServedPolicy(algo=algo, net=net, env_name=env_name,
                        frame_stack=frame_stack, step=int(step),
                        metadata=dict(md), agent=agent, params=params,
                        env=env, norm_stats=norm_stats)
