"""Batched RL policy serving: checkpoint -> packed weights -> actions.

The deployment half of the QForce-RL story as a subsystem: load a
value-RL checkpoint (:func:`load_policy`), pack the behaviour net to
int8/int4 ``QTensor``s (:meth:`ServedPolicy.pack`), and answer action
requests for banks of concurrent episodes through the micro-batching
engine (:class:`PolicyServer` / :func:`serve_episodes`).
"""
from repro.serve.engine import (EpisodeStats, PolicyServer, bucket_for,
                                bucket_sizes, check_parity,
                                serve_episodes)
from repro.serve.loader import (PRECISIONS, ServedPolicy, load_policy)

__all__ = [
    "EpisodeStats", "PolicyServer", "PRECISIONS", "ServedPolicy",
    "bucket_for", "bucket_sizes", "check_parity", "load_policy",
    "serve_episodes",
]
