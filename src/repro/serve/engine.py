"""Batched policy inference: micro-batching engine + episode slots.

The serving analogue of the paper's deployment half: a trained value
policy, weights packed to int8/int4 ``QTensor``s, answering action
requests for thousands of concurrent episodes.  Requests are assembled
into power-of-two *buckets* (pad-to-bucket) so XLA compiles one program
per bucket size instead of one per request count — the same trick the
LM serving path uses for sequence lengths.  The engine records a wall
latency per request (each request in a micro-batch pays that batch's
inference wall) into a fixed-bucket histogram — bounded memory under
production traffic, p50/p99 within one bucket's resolution — plus a
per-bucket-size request counter, and reports actions/s, p50/p99 and
the packed model footprint.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantizer import quantized_nbytes
from repro.obs import SCHEMA, FixedHistogram, JsonlSink, SpanClock
from repro.rl.rollout import init_envs
from repro.serve.loader import PRECISIONS, ServedPolicy


def bucket_sizes(max_bucket: int) -> List[int]:
    """Power-of-two bucket ladder: 1, 2, 4, ..., max_bucket."""
    if max_bucket < 1:
        raise ValueError(f"max_bucket must be >= 1, got {max_bucket}")
    sizes = []
    b = 1
    while b < max_bucket:
        sizes.append(b)
        b *= 2
    sizes.append(max_bucket)
    return sizes


def bucket_for(n: int, sizes: List[int]) -> int:
    """Smallest bucket that fits ``n`` requests (largest bucket caps —
    callers chunk anything bigger)."""
    for b in sizes:
        if n <= b:
            return b
    return sizes[-1]


class PolicyServer:
    """Micro-batched action server over one packed policy.

    ``act(obs)`` answers a [N, ...] observation batch of any N: chunks
    of ``max_bucket`` stream through the largest program, the remainder
    pads up to the smallest fitting bucket.  One jitted program is
    compiled (and cached in ``self._jit_cache``) per bucket size
    actually seen.  ``mode="greedy"`` is the evaluation head —
    bit-identical at w8 to ``value_eval`` under fxp8 — and
    ``mode="sample"`` the stochastic head (Boltzmann / bounded
    Gaussian, scaled by ``temperature``).
    """

    def __init__(self, policy: ServedPolicy, precision: str = "w8",
                 mode: str = "greedy", temperature: float = 1.0,
                 max_bucket: int = 256, seed: int = 0):
        if mode not in ("greedy", "sample"):
            raise ValueError(f"unknown serving mode {mode!r} "
                             "(expected 'greedy' or 'sample')")
        self.policy = policy
        self.precision = precision
        self.mode = mode
        self.temperature = float(temperature)
        self.buckets = bucket_sizes(max_bucket)
        self.max_bucket = max_bucket

        packed, apply_policy = policy.pack(precision)
        # the full-tree shape greedy/sampled expect (ddpg re-wraps the
        # bare actor subtree)
        self.served_params = policy.agent.from_behaviour(packed)
        self.apply_policy = apply_policy
        self._key = jax.random.PRNGKey(seed)
        self._jit_cache: Dict[int, object] = {}
        # bounded telemetry state: O(buckets) forever, never a list
        # that grows with traffic
        self._latency = FixedHistogram()
        self._bucket_requests: Dict[int, int] = {}
        self._requests = 0
        self._infer_s = 0.0

    # -- compiled programs -------------------------------------------------

    def _fn_for(self, bucket: int):
        fn = self._jit_cache.get(bucket)
        if fn is not None:
            return fn
        agent, pol = self.policy.agent, self.apply_policy
        if self.mode == "greedy":
            def run(params, obs, key):
                del key
                return agent.greedy(params, obs, pol)
        else:
            t = self.temperature

            def run(params, obs, key):
                return agent.sampled(params, obs, key, temperature=t,
                                     policy=pol)
        fn = jax.jit(run)
        self._jit_cache[bucket] = fn
        return fn

    def warmup(self, n_slots: Optional[int] = None):
        """Pre-compile the programs a ``n_slots``-wide slot bank will
        hit (all buckets when ``None``), so compile time never lands in
        a request latency."""
        if n_slots is None:
            need = list(self.buckets)
        else:
            need = []
            n = n_slots
            while n > 0:
                b = bucket_for(min(n, self.max_bucket), self.buckets)
                if b not in need:
                    need.append(b)
                n -= min(n, self.max_bucket)
        obs_shape = self.policy.env.obs_shape
        for b in need:
            obs = jnp.zeros((b,) + tuple(obs_shape), jnp.float32)
            jax.block_until_ready(
                self._fn_for(b)(self.served_params, obs, self._key))

    # -- serving -----------------------------------------------------------

    def act(self, obs) -> jax.Array:
        """Actions for an [N, ...] observation batch, micro-batched."""
        obs = jnp.asarray(obs)
        n = obs.shape[0]
        outs = []
        start = 0
        while start < n:
            chunk = min(n - start, self.max_bucket)
            bucket = bucket_for(chunk, self.buckets)
            block = obs[start:start + chunk]
            if bucket != chunk:
                pad = [(0, bucket - chunk)] + [(0, 0)] * (obs.ndim - 1)
                block = jnp.pad(block, pad)
            self._key, sub = jax.random.split(self._key)
            fn = self._fn_for(bucket)
            t0 = time.perf_counter()
            acts = jax.block_until_ready(
                fn(self.served_params, block, sub))
            dt = time.perf_counter() - t0
            self._latency.observe(dt, n=chunk)
            self._bucket_requests[bucket] = (
                self._bucket_requests.get(bucket, 0) + chunk)
            self._requests += chunk
            self._infer_s += dt
            outs.append(acts[:chunk])
            start += chunk
        return outs[0] if len(outs) == 1 else jnp.concatenate(outs)

    # -- accounting --------------------------------------------------------

    def model_bytes(self):
        """(stored bytes, fp32 bytes) of the served behaviour subtree."""
        return quantized_nbytes(
            self.policy.agent.behaviour_subtree(self.served_params))

    def stats(self) -> Dict[str, float]:
        stored, fp32 = self.model_bytes()
        out = {
            "requests": float(self._requests),
            "infer_s": self._infer_s,
            "actions_per_s": (self._requests / self._infer_s
                              if self._infer_s > 0 else 0.0),
            "p50_ms": self._latency.percentile(50) * 1e3,
            "p99_ms": self._latency.percentile(99) * 1e3,
            "model_bytes": float(stored),
            "model_fp32_bytes": float(fp32),
            "compression": stored / fp32 if fp32 else 1.0,
            "jit_programs": float(len(self._jit_cache)),
        }
        return out

    def bucket_requests(self) -> Dict[int, int]:
        """Requests answered per padded micro-batch bucket size."""
        return dict(self._bucket_requests)

    def latency_hist(self) -> Dict:
        """The latency histogram's ``{edges, counts}`` (seconds)."""
        return self._latency.to_dict()

    def reset_stats(self):
        self._latency.reset()
        self._bucket_requests = {}
        self._requests = 0
        self._infer_s = 0.0


@dataclasses.dataclass
class EpisodeStats:
    """What one :func:`serve_episodes` run produced."""

    episodes: int
    env_steps: int
    mean_return: float
    wall_s: float
    server: Dict[str, float]


def serve_episodes(server: PolicyServer, episodes: int,
                   n_slots: int = 64, seed: int = 0,
                   max_env_steps: Optional[int] = None,
                   telemetry: Optional[JsonlSink] = None,
                   flush_every: int = 0) -> EpisodeStats:
    """Run ``n_slots`` concurrent episode slots until ``episodes``
    episodes complete, every action answered through the server's
    micro-batching path.  Slots auto-reset (the envs reset internally
    on done/truncation), so a bank of 64 slots serves thousands of
    episodes back-to-back — the production-traffic shape.

    With ``telemetry`` (a :class:`~repro.obs.sink.JsonlSink`) the loop
    writes ``serve`` records: one per ``flush_every`` loop steps (0:
    one record for the whole run), each carrying the window's request
    count, latency histogram delta, per-bucket request counts and
    ``infer``/``env`` phase spans.
    """
    if n_slots < 1:
        raise ValueError(f"n_slots must be >= 1, got {n_slots}")
    env = server.policy.env
    spec = env.spec
    cap = (max_env_steps if max_env_steps is not None
           else spec.max_steps * (episodes + 2 * n_slots))
    est, obs = init_envs(env, jax.random.PRNGKey(seed), n_slots)
    step_fn = jax.jit(jax.vmap(env.step))
    server.warmup(n_slots)
    # one throwaway step to compile step_fn outside the timed region
    # (the result is discarded; the act() bookkeeping is reset below)
    jax.block_until_ready(step_fn(est, server.act(obs)))
    server.reset_stats()

    clock = SpanClock()
    prev_r = 0
    prev_inf = 0.0
    prev_counts = np.array(server._latency.counts)
    prev_buckets: Dict[int, int] = {}
    prev_steps = prev_eps = 0

    def flush_window(env_steps: int, done_episodes: int):
        nonlocal prev_r, prev_inf, prev_counts, prev_buckets
        nonlocal prev_steps, prev_eps
        r1 = server._requests
        if telemetry is None or r1 == prev_r:
            return
        counts = np.array(server._latency.counts)
        buckets = server.bucket_requests()
        telemetry.write({
            "schema": SCHEMA, "kind": "serve", "t_wall": time.time(),
            "window": [prev_r, r1],
            "metrics": {"requests": r1 - prev_r,
                        "infer_s": server._infer_s - prev_inf,
                        "env_steps": env_steps - prev_steps,
                        "episodes": done_episodes - prev_eps},
            "hists": {"latency_s": {
                "edges": [float(e) for e in server._latency.edges],
                "counts": [int(c) for c in counts - prev_counts]}},
            "buckets": {str(b): n - prev_buckets.get(b, 0)
                        for b, n in buckets.items()
                        if n - prev_buckets.get(b, 0)},
            "spans": clock.drain(),
        })
        prev_r, prev_inf, prev_counts = r1, server._infer_s, counts
        prev_buckets = buckets
        prev_steps, prev_eps = env_steps, done_episodes

    done_episodes = 0
    env_steps = 0
    loop_steps = 0
    acc = np.zeros(n_slots, np.float64)       # running per-slot return
    returns: List[float] = []
    t0 = time.perf_counter()
    while done_episodes < episodes and env_steps < cap:
        with clock("infer"):
            acts = server.act(obs)
        with clock("env"):
            est, obs, r, d, tr, _ = step_fn(est, acts)
            d, tr = np.asarray(d), np.asarray(tr)
        env_steps += n_slots
        loop_steps += 1
        fin = d | tr
        acc += np.asarray(r, np.float64)
        if fin.any():
            returns.extend(acc[fin].tolist())
            done_episodes += int(fin.sum())
            acc[fin] = 0.0
        if flush_every and loop_steps % flush_every == 0:
            flush_window(env_steps, done_episodes)
    wall = time.perf_counter() - t0
    flush_window(env_steps, done_episodes)
    mean_ret = float(np.mean(returns)) if returns else float("nan")
    return EpisodeStats(episodes=done_episodes, env_steps=env_steps,
                        mean_return=mean_ret, wall_s=wall,
                        server=server.stats())


def check_parity(policy: ServedPolicy, precision: str = "w8",
                 n_obs: int = 128, seed: int = 0) -> int:
    """Mismatch count between the served greedy head (packed QTensor
    weights) and the evaluation greedy head (fp32 weights under the
    same quant policy's fake-quant) on a rollout of real observations.

    Zero at w8 by construction — both paths round on the same fxp8
    grid (``quantize_params`` vs ``fake_quant``) and rescale in the
    same order — which is the deployment guarantee: shipping the
    packed policy cannot change a single evaluated action.
    """
    if precision not in PRECISIONS or precision == "fp32":
        raise ValueError("parity is defined for the packed precisions "
                         f"('w8', 'w4'), got {precision!r}")
    env, agent = policy.env, policy.agent
    n_slots = min(n_obs, 32)
    est, obs = init_envs(env, jax.random.PRNGKey(seed), n_slots)
    step_fn = jax.jit(jax.vmap(env.step))
    packed, pol = policy.pack(precision)
    served = agent.from_behaviour(packed)

    fn = jax.jit(lambda p, o: agent.greedy(p, o, pol))
    mismatches = 0
    seen = 0
    while seen < n_obs:
        a_eval = fn(policy.params, obs)
        a_serve = fn(served, obs)
        diff = a_eval != a_serve
        if diff.ndim > 1:
            diff = jnp.any(diff, axis=tuple(range(1, diff.ndim)))
        mismatches += int(jnp.sum(diff))
        seen += n_slots
        est, obs, *_ = step_fn(est, a_eval)
    return mismatches
