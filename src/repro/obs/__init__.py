"""Structured telemetry: jit-safe metrics, spans, JSONL sinks.

See docs/observability.md for the metric taxonomy, the JSONL schema
and the metrics-don't-perturb-training contract.
"""
from repro.obs.console import Console, fmt_metrics           # noqa: F401
from repro.obs.hist import (FixedHistogram,                  # noqa: F401
                            LATENCY_EDGES_S, log_edges)
from repro.obs.metrics import (MetricSpec, counter_add,      # noqa: F401
                               flush, gauge_max, gauge_set,
                               hist_observe)
from repro.obs.profiler import ProfileWindow                 # noqa: F401
from repro.obs.runlog import RunTelemetry                    # noqa: F401
from repro.obs.sink import (KINDS, SCHEMA, JsonlSink,        # noqa: F401
                            iter_records, read_records,
                            validate_record)
from repro.obs.spans import (SERVE_PHASES, TRAIN_PHASES,     # noqa: F401
                             SpanClock)
from repro.obs.summary import (render, summarize,            # noqa: F401
                               summarize_file)
