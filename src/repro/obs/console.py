"""Console renderer: the one sanctioned print site in library code.

Everything the trainer says to a terminal goes through a
:class:`Console` — structured records in, human lines out.  The QF601
lint rule forbids bare ``print()`` elsewhere in ``src/repro/``
(``launch/`` excepted); this module carries the allowlist entry, so a
future reader grepping for output always lands here.
"""
from __future__ import annotations

import sys
from typing import Dict, Optional, TextIO


class Console:
    """Minimal leveled writer.  ``verbose=False`` swallows ``info``
    but still passes ``warn`` through (operator-facing surprises
    should not depend on a verbosity flag)."""

    def __init__(self, verbose: bool = True,
                 stream: Optional[TextIO] = None):
        self.verbose = verbose
        self.stream = stream if stream is not None else sys.stdout

    def info(self, line: str) -> None:
        if self.verbose:
            print(line, file=self.stream)

    def warn(self, line: str) -> None:
        print(f"warning: {line}", file=self.stream)


def fmt_metrics(metrics: Dict, keys, precision: int = 3) -> str:
    """Render selected metrics as ``k=v`` pairs (missing keys
    skipped), matching the benchmarks' emit style."""
    parts = []
    for k in keys:
        if k not in metrics:
            continue
        v = metrics[k]
        if isinstance(v, float):
            parts.append(f"{k}={v:.{precision}f}")
        else:
            parts.append(f"{k}={v}")
    return "  ".join(parts)
