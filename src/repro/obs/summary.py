"""Render a JSONL telemetry run into the benchmarks' table format.

``summarize(records)`` folds a run's records into ``(table, name,
fields)`` rows — the exact shape :func:`benchmarks.common.emit`
prints — so a live run and a bench script read the same way:

    [obs/train] dqn/cartpole: iters=40 env_steps=10240 steps_per_s=...
    [obs/spans] dqn/cartpole: step=1.23 sync=0.04 checkpoint=0.11
    [obs/serve] dqn/cartpole: requests=6400 actions_per_s=... p50_ms=...

The CLI wrapper lives in ``tools/obs_summary.py``; its ``--validate``
mode is the CI schema gate (every line revalidated on read).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.obs.hist import FixedHistogram
from repro.obs.sink import read_records

Row = Tuple[str, str, Dict]


def _run_name(records: List[Dict]) -> str:
    for rec in records:
        if rec["kind"] == "meta":
            run = rec["run"]
            algo = run.get("algo") or run.get("family") or "run"
            env = run.get("env")
            return f"{algo}/{env}" if env else str(algo)
    return "run"


def _fold_hist(into: Dict[str, FixedHistogram], hists: Dict) -> None:
    for name, h in hists.items():
        fh = into.get(name)
        if fh is None:
            fh = into[name] = FixedHistogram(h["edges"])
        elif list(fh.edges) != [float(e) for e in h["edges"]]:
            raise ValueError(f"hist {name!r} changed edges mid-run")
        for i, c in enumerate(h["counts"]):
            if c:
                # fold counts bucket-wise: attribute each bucket's
                # mass to its lower edge (the below-range bucket to
                # just under the first edge, keeping it below range)
                e0 = float(h["edges"][0])
                v = (h["edges"][i - 1] if i > 0
                     else e0 - max(abs(e0), 1.0))
                fh.observe(v, int(c))


def summarize(records: List[Dict], name: str = "") -> List[Row]:
    name = name or _run_name(records)
    rows: List[Row] = []

    steps = [r for r in records if r["kind"] == "step"]
    if steps:
        m: Dict[str, float] = {}
        spans: Dict[str, float] = {}
        for rec in steps:
            for k, v in rec["metrics"].items():
                m[k] = m.get(k, 0) + v
            for k, v in rec["spans"].items():
                spans[k] = spans.get(k, 0.0) + v
        g0 = min(r["window"][0] for r in steps)
        g1 = max(r["window"][1] for r in steps)
        fields = {"iters": g1 - g0}
        for k in ("env_steps", "episodes"):
            if k in m:
                fields[k] = int(m[k])
        wall = sum(spans.values())
        if "env_steps" in m and wall > 0:
            fields["steps_per_s"] = round(m["env_steps"] / wall, 1)
        last = steps[-1]["metrics"]
        if "return_mean" in last:
            fields["final_return"] = round(last["return_mean"], 2)
        rows.append(("obs/train", name, fields))
        if spans:
            rows.append(("obs/spans", name,
                         {k: round(v, 3) for k, v in
                          sorted(spans.items())}))

    serves = [r for r in records if r["kind"] == "serve"]
    if serves:
        m = {}
        for rec in serves:
            for k, v in rec["metrics"].items():
                m[k] = m.get(k, 0) + v
        hists: Dict[str, FixedHistogram] = {}
        buckets: Dict[str, int] = {}
        for rec in serves:
            _fold_hist(hists, rec["hists"])
            for b, n in rec["buckets"].items():
                buckets[b] = buckets.get(b, 0) + n
        fields = {"requests": int(m.get("requests", 0)),
                  "infer_s": round(m.get("infer_s", 0.0), 3)}
        if m.get("infer_s", 0) > 0:
            fields["actions_per_s"] = round(
                m["requests"] / m["infer_s"], 1)
        lat = hists.get("latency_s")
        if lat is not None and lat.count:
            fields["p50_ms"] = round(lat.percentile(50) * 1e3, 3)
            fields["p99_ms"] = round(lat.percentile(99) * 1e3, 3)
        rows.append(("obs/serve", name, fields))
        if buckets:
            rows.append(("obs/buckets", name,
                         {f"b{b}": n for b, n in
                          sorted(buckets.items(),
                                 key=lambda kv: int(kv[0]))}))

    for rec in records:
        if rec["kind"] == "profile":
            rows.append(("obs/profile", name,
                         {"dir": rec["dir"],
                          "window": f"{rec['window'][0]}.."
                                    f"{rec['window'][1]}"}))
    return rows


def render(rows: List[Row]) -> str:
    lines = []
    for table, name, fields in rows:
        kv = "  ".join(f"{k}={v}" for k, v in fields.items())
        lines.append(f"[{table}] {name}: {kv}")
    return "\n".join(lines)


def summarize_file(path: str, name: str = "") -> List[Row]:
    return summarize(read_records(path), name=name)
