"""jax.profiler capture around a configurable step window.

``ProfileWindow(dir, start, steps)`` arms a trace that starts when the
global step first reaches ``start`` and stops ``steps`` iterations
later (or at ``stop()``, whichever comes first).  The trainer calls
``tick(g)`` once per iteration from host code; the window is inclusive
of ``start`` and captures exactly the jitted programs dispatched in
between, which is the supported way to see inside the fused
collect+learn step that wall-clock spans cannot split.

The capture is TensorBoard-loadable (``tensorboard --logdir <dir>``)
or openable with ``xprof``.  A ``profile`` record is reported through
the telemetry sink when one is attached, so a JSONL run documents its
own traces.
"""
from __future__ import annotations

import os
from typing import Optional, Tuple

import jax


class ProfileWindow:
    def __init__(self, profile_dir: str, start: int = 0,
                 steps: int = 1):
        if steps < 1:
            raise ValueError(f"profile window needs steps >= 1, got {steps}")
        self.dir = profile_dir
        self.start = int(start)
        self.steps = int(steps)
        self.active = False
        self.done = False
        self._window: Optional[Tuple[int, int]] = None

    def tick(self, g: int) -> Optional[Tuple[int, int]]:
        """Advance to global step ``g``.  Returns the captured
        ``(g0, g1)`` window on the tick that stops the trace, else
        ``None``."""
        if self.done:
            return None
        if not self.active and g >= self.start:
            os.makedirs(self.dir, exist_ok=True)
            jax.profiler.start_trace(self.dir)
            self.active = True
            self._window = (g, g)
        elif self.active:
            g0, _ = self._window
            self._window = (g0, g)
            if g - g0 >= self.steps:
                return self.stop()
        return None

    def stop(self) -> Optional[Tuple[int, int]]:
        """Stop an active trace (idempotent); returns its window."""
        if not self.active:
            return None
        jax.profiler.stop_trace()
        self.active = False
        self.done = True
        return self._window
