"""Jit-safe metric state: the ``MetricBuffer`` pytree.

The buffer is a plain nested dict of small device arrays — counters
(int32, reset on every flush), gauges (float32, last-write-wins) and
fixed-bucket histograms (int32 counts over static edges) — built from
a :class:`MetricSpec` that is frozen for the run.  It is threaded
through the jitted training iteration *exactly like replay state*:
passed in, donated, and returned updated, so instrumentation adds no
host sync and no per-iteration copies.  Reads happen only at host
sync points (:func:`flush`), which is what keeps the
metrics-don't-perturb-training contract (docs/observability.md) cheap
to honour: the update ops consume already-computed traced values and
feed nothing back into the training math.

Everything here is 32-bit by construction — the trace audit's QF901
(no 64-bit dtypes in a traced step) applies to the instrumented
programs too.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MetricSpec:
    """The static shape of a run's metric buffer.

    ``hists`` maps a name to its (static) bucket edges; a value ``v``
    lands in bucket ``i`` when ``edges[i-1] <= v < edges[i]`` with the
    two open ends included, so counts has ``len(edges) + 1`` slots.
    """

    counters: Tuple[str, ...] = ()
    gauges: Tuple[str, ...] = ()
    hists: Tuple[Tuple[str, Tuple[float, ...]], ...] = ()

    def __post_init__(self):
        names = (list(self.counters) + list(self.gauges)
                 + [n for n, _ in self.hists])
        dupes = {n for n in names if names.count(n) > 1}
        if dupes:
            raise ValueError(f"duplicate metric names: {sorted(dupes)}")
        for name, edges in self.hists:
            if len(edges) < 1:
                raise ValueError(f"histogram {name!r} needs >= 1 edge")
            if list(edges) != sorted(edges):
                raise ValueError(f"histogram {name!r} edges must be "
                                 "sorted ascending")

    def edges(self, name: str) -> Tuple[float, ...]:
        for n, e in self.hists:
            if n == name:
                return e
        raise KeyError(f"no histogram named {name!r} in this spec")

    def init(self) -> Dict:
        """A zeroed :data:`MetricBuffer` for this spec."""
        return {
            "counters": {n: jnp.zeros((), jnp.int32)
                         for n in self.counters},
            "gauges": {n: jnp.zeros((), jnp.float32)
                       for n in self.gauges},
            "hists": {n: jnp.zeros((len(e) + 1,), jnp.int32)
                      for n, e in self.hists},
        }


def counter_add(buf: Dict, name: str, value) -> Dict:
    """Increment a window counter (reset to zero on flush)."""
    c = dict(buf["counters"])
    c[name] = c[name] + jnp.asarray(value, jnp.int32)
    return {**buf, "counters": c}


def gauge_set(buf: Dict, name: str, value) -> Dict:
    """Record a gauge (last write in the window wins)."""
    g = dict(buf["gauges"])
    g[name] = jnp.asarray(value, jnp.float32)
    return {**buf, "gauges": g}


def gauge_max(buf: Dict, name: str, value) -> Dict:
    """Record the running window maximum of a gauge."""
    g = dict(buf["gauges"])
    g[name] = jnp.maximum(g[name], jnp.asarray(value, jnp.float32))
    return {**buf, "gauges": g}


def hist_observe(spec: MetricSpec, buf: Dict, name: str,
                 values) -> Dict:
    """Scatter ``values`` (any shape) into the named histogram."""
    edges = jnp.asarray(spec.edges(name), jnp.float32)
    idx = jnp.searchsorted(edges, jnp.ravel(
        jnp.asarray(values, jnp.float32)), side="right")
    h = dict(buf["hists"])
    h[name] = h[name].at[idx].add(1)
    return {**buf, "hists": h}


def flush(spec: MetricSpec, buf: Dict) -> Tuple[Dict, Dict, Dict]:
    """Host sync point: pull the buffer to host and return
    ``(metrics, hists, zeroed_buffer)``.

    ``metrics`` is a flat name -> python number dict (counters and
    gauges); ``hists`` maps name -> ``{"edges": [...], "counts":
    [...]}`` — the JSONL-ready shapes.  The returned buffer is a fresh
    zero tree, so the caller keeps donating without aliasing the read.
    """
    host = jax.device_get(buf)
    metrics = {n: int(host["counters"][n]) for n in spec.counters}
    metrics.update({n: float(host["gauges"][n]) for n in spec.gauges})
    hists = {n: {"edges": [float(x) for x in e],
                 "counts": [int(c) for c in host["hists"][n]]}
             for n, e in spec.hists}
    return metrics, hists, spec.init()
