"""RunTelemetry: the host-side aggregator one run owns.

Binds together the pieces the training/serving loop needs — a
:class:`~repro.obs.sink.JsonlSink`, a
:class:`~repro.obs.spans.SpanClock` and the step-window bookkeeping —
behind three calls: ``span(name)`` around host phases, ``step_flush``
at each log window, ``profile`` when a trace capture closes.  Windows
are half-open ``[g0, g1)`` global-step ranges and stay contiguous
across checkpoint resume because the sink appends and the first
window starts at the resume step (the constructor's ``start``).
"""
from __future__ import annotations

import os
import time
from typing import Dict, Optional

from repro.obs.sink import SCHEMA, JsonlSink
from repro.obs.spans import SpanClock


class RunTelemetry:
    def __init__(self, metrics_dir: str, *, run: Dict,
                 name: str = "train", start: int = 0):
        self.path = os.path.join(metrics_dir, f"{name}.jsonl")
        self.sink = JsonlSink(self.path, run=run)
        self.clock = SpanClock()
        self._g0 = int(start)

    def span(self, phase: str):
        return self.clock(phase)

    def step_flush(self, g: int, metrics: Dict,
                   hists: Optional[Dict] = None) -> Dict:
        """Close the window ending at global step ``g`` (inclusive)
        and write its ``step`` record; returns the record."""
        rec = {"schema": SCHEMA, "kind": "step", "t_wall": time.time(),
               "step": int(g), "window": [self._g0, int(g) + 1],
               "metrics": metrics, "spans": self.clock.drain()}
        if hists:
            rec["hists"] = hists
        self.sink.write(rec)
        self._g0 = int(g) + 1
        return rec

    def profile(self, profile_dir: str, window) -> None:
        self.sink.write({"schema": SCHEMA, "kind": "profile",
                         "t_wall": time.time(), "dir": profile_dir,
                         "window": [int(window[0]), int(window[1])]})

    def close(self) -> None:
        self.sink.close()
