"""The JSONL event sink: versioned schema, one record per window.

Every record is one JSON object on one line, carrying ``schema``
(:data:`SCHEMA`, bumped on breaking layout changes), ``kind`` and
``t_wall`` (unix seconds).  Four kinds exist today:

``meta``
    Run header, written once at open: ``run`` dict (driver, env,
    algo, config echo — whatever the caller passes).
``step``
    One training step window: ``step`` (global step at flush),
    ``window`` ``[g0, g1)`` of global steps covered, ``metrics``
    (flat name -> number), ``spans`` (phase -> wall seconds) and
    optionally ``hists`` (name -> {edges, counts}).
``serve``
    One serving window: ``window`` ``[r0, r1)`` of request counts,
    ``metrics``, ``hists`` and ``buckets`` (padded-batch-size ->
    request count).
``profile``
    A profiler capture: ``dir`` it was written to and the ``window``
    of global steps it covered.

:func:`validate_record` is the single source of truth for the shape —
the writer runs it on every append (writing a bad record is a bug,
not a condition to tolerate) and ``tools/obs_summary.py --validate``
runs it over whole files in CI.
"""
from __future__ import annotations

import json
import numbers
import os
import time
from typing import Dict, Iterator, List, Optional

SCHEMA = "obs/v1"
KINDS = ("meta", "step", "serve", "profile")


def _need(rec: Dict, key: str, kind) -> None:
    if key not in rec:
        raise ValueError(f"{rec.get('kind', '?')} record missing {key!r}")
    if not isinstance(rec[key], kind):
        raise ValueError(
            f"{rec.get('kind', '?')} record field {key!r} must be "
            f"{getattr(kind, '__name__', kind)}, got {type(rec[key]).__name__}")


def _check_metrics(metrics: Dict) -> None:
    for name, v in metrics.items():
        if not isinstance(name, str):
            raise ValueError(f"metric name must be str, got {name!r}")
        if isinstance(v, bool) or not isinstance(v, numbers.Real):
            raise ValueError(f"metric {name!r} must be a number, got {v!r}")


def _check_hists(hists: Dict) -> None:
    for name, h in hists.items():
        if not isinstance(h, dict) or set(h) != {"edges", "counts"}:
            raise ValueError(f"hist {name!r} must be {{edges, counts}}")
        if len(h["counts"]) != len(h["edges"]) + 1:
            raise ValueError(
                f"hist {name!r}: need len(counts) == len(edges) + 1, got "
                f"{len(h['counts'])} vs {len(h['edges'])}")
        if list(h["edges"]) != sorted(float(e) for e in h["edges"]):
            raise ValueError(f"hist {name!r}: edges must ascend")
        if any(int(c) < 0 for c in h["counts"]):
            raise ValueError(f"hist {name!r}: negative count")


def _check_window(rec: Dict) -> None:
    w = rec["window"]
    if (not isinstance(w, (list, tuple)) or len(w) != 2
            or not all(isinstance(x, int) for x in w) or w[0] > w[1]):
        raise ValueError(f"window must be [lo, hi] ints with lo <= hi, "
                         f"got {w!r}")


def validate_record(rec: Dict) -> Dict:
    """Raise ``ValueError`` unless ``rec`` is a well-formed obs/v1
    record; returns it unchanged so calls chain."""
    if not isinstance(rec, dict):
        raise ValueError(f"record must be a dict, got {type(rec).__name__}")
    if rec.get("schema") != SCHEMA:
        raise ValueError(f"schema must be {SCHEMA!r}, got "
                         f"{rec.get('schema')!r}")
    if rec.get("kind") not in KINDS:
        raise ValueError(f"kind must be one of {KINDS}, got "
                         f"{rec.get('kind')!r}")
    _need(rec, "t_wall", numbers.Real)
    kind = rec["kind"]
    if kind == "meta":
        _need(rec, "run", dict)
    elif kind == "step":
        _need(rec, "step", int)
        _need(rec, "window", (list, tuple))
        _check_window(rec)
        _need(rec, "metrics", dict)
        _check_metrics(rec["metrics"])
        _need(rec, "spans", dict)
        _check_metrics(rec["spans"])
        if "hists" in rec:
            _check_hists(rec["hists"])
    elif kind == "serve":
        _need(rec, "window", (list, tuple))
        _check_window(rec)
        _need(rec, "metrics", dict)
        _check_metrics(rec["metrics"])
        _need(rec, "hists", dict)
        _check_hists(rec["hists"])
        _need(rec, "buckets", dict)
        for b, n in rec["buckets"].items():
            if not str(b).isdigit() or not isinstance(n, int) or n < 0:
                raise ValueError(f"buckets wants digit-keyed non-negative "
                                 f"ints, got {b!r}: {n!r}")
    elif kind == "profile":
        _need(rec, "dir", str)
        _need(rec, "window", (list, tuple))
        _check_window(rec)
    return rec


class JsonlSink:
    """Append-mode JSONL writer.

    Opened in append mode so a checkpoint-resumed run continues the
    same file — step windows stay contiguous across the restart (the
    resume-continuity test relies on this).  ``write`` validates,
    serialises and flushes each record; telemetry that lies about its
    own shape is worse than none.
    """

    def __init__(self, path: str, run: Optional[Dict] = None):
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        self.path = path
        self._f = open(path, "a", encoding="utf-8")
        if run is not None:
            self.write({"schema": SCHEMA, "kind": "meta",
                        "t_wall": time.time(), "run": run})

    def write(self, rec: Dict) -> None:
        validate_record(rec)
        self._f.write(json.dumps(rec, sort_keys=True) + "\n")
        self._f.flush()

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()

    def __enter__(self) -> "JsonlSink":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_records(path: str, validate: bool = True) -> List[Dict]:
    """Load a JSONL file back into a list of records."""
    out: List[Dict] = []
    for rec in iter_records(path, validate=validate):
        out.append(rec)
    return out


def iter_records(path: str, validate: bool = True) -> Iterator[Dict]:
    with open(path, encoding="utf-8") as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{lineno}: not JSON: {e}") from e
            if validate:
                try:
                    validate_record(rec)
                except ValueError as e:
                    raise ValueError(f"{path}:{lineno}: {e}") from e
            yield rec
