"""Host-side fixed-bucket histograms: bounded-memory percentile state.

The serving engine's latency record was an unbounded python list —
fine for a bench, a leak under production traffic.  A
:class:`FixedHistogram` holds one int64 count per (static) bucket plus
exact running ``count``/``sum``/``min``/``max``, so memory is O(
buckets) forever and percentiles come back within one bucket's
resolution of the exact answer (geometric ~±3.1% for the default
latency edges — see :func:`log_edges`).

This is the *host* twin of the jit-side histograms in
:mod:`repro.obs.metrics`: same edges/counts shape on the wire (the
JSONL ``hists`` field), numpy instead of jnp, mutable because it
lives outside every traced program.
"""
from __future__ import annotations

import math
from typing import Dict, List, Sequence

import numpy as np


def log_edges(lo: float, hi: float, per_decade: int = 16) -> List[float]:
    """Log-spaced bucket edges covering [lo, hi] with ``per_decade``
    buckets per decade (relative resolution ``10**(1/per_decade)``,
    ~15.5% at 16/decade; adjacent-edge ratio is constant)."""
    if not (0 < lo < hi):
        raise ValueError(f"need 0 < lo < hi, got ({lo}, {hi})")
    n = int(math.ceil((math.log10(hi) - math.log10(lo)) * per_decade))
    return [lo * 10 ** (i / per_decade) for i in range(n + 1)]


# serving latencies: 1us .. 100s at 16 buckets/decade (129 buckets)
LATENCY_EDGES_S = log_edges(1e-6, 1e2, per_decade=16)


class FixedHistogram:
    """Fixed-bucket histogram with interpolated percentiles.

    ``observe`` is O(log buckets); state never grows.  Values below
    ``edges[0]`` / at-or-above ``edges[-1]`` land in the two open-end
    buckets and percentiles falling there clamp to the nearest edge
    (tracked exactly via running min/max).
    """

    def __init__(self, edges: Sequence[float] = LATENCY_EDGES_S):
        edges = [float(e) for e in edges]
        if len(edges) < 1 or edges != sorted(edges):
            raise ValueError("edges must be >= 1 values, ascending")
        self.edges = np.asarray(edges, np.float64)
        self.counts = np.zeros(len(edges) + 1, np.int64)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, value: float, n: int = 1) -> None:
        v = float(value)
        self.counts[int(np.searchsorted(self.edges, v,
                                        side="right"))] += n
        self.count += n
        self.sum += v * n
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """Approximate q-th percentile (0..100), linear within the
        containing bucket; exact when all mass is one value."""
        if not 0 <= q <= 100:
            raise ValueError(f"percentile wants 0..100, got {q}")
        if self.count == 0:
            return 0.0
        rank = q / 100.0 * self.count
        cum = np.cumsum(self.counts)
        b = int(np.searchsorted(cum, rank, side="left"))
        b = min(b, len(self.counts) - 1)
        lo = self.edges[b - 1] if b > 0 else self.min
        hi = self.edges[b] if b < len(self.edges) else self.max
        # clamp the open ends to the observed extremes
        lo, hi = max(lo, self.min), min(hi, self.max)
        if hi <= lo:
            return float(lo)
        prev = cum[b - 1] if b > 0 else 0
        inbucket = self.counts[b]
        frac = ((rank - prev) / inbucket) if inbucket else 0.0
        return float(lo + (hi - lo) * min(max(frac, 0.0), 1.0))

    def to_dict(self) -> Dict:
        """The JSONL ``hists`` entry shape (edges + counts)."""
        return {"edges": [float(e) for e in self.edges],
                "counts": [int(c) for c in self.counts]}

    def reset(self) -> None:
        self.counts[:] = 0
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
