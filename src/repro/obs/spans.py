"""Wall-clock phase spans, accumulated per step window.

``SpanClock`` is a context-manager stopwatch: entering
``clock("sync")`` starts the phase, leaving it adds the elapsed wall
seconds (and one call) to that phase's window bucket; ``drain()``
hands the accumulated ``{phase: seconds}`` map to the step record and
resets the window.  Phases nest freely and the set of names is open —
the trainer uses ``step`` (the fused collect+learn jitted program —
the two cannot be timed apart without a host barrier that would break
the double-buffered overlap, see docs/observability.md), ``sync``,
``checkpoint`` and ``eval``; the serve loop uses ``infer`` and
``env``.

Host-side only: never enter a span inside traced code (QF301 — the
clock read would bake into the program).
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Dict

# the trainer/serve taxonomy, for docs and the summary renderer; the
# clock itself accepts any name
TRAIN_PHASES = ("step", "sync", "eval", "checkpoint")
SERVE_PHASES = ("infer", "env")


class SpanClock:
    def __init__(self):
        self._s: Dict[str, float] = {}
        self._n: Dict[str, int] = {}

    @contextmanager
    def __call__(self, phase: str):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            dt = time.perf_counter() - t0
            self._s[phase] = self._s.get(phase, 0.0) + dt
            self._n[phase] = self._n.get(phase, 0) + 1

    def seconds(self, phase: str) -> float:
        return self._s.get(phase, 0.0)

    def drain(self) -> Dict[str, float]:
        """Window flush: ``{phase: seconds}`` since the last drain."""
        out = dict(self._s)
        self._s.clear()
        self._n.clear()
        return out
