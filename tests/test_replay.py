"""The replay subsystem (repro.rl.replay): sum-tree invariants,
uniform bit-compatibility with the pre-refactor buffer, PER semantics
(max-priority insertion, IS weights, priority refresh), checkpoint
round-trips, and the TQC truncation on the DDPG critic targets.

Two test styles per invariant: a hypothesis property (runs in CI where
hypothesis is installed; auto-skips via tests/_hypothesis_compat
otherwise) and a deterministic twin that always runs, so tier-1 never
collects an unverified invariant.

The stratified-sampling checks exploit a structural fact: with one
draw per 1/n-stratum of the priority mass, the count for any leaf can
differ from ``n * p_leaf`` by at most the two boundary strata — a
DETERMINISTIC +/-2 bound, not a statistical tolerance, so none of
these tests are flaky.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpoint import CheckpointManager
from repro.launch.rl_train import main, make_value_agent, value_eval, value_train
from repro.nn.module import unbox
from repro.rl.envs import make
from repro.rl.nets import (mlp_pi_apply, mlp_pi_init, mlp_twin_q_apply,
                           mlp_twin_q_init, mlp_twin_qr_apply,
                           mlp_twin_qr_init)
from repro.rl.replay import (PRIORITY_EPS, make_replay, per_init,
                             per_sample, per_update, replay_init,
                             sum_tree)
from repro.rl.value import (DDPGConfig, ddpg_actor_loss,
                            ddpg_critic_loss, ddpg_critic_loss_td,
                            truncated_target_quantiles)


def assert_internal_sums_exact(tree):
    """Every internal node must equal its children's sum BITWISE —
    update() recomputes ancestors from the children, so no float drift
    is tolerated."""
    nodes = np.asarray(tree)
    L = len(nodes) // 2
    for i in range(1, L):
        assert nodes[i] == nodes[2 * i] + nodes[2 * i + 1], (
            f"node {i}: {nodes[i]} != {nodes[2*i]} + {nodes[2*i+1]}")


# ---------------------------------------------------------------------------
# sum tree
# ---------------------------------------------------------------------------

def test_sum_tree_shapes_and_zero_init():
    t = sum_tree.init(10)                 # rounds up to 16 leaves
    assert t.shape == (32,) and t.dtype == jnp.float32
    assert float(sum_tree.total(t)) == 0.0
    assert sum_tree.leaf_count(1) == 1
    assert sum_tree.leaf_count(16) == 16
    assert sum_tree.leaf_count(17) == 32
    with pytest.raises(ValueError, match="capacity"):
        sum_tree.leaf_count(0)


def test_sum_tree_update_preserves_internal_sums_exactly():
    """Repeated partial updates (jitted) keep every internal node the
    bitwise sum of its children, and leaves read back exactly."""
    rng = np.random.RandomState(0)
    t = sum_tree.init(23)                 # non-power-of-two capacity
    upd = jax.jit(sum_tree.update)
    for _ in range(5):
        m = rng.randint(1, 23)
        idx = rng.choice(23, size=m, replace=False)
        vals = rng.uniform(0.0, 10.0, size=m).astype(np.float32)
        t = upd(t, jnp.asarray(idx), jnp.asarray(vals))
        np.testing.assert_array_equal(
            np.asarray(sum_tree.get(t, jnp.asarray(idx))), vals)
        assert_internal_sums_exact(t)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 100), st.integers(0, 2**31 - 1))
def test_sum_tree_update_property(capacity, seed):
    """Property: any update sequence keeps the internal-sum invariant
    and the root equal to the (exactly re-added) leaf total."""
    rng = np.random.RandomState(seed)
    t = sum_tree.init(capacity)
    for _ in range(3):
        m = rng.randint(1, capacity + 1)
        idx = rng.choice(capacity, size=m, replace=False)
        # small integers: exactly representable, sums exact in f32
        vals = rng.randint(0, 64, size=m).astype(np.float32)
        t = sum_tree.update(t, jnp.asarray(idx), jnp.asarray(vals))
        assert_internal_sums_exact(t)
    nodes = np.asarray(t)
    L = len(nodes) // 2
    assert nodes[1] == nodes[L:].sum(dtype=np.float32)


def test_sum_tree_find_matches_naive_prefix_sum_search():
    """Inverse-CDF descent == np.searchsorted(cumsum, u, 'right') on
    integer-valued priorities (where both arithmetics are exact),
    including interval boundaries and zero-mass leaves."""
    pri = np.array([3, 0, 5, 1, 0, 7, 2, 6], np.float32)
    t = sum_tree.update(sum_tree.init(8), jnp.arange(8),
                        jnp.asarray(pri))
    total = pri.sum()
    u = np.concatenate([np.arange(total),            # every boundary
                        np.arange(total) + 0.5])     # every interior
    got = np.asarray(sum_tree.find(t, jnp.asarray(u, jnp.float32)))
    want = np.searchsorted(np.cumsum(pri), u, side="right")
    np.testing.assert_array_equal(got, want)
    assert not np.isin(got, [1, 4]).any()            # zero-mass leaves


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 50), min_size=1, max_size=64),
       st.integers(0, 2**31 - 1))
def test_sum_tree_find_property_vs_searchsorted(pri, seed):
    """Property: tree descent agrees with the naive prefix-sum search
    for any integer priority vector with non-zero total."""
    pri = np.asarray(pri, np.float32)
    if pri.sum() == 0:
        pri[0] = 1.0
    t = sum_tree.update(sum_tree.init(len(pri)), jnp.arange(len(pri)),
                        jnp.asarray(pri))
    u = np.random.RandomState(seed).uniform(
        0, float(pri.sum()), size=128).astype(np.float32)
    u = np.minimum(u, pri.sum() * (1 - 1e-7))
    got = np.asarray(sum_tree.find(t, jnp.asarray(u)))
    want = np.searchsorted(np.cumsum(pri), u, side="right")
    np.testing.assert_array_equal(got, want)


def test_sum_tree_update_duplicate_indices_last_wins():
    """Duplicate indices in one batch (legal under PER: the same slot
    sampled twice can carry different TD errors) resolve to the LAST
    occurrence, deterministically, and keep the invariant."""
    t = sum_tree.init(8)
    t = sum_tree.update(t, jnp.array([3, 1, 3, 5, 3]),
                        jnp.array([9.0, 2.0, 7.0, 4.0, 5.0]))
    np.testing.assert_array_equal(
        np.asarray(sum_tree.get(t, jnp.array([1, 3, 5]))),
        [2.0, 5.0, 4.0])
    assert float(sum_tree.total(t)) == 11.0
    assert_internal_sums_exact(t)
    # bitwise-identical across calls (no XLA-unspecified scatter order)
    t2 = sum_tree.update(sum_tree.init(8), jnp.array([3, 1, 3, 5, 3]),
                         jnp.array([9.0, 2.0, 7.0, 4.0, 5.0]))
    np.testing.assert_array_equal(np.asarray(t), np.asarray(t2))


def test_per_sample_on_empty_buffer_returns_legal_slots():
    """The inverse-CDF descent over an all-zero tree must not leak the
    padded last leaf: indices clamp to the valid prefix (slot 0 when
    empty) and the batch weights are fully masked, so a premature
    priority write-back can never deposit mass beyond capacity."""
    s = per_init(50, (2,))                 # pads to 64 leaves
    b = jax.jit(lambda s, k: per_sample(s, k, 8, min_size=1))(
        s, jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(b["indices"]), 0)
    np.testing.assert_array_equal(np.asarray(b["weight"]), 0.0)
    s2 = per_update(s, b["indices"], jnp.ones(8))
    assert float(np.asarray(s2.tree)[64 + 50:].sum()) == 0.0


def stratified_counts(tree, key, n):
    idx, _ = jax.jit(sum_tree.stratified_sample,
                     static_argnums=2)(tree, key, n)
    L = tree.shape[0] // 2
    return np.bincount(np.asarray(idx), minlength=L)


def test_stratified_sample_frequencies_match_priorities():
    """Counts track n * p_i / total with the deterministic +/-2
    stratification bound — the 'sampling follows priority**alpha /
    sum' acceptance check (the tree stores mass already exponentiated,
    so the tree-level law is mass / total)."""
    pri = np.array([1, 2, 3, 4, 5, 0, 10, 0.5], np.float32)
    t = sum_tree.update(sum_tree.init(8), jnp.arange(8),
                        jnp.asarray(pri))
    n = 5000
    counts = stratified_counts(t, jax.random.PRNGKey(0), n)
    expect = n * pri / pri.sum()
    assert np.all(np.abs(counts[:8] - expect) <= 2.0), (counts, expect)
    assert counts[8:].sum() == 0                     # beyond capacity


@settings(max_examples=15, deadline=None)
@given(st.lists(st.floats(0.0, 100.0, width=32), min_size=2,
                max_size=32),
       st.integers(0, 2**31 - 1))
def test_stratified_sample_frequency_property(pri, seed):
    pri = np.asarray(pri, np.float32)
    if pri.sum() <= 0:
        pri[0] = 1.0
    t = sum_tree.update(sum_tree.init(len(pri)), jnp.arange(len(pri)),
                        jnp.asarray(pri))
    n = 1024
    counts = stratified_counts(t, jax.random.PRNGKey(seed % 2**31), n)
    expect = n * pri / pri.sum()
    # +/-2 strata + float slack on the stratum edges
    assert np.all(np.abs(counts[:len(pri)] - expect) <= 3.0)


# ---------------------------------------------------------------------------
# uniform backend: bit-compatibility with the pre-refactor buffer
# ---------------------------------------------------------------------------

# the PR-3 repro.rl.value implementation, frozen verbatim as the
# bit-compatibility reference (do not "modernize" this copy)
def _legacy_replay_add(buf, obs, action, reward, next_obs, discount):
    B = obs.shape[0]
    cap = buf.obs.shape[0]
    ptr = buf.ptr
    if B >= cap:
        drop = B - cap
        obs, action, reward, next_obs, discount = (
            x[drop:] for x in (obs, action, reward, next_obs, discount))
        ptr = ptr + drop
        B = cap
    idx = (ptr + jnp.arange(B)) % cap
    return type(buf)(
        buf.obs.at[idx].set(obs),
        buf.actions.at[idx].set(action),
        buf.rewards.at[idx].set(reward),
        buf.next_obs.at[idx].set(next_obs),
        buf.discounts.at[idx].set(discount),
        (ptr + B) % cap,
        jnp.minimum(buf.size + B, cap),
    )


def _legacy_replay_sample(buf, key, n, min_size=1):
    min_size = max(int(min_size), 1)
    idx = jax.random.randint(key, (n,), 0, jnp.maximum(buf.size, 1))
    weight = jnp.broadcast_to(
        (buf.size >= min_size).astype(jnp.float32), (n,))
    return {"obs": buf.obs[idx], "actions": buf.actions[idx],
            "rewards": buf.rewards[idx], "next_obs": buf.next_obs[idx],
            "discounts": buf.discounts[idx], "weight": weight}


def test_uniform_backend_bit_exact_with_pre_refactor_buffer():
    """Same capacity, same add/sample sequence, same keys -> byte-
    identical buffers and batches (including the overflow path)."""
    rb = make_replay("uniform", 8, (3,))
    new, old = rb.init(), replay_init(8, (3,))
    rng = np.random.RandomState(7)
    for batch in (3, 5, 8, 11):          # partial, wrap, ==cap, >cap
        obs = jnp.asarray(rng.randn(batch, 3), jnp.float32)
        act = jnp.asarray(rng.randint(0, 4, batch), jnp.int32)
        rew = jnp.asarray(rng.randn(batch), jnp.float32)
        disc = jnp.asarray(rng.uniform(0, 1, batch), jnp.float32)
        new = rb.add(new, obs, act, rew, obs + 1, disc)
        old = _legacy_replay_add(old, obs, act, rew, obs + 1, disc)
        for a, b in zip(jax.tree.leaves(new), jax.tree.leaves(old), strict=True):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        key = jax.random.PRNGKey(batch)
        s_new = rb.sample(new, key, 16, min_size=2)
        s_old = _legacy_replay_sample(old, key, 16, min_size=2)
        for col in s_old:
            np.testing.assert_array_equal(np.asarray(s_new[col]),
                                          np.asarray(s_old[col]))


def test_value_module_reexports_the_replay_subsystem():
    """repro.rl.value keeps the historical surface as aliases of the
    subsystem functions — one implementation, not a drifting copy."""
    from repro.rl import value
    from repro.rl.replay import uniform
    assert value.replay_add is uniform.replay_add
    assert value.replay_sample is uniform.replay_sample
    assert value.replay_init is uniform.replay_init
    assert value.Replay is uniform.Replay


# ---------------------------------------------------------------------------
# PER backend
# ---------------------------------------------------------------------------

def test_per_max_priority_insertion_and_refresh():
    """New transitions enter at the running max priority; the TD
    write-back re-prices exactly the sampled slots and lifts max_p."""
    alpha = 0.8
    rb = make_replay("per", 8, (2,), alpha=alpha)
    s = rb.init()
    obs = jnp.ones((3, 2))
    s = rb.add(s, obs, jnp.zeros(3, jnp.int32), jnp.ones(3), obs,
               jnp.full(3, 0.9))
    np.testing.assert_array_equal(
        np.asarray(sum_tree.get(s.tree, jnp.arange(3))), 1.0)

    td = jnp.array([4.0, 0.0])
    s = rb.update(s, jnp.array([0, 2]), td)
    want = (np.abs(np.asarray(td)) + PRIORITY_EPS) ** alpha
    got = np.asarray(sum_tree.get(s.tree, jnp.array([0, 2])))
    np.testing.assert_allclose(got, want, rtol=1e-6)
    # zero TD keeps a revisitable floor, never zero mass
    assert got[1] > 0.0
    # slot 1 untouched; max_p lifted to the new largest mass
    assert float(sum_tree.get(s.tree, jnp.array([1]))[0]) == 1.0
    assert float(s.max_p) == pytest.approx(want.max(), rel=1e-6)
    # the next insert lands at the lifted max
    s = rb.add(s, obs[:1], jnp.zeros(1, jnp.int32), jnp.ones(1),
               obs[:1], jnp.full(1, 0.9))
    assert float(sum_tree.get(s.tree, jnp.array([3]))[0]) \
        == pytest.approx(want.max(), rel=1e-6)
    assert_internal_sums_exact(s.tree)


def test_per_sample_importance_weights():
    """beta=1 weights are (N * P)^-1 max-normalized; beta=0 weights
    are all 1; the underfill guard mirrors the uniform backend."""
    s = per_init(8, (2,))
    obs = jnp.ones((4, 2))
    s = jax.jit(lambda s: make_replay("per", 8, (2,)).add(
        s, obs, jnp.zeros(4, jnp.int32), jnp.ones(4), obs,
        jnp.full(4, 0.9)))(s)
    s = per_update(s, jnp.arange(4), jnp.array([1.0, 2.0, 4.0, 8.0]),
                   alpha=1.0)
    b = per_sample(s, jax.random.PRNGKey(0), 64, min_size=2, beta=1.0)
    pri = np.asarray(sum_tree.get(s.tree, jnp.arange(4)))
    probs = pri / pri.sum()
    w_all = (4 * probs) ** -1.0
    want = w_all / w_all.max()
    idx = np.asarray(b["indices"])
    np.testing.assert_allclose(np.asarray(b["weight"]), want[idx],
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(b["probs"]), probs[idx],
                               rtol=1e-5)
    b0 = per_sample(s, jax.random.PRNGKey(0), 64, min_size=2, beta=0.0)
    np.testing.assert_array_equal(np.asarray(b0["weight"]), 1.0)

    # the losses consume the weights as (1/B) * sum(w * per_sample):
    # dividing by sum(w) instead would cancel the max-normalization
    # and AMPLIFY the effective lr under skewed weights
    from repro.rl.value import _weighted_mean
    x = jnp.array([1.0, 1.0, 1.0, 1.0])
    w = jnp.array([1.0, 0.01, 0.01, 0.01])
    assert float(_weighted_mean(x, w)) == pytest.approx(1.03 / 4)
    assert float(_weighted_mean(x, jnp.ones(4))) == 1.0
    assert float(_weighted_mean(x, jnp.zeros(4))) == 0.0

    with pytest.raises(ValueError, match="min_size"):
        per_sample(s, jax.random.PRNGKey(0), 4, min_size=5)
    masked = jax.jit(lambda s, k: per_sample(s, k, 4, min_size=5))(
        s, jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(masked["weight"]), 0.0)


def test_per_sampling_tracks_updated_priorities():
    """After a refresh, the sampled-slot distribution follows the NEW
    priorities (the naive-CDF law), not the insertion priorities."""
    rb = make_replay("per", 16, (1,), alpha=1.0)
    s = rb.init()
    obs = jnp.zeros((16, 1))
    s = rb.add(s, obs, jnp.zeros(16, jnp.int32), jnp.zeros(16), obs,
               jnp.zeros(16))
    td = jnp.asarray(np.r_[np.full(8, 0.001), np.full(8, 10.0)],
                     jnp.float32)
    s = rb.update(s, jnp.arange(16), td)
    n = 4096
    counts = stratified_counts(s.tree, jax.random.PRNGKey(1), n)
    pri = np.asarray(sum_tree.get(s.tree, jnp.arange(16)))
    expect = n * pri / pri.sum()
    assert np.all(np.abs(counts[:16] - expect) <= 2.0)


def test_make_replay_validates():
    with pytest.raises(ValueError, match="unknown replay kind"):
        make_replay("rainbow", 8, (2,))
    with pytest.raises(ValueError, match="alpha"):
        make_replay("per", 8, (2,), alpha=1.5)


# ---------------------------------------------------------------------------
# PER end to end: training, checkpoint resume, both actor precisions
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("actor_policy", ["fxp8", None])
def test_per_train_mechanics_both_precisions(actor_policy):
    """dqn --replay per runs end to end under fp32 AND fxp8 behaviour
    actors: params move, the tree stays internally consistent, the
    priorities differentiate away from the insertion value, and the
    final tree's sampling still follows the naive-CDF law."""
    agent0 = make_value_agent("dqn", make("cartpole").spec,
                              jax.random.PRNGKey(0))
    out = {}
    params, hist = value_train("dqn", "cartpole", iters=6, n_envs=8,
                               rollout_len=4, updates_per_iter=2,
                               learn_start=32, replay="per",
                               per_alpha=0.7, per_beta0=0.5,
                               actor_policy=actor_policy,
                               verbose=False, state_out=out)
    assert len(hist) == 6 and all(np.isfinite(h) for h in hist)
    delta = sum(float(jnp.sum(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(agent0.params),
                                jax.tree.leaves(params), strict=True))
    assert delta > 0, "updates were warmup no-ops"

    buf = out["replay"]
    size = int(buf.store.size)
    assert size == 6 * 8 * 4
    assert_internal_sums_exact(buf.tree)
    pri = np.asarray(sum_tree.get(buf.tree, jnp.arange(size)))
    assert (pri > 0).all()
    assert len(np.unique(pri)) > 1, "no priority was ever refreshed"
    n = 4096
    counts = stratified_counts(buf.tree, jax.random.PRNGKey(5), n)
    expect = n * pri / pri.sum()
    assert np.all(np.abs(counts[:size] - expect) <= 2.0)


def test_per_checkpoint_resume_roundtrip(tmp_path):
    """A preempted PER run relaunched with the same command line
    resumes with the exact tree, max-priority and storage pointers it
    checkpointed; a --replay mismatch is refused loudly."""
    d = str(tmp_path / "ck")
    kw = dict(env_name="cartpole", iters=6, n_envs=16, rollout_len=4,
              updates_per_iter=1, ckpt_dir=d, save_every=2,
              replay="per", verbose=False, seed=3)
    out = {}
    params, hist = value_train("dqn", state_out=out, **kw)
    assert len(hist) == 6

    mgr = CheckpointManager(d)
    assert mgr.latest_step() == 4
    agent = make_value_agent("dqn", make("cartpole").spec,
                             jax.random.PRNGKey(3))
    from repro.optim import adamw_init
    from repro.rl import init_envs
    from repro.rl.envs.wrappers import ensure_vector_obs
    est0, obs0 = init_envs(ensure_vector_obs(make("cartpole")),
                           jax.random.PRNGKey(3 + 1), 16)
    rb = make_replay("per", 50_000, (4,))
    like = (agent.params, agent.params, adamw_init(agent.params),
            rb.init(), est0, obs0)
    (p, tgt, opt, buf, _, _), md = mgr.restore(like)
    assert md["algo"] == "dqn" and md["it"] == 4
    assert md["replay"] == "per"
    # storage pointers exact: 5 chunks x 16 envs x 4 steps
    assert int(buf.store.size) == 5 * 16 * 4
    assert int(buf.store.ptr) == 5 * 16 * 4
    # the tree state is real: consistent, with refreshed priorities
    assert_internal_sums_exact(buf.tree)
    pri = np.asarray(sum_tree.get(buf.tree,
                                  jnp.arange(int(buf.store.size))))
    assert (pri > 0).all() and len(np.unique(pri)) > 1
    assert float(buf.max_p) >= pri.max() - 1e-6

    # relaunch resumes at it=5 (exactly the missing iteration) and the
    # final tree matches the uninterrupted run's bitwise
    out2 = {}
    params2, hist2 = value_train("dqn", state_out=out2, **kw)
    assert len(hist2) == 1
    for a, b in zip(jax.tree.leaves(out["replay"]),
                    jax.tree.leaves(out2["replay"]), strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # the sampling stream is part of the run: backend switches refuse,
    # and so do changed PER hyperparameters (they shape every draw)
    with pytest.raises(ValueError, match="--replay"):
        value_train("dqn", **{**kw, "replay": "uniform"})
    with pytest.raises(ValueError, match="--per-alpha"):
        value_train("dqn", **{**kw, "per_alpha": 0.9})
    with pytest.raises(ValueError, match="--per-beta0"):
        value_train("dqn", **{**kw, "per_beta0": 0.8})


def test_value_cli_replay_flags():
    main(["--algo", "dqn", "--env", "cartpole", "--iters", "2",
          "--n-envs", "8", "--rollout-len", "4", "--replay", "per",
          "--per-alpha", "0.5", "--per-beta0", "0.4"])
    # replay/TQC flags are value-based; on-policy rejects them loudly
    with pytest.raises(ValueError, match="value-based"):
        main(["--algo", "ppo", "--replay", "per", "--iters", "1"])
    with pytest.raises(ValueError, match="value-based"):
        main(["--algo", "a2c", "--tqc-drop", "2", "--iters", "1"])
    # tqc is a ddpg knob
    with pytest.raises(ValueError, match="twin critics"):
        main(["--algo", "dqn", "--tqc-drop", "2", "--iters", "1"])
    # per-* hyperparameters without --replay per would be silently
    # ignored (a uniform run masquerading as a PER experiment)
    with pytest.raises(ValueError, match="--replay per"):
        main(["--algo", "qrdqn", "--per-alpha", "0.9", "--iters", "1"])
    with pytest.raises(ValueError, match="--replay per"):
        main(["--algo", "dqn", "--per-beta-iters", "50", "--iters", "1"])


@pytest.mark.slow
def test_dqn_per_smoke_cartpole_reaches_floor():
    """Acceptance: dqn --replay per reaches at least the uniform-
    replay eval floor (150, test_dqn_smoke_cartpole_reaches_floor)."""
    params, hist = value_train("dqn", "cartpole", iters=300, n_envs=32,
                               rollout_len=8, updates_per_iter=8,
                               lr=5e-4, replay="per", seed=0,
                               actor_policy="fxp8", verbose=False)
    assert all(np.isfinite(h) for h in hist)
    ret, n_ep = value_eval("dqn", "cartpole", params, n_envs=16,
                           actor_policy="fxp8")
    assert n_ep > 0
    assert ret > 150.0, f"per-dqn stuck at {ret:.1f}"


def test_check_regression_per_row_slowdown_tolerance():
    """A baseline row's ``slowdown_tol`` overrides the global budget —
    the replay micro-bench rows ride a coarse catastrophic-regression
    net instead of the 2x steps/s watchdog."""
    import sys
    sys.path.insert(0, ".")
    from benchmarks.check_regression import check
    base = {("replay", "per/x"): {"table": "replay", "name": "per/x",
                                  "adds_per_s": 1000,
                                  "slowdown_tol": 30.0},
            ("env", "y"): {"table": "env", "name": "y",
                           "steps_per_s": 1000}}
    cur = {("replay", "per/x"): {"table": "replay", "name": "per/x",
                                 "adds_per_s": 100},     # 10x: inside 30
            ("env", "y"): {"table": "env", "name": "y",
                           "steps_per_s": 100}}          # 10x: beyond 2
    failures, notes = check(cur, base, max_slowdown=2.0,
                            max_sync_growth=1.05)
    assert len(failures) == 1 and "env/y" in failures[0]
    cur[("replay", "per/x")]["adds_per_s"] = 10          # 100x: beyond 30
    failures, _ = check(cur, base, 2.0, 1.05)
    assert any("replay/per/x" in f and "30.0x" in f for f in failures)


# ---------------------------------------------------------------------------
# TQC quantile truncation (ddpg)
# ---------------------------------------------------------------------------

def test_truncated_target_quantiles():
    z1 = jnp.array([[1.0, 3.0], [10.0, -1.0]])
    z2 = jnp.array([[2.0, 4.0], [0.0, 5.0]])
    np.testing.assert_array_equal(
        np.asarray(truncated_target_quantiles(z1, z2, 0)),
        [[1.0, 2.0, 3.0, 4.0], [-1.0, 0.0, 5.0, 10.0]])
    np.testing.assert_array_equal(
        np.asarray(truncated_target_quantiles(z1, z2, 2)),
        [[1.0, 2.0], [-1.0, 0.0]])
    with pytest.raises(ValueError, match="no target quantiles"):
        truncated_target_quantiles(z1, z2, 4)


def test_ddpg_config_validates_tqc():
    with pytest.raises(ValueError, match="min-backup"):
        DDPGConfig(tqc_drop=1)            # scalar critics can't prune
    with pytest.raises(ValueError, match="at least one"):
        DDPGConfig(critic_quantiles=2, tqc_drop=4)
    with pytest.raises(ValueError, match="critic_quantiles"):
        DDPGConfig(critic_quantiles=0)
    with pytest.raises(ValueError, match="twin critics"):
        make_value_agent("dqn", make("cartpole").spec, tqc_drop=2)


def test_ddpg_scalar_path_unchanged_and_td_matches():
    """tqc_drop=0 keeps the TD3 min-backup formula exactly, and the
    aux |td| is the per-sample critic error."""
    key = jax.random.PRNGKey(0)
    ka, kc, kb, kn = jax.random.split(key, 4)
    cfg = DDPGConfig()
    actor = unbox(mlp_pi_init(ka, 3, 2))
    critic = unbox(mlp_twin_q_init(kc, 3, 2))
    B = 5
    batch = {"obs": jax.random.normal(kb, (B, 3)),
             "actions": jax.random.uniform(kb, (B, 2), minval=-1,
                                           maxval=1),
             "rewards": jnp.arange(B, dtype=jnp.float32),
             "next_obs": jax.random.normal(kn, (B, 3)),
             "discounts": jnp.full((B,), 0.97)}
    actor_apply = lambda p, o: mlp_pi_apply(p, o, cfg.low, cfg.high)
    critic_apply = lambda p, o, a: mlp_twin_q_apply(p, o, a)
    loss, td = ddpg_critic_loss_td(critic, critic, actor, critic_apply,
                                   actor_apply, batch, cfg, kn)
    # the reference: TD3 eq. 14 computed by hand
    na = actor_apply(actor, batch["next_obs"])
    noise = jnp.clip(jax.random.normal(kn, na.shape) * cfg.policy_noise,
                     -cfg.noise_clip, cfg.noise_clip) * cfg.half_range
    na = jnp.clip(na + noise, cfg.low, cfg.high)
    q1_t, q2_t = critic_apply(critic, batch["next_obs"], na)
    tgt = batch["rewards"] + 0.97 * jnp.minimum(q1_t, q2_t)
    q1, q2 = critic_apply(critic, batch["obs"], batch["actions"])
    want = jnp.mean(jnp.square(q1 - tgt) + jnp.square(q2 - tgt))
    assert float(loss) == pytest.approx(float(want), rel=1e-6)
    np.testing.assert_allclose(
        np.asarray(td),
        np.asarray(0.5 * (jnp.abs(q1 - tgt) + jnp.abs(q2 - tgt))),
        rtol=1e-6)
    # the scalar loss face is the same computation
    assert float(ddpg_critic_loss(critic, critic, actor, critic_apply,
                                  actor_apply, batch, cfg, kn)) \
        == float(loss)


def test_ddpg_tqc_quantile_path_shapes_and_truncation_effect():
    """The TQC backup prices targets off the truncated pooled
    quantiles: dropping top quantiles can only lower the loss target
    (left-tail mixture), and the actor sees the quantile means."""
    key = jax.random.PRNGKey(1)
    ka, kc, kb, kn = jax.random.split(key, 4)
    N = 5
    cfg0 = DDPGConfig(critic_quantiles=N, tqc_drop=0)
    cfg3 = DDPGConfig(critic_quantiles=N, tqc_drop=3)
    actor = unbox(mlp_pi_init(ka, 3, 2))
    critic = unbox(mlp_twin_qr_init(kc, 3, 2, N))
    B = 4
    batch = {"obs": jax.random.normal(kb, (B, 3)),
             "actions": jax.random.uniform(kb, (B, 2), minval=-1,
                                           maxval=1),
             "rewards": jnp.zeros((B,)),
             "next_obs": jax.random.normal(kn, (B, 3)),
             "discounts": jnp.full((B,), 0.97)}
    actor_apply = lambda p, o: mlp_pi_apply(p, o, cfg0.low, cfg0.high)
    critic_apply = lambda p, o, a: mlp_twin_qr_apply(p, o, a)
    z1, z2 = critic_apply(critic, batch["obs"], batch["actions"])
    assert z1.shape == (B, N) and z2.shape == (B, N)
    loss0, td0 = ddpg_critic_loss_td(critic, critic, actor,
                                     critic_apply, actor_apply, batch,
                                     cfg0, kn)
    loss3, td3 = ddpg_critic_loss_td(critic, critic, actor,
                                     critic_apply, actor_apply, batch,
                                     cfg3, kn)
    assert np.isfinite(float(loss0)) and np.isfinite(float(loss3))
    assert td0.shape == (B,) and td3.shape == (B,)
    assert float(loss0) != float(loss3)
    a_loss = ddpg_actor_loss(actor, critic, critic_apply, actor_apply,
                             batch)
    assert np.isfinite(float(a_loss))
    g = jax.grad(ddpg_actor_loss)(actor, critic, critic_apply,
                                  actor_apply, batch)
    assert any(float(jnp.sum(jnp.abs(x))) > 0
               for x in jax.tree.leaves(g))


def test_ddpg_tqc_trains_end_to_end():
    """value_train with --tqc-drop: quantile twin critics, finite
    history, params move — under the fxp8 behaviour actor and PER."""
    agent = make_value_agent("ddpg", make("pendulum").spec,
                             jax.random.PRNGKey(0), tqc_drop=5)
    assert agent.cfg.critic_quantiles == 25 and agent.cfg.tqc_drop == 5
    params, hist = value_train("ddpg", "pendulum", iters=4, n_envs=8,
                               rollout_len=4, updates_per_iter=1,
                               learn_start=32, tqc_drop=5,
                               replay="per", actor_policy="fxp8",
                               verbose=False)
    assert len(hist) == 4 and all(np.isfinite(h) for h in hist)
    # the critic heads really are [.., 25]-quantile
    q_head = params["critic"]["q1"]["q"]["w"]
    assert unbox(q_head).shape[-1] == 25
    ret, _ = value_eval("ddpg", "pendulum", params, n_envs=4,
                        n_steps=32, actor_policy="fxp8")
    assert np.isfinite(ret)


def test_tqc_resume_requires_matching_critic_shape(tmp_path):
    """A tqc checkpoint reloaded without --tqc-drop would restore
    quantile critic arrays into scalar templates (restore does not
    shape-check) — the metadata guard must refuse it loudly."""
    d = str(tmp_path / "ck")
    kw = dict(env_name="pendulum", iters=3, n_envs=8, rollout_len=4,
              updates_per_iter=1, learn_start=32, ckpt_dir=d,
              save_every=2, verbose=False)
    value_train("ddpg", tqc_drop=5, **kw)
    with pytest.raises(ValueError, match="--tqc-drop"):
        value_train("ddpg", tqc_drop=0, **kw)
