"""Checkpoint round-trip, atomicity, retention, auto-resume."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore, save
from repro.core.fxp import QTensor


def tree_example():
    return {
        "params": {"w": jnp.arange(12.0).reshape(3, 4),
                   "b": jnp.ones((4,), jnp.bfloat16)},
        "opt": {"mu": jnp.zeros((3, 4)), "count": jnp.asarray(7)},
        "qw": QTensor(jnp.arange(16, dtype=jnp.int8).reshape(4, 4),
                      jnp.full((1, 4), 0.5), 8),
    }


def assert_tree_equal(a, b):
    la = jax.tree.leaves(a, is_leaf=lambda x: isinstance(x, QTensor))
    lb = jax.tree.leaves(b, is_leaf=lambda x: isinstance(x, QTensor))
    for x, y in zip(la, lb, strict=True):
        if isinstance(x, QTensor):
            np.testing.assert_array_equal(np.asarray(x.qvalue),
                                          np.asarray(y.qvalue))
            np.testing.assert_allclose(np.asarray(x.scale),
                                       np.asarray(y.scale))
            assert x.bits == y.bits
        else:
            np.testing.assert_allclose(
                np.asarray(x, np.float32), np.asarray(y, np.float32))


def test_roundtrip(tmp_path):
    t = tree_example()
    p = str(tmp_path / "ck.npz")
    save(p, t, {"step": 3})
    r, md = restore(p, t)
    assert md["step"] == 3
    assert_tree_equal(t, r)
    # dtype preserved
    assert r["params"]["b"].dtype == jnp.bfloat16


def test_missing_leaf_raises(tmp_path):
    t = {"a": jnp.ones(3)}
    p = str(tmp_path / "ck.npz")
    save(p, t)
    with pytest.raises(KeyError):
        restore(p, {"a": jnp.ones(3), "extra": jnp.ones(2)})


def test_manager_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, save_every=5)
    t = {"x": jnp.ones(2)}
    for s in (5, 10, 15, 20):
        mgr.save(s, t)
    assert mgr.all_steps() == [15, 20]
    assert mgr.latest_step() == 20
    assert not mgr.should_save(3)
    assert mgr.should_save(25)


def test_manager_survives_missing_latest_pointer(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(5, {"x": jnp.ones(2)})
    os.unlink(os.path.join(str(tmp_path), "LATEST"))
    assert mgr.latest_step() == 5          # falls back to scanning


def test_restore_or_init(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    init = lambda: {"w": jnp.zeros(4)}
    t, step = mgr.restore_or_init(init)
    assert step == 0
    t = {"w": jnp.ones(4) * 2}
    mgr.save(42, t)
    r, step = mgr.restore_or_init(init)
    assert step == 42
    np.testing.assert_allclose(np.asarray(r["w"]), 2.0)


def test_no_torn_writes(tmp_path):
    """The npz appears only after a complete write: no *.tmp left over
    and the sidecar always parses."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, tree_example())
    leftovers = [f for f in os.listdir(str(tmp_path))
                 if f.endswith(".tmp")]
    assert leftovers == []
    with open(mgr.path_for(1) + ".json") as f:
        json.load(f)


def test_elastic_restore_onto_sharding(tmp_path):
    """Restore with an explicit sharding tree (1-device mesh here;
    the same code path re-shards onto any mesh)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    t = {"w": jnp.arange(8.0).reshape(2, 4)}
    p = str(tmp_path / "ck.npz")
    save(p, t)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P(None, None))}
    r, _ = restore(p, t, sh)
    assert r["w"].sharding == sh["w"]
    np.testing.assert_allclose(np.asarray(r["w"]), np.asarray(t["w"]))
