"""Q-Conv kernel parity suite: ops vs oracle, Pallas vs XLA taps,
integer-path conv2d_apply vs the fake-quant reference, and the
serve-vs-eval Q-vector bit-parity the packed path guarantees."""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fxp import QTensor
from repro.core.policy import get_policy
from repro.core.quantizer import quantize_params
from repro.kernels.qconv import ops, ref
from repro.nn.conv import conv2d_apply, conv2d_init, qconv_block
from repro.nn.module import unbox

# (B, H, W, C, N, k, stride, padding): stem-like shapes plus odd
# spatial sizes, frame-stack channel counts, and non-3x3 filters.
SHAPES = [
    (4, 10, 5, 4, 16, 3, 2, "SAME"),     # catch stem, stride 2
    (2, 32, 32, 12, 16, 3, 2, "SAME"),   # keydoor k=4 frame stack
    (3, 9, 7, 16, 32, 3, 1, "SAME"),     # odd spatial, stride 1
    (2, 8, 8, 8, 8, 3, 2, "VALID"),
    (1, 5, 5, 3, 5, 2, 1, "VALID"),      # even kernel
    (2, 7, 11, 1, 4, 3, 2, "SAME"),      # single channel
]


def _quantized_operands(shape, seed=0):
    b, h, w, c, n, k, _, _ = shape
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(k1, (b, h, w, c))
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    sx = jnp.maximum(amax, 1e-12) / 127.0
    qx = jnp.clip(jnp.round(x / sx), -127, 127).astype(jnp.int8)
    wgt = jax.random.normal(k2, (k, k, c, n)) * 0.1
    wa = jnp.max(jnp.abs(wgt), axis=(0, 1, 2), keepdims=True)
    sw = (jnp.maximum(wa, 1e-12) / 127.0).reshape(-1)
    qw = jnp.clip(jnp.round(wgt / sw), -127, 127).astype(jnp.int8)
    bias = jax.random.normal(k3, (n,)) * 0.01
    return qx, sx, qw, sw, bias


@pytest.mark.parametrize("shape", SHAPES)
def test_ops_xla_matches_oracle_bitwise(shape):
    """Eager tap-dot path == independent broadcast-sum oracle, exactly."""
    qx, sx, qw, sw, b = _quantized_operands(shape)
    stride, pad = shape[6], shape[7]
    out = ops.qconv2d_i8(qx, sx, qw, sw, b, stride=stride, padding=pad)
    want = ref.qconv2d_i8(qx, sx, qw, sw, b, stride=stride, padding=pad)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))
    assert out.dtype == jnp.float32


@pytest.mark.parametrize("shape", SHAPES)
def test_exact_f32_embedding_matches_int32(shape):
    """fp32-embedded integer dots == true int32 dots, bitwise (jit)."""
    qx, sx, qw, sw, b = _quantized_operands(shape, seed=1)
    stride, pad = shape[6], shape[7]
    f = functools.partial(ops.qconv2d_i8, stride=stride, padding=pad)
    a = jax.jit(functools.partial(f, exact_f32=True))(qx, sx, qw, sw, b)
    c = jax.jit(functools.partial(f, exact_f32=False))(qx, sx, qw, sw, b)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("fuse_relu", [False, True])
def test_pallas_kernel_matches_taps(shape, fuse_relu):
    """Pallas kernel (interpret on CPU) vs tap-dot path: same integer
    program, fp accumulation within 1 ulp (FMA regrouping only)."""
    qx, sx, qw, sw, b = _quantized_operands(shape, seed=2)
    stride, pad = shape[6], shape[7]
    f = functools.partial(ops.qconv2d_i8, stride=stride, padding=pad,
                          fuse_relu=fuse_relu)
    out_k = f(qx, sx, qw, sw, b, kernel=True)
    out_x = f(qx, sx, qw, sw, b)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_x),
                               rtol=1e-6, atol=1e-6)


def test_kernel_interpret_fallback_on_cpu():
    """interpret=None resolves to interpreter mode off-TPU."""
    assert ops._interpret_default() == (jax.default_backend() != "tpu")
    qx, sx, qw, sw, b = _quantized_operands(SHAPES[0], seed=3)
    out = ops.qconv2d_i8(qx, sx, qw, sw, b, stride=2, kernel=True,
                         interpret=None)
    assert out.shape == (4, 5, 3, 16)


def test_fused_relu_equals_relu_of_unfused():
    qx, sx, qw, sw, b = _quantized_operands(SHAPES[1], seed=4)
    fused = ops.qconv2d_i8(qx, sx, qw, sw, b, stride=2, fuse_relu=True)
    plain = ops.qconv2d_i8(qx, sx, qw, sw, b, stride=2)
    np.testing.assert_array_equal(np.asarray(fused),
                                  np.asarray(jnp.maximum(plain, 0.0)))


def test_conv2d_apply_integer_path_matches_fake_quant():
    """Dispatch sanity: fxp8 integer path vs the ref-backend fake-quant
    conv.  Same quantization grids, different accumulation order."""
    fxp8 = get_policy("fxp8")
    p = unbox(conv2d_init(jax.random.PRNGKey(0), 4, 16, 3))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 10, 5, 4))
    y_int = conv2d_apply(p, x, stride=2, policy=fxp8)
    y_ref = conv2d_apply(p, x, stride=2,
                         policy=dataclasses.replace(fxp8, backend="ref"))
    np.testing.assert_allclose(np.asarray(y_int), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-5)


def test_conv2d_apply_pallas_backend():
    fxp8 = get_policy("fxp8")
    pal = dataclasses.replace(fxp8, backend="pallas")
    p = unbox(conv2d_init(jax.random.PRNGKey(0), 4, 16, 3))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 10, 5, 4))
    y_pl = conv2d_apply(p, x, stride=2, policy=pal)
    y_x = conv2d_apply(p, x, stride=2, policy=fxp8)
    np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_x),
                               rtol=1e-6, atol=1e-6)


def test_packed_weights_bit_identical_to_eval():
    """The serve-vs-eval contract at the Q-vector level: QTensor
    weights through the kernel == fp weights quantized on the fly,
    bitwise, eager and jitted."""
    fxp8 = get_policy("fxp8")
    p = unbox(conv2d_init(jax.random.PRNGKey(0), 12, 16, 3))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 10, 10, 12))
    pq = quantize_params(p, dataclasses.replace(fxp8, per_channel=True))
    assert isinstance(pq["w"], QTensor)
    y_eval = conv2d_apply(p, x, stride=2, policy=fxp8)
    y_srv = conv2d_apply(pq, x, stride=2, policy=fxp8)
    np.testing.assert_array_equal(np.asarray(y_srv), np.asarray(y_eval))
    f = jax.jit(lambda pp, xx: conv2d_apply(pp, xx, stride=2,
                                            policy=fxp8))
    np.testing.assert_array_equal(np.asarray(f(pq, x)),
                                  np.asarray(f(p, x)))


def test_qconv_block_integer_path_gradients_match_ste():
    """The custom-vjp backward must reproduce the fake-quant STE
    gradients exactly (same dequantized operands, same fp conv vjp)."""
    fxp8 = get_policy("fxp8")
    ref_pol = dataclasses.replace(fxp8, backend="ref")
    p = unbox(conv2d_init(jax.random.PRNGKey(0), 4, 16, 3))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 10, 5, 4))
    g = jax.grad(lambda p_, x_: qconv_block(p_, x_, policy=fxp8).sum())(
        p, x)
    g_ref = jax.grad(
        lambda p_, x_: qconv_block(p_, x_, policy=ref_pol).sum())(p, x)
    for k in ("w", "b"):
        np.testing.assert_array_equal(np.asarray(g[k]),
                                      np.asarray(g_ref[k]))


def test_wide_policy_stays_on_fp_path():
    """w8 (a_bits=32) must keep the fake-quant fallback — integer
    activations need a quantized-activation policy."""
    from repro.nn.conv import _use_integer_conv
    w8 = get_policy("w8")
    p = unbox(conv2d_init(jax.random.PRNGKey(0), 4, 16, 3))
    assert not _use_integer_conv(w8, p["w"])
    assert _use_integer_conv(get_policy("fxp8"), p["w"])
    assert _use_integer_conv(get_policy("w4a8"), p["w"])
