"""Docs stay true: kernel entry points keep real docstrings, the
authoring guide exists and names the validation instruments, and no
markdown doc carries a broken local link."""
import importlib
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

DTYPE_HINTS = ("int8", "int32", "fp32")


def test_kernels_public_api_docstrings():
    """Every name in repro.kernels.__all__ must carry a docstring
    stating its dtype contract (the authoring guide's requirement)."""
    kernels = importlib.import_module("repro.kernels")
    assert kernels.__all__, "kernels package must export its API"
    assert "qconv2d_i8" in kernels.__all__
    for name in kernels.__all__:
        fn = getattr(kernels, name)
        doc = fn.__doc__ or ""
        assert len(doc.strip()) > 40, f"{name}: missing/thin docstring"
        lowered = doc.lower()
        assert any(h in lowered for h in DTYPE_HINTS), \
            f"{name}: docstring must state its dtype contract"
    assert (kernels.__doc__ or "").strip(), "package docstring required"


def test_kernel_guide_exists_and_names_instruments():
    guide = (REPO / "docs" / "kernels.md").read_text()
    for needle in ("Q-MAC blocking", "tap-blocked im2col",
                   "check_regression", "trace audit",
                   "When to fall back to XLA", "rtol=1e-6"):
        assert needle in guide, f"docs/kernels.md lost: {needle!r}"


def test_architecture_doc_exists_and_maps_layers():
    arch = (REPO / "docs" / "architecture.md").read_text()
    for needle in ("repro.rl.trainer", "repro.serve", "repro.kernels",
                   "repro.analysis", "Bit-exactness contracts"):
        assert needle in arch, f"docs/architecture.md lost: {needle!r}"


def test_markdown_links_resolve():
    """tools/check_md_links.py over README + docs/ must pass."""
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_md_links.py")],
        capture_output=True, text=True, cwd=REPO)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_conv_allowlist_reasons_point_at_docs():
    """The QF101 conv fallback entries must justify themselves against
    the documented fallback policy."""
    toml = (REPO / "src" / "repro" / "analysis" /
            "allowlist.toml").read_text()
    assert "docs/kernels.md" in toml
    assert "_raw_conv" in toml
