"""Self-tests for the repro.analysis static checker.

Every lint rule gets at least one positive (fires on a fixture
violation) and one negative (stays quiet on the compliant twin in the
same file); the trace checks get unit-level positives via poisoned
inputs plus a fast end-to-end sweep marked slow.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.allowlist import (AllowEntry, AllowlistError,
                                      DEFAULT_PATH, apply_allowlist,
                                      load_allowlist)
from repro.analysis.cli import main as cli_main
from repro.analysis.lint import LintConfig, run_lint
from repro.analysis.rules import Finding
from repro.analysis import trace_audit as ta

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXDIR = "tests/analysis_fixtures"


def fixture_config(**over):
    cfg = LintConfig(
        qf101_scope=(FIXDIR + "/",),
        qf101_blessed=(FIXDIR + "/fx_blessed.py",),
        qf501_scope=(FIXDIR + "/fx_qf501.py",),
        library=(FIXDIR + "/",),
    )
    return dataclasses.replace(cfg, **over) if over else cfg


def lint_fixtures(*names, **over):
    paths = [os.path.join(ROOT, FIXDIR, n) for n in names]
    return run_lint(ROOT, paths=paths, config=fixture_config(**over))


def lines_of(findings, rule):
    return sorted(f.line for f in findings if f.rule == rule)


def fixture_line(name, needle):
    with open(os.path.join(ROOT, FIXDIR, name), encoding="utf-8") as fh:
        for i, line in enumerate(fh, 1):
            if needle in line:
                return i
    raise AssertionError(f"{needle!r} not in {name}")


# ---------------------------------------------------------------------------
# Mode 1 — one positive and one negative per rule
# ---------------------------------------------------------------------------


def test_qf101_raw_matmul_fires_and_blessed_is_exempt():
    findings = lint_fixtures("fx_qf101.py", "fx_blessed.py")
    assert {f.rule for f in findings} == {"QF101"}
    # both the jnp.dot call and the @ operator
    want = {fixture_line("fx_qf101.py", "jnp.dot"),
            fixture_line("fx_qf101.py", "x @ w")}
    assert set(lines_of(findings, "QF101")) == want
    # negative: the blessed module uses jnp.dot freely
    assert not [f for f in findings if "fx_blessed" in f.path]
    # negative: elementwise ops in scope are fine
    good = fixture_line("fx_qf101.py", "jnp.add")
    assert good not in lines_of(findings, "QF101")


def test_qf201_tracer_branching_fires_with_reachability():
    findings = lint_fixtures("fx_qf201.py")
    assert {f.rule for f in findings} == {"QF201"}
    got = lines_of(findings, "QF201")
    assert fixture_line("fx_qf201.py", "x.sum() > 0") in got
    assert fixture_line("fx_qf201.py", "len(y)") in got
    # reachable only through jax.lax.scan(scan_body, ...)
    assert fixture_line("fx_qf201.py", "carry.sum() > 0") in got
    # negatives: static shapes, None guards, unreachable helpers
    for needle in ("x.shape[0] > n", "mask is None", "y.mean() > 0"):
        assert fixture_line("fx_qf201.py", needle) not in got


def test_qf301_nondeterminism_fires_only_when_reachable():
    findings = lint_fixtures("fx_qf301.py")
    assert {f.rule for f in findings} == {"QF301"}
    got = lines_of(findings, "QF301")
    for needle in ("np.random.rand", "time.time()", "random.random"):
        assert fixture_line("fx_qf301.py", needle) in got
    # negatives: jax.random is the sanctioned path; host helpers that
    # tracing never reaches may read the clock
    assert fixture_line("fx_qf301.py", "jax.random.normal") not in got
    host = fixture_line("fx_qf301.py", "# negative: not jit-reachable")
    assert host not in got


def test_qf401_missing_donation_fires_on_decorator_and_call_site():
    findings = lint_fixtures("fx_qf401.py")
    assert {f.rule for f in findings} == {"QF401"}
    qns = {f.qualname for f in findings}
    assert "bad_step" in qns            # @jax.jit decorator site
    assert "_local_update" in qns       # jax.jit(fn) call site
    # negative: the donated twin threads the same state
    assert "good_step" not in qns


def test_qf501_untagged_wrapper_fires_outside_wrap():
    findings = lint_fixtures("fx_qf501.py")
    assert {f.rule for f in findings} == {"QF501"}
    got = lines_of(findings, "QF501")
    assert got == [fixture_line("fx_qf501.py", "# QF501 positive")]


def test_qf601_bare_print_fires_in_library_code():
    findings = lint_fixtures("fx_qf601.py")
    assert {f.rule for f in findings} == {"QF601"}
    got = lines_of(findings, "QF601")
    assert fixture_line("fx_qf601.py", "QF601 module positive") in got
    assert fixture_line("fx_qf601.py", "QF601 positive") in got
    assert fixture_line("fx_qf601.py", "QF601 method positive") in got
    # negatives: Console / stream APIs are the sanctioned outputs
    for needle in ("console.info", "stream.write"):
        assert fixture_line("fx_qf601.py", needle) not in got
    # method findings carry the class-qualified name for allowlisting
    assert "Reporter.dump" in {f.qualname for f in findings}


def test_qf601_exempt_paths_are_skipped():
    findings = lint_fixtures(
        "fx_qf601.py",
        qf601_exempt=(FIXDIR + "/fx_qf601.py",))
    assert not findings


def test_rules_filter_restricts_the_run():
    findings = lint_fixtures("fx_qf101.py", "fx_qf301.py",
                             rules=("QF301",))
    assert findings and {f.rule for f in findings} == {"QF301"}


# ---------------------------------------------------------------------------
# allowlist semantics
# ---------------------------------------------------------------------------


def _finding(rule="QF201", path="src/repro/x.py", line=3,
             message="msg about foo", qualname="foo"):
    return Finding(path, line, rule, message, qualname)


def test_allowlist_suppresses_matching_and_reports_stale():
    fd = _finding()
    live = AllowEntry(rule="QF201", path="src/repro/x.py",
                      match="foo", reason="audited")
    stale = AllowEntry(rule="QF101", path="src/repro/y.py",
                       match="", reason="obsolete")
    kept, stale_out, suppressed = apply_allowlist([fd], [live, stale])
    assert kept == [] and suppressed == [fd] and stale_out == [stale]


def test_allowlist_mismatch_keeps_the_finding():
    fd = _finding()
    miss = AllowEntry(rule="QF201", path="src/repro/x.py",
                      match="unrelated", reason="r")
    kept, stale_out, suppressed = apply_allowlist([fd], [miss])
    assert kept == [fd] and suppressed == [] and stale_out == [miss]


def test_committed_allowlist_parses_with_reasons():
    entries = load_allowlist(DEFAULT_PATH)
    assert entries and all(e.reason for e in entries)


def test_allowlist_rejects_entries_without_reason(tmp_path):
    p = tmp_path / "allow.toml"
    p.write_text('[[allow]]\nrule = "QF201"\n'
                 'path = "src/repro/x.py"\n')
    with pytest.raises(AllowlistError):
        load_allowlist(str(p))


# ---------------------------------------------------------------------------
# the real tree is clean (modulo the committed allowlist)
# ---------------------------------------------------------------------------


def test_real_tree_lint_is_clean_and_allowlist_not_stale():
    findings = run_lint(ROOT)
    kept, stale, _ = apply_allowlist(findings,
                                     load_allowlist(DEFAULT_PATH))
    assert kept == [], "\n".join(f.render() for f in kept)
    assert stale == [], f"stale allowlist entries: {stale}"


def test_cli_lint_exits_clean_on_the_tree(capsys):
    assert cli_main(["lint", "--root", ROOT]) == 0
    capsys.readouterr()


def test_cli_rejects_unknown_rule_ids(capsys):
    assert cli_main(["lint", "--rules", "QF999"]) == 2
    capsys.readouterr()


# ---------------------------------------------------------------------------
# Mode 2 — trace-audit unit checks
# ---------------------------------------------------------------------------


def test_expected_scale_shape_table():
    assert ta.expected_scale_shape((32, 64)) == (1, 64)
    assert ta.expected_scale_shape((3, 32, 64)) == (3, 1, 64)
    assert ta.expected_scale_shape((3, 3, 8, 16)) == (1, 1, 1, 16)
    assert ta.expected_scale_shape((7,)) is None


def test_qf902_wrong_grid_qtensor_fires():
    from repro.core.fxp import QTensor
    # per-tensor scale where the consumer broadcasts per-out-channel
    wrong = QTensor(jax.ShapeDtypeStruct((4, 8), jnp.int8),
                    jax.ShapeDtypeStruct((1, 1), jnp.float32), 8)
    found = ta.check_packed_tree({"w": wrong}, 8, "trace:test")
    assert [f.rule for f in found] == ["QF902"]
    assert "(1, 8)" in found[0].message
    # rank outside the convention table is itself a finding
    odd = QTensor(jax.ShapeDtypeStruct((5,), jnp.int8),
                  jax.ShapeDtypeStruct((1,), jnp.float32), 8)
    found = ta.check_packed_tree({"w": odd}, 8, "trace:test")
    assert found and "grid table" in found[0].message


def test_qf902_real_quantize_params_is_on_grid():
    import numpy as np
    params = {"dense": {"w": jnp.asarray(
        np.linspace(-1, 1, 32 * 8, dtype="float32").reshape(32, 8)),
        "b": jnp.zeros((8,), jnp.float32)}}
    assert ta.audit_qtensor_grids(params, 8, "trace:test") == []
    assert ta.audit_qtensor_grids(params, 4, "trace:test") == []


def test_qf901_wide_dtype_walk():
    from jax.experimental import enable_x64
    with enable_x64():
        closed = jax.make_jaxpr(
            lambda x: x.astype(jnp.float64) * 2.0)(jnp.ones(3))
    assert ta.find_wide_dtypes(closed) == ["float64"]
    clean = jax.make_jaxpr(lambda x: jnp.sin(x) * 2.0)(jnp.ones(3))
    assert ta.find_wide_dtypes(clean) == []


def test_qf901_state_parity_catches_dtype_drift():
    good = ta.state_parity_mismatches(
        {"a": jnp.zeros(3)}, {"a": jnp.zeros(3)}, "est")
    assert good == []
    drift = ta.state_parity_mismatches(
        {"a": jnp.zeros(3)}, {"a": jnp.zeros(3, jnp.float16)}, "est")
    assert len(drift) == 1 and "float16" in drift[0]
    reshaped = ta.state_parity_mismatches(
        {"a": jnp.zeros(3)}, {"a": jnp.zeros((3, 1))}, "obs")
    assert len(reshaped) == 1


def test_qf904_donation_survives_lowering_text():
    x = jnp.zeros(8)
    donated = jax.jit(lambda buf: buf + 1, donate_argnums=(0,))
    assert "tf.aliasing_output" in donated.lower(x).as_text()
    plain = jax.jit(lambda buf: buf + 1)
    assert "tf.aliasing_output" not in plain.lower(x).as_text()


def test_accepted_combos_mirror_rl_train_dispatch():
    combos = ta.accepted_combos()
    assert len(combos) == 54
    assert ("pendulum", "mlp", "ddpg", "fp32") in combos
    assert ("cartpole", "mlp", "dqn", "fxp8") in combos
    assert ("catch", "conv", "qrdqn", "fp32") in combos
    # ddpg needs a bounded Box: no discrete env ever qualifies
    assert not any(c[2] == "ddpg" and c[0] != "pendulum"
                   for c in combos)
    # conv needs image obs: no 1-D env reaches the conv stem
    assert not any(c[1] == "conv" and c[0] not in ("catch", "keydoor")
                   for c in combos)


# ---------------------------------------------------------------------------
# Mode 2 — live serving-ladder audit (compiles small programs)
# ---------------------------------------------------------------------------


def _tiny_server(max_bucket=4):
    from repro.rl.inference import build_env, make_value_agent
    from repro.serve.engine import PolicyServer
    from repro.serve.loader import ServedPolicy

    env = build_env("cartpole", "mlp")
    agent = make_value_agent("dqn", env.spec,
                             key=jax.random.PRNGKey(0), net="mlp")
    policy = ServedPolicy.from_agent(agent, "cartpole", net="mlp")
    return PolicyServer(policy, precision="w8", max_bucket=max_bucket)


def test_qf903_bucket_ladder_clean_then_retrace_detected():
    server = _tiny_server()
    server.warmup()
    obs_shape = tuple(server.policy.env.obs_shape)
    for n in (1, 3, 5):
        server.act(jnp.zeros((n,) + obs_shape, jnp.float32))
    assert ta.check_bucket_ladder(server, "trace:test") == []

    # poison: a second program sneaks into one bucket's jit cache via a
    # dtype change past the pad-to-bucket boundary
    bucket = server.buckets[0]
    fn = server._jit_cache[bucket]
    fn(server.served_params,
       jnp.zeros((bucket,) + obs_shape, jnp.float16), server._key)
    found = ta.check_bucket_ladder(server, "trace:test")
    assert [f.rule for f in found] == ["QF903"]
    assert "retraced" in found[0].message

    # poison: a bucket with no compiled program at all
    del server._jit_cache[server.buckets[-1]]
    found = ta.check_bucket_ladder(server, "trace:test")
    assert any("one program per bucket" in f.message for f in found)


@pytest.mark.slow
def test_trace_audit_fast_sweep_is_clean():
    res = ta.run_trace_audit(fast=True)
    assert res.findings == [], "\n".join(
        f.render() for f in res.findings)
    # one representative per (net, algo, precision) family + serving
    assert len(res.combos_checked) >= 18
