"""Fused Q-LSTM cell kernel vs oracle + vs fp32 LSTM reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.qlstm import ops, ref

SIZES = [(8, 32, 32), (16, 64, 32), (5, 24, 48), (1, 32, 32)]


def _setup(b, din, h, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 8)
    qx = jax.random.randint(ks[0], (b, din), -128, 128, dtype=jnp.int8)
    qh = jax.random.randint(ks[1], (b, h), -128, 128, dtype=jnp.int8)
    qw = jax.random.randint(ks[2], (din, 4 * h), -128, 128, dtype=jnp.int8)
    qu = jax.random.randint(ks[3], (h, 4 * h), -128, 128, dtype=jnp.int8)
    sx, sh = 0.02, 0.015
    sw = jax.random.uniform(ks[4], (1, 4 * h), minval=1e-3, maxval=5e-3)
    su = jax.random.uniform(ks[5], (1, 4 * h), minval=1e-3, maxval=5e-3)
    bias = jax.random.normal(ks[6], (4 * h,)) * 0.1
    c = jax.random.normal(ks[7], (b, h)) * 0.5
    return qx, sx, qh, sh, qw, sw, qu, su, bias, c


@pytest.mark.parametrize("b,din,h", SIZES)
def test_qlstm_kernel_vs_oracle(b, din, h):
    args = _setup(b, din, h)
    h_k, c_k = ops.qlstm_cell(*args, n_iters=13)
    h_r, c_r = ref.qlstm_cell(*[jnp.asarray(a) for a in args], n_iters=13)
    np.testing.assert_allclose(np.asarray(c_k), np.asarray(c_r),
                               atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r),
                               atol=1e-5, rtol=1e-4)


def test_qlstm_tracks_fp32_lstm():
    """Quantized fused cell ~= fp32 LSTM math within quantization error."""
    b, din, h = 8, 32, 32
    qx, sx, qh, sh, qw, sw, qu, su, bias, c = _setup(b, din, h, key=3)
    x = qx.astype(jnp.float32) * sx
    hh = qh.astype(jnp.float32) * sh
    w = qw.astype(jnp.float32) * sw
    u = qu.astype(jnp.float32) * su
    gates = x @ w + hh @ u + bias
    i, f, g, o = jnp.split(jax.nn.sigmoid(gates), 4, axis=1)
    g = jnp.tanh(gates[:, 2 * h:3 * h])
    c_fp = f[:, :h] * 0 + jax.nn.sigmoid(gates[:, h:2 * h]) * c \
        + jax.nn.sigmoid(gates[:, :h]) * g
    h_fp = jnp.tanh(c_fp) * jax.nn.sigmoid(gates[:, 3 * h:])
    h_k, c_k = ops.qlstm_cell(qx, sx, qh, sh, qw, sw, qu, su, bias, c,
                              n_iters=13)
    assert float(jnp.abs(c_k - c_fp).max()) < 5e-3
    assert float(jnp.abs(h_k - h_fp).max()) < 5e-3


def test_qlstm_vmem_guard():
    with pytest.raises(ValueError):
        args = _setup(8, 2048, 2048)
        ops.qlstm_cell(*args, n_iters=6)
