"""RL subsystem tests: envs, GAE, PPO, DQN, dists, actor-learner sync."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.policy import FXP8, QuantPolicy
from repro.nn.module import unbox
from repro.rl import PPOConfig, batch_from_traj, gae, init_envs, rollout
from repro.rl.actor_learner import (merge_results, pack_weights,
                                    sync_bytes, unpack_weights)
from repro.rl.dists import Categorical, TanhGaussian, distribution_for
from repro.rl.envs import Box, Discrete, Environment, make
from repro.rl.envs.spaces import head_dim
from repro.rl.nets import (mlp_ac_apply, mlp_ac_init, mlp_pi_apply,
                           mlp_pi_init, mlp_q_apply, mlp_q_init,
                           mlp_qr_apply, mlp_qr_init, mlp_twin_q_apply,
                           mlp_twin_q_init)
from repro.rl.value import (DDPGConfig, DQNConfig, QRDQNConfig,
                            ddpg_actor_loss, ddpg_critic_loss, dqn_loss,
                            egreedy, epsilon, nstep_targets, polyak,
                            qrdqn_loss, replay_add, replay_init,
                            replay_sample)
from repro.rl.ppo import (a2c_loss, apply_stage_mask, minibatch_epochs,
                          ppo_loss, stage_mask)
from repro.rl.rollout import episode_returns


# -- envs (spot checks; the per-env contract lives in test_envs.py) ----------

def test_make_returns_typed_environment():
    env = make("cartpole")
    assert isinstance(env, Environment)
    assert env.spec.name == "cartpole"
    assert isinstance(env.action_space, Discrete)
    assert env.spec.n_actions == 2
    assert env.obs_shape == (4,)


def test_make_unknown_env_lists_registry():
    with pytest.raises(ValueError, match="cartpole"):
        make("nope")


def test_cartpole_terminates_on_angle():
    env = make("cartpole")
    s, _ = env.reset(jax.random.PRNGKey(0))
    done = False
    for _ in range(500):          # always push right -> falls over
        s, _, _, d, tr, _ = jax.jit(env.step)(s, jnp.asarray(1))
        done = done or bool(d)
        if done:
            break
        assert not bool(tr)       # falls well before the 500-step limit
    assert done


def test_keydoor_subgoal_then_goal():
    """Walking to key then door yields both bonuses and terminates."""
    from repro.rl.envs import keydoor
    s, _ = keydoor.reset(jax.random.PRNGKey(3))
    step = jax.jit(keydoor.step)

    def walk_to(s, target):
        total = 0.0
        for _ in range(2 * keydoor.GRID):
            dr = target[0] - s.agent[0]
            dc = target[1] - s.agent[1]
            if dr < 0:
                a = 0
            elif dr > 0:
                a = 1
            elif dc < 0:
                a = 2
            elif dc > 0:
                a = 3
            else:
                break
            s, _, r, d, tr, _ = step(s, jnp.asarray(a))
            total += float(r)
            if bool(d | tr):
                break
        return s, total

    key_pos = np.asarray(s.key_pos)
    s, r1 = walk_to(s, key_pos)
    assert bool(s.has_key)
    assert r1 > 0.3                       # +0.5 pickup minus step costs
    door = np.asarray(s.door)
    s2, r2 = walk_to(s, door)
    assert r2 > 0.8                       # +1.0 open minus step costs


def test_vectorized_rollout_and_returns():
    env = make("cartpole")
    params = unbox(mlp_ac_init(jax.random.PRNGKey(0), 4, 2))
    fn = lambda p, o: mlp_ac_apply(p, o)
    est, obs = init_envs(env, jax.random.PRNGKey(1), 8)
    res = jax.jit(lambda p, e, o: rollout(
        p, env, fn, jax.random.PRNGKey(2), e, o, 64))(params, est, obs)
    assert res.traj.rewards.shape == (64, 8)
    ret, n = episode_returns(res.traj)
    assert int(n) > 0 and float(ret) > 5.0     # random policy survives >5


# -- action distributions -----------------------------------------------

def test_distribution_for_space_kinds():
    assert isinstance(distribution_for(Discrete(4)), Categorical)
    d = distribution_for(Box(-2.0, 2.0, (1,)))
    assert isinstance(d, TanhGaussian)
    with pytest.raises(ValueError):
        distribution_for(Box(-np.inf, np.inf, (1,)))


def test_head_dim():
    assert head_dim(Discrete(6)) == 6
    assert head_dim(Box(-1.0, 1.0, (3,))) == 6


def test_categorical_matches_log_softmax():
    dist = Categorical()
    logits = jax.random.normal(jax.random.PRNGKey(0), (5, 3))
    a = jnp.array([0, 2, 1, 2, 0])
    expect = jax.nn.log_softmax(logits)[jnp.arange(5), a]
    np.testing.assert_allclose(np.asarray(dist.log_prob(logits, a)),
                               np.asarray(expect), rtol=1e-6)
    ent = dist.entropy(jnp.zeros((2, 4)))
    np.testing.assert_allclose(np.asarray(ent), np.log(4.0), rtol=1e-5)


def test_tanh_gaussian_samples_in_bounds_and_logprob_finite():
    dist = TanhGaussian(-2.0, 2.0)
    dparams = jax.random.normal(jax.random.PRNGKey(0), (64, 2))  # d=1
    a = dist.sample(jax.random.PRNGKey(1), dparams)
    assert a.shape == (64, 1)
    # fp32 tanh saturates to exactly +/-1, so the bounds are closed
    assert bool(jnp.all((a >= -2.0) & (a <= 2.0)))
    lp = dist.log_prob(dparams, a)
    assert lp.shape == (64,)
    assert bool(jnp.all(jnp.isfinite(lp)))
    assert bool(jnp.all(jnp.isfinite(dist.entropy(dparams))))


def test_tanh_gaussian_logprob_integrates_to_one():
    """Riemann-integrate exp(log_prob) over the support: ~1."""
    dist = TanhGaussian(-2.0, 2.0)
    dparams = jnp.array([0.3, -0.5])      # mu=0.3, log_std=-0.5
    xs = jnp.linspace(-1.999, 1.999, 4001).reshape(-1, 1)
    lp = jax.vmap(lambda x: dist.log_prob(dparams, x))(xs)
    mass = float(jnp.sum(jnp.exp(lp)) * (xs[1, 0] - xs[0, 0]))
    assert mass == pytest.approx(1.0, abs=2e-2)


def test_continuous_rollout_and_ppo_loss():
    """Pendulum actions flow through rollout + PPO without reshaping."""
    env = make("pendulum")
    dist = distribution_for(env.action_space)
    params = unbox(mlp_ac_init(jax.random.PRNGKey(0), 3,
                               head_dim(env.action_space)))
    fn = lambda p, o: mlp_ac_apply(p, o)
    est, obs = init_envs(env, jax.random.PRNGKey(1), 4)
    res = jax.jit(lambda p, e, o: rollout(
        p, env, fn, jax.random.PRNGKey(2), e, o, 16,
        dist))(params, est, obs)
    assert res.traj.actions.shape == (16, 4, 1)
    batch = batch_from_traj(res.traj, res.last_value, PPOConfig())
    (loss, stats), grads = jax.value_and_grad(ppo_loss, has_aux=True)(
        params, fn, batch, PPOConfig(), dist)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.abs(g)))
                for g in jax.tree.leaves(grads))
    assert gnorm > 0


# -- GAE ----------------------------------------------------------------

def test_gae_matches_manual_single_env():
    r = jnp.array([[1.0], [1.0], [1.0]])
    v = jnp.array([[0.5], [0.5], [0.5]])
    d = jnp.zeros((3, 1), bool)
    lastv = jnp.array([0.5])
    adv, ret = gae(r, v, d, lastv, gamma=0.9, lam=1.0)
    # lam=1: adv_t = sum_k gamma^k r_{t+k} + gamma^{T-t} v_T - v_t
    expect0 = 1 + 0.9 + 0.81 + 0.729 * 0.5 - 0.5
    assert float(adv[0, 0]) == pytest.approx(expect0, rel=1e-5)
    np.testing.assert_allclose(np.asarray(ret), np.asarray(adv + v))


def test_gae_stops_at_done():
    r = jnp.ones((2, 1))
    v = jnp.zeros((2, 1))
    d = jnp.array([[True], [False]])
    adv, _ = gae(r, v, d, jnp.array([10.0]), gamma=0.9, lam=0.95)
    assert float(adv[0, 0]) == pytest.approx(1.0)  # no bootstrap past done


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10**6))
def test_gae_zero_when_values_consistent(seed):
    """If v exactly equals discounted return, advantages are ~0."""
    key = jax.random.PRNGKey(seed)
    r = jax.random.uniform(key, (5, 2))
    lastv = jnp.zeros((2,))
    d = jnp.zeros((5, 2), bool)
    # v_t = r_t + g*v_{t+1}
    g = 0.9
    vs = []
    nxt = lastv
    for t in range(4, -1, -1):
        nxt = r[t] + g * nxt
        vs.append(nxt)
    v = jnp.stack(vs[::-1])
    # v here includes r_t; GAE defines delta = r + g*v' - v, so feed
    # v_t as value BEFORE reward: shift
    adv, _ = gae(r, v, d, lastv, gamma=g, lam=0.95)
    # delta_t = r_t + g v_{t+1} - v_t = 0 by construction
    np.testing.assert_allclose(np.asarray(adv), 0.0, atol=1e-4)


# -- PPO / A2C ----------------------------------------------------------

def _tiny_batch(n=16):
    key = jax.random.PRNGKey(0)
    return {
        "obs": jax.random.normal(key, (n, 4)),
        "actions": jnp.zeros((n,), jnp.int32),
        "log_probs": jnp.full((n,), -0.69),
        "advantages": jnp.ones((n,)),
        "returns": jnp.ones((n,)),
    }


def test_ppo_loss_finite_and_grads_flow():
    params = unbox(mlp_ac_init(jax.random.PRNGKey(0), 4, 2))
    fn = lambda p, o: mlp_ac_apply(p, o)
    (loss, stats), grads = jax.value_and_grad(ppo_loss, has_aux=True)(
        params, fn, _tiny_batch(), PPOConfig())
    assert np.isfinite(float(loss))
    assert all(np.all(np.isfinite(np.asarray(g)))
               for g in jax.tree.leaves(grads))
    gnorm = sum(float(jnp.sum(jnp.abs(g)))
                for g in jax.tree.leaves(grads))
    assert gnorm > 0


def test_ppo_clipping_caps_ratio_gradient():
    """With a huge positive advantage and ratio far above 1+eps, the
    pg gradient wrt logits must vanish (clip active)."""
    cfg = PPOConfig(ent_coef=0.0, vf_coef=0.0)
    params = unbox(mlp_ac_init(jax.random.PRNGKey(0), 4, 2))
    fn = lambda p, o: mlp_ac_apply(p, o)
    b = _tiny_batch(4)
    b["log_probs"] = jnp.full((4,), -20.0)   # ratio = e^(logp+20) >> 1.2
    b["advantages"] = jnp.ones((4,)) * 5.0
    grads = jax.grad(lambda p: ppo_loss(p, fn, b, cfg)[0])(params)
    gnorm = sum(float(jnp.sum(jnp.abs(g)))
                for g in jax.tree.leaves(grads))
    assert gnorm < 1e-5


def test_minibatch_epochs_rejects_indivisible_batch():
    """A batch that does not divide into cfg.minibatches would silently
    drop the tail every epoch — it must be a loud error instead."""
    from repro.optim import AdamWConfig, adamw_init, adamw_update, constant
    params = unbox(mlp_ac_init(jax.random.PRNGKey(0), 4, 2))
    fn = lambda p, o: mlp_ac_apply(p, o)
    batch = _tiny_batch(n=10)                 # 10 % 4 != 0
    opt = adamw_init(params)
    sched = constant(1e-3)
    ocfg = AdamWConfig()

    def opt_step(p, s, g):
        p, s, _ = adamw_update(g, s, p, sched, ocfg)
        return p, s

    with pytest.raises(ValueError, match="silently"):
        minibatch_epochs(jax.random.PRNGKey(0), params, opt, batch, fn,
                         PPOConfig(), opt_step)
    # the divisible case still runs
    out = minibatch_epochs(jax.random.PRNGKey(0), params, opt,
                           _tiny_batch(n=16), fn, PPOConfig(), opt_step)
    assert len(out) == 3


def test_a2c_loss_finite():
    params = unbox(mlp_ac_init(jax.random.PRNGKey(0), 4, 2))
    fn = lambda p, o: mlp_ac_apply(p, o)
    loss, _ = a2c_loss(params, fn, _tiny_batch(), PPOConfig())
    assert np.isfinite(float(loss))


def test_stage_mask_freezes_subgoal():
    params = {"stem": {"w": jnp.ones(3)}, "subgoal": {"w": jnp.ones(3)},
              "action": {"w": jnp.ones(3)}, "value": {"w": jnp.ones(3)}}
    grads = jax.tree.map(jnp.ones_like, params)
    m1 = stage_mask(params, "action")
    g1 = apply_stage_mask(grads, m1)
    assert float(jnp.sum(g1["subgoal"]["w"])) == 0
    assert float(jnp.sum(g1["stem"]["w"])) == 3
    m2 = stage_mask(params, "subgoal")
    g2 = apply_stage_mask(grads, m2)
    assert float(jnp.sum(g2["subgoal"]["w"])) == 3
    assert float(jnp.sum(g2["action"]["w"])) == 0


def test_two_stage_grad_mask_freezes_offstage_subtree():
    """The exact wiring rl_train --two-stage uses: minibatch_epochs with
    a stage_mask grad mask bitwise-freezes the off-stage subtree while
    the on-stage subtrees train (param-delta test on the real agent)."""
    from repro.launch.rl_train import make_agent
    from repro.optim import AdamWConfig, adamw_init, adamw_update, constant

    env = make("catch")                      # smallest image env
    dist = distribution_for(env.action_space)
    params, apply_fn = make_agent("hrl", env, jax.random.PRNGKey(0), None)
    fn = lambda p, o: apply_fn(p, o, None)
    est, obs = init_envs(env, jax.random.PRNGKey(1), 4)
    res = rollout(params, env, fn, jax.random.PRNGKey(2), est, obs, 8,
                  dist)
    batch = batch_from_traj(res.traj, res.last_value, PPOConfig())
    opt = adamw_init(params)
    sched = constant(3e-3)
    ocfg = AdamWConfig(weight_decay=0.0, max_grad_norm=0.5)

    def opt_step(p, s, g):
        p, s, _ = adamw_update(g, s, p, sched, ocfg)
        return p, s

    for stage, frozen, trained in (("action", "subgoal", "action"),
                                   ("subgoal", "action", "subgoal")):
        gmask = stage_mask(params, stage)
        new_params, _, _ = minibatch_epochs(
            jax.random.PRNGKey(3), params, opt, batch, fn, PPOConfig(),
            opt_step, grad_mask=gmask, dist=dist)
        for a, b in zip(jax.tree.leaves(params[frozen]),
                        jax.tree.leaves(new_params[frozen]), strict=True):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        delta = sum(float(jnp.sum(jnp.abs(a - b)))
                    for a, b in zip(jax.tree.leaves(params[trained]),
                                    jax.tree.leaves(new_params[trained]), strict=True))
        assert delta > 0, f"stage {stage} did not train {trained}"


def test_two_stage_checkpoint_records_stage_and_resumes_in_stage(
        tmp_path, capsys):
    """Two-stage steps are namespaced (g = stage*iters + it) and tagged
    with the stage, so a resume lands mid-stage-2 instead of silently
    restarting stage 1."""
    from repro.checkpoint import CheckpointManager
    from repro.launch.rl_train import make_agent, rl_train
    from repro.optim import adamw_init

    d = str(tmp_path / "ck")
    kw = dict(env_name="catch", agent="hrl", iters=2, n_envs=4,
              rollout_len=4, two_stage=True, ckpt_dir=d, save_every=1)
    rl_train(verbose=False, **kw)
    capsys.readouterr()

    mgr = CheckpointManager(d)
    assert mgr.latest_step() == 3            # 2 stages x 2 iters - 1
    env = make("catch")
    params, _ = make_agent("hrl", env, jax.random.PRNGKey(0), "fxp8")
    est0, obs0 = init_envs(env, jax.random.PRNGKey(1), 4)
    from repro.rl.trainer import onpolicy_state
    _, md = mgr.restore(onpolicy_state(params, adamw_init(params),
                                       est0, obs0))
    assert md["stage"] == "subgoal"
    assert md["stage_iter"] == 1

    # simulate preemption right after g=2 (stage 2, iter 0) and
    # relaunch with the same command line: must resume inside stage 2
    # at g=3, never re-running stage 1 or the checkpointed step
    import os
    for sfx in (".npz", ".npz.json"):
        os.unlink(os.path.join(d, f"step_3{sfx}"))
    _, hist = rl_train(verbose=True, **kw)
    out = capsys.readouterr().out
    assert "resumed at global iter 3 (stage subgoal, iter 0 done)" in out
    assert "[stage=action]" not in out
    assert "[stage=subgoal]" in out
    assert len(hist) == 1                    # exactly the missing iter

    # resuming a two-stage checkpoint without --two-stage must refuse
    # loudly, not silently reinterpret the step in single-stage terms
    with pytest.raises(ValueError, match="saved in stage"):
        rl_train(verbose=False, **{**kw, "two_stage": False})


def test_two_stage_requires_hrl_agent():
    from repro.launch.rl_train import rl_train
    with pytest.raises(ValueError, match="requires --agent hrl"):
        rl_train(env_name="cartpole", agent="mlp", iters=1,
                 two_stage=True, verbose=False)


def test_masked_batch_zeroes_straggler_loss():
    """A batch whose mask is all-zero produces zero pg/v loss."""
    from repro.rl.rollout import Trajectory
    T, B = 8, 4
    traj = Trajectory(
        obs=jnp.zeros((T, B, 4)), actions=jnp.zeros((T, B), jnp.int32),
        log_probs=jnp.zeros((T, B)), values=jnp.zeros((T, B)),
        rewards=jnp.ones((T, B)), dones=jnp.zeros((T, B), bool),
        truncated=jnp.zeros((T, B), bool), next_obs=jnp.zeros((T, B, 4)))
    batch = batch_from_traj(traj, jnp.zeros((B,)), PPOConfig(),
                            actor_mask=jnp.zeros((B,)))
    params = unbox(mlp_ac_init(jax.random.PRNGKey(0), 4, 2))
    fn = lambda p, o: mlp_ac_apply(p, o)
    cfg = PPOConfig(ent_coef=0.0)
    loss, stats = ppo_loss(params, fn, batch, cfg)
    assert float(stats["pg_loss"]) == 0.0
    assert float(stats["v_loss"]) == 0.0
    # a2c honours the same liveness-mask contract (--algo a2c runs
    # through the identical masked sharded driver)
    loss, stats = a2c_loss(params, fn, batch, cfg)
    assert float(stats["pg_loss"]) == 0.0
    assert float(stats["v_loss"]) == 0.0


# -- truncation-aware GAE (the headline bugfix) --------------------------

def test_gae_bootstraps_through_truncation_not_termination():
    """Identical rewards/values, one env truncated vs one terminated at
    t=0: the truncated row's advantage must include the discounted
    bootstrap value of its final (pre-reset) observation; the
    terminated row must not."""
    r = jnp.array([[1.0, 1.0], [1.0, 1.0]])
    v = jnp.zeros((2, 2))
    dones = jnp.array([[False, True], [False, False]])
    trunc = jnp.array([[True, False], [False, False]])
    boot = jnp.full((2, 2), 10.0)          # V(final_obs) everywhere
    lastv = jnp.zeros((2,))
    adv, _ = gae(r, v, dones, lastv, gamma=0.9, lam=0.95,
                 truncated=trunc, bootstrap_values=boot)
    # env 0 truncated at t=0: adv = r + gamma * V(final_obs)
    assert float(adv[0, 0]) == pytest.approx(1.0 + 0.9 * 10.0)
    # env 1 terminated at t=0: no bootstrap
    assert float(adv[0, 1]) == pytest.approx(1.0)
    # the advantage chain still breaks at the truncation: row 1 of
    # env 0 (the fresh episode) must not leak into row 0 beyond the
    # bootstrap — identical to a lam=0 one-step target here
    adv_no_chain, _ = gae(r, v, dones, lastv, gamma=0.9, lam=0.0,
                          truncated=trunc, bootstrap_values=boot)
    assert float(adv[0, 0]) == pytest.approx(float(adv_no_chain[0, 0]))

    # truncated without bootstrap values is a loud error, not a bias
    with pytest.raises(ValueError, match="bootstrap_values"):
        gae(r, v, dones, lastv, truncated=trunc)


def test_gae_truncation_end_to_end_on_pendulum():
    """A pendulum rollout across the 200-step horizon: dones stay
    False, the boundary row is truncated, and batch_from_traj with a
    value_fn produces targets that bootstrap V(final_obs) there."""
    env = make("pendulum")
    dist = distribution_for(env.action_space)
    params = unbox(mlp_ac_init(jax.random.PRNGKey(0), 3,
                               head_dim(env.action_space)))
    fn = lambda p, o: mlp_ac_apply(p, o)
    est, obs = init_envs(env, jax.random.PRNGKey(1), 2)
    res = jax.jit(lambda p, e, o: rollout(
        p, env, fn, jax.random.PRNGKey(2), e, o, 202,
        dist))(params, est, obs)
    assert not bool(res.traj.dones.any())
    assert bool(res.traj.truncated.any())
    t, b = map(int, np.argwhere(np.asarray(res.traj.truncated))[0])
    # next_obs at the truncation is the pre-reset state, not the fresh
    # episode's first observation (which the next row acts on)
    assert not np.allclose(np.asarray(res.traj.next_obs[t, b]),
                           np.asarray(res.traj.obs[t + 1, b]))

    cfg = PPOConfig(gamma=0.9, lam=0.95)
    value_fn = lambda o: fn(params, o)[1]
    batch = batch_from_traj(res.traj, res.last_value, cfg,
                            value_fn=value_fn)
    T, B = res.traj.rewards.shape
    rets = batch["returns"].reshape(T, B)
    boot = value_fn(res.traj.next_obs.reshape(T * B, 3)).reshape(T, B)
    # at the truncation row return = r + gamma * V(final_obs) exactly
    # (the recursion restarts there, so lam plays no role in that row)
    expect = float(res.traj.rewards[t, b] + 0.9 * boot[t, b])
    assert float(rets[t, b]) == pytest.approx(expect, rel=1e-5)


def test_nstep_targets_windows_and_discounts():
    """3-step windows stop at boundaries: termination zeroes the
    discount, truncation keeps gamma^K, the tail degrades to shorter
    valid windows."""
    g = 0.5
    T, B = 5, 1
    r = jnp.arange(1.0, 6.0).reshape(T, B)          # 1..5
    dones = jnp.array([[False], [True], [False], [False], [False]])
    trunc = jnp.array([[False], [False], [False], [True], [False]])
    nobs = jnp.arange(10.0, 15.0).reshape(T, B, 1)  # distinct markers
    rets, nxt, disc = nstep_targets(r, dones, trunc, nobs, g, 3)
    rets, nxt, disc = (np.asarray(rets)[:, 0], np.asarray(nxt)[:, 0, 0],
                       np.asarray(disc)[:, 0])
    # t=0: window hits the termination at t=1 -> K=2, no bootstrap
    assert rets[0] == pytest.approx(1.0 + g * 2.0)
    assert disc[0] == 0.0 and nxt[0] == 11.0
    # t=1: terminated immediately -> K=1, no bootstrap
    assert rets[1] == pytest.approx(2.0) and disc[1] == 0.0
    # t=2: window hits the truncation at t=3 -> K=2, bootstrap gamma^2
    assert rets[2] == pytest.approx(3.0 + g * 4.0)
    assert disc[2] == pytest.approx(g ** 2) and nxt[2] == 13.0
    # t=3: truncated immediately -> K=1, bootstrap gamma
    assert disc[3] == pytest.approx(g) and nxt[3] == 13.0
    # t=4: chunk tail -> K=1 one-step target
    assert rets[4] == pytest.approx(5.0)
    assert disc[4] == pytest.approx(g) and nxt[4] == 14.0


# -- replay + value-based losses -----------------------------------------

def test_replay_circular_and_sample():
    buf = replay_init(8, (4,))
    obs = jnp.arange(24.0).reshape(6, 4)
    buf = replay_add(buf, obs, jnp.zeros(6, jnp.int32), jnp.ones(6),
                     obs, jnp.full(6, 0.99))
    assert int(buf.size) == 6 and int(buf.ptr) == 6
    buf = replay_add(buf, obs, jnp.zeros(6, jnp.int32), jnp.ones(6),
                     obs, jnp.full(6, 0.99))
    assert int(buf.size) == 8 and int(buf.ptr) == 4   # wrapped
    s = replay_sample(buf, jax.random.PRNGKey(0), 16)
    assert s["obs"].shape == (16, 4)
    np.testing.assert_array_equal(np.asarray(s["weight"]), 1.0)


def test_replay_sample_guards_underfilled_buffer():
    """The empty/underfilled buffer is never silently trained on:
    eager sampling raises, and under jit the weight column masks the
    whole batch (so a weighted loss is exactly zero)."""
    buf = replay_init(8, (4,))
    with pytest.raises(ValueError, match="min_size"):
        replay_sample(buf, jax.random.PRNGKey(0), 4)
    obs = jnp.ones((2, 4))
    buf = replay_add(buf, obs, jnp.zeros(2, jnp.int32), jnp.ones(2),
                     obs, jnp.zeros(2))
    with pytest.raises(ValueError, match="min_size"):
        replay_sample(buf, jax.random.PRNGKey(0), 4, min_size=4)
    # under jit size is a tracer: the guard becomes a zero weight...
    s = jax.jit(lambda b, k: replay_sample(b, k, 4, min_size=4))(
        buf, jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(s["weight"]), 0.0)
    # ...which zeroes the masked losses
    params = unbox(mlp_q_init(jax.random.PRNGKey(0), 4, 2))
    fn = lambda p, o: mlp_q_apply(p, o)
    assert float(dqn_loss(params, params, fn, s, DQNConfig())) == 0.0
    # and once filled past min_size the same call trains normally
    obs = jnp.ones((6, 4))
    buf = replay_add(buf, obs, jnp.zeros(6, jnp.int32), jnp.ones(6),
                     obs, jnp.zeros(6))
    s = jax.jit(lambda b, k: replay_sample(b, k, 4, min_size=4))(
        buf, jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(s["weight"]), 1.0)
    assert float(dqn_loss(params, params, fn, s, DQNConfig())) > 0.0


def test_dqn_shim_is_gone():
    """The deprecated ``repro.rl.dqn`` compatibility shim (a PR-3
    re-export of the replay/value split) is deleted: the import path
    must fail loudly, and nothing in the source tree may still spell
    it."""
    with pytest.raises(ModuleNotFoundError):
        import repro.rl.dqn  # noqa: F401
    import pathlib
    src = pathlib.Path(__file__).resolve().parents[1] / "src"
    hits = [p for p in src.rglob("*.py")
            if "repro.rl.dqn" in p.read_text()]
    assert not hits, f"stale repro.rl.dqn references: {hits}"


def test_replay_add_overflow_keeps_last_capacity_deterministically():
    """B >= capacity: only the newest `capacity` transitions survive, at
    well-defined slots (duplicate scatter indices have unspecified write
    order in XLA — the overflow path must never produce them)."""
    cap = 4
    buf = replay_init(cap, (1,))
    obs = jnp.arange(6.0).reshape(6, 1)
    add = jax.jit(replay_add)
    buf = add(buf, obs, jnp.arange(6, dtype=jnp.int32), jnp.arange(6.0),
              obs + 100.0, jnp.zeros(6))
    assert int(buf.size) == cap
    assert int(buf.ptr) == 6 % cap            # ptr advances by full B
    # transitions 2..5 land at slots (0+2..5) % 4 = [2, 3, 0, 1]
    np.testing.assert_array_equal(np.asarray(buf.obs[:, 0]),
                                  [4.0, 5.0, 2.0, 3.0])
    np.testing.assert_array_equal(np.asarray(buf.actions), [4, 5, 2, 3])
    np.testing.assert_array_equal(np.asarray(buf.next_obs[:, 0]),
                                  [104.0, 105.0, 102.0, 103.0])
    # and a non-zero ptr start still wraps correctly
    buf = add(buf, obs, jnp.arange(6, dtype=jnp.int32), jnp.arange(6.0),
              obs, jnp.zeros(6))
    assert int(buf.ptr) == (6 + 6) % cap
    np.testing.assert_array_equal(np.asarray(buf.obs[:, 0]),
                                  [2.0, 3.0, 4.0, 5.0])


def test_dqn_loss_and_epsilon_schedule():
    params = unbox(mlp_q_init(jax.random.PRNGKey(0), 4, 2))
    fn = lambda p, o: mlp_q_apply(p, o)
    # legacy batches carry `dones`; discount-encoded ones `discounts` —
    # both must produce finite losses with gradients
    legacy = {"obs": jnp.zeros((8, 4)),
              "actions": jnp.zeros((8,), jnp.int32),
              "rewards": jnp.ones((8,)), "next_obs": jnp.zeros((8, 4)),
              "dones": jnp.zeros((8,), bool)}
    cfg = DQNConfig()
    for batch in (legacy,
                  {**{k: v for k, v in legacy.items() if k != "dones"},
                   "discounts": jnp.full((8,), 0.99)}):
        loss = dqn_loss(params, params, fn, batch, cfg)
        assert np.isfinite(float(loss))
    # Double-DQN selects with the ONLINE argmax but prices with the
    # target net: with q(obs) = obs + params, online argmax on
    # next_obs=[1, 0] is action 0, where the (shifted) target net says
    # 1.0 — vanilla max over the target net would say 2.0
    table_fn = lambda p, o: o + p
    tbatch = {"obs": jnp.zeros((1, 2)),
              "actions": jnp.zeros((1,), jnp.int32),
              "rewards": jnp.zeros((1,)),
              "next_obs": jnp.array([[1.0, 0.0]]),
              "discounts": jnp.ones((1,))}
    online_p = jnp.zeros((2,))
    target_p = jnp.array([0.0, 2.0])
    l_double = dqn_loss(online_p, target_p, table_fn, tbatch, cfg)
    l_vanilla = dqn_loss(online_p, target_p, table_fn, tbatch,
                         DQNConfig(double=False))
    assert float(l_double) == pytest.approx(1.0)    # (0 - 1*1.0)^2
    assert float(l_vanilla) == pytest.approx(4.0)   # (0 - 1*2.0)^2
    assert float(epsilon(jnp.asarray(0), cfg)) == pytest.approx(1.0)
    assert float(epsilon(jnp.asarray(10**6), cfg)) == pytest.approx(0.05)
    acts = egreedy(jax.random.PRNGKey(0),
                   jnp.array([[0.0, 9.9]] * 100), jnp.asarray(0.0))
    assert int(acts.sum()) == 100          # greedy when eps=0


def test_qrdqn_loss_finite_and_head_shape():
    n_act, n_q = 3, 8
    params = unbox(mlp_qr_init(jax.random.PRNGKey(0), 4, n_act, n_q))
    fn = lambda p, o: mlp_qr_apply(p, o, n_act, n_q)
    out = fn(params, jnp.zeros((5, 4)))
    assert out.shape == (5, n_act, n_q)
    batch = {"obs": jax.random.normal(jax.random.PRNGKey(1), (8, 4)),
             "actions": jnp.zeros((8,), jnp.int32),
             "rewards": jnp.ones((8,)),
             "next_obs": jax.random.normal(jax.random.PRNGKey(2), (8, 4)),
             "discounts": jnp.full((8,), 0.99)}
    cfg = QRDQNConfig(n_quantiles=n_q)
    (loss, ), grads = (qrdqn_loss(params, params, fn, batch, cfg),), \
        jax.grad(qrdqn_loss)(params, params, fn, batch, cfg)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.abs(g)))
                for g in jax.tree.leaves(grads))
    assert gnorm > 0


def test_ddpg_losses_and_polyak():
    obs_dim, act_dim = 3, 1
    ka, kc = jax.random.split(jax.random.PRNGKey(0))
    cfg = DDPGConfig(low=-2.0, high=2.0)
    actor = unbox(mlp_pi_init(ka, obs_dim, act_dim))
    critic = unbox(mlp_twin_q_init(kc, obs_dim, act_dim))
    actor_apply = lambda p, o, pol=None: mlp_pi_apply(p, o, cfg.low,
                                                      cfg.high, pol)
    critic_apply = lambda p, o, a, pol=None: mlp_twin_q_apply(p, o, a,
                                                              pol)
    a = actor_apply(actor, jnp.zeros((4, obs_dim)))
    assert a.shape == (4, act_dim)
    assert bool(jnp.all((a >= cfg.low) & (a <= cfg.high)))
    batch = {"obs": jax.random.normal(jax.random.PRNGKey(1), (8, obs_dim)),
             "actions": jax.random.uniform(jax.random.PRNGKey(2),
                                           (8, act_dim), minval=-2.0,
                                           maxval=2.0),
             "rewards": jnp.ones((8,)),
             "next_obs": jax.random.normal(jax.random.PRNGKey(3),
                                           (8, obs_dim)),
             "discounts": jnp.full((8,), 0.99)}
    c_loss = ddpg_critic_loss(critic, critic, actor, critic_apply,
                              actor_apply, batch, cfg,
                              jax.random.PRNGKey(4))
    assert np.isfinite(float(c_loss))
    g = jax.grad(ddpg_actor_loss)(actor, critic, critic_apply,
                                  actor_apply, batch)
    gnorm = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g))
    assert gnorm > 0
    # polyak moves the target a tau-fraction toward the online params
    tgt = jax.tree.map(jnp.zeros_like, actor)
    moved = polyak(tgt, actor, 0.25)
    for t, o in zip(jax.tree.leaves(moved), jax.tree.leaves(actor), strict=True):
        np.testing.assert_allclose(np.asarray(t), 0.25 * np.asarray(o),
                                   rtol=1e-6)


# -- actor-learner sync --------------------------------------------------

def test_sync_bytes_4x_reduction():
    params = unbox(mlp_ac_init(jax.random.PRNGKey(0), 4, 2, hidden=128))
    packed = pack_weights(params, 8)
    payload, fp32 = sync_bytes(packed)
    assert payload < 0.35 * fp32          # int8 + scales < 35% of fp32


def test_pack_unpack_roundtrip_error_bounded():
    params = unbox(mlp_ac_init(jax.random.PRNGKey(0), 4, 2))
    rec = unpack_weights(pack_weights(params, 8))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(rec), strict=True):
        scale = float(jnp.max(jnp.abs(a))) / 127.0
        assert float(jnp.max(jnp.abs(a - b))) <= scale * 0.51 + 1e-8


def test_quantized_actor_rollout_runs():
    """Rollout under the FXP8 actor policy with int8-packed weights."""
    from repro.rl.actor_learner import collect
    env = make("cartpole")
    params = unbox(mlp_ac_init(jax.random.PRNGKey(0), 4, 2))
    packed = pack_weights(params, 8)
    est, obs = init_envs(env, jax.random.PRNGKey(1), 4)
    res = collect(packed, env, mlp_ac_apply, FXP8,
                  jax.random.PRNGKey(2), est, obs, 16)
    assert res.traj.rewards.shape == (16, 4)
    assert np.all(np.isfinite(np.asarray(res.traj.log_probs)))


def test_merge_results_masks_stragglers():
    from repro.rl.actor_learner import collect
    env = make("cartpole")
    params = unbox(mlp_ac_init(jax.random.PRNGKey(0), 4, 2))
    packed = pack_weights(params, 8)
    results = []
    for i in range(3):
        est, obs = init_envs(env, jax.random.PRNGKey(i), 4)
        results.append(collect(packed, env, mlp_ac_apply, FXP8,
                               jax.random.PRNGKey(10 + i), est, obs, 8))
    merged, mask = merge_results(results, jnp.array([True, False, True]))
    assert merged.traj.rewards.shape == (8, 12)
    np.testing.assert_array_equal(
        np.asarray(mask), np.repeat([1.0, 0.0, 1.0], 4))


def test_merge_results_final_env_resumes_collection():
    """merged.final_env honors the RolloutResult contract: env-state
    leaves are tree-concatenated along the env axis (not a python list)
    and resume a rollout at the merged fleet size."""
    from repro.rl.actor_learner import collect, unpack_weights
    env = make("cartpole")
    params = unbox(mlp_ac_init(jax.random.PRNGKey(0), 4, 2))
    packed = pack_weights(params, 8)
    results, states = [], []
    for i in range(2):
        est, obs = init_envs(env, jax.random.PRNGKey(i), 4)
        results.append(collect(packed, env, mlp_ac_apply, FXP8,
                               jax.random.PRNGKey(10 + i), est, obs, 8))
        states.append(results[-1].final_env)
    merged, _ = merge_results(results, jnp.array([True, True]))
    # same tree structure as a batched env state, leaves stacked [8, ...]
    assert (jax.tree.structure(merged.final_env)
            == jax.tree.structure(states[0]))
    for leaf, a, b in zip(jax.tree.leaves(merged.final_env),
                          jax.tree.leaves(states[0]),
                          jax.tree.leaves(states[1]), strict=True):
        assert leaf.shape[0] == 8
        np.testing.assert_array_equal(np.asarray(leaf),
                                      np.concatenate([np.asarray(a),
                                                      np.asarray(b)]))
    # resume: roll the merged fleet onward without any re-reset
    fn = lambda p, o: mlp_ac_apply(p, o, FXP8)
    res = rollout(unpack_weights(packed), env, fn, jax.random.PRNGKey(7),
                  merged.final_env, merged.final_obs, 4)
    assert res.traj.rewards.shape == (4, 8)
    assert np.all(np.isfinite(np.asarray(res.traj.log_probs)))
