"""Per-architecture smoke tests: reduced config, one forward + one
train step + (where defined) one prefill/decode step on CPU; asserts
output shapes and finiteness.  Full configs are dry-run-only."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, get_arch
from repro.core.policy import get_policy
from repro.launch.steps import make_train_step
from repro.models.registry import model_for
from repro.nn.module import count_params, unbox
from repro.optim import adamw_init

POLICY = get_policy("w8a8")

B, S = 2, 32


def batch_for(cfg):
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab, dtype=jnp.int32)
    out = {"tokens": toks, "labels": toks}
    if cfg.is_encdec:
        out["frames"] = jax.random.normal(key, (B, S, cfg.d_model))
    return out


@pytest.fixture(scope="module", params=sorted(ARCHS))
def arch_setup(request):
    cfg = get_arch(request.param).reduced().replace(q_chunk=16)
    model = model_for(cfg)
    params = unbox(model.init(jax.random.PRNGKey(0), cfg))
    return cfg, model, params


def test_forward_shapes_finite(arch_setup):
    cfg, model, params = arch_setup
    batch = batch_for(cfg)
    if cfg.is_encdec:
        enc = model.encode(params, batch["frames"], cfg, POLICY)
        logits = model.decode_train(params, batch["tokens"], enc, cfg,
                                    POLICY)
    else:
        logits = model.forward(params, batch["tokens"], cfg, POLICY)
    assert logits.shape[:2] == (B, S)
    assert logits.shape[2] >= cfg.vocab          # padded vocab allowed
    assert bool(jnp.all(jnp.isfinite(logits)))
    # padded columns are masked to -inf-ish
    if logits.shape[2] > cfg.vocab:
        assert float(logits[..., cfg.vocab:].max()) < -1e8


def test_train_step_reduces_loss_no_nans(arch_setup):
    cfg, model, params = arch_setup
    batch = batch_for(cfg)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, None, POLICY))
    p, o, stats = step(params, opt, batch)
    l0 = float(stats["loss"])
    assert np.isfinite(l0)
    for _ in range(2):
        p, o, stats = step(p, o, batch)
    assert np.isfinite(float(stats["loss"]))
    assert float(stats["loss"]) < l0 + 1.0       # not diverging


def test_prefill_decode_consistency(arch_setup):
    """prefill(x[:S]) then decode_step must agree with forward logits
    (greedy argmax parity on the last position, fp tolerance)."""
    cfg, model, params = arch_setup
    if cfg.is_encdec:
        pytest.skip("encdec covered by its own path below")
    toks = batch_for(cfg)["tokens"]
    logits_f = model.forward(params, toks, cfg, POLICY)
    logits_p, caches = model.prefill(params, toks, cfg, POLICY)
    np.testing.assert_allclose(np.asarray(logits_p),
                               np.asarray(logits_f[:, -1]),
                               rtol=2e-2, atol=2e-2)
    # one decode step from the cache
    nxt = jnp.argmax(logits_p, -1)[:, None].astype(jnp.int32)
    logits_d, caches = model.decode_step(params, nxt, caches,
                                         jnp.asarray(S, jnp.int32),
                                         cfg, POLICY)
    assert logits_d.shape[0] == B
    assert bool(jnp.all(jnp.isfinite(logits_d)))


def test_encdec_prefill_decode():
    cfg = get_arch("whisper-large-v3").reduced().replace(q_chunk=16)
    model = model_for(cfg)
    params = unbox(model.init(jax.random.PRNGKey(0), cfg))
    batch = batch_for(cfg)
    logits_p, caches = model.prefill(params, batch, cfg, POLICY)
    assert bool(jnp.all(jnp.isfinite(logits_p)))
    nxt = jnp.argmax(logits_p, -1)[:, None].astype(jnp.int32)
    logits_d, _ = model.decode_step(params, nxt, caches,
                                    jnp.asarray(S, jnp.int32), cfg,
                                    POLICY)
    assert bool(jnp.all(jnp.isfinite(logits_d)))


def test_param_scale_sanity(arch_setup):
    """Reduced models stay tiny (same code paths, not same size)."""
    cfg, model, params = arch_setup
    n = count_params(params)
    assert n < 20e6, f"{cfg.name}: reduced config too big ({n})"
