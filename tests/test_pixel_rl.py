"""Quantized pixel pipeline tests: the Welford running-norm wrapper,
the Q-Conv actor-critic / Q-head family, and the conv training paths
(catch/keydoor with no flatten_observation).

The Welford carry lives in env state, so it is exercised through the
same jit/vmap/scan machinery as the envs themselves; checkpoint
round-trips ride the value_train env-state capture.
"""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fxp import QTensor
from repro.core.policy import FXP8
from repro.launch.rl_train import (build_env, make_agent,
                                   make_value_agent, rl_train,
                                   value_eval, value_train)
from repro.nn.module import unbox
from repro.rl import init_envs, rollout
from repro.rl.actor_learner import collect, pack_weights, sync_bytes
from repro.rl.dists import distribution_for
from repro.rl.envs import make, wrappers
from repro.rl.envs.spaces import head_dim
from repro.rl.envs.wrappers import (NormStats, init_norm_stats,
                                    merge_norm_stats, norm_stats_of,
                                    pixel_pipeline,
                                    running_normalize_observation,
                                    wrapper_stack)
from repro.rl.nets import (conv_ac_apply, conv_ac_init, conv_flat_dim,
                           conv_q_apply, conv_q_init, conv_qr_apply,
                           conv_qr_init)
from repro.rl.rollout import episode_returns_from

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# Welford running-norm wrapper
# ---------------------------------------------------------------------------

def _paired_stream(T=37, seed=1):
    """Drive the wrapped and the raw env through identical (key, action)
    streams; return (final wrapped state, normalized obs, raw stream
    including the reset observation)."""
    raw = make("catch")
    env = running_normalize_observation(raw)
    key = jax.random.PRNGKey(0)
    s_raw, o_raw = raw.reset(key)
    s, _ = env.reset(key)

    def one(carry, k):
        s, sr = carry
        a = raw.action_space.sample(k)
        s, o, *_ = env.step(s, a)
        sr, orr, *_ = raw.step(sr, a)
        return (s, sr), (o, orr)

    ks = jax.random.split(jax.random.PRNGKey(seed), T)
    (s, _), (obs_n, obs_r) = jax.jit(
        lambda c, k: jax.lax.scan(one, c, k))((s, s_raw), ks)
    stream = jnp.concatenate([o_raw[None], obs_r], axis=0)
    return s, obs_n, stream


def test_welford_matches_stream_moments():
    """The carry reproduces jnp.mean / jnp.std (population) over the
    exact observation stream the wrapper saw."""
    state, _, stream = _paired_stream(T=37)
    stats = norm_stats_of(state)
    assert float(stats.count) == stream.shape[0]
    np.testing.assert_allclose(np.asarray(stats.mean),
                               np.asarray(stream.mean(0)), atol=1e-5)
    np.testing.assert_allclose(np.asarray(stats.std),
                               np.asarray(stream.std(0)), atol=1e-5)


def test_welford_normalized_obs_use_running_stats():
    """Each emitted observation is (raw - mean_t) / (std_t + eps) under
    the stats *including* that observation."""
    state, obs_n, stream = _paired_stream(T=9)
    # recompute the prefix stats at the last step
    mean = stream.mean(0)
    std = stream.std(0)
    np.testing.assert_allclose(
        np.asarray(obs_n[-1]),
        (np.asarray(stream[-1]) - np.asarray(mean))
        / (np.asarray(std) + 1e-8), atol=1e-5)


def test_merge_norm_stats_matches_pooled_moments():
    """Chan-merging per-env carries equals the moments of the pooled
    stream — the eval-freeze path for a vmapped fleet."""
    env = running_normalize_observation(make("catch"))
    n_envs, T = 5, 11
    est, _ = init_envs(env, jax.random.PRNGKey(0), n_envs)

    def one(carry, k):
        est, = carry
        a = jax.vmap(env.action_space.sample)(
            jax.random.split(k, n_envs))
        est, o, *_ = jax.vmap(env.step)(est, a)
        return (est,), a

    ks = jax.random.split(jax.random.PRNGKey(7), T)
    (est,), actions = jax.lax.scan(one, (est,), ks)
    # replay the same per-env streams on the raw env to pool frames
    # (init_envs derives per-env reset keys as split(key, n_envs))
    raw = make("catch")
    keys = jax.random.split(jax.random.PRNGKey(0), n_envs)
    raws = []
    for i in range(n_envs):
        s, o = raw.reset(keys[i])
        raws.append(o)
        for t in range(T):
            s, o, *_ = raw.step(s, actions[t, i])
            raws.append(o)
    pooled = jnp.stack(raws)
    merged = merge_norm_stats(norm_stats_of(est))
    assert float(merged.count) == n_envs * (T + 1)
    np.testing.assert_allclose(np.asarray(merged.mean),
                               np.asarray(pooled.mean(0)), atol=1e-5)
    np.testing.assert_allclose(np.asarray(merged.std),
                               np.asarray(pooled.std(0)), atol=1e-5)


def test_running_norm_frozen_at_eval():
    """stats=NormStats freezes the transform: no carry in the state,
    constant affine normalization, bitwise-stable across steps."""
    raw = make("catch")
    stats = NormStats(jnp.asarray(10.0),
                      jnp.full(raw.obs_shape, 0.25),
                      jnp.full(raw.obs_shape, 10.0 * 0.16))  # std 0.4
    env = running_normalize_observation(raw, stats=stats)
    s, o = env.reset(jax.random.PRNGKey(0))
    assert not isinstance(s, wrappers.RunningNormState)
    _, o_raw = raw.reset(jax.random.PRNGKey(0))
    np.testing.assert_allclose(np.asarray(o),
                               (np.asarray(o_raw) - 0.25) / (0.4 + 1e-8),
                               atol=1e-5)
    with pytest.raises(TypeError, match="carry"):
        norm_stats_of(s)
    # identity fallback: zero-count stats normalize to the raw pixels
    ident = running_normalize_observation(raw,
                                          stats=init_norm_stats(
                                              raw.obs_shape))
    _, oi = ident.reset(jax.random.PRNGKey(0))
    np.testing.assert_allclose(np.asarray(oi), np.asarray(o_raw),
                               atol=1e-6)


def test_running_norm_rejects_frame_stack_order():
    """Stats are defined over raw frames: normalize-then-stack is the
    canonical pixel pipeline, stack-then-normalize a loud error."""
    stacked = wrappers.frame_stack(make("catch"), 4)
    with pytest.raises(ValueError, match="frame_stack second"):
        running_normalize_observation(stacked)
    env = pixel_pipeline(make("catch"), 4)
    assert env.obs_shape == (10, 5, 4)
    assert wrapper_stack(env) == ("frame_stack",
                                  "running_normalize_observation")
    est, obs = init_envs(env, jax.random.PRNGKey(0), 3)
    assert obs.shape == (3, 10, 5, 4)
    # the carry is reachable through the frame-stack state
    assert norm_stats_of(est).count.shape == (3,)
    with pytest.raises(ValueError, match="pixel_pipeline"):
        pixel_pipeline(make("cartpole"), 4)
    with pytest.raises(ValueError, match="k >= 1"):
        pixel_pipeline(make("catch"), 0)


def test_running_norm_resumes_from_checkpoint(tmp_path):
    """The Welford carry rides the value_train checkpoint: a preempted
    conv run relaunched with the same command line continues the stream
    (count = 1 reset + iters * rollout_len per env), never restarts it.
    """
    d = str(tmp_path / "ck")
    kw = dict(env_name="catch", n_envs=4, rollout_len=4,
              updates_per_iter=1, learn_start=8, replay_capacity=512,
              net="conv", frame_stack_k=2, ckpt_dir=d, save_every=2,
              verbose=False, seed=5)
    out1 = {}
    value_train("dqn", iters=3, state_out=out1, **kw)
    c1 = norm_stats_of(out1["env_state"]).count
    np.testing.assert_allclose(np.asarray(c1), 1 + 3 * 4)
    # relaunch with a larger budget: resumes at iter 3 (ckpt at it=2)
    out2 = {}
    params2, hist2 = value_train("dqn", iters=5, state_out=out2, **kw)
    assert len(hist2) == 2                   # exactly iters 3 and 4
    c2 = norm_stats_of(out2["env_state"]).count
    np.testing.assert_allclose(np.asarray(c2), 1 + 5 * 4)
    # greedy eval under the *frozen* merged stats (the eval contract)
    stats = merge_norm_stats(norm_stats_of(out2["env_state"]))
    ret, _ = value_eval("dqn", "catch", params2, n_envs=4, n_steps=16,
                        net="conv", frame_stack_k=2, norm_stats=stats)
    assert np.isfinite(ret)


# ---------------------------------------------------------------------------
# conv net family
# ---------------------------------------------------------------------------

def test_conv_flat_dim_matches_forward():
    for shape in ((10, 5, 1), (10, 5, 4), (32, 32, 3), (32, 32, 12)):
        params = unbox(conv_ac_init(jax.random.PRNGKey(0), shape, 3))
        obs = jnp.zeros((2,) + shape)
        logits, value = conv_ac_apply(params, obs)
        assert logits.shape == (2, 3) and value.shape == (2,)
        assert params["torso"]["fc"]["w"].shape[0] == conv_flat_dim(shape)


def test_conv_qr_head_shape():
    params = unbox(conv_qr_init(jax.random.PRNGKey(0), (10, 5, 2), 3, 8))
    out = conv_qr_apply(params, jnp.zeros((4, 10, 5, 2)), 3, 8)
    assert out.shape == (4, 3, 8)
    q = conv_q_apply(
        unbox(conv_q_init(jax.random.PRNGKey(1), (10, 5, 2), 3)),
        jnp.zeros((4, 10, 5, 2)))
    assert q.shape == (4, 3)


def test_conv_fxp8_forward_parity():
    """Fig. 3a precondition at the net level: the quantized conv stem
    tracks the fp32 forward closely (int8 per-channel grids)."""
    params = unbox(conv_ac_init(jax.random.PRNGKey(0), (10, 5, 4), 3))
    obs = jax.random.uniform(jax.random.PRNGKey(1), (16, 10, 5, 4))
    l32, v32 = conv_ac_apply(params, obs)
    l8, v8 = conv_ac_apply(params, obs, FXP8)
    assert np.all(np.isfinite(np.asarray(l8)))
    scale = float(jnp.abs(l32).max())
    assert float(jnp.abs(l32 - l8).max()) < 0.1 * scale + 0.05
    assert float(jnp.abs(v32 - v8).max()) < 0.1 * float(
        jnp.abs(v32).max()) + 0.05


def test_conv_weights_ship_as_int8():
    """pack_weights quantizes the conv kernels like every matmul weight
    — the behaviour-actor sync carries int8 conv payloads, and the
    sync-MiB accounting reflects the cut."""
    params = unbox(conv_ac_init(jax.random.PRNGKey(0), (32, 32, 12), 4))
    packed = pack_weights(params, 8)
    qs = [l for l in jax.tree.leaves(
        packed, is_leaf=lambda l: isinstance(l, QTensor))
        if isinstance(l, QTensor)]
    # 2 conv kernels + torso fc + pi + v
    assert len(qs) == 5
    assert all(q.qvalue.dtype == jnp.int8 for q in qs)
    assert any(q.qvalue.ndim == 4 for q in qs)      # the conv kernels
    payload, fp32 = sync_bytes(packed)
    assert payload < 0.35 * fp32


def test_conv_quantized_rollout_over_pixel_pipeline():
    """Jitted fxp8 collect over the full pixel stack — the acceptance
    path's inner loop, with no flatten_observation anywhere."""
    env = pixel_pipeline(make("catch"), 2)
    assert "flatten_observation" not in wrapper_stack(env)
    dist = distribution_for(env.action_space)
    params = unbox(conv_ac_init(jax.random.PRNGKey(0), env.obs_shape,
                                head_dim(env.action_space)))
    packed = pack_weights(params, 8)
    est, obs = init_envs(env, jax.random.PRNGKey(1), 4)
    res = jax.jit(lambda p, e, o: collect(
        p, env, conv_ac_apply, FXP8, jax.random.PRNGKey(2), e, o, 8,
        dist))(packed, est, obs)
    assert res.traj.obs.shape == (8, 4, 10, 5, 2)
    assert np.all(np.isfinite(np.asarray(res.traj.log_probs)))


# ---------------------------------------------------------------------------
# conv training drivers (mechanics; the learning floor is the slow test)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ["ppo", "qrdqn"])
@pytest.mark.parametrize("actor_policy", ["fxp8", None])
def test_pixel_agents_train_both_precisions(algo, actor_policy):
    """Acceptance: catch trains 3 iterations under --net conv for the
    on-policy AND value families, fp32 and fxp8, no flatten anywhere."""
    if algo == "ppo":
        params, hist = rl_train("catch", "mlp", iters=3, n_envs=8,
                                rollout_len=16, actor_policy=actor_policy,
                                net="conv", frame_stack_k=4,
                                verbose=False)
    else:
        params, hist = value_train("qrdqn", "catch", iters=3, n_envs=8,
                                   rollout_len=4, updates_per_iter=1,
                                   learn_start=32, replay_capacity=512,
                                   actor_policy=actor_policy, net="conv",
                                   frame_stack_k=4, verbose=False)
    assert len(hist) == 3 and all(np.isfinite(h) for h in hist)
    key0 = jax.random.PRNGKey(0)
    init = (make_agent("mlp", build_env("catch", "conv", 4), key0, None,
                       "conv")[0] if algo == "ppo"
            else make_value_agent("qrdqn",
                                  build_env("catch", "conv", 4).spec,
                                  key0, net="conv").params)
    delta = sum(float(jnp.sum(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(init),
                                jax.tree.leaves(params), strict=True))
    assert delta > 0, "conv params never moved"


def test_keydoor_conv_trains():
    """The 32x32x3 HRL gridworld also reaches the standalone conv stem
    (frame-stacked RGB: first conv takes 12 channels)."""
    _, hist = rl_train("keydoor", "mlp", iters=2, n_envs=4,
                       rollout_len=8, net="conv", frame_stack_k=4,
                       verbose=False)
    assert len(hist) == 2 and all(np.isfinite(h) for h in hist)


def test_build_env_and_net_validation():
    with pytest.raises(ValueError, match="--net conv"):
        build_env("cartpole", "conv", 1)
    with pytest.raises(ValueError, match="requires --net conv"):
        build_env("cartpole", "mlp", 4)
    with pytest.raises(ValueError, match="unknown net"):
        build_env("catch", "resnet", 1)
    with pytest.raises(ValueError, match="requires --net conv"):
        rl_train("cartpole", "mlp", iters=1, frame_stack_k=4,
                 verbose=False)
    with pytest.raises(ValueError, match="drop --net"):
        make_agent("hrl", make("keydoor"), jax.random.PRNGKey(0), None,
                   "conv")
    with pytest.raises(ValueError, match="conv"):
        make_value_agent("ddpg", make("pendulum").spec,
                         jax.random.PRNGKey(0), net="conv")
    # the mlp value nets tell pixel envs where to go
    with pytest.raises(ValueError, match="--net conv"):
        make_value_agent("dqn", make("catch").spec,
                         jax.random.PRNGKey(0), net="mlp")


def test_pixel_cli_dispatch(capsys):
    from repro.launch.rl_train import main
    main(["--algo", "qrdqn", "--env", "catch", "--net", "conv",
          "--frame-stack", "2", "--iters", "2", "--n-envs", "4",
          "--rollout-len", "4", "--learn-start", "16",
          "--replay-capacity", "256"])
    out = capsys.readouterr().out
    assert "qrdqn on catch" in out


@pytest.mark.slow
def test_conv_catch_greedy_eval_floor():
    """End-to-end learning floor: PPO through the quantized conv stem
    clears catch far above the random baseline (~-0.6), and the greedy
    policy evaluated under *frozen* normalizer stats confirms it."""
    out = {}
    params, hist = rl_train("catch", "mlp", iters=15, n_envs=32,
                            rollout_len=64, actor_policy="fxp8",
                            net="conv", frame_stack_k=2, verbose=False,
                            seed=0, state_out=out)
    assert max(hist[-5:]) > 0.2, f"training never took off: {hist[-5:]}"
    stats = merge_norm_stats(norm_stats_of(out["env_state"]))
    env = pixel_pipeline(make("catch"), 2, stats=stats)  # frozen
    est, obs = init_envs(env, jax.random.PRNGKey(123), 16)

    @jax.jit
    def greedy_run(params, est, obs):
        def one(carry, _):
            est, o = carry
            logits, _ = conv_ac_apply(params, o)
            a = jnp.argmax(logits, axis=-1)
            est, nxt, r, d, tr, _ = jax.vmap(env.step)(est, a)
            return (est, nxt), (r, d | tr)

        (_, _), (rews, bounds) = jax.lax.scan(one, (est, obs), None,
                                              length=40)
        return episode_returns_from(rews, bounds)

    ret, n_ep = greedy_run(params, est, obs)
    assert int(n_ep) > 0
    assert float(ret) > 0.3, f"greedy conv agent stuck at {float(ret)}"


# ---------------------------------------------------------------------------
# benchmark regression gate (pure logic — no benches run here)
# ---------------------------------------------------------------------------

def test_check_regression_gate_logic():
    sys.path.insert(0, _ROOT)
    try:
        from benchmarks.check_regression import check
    finally:
        sys.path.remove(_ROOT)
    base = {("t", "a"): {"table": "t", "name": "a", "steps_per_s": 1000,
                         "sync_mib": 0.50},
            ("t", "b"): {"table": "t", "name": "b", "steps_per_s": 400}}
    # within tolerance: half-speed is allowed at 2.0x, sync equal
    cur = {("t", "a"): {"table": "t", "name": "a", "steps_per_s": 501,
                        "sync_mib": 0.50},
           ("t", "b"): {"table": "t", "name": "b", "steps_per_s": 400},
           ("t", "c"): {"table": "t", "name": "c", "steps_per_s": 9}}
    fails, notes = check(cur, base, 2.0, 1.05)
    assert fails == []
    assert any("new row" in n for n in notes)
    # >2x slowdown fails
    slow = {**cur, ("t", "a"): {**cur[("t", "a")], "steps_per_s": 499}}
    fails, _ = check(slow, base, 2.0, 1.05)
    assert len(fails) == 1 and "steps_per_s" in fails[0]
    # sync payload growth fails even when fast
    fat = {**cur, ("t", "a"): {**cur[("t", "a")], "steps_per_s": 2000,
                               "sync_mib": 0.60}}
    fails, _ = check(fat, base, 2.0, 1.05)
    assert len(fails) == 1 and "sync_mib" in fails[0]
    # a dropped bench leg cannot hide a regression
    fails, _ = check({("t", "a"): cur[("t", "a")]}, base, 2.0, 1.05)
    assert len(fails) == 1 and "missing" in fails[0]
    # ...and neither can a dropped sync_mib field
    nofield = {**cur, ("t", "a"): {k: v for k, v in
                                   cur[("t", "a")].items()
                                   if k != "sync_mib"}}
    fails, _ = check(nofield, base, 2.0, 1.05)
    assert len(fails) == 1 and "sync_mib missing" in fails[0]
