"""Optimizer, schedules, clipping, and compressed-collective tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         clip_by_global_norm, compressed_psum_mean,
                         compression_ratio, constant, global_norm,
                         inverse_sqrt, warmup_cosine, zero_nonfinite)


def quad_params():
    return {"w": jnp.array([3.0, -2.0, 1.0]), "b": jnp.array([0.5])}


def test_adamw_reduces_quadratic_loss():
    params = quad_params()
    state = adamw_init(params)
    sched = constant(5e-2)
    cfg = AdamWConfig(weight_decay=0.0)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)

    l0 = loss(params)
    step = jax.jit(lambda p, s: adamw_update(
        jax.grad(loss)(p), s, p, sched, cfg))
    for _ in range(200):
        params, state, _ = step(params, state)
    assert loss(params) < 1e-3 * l0


def test_adamw_weight_decay_shrinks_weights():
    params = {"w": jnp.ones((8,)) * 2.0}
    state = adamw_init(params)
    zero_g = jax.tree.map(jnp.zeros_like, params)
    p, _, _ = adamw_update(zero_g, state, params, constant(1e-2),
                           AdamWConfig(weight_decay=0.5))
    assert float(jnp.max(p["w"])) < 2.0


def test_nonfinite_grads_zeroed_and_flagged():
    g = {"w": jnp.array([1.0, jnp.nan, jnp.inf])}
    cleaned, flag = zero_nonfinite(g)
    assert bool(flag)
    assert np.all(np.isfinite(np.asarray(cleaned["w"])))


def test_clip_by_global_norm():
    g = {"a": jnp.ones((100,)) * 10.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(100.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_schedules_shapes_and_monotone_warmup():
    sched = warmup_cosine(1e-3, 10, 100)
    vals = [float(sched(s)) for s in range(0, 101, 5)]
    assert vals[1] > vals[0]                    # warming up
    assert vals[-1] < max(vals)                 # decayed
    assert float(sched(100)) == pytest.approx(1e-4, rel=1e-2)
    isq = inverse_sqrt(1e-3, 10)
    assert float(isq(40)) == pytest.approx(5e-4, rel=1e-3)


# ---------------------------------------------------------------------------
# compressed collectives (vmap-emulated axis: lax collectives work under
# vmap axis_name, so semantics are tested without multiple devices)
# ---------------------------------------------------------------------------

def _mean_over_axis(g, bits, strategy, error=None):
    e = jnp.zeros_like(g) if error is None else error
    f = lambda gi, ei: compressed_psum_mean(gi, "dp", bits=bits,
                                            error=ei, strategy=strategy)
    return jax.vmap(f, axis_name="dp")(g, e)


@pytest.mark.parametrize("strategy", ["gather", "psum"])
@pytest.mark.parametrize("bits", [8, 16])
def test_compressed_mean_close_to_exact(bits, strategy):
    g = jax.random.normal(jax.random.PRNGKey(0), (8, 128))
    mean, _ = _mean_over_axis(g, bits, strategy)
    exact = jnp.mean(g, axis=0)
    tol = 4.0 / (2 ** (bits - 1))   # few LSBs of the shared-scale grid
    np.testing.assert_allclose(np.asarray(mean[0]), np.asarray(exact),
                               atol=tol * float(jnp.max(jnp.abs(g))))


def test_bits32_is_exact():
    g = jax.random.normal(jax.random.PRNGKey(1), (4, 32))
    mean, _ = _mean_over_axis(g, 32, "gather")
    np.testing.assert_allclose(np.asarray(mean[0]),
                               np.asarray(jnp.mean(g, axis=0)), rtol=1e-6)


def test_error_feedback_recovers_bias():
    """Repeated compression of a CONSTANT gradient: with error feedback
    the time-average of the estimates converges to the true value."""
    g = jax.random.normal(jax.random.PRNGKey(2), (4, 64)) * 0.1
    err = jnp.zeros_like(g)
    acc = jnp.zeros((64,))
    T = 50
    for _ in range(T):
        mean, err = _mean_over_axis(g, 8, "gather", err)
        acc = acc + mean[0]
    exact = jnp.mean(g, axis=0)
    np.testing.assert_allclose(np.asarray(acc / T), np.asarray(exact),
                               atol=5e-4)


def test_compression_ratio_math():
    assert compression_ratio(32, 4) == 1.0
    # n=2 pods, int8 all-gather: 1 byte vs 2*4*(1/2)=4 bytes -> 0.25
    assert compression_ratio(8, 2, "gather") == pytest.approx(0.25)
    assert compression_ratio(8, 16, "psum") == pytest.approx(1.0)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([8, 16]))
def test_compression_error_bounded_by_grid(seed, bits):
    """|mean_est - mean| <= n_dev LSBs of the shared grid (1 round,
    zero error buffer): quantization error per device is <= scale/2."""
    g = jax.random.normal(jax.random.PRNGKey(seed), (4, 16))
    mean, _ = _mean_over_axis(g, bits, "gather")
    exact = jnp.mean(g, axis=0)
    qmax = float(2 ** (bits - 1) - 1)
    scale = float(jnp.max(jnp.abs(g))) / qmax
    assert float(jnp.max(jnp.abs(mean[0] - exact))) <= scale * 0.5 + 1e-7
