"""Soft dependency shim for `hypothesis`.

The property tests are kept when hypothesis is installed; without it
they are collected but individually skipped (via a stub ``@given``)
instead of failing the whole module at import time — so
``pytest -x -q`` always reaches the rest of the suite.

Usage in a test module:

    from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    try:
        from hypothesis.extra import numpy as hnp
    except ImportError:        # hypothesis without the numpy extra
        hnp = None
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for `strategies`/`extra.numpy`: every attribute is
        a callable returning None, enough for module-level strategy
        construction in skipped tests."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()
    hnp = _AnyStrategy()

    def settings(*a, **k):
        return lambda fn: fn

    def given(*a, **k):
        def deco(fn):
            # zero-arg replacement (the original's params are hypothesis
            # strategies, not fixtures) that skips at run time
            def skipped():
                pytest.skip("hypothesis not installed")
            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped
        return deco


def require_hypothesis():
    """`pytest.importorskip` equivalent for use inside fixtures."""
    pytest.importorskip("hypothesis")
