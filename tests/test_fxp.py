"""Property tests (hypothesis) + unit tests for the quantization core."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st, hnp

from repro.core import (FXP8, FXP16, FP32, W8, W8A8, QTensor, QuantPolicy,
                        dequantize, fake_quant, q_matmul, quantize,
                        quantize_eq1)
from repro.core.fxp import absmax_scale, fxp_qmax

finite_f32 = st.floats(min_value=-1e4, max_value=1e4, width=32,
                       allow_nan=False, allow_infinity=False)


def arrays(shape):
    return hnp.arrays(np.float32, shape, elements=finite_f32)


@settings(max_examples=50, deadline=None)
@given(arrays((17, 9)), st.sampled_from([8, 16]))
def test_quant_dequant_error_bound(x, bits):
    """|x - deq(quant(x))| <= scale/2 elementwise (uniform grid)."""
    x = jnp.asarray(x)
    q, s = quantize(x, bits)
    err = jnp.abs(dequantize(q, s) - x)
    assert bool(jnp.all(err <= jnp.squeeze(s) * 0.5 + 1e-6))


@settings(max_examples=50, deadline=None)
@given(arrays((8, 16)), st.sampled_from([8, 16]))
def test_fake_quant_idempotent(x, bits):
    """fake_quant is a projection: applying twice == applying once."""
    x = jnp.asarray(x)
    once = fake_quant(x, bits)
    twice = fake_quant(once, bits)
    np.testing.assert_allclose(np.asarray(once), np.asarray(twice),
                               rtol=1e-6, atol=1e-7)


@settings(max_examples=30, deadline=None)
@given(arrays((4, 8)))
def test_ste_gradient_is_identity(x):
    x = jnp.asarray(x)
    g = jax.grad(lambda v: fake_quant(v, 8).sum())(x)
    np.testing.assert_allclose(np.asarray(g), 1.0)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 30), st.integers(1, 30))
def test_qmatmul_backends_agree(m, n):
    """ref and xla backends produce the same quantized product."""
    x = jax.random.normal(jax.random.PRNGKey(m), (m, 16))
    w = jax.random.normal(jax.random.PRNGKey(n + 100), (16, n)) * 0.1
    a = q_matmul(x, w, W8A8.with_backend("ref"))
    b = q_matmul(x, w, W8A8.with_backend("xla"))
    # identical grids; differences only from fp accumulation order
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=5e-3, atol=5e-4)


def test_quantize_eq1_matches_paper_form():
    """Eq (1): grid step = (|min(W,0)|+|max(W,0)|) / 2^n."""
    w = jnp.array([[-2.0, 1.0], [0.5, -0.25]])
    q, s = quantize_eq1(w, n=8)
    assert abs(float(s) - 3.0 / 256.0) < 1e-9
    np.testing.assert_allclose(np.asarray(q * s), np.asarray(w),
                               atol=float(s) / 2 + 1e-9)


def test_per_channel_beats_per_tensor():
    """Per-channel scales must not increase worst-case error."""
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (64, 32)) * jnp.logspace(-2, 0, 32)
    q_pc, s_pc = quantize(w, 8, channel_axis=1)
    q_pt, s_pt = quantize(w, 8, channel_axis=None)
    err_pc = float(jnp.abs(dequantize(q_pc, s_pc) - w).max())
    err_pt = float(jnp.abs(dequantize(q_pt, s_pt) - w).max())
    assert err_pc <= err_pt + 1e-7


def test_qtensor_roundtrip_and_bytes():
    w = jax.random.normal(jax.random.PRNGKey(1), (128, 64))
    qt = QTensor.quant(w, 8, channel_axis=1)
    assert qt.qvalue.dtype == jnp.int8
    rel = float(jnp.abs(qt.deq() - w).max() / jnp.abs(w).max())
    assert rel < 0.02
    # pytree round trip (jit boundary)
    out = jax.jit(lambda t: t.deq())(qt)
    np.testing.assert_allclose(np.asarray(out), np.asarray(qt.deq()))


def test_fp32_policy_is_exact():
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 8))
    w = jax.random.normal(jax.random.PRNGKey(1), (8, 8))
    np.testing.assert_allclose(np.asarray(q_matmul(x, w, FP32)),
                               np.asarray(x @ w), rtol=1e-6)


def test_grad_flows_through_all_policies():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 16))
    w = jax.random.normal(jax.random.PRNGKey(1), (16, 8)) * 0.1
    for pol in [FP32, FXP8, FXP16, W8, W8A8]:
        gx, gw = jax.grad(
            lambda x, w, pol=pol: q_matmul(x, w, pol).sum(),
            argnums=(0, 1))(x, w)
        assert bool(jnp.isfinite(gx).all() and jnp.isfinite(gw).all()), pol
        assert float(jnp.abs(gw).max()) > 0
