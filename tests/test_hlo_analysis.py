"""HLO cost-model unit tests on hand-written module text + a live
lowering cross-check against XLA's aggregate on a while-free graph."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_analysis as H

SYNTH = """\
HloModule synth

%scalar_add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

%fused_elem (param_0.1: f32[8,16], param_1.1: f32[8,16]) -> f32[8,16] {
  %param_0.1 = f32[8,16] parameter(0)
  %param_1.1 = f32[8,16] parameter(1)
  ROOT %m = f32[8,16] multiply(%param_0.1, %param_1.1)
}

%fused_slice (param_0.2: f32[10,8,16], param_1.2: s32[]) -> f32[8,16] {
  %param_0.2 = f32[10,8,16] parameter(0)
  %param_1.2 = s32[] parameter(1)
  %c0 = s32[] constant(0)
  %ds = f32[1,8,16] dynamic-slice(%param_0.2, %param_1.2, %c0, %c0), dynamic_slice_sizes={1,8,16}
  ROOT %r2 = f32[8,16] reshape(%ds)
}

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16] get-tuple-element(%p), index=1
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  %w = f32[16,16] constant({...})
  %d = f32[8,16] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[8,16]) tuple(%i2, %d)
}

%cond (p2: (s32[], f32[8,16])) -> pred[] {
  %p2 = (s32[], f32[8,16]) parameter(0)
  %i3 = s32[] get-tuple-element(%p2), index=0
  %lim = s32[] constant(5)
  ROOT %lt = pred[] compare(%i3, %lim), direction=LT
}

ENTRY %main (arg0: f32[8,16], arg1: f32[8,16], arg2: f32[10,8,16]) -> f32[8,16] {
  %arg0 = f32[8,16] parameter(0)
  %arg1 = f32[8,16] parameter(1)
  %arg2 = f32[10,8,16] parameter(2)
  %f1 = f32[8,16] fusion(%arg0, %arg1), kind=kLoop, calls=%fused_elem
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,16]) tuple(%zero, %f1)
  %loop = (s32[], f32[8,16]) while(%init), condition=%cond, body=%body
  %out = f32[8,16] get-tuple-element(%loop), index=1
  %idx = s32[] constant(3)
  %f2 = f32[8,16] fusion(%arg2, %idx), kind=kLoop, calls=%fused_slice
  %q = s8[8,16] convert(%f2)
  %qd = s32[8,8] dot(%q, %q), lhs_contracting_dims={1}, rhs_contracting_dims={1}
  %qdf = f32[8,8] convert(%qd)
  %pad = f32[8,8] all-reduce(%qdf), replica_groups={}, to_apply=%scalar_add
  ROOT %sum = f32[8,16] add(%out, %out)
}
"""


def test_parse_module_finds_computations():
    comps = H.parse_module(SYNTH)
    assert {"scalar_add", "fused_elem", "fused_slice", "body", "cond",
            "main"} <= set(comps)
    assert len(comps["main"].ops) >= 8
    assert comps["fused_slice"].params == ["param_0.2", "param_1.2"]


def test_trip_count_from_condition():
    comps = H.parse_module(SYNTH)
    assert H._trip_count(comps["cond"]) == 5


def test_flops_scaled_by_trip_count():
    cm = H.CostModel(SYNTH)
    t = cm.totals()
    # while dot: 2*8*16*16 per iter x 5 trips
    assert t["flops"] == pytest.approx(2 * 8 * 16 * 16 * 5)
    # int8 dot: 2*8*8*16, counted as int_ops not flops
    assert t["int_ops"] == pytest.approx(2 * 8 * 8 * 16)


def test_collective_bytes_all_reduce_doubled():
    cm = H.CostModel(SYNTH)
    t = cm.totals()
    assert t["all-reduce"] == pytest.approx(2 * 8 * 8 * 4)
    assert t["collective_bytes"] == t["all-reduce"]


def test_fusion_bytes_boundary_and_slice_aware():
    cm = H.CostModel(SYNTH)
    comps = cm.comps
    main = comps["main"]
    f1 = next(o for o in main.ops if o.name == "f1")
    # elementwise fusion: 2 inputs + 1 output, all 8x16 f32
    assert cm._op_bytes(f1, main) == pytest.approx(3 * 8 * 16 * 4)
    f2 = next(o for o in main.ops if o.name == "f2")
    # slicing fusion: big operand charged at slice size (1x8x16), not
    # the full 10x8x16 stack
    b = cm._op_bytes(f2, main)
    assert b <= (1 * 8 * 16 * 4) + 4 + (8 * 16 * 4) + 1


def test_dynamic_slice_top_level():
    comps = H.parse_module(SYNTH)
    fs = comps["fused_slice"]
    ds = next(o for o in fs.ops if o.opcode == "dynamic-slice")
    cm = H.CostModel(SYNTH)
    assert cm._op_bytes(ds, fs) == pytest.approx(2 * 1 * 8 * 16 * 4)


def test_live_crosscheck_against_xla():
    """On a while-free jit, our totals track XLA's within 15%."""
    def f(a, b):
        return jnp.tanh(a @ b) @ b

    a = jnp.ones((64, 64))
    b = jnp.ones((64, 64))
    c = jax.jit(f).lower(a, b).compile()
    mine = H.cost_terms(c)
    assert mine["flops"] == pytest.approx(mine["xla_flops_1trip"],
                                          rel=0.15)
    assert mine["bytes"] == pytest.approx(mine["xla_bytes_1trip"],
                                          rel=0.3)


def test_memory_stats_fields():
    c = jax.jit(lambda x: x * 2).lower(jnp.ones((8, 8))).compile()
    m = H.memory_stats(c)
    assert m["total_bytes"] > 0
    assert "temp_size_in_bytes" in m


def test_op_histogram():
    h = H.op_histogram(SYNTH)
    assert h["while"] == 1
    assert h["dot"] == 2
    assert h["all-reduce"] == 1


# ---------------------------------------------------------------------------
# quantized data-path costing: the qmac (int8 MXU dot) and qconv paths
# ---------------------------------------------------------------------------

QMAC_SYNTH = """\
HloModule qmac

ENTRY %main (x: s8[16,32], w: s8[32,24]) -> f32[16,24] {
  %x = s8[16,32] parameter(0)
  %w = s8[32,24] parameter(1)
  %acc = s32[16,24] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %deq = f32[16,24] convert(%acc)
}
"""

QCONV_SYNTH = """\
HloModule qconv

ENTRY %main (x: f32[2,8,8,3], w: f32[3,3,3,8]) -> f32[2,4,4,8] {
  %x = f32[2,8,8,3] parameter(0)
  %w = f32[3,3,3,8] parameter(1)
  ROOT %c = f32[2,4,4,8] convolution(%x, %w), window={size=3x3 stride=2x2 pad=1_1x1_1}, dim_labels=b01f_01io->b01f
}
"""


def test_qmac_synthetic_int_dot_counted_as_int_ops():
    t = H.CostModel(QMAC_SYNTH).totals()
    # 2 * M*N * K on the s32-accumulating int8 dot, none as fp flops
    assert t["int_ops"] == 2 * 16 * 24 * 32
    assert t["flops"] == 0.0
    # operand + output traffic: s8 inputs, s32 acc, f32 out
    assert t["bytes"] >= 16 * 32 + 32 * 24 + 16 * 24 * 4


def test_qconv_synthetic_flops_from_kernel_volume():
    t = H.CostModel(QCONV_SYNTH).totals()
    # 2 * out_elems * (kh * kw * c_in)
    assert t["flops"] == 2 * (2 * 4 * 4 * 8) * (3 * 3 * 3)
    assert t["int_ops"] == 0.0


def test_qmac_live_w8a8_routes_to_int_ops():
    from repro.core import W8, W8A8
    from repro.core.qmatmul import q_matmul

    x = jax.random.normal(jax.random.PRNGKey(0), (16, 32))
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 24)) * 0.1
    c = jax.jit(lambda x, w: q_matmul(x, w, W8A8)).lower(x, w).compile()
    t = H.cost_terms(c)
    # the contraction runs on the int8 path: counted as int_ops, and
    # no fp dot appears anywhere in the program
    assert t["int_ops"] == 2 * 16 * 24 * 32
    assert t["flops"] == 0.0
    assert t["bytes"] > 0

    # weight-only serving (W8) dequantizes and uses the fp dot
    cw = jax.jit(lambda x, w: q_matmul(x, w, W8)).lower(x, w).compile()
    tw = H.cost_terms(cw)
    assert tw["flops"] == 2 * 16 * 24 * 32
    assert tw["int_ops"] == 0.0


def test_qconv_live_block_flops_and_bytes():
    from repro.core import W8
    from repro.nn.conv import conv2d_init, qconv_block
    from repro.nn.module import unbox

    p = unbox(conv2d_init(jax.random.PRNGKey(2), 3, 8, 3))
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 8, 8, 3))
    c = jax.jit(lambda p, x: qconv_block(p, x, stride=2,
                                         policy=W8)).lower(p, x).compile()
    t = H.cost_terms(c)
    # stride-2 SAME conv: [2,8,8,3] -> [2,4,4,8], kernel volume 3*3*3
    assert t["flops"] == 2 * (2 * 4 * 4 * 8) * (3 * 3 * 3)
    assert t["int_ops"] == 0.0
    # at least the conv boundary traffic (inputs + weights + output)
    assert t["bytes"] >= (2 * 8 * 8 * 3 + 3 * 3 * 3 * 8
                          + 2 * 4 * 4 * 8) * 4
