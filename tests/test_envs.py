"""Conformance suite for the typed environment API.

Every registered env must honour the same contract: spec-accurate
shapes/dtypes, deterministic reset, jit purity, vmap batching,
auto-reset on done, and a jitted rollout under the FxP8 quantized
actor policy.  Wrapper and registry semantics are covered at the end.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policy import FXP8
from repro.nn.module import unbox
from repro.rl import init_envs, rollout
from repro.rl.actor_learner import collect, pack_weights
from repro.rl.dists import distribution_for
from repro.rl.envs import (Box, Discrete, Environment, make, register,
                           registered, wrappers)
from repro.rl.envs.spaces import head_dim
from repro.rl.nets import mlp_ac_apply, mlp_ac_init

ALL_ENVS = registered()


def _vectorized(env: Environment) -> Environment:
    """MLP-policy view: ravel image observations."""
    return wrappers.ensure_vector_obs(env)


# ---------------------------------------------------------------------------
# per-env contract
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ALL_ENVS)
def test_spec_contract(name):
    env = make(name)
    assert isinstance(env, Environment)
    assert env.spec.name == name
    assert isinstance(env.observation_space, Box)
    assert isinstance(env.action_space, (Box, Discrete))
    assert env.spec.max_steps > 0
    assert len(env.obs_shape) >= 1


@pytest.mark.parametrize("name", ALL_ENVS)
def test_reset_step_shapes_and_dtypes(name):
    env = make(name)
    obs_space, act_space = env.observation_space, env.action_space
    state, obs = env.reset(jax.random.PRNGKey(0))
    assert obs.shape == obs_space.shape
    assert obs.dtype == obs_space.dtype
    action = act_space.sample(jax.random.PRNGKey(1))
    assert action.shape == act_space.shape

    state, obs2, reward, done, truncated, final_obs = \
        env.step(state, action)
    assert obs2.shape == obs_space.shape
    assert obs2.dtype == obs_space.dtype
    assert reward.shape == () and reward.dtype == jnp.float32
    assert done.shape == () and done.dtype == jnp.bool_
    assert truncated.shape == () and truncated.dtype == jnp.bool_
    assert final_obs.shape == obs_space.shape
    assert final_obs.dtype == obs_space.dtype
    assert bool(obs_space.contains(obs2))


@pytest.mark.parametrize("name", ALL_ENVS)
def test_determinism_and_jit_purity(name):
    env = make(name)
    action = env.action_space.sample(jax.random.PRNGKey(1))
    s1, o1 = env.reset(jax.random.PRNGKey(0))
    s2, o2 = env.reset(jax.random.PRNGKey(0))
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2))

    _, eo, er, ed, et, ef = env.step(s1, action)
    _, jo, jr, jd, jt, jf = jax.jit(env.step)(s2, action)
    np.testing.assert_allclose(np.asarray(eo), np.asarray(jo),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(ef), np.asarray(jf),
                               rtol=1e-5, atol=1e-6)
    assert float(er) == pytest.approx(float(jr), rel=1e-5)
    assert bool(ed) == bool(jd) and bool(et) == bool(jt)


@pytest.mark.parametrize("name", ALL_ENVS)
def test_vmap_batching(name):
    env = make(name)
    n = 5
    state, obs = init_envs(env, jax.random.PRNGKey(0), n)
    assert obs.shape == (n,) + env.obs_shape
    keys = jax.random.split(jax.random.PRNGKey(1), n)
    actions = jax.vmap(env.action_space.sample)(keys)
    state, obs, reward, done, truncated, final_obs = \
        jax.jit(jax.vmap(env.step))(state, actions)
    assert obs.shape == (n,) + env.obs_shape
    assert final_obs.shape == (n,) + env.obs_shape
    assert reward.shape == (n,) and done.shape == (n,)
    assert truncated.shape == (n,)


@pytest.mark.parametrize("name", ALL_ENVS)
def test_auto_reset_semantics(name):
    """Within max_steps+1 random steps at least one episode boundary
    (termination OR truncation) occurs, and the state returned by every
    boundary transition is a fresh episode (step counter back to
    zero)."""
    env = make(name)
    T = env.spec.max_steps + 1
    s0, _ = env.reset(jax.random.PRNGKey(0))

    def one(state, key):
        action = env.action_space.sample(key)
        state, _, _, done, truncated, _ = env.step(state, action)
        return state, (done | truncated, state.t)

    keys = jax.random.split(jax.random.PRNGKey(1), T)
    _, (bounds, ts) = jax.jit(
        lambda s, k: jax.lax.scan(one, s, k))(s0, keys)
    bounds, ts = np.asarray(bounds), np.asarray(ts)
    assert bounds.any(), f"{name}: no episode ended in {T} steps"
    assert (ts[bounds] == 0).all(), \
        f"{name}: boundary transition did not return a fresh episode"


@pytest.mark.parametrize("name", ALL_ENVS)
def test_termination_truncation_contract(name):
    """done and truncated are mutually exclusive, final_obs equals obs
    off-boundary, and the pure time limit reports truncated — never
    done — so value targets can bootstrap through it."""
    env = make(name)
    T = env.spec.max_steps + 1
    s0, _ = env.reset(jax.random.PRNGKey(0))

    def one(state, key):
        action = env.action_space.sample(key)
        state, obs, _, done, truncated, final_obs = \
            env.step(state, action)
        off = ~(done | truncated)
        same = jnp.all(jnp.abs(obs - final_obs) == 0.0) | ~off
        return state, (done, truncated, same)

    keys = jax.random.split(jax.random.PRNGKey(1), T)
    _, (dones, truncs, same) = jax.jit(
        lambda s, k: jax.lax.scan(one, s, k))(s0, keys)
    dones, truncs = np.asarray(dones), np.asarray(truncs)
    assert not (dones & truncs).any(), \
        f"{name}: a step reported done AND truncated"
    assert np.asarray(same).all(), \
        f"{name}: final_obs differed from obs off-boundary"
    if name == "pendulum":       # pure time-limit env: never terminates
        assert not dones.any() and truncs.any()


@pytest.mark.parametrize("name", ALL_ENVS)
def test_quantized_actor_rollout(name):
    """Smoke rollout under the fxp8 actor policy with int8-packed
    weights — any registered env, one shared rollout path."""
    env = _vectorized(make(name))
    dist = distribution_for(env.action_space)
    params = unbox(mlp_ac_init(jax.random.PRNGKey(0), env.obs_shape[0],
                               head_dim(env.action_space), hidden=32))
    packed = pack_weights(params, 8)
    est, obs = init_envs(env, jax.random.PRNGKey(1), 4)
    res = jax.jit(lambda p, e, o: collect(
        p, env, mlp_ac_apply, FXP8, jax.random.PRNGKey(2), e, o, 8,
        dist))(packed, est, obs)
    assert res.traj.rewards.shape == (8, 4)
    assert np.all(np.isfinite(np.asarray(res.traj.log_probs)))
    acts = res.traj.actions.reshape((-1,) + env.action_space.shape)
    assert bool(jnp.all(env.action_space.contains(acts)))


def test_pendulum_is_continuous():
    env = make("pendulum")
    assert env.spec.continuous
    assert isinstance(env.action_space, Box)
    assert env.action_space.shape == (1,)
    with pytest.raises(TypeError):
        env.spec.n_actions


# ---------------------------------------------------------------------------
# wrappers
# ---------------------------------------------------------------------------

def test_flatten_observation():
    env = wrappers.flatten_observation(make("catch"))
    assert env.obs_shape == (50,)
    _, obs = env.reset(jax.random.PRNGKey(0))
    assert obs.shape == (50,)


def test_normalize_observation_affine():
    base = make("cartpole")
    env = wrappers.normalize_observation(base, 1.0, 2.0)
    _, raw = base.reset(jax.random.PRNGKey(0))
    _, nrm = env.reset(jax.random.PRNGKey(0))
    np.testing.assert_allclose(np.asarray(nrm), (np.asarray(raw) - 1) / 2,
                               rtol=1e-6)


def test_normalize_observation_array_stats_keep_finite_bounds():
    """Obs-shaped mean/std must not collapse a bounded space to
    Box(-inf, inf): the bounds are transformed elementwise and the
    tightest enclosing interval kept (finite, and still containing
    every normalized observation)."""
    base = make("mountain_car")              # Box(-1.2, 0.6, (2,))
    mean = np.array([-0.3, 0.0], np.float32)
    std = np.array([0.9, 0.035], np.float32)
    env = wrappers.normalize_observation(base, mean, std)
    space = env.observation_space
    assert space.bounded, "array stats collapsed the space to inf bounds"
    lo = (np.array([base.observation_space.low] * 2) - mean) / std
    hi = (np.array([base.observation_space.high] * 2) - mean) / std
    assert space.low == pytest.approx(float(np.minimum(lo, hi).min()))
    assert space.high == pytest.approx(float(np.maximum(lo, hi).max()))
    _, obs = env.reset(jax.random.PRNGKey(0))
    assert bool(space.contains(obs))
    # a negative std flips the interval per element; bounds stay ordered
    env2 = wrappers.normalize_observation(base, 0.0,
                                          np.array([-1.0, 1.0], np.float32))
    assert env2.observation_space.bounded
    assert env2.observation_space.low < env2.observation_space.high
    with pytest.raises(ValueError, match="non-zero"):
        wrappers.normalize_observation(base, 0.0,
                                       np.array([1.0, 0.0], np.float32))


def test_scale_reward():
    base = make("cartpole")            # reward is +1 per step
    env = wrappers.scale_reward(base, 0.25)
    s, _ = env.reset(jax.random.PRNGKey(0))
    _, _, r, _, _, _ = env.step(s, jnp.asarray(0))
    assert float(r) == pytest.approx(0.25)


def test_time_limit_truncates_and_force_resets():
    env = wrappers.time_limit(make("pendulum"), 5)   # inner horizon 200
    assert env.spec.max_steps == 5
    s, _ = env.reset(jax.random.PRNGKey(0))
    step = jax.jit(env.step)
    for _ in range(5):
        s, obs, r, d, tr, final_obs = step(s, jnp.zeros((1,)))
    # a pure timeout is TRUNCATED, never folded into done
    assert bool(tr), "episode must truncate at the wrapper limit"
    assert not bool(d), "a pure timeout must not report done"
    assert int(s.t) == 0 and int(s.inner.t) == 0   # forced inner reset
    assert bool(env.observation_space.contains(obs))
    # final_obs is the pre-reset observation, not the fresh episode's
    assert not np.allclose(np.asarray(final_obs), np.asarray(obs))


def test_frame_stack_shape_and_episode_boundary():
    k = 4
    env = wrappers.frame_stack(make("catch"), k)
    assert env.obs_shape == (10, 5, k)
    s, obs = env.reset(jax.random.PRNGKey(0))
    # initial buffer: all frames identical
    f = np.asarray(obs)
    for i in range(1, k):
        np.testing.assert_array_equal(f[..., 0], f[..., i])
    step = jax.jit(env.step)
    done = False
    for _ in range(12):                # catch ends within 10 steps
        s, obs, r, d, tr, _ = step(s, jnp.asarray(1))
        if bool(d | tr):
            done = True
            break
    assert done
    # post-done buffer refilled with the fresh episode's first frame
    f = np.asarray(obs)
    for i in range(1, k):
        np.testing.assert_array_equal(f[..., 0], f[..., i])


def test_frame_stack_vector_env():
    env = wrappers.frame_stack(make("cartpole"), 3)
    assert env.obs_shape == (12,)
    _, obs = env.reset(jax.random.PRNGKey(0))
    assert obs.shape == (12,)


def test_wrapped_env_rolls_under_rollout():
    env = wrappers.frame_stack(
        wrappers.normalize_observation(
            wrappers.flatten_observation(make("catch")), 0.5, 0.5), 2)
    params = unbox(mlp_ac_init(jax.random.PRNGKey(0), env.obs_shape[0],
                               head_dim(env.action_space), hidden=16))
    est, obs = init_envs(env, jax.random.PRNGKey(1), 3)
    res = jax.jit(lambda p, e, o: rollout(
        p, env, mlp_ac_apply, jax.random.PRNGKey(2), e, o,
        12))(params, est, obs)
    assert res.traj.obs.shape == (12, 3, 100)
    assert np.all(np.isfinite(np.asarray(res.traj.log_probs)))


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_rejects_duplicates_and_unknown():
    with pytest.raises(ValueError, match="already registered"):
        register("cartpole", make)
    with pytest.raises(ValueError, match="registered:"):
        make("not-an-env")


def test_registry_overwrite_and_kwargs():
    from repro.rl.envs import cartpole as cp

    calls = {}

    def factory(max_steps=123):
        calls["max_steps"] = max_steps
        return cp.make()

    register("_test_env", factory)
    try:
        env = make("_test_env", max_steps=7)
        assert calls["max_steps"] == 7
        assert isinstance(env, Environment)
        register("_test_env", cp.make, overwrite=True)
    finally:
        from repro.rl.envs import registry
        registry._REGISTRY.pop("_test_env", None)
