"""Q-MAC Pallas kernel vs pure-jnp oracle: shape sweeps, exactness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.qmac import ops, ref

SHAPES = [
    (128, 128, 128),
    (256, 512, 384),
    (8, 8, 8),
    (64, 100, 72),      # non-multiple K/N
    (33, 17, 9),        # tiny odd shapes (padding path)
    (1, 256, 256),      # single row (decode-like)
    (512, 32, 1024),
]


def _rand_i8(key, shape):
    return jax.random.randint(key, shape, -128, 128, dtype=jnp.int8)


@pytest.mark.parametrize("m,k,n", SHAPES)
def test_qmac_i8_exact(m, k, n):
    k1, k2 = jax.random.split(jax.random.PRNGKey(m * 7 + n))
    qx, qw = _rand_i8(k1, (m, k)), _rand_i8(k2, (k, n))
    out = ops.qmac_i8(qx, qw)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.qmac_i8(qx, qw)))
    assert out.dtype == jnp.int32


@pytest.mark.parametrize("m,k,n", SHAPES)
def test_qmac_i8_deq(m, k, n):
    key = jax.random.PRNGKey(m + k + n)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    qx, qw = _rand_i8(k1, (m, k)), _rand_i8(k2, (k, n))
    sx = jax.random.uniform(k3, (m, 1), minval=1e-3, maxval=0.1)
    sw = jax.random.uniform(k4, (1, n), minval=1e-3, maxval=0.1)
    out = ops.qmac_i8_deq(qx, sx, qw, sw)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.qmac_i8_deq(qx, sx, qw, sw)),
                               rtol=1e-6)
    assert out.dtype == jnp.float32


@pytest.mark.parametrize("bm,bn,bk", [(8, 8, 8), (32, 64, 16),
                                      (128, 128, 128)])
def test_qmac_block_shape_invariance(bm, bn, bk):
    """Result must not depend on the chosen BlockSpec tiling."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    qx, qw = _rand_i8(k1, (128, 128)), _rand_i8(k2, (128, 128))
    out = ops.qmac_i8(qx, qw, bm=bm, bn=bn, bk=bk)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.qmac_i8(qx, qw)))


def test_qmac_extreme_values_no_overflow():
    """Worst case |acc| = K * 127 * 128 must fit int32 (K <= 131072)."""
    qx = jnp.full((8, 2048), 127, jnp.int8)
    qw = jnp.full((2048, 8), -128, jnp.int8)
    out = ops.qmac_i8(qx, qw)
    assert int(out[0, 0]) == 2048 * 127 * (-128)


def test_qmac_matches_fp_product_within_quant_error():
    """End-to-end: quantize fp operands, Q-MAC, dequant ~= fp matmul."""
    from repro.core.qmatmul import quantize_rowwise
    from repro.core.fxp import quantize
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (64, 256))
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 128)) * 0.05
    qx, sx = quantize_rowwise(x, 8)
    qw, sw = quantize(w, 8, channel_axis=1)
    out = ops.qmac_i8_deq(qx, sx, qw, sw.reshape(1, -1))
    rel = float(jnp.abs(out - x @ w).max() / jnp.abs(x @ w).max())
    assert rel < 0.02, rel
