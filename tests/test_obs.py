"""Observability subsystem tests: jit-safe metric buffers, fixed-bucket
histograms, the obs/v1 JSONL schema, and the load-bearing contract that
instrumented training is bitwise identical to uninstrumented training
(docs/observability.md)."""
import importlib.util
import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.obs import (LATENCY_EDGES_S, FixedHistogram, JsonlSink,
                       MetricSpec, SpanClock, counter_add, flush,
                       gauge_max, gauge_set, hist_observe, log_edges,
                       read_records, render, summarize, summarize_file,
                       validate_record)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        bool(jnp.array_equal(x, y)) for x, y in zip(la, lb))


def _step_records(path):
    return [r for r in read_records(path) if r["kind"] == "step"]


def _assert_contiguous(windows, lo, hi):
    assert windows[0][0] == lo and windows[-1][1] == hi
    for (a, b), (c, d) in zip(windows, windows[1:]):
        assert b == c, f"gap between windows {[a, b]} and {[c, d]}"


# ---------------------------------------------------------------------------
# MetricBuffer: jit-safe ops, 32-bit dtypes, flush semantics
# ---------------------------------------------------------------------------


def test_metric_buffer_ops_under_jit_and_flush_resets():
    spec = MetricSpec(counters=("steps",), gauges=("ret", "peak"),
                      hists=(("lat", (0.1, 1.0, 10.0)),))

    @jax.jit
    def update(buf, x):
        buf = counter_add(buf, "steps", 4)
        buf = gauge_set(buf, "ret", x)
        buf = gauge_max(buf, "peak", x)
        buf = hist_observe(spec, buf, "lat",
                           jnp.array([0.05, 0.5, 5.0, 50.0]))
        return buf

    buf = spec.init()
    buf = update(buf, jnp.float32(2.5))
    buf = update(buf, jnp.float32(1.0))
    # everything 32-bit by construction (trace-audit QF901 applies to
    # instrumented programs too)
    for leaf in jax.tree.leaves(buf):
        assert leaf.dtype in (jnp.int32, jnp.float32)

    metrics, hists, fresh = flush(spec, buf)
    assert metrics["steps"] == 8
    assert metrics["ret"] == 1.0          # last write wins
    assert metrics["peak"] == 2.5         # running max
    assert hists["lat"]["counts"] == [2, 2, 2, 2]
    assert hists["lat"]["edges"] == [0.1, 1.0, 10.0]
    # the returned buffer is a fresh zero tree, safe to keep donating
    assert all(not leaf.any() for leaf in jax.tree.leaves(fresh))
    m2, _, _ = flush(spec, fresh)
    assert m2["steps"] == 0 and m2["peak"] == 0.0


def test_metric_spec_rejects_bad_shapes():
    with pytest.raises(ValueError, match="duplicate"):
        MetricSpec(counters=("x",), gauges=("x",))
    with pytest.raises(ValueError, match="sorted"):
        MetricSpec(hists=(("h", (2.0, 1.0)),))
    with pytest.raises(ValueError, match="edge"):
        MetricSpec(hists=(("h", ()),))


# ---------------------------------------------------------------------------
# FixedHistogram: percentiles within bucket resolution, bounded state
# ---------------------------------------------------------------------------


def test_histogram_percentiles_track_numpy_within_resolution():
    rng = np.random.RandomState(0)
    samples = np.exp(rng.normal(-7.0, 1.0, size=2000))  # ~1ms-ish
    h = FixedHistogram()
    for s in samples:
        h.observe(float(s))
    for q in (10, 50, 90, 99):
        exact = float(np.percentile(samples, q))
        approx = h.percentile(q)
        # log-spaced edges at 16/decade: ~15.5% relative resolution
        assert exact / 1.2 <= approx <= exact * 1.2, (q, exact, approx)
    assert h.count == len(samples)
    assert np.isclose(h.mean(), samples.mean(), rtol=1e-6)


def test_histogram_state_is_bounded_and_ends_clamp():
    h = FixedHistogram(log_edges(1e-3, 1e0, per_decade=4))
    n_buckets = len(h.counts)
    for v in (1e-9, 5e-2, 1e6):           # below, inside, above range
        for _ in range(100):
            h.observe(v)
    assert len(h.counts) == n_buckets     # memory never grows
    assert h.counts[0] == 100 and h.counts[-1] == 100
    # open-end percentiles clamp to the observed extremes
    assert h.percentile(0) == pytest.approx(1e-9)
    assert h.percentile(100) == pytest.approx(1e6)
    d = h.to_dict()
    assert len(d["counts"]) == len(d["edges"]) + 1
    h.reset()
    assert h.count == 0 and not any(h.counts)


# ---------------------------------------------------------------------------
# JSONL sink: schema validation, round-trip, append mode
# ---------------------------------------------------------------------------


def test_jsonl_roundtrip_and_append(tmp_path):
    p = str(tmp_path / "m" / "train.jsonl")   # parent dir auto-created
    with JsonlSink(p, run={"algo": "dqn", "env": "cartpole"}) as sink:
        sink.write({"schema": "obs/v1", "kind": "step", "t_wall": 1.0,
                    "step": 1, "window": [0, 2],
                    "metrics": {"env_steps": 64, "return_mean": 9.5},
                    "spans": {"step": 0.25},
                    "hists": {"h": {"edges": [1.0], "counts": [0, 3]}}})
    # append mode: reopening continues the same file
    with JsonlSink(p) as sink:
        sink.write({"schema": "obs/v1", "kind": "profile",
                    "t_wall": 2.0, "dir": "/tmp/prof",
                    "window": [0, 2]})
    recs = read_records(p)
    assert [r["kind"] for r in recs] == ["meta", "step", "profile"]
    assert recs[0]["run"]["algo"] == "dqn"
    assert recs[1]["metrics"]["env_steps"] == 64


@pytest.mark.parametrize("rec, err", [
    ({"schema": "obs/v2", "kind": "step", "t_wall": 0.0}, "schema"),
    ({"schema": "obs/v1", "kind": "stepz", "t_wall": 0.0}, "kind"),
    ({"schema": "obs/v1", "kind": "meta", "t_wall": 0.0}, "run"),
    ({"schema": "obs/v1", "kind": "step", "t_wall": 0.0, "step": 1,
      "window": [3, 1], "metrics": {}, "spans": {}}, "window"),
    ({"schema": "obs/v1", "kind": "step", "t_wall": 0.0, "step": 1,
      "window": [0, 1], "metrics": {"x": True}, "spans": {}}, "number"),
    ({"schema": "obs/v1", "kind": "serve", "t_wall": 0.0,
      "window": [0, 1], "metrics": {}, "buckets": {},
      "hists": {"h": {"edges": [1.0], "counts": [1]}}}, "counts"),
    ({"schema": "obs/v1", "kind": "serve", "t_wall": 0.0,
      "window": [0, 1], "metrics": {},
      "hists": {"h": {"edges": [1.0], "counts": [0, -1]}},
      "buckets": {}}, "negative"),
    ({"schema": "obs/v1", "kind": "serve", "t_wall": 0.0,
      "window": [0, 1], "metrics": {}, "hists": {},
      "buckets": {"big": 3}}, "digit"),
])
def test_validate_record_rejects_malformed(rec, err):
    with pytest.raises(ValueError, match=err):
        validate_record(rec)


def test_sink_refuses_to_write_invalid_records(tmp_path):
    sink = JsonlSink(str(tmp_path / "x.jsonl"))
    with pytest.raises(ValueError):
        sink.write({"schema": "obs/v1", "kind": "nope", "t_wall": 0.0})
    sink.close()
    assert read_records(sink.path) == []


def test_span_clock_accumulates_and_drains():
    clock = SpanClock()
    with clock("step"):
        pass
    with clock("step"):
        pass
    with clock("sync"):
        pass
    spans = clock.drain()
    assert set(spans) == {"step", "sync"}
    assert spans["step"] >= 0.0
    assert clock.drain() == {}            # drained


# ---------------------------------------------------------------------------
# the load-bearing contract: metrics do not perturb training
# ---------------------------------------------------------------------------


def test_value_train_bitwise_parity_and_jsonl_content(tmp_path):
    """dqn with --metrics-dir is bitwise identical to without, and the
    JSONL step windows tile [0, iters) with exact env-step counts."""
    from repro.rl.trainer import value_train

    kw = dict(iters=6, n_envs=8, rollout_len=4, verbose=False,
              replay_capacity=512, seed=5, learn_start=32,
              log_every=2, updates_per_iter=1)
    p0, h0 = value_train("dqn", "cartpole", **kw)
    m = str(tmp_path / "metrics")
    p1, h1 = value_train("dqn", "cartpole", metrics_dir=m, **kw)
    assert h0 == h1
    assert _tree_equal(p0, p1)

    path = os.path.join(m, "train.jsonl")
    recs = read_records(path)
    assert recs[0]["kind"] == "meta"
    assert recs[0]["run"]["algo"] == "dqn"
    steps = _step_records(path)
    _assert_contiguous([r["window"] for r in steps], 0, kw["iters"])
    total = sum(r["metrics"]["env_steps"] for r in steps)
    assert total == kw["iters"] * kw["n_envs"] * kw["rollout_len"]
    last = steps[-1]["metrics"]
    for key in ("return_mean", "epsilon", "replay_size",
                "steps_per_s"):
        assert key in last
    assert last["replay_size"] > 0
    assert all("step" in r["spans"] for r in steps)


def test_onpolicy_train_bitwise_parity(tmp_path):
    from repro.rl.trainer import rl_train

    kw = dict(iters=4, n_envs=8, rollout_len=8, verbose=False,
              seed=2, log_every=2, algo="ppo")
    p0, h0 = rl_train("cartpole", **kw)
    m = str(tmp_path / "metrics")
    p1, h1 = rl_train("cartpole", metrics_dir=m, **kw)
    assert h0 == h1
    assert _tree_equal(p0, p1)

    steps = _step_records(os.path.join(m, "train.jsonl"))
    _assert_contiguous([r["window"] for r in steps], 0, kw["iters"])
    total = sum(r["metrics"]["env_steps"] for r in steps)
    assert total == kw["iters"] * kw["n_envs"] * kw["rollout_len"]
    assert "alive_frac" in steps[-1]["metrics"]
    assert "sync_payload_bytes" in steps[-1]["metrics"]


def test_sharded_value_train_bitwise_parity(tmp_path):
    from repro.rl.trainer import value_train

    kw = dict(iters=6, n_envs=8, rollout_len=4, verbose=False,
              replay_capacity=512, seed=9, learn_start=32,
              log_every=2, mesh_kind="host", mesh_devices=1,
              sync="lockstep")
    p0, h0 = value_train("dqn", "cartpole", **kw)
    m = str(tmp_path / "metrics")
    p1, h1 = value_train("dqn", "cartpole", metrics_dir=m, **kw)
    assert h0 == h1
    assert _tree_equal(p0, p1)
    steps = _step_records(os.path.join(m, "train.jsonl"))
    last = steps[-1]["metrics"]
    assert "alive_frac" in last and "staleness_max" in last


def test_resume_continues_metric_windows(tmp_path):
    """A checkpoint-resumed run appends to the same JSONL file and its
    first window starts exactly at the resume step — windows stay
    contiguous across the preemption."""
    from repro.rl.trainer import value_train

    d = str(tmp_path / "ck")
    m = str(tmp_path / "metrics")
    kw = dict(iters=6, n_envs=8, rollout_len=4, verbose=False,
              replay_capacity=512, seed=11, learn_start=32,
              log_every=2, mesh_kind="host", mesh_devices=1,
              sync="lockstep", save_every=2, updates_per_iter=1)
    value_train("dqn", "cartpole", ckpt_dir=d, metrics_dir=m, **kw)
    path = os.path.join(m, "train.jsonl")
    n_first = len(read_records(path))
    # drop the last checkpoint to simulate preemption after it=4,
    # rerun the same command line: resumes at it=3
    for sfx in (".npz", ".npz.json"):
        os.unlink(os.path.join(d, f"step_4{sfx}"))
    value_train("dqn", "cartpole", ckpt_dir=d, metrics_dir=m, **kw)
    recs = read_records(path)
    resumed = recs[n_first:]
    assert resumed[0]["kind"] == "meta"   # second run header
    windows = [r["window"] for r in resumed if r["kind"] == "step"]
    _assert_contiguous(windows, 3, kw["iters"])


# ---------------------------------------------------------------------------
# serving: bounded latency state, bucket counters, telemetry windows
# ---------------------------------------------------------------------------


def _mlp_server(max_bucket=8):
    from repro.rl.inference import build_env, make_value_agent
    from repro.serve import PolicyServer, ServedPolicy

    env = build_env("cartpole", "mlp")
    agent = make_value_agent("dqn", env.spec,
                             key=jax.random.PRNGKey(0), net="mlp")
    policy = ServedPolicy.from_agent(agent, "cartpole")
    return PolicyServer(policy, precision="w8", max_bucket=max_bucket)


def test_server_latency_state_is_bounded():
    server = _mlp_server()
    n_buckets = len(server.latency_hist()["counts"])
    for _ in range(40):
        server.act(jnp.zeros((8, 4)))
    # the unbounded per-request list is gone; state stays O(buckets)
    assert not hasattr(server, "_latencies_s")
    assert len(server.latency_hist()["counts"]) == n_buckets
    assert n_buckets == len(LATENCY_EDGES_S) + 1
    s = server.stats()
    assert s["requests"] == 40 * 8
    assert s["p99_ms"] >= s["p50_ms"] > 0
    assert sum(server.latency_hist()["counts"]) == s["requests"]
    assert sum(server.bucket_requests().values()) == s["requests"]
    server.reset_stats()
    assert not any(server.latency_hist()["counts"])
    assert server.bucket_requests() == {}


def test_serve_episodes_telemetry_matches_stats(tmp_path):
    from repro.serve import serve_episodes

    server = _mlp_server()
    path = str(tmp_path / "serve.jsonl")
    sink = JsonlSink(path, run={"algo": "dqn", "env": "cartpole"})
    st = serve_episodes(server, episodes=6, n_slots=8, seed=0,
                        telemetry=sink, flush_every=3)
    sink.close()

    s = st.server
    serves = [r for r in read_records(path) if r["kind"] == "serve"]
    assert len(serves) >= 2               # flushed mid-run and at end
    # request-count windows tile [0, total requests)
    _assert_contiguous([r["window"] for r in serves],
                       0, s["requests"])
    assert sum(r["metrics"]["requests"] for r in serves) \
        == s["requests"]
    assert sum(r["metrics"]["env_steps"] for r in serves) \
        == st.env_steps
    # per-window bucket deltas sum to the engine's counters
    buckets = {}
    for r in serves:
        for b, n in r["buckets"].items():
            buckets[int(b)] = buckets.get(int(b), 0) + n
    assert buckets == server.bucket_requests()
    # folding the per-window hist deltas reproduces the engine's
    # percentiles within bucket resolution
    rows = summarize_file(path)
    fields = next(f for t, _, f in rows if t == "obs/serve")
    assert fields["requests"] == s["requests"]
    for q, key in ((50, "p50_ms"), (99, "p99_ms")):
        assert fields[key] == pytest.approx(s[key], rel=0.35)


# ---------------------------------------------------------------------------
# summary rendering + CLI
# ---------------------------------------------------------------------------


def _load_cli():
    spec = importlib.util.spec_from_file_location(
        "obs_summary", os.path.join(ROOT, "tools", "obs_summary.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _write_train_file(path):
    with JsonlSink(path, run={"algo": "dqn", "env": "cartpole"}) as s:
        s.write({"schema": "obs/v1", "kind": "step", "t_wall": 1.0,
                 "step": 1, "window": [0, 2],
                 "metrics": {"env_steps": 64, "episodes": 3,
                             "return_mean": 12.5},
                 "spans": {"step": 0.5, "sync": 0.1}})
        s.write({"schema": "obs/v1", "kind": "step", "t_wall": 2.0,
                 "step": 3, "window": [2, 4],
                 "metrics": {"env_steps": 64, "episodes": 2,
                             "return_mean": 20.0},
                 "spans": {"step": 0.3, "checkpoint": 0.1}})


def test_summarize_folds_step_records(tmp_path):
    p = str(tmp_path / "train.jsonl")
    _write_train_file(p)
    out = render(summarize(read_records(p)))
    assert "[obs/train] dqn/cartpole:" in out
    assert "iters=4" in out and "env_steps=128" in out
    assert "episodes=5" in out and "final_return=20.0" in out
    assert "steps_per_s=128.0" in out     # 128 steps / 1.0s spans
    assert "[obs/spans] dqn/cartpole:" in out
    assert "step=0.8" in out and "sync=0.1" in out


def test_obs_summary_cli_renders_and_validates(tmp_path, capsys):
    cli = _load_cli()
    p = str(tmp_path / "train.jsonl")
    _write_train_file(p)

    assert cli.main([p]) == 0
    out = capsys.readouterr().out
    assert "[obs/train] dqn/cartpole:" in out

    assert cli.main([p, "--validate"]) == 0
    assert "3 valid records" in capsys.readouterr().out

    bad = str(tmp_path / "bad.jsonl")
    with open(bad, "w") as f:
        f.write(json.dumps({"schema": "obs/v1", "kind": "nope",
                            "t_wall": 0.0}) + "\n")
    assert cli.main([bad, "--validate"]) == 1
    assert "INVALID" in capsys.readouterr().err
