"""Layer-level unit tests: shapes, numerics, quantized-vs-fp proximity,
decode-vs-full-sequence consistency for every stateful layer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FP32, FXP8, W8A8, QuantPolicy
from repro.nn.attention import (AttnConfig, attention_apply,
                                attention_decode, attention_init,
                                init_cache)
from repro.nn.conv import (causal_conv1d_apply, causal_conv1d_init,
                           conv2d_apply, conv2d_init, qconv_block)
from repro.nn.linear import (embedding_apply, embedding_attend,
                             embedding_init, linear_apply, linear_init)
from repro.nn.lstm import lstm_apply, lstm_cell, lstm_init
from repro.nn.mlp import mlp_apply, mlp_init, swiglu_apply, swiglu_init
from repro.nn.module import unbox
from repro.nn.moe import moe_apply, moe_init
from repro.nn.norm import (layernorm_apply, layernorm_init, rmsnorm_apply,
                           rmsnorm_init)
from repro.nn.rglru import (recurrent_block_apply, recurrent_block_init,
                            recurrent_block_init_state, rglru_apply,
                            rglru_init)
from repro.nn.rotary import apply_rope
from repro.nn.ssm import (SSMConfig, ssm_apply, ssm_init, ssm_init_state)

K = jax.random.PRNGKey


def test_linear_quantized_close_to_fp():
    p = unbox(linear_init(K(0), 64, 32, axes=("d_model", "d_ff")))
    x = jax.random.normal(K(1), (4, 64))
    fp = linear_apply(p, x, FP32)
    q8 = linear_apply(p, x, W8A8)
    rel = float(jnp.abs(fp - q8).max() / jnp.abs(fp).max())
    assert rel < 0.05


def test_embedding_tied_head():
    p = unbox(embedding_init(K(0), 100, 16, axes=("vocab", "d_model")))
    ids = jnp.array([[1, 5, 99]])
    e = embedding_apply(p, ids)
    assert e.shape == (1, 3, 16)
    logits = embedding_attend(p, e)
    assert logits.shape == (1, 3, 100)
    # row i of logits should peak at token i for a near-orthogonal table
    assert int(jnp.argmax(logits[0, 2])) == 99


def test_norms():
    p = unbox(rmsnorm_init(K(0), 32))
    x = jax.random.normal(K(1), (2, 5, 32)) * 10
    y = rmsnorm_apply(p, x)
    rms = jnp.sqrt(jnp.mean(y ** 2, -1))
    np.testing.assert_allclose(np.asarray(rms), 1.0, atol=1e-3)
    pl = unbox(layernorm_init(K(0), 32))
    yl = layernorm_apply(pl, x)
    np.testing.assert_allclose(np.asarray(yl.mean(-1)), 0.0, atol=1e-4)


def test_rope_is_rotation():
    x = jax.random.normal(K(0), (1, 8, 2, 16))
    pos = jnp.arange(8)[None, :]
    y = apply_rope(x, pos)
    # norms preserved
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               rtol=1e-5)
    # relative property: <R(p)q, R(p+d)k> depends only on d
    q = jax.random.normal(K(1), (1, 1, 1, 16))
    k = jax.random.normal(K(2), (1, 1, 1, 16))
    def score(pq, pk):
        rq = apply_rope(q, jnp.array([[pq]]))
        rk = apply_rope(k, jnp.array([[pk]]))
        return float((rq * rk).sum())
    assert abs(score(3, 5) - score(10, 12)) < 1e-3


@pytest.mark.parametrize("n_kv", [8, 2, 1])
def test_attention_gqa_shapes_and_causality(n_kv):
    cfg = AttnConfig(d_model=64, n_heads=8, n_kv_heads=n_kv, head_dim=8)
    p = unbox(attention_init(K(0), cfg))
    x = jax.random.normal(K(1), (2, 10, 64))
    y = attention_apply(p, x, cfg, FP32)
    assert y.shape == (2, 10, 64)
    # causality: future perturbation must not change past outputs
    x2 = x.at[:, 7:].set(jax.random.normal(K(2), (2, 3, 64)))
    y2 = attention_apply(p, x2, cfg, FP32)
    np.testing.assert_allclose(np.asarray(y[:, :7]),
                               np.asarray(y2[:, :7]), atol=1e-5)


def test_attention_sliding_window():
    cfg = AttnConfig(d_model=32, n_heads=4, n_kv_heads=4, head_dim=8,
                     window=3)
    p = unbox(attention_init(K(0), cfg))
    x = jax.random.normal(K(1), (1, 12, 32))
    y = attention_apply(p, x, cfg, FP32)
    # tokens more than `window` back must not influence the output
    x2 = x.at[:, 0:2].set(0.0)
    y2 = attention_apply(p, x2, cfg, FP32)
    np.testing.assert_allclose(np.asarray(y[:, 8:]),
                               np.asarray(y2[:, 8:]), atol=1e-5)


@pytest.mark.parametrize("kv_bits", [32, 8])
def test_attention_decode_matches_prefill(kv_bits):
    cfg = AttnConfig(d_model=32, n_heads=4, n_kv_heads=2, head_dim=8)
    p = unbox(attention_init(K(0), cfg))
    x = jax.random.normal(K(1), (2, 6, 32))
    full = attention_apply(p, x, cfg, FP32)
    # prefill first 3 tokens, then decode 3 more one at a time
    _, cache = attention_apply(p, x[:, :3], cfg, FP32, return_cache=True,
                               cache=init_cache(2, 6, 2, 8, kv_bits),
                               kv_bits=kv_bits)
    outs = []
    for t in range(3, 6):
        o, cache = attention_decode(p, x[:, t:t + 1], cfg, cache,
                                    jnp.int32(t), FP32, kv_bits=kv_bits)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    tol = 1e-5 if kv_bits == 32 else 0.06
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, 3:]),
                               atol=tol)


def test_qconv_block():
    p = unbox(conv2d_init(K(0), 3, 16, 3))
    x = jax.random.normal(K(1), (2, 32, 32, 3))
    y = qconv_block(p, x, stride=2, policy=FXP8)
    assert y.shape == (2, 16, 16, 16)
    assert bool((y >= 0).all())          # ReLU applied


def test_causal_conv1d_decode_matches_full():
    p = unbox(causal_conv1d_init(K(0), 8, width=4))
    x = jax.random.normal(K(1), (2, 6, 8))
    full = causal_conv1d_apply(p, x)
    state = jnp.zeros((2, 3, 8))
    outs = []
    for t in range(6):
        o, state = causal_conv1d_apply(p, x[:, t:t + 1], state)
        outs.append(o)
    dec = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=1e-5)


def test_lstm_shapes_and_fxp8_close():
    p = unbox(lstm_init(K(0), 16, 32))
    x = jax.random.normal(K(1), (4, 10, 16))
    hs, (h, c) = lstm_apply(p, x, FP32)
    assert hs.shape == (4, 10, 32) and h.shape == (4, 32)
    hs8, _ = lstm_apply(p, x, FXP8.replace(act_backend="cordic"))
    assert float(jnp.abs(hs8 - hs).max()) < 0.15


def test_lstm_pallas_path_matches_xla_path():
    pol8 = QuantPolicy(name="fxp8", w_bits=8, a_bits=8,
                       act_backend="cordic", cordic_iters=13)
    p = unbox(lstm_init(K(0), 16, 32))
    x = jax.random.normal(K(1), (4, 16))
    h = jnp.zeros((4, 32)); c = jnp.zeros((4, 32))
    h_x, c_x = lstm_cell(p, x, h, c, pol8.with_backend("xla"))
    h_p, c_p = lstm_cell(p, x, h, c, pol8.with_backend("pallas"))
    # same math modulo per-tensor vs per-row activation scales
    assert float(jnp.abs(h_p - h_x).max()) < 0.05


def test_swiglu_and_mlp():
    p = unbox(swiglu_init(K(0), 32, 64))
    x = jax.random.normal(K(1), (2, 5, 32))
    assert swiglu_apply(p, x, FP32).shape == (2, 5, 32)
    p2 = unbox(mlp_init(K(0), 32, 64))
    assert mlp_apply(p2, x, W8A8).shape == (2, 5, 32)


@pytest.mark.parametrize("E,k", [(8, 2), (16, 4)])
def test_moe_routes_and_preserves_shape(E, k):
    p = unbox(moe_init(K(0), 32, 64, E))
    x = jax.random.normal(K(1), (2, 8, 32))
    y = moe_apply(p, x, top_k=k, policy=FP32, capacity_factor=2.0)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    # with generous capacity, output must differ from zero for all tokens
    assert float(jnp.abs(y).sum(-1).min()) > 0


def test_moe_quantized_close_to_fp():
    p = unbox(moe_init(K(0), 32, 64, 8))
    x = jax.random.normal(K(1), (2, 8, 32))
    fp = moe_apply(p, x, top_k=2, policy=FP32, capacity_factor=4.0)
    q8 = moe_apply(p, x, top_k=2, policy=W8A8, capacity_factor=4.0)
    assert float(jnp.abs(fp - q8).max() / (jnp.abs(fp).max() + 1e-9)) < 0.1


def test_ssm_decode_matches_full():
    cfg = SSMConfig(d_model=16, d_inner=32, head_dim=8, d_state=16,
                    n_groups=1, chunk=4)
    p = unbox(ssm_init(K(0), cfg))
    x = jax.random.normal(K(1), (2, 8, 16))
    full = ssm_apply(p, x, cfg, FP32)
    state = ssm_init_state(2, cfg)
    outs = []
    for t in range(8):
        o, state = ssm_apply(p, x[:, t:t + 1], cfg, FP32, state=state)
        outs.append(o)
    dec = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=2e-3, rtol=1e-2)


def test_rglru_decode_matches_scan():
    p = unbox(rglru_init(K(0), 16))
    x = jax.random.normal(K(1), (2, 8, 16))
    full, last = rglru_apply(p, x, FP32)
    h = jnp.zeros((2, 16))
    outs = []
    for t in range(8):
        o, h = rglru_apply(p, x[:, t:t + 1], FP32, state=h)
        outs.append(o)
    dec = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(last), atol=1e-5)


def test_recurrent_block_decode_matches_full():
    p = unbox(recurrent_block_init(K(0), 16, 32))
    x = jax.random.normal(K(1), (2, 6, 16))
    full = recurrent_block_apply(p, x, FP32)
    state = recurrent_block_init_state(2, 32)
    outs = []
    for t in range(6):
        o, state = recurrent_block_apply(p, x[:, t:t + 1], FP32,
                                         state=state)
        outs.append(o)
    dec = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=1e-4)
