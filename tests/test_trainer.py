"""Trainer-layer tests: the TrainState schema, FleetSync staleness,
checkpoint compatibility across the refactor, and the sharded value
path's bit-exactness contracts."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.rl.actor_learner import (FleetSync, collect_value,
                                    collect_value_sharded, pack_weights,
                                    slot_key, slot_keys)
from repro.rl.trainer import (STATE_SCHEMA, OnPolicyTrainer, TrainState,
                              ValueTrainer, value_eval)


def _tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        bool(jnp.array_equal(x, y)) for x, y in zip(la, lb))


# ---------------------------------------------------------------------------
# TrainState schema
# ---------------------------------------------------------------------------


def test_trainstate_flattens_with_index_keys():
    """TrainState registers SequenceKey (index) tree paths, so its
    checkpoint keys are "0/..".."5/.." — identical to the legacy value
    6-tuple layout — and None slots contribute no leaves."""
    ts = TrainState({"w": jnp.ones(2)}, None, {"m": jnp.zeros(3)},
                    None, jnp.ones(4), jnp.ones(5))
    paths = jax.tree_util.tree_flatten_with_path(ts)[0]
    idx = [p[0].idx for p, _ in paths]
    assert idx == [0, 2, 4, 5]
    # tree ops rebuild the NamedTuple, not a plain tuple
    out = jax.tree.map(lambda x: x + 1, ts)
    assert isinstance(out, TrainState) and out.target is None


def test_trainstate_checkpoint_keys_match_legacy_tuple(tmp_path):
    """A value checkpoint written as a TrainState restores through the
    legacy 6-tuple template bitwise, and vice versa — the serving
    loader's tuple templates keep working unchanged."""
    k = jax.random.PRNGKey(0)
    ts = TrainState({"w": jax.random.normal(k, (3, 2))},
                    {"w": jnp.zeros((3, 2))}, {"mu": jnp.ones(2)},
                    jnp.arange(4.0), jnp.arange(3), jnp.arange(6.0))
    d1 = str(tmp_path / "a")
    mgr = CheckpointManager(d1, save_every=1)
    mgr.save(0, ts, metadata={"schema": STATE_SCHEMA})
    legacy, md = mgr.restore(tuple(jax.tree.map(jnp.zeros_like, ts)))
    assert md["schema"] == STATE_SCHEMA
    assert _tree_equal(tuple(ts), legacy)

    d2 = str(tmp_path / "b")
    mgr2 = CheckpointManager(d2, save_every=1)
    mgr2.save(0, tuple(ts))                       # legacy tuple layout
    back, _ = mgr2.restore(jax.tree.map(jnp.zeros_like, ts))
    assert isinstance(back, TrainState)
    assert _tree_equal(ts, back)


def test_unknown_schema_is_refused_by_name(tmp_path):
    d = str(tmp_path / "ck")
    tr = ValueTrainer("dqn", "cartpole", iters=2, n_envs=4,
                      rollout_len=4, ckpt_dir=d, save_every=1,
                      verbose=False)
    state = tr.init_state()
    mgr = CheckpointManager(d, save_every=1)
    mgr.save(0, state, metadata={"schema": "trainstate/v999",
                                 "algo": "dqn"})
    with pytest.raises(ValueError, match="trainstate/v999"):
        tr.restore(mgr, state)


def test_legacy_onpolicy_checkpoint_restores_through_compat_template(
        tmp_path):
    """A schema-less on-policy checkpoint (the pre-TrainState 4-tuple
    ``(params, opt, est, obs)``) restores through the trainer's compat
    template into a TrainState."""
    d = str(tmp_path / "ck")
    tr = OnPolicyTrainer("cartpole", iters=2, n_envs=4, rollout_len=4,
                         ckpt_dir=d, save_every=1, verbose=False)
    state = tr.init_state()
    mgr = CheckpointManager(d, save_every=1)
    # write the legacy layout with legacy metadata (no schema)
    mgr.save(0, (state.params, state.opt, state.est, state.obs),
             metadata={"stage": "all", "stage_iter": 0})
    got, md = tr.restore(mgr, jax.tree.map(jnp.zeros_like, state))
    assert isinstance(got, TrainState) and got.replay is None
    assert _tree_equal(got, state)
    assert tr.resume_start(md) == 1


# ---------------------------------------------------------------------------
# FleetSync
# ---------------------------------------------------------------------------


def test_fleetsync_staleness_derives_alive_mask():
    fs = FleetSync(3, max_lag=1)
    fs.push("v0")
    assert fs.fetch() == "v0"
    assert fs.alive().tolist() == [True] * 3
    # slot 2 stops fetching: it ages one version per push until it
    # falls past max_lag and drops out of alive()
    fs.push("v1")
    fs.fetch(0, slots=[0, 1])
    assert fs.staleness().tolist() == [0, 0, 1]
    assert fs.alive().tolist() == [True, True, True]
    fs.push("v2")
    fs.fetch(0, slots=[0, 1])
    assert fs.staleness().tolist() == [0, 0, 2]
    assert fs.alive().tolist() == [True, True, False]


def test_fleetsync_doublebuf_fetch_lags_one_version():
    fs = FleetSync(2, max_lag=1)
    fs.push("v0")
    assert fs.fetch(1) == "v0"         # clamped to the oldest retained
    fs.push("v1")
    assert fs.fetch(1) == "v0"
    fs.push("v2")
    assert fs.fetch(1) == "v1"
    assert fs.alive().tolist() == [True, True]


# ---------------------------------------------------------------------------
# sharded value path: bit-exactness contracts
# ---------------------------------------------------------------------------


def test_slot_key_matches_slot_keys_and_keeps_slot0_identity():
    key = jax.random.PRNGKey(42)
    ks = slot_keys(key, 4)
    assert bool(jnp.array_equal(ks[0], key))       # slot 0: raw key
    for i in range(4):
        assert bool(jnp.array_equal(slot_key(key, jnp.asarray(i)),
                                    ks[i]))


def test_collect_value_sharded_1dev_bitwise_vs_local():
    from repro.core.policy import get_policy
    from repro.launch.mesh import make_host_mesh
    from repro.rl.inference import build_env, make_value_agent
    from repro.rl.rollout import init_envs

    env = build_env("cartpole", "mlp")
    agent = make_value_agent("dqn", env.spec, jax.random.PRNGKey(0))
    pol = get_policy("fxp8")
    packed = pack_weights(agent.behaviour_subtree(agent.params), 8)
    mesh = make_host_mesh(1)
    key = jax.random.PRNGKey(5)
    est, obs = init_envs(env, jax.random.PRNGKey(1), 8)
    est_m, obs_m = init_envs(env, jax.random.PRNGKey(1), 8, mesh=mesh)
    eps = jnp.asarray(0.3)
    (s1, o1), t1 = collect_value(packed, env, agent.behave, pol, key,
                                 est, obs, 6, eps)
    (s2, o2), t2 = collect_value_sharded(packed, env, agent.behave,
                                         pol, key, est_m, obs_m, 6,
                                         eps, mesh)
    assert _tree_equal((o1, t1), (o2, t2))
    assert _tree_equal(s1, s2)


@pytest.mark.parametrize("replay", ["uniform", "per"])
def test_sharded_value_training_1dev_bitwise_vs_legacy(replay):
    """The whole training loop — collect, replay, learner, weight sync
    — is bit-exact between the legacy single-device path and the
    sharded path on a 1-device mesh (slot-0 RNG identity + 1-device
    psum/pmax identities)."""
    from repro.rl.trainer import value_train

    kw = dict(iters=6, n_envs=8, rollout_len=8, verbose=False,
              replay_capacity=1024, seed=3, learn_start=64,
              replay=replay)
    p_legacy, h_legacy = value_train("dqn", "cartpole", **kw)
    p_shard, h_shard = value_train("dqn", "cartpole", mesh_kind="host",
                                   mesh_devices=1, sync="lockstep",
                                   **kw)
    assert h_legacy == h_shard
    assert _tree_equal(p_legacy, p_shard)


def test_sharded_per_resume_is_bitwise(tmp_path):
    """A preempted sharded PER run resumes bitwise in lockstep mode:
    the per-slot sum-tree state, pointers included, round-trips the
    checkpoint and the fold_in stream replays from the global step."""
    import os

    from repro.rl.trainer import value_train

    d = str(tmp_path / "ck")
    kw = dict(iters=6, n_envs=8, rollout_len=8, verbose=False,
              replay_capacity=1024, seed=11, learn_start=64,
              replay="per", mesh_kind="host", mesh_devices=1,
              sync="lockstep", save_every=2, updates_per_iter=2)
    full_out = {}
    p_full, h_full = value_train("dqn", "cartpole", ckpt_dir=d,
                                 state_out=full_out, **kw)
    # drop the last checkpoint to simulate preemption after it=4, then
    # resume with the same command line
    for sfx in (".npz", ".npz.json"):
        os.unlink(os.path.join(d, f"step_4{sfx}"))
    resumed_out = {}
    p_res, h_res = value_train("dqn", "cartpole", ckpt_dir=d,
                               state_out=resumed_out, **kw)
    assert h_res == h_full[3:]       # resumed at it=3 (step_2 + 1)
    assert _tree_equal(p_full, p_res)
    assert _tree_equal(full_out["replay"], resumed_out["replay"])


def test_sharded_per_doublebuf_resume_continues(tmp_path):
    """Doublebuf resume re-primes the weight mailbox (the FleetSync
    buffer is not part of the checkpoint, so the first resumed collect
    sees the freshest pack instead of the lag-1 one) — it must still
    resume at the right step and train to completion."""
    import os

    from repro.rl.trainer import value_train

    d = str(tmp_path / "ck")
    kw = dict(iters=6, n_envs=8, rollout_len=8, verbose=False,
              replay_capacity=1024, seed=11, learn_start=64,
              replay="per", mesh_kind="host", mesh_devices=1,
              sync="doublebuf", save_every=2, updates_per_iter=2)
    _, h_full = value_train("dqn", "cartpole", ckpt_dir=d, **kw)
    assert len(h_full) == 6
    for sfx in (".npz", ".npz.json"):
        os.unlink(os.path.join(d, f"step_4{sfx}"))
    p_res, h_res = value_train("dqn", "cartpole", ckpt_dir=d, **kw)
    assert len(h_res) == 3           # resumed at it=3 (step_2 + 1)
    assert all(np.isfinite(r) for r in h_res)


def test_sharded_checkpoint_refuses_mismatched_slot_layout(tmp_path):
    """A checkpoint whose sharded-replay slot layout (or weight-sync
    mode) differs from the relaunch flags is refused by the metadata
    gate, before any tree restore."""
    d = str(tmp_path / "ck")
    tr = ValueTrainer("dqn", "cartpole", iters=2, n_envs=8,
                      rollout_len=4, ckpt_dir=d, save_every=1,
                      verbose=False, mesh_kind="host", mesh_devices=1)
    state = tr.init_state()
    mgr = CheckpointManager(d, save_every=1)
    mgr.save(0, state, metadata={**tr.metadata(0, None),
                                 "schema": STATE_SCHEMA,
                                 "replay_slots": 4})
    with pytest.raises(ValueError, match="4 replay slot"):
        tr.restore(mgr, state)
    mgr.save(1, state, metadata={**tr.metadata(1, None),
                                 "schema": STATE_SCHEMA,
                                 "sync": "doublebuf"})
    with pytest.raises(ValueError, match="--sync"):
        tr.restore(mgr, state)


# ---------------------------------------------------------------------------
# the shared evaluation head
# ---------------------------------------------------------------------------


def test_value_trainer_eval_policy_is_value_eval():
    tr = ValueTrainer("dqn", "cartpole", iters=1, n_envs=4,
                      rollout_len=4, verbose=False)
    params = tr.agent.params
    got = tr.eval_policy(params, n_envs=4, n_steps=24,
                         actor_policy="fxp8", seed=2)
    want = value_eval("dqn", "cartpole", params, n_envs=4, n_steps=24,
                      actor_policy="fxp8", seed=2)
    assert got == want


def test_onpolicy_trainer_eval_policy_runs_greedy_head():
    tr = OnPolicyTrainer("cartpole", iters=1, n_envs=4, rollout_len=4,
                         verbose=False)
    ret, n_ep = tr.eval_policy(tr.init_state().params, n_envs=4,
                               n_steps=32)
    assert np.isfinite(ret) and n_ep >= 0
    # Box action spaces route through the TanhGaussian mode
    trb = OnPolicyTrainer("pendulum", iters=1, n_envs=4, rollout_len=4,
                          verbose=False)
    retb, _ = trb.eval_policy(trb.init_state().params, n_envs=4,
                              n_steps=16)
    assert np.isfinite(retb)
