"""Sharded actor-fleet tests (shard_map over the mesh's data axes).

The multi-device cases need forced host devices, which must be set
before the jax backend initializes — CI runs this file in its own job
under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (see
.github/workflows/ci.yml); in a plain single-device tier-1 run those
cases skip and the subprocess test below still exercises the full
8-device training path end-to-end.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policy import FXP8
from repro.launch.mesh import make_host_mesh
from repro.nn.module import unbox
from repro.rl import init_envs
from repro.rl.actor_learner import (collect, collect_sharded, fleet_mask,
                                    pack_weights)
from repro.rl.envs import make
from repro.rl.nets import mlp_ac_apply, mlp_ac_init

multi_device = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


def _fleet(n_envs, key_seed=1, mesh=None):
    env = make("cartpole")
    params = unbox(mlp_ac_init(jax.random.PRNGKey(0), 4, 2))
    packed = pack_weights(params, 8)
    est, obs = init_envs(env, jax.random.PRNGKey(key_seed), n_envs,
                         mesh=mesh)
    return env, packed, est, obs


# -- always-on (any device count) ----------------------------------------

def test_one_device_shard_map_bit_exact_vs_plain_rollout():
    """The 1-device sharded path degenerates to the plain collect:
    bit-exact on every leaf (same key stream: fold_in(key, 0))."""
    mesh = make_host_mesh(1)
    env, packed, est, obs = _fleet(8, mesh=mesh)
    key = jax.random.PRNGKey(2)
    res = collect_sharded(packed, env, mlp_ac_apply, FXP8, key, est, obs,
                          16, mesh)
    ref = collect(packed, env, mlp_ac_apply, FXP8,
                  jax.random.fold_in(key, 0), est, obs, 16)
    for a, b in zip(jax.tree.leaves(res), jax.tree.leaves(ref), strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_collect_sharded_composes_with_jit():
    mesh = make_host_mesh(1)
    env, packed, est, obs = _fleet(4, mesh=mesh)
    fn = jax.jit(lambda p, k, e, o: collect_sharded(
        p, env, mlp_ac_apply, FXP8, k, e, o, 8, mesh))
    res = fn(packed, jax.random.PRNGKey(2), est, obs)
    assert res.traj.rewards.shape == (8, 4)
    assert np.all(np.isfinite(np.asarray(res.traj.log_probs)))


def test_fleet_mask_layout():
    m = fleet_mask(jnp.array([True, False, True]), 4)
    np.testing.assert_array_equal(np.asarray(m),
                                  np.repeat([1.0, 0.0, 1.0], 4))


@pytest.mark.skipif(
    jax.device_count() >= 8,
    reason="already multi-device: the in-process tests below cover this "
           "without paying for a second jax startup")
def test_rl_train_forced_8dev_subprocess():
    """End-to-end acceptance path: rl_train on a forced 8-device host
    mesh, sharded actors, int8 sync — run in a subprocess because the
    device count must be fixed before the jax backend initializes."""
    code = (
        "from repro.launch.rl_train import rl_train\n"
        "import jax\n"
        "assert jax.device_count() == 8, jax.device_count()\n"
        "params, hist = rl_train(env_name='cartpole', iters=2,\n"
        "                        n_envs=16, rollout_len=8)\n"
        "assert len(hist) == 2\n"
        "print('SHARDED_TRAIN_OK')\n"
    )
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORM_NAME="cpu",
               PYTHONPATH="src" + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          cwd=root, capture_output=True, text=True,
                          timeout=540)
    assert proc.returncode == 0, proc.stderr
    assert "SHARDED_TRAIN_OK" in proc.stdout
    assert "8 devices" in proc.stdout          # mesh banner printed


@pytest.mark.skipif(
    jax.device_count() >= 8,
    reason="already multi-device: the in-process tests below cover this "
           "without paying for a second jax startup")
def test_value_train_forced_8dev_subprocess():
    """The value-family counterpart: qrdqn over 8 sharded actor slots,
    per-slot PER shards, double-buffered int8 weight sync."""
    code = (
        "from repro.launch.rl_train import value_train\n"
        "import jax\n"
        "assert jax.device_count() == 8, jax.device_count()\n"
        "params, hist = value_train('qrdqn', 'cartpole', iters=3,\n"
        "                           n_envs=16, rollout_len=8,\n"
        "                           replay='per', replay_capacity=2048,\n"
        "                           learn_start=64, mesh_kind='host',\n"
        "                           sync='doublebuf')\n"
        "assert len(hist) == 3\n"
        "print('SHARDED_VALUE_OK')\n"
    )
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORM_NAME="cpu",
               PYTHONPATH="src" + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          cwd=root, capture_output=True, text=True,
                          timeout=540)
    assert proc.returncode == 0, proc.stderr
    assert "SHARDED_VALUE_OK" in proc.stdout
    assert "8 actor slot(s) x 2 envs" in proc.stdout


# -- forced multi-device ---------------------------------------------------

@multi_device
def test_uneven_envs_raise():
    mesh = make_host_mesh(8)
    env, packed, est, obs = _fleet(12)
    with pytest.raises(ValueError, match="does not divide"):
        collect_sharded(packed, env, mlp_ac_apply, FXP8,
                        jax.random.PRNGKey(2), est, obs, 4, mesh)


@multi_device
def test_rl_train_rejects_uneven_envs_on_explicit_mesh():
    """--mesh-devices is a hard constraint; only the default host mesh
    auto-fits its device count to n_envs."""
    from repro.launch.rl_train import rl_train
    with pytest.raises(ValueError, match="divisible"):
        rl_train(env_name="cartpole", iters=1, n_envs=12, rollout_len=4,
                 mesh_devices=8, verbose=False)


@multi_device
def test_rl_train_default_mesh_autofits_odd_n_envs(capsys):
    """n_envs=12 on an 8-device host degrades to the largest dividing
    prefix (6 slots) instead of failing."""
    from repro.launch.rl_train import rl_train
    _, hist = rl_train(env_name="cartpole", iters=1, n_envs=12,
                       rollout_len=4, verbose=True)
    out = capsys.readouterr().out
    assert "6 actor slot(s) x 2 envs" in out
    assert len(hist) == 1


@multi_device
def test_eight_device_parity_vs_manual_per_device_collect():
    """The sharded fleet must equal 8 independent per-device collects
    (fold_in(key, d) streams) concatenated along the env axis —
    bit-exact, including the resumable final env state."""
    mesh = make_host_mesh(8)
    n_envs, T = 16, 12
    env, packed, est, obs = _fleet(n_envs, mesh=mesh)
    key = jax.random.PRNGKey(2)
    res = collect_sharded(packed, env, mlp_ac_apply, FXP8, key, est, obs,
                          T, mesh)
    per = n_envs // 8
    for d in range(8):
        sl = slice(d * per, (d + 1) * per)
        est_d = jax.tree.map(lambda x: x[sl], est)
        ref = collect(packed, env, mlp_ac_apply, FXP8,
                      jax.random.fold_in(key, d), est_d, obs[sl], T)
        np.testing.assert_array_equal(np.asarray(res.traj.obs[:, sl]),
                                      np.asarray(ref.traj.obs))
        np.testing.assert_array_equal(np.asarray(res.traj.actions[:, sl]),
                                      np.asarray(ref.traj.actions))
        np.testing.assert_array_equal(np.asarray(res.last_value[sl]),
                                      np.asarray(ref.last_value))
        for a, b in zip(jax.tree.leaves(res.final_env),
                        jax.tree.leaves(ref.final_env), strict=True):
            np.testing.assert_array_equal(np.asarray(a)[sl],
                                          np.asarray(b))


@multi_device
def test_sharded_result_resumes_collection():
    """final_env/final_obs of a sharded collect feed straight back in."""
    mesh = make_host_mesh(8)
    env, packed, est, obs = _fleet(16, mesh=mesh)
    r1 = collect_sharded(packed, env, mlp_ac_apply, FXP8,
                         jax.random.PRNGKey(2), est, obs, 8, mesh)
    r2 = collect_sharded(packed, env, mlp_ac_apply, FXP8,
                         jax.random.PRNGKey(3), r1.final_env,
                         r1.final_obs, 8, mesh)
    assert r2.traj.rewards.shape == (8, 16)
    assert np.all(np.isfinite(np.asarray(r2.traj.log_probs)))


@multi_device
def test_sharded_train_smoke_in_process():
    from repro.launch.rl_train import rl_train
    params, hist = rl_train(env_name="cartpole", iters=2, n_envs=16,
                            rollout_len=8, verbose=False)
    assert len(hist) == 2
    assert all(np.isfinite(h) for h in hist)


@multi_device
def test_eight_device_value_collect_parity_vs_per_slot():
    """The sharded value-family fleet must equal 8 independent
    per-slot ``collect_value`` runs under the ``slot_keys`` streams
    (slot 0 the raw key, others fold_in) concatenated along the env
    axis — bit-exact, final env state included."""
    from repro.core.policy import get_policy
    from repro.rl.actor_learner import (collect_value,
                                        collect_value_sharded, slot_keys)
    from repro.rl.inference import build_env, make_value_agent

    mesh = make_host_mesh(8)
    n_envs, T = 16, 12
    env = build_env("cartpole", "mlp")
    agent = make_value_agent("dqn", env.spec, jax.random.PRNGKey(0))
    packed = pack_weights(agent.behaviour_subtree(agent.params), 8)
    pol = get_policy("fxp8")
    key = jax.random.PRNGKey(2)
    est, obs = init_envs(env, jax.random.PRNGKey(1), n_envs, mesh=mesh)
    eps = jnp.asarray(0.2)
    (est_s, obs_s), traj_s = collect_value_sharded(
        packed, env, agent.behave, pol, key, est, obs, T, eps, mesh)
    ks = slot_keys(key, 8)
    per = n_envs // 8
    for d in range(8):
        sl = slice(d * per, (d + 1) * per)
        est_d = jax.tree.map(lambda x: x[sl], est)
        (est_r, obs_r), traj_r = collect_value(
            packed, env, agent.behave, pol, ks[d], est_d, obs[sl], T,
            eps)
        np.testing.assert_array_equal(np.asarray(obs_s[sl]),
                                      np.asarray(obs_r))
        for a, b in zip(jax.tree.leaves(est_s),
                        jax.tree.leaves(est_r), strict=True):
            np.testing.assert_array_equal(np.asarray(a)[sl],
                                          np.asarray(b))
        for a, b in zip(jax.tree.leaves(traj_s),
                        jax.tree.leaves(traj_r), strict=True):
            np.testing.assert_array_equal(np.asarray(a)[:, sl],
                                          np.asarray(b))


@multi_device
def test_sharded_value_train_smoke_in_process():
    """qrdqn + per-slot PER shards + doublebuf int8 sync over the full
    8-slot mesh, in process (CI's multidevice job runs this file under
    forced 8 host devices)."""
    from repro.rl.trainer import value_train
    params, hist = value_train("qrdqn", "cartpole", iters=3, n_envs=16,
                               rollout_len=8, verbose=False,
                               replay="per", replay_capacity=2048,
                               learn_start=64, mesh_kind="host",
                               sync="doublebuf")
    assert len(hist) == 3
    assert all(np.isfinite(h) for h in hist)
