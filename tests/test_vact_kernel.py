"""V-ACT Pallas kernel vs oracles: kinds x iterations x shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policy import cordic_iterations, FXP8, FXP16, FXP32
from repro.kernels.vact import ops, ref

KINDS = ["relu", "sigmoid", "tanh"]
SHAPES = [(8, 128), (256, 128), (100, 100), (3, 7), (1, 513)]
ITERS = [6, 7, 13]

# CORDIC truncation error ~ 2^-n plus fp32 noise
TOL = {6: 3e-2, 7: 1.5e-2, 13: 5e-4}


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("n_iters", ITERS)
def test_vact_kernel_vs_cordic_oracle(kind, shape, n_iters):
    x = jax.random.normal(jax.random.PRNGKey(hash((kind, shape)) % 2**31),
                          shape) * 4.0
    out = ops.vact(x, kind, n_iters)
    expect = ref.vact(x, kind, n_iters)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-6, rtol=1e-5)


@pytest.mark.parametrize("kind", ["sigmoid", "tanh"])
@pytest.mark.parametrize("n_iters", ITERS)
def test_vact_kernel_vs_native(kind, n_iters):
    """CORDIC approximation error against jax.nn, bounded by schedule."""
    x = jnp.linspace(-8, 8, 2048).reshape(16, 128)
    out = ops.vact(x, kind, n_iters)
    native = jnp.tanh(x) if kind == "tanh" else jax.nn.sigmoid(x)
    err = float(jnp.abs(out - native).max())
    assert err < TOL[n_iters], (kind, n_iters, err)


@pytest.mark.parametrize("shape", [(8, 128), (64, 50), (2, 1000)])
def test_vact_softmax_kernel(shape):
    x = jax.random.normal(jax.random.PRNGKey(0), shape) * 5.0
    out = ops.vact(x, "softmax", 13)
    np.testing.assert_allclose(np.asarray(out.sum(-1)), 1.0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jax.nn.softmax(x, axis=-1)),
                               atol=1e-3)


@pytest.mark.parametrize("kind", ["sigmoid", "tanh", "relu"])
def test_vact_q8_fused(kind):
    """int8-in/int8-out fused path: one LSB (1/127) accuracy."""
    qx = jax.random.randint(jax.random.PRNGKey(1), (32, 128), -128, 128,
                            dtype=jnp.int8)
    sx = 0.05
    out = ops.vact_q8(qx, sx, kind, 13)
    expect = ref.vact_q8(qx, jnp.float32(sx), kind, 13)
    assert out.dtype == jnp.int8
    # relu of an exact grid is exact; cordic kinds within 1 LSB
    diff = np.abs(np.asarray(out, np.int32) - np.asarray(expect, np.int32))
    assert diff.max() <= 1


def test_iteration_schedule_matches_paper_formula():
    """(3n/8 + 1) iterations per precision, floored at 6."""
    assert cordic_iterations(FXP32) == 13      # 3*32/8+1
    assert cordic_iterations(FXP16) == 7       # 3*16/8+1
    assert cordic_iterations(FXP8) == 6        # 3*8/8+1=4 -> floor 6
