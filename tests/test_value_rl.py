"""End-to-end tests for the off-policy value-based drivers.

Smoke training budgets are CPU-sized: the floors assert "clearly
learned" (far above the untrained/random policy), not SOTA.  The
greedy evaluation (`value_eval`) is used instead of the training-chunk
returns because long-horizon envs complete few episodes per chunk.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.launch.rl_train import (make_value_agent, value_eval,
                                   value_train)
from repro.rl.envs import make

DQN_KW = dict(env_name="cartpole", iters=300, n_envs=32, rollout_len=8,
              updates_per_iter=8, lr=5e-4, verbose=False)
DDPG_KW = dict(env_name="pendulum", iters=600, n_envs=32, rollout_len=8,
               updates_per_iter=8, lr=1e-3, n_step=3, verbose=False)


@pytest.mark.slow
def test_dqn_smoke_cartpole_reaches_floor():
    """Double-DQN with the fxp8 behaviour actor balances cartpole far
    beyond the ~10-step greedy-untrained baseline."""
    params, hist = value_train("dqn", actor_policy="fxp8", seed=0,
                               **DQN_KW)
    assert all(np.isfinite(h) for h in hist)
    ret, n_ep = value_eval("dqn", "cartpole", params, n_envs=16,
                           actor_policy="fxp8")
    assert n_ep > 0
    assert ret > 150.0, f"dqn stuck at {ret:.1f}"


@pytest.mark.slow
def test_qrdqn_smoke_cartpole_reaches_floor():
    params, _ = value_train("qrdqn", actor_policy="fxp8", seed=0,
                            **DQN_KW)
    ret, _ = value_eval("qrdqn", "cartpole", params, n_envs=16,
                        actor_policy="fxp8")
    assert ret > 100.0, f"qrdqn stuck at {ret:.1f}"


@pytest.mark.slow
def test_ddpg_smoke_pendulum_reaches_floor():
    """TD3-style DDPG on the continuous pendulum: the greedy policy
    must land far above the ~-1580 untrained baseline."""
    params, _ = value_train("ddpg", actor_policy="fxp8", seed=0,
                            **DDPG_KW)
    ret, _ = value_eval("ddpg", "pendulum", params, n_envs=16,
                        actor_policy="fxp8")
    assert ret > -1100.0, f"ddpg stuck at {ret:.1f}"


@pytest.mark.slow
def test_dqn_fxp8_parity_with_fp32():
    """Fig. 3a for the value-based family: the quantized behaviour
    actor reaches returns comparable to the fp32 baseline at an equal
    step budget."""
    p32, _ = value_train("dqn", actor_policy=None, seed=0, **DQN_KW)
    p8, _ = value_train("dqn", actor_policy="fxp8", seed=0, **DQN_KW)
    r32, _ = value_eval("dqn", "cartpole", p32, n_envs=16)
    r8, _ = value_eval("dqn", "cartpole", p8, n_envs=16,
                       actor_policy="fxp8")
    assert r32 > 150.0 and r8 > 150.0
    assert r8 >= 0.5 * r32, f"fxp8 {r8:.1f} vs fp32 {r32:.1f}"


@pytest.mark.parametrize("algo,env_name",
                         [("qrdqn", "cartpole"), ("ddpg", "pendulum")])
@pytest.mark.parametrize("actor_policy", ["fxp8", None])
def test_value_algos_train_under_both_precisions(algo, env_name,
                                                 actor_policy):
    """Acceptance path: qrdqn/ddpg run end to end under fp32 AND fxp8
    behaviour actors (tiny budget — mechanics, not learning).
    learn_start=32 < the 128 collected transitions, so the sampled
    learner updates genuinely run and must move the params."""
    agent0 = make_value_agent(algo, make(env_name).spec,
                              jax.random.PRNGKey(0))
    params, hist = value_train(algo, env_name, iters=4, n_envs=8,
                               rollout_len=4, updates_per_iter=1,
                               learn_start=32,
                               actor_policy=actor_policy, verbose=False)
    assert len(hist) == 4 and all(np.isfinite(h) for h in hist)
    delta = sum(float(jnp.sum(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(agent0.params),
                                jax.tree.leaves(params), strict=True))
    assert delta > 0, "updates were warmup no-ops"
    ret, _ = value_eval(algo, env_name, params, n_envs=4, n_steps=32,
                        actor_policy=actor_policy)
    assert np.isfinite(ret)


def test_value_train_cli_dispatch(capsys):
    from repro.launch.rl_train import main
    main(["--algo", "qrdqn", "--env", "cartpole", "--iters", "2",
          "--n-envs", "8", "--rollout-len", "4"])
    out = capsys.readouterr().out
    assert "qrdqn on cartpole" in out
    with pytest.raises(ValueError, match="Discrete"):
        main(["--algo", "dqn", "--env", "pendulum", "--iters", "1"])
    with pytest.raises(ValueError, match="Box"):
        main(["--algo", "ddpg", "--env", "cartpole", "--iters", "1"])
    with pytest.raises(ValueError, match="on-policy"):
        main(["--algo", "dqn", "--agent", "hrl", "--iters", "1"])
    # sharding knobs that need a mesh are rejected, not silently
    # dropped; with --mesh host the value loop itself shards
    with pytest.raises(ValueError, match="--mesh host"):
        main(["--algo", "dqn", "--mesh-devices", "8", "--iters", "1"])
    with pytest.raises(ValueError, match="--mesh host"):
        main(["--algo", "dqn", "--sync", "doublebuf", "--iters", "1"])
    with pytest.raises(ValueError, match="value-based"):
        main(["--algo", "ppo", "--sync", "lockstep", "--iters", "1"])
    main(["--algo", "dqn", "--mesh", "host", "--iters", "2",
          "--n-envs", "8", "--rollout-len", "4"])
    out = capsys.readouterr().out
    assert "actor slot(s)" in out and "dqn on cartpole" in out


def test_replay_and_targets_resume_roundtrip(tmp_path):
    """A preempted value-based run relaunched with the same command
    line resumes with the exact replay pointers, target params and
    optimizer state it checkpointed."""
    d = str(tmp_path / "ck")
    # 64 transitions/iter: learn_start=256 is crossed at it=3, so the
    # it=4 checkpoint holds post-update params and a lagged target
    kw = dict(env_name="cartpole", iters=6, n_envs=16, rollout_len=4,
              updates_per_iter=1, ckpt_dir=d, save_every=2,
              verbose=False, seed=3)
    params, hist = value_train("dqn", **kw)
    assert len(hist) == 6

    mgr = CheckpointManager(d)
    assert mgr.latest_step() == 4            # saves at it=2 and it=4
    agent = make_value_agent("dqn", make("cartpole").spec,
                             jax.random.PRNGKey(3))
    from repro.optim import adamw_init
    from repro.rl import init_envs
    from repro.rl.envs.wrappers import ensure_vector_obs
    from repro.rl.value import replay_init
    est0, obs0 = init_envs(ensure_vector_obs(make("cartpole")),
                           jax.random.PRNGKey(3 + 1), 16)
    like = (agent.params, agent.params, adamw_init(agent.params),
            replay_init(50_000, (4,)), est0, obs0)
    (p, tgt, opt, buf, _, _), md = mgr.restore(like)
    assert md["algo"] == "dqn" and md["it"] == 4
    # replay pointers captured exactly: 5 chunks x 16 envs x 4 steps
    assert int(buf.size) == 5 * 16 * 4
    assert int(buf.ptr) == 5 * 16 * 4
    # target is a real polyak-lagged copy, not the online params
    deltas = [float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(tgt), strict=True)]
    assert any(dl > 0 for dl in deltas)

    # relaunch: resumes at it=5 (exactly the missing iteration) and
    # keeps growing the same buffer
    params2, hist2 = value_train("dqn", **kw)
    assert len(hist2) == 1

    # a different algo must refuse the checkpoint loudly
    with pytest.raises(ValueError, match="--algo"):
        value_train("qrdqn", **kw)


def test_value_train_rejects_on_policy_algos():
    from repro.launch.rl_train import rl_train
    with pytest.raises(ValueError, match="value_train"):
        rl_train(env_name="cartpole", iters=1, algo="dqn")
    with pytest.raises(ValueError, match="rl_train"):
        value_train("ppo", "cartpole", iters=1, verbose=False)
