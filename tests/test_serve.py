"""Policy-serving subsystem: int4 packing, micro-batching, checkpoint
loading, and the serve-vs-eval parity guarantee."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fxp import (QTensor, fxp_dtype, fxp_qmax, pack_nibbles,
                            unpack_nibbles)
from repro.core.policy import QuantPolicy, get_policy
from repro.core.quantizer import quantize_params, quantized_nbytes
from repro.launch.rl_train import value_train
from repro.rl.inference import build_env, make_value_agent
from repro.serve import (PolicyServer, ServedPolicy, bucket_for,
                         bucket_sizes, check_parity, load_policy,
                         serve_episodes)


# ---------------------------------------------------------------------------
# int4: grid, nibble packing, sub-byte storage accounting
# ---------------------------------------------------------------------------

def test_int4_quant_grid():
    """4-bit codes live in an int8 container on the symmetric [-7, 7]
    grid (qmax 7), the int4 analogue of int8's [-127, 127]."""
    assert fxp_dtype(4) == jnp.int8
    assert fxp_qmax(4) == 7.0
    w = jax.random.normal(jax.random.PRNGKey(0), (32, 16))
    qt = quantize_params({"w": w}, QuantPolicy(w_bits=4))["w"]
    assert qt.bits == 4
    q = np.asarray(qt.qvalue)
    assert q.min() >= -7 and q.max() <= 7


@pytest.mark.parametrize("n", [8, 9])          # even and odd counts
def test_nibble_roundtrip(n):
    q = jnp.arange(-7, -7 + n, dtype=jnp.int8) % 15 - 7
    packed = pack_nibbles(q)
    assert packed.dtype == jnp.uint8
    assert packed.size == (n + 1) // 2
    back = unpack_nibbles(packed, n)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(q))


def test_quantized_nbytes_sub_byte():
    """int4 QTensors count at their packed width: two codes per byte,
    not the int8 container size."""
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 64))
    q8 = quantize_params({"w": w}, QuantPolicy(w_bits=8))["w"]
    q4 = quantize_params({"w": w}, QuantPolicy(w_bits=4))["w"]
    s8, f8 = quantized_nbytes({"w": q8})
    s4, f4 = quantized_nbytes({"w": q4})
    assert f8 == f4 == 64 * 64 * 4
    scales = 64 * 4                              # fp32 per-channel
    assert s8 == 64 * 64 + scales
    assert s4 == 64 * 64 // 2 + scales
    # odd element counts round the payload up to whole bytes
    odd = QTensor(jnp.zeros((3, 3), jnp.int8), jnp.ones((1, 3)), 4)
    s_odd, _ = quantized_nbytes({"w": odd})
    assert s_odd == (9 * 4 + 7) // 8 + 3 * 4


def test_conv_kernels_pack_on_the_forward_grid():
    """4D conv kernels take per-out-channel scales — the exact grid
    ``conv2d_apply``'s fake-quant uses — while scan-stacked 3D layers
    keep their per-(layer, channel) scales."""
    wc = jax.random.normal(jax.random.PRNGKey(2), (3, 3, 8, 16))
    qc = quantize_params({"w": wc}, QuantPolicy(w_bits=8))["w"]
    assert qc.scale.shape == (1, 1, 1, 16)
    ws = jax.random.normal(jax.random.PRNGKey(3), (4, 8, 16))
    qs = quantize_params({"w": ws}, QuantPolicy(w_bits=8))["w"]
    assert qs.scale.shape == (4, 1, 16)


# ---------------------------------------------------------------------------
# micro-batching: bucket ladder, padding, jit program cache
# ---------------------------------------------------------------------------

def test_bucket_ladder():
    assert bucket_sizes(16) == [1, 2, 4, 8, 16]
    assert bucket_sizes(1) == [1]
    assert bucket_sizes(24) == [1, 2, 4, 8, 16, 24]
    sizes = bucket_sizes(16)
    assert bucket_for(1, sizes) == 1
    assert bucket_for(3, sizes) == 4
    assert bucket_for(16, sizes) == 16


def _mlp_policy(algo="dqn", env_name="cartpole", seed=0):
    env = build_env(env_name, "mlp")
    agent = make_value_agent(algo, env.spec,
                             key=jax.random.PRNGKey(seed), net="mlp")
    return ServedPolicy.from_agent(agent, env_name)


def test_microbatched_actions_match_direct_forward():
    """Chunking + pad-to-bucket must not change a single action: a
    40-request batch through max_bucket=16 equals the direct greedy
    forward over all 40 observations."""
    policy = _mlp_policy()
    server = PolicyServer(policy, precision="w8", max_bucket=16)
    obs = jax.random.normal(jax.random.PRNGKey(5), (40, 4))
    served = server.act(obs)
    direct = policy.agent.greedy(server.served_params, obs,
                                 server.apply_policy)
    np.testing.assert_array_equal(np.asarray(served),
                                  np.asarray(direct))
    # 40 = 16 + 16 + 8: two bucket sizes -> two compiled programs
    assert set(server._jit_cache) == {16, 8}
    assert server.stats()["requests"] == 40


def test_one_program_per_bucket_size():
    policy = _mlp_policy()
    server = PolicyServer(policy, precision="fp32", max_bucket=8)
    for n in (1, 2, 3, 5, 8, 11, 30):
        server.act(jnp.zeros((n, 4)))
    # every request shape mapped onto the ladder {1, 2, 4, 8}
    assert set(server._jit_cache) <= {1, 2, 4, 8}
    stats = server.stats()
    assert stats["jit_programs"] == len(server._jit_cache)
    assert stats["requests"] == 1 + 2 + 3 + 5 + 8 + 11 + 30


def test_sampled_mode_respects_action_space():
    policy = _mlp_policy()
    server = PolicyServer(policy, precision="w8", mode="sample",
                          temperature=0.7, max_bucket=8)
    acts = np.asarray(server.act(jnp.zeros((12, 4))))
    assert acts.shape == (12,)
    assert set(np.unique(acts)) <= {0, 1}
    env = build_env("pendulum", "mlp")
    agent = make_value_agent("ddpg", env.spec,
                             key=jax.random.PRNGKey(1), net="mlp")
    bpolicy = ServedPolicy.from_agent(agent, "pendulum")
    bserver = PolicyServer(bpolicy, precision="w8", mode="sample",
                           temperature=0.5, max_bucket=8)
    bacts = np.asarray(bserver.act(jnp.zeros((12, 3))))
    assert bacts.shape == (12, 1)
    assert (bacts >= agent.cfg.low - 1e-6).all()
    assert (bacts <= agent.cfg.high + 1e-6).all()


def test_serve_episodes_counts_and_stats():
    policy = _mlp_policy()
    server = PolicyServer(policy, precision="w8", max_bucket=8)
    st = serve_episodes(server, episodes=6, n_slots=8, seed=0)
    assert st.episodes >= 6
    assert st.env_steps % 8 == 0
    assert np.isfinite(st.mean_return)
    s = st.server
    assert s["requests"] == st.env_steps
    assert s["actions_per_s"] > 0
    assert s["p99_ms"] >= s["p50_ms"] > 0
    assert s["model_bytes"] < s["model_fp32_bytes"]


# ---------------------------------------------------------------------------
# parity: packed serving == evaluation forward, bit for bit at w8
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo,env_name", [("dqn", "cartpole"),
                                           ("qrdqn", "cartpole"),
                                           ("ddpg", "pendulum")])
def test_w8_parity_mlp(algo, env_name):
    env = build_env(env_name, "mlp")
    agent = make_value_agent(algo, env.spec,
                             key=jax.random.PRNGKey(7), net="mlp")
    policy = ServedPolicy.from_agent(agent, env_name)
    assert check_parity(policy, "w8", n_obs=96) == 0


def test_w8_parity_conv():
    env = build_env("catch", "conv", 2)
    agent = make_value_agent("dqn", env.spec,
                             key=jax.random.PRNGKey(8), net="conv")
    policy = ServedPolicy.from_agent(agent, "catch", net="conv",
                                     frame_stack=2)
    assert check_parity(policy, "w8", n_obs=64) == 0


def test_w8_qvalues_bit_identical_not_just_argmax():
    """The strong form: the full Q vectors match, so parity can't be an
    argmax-robustness accident."""
    env = build_env("cartpole", "mlp")
    agent = make_value_agent("dqn", env.spec,
                             key=jax.random.PRNGKey(9), net="mlp")
    pol = get_policy("fxp8")
    obs = jax.random.normal(jax.random.PRNGKey(10), (32, 4))
    packed = quantize_params(agent.params,
                             QuantPolicy(w_bits=8, per_channel=True))
    q_eval = agent.qvals(agent.params, obs, pol)
    q_serve = agent.qvals(packed, obs, pol)
    assert jnp.array_equal(q_eval, q_serve)


def test_parity_rejects_fp32():
    policy = _mlp_policy()
    with pytest.raises(ValueError, match="packed"):
        check_parity(policy, "fp32")


def test_w8_eval_policy_routes_through_shared_greedy_head(dqn_ckpt):
    """The w8 deployment guarantee lifted to the trainer's eval head:
    ``value_eval`` is the shared ``Trainer.eval_policy`` route, and
    substituting the served packed weights into that same greedy head
    reproduces the evaluated return bit for bit."""
    from repro.rl.trainer import ValueTrainer, greedy_eval, value_eval

    policy = load_policy(dqn_ckpt)
    agent = policy.agent
    want = value_eval("dqn", "cartpole", policy.params, n_envs=8,
                      n_steps=32, actor_policy="fxp8", seed=3)
    tr = ValueTrainer("dqn", "cartpole", iters=1, n_envs=4,
                      rollout_len=2, verbose=False)
    assert tr.eval_policy(policy.params, n_envs=8, n_steps=32,
                          actor_policy="fxp8", seed=3) == want
    packed, pol = policy.pack("w8")
    act = lambda p, o: agent.greedy(p, o, pol)  # noqa: E731
    ret_eval = greedy_eval(policy.env, act, policy.params,
                           jax.random.PRNGKey(3 + 17), 8, 32)
    ret_served = greedy_eval(policy.env, act,
                             agent.from_behaviour(packed),
                             jax.random.PRNGKey(3 + 17), 8, 32)
    assert ret_eval == want
    assert ret_served == ret_eval


# ---------------------------------------------------------------------------
# checkpoint loading: metadata validation on the serving path
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def dqn_ckpt(tmp_path_factory):
    d = str(tmp_path_factory.mktemp("serve_ckpt") / "dqn")
    value_train("dqn", "cartpole", iters=6, n_envs=4, rollout_len=2,
                learn_start=8, ckpt_dir=d, save_every=5, verbose=False)
    return d


def test_load_policy_roundtrip(dqn_ckpt):
    policy = load_policy(dqn_ckpt)
    assert (policy.algo, policy.net, policy.env_name) == \
        ("dqn", "mlp", "cartpole")
    assert policy.step == 5
    assert policy.metadata["algo"] == "dqn"
    # the restored params drive the server end to end
    server = PolicyServer(policy, precision="w8", max_bucket=4)
    st = serve_episodes(server, episodes=2, n_slots=4)
    assert st.episodes >= 2
    assert check_parity(policy, "w8", n_obs=32) == 0


@pytest.mark.parametrize("kw,wrong,flag", [
    ("algo", "qrdqn", "--algo"),
    ("net", "conv", "--net"),
    ("env_name", "acrobot", "--env"),
])
def test_load_policy_names_the_mismatched_flag(dqn_ckpt, kw, wrong,
                                               flag):
    """A wrong flag fails with the launcher's own error naming the
    flag — never a missing-leaf KeyError from the tree restore."""
    with pytest.raises(ValueError, match=flag):
        load_policy(dqn_ckpt, **{kw: wrong})


def test_load_policy_missing_dir(tmp_path):
    with pytest.raises(FileNotFoundError):
        load_policy(str(tmp_path / "nope"))


def test_value_train_resume_rejects_net_mismatch(dqn_ckpt):
    """Resuming a checkpoint under a different --net fails with the
    launcher error naming --net (the obs pipeline differs), before any
    tree restore is attempted."""
    with pytest.raises(ValueError, match="--net"):
        value_train("dqn", "catch", iters=1, n_envs=4, rollout_len=2,
                    ckpt_dir=dqn_ckpt, net="conv", frame_stack_k=2,
                    verbose=False)
    with pytest.raises(ValueError, match="--env"):
        value_train("dqn", "acrobot", iters=1, n_envs=4, rollout_len=2,
                    ckpt_dir=dqn_ckpt, verbose=False)


def test_serve_precision_names(dqn_ckpt):
    policy = load_policy(dqn_ckpt)
    with pytest.raises(ValueError, match="precision"):
        policy.pack("w2")
    packed, pol = policy.pack("w4")
    qts = [l for l in jax.tree.leaves(
        packed, is_leaf=lambda l: isinstance(l, QTensor))
        if isinstance(l, QTensor)]
    assert qts and all(q.bits == 4 for q in qts)
    assert pol.a_bits == 8
