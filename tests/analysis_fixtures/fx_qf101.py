"""QF101 fixture: raw contractions in a quantized data-path module."""
import jax
import jax.numpy as jnp


@jax.jit
def bad_head(w, x):
    return jnp.dot(x, w)          # QF101 positive: raw contraction


@jax.jit
def bad_operator(w, x):
    return x @ w                  # QF101 positive: MatMult


@jax.jit
def good_elementwise(w, x):
    return jnp.add(x, w)          # negative: not a contraction
