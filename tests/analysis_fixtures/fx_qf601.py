"""QF601 fixture: bare print() in library code vs sanctioned output."""

print("loading")                                 # QF601 module positive


def noisy_helper(x):
    print(f"x = {x}")                            # QF601 positive
    return x + 1


def quiet_helper(x, console):
    console.info(f"x = {x}")                     # negative: Console
    return x + 1


class Reporter:
    def render(self, stream):
        stream.write("done\n")                   # negative: stream API

    def dump(self):
        print("report")                          # QF601 method positive
