"""QF301 fixture: nondeterministic host calls in jit-reachable code."""
import random
import time

import jax
import numpy as np


@jax.jit
def bad_noise(x):
    return x + np.random.rand()   # QF301 positive: numpy.random


@jax.jit
def bad_clock(x):
    return x * time.time()        # QF301 positive: wall clock


@jax.jit
def bad_shuffle(x):
    return x + random.random()    # QF301 positive: stdlib random


@jax.jit
def good_noise(x, key):
    return x + jax.random.normal(key, x.shape)   # negative: jax.random


def host_timer():
    return time.time()            # negative: not jit-reachable
