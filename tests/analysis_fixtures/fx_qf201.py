"""QF201 fixture: Python control flow on tracers in jit-reachable code."""
import jax
import jax.numpy as jnp


@jax.jit
def bad_branch(x):
    if x.sum() > 0:               # QF201 positive: tracer in `if`
        return x
    return -x


@jax.jit
def bad_len(x):
    y = jnp.tanh(x)
    return len(y)                 # QF201 positive: len() on tracer


def scan_body(carry, x):
    if carry.sum() > 0:           # QF201 positive: reachable via scan
        return carry, x
    return carry, -x


def drive(xs):
    return jax.lax.scan(scan_body, jnp.zeros(3), xs)


@jax.jit
def good_static(x, n: int):
    if x.shape[0] > n:            # negative: shape is static
        return x * 2.0
    return x


@jax.jit
def good_none_guard(x, mask=None):
    if mask is None:              # negative: `is None` is concrete
        return x
    return x * mask


def table_lookup(x):
    y = jnp.abs(x)
    if y.mean() > 0:              # negative: not jit-reachable
        return y
    return -y
