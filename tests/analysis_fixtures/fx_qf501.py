"""QF501 fixture: env wrappers bypassing the _wrap tagging protocol."""


def _wrap(env, name, *, reset, step):
    step._wrapper_stack = (name,)
    return env.replace(reset=reset, step=step)   # negative: inside _wrap


def bad_wrapper(env):
    def step(state, action):
        return env.step(state, action)

    return env.replace(step=step)                # QF501 positive


def good_wrapper(env):
    def step(state, action):
        return env.step(state, action)

    return _wrap(env, "good", reset=env.reset, step=step)   # negative
