"""Blessed contraction module for the QF101 fixture config."""
import jax.numpy as jnp


def q_matmul(x, w):
    return jnp.dot(x, w)          # blessed module: never flagged
