"""QF401 fixture: jitted state threading without donation."""
from functools import partial

import jax


@jax.jit
def bad_step(params, buf):
    buf = buf.at[0].set(params["w"].sum())
    return params, buf            # QF401 positive: buf not donated


@partial(jax.jit, donate_argnums=(1,))
def good_step(params, buf):
    buf = buf.at[0].set(params["w"].sum())
    return params, buf            # negative: donated


def _local_update(state):
    return state


bad_jit = jax.jit(_local_update)  # QF401 positive: call site
good_jit = jax.jit(_local_update, donate_argnums=(0,))   # negative
