"""Quantized batched serving across architecture families.

Runs the serve driver (PTQ int8 weights + int8 KV/state caches) on a
reduced config of each requested arch and reports footprint + latency.

    PYTHONPATH=src python examples/serve_quantized.py \
        [--archs tinyllama-1.1b,mamba2-2.7b] [--policy w8a8kv8]
"""
import argparse

from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs",
                    default="tinyllama-1.1b,mamba2-2.7b,"
                            "recurrentgemma-9b")
    ap.add_argument("--policy", default="w8a8kv8")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    for arch in args.archs.split(","):
        print(f"\n=== {arch} ({args.policy}) ===")
        serve(arch, smoke=True, policy_name=args.policy,
              batch=args.batch, prompt_len=args.prompt_len,
              gen=args.gen)


if __name__ == "__main__":
    main()
