"""Quickstart: the QForce-RL fabric in five minutes (CPU-friendly).

1. build a small LM from an assigned-architecture family,
2. train a few steps under the FxP8 quantization policy (Q-MAC path),
3. PTQ the weights to int8 (4x smaller),
4. serve a few greedy tokens with an int8 KV cache.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.registry import get_arch
from repro.core.policy import get_policy
from repro.core.quantizer import quantize_params, quantized_nbytes
from repro.data import DataConfig, batch_at
from repro.launch.steps import make_train_step
from repro.models.registry import model_for
from repro.nn.module import count_params, unbox
from repro.optim import adamw_init


def main():
    # -- 1. model ---------------------------------------------------------
    cfg = get_arch("tinyllama-1.1b").reduced()      # same family, tiny
    model = model_for(cfg)
    params = unbox(model.init(jax.random.PRNGKey(0), cfg))
    print(f"model: {cfg.name}  params: {count_params(params):,}")

    # -- 2. quantized training (W8A8: every matmul is a Q-MAC) ------------
    policy = get_policy("w8a8")
    step = jax.jit(make_train_step(cfg, None, policy))
    opt = adamw_init(params)
    data = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8)
    for i in range(5):
        params, opt, stats = step(params, opt, batch_at(data, i))
        print(f"step {i}: loss {float(stats['loss']):.3f} "
              f"(grad norm {float(stats['grad_norm']):.2f})")

    # -- 3. post-training quantization ------------------------------------
    qparams = quantize_params(params, get_policy("w8a8kv8"))
    stored, fp32 = quantized_nbytes(qparams)
    print(f"PTQ: {fp32 / 2**20:.2f} MiB fp32 -> {stored / 2**20:.2f} MiB "
          f"int8 ({fp32 / stored:.2f}x smaller)")

    # -- 4. quantized serving (int8 weights + int8 KV cache) --------------
    serve_policy = get_policy("w8a8kv8")
    prompt = jnp.array([[1, 2, 3, 4, 5, 6, 7, 8]], jnp.int32)
    logits, caches = model.prefill(qparams, prompt, cfg, serve_policy,
                                   kv_bits=8)
    # grow capacity for the generated tokens
    from repro.launch.serve import pad_caches
    caches = pad_caches(caches, 8)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [int(tok[0, 0])]
    for i in range(7):
        logits, caches = model.decode_step(
            qparams, tok, caches,
            jnp.asarray(prompt.shape[1] + i, jnp.int32), cfg,
            serve_policy, kv_bits=8)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(int(tok[0, 0]))
    print("generated token ids:", out)


if __name__ == "__main__":
    main()
