"""E2HRL agent on the KeyDoor gridworld with the paper's two-stage PPO.

The agent is the paper's exact pipeline (3 Q-Conv stride-2 + Q-FC
embedding -> sub-goal module -> concat -> softmax action head), run
under a quantization policy.  Stage 1 trains stem+action+value with
the sub-goal frozen; stage 2 fine-tunes the sub-goal module alone
(paper Sec. III).

    PYTHONPATH=src python examples/hrl_gridworld.py [--iters 30]
"""
import argparse

import jax

from repro.configs.e2hrl import HRLConfig
from repro.core.policy import get_policy
from repro.models import hrl
from repro.nn.module import count_params, unbox
from repro.optim import AdamWConfig, adamw_init, adamw_update, constant
from repro.rl import PPOConfig, batch_from_traj, init_envs, rollout
from repro.rl.envs import make
from repro.rl.ppo import minibatch_epochs, stage_mask
from repro.rl.rollout import episode_returns


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=30)
    ap.add_argument("--policy", default="fxp8")
    ap.add_argument("--n-envs", type=int, default=16)
    args = ap.parse_args()

    env = make("keydoor")
    cfg = HRLConfig(n_actions=env.spec.n_actions)
    policy = get_policy(args.policy)
    params = unbox(hrl.init(jax.random.PRNGKey(0), cfg))
    print(f"E2HRL agent ({cfg.subgoal_kind}-HRL): "
          f"{count_params(params):,} params, actor policy {policy.name}")

    opt = adamw_init(params)
    ocfg = AdamWConfig(weight_decay=0.0, max_grad_norm=0.5)
    pcfg = PPOConfig(ent_coef=0.02)
    sched = constant(1e-3)
    apply_fn = lambda p, o: hrl.apply(p, o, cfg, policy)[:2]
    learner_fn = lambda p, o: hrl.apply(p, o, cfg, None)[:2]
    est, obs = init_envs(env, jax.random.PRNGKey(1), args.n_envs)
    key = jax.random.PRNGKey(2)

    def make_iteration(stage):
        gmask = stage_mask(params, stage)

        @jax.jit
        def iteration(params, opt, est, obs, key):
            k1, k2 = jax.random.split(key)
            res = rollout(params, env, apply_fn, k1, est, obs, 64)
            value_fn = lambda o: learner_fn(params, o)[1]
            batch = batch_from_traj(res.traj, res.last_value, pcfg,
                                    value_fn=value_fn)

            def opt_step(p, s, g):
                p, s, _ = adamw_update(g, s, p, sched, ocfg)
                return p, s

            params, opt, _ = minibatch_epochs(
                k2, params, opt, batch, learner_fn, pcfg, opt_step,
                grad_mask=gmask)
            ret, n = episode_returns(res.traj)
            return params, opt, res.final_env, res.final_obs, ret, n
        return iteration

    for stage in ("action", "subgoal"):
        print(f"--- stage: train {stage} module "
              f"({'sub-goal frozen' if stage == 'action' else 'rest frozen'}) ---")
        iteration = make_iteration(stage)
        for it in range(args.iters):
            key, sub = jax.random.split(key)
            params, opt, est, obs, ret, n = iteration(params, opt, est,
                                                      obs, sub)
            if it % 5 == 0 or it == args.iters - 1:
                print(f"  iter {it:3d}: return {float(ret):6.2f} "
                      f"({int(n)} episodes)")


if __name__ == "__main__":
    main()
