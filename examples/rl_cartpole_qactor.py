"""Q-Actor on CartPole: FP32 learner + int8 actors (paper Fig. 2/3a).

Trains PPO twice — once with FP32 rollout actors, once with FxP8
(int8 weights + activations + CORDIC activations) actors synced over
an int8-compressed channel — and prints the reward curves side by
side.  The expected outcome is parity (the paper's core claim), with
a ~4x smaller learner->actor payload.

Works for any registered vector-obs env — including the continuous
``pendulum`` (tanh-Gaussian PPO head) — via ``--env``:

    PYTHONPATH=src python examples/rl_cartpole_qactor.py [--iters 40] \
        [--env cartpole|acrobot|mountain_car|pendulum]
"""
import argparse

from repro.launch.rl_train import rl_train
from repro.rl.envs import make, registered

# this example drives the MLP agent, so offer only vector-obs envs
VECTOR_ENVS = [n for n in registered() if len(make(n).obs_shape) == 1]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=40)
    ap.add_argument("--env", default="cartpole", choices=VECTOR_ENVS)
    args = ap.parse_args()

    print("=== FP32 actors ===")
    _, hist_fp32 = rl_train(args.env, "mlp", iters=args.iters,
                            actor_policy=None, comm_bits=32,
                            log_every=10)
    print("\n=== FxP8 actors (int8 sync) ===")
    _, hist_q8 = rl_train(args.env, "mlp", iters=args.iters,
                          actor_policy="fxp8", comm_bits=8,
                          log_every=10)

    k = max(len(hist_fp32) // 5, 1)
    tail32 = sum(hist_fp32[-k:]) / k
    tail8 = sum(hist_q8[-k:]) / k
    print(f"\nfinal mean return: FP32 {tail32:.1f}  Q8 {tail8:.1f}  "
          f"(parity {tail8 / max(tail32, 1e-9):.2f})")


if __name__ == "__main__":
    main()
