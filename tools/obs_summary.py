"""Render obs/v1 JSONL telemetry runs as benchmark-style tables.

    PYTHONPATH=src python tools/obs_summary.py /tmp/run/train.jsonl \
        /tmp/run/serve.jsonl [--name dqn/cartpole] [--validate]

Each file is folded into ``[table] name: k=v`` rows — the exact format
:func:`benchmarks.common.emit` prints — so a live training/serving run
reads the same way as a bench script:

    [obs/train] dqn/cartpole: iters=40 env_steps=10240 steps_per_s=...
    [obs/spans] dqn/cartpole: checkpoint=0.11 step=1.23 sync=0.04
    [obs/serve] dqn/cartpole: requests=6400 actions_per_s=... p50_ms=...

``--validate`` only checks every record against the schema (no
rendering) — the CI gate for telemetry produced by the smoke runs.
Exit 1 on any invalid record or unreadable file in either mode.
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="summarize obs/v1 JSONL telemetry files")
    ap.add_argument("files", nargs="+", help="JSONL files to render")
    ap.add_argument("--name", default="",
                    help="row name (default: from the meta record)")
    ap.add_argument("--validate", action="store_true",
                    help="schema-check only, render nothing")
    args = ap.parse_args(argv)

    from repro.obs import read_records, render, summarize

    status = 0
    for path in args.files:
        try:
            records = read_records(path)
        except (OSError, ValueError) as e:
            print(f"{path}: INVALID: {e}", file=sys.stderr)
            status = 1
            continue
        if args.validate:
            print(f"{path}: {len(records)} valid records")
            continue
        out = render(summarize(records, name=args.name))
        if out:
            print(out)
    return status


if __name__ == "__main__":
    sys.exit(main())
